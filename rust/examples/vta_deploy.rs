//! Integer-only deployment on the VTA simulator (the paper's Fig 8
//! scenario): explore the 12-config space of Eq. 23, compare Quantune's
//! per-layer power-of-two scales against the TVM-VTA single-global-scale
//! baseline, and report accuracy + simulated cycles.

use anyhow::Result;

use quantune::calib::{calibrate, CalibBackend};
use quantune::coordinator::Quantune;
use quantune::quant::VtaConfig;
use quantune::vta::VtaModel;
use quantune::zoo;

fn main() -> Result<()> {
    let q = Quantune::open(zoo::artifacts_dir())?;
    let model_name =
        std::env::args().nth(1).unwrap_or_else(|| "rn18".to_string());
    let model = q.load_model(&model_name)?;
    println!(
        "{}: VTA integer-only deployment (fp32 top1 {:.2}%)",
        model.name,
        model.fp32_top1 * 100.0
    );

    let eval_n = 256.min(q.eval.n);
    let idx: Vec<usize> = (0..eval_n).collect();
    let measure = |vm: &VtaModel| -> Result<(f64, u64)> {
        let mut hits = 0;
        let mut cycles = 0u64;
        for chunk in idx.chunks(64) {
            let x = q.eval.batch(chunk);
            let (_, preds, cyc) = vm.forward(&x)?;
            hits += preds
                .iter()
                .zip(&q.eval.labels_for(chunk))
                .filter(|(&p, &l)| p == l as usize)
                .count();
            cycles += cyc.total();
        }
        Ok((hits as f64 / eval_n as f64, cycles / eval_n as u64))
    };

    // the TVM-VTA baseline: one scale for the entire network
    let base_cache = calibrate(
        &model,
        &q.calib_pool,
        quantune::quant::CalibCount::C512,
        &CalibBackend::Interp,
        q.seed,
    )?;
    let global =
        VtaModel::build_global_scale(&model.graph, model.weights_map(), &base_cache.hists, true)?;
    let (gacc, gcyc) = measure(&global)?;
    println!(
        "  TVM-VTA baseline (global scale): top1 {:5.2}%  {} cycles/img ({:.2} ms @100MHz)",
        gacc * 100.0,
        gcyc,
        gcyc as f64 / 100e3
    );

    // Quantune: explore all 12 configs
    println!("  Quantune per-layer configs:");
    let mut best: Option<(VtaConfig, f64, u64)> = None;
    for cfg in VtaConfig::space() {
        let cache = calibrate(
            &model,
            &q.calib_pool,
            cfg.calib,
            &CalibBackend::Interp,
            q.seed,
        )?;
        let vm = VtaModel::build(&model.graph, model.weights_map(), &cache.hists, &cfg)?;
        let (acc, cyc) = measure(&vm)?;
        println!(
            "    {:28} top1 {:5.2}%  {} cycles/img",
            cfg.slug(),
            acc * 100.0,
            cyc
        );
        if best.map_or(true, |(_, a, c)| acc > a || (acc == a && cyc < c)) {
            best = Some((cfg, acc, cyc));
        }
    }
    let (cfg, acc, cyc) = best.unwrap();
    println!(
        "  => Quantune best: {} top1 {:.2}% ({:+.2}% vs global baseline, Fig 8's gap), {} cycles/img",
        cfg.slug(),
        acc * 100.0,
        (acc - gacc) * 100.0,
        cyc
    );
    Ok(())
}
