//! Mixed precision on a fragile model (paper §4.5 + Table 5,
//! generalized to per-layer bit-widths).
//!
//! Depthwise/group-conv models (MobileNet, ShuffleNet) are the paper's
//! "fragile" cases: tiny per-channel weight ranges make tensor-granular
//! int8 lossy. This example shows how keeping the first/last layers in
//! fp32 (the paper's §4.5 mixed precision, derived from the config's
//! `mixed` bit) and switching granularity trades accuracy against model
//! size -- and then how the radix generalization prices arbitrary
//! per-layer {int4, int8, int16, fp32} assignments, which is the space
//! `quantune search --space layerwise --bits 4,8,16` actually explores.

use anyhow::Result;

use quantune::coordinator::{Evaluator, InterpEvaluator, Quantune};
use quantune::quant::{
    model_size_bytes, model_size_bytes_at, model_size_fp32, BitWidth, CalibCount,
    Clipping, Granularity, QuantConfig, Scheme,
};
use quantune::zoo;

fn main() -> Result<()> {
    let q = Quantune::open(zoo::artifacts_dir())?;
    let model_name = std::env::args().nth(1).unwrap_or_else(|| "shn".to_string());
    let model = q.load_model(&model_name)?;
    println!(
        "{} ({}): fp32 top1 {:.2}%",
        model.name,
        zoo::full_name(&model.name),
        model.fp32_top1 * 100.0
    );

    let weight_dims = |layer: &str| {
        let w = model.weights.get(&format!("{layer}_w")).unwrap();
        let b = model.weights.get(&format!("{layer}_b")).unwrap();
        (w.len(), b.len())
    };
    let orig = model_size_fp32(&model.graph, &weight_dims);
    println!("fp32 size: {:.2} KiB", orig as f64 / 1024.0);

    let mut evaluator = InterpEvaluator::new(&model, &q.calib_pool, &q.eval, q.seed);
    println!(
        "{:>9} {:>7} | {:>9} | {:>9} | {:>8}",
        "gran", "mixed", "top1", "drop", "size"
    );
    for gran in [Granularity::Tensor, Granularity::Channel] {
        for mixed in [false, true] {
            let cfg = QuantConfig {
                calib: CalibCount::C512,
                scheme: Scheme::SymmetricUint8,
                clip: Clipping::Max,
                gran,
                mixed,
                bias_correct: false,
            };
            let acc = evaluator.measure(cfg.index())?;
            let size = model_size_bytes(&model.graph, &weight_dims, gran, mixed);
            println!(
                "{:>9} {:>7} | {:>8.2}% | {:>+8.2}% | {:>7.2}K",
                match gran {
                    Granularity::Tensor => "tensor",
                    Granularity::Channel => "channel",
                },
                mixed,
                acc * 100.0,
                (acc - model.fp32_top1) * 100.0,
                size as f64 / 1024.0,
            );
        }
    }
    println!(
        "\nTable 5's shape: channel granularity costs a few % in size;\n\
         mixed precision costs more (first/last layers stay fp32) but\n\
         recovers accuracy on fragile models."
    );

    // the radix generalization: instead of the binary first/last-fp32
    // bypass, every layer carries its own weight bit-width -- here a
    // hand-built ramp (first layer int16, last fp32, everything else
    // int4) priced by the same Table-5 accounting
    let n = model.graph.layers().len();
    let widths: Vec<BitWidth> = (0..n)
        .map(|i| {
            if i == 0 {
                BitWidth::Int16
            } else if i == n - 1 {
                BitWidth::Fp32
            } else {
                BitWidth::Int4
            }
        })
        .collect();
    let radix_size =
        model_size_bytes_at(&model.graph, &weight_dims, Granularity::Tensor, &widths);
    println!(
        "\nper-layer widths (int16 first, int4 middle, fp32 last):\n\
         {:.2} KiB -- int4 packs two weights per byte, so the radix\n\
         search can undercut every binary {{int8, fp32}} mask;\n\
         `quantune search --space layerwise --bits 4,8,16` searches\n\
         these assignments over the most fragile layers.",
        radix_size as f64 / 1024.0,
    );
    Ok(())
}
