//! Mixed precision on a fragile model (paper §4.5 + Table 5).
//!
//! Depthwise/group-conv models (MobileNet, ShuffleNet) are the paper's
//! "fragile" cases: tiny per-channel weight ranges make tensor-granular
//! int8 lossy. This example shows how keeping the first/last layers in
//! fp32 (mixed precision) and switching granularity trades accuracy
//! against model size.

use anyhow::Result;

use quantune::coordinator::{Evaluator, InterpEvaluator, Quantune};
use quantune::quant::{
    model_size_bytes, model_size_fp32, CalibCount, Clipping, Granularity, QuantConfig,
    Scheme,
};
use quantune::zoo;

fn main() -> Result<()> {
    let q = Quantune::open(zoo::artifacts_dir())?;
    let model_name = std::env::args().nth(1).unwrap_or_else(|| "shn".to_string());
    let model = q.load_model(&model_name)?;
    println!(
        "{} ({}): fp32 top1 {:.2}%",
        model.name,
        zoo::full_name(&model.name),
        model.fp32_top1 * 100.0
    );

    let weight_dims = |layer: &str| {
        let w = model.weights.get(&format!("{layer}_w")).unwrap();
        let b = model.weights.get(&format!("{layer}_b")).unwrap();
        (w.len(), b.len())
    };
    let orig = model_size_fp32(&model.graph, &weight_dims);
    println!("fp32 size: {:.2} KiB", orig as f64 / 1024.0);

    let mut evaluator = InterpEvaluator::new(&model, &q.calib_pool, &q.eval, q.seed);
    println!(
        "{:>9} {:>7} | {:>9} | {:>9} | {:>8}",
        "gran", "mixed", "top1", "drop", "size"
    );
    for gran in [Granularity::Tensor, Granularity::Channel] {
        for mixed in [false, true] {
            let cfg = QuantConfig {
                calib: CalibCount::C512,
                scheme: Scheme::SymmetricUint8,
                clip: Clipping::Max,
                gran,
                mixed,
            };
            let acc = evaluator.measure(cfg.index())?;
            let size = model_size_bytes(&model.graph, &weight_dims, gran, mixed);
            println!(
                "{:>9} {:>7} | {:>8.2}% | {:>+8.2}% | {:>7.2}K",
                match gran {
                    Granularity::Tensor => "tensor",
                    Granularity::Channel => "channel",
                },
                mixed,
                acc * 100.0,
                (acc - model.fp32_top1) * 100.0,
                size as f64 / 1024.0,
            );
        }
    }
    println!(
        "\nTable 5's shape: channel granularity costs a few % in size;\n\
         mixed precision costs more (first/last layers stay fp32) but\n\
         recovers accuracy on fragile models."
    );
    Ok(())
}
