//! Search-algorithm comparison on one model (a single-model slice of the
//! paper's Fig 5).
//!
//! Runs all six algorithms -- random, grid, genetic, XGB, XGB-T, and
//! the NSGA-II Pareto search (scored here by its scalar trace; see
//! rust/SEARCH.md) -- against the sweep ground truth in the trial
//! database and prints each one's accuracy-vs-trials convergence.
//! Requires `quantune sweep` (the bench harness runs it automatically;
//! this example asks politely).

use anyhow::{Context, Result};

use quantune::coordinator::{OracleEvaluator, Quantune, GENERAL_SPACE_TAG, PROPOSERS};
use quantune::quant::{general_space, QuantConfig};
use quantune::util::stats::mean;
use quantune::zoo;

fn main() -> Result<()> {
    let q = Quantune::open(zoo::artifacts_dir())?;
    let model_name =
        std::env::args().nth(1).unwrap_or_else(|| "mn".to_string());
    let model = q.load_model(&model_name)?;
    let space = general_space();
    let table =
        q.db.accuracy_table(&model.name, GENERAL_SPACE_TAG, QuantConfig::SPACE_SIZE);
    anyhow::ensure!(
        table.iter().all(|a| !a.is_nan()),
        "no full sweep for {model_name}; run `quantune sweep --models {model_name}`"
    );
    let best = table.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    println!(
        "{model_name}: sweep best {:.2}% (fp32 {:.2}%), eps = 0.1%",
        best * 100.0,
        model.fp32_top1 * 100.0
    );

    // xgb_t needs other models' sweeps
    let transfer_ready = !q
        .transfer_for(&model, space.as_ref())
        .context("loading transfer records")?
        .is_empty();

    let seeds: Vec<u64> = (0..5).collect();
    println!("{:>8} | {:>14} | {:>10} | convergence (best top1 after 1/4/16/48 trials)", "algo", "trials-to-best", "speedup");
    let mut random_mean = None;
    for algo in PROPOSERS {
        if algo == "xgb_t" && !transfer_ready {
            println!("{algo:>8} | (needs other models' sweeps in the database)");
            continue;
        }
        let mut to_best = Vec::new();
        let mut curves = [0.0f64; 4];
        for &seed in &seeds {
            let mut oracle = OracleEvaluator::new(table.clone());
            let trace =
                q.search(&model, &space, algo, &mut oracle, QuantConfig::SPACE_SIZE, seed)?;
            let t =
                trace.trials_to_reach(best, 1e-3).unwrap_or(QuantConfig::SPACE_SIZE) as f64;
            to_best.push(t);
            for (i, &n) in [1usize, 4, 16, 48].iter().enumerate() {
                curves[i] += trace.best_after(n) / seeds.len() as f64;
            }
        }
        let m = mean(&to_best);
        if algo == "random" {
            random_mean = Some(m);
        }
        let speedup = random_mean.map(|r| r / m).unwrap_or(1.0);
        println!(
            "{algo:>8} | {m:>14.1} | {speedup:>9.2}x | {:.1}% / {:.1}% / {:.1}% / {:.1}%",
            curves[0] * 100.0,
            curves[1] * 100.0,
            curves[2] * 100.0,
            curves[3] * 100.0
        );
    }
    Ok(())
}
