//! Quickstart: quantize one model end to end.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example quickstart
//! ```
//!
//! Loads a trained mini CNN from the AOT artifacts, builds a calibration
//! cache, quantizes it under a hand-picked configuration, measures Top-1
//! through the PJRT runtime, and compares against fp32 -- the minimal
//! end-to-end path through all three layers.
//!
//! A `QuantConfig` sets the base axes (calibration, scheme, clipping,
//! granularity) for *every* layer; per-layer precision comes from the
//! layer-wise space, where each fragile layer picks its own weight
//! `BitWidth` (int4 / int8 / int16 / fp32) -- see the `mixed_precision`
//! example and `quantune search --space layerwise --bits 4,8,16`.

use anyhow::Result;

use quantune::coordinator::{Evaluator, HloEvaluator, Quantune};
use quantune::quant::{CalibCount, Clipping, Granularity, QuantConfig, Scheme};
use quantune::runtime::Runtime;
use quantune::zoo;

fn main() -> Result<()> {
    let q = Quantune::open(zoo::artifacts_dir())?;
    let model = q.load_model("sqn")?;
    println!(
        "model: {} ({}) -- {} params, fp32 top1 {:.2}%",
        model.name,
        zoo::full_name(&model.name),
        model.graph.num_params(),
        model.fp32_top1 * 100.0
    );

    let runtime = Runtime::cpu()?;
    println!("PJRT platform: {}", runtime.platform());

    let mut evaluator = HloEvaluator::new(
        &model,
        &runtime,
        q.artifacts.clone(),
        &q.calib_pool,
        &q.eval,
        q.seed,
    );

    // a strong default configuration ...
    let good = QuantConfig {
        calib: CalibCount::C512,
        scheme: Scheme::Asymmetric,
        clip: Clipping::Kl,
        gran: Granularity::Channel,
        mixed: false,
        bias_correct: false,
    };
    // ... and a deliberately weak one
    let weak = QuantConfig {
        calib: CalibCount::C1,
        scheme: Scheme::Pow2,
        clip: Clipping::Max,
        gran: Granularity::Tensor,
        mixed: false,
        bias_correct: false,
    };

    for (label, cfg) in [("weak", weak), ("good", good)] {
        let acc = evaluator.measure(cfg.index())?;
        println!(
            "{label:5} config {:40} -> int8 top1 {:5.2}%  (drop {:+.2}%)",
            cfg.slug(),
            acc * 100.0,
            (acc - model.fp32_top1) * 100.0
        );
    }
    println!(
        "mean measurement time: {:.2}s per config (Table 2's cost on this host)",
        evaluator.mean_measure_secs()
    );
    println!("\nnext: `quantune sweep` for ground truth, `quantune search --algo xgb_t`");
    Ok(())
}
