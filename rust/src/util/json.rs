//! Minimal JSON parser/serializer.
//!
//! The crate has no serde (offline build, vendored deps only), so this
//! module provides the small JSON surface we need: the model metadata
//! emitted by python/compile/aot.py, the trial database `D`, and
//! experiment reports.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Numbers are kept as f64 (adequate for our payloads).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (stored as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys, so serialization is deterministic).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at offset {}", p.i);
        }
        Ok(v)
    }

    /// Parse the JSON document at `path`.
    pub fn from_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
        Json::parse(&text)
    }

    /// Write the pretty-printed document to `path`.
    pub fn write_file(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.pretty())?;
        Ok(())
    }

    // ---- accessors ----

    /// The value as a number, or an error.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    /// The value as a usize (truncating), or an error.
    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    /// The value as a string slice, or an error.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    /// The value as a bool, or an error.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    /// The value as an array slice, or an error.
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("expected array, got {:.60?}", self),
        }
    }

    /// The value as an object map, or an error.
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Ok(o),
            _ => bail!("expected object"),
        }
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    /// Object field access with a default when the key is absent.
    pub fn get_or<'a>(&'a self, key: &str, default: &'a Json) -> &'a Json {
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(default),
            _ => default,
        }
    }

    // ---- constructors ----

    /// Object from (key, value) pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Number value.
    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    /// String value.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Array of numbers from an f64 slice.
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Array of numbers from an f32 slice.
    pub fn arr_f32(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ---- serialization ----

    /// Compact single-line serialization.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Indented serialization (stable across runs: object keys sort).
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(1), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                        if indent.is_some() {
                            out.push(' ');
                        }
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(step) = indent {
                        out.push('\n');
                        for _ in 0..(depth + 1) * step {
                            out.push(' ');
                        }
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if let Some(step) = indent {
                    if !o.is_empty() {
                        out.push('\n');
                        for _ in 0..depth * step {
                            out.push(' ');
                        }
                    }
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at offset {}, got {:?}",
                  c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at offset {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                c => bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                c => bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                c => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let width = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let end = (start + width).min(self.b.len());
                        s.push_str(std::str::from_utf8(&self.b[start..end])?);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| {
            anyhow!("bad number {text:?} at offset {start}: {e}")
        })?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 3, "s": "hi", "a": [1,2]}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize().unwrap(), 3);
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "hi");
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
        assert!(v.get("zzz").is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse(r#""café naïve""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café naïve");
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::obj(vec![
            ("x", Json::arr_f64(&[1.0, 2.5])),
            ("y", Json::str("s")),
        ]);
        assert_eq!(Json::parse(&v.pretty()).unwrap(), v);
    }

    #[test]
    fn integers_stay_integral() {
        let v = Json::Num(42.0);
        assert_eq!(v.dump(), "42");
    }
}
