//! Shared utilities: seeded RNG, minimal JSON, statistics, timing, CSV,
//! and the data-parallel worker pool ([`pool`]).

pub mod json;
pub mod pool;
pub mod rng;
pub mod stats;

pub use json::Json;
pub use pool::Pool;
pub use rng::Pcg32;
pub use stats::nan_min_cmp;

use std::time::Instant;

/// Wall-clock stopwatch returning milliseconds.
pub struct Timer(Instant);

impl Timer {
    /// Start the stopwatch now.
    pub fn start() -> Self {
        Timer(Instant::now())
    }

    /// Elapsed milliseconds.
    pub fn ms(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }

    /// Elapsed seconds.
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

impl Default for Timer {
    fn default() -> Self {
        Self::start()
    }
}

/// Minimal CSV writer (no quoting needs beyond our numeric/slug payloads).
pub struct Csv {
    out: String,
    cols: usize,
}

impl Csv {
    /// Start a CSV with the given header row.
    pub fn new(header: &[&str]) -> Self {
        Csv { out: header.join(",") + "\n", cols: header.len() }
    }

    /// Append one row (arity must match the header).
    pub fn row(&mut self, fields: &[String]) {
        assert_eq!(fields.len(), self.cols, "csv row arity mismatch");
        self.out.push_str(&fields.join(","));
        self.out.push('\n');
    }

    /// The accumulated CSV text.
    pub fn finish(self) -> String {
        self.out
    }

    /// Write the CSV to `path`, creating parent directories.
    pub fn write_file(self, path: &std::path::Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.finish())?;
        Ok(())
    }
}

/// Format seconds as "1.2s" / "340ms".
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.2}s")
    } else {
        format!("{:.1}ms", secs * 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_shape() {
        let mut c = Csv::new(&["a", "b"]);
        c.row(&["1".into(), "2".into()]);
        assert_eq!(c.finish(), "a,b\n1,2\n");
    }

    #[test]
    #[should_panic]
    fn csv_arity_checked() {
        let mut c = Csv::new(&["a", "b"]);
        c.row(&["1".into()]);
    }

    #[test]
    fn duration_fmt() {
        assert_eq!(fmt_duration(2.0), "2.00s");
        assert_eq!(fmt_duration(0.1234), "123.4ms");
    }
}
