//! Seeded PCG32 random number generator.
//!
//! Every stochastic component (search algorithms, calibration image
//! selection, property tests) takes an explicit seed so runs are exactly
//! reproducible; no global RNG state exists anywhere in the crate.

/// PCG-XSH-RR 64/32 (O'Neill 2014). Small, fast, statistically solid.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience constructor with the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 54)
    }

    /// Next uniform u32.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next uniform u64 (two u32 draws).
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` without modulo bias.
    pub fn below(&mut self, bound: usize) -> usize {
        assert!(bound > 0);
        let bound = bound as u32;
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u32();
            if r >= threshold {
                return (r % bound) as usize;
            }
        }
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u32() as f64) / (u32::MAX as f64 + 1.0)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = (1.0 - self.f64()).max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// True with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_in_range() {
        let mut r = Pcg32::seeded(7);
        for _ in 0..1000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn f64_in_unit() {
        let mut r = Pcg32::seeded(9);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniformity_rough() {
        let mut r = Pcg32::seeded(3);
        let mut counts = [0usize; 10];
        for _ in 0..10_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((800..1200).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(5);
        let xs: Vec<f32> = (0..20_000).map(|_| r.normal()).collect();
        let mean: f32 = xs.iter().sum::<f32>() / xs.len() as f32;
        let var: f32 =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(11);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg32::seeded(13);
        let s = r.sample_indices(100, 30);
        assert_eq!(s.len(), 30);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 30);
    }
}
