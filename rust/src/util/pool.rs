//! Dependency-free data-parallel worker pool over std scoped threads.
//!
//! This is the engine behind the parallel evaluation path: the row-tiled
//! GEMM, the batch-level Top-1 measurement, and the (algorithm x seed x
//! config) fan-outs in the experiment drivers all schedule through here.
//!
//! Design rules (enforced by rust/tests/parallel.rs):
//! - **Deterministic ordering**: `run`/`map` return results in input
//!   order no matter which worker produced them, so a parallel reduction
//!   performed in that order is bit-identical to the serial loop.
//! - **Panic safety**: a panicking task poisons the pool, remaining
//!   workers drain, and the call returns an error instead of hanging or
//!   aborting the process.
//! - **No nesting**: work items running on a pool worker see
//!   [`effective_threads`] `== 1`, so nested data parallelism (e.g. the
//!   tiled GEMM inside a batch-parallel evaluator) serializes instead of
//!   oversubscribing the machine.
//!
//! The worker count comes from `QUANTUNE_THREADS` (or the machine's
//! available parallelism); threads are spawned per call, which keeps the
//! pool free of shutdown logic and is noise-level overhead for the
//! coarse-grained work it schedules (whole eval batches, whole search
//! runs, multi-ms GEMM tiles).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{anyhow, Result};

thread_local! {
    static IN_POOL_WORKER: std::cell::Cell<bool> = std::cell::Cell::new(false);
}

/// Process-wide thread-count override (0 = none). Used by benches that
/// A/B the engine within one process; takes precedence over the env.
static OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Set (or clear) the process-wide thread-count override. Intended for
/// single-threaded harness code (benches); not synchronized with pools
/// already running.
pub fn set_thread_override(threads: Option<usize>) {
    OVERRIDE.store(threads.unwrap_or(0), Ordering::Relaxed);
}

/// True on a thread currently executing pool work.
pub fn in_worker() -> bool {
    IN_POOL_WORKER.with(|w| w.get())
}

/// Configured worker count: the `set_thread_override` value if any, else
/// `QUANTUNE_THREADS`, else the machine's available parallelism.
pub fn default_threads() -> usize {
    let forced = OVERRIDE.load(Ordering::Relaxed);
    if forced > 0 {
        return forced;
    }
    if let Some(n) = std::env::var("QUANTUNE_THREADS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
    {
        if n >= 1 {
            return n;
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Worker count data-parallel code should use *right now*: 1 on a pool
/// worker (the outer level owns the cores), else [`default_threads`].
pub fn effective_threads() -> usize {
    if in_worker() {
        1
    } else {
        default_threads()
    }
}

/// A worker-pool configuration. `Copy`-cheap: threads are spawned per
/// `run`/`map` call as scoped threads.
#[derive(Clone, Copy, Debug)]
pub struct Pool {
    threads: usize,
}

impl Pool {
    /// Pool with an explicit worker count (clamped to >= 1).
    pub fn new(threads: usize) -> Pool {
        Pool { threads: threads.max(1) }
    }

    /// Pool sized by [`effective_threads`] (env knob, nesting-aware).
    pub fn auto() -> Pool {
        Pool::new(effective_threads())
    }

    /// Configured worker count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Apply `f` to every index in `0..n`, returning the outputs in index
    /// order. Worker panics surface as an `Err`.
    pub fn run<R, F>(&self, n: usize, f: F) -> Result<Vec<R>>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        self.run_init(n, || (), |(), i| f(i))
    }

    /// Like [`Pool::run`], but each worker first builds a private state
    /// with `init` and threads it through every item it steals — the
    /// hook for per-worker scratch arenas (e.g.
    /// [`crate::interp::InterpScratch`]) that are built once per worker
    /// instead of once per item. The inline (`<= 1` worker) path builds
    /// exactly one state.
    pub fn run_init<S, R, I, F>(&self, n: usize, init: I, f: F) -> Result<Vec<R>>
    where
        R: Send,
        I: Fn() -> S + Sync,
        F: Fn(&mut S, usize) -> R + Sync,
    {
        if n == 0 {
            return Ok(Vec::new());
        }
        let workers = self.threads.min(n);
        if workers <= 1 {
            // the inline path still marks this thread as a worker so a
            // 1-thread pool is *fully* serial: nested Pool::auto() and
            // the tiled GEMM see effective_threads() == 1, same as on a
            // spawned worker
            let _guard = WorkerFlag::enter();
            let mut state = init();
            let mut out = Vec::with_capacity(n);
            for i in 0..n {
                match catch_unwind(AssertUnwindSafe(|| f(&mut state, i))) {
                    Ok(r) => out.push(r),
                    Err(p) => {
                        return Err(anyhow!(
                            "pool worker panicked: {}",
                            panic_message(p.as_ref())
                        ))
                    }
                }
            }
            return Ok(out);
        }

        let next = AtomicUsize::new(0);
        let poisoned = AtomicBool::new(false);
        let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let failure: Mutex<Option<String>> = Mutex::new(None);
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| {
                    IN_POOL_WORKER.with(|w| w.set(true));
                    let mut state = init();
                    loop {
                        if poisoned.load(Ordering::Relaxed) {
                            break;
                        }
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        match catch_unwind(AssertUnwindSafe(|| f(&mut state, i))) {
                            Ok(r) => *slots[i].lock().unwrap() = Some(r),
                            Err(p) => {
                                poisoned.store(true, Ordering::Relaxed);
                                let mut note = failure.lock().unwrap();
                                if note.is_none() {
                                    *note = Some(panic_message(p.as_ref()));
                                }
                                break;
                            }
                        }
                    }
                });
            }
        });
        if poisoned.load(Ordering::Relaxed) {
            let note = failure
                .lock()
                .unwrap()
                .take()
                .unwrap_or_else(|| "unknown panic".to_string());
            return Err(anyhow!("pool worker panicked: {note}"));
        }
        let mut out = Vec::with_capacity(n);
        for slot in slots {
            match slot.into_inner().unwrap() {
                Some(r) => out.push(r),
                None => return Err(anyhow!("pool dropped an item (internal bug)")),
            }
        }
        Ok(out)
    }

    /// Apply `f` to every item of `items`, outputs in input order.
    pub fn map<T, R, F>(&self, items: &[T], f: F) -> Result<Vec<R>>
    where
        T: Sync,
        R: Send,
        F: Fn(&T) -> R + Sync,
    {
        self.run(items.len(), |i| f(&items[i]))
    }

    /// Like [`Pool::map`], but with a per-worker state built by `init`
    /// (see [`Pool::run_init`]).
    pub fn map_init<T, S, R, I, F>(&self, items: &[T], init: I, f: F) -> Result<Vec<R>>
    where
        T: Sync,
        R: Send,
        I: Fn() -> S + Sync,
        F: Fn(&mut S, &T) -> R + Sync,
    {
        self.run_init(items.len(), init, |state, i| f(state, &items[i]))
    }
}

/// RAII flag for the inline path: marks the calling thread as a pool
/// worker and restores the previous state on drop (spawned workers just
/// set the flag — their thread dies with the scope).
struct WorkerFlag {
    prev: bool,
}

impl WorkerFlag {
    fn enter() -> WorkerFlag {
        WorkerFlag { prev: IN_POOL_WORKER.with(|w| w.replace(true)) }
    }
}

impl Drop for WorkerFlag {
    fn drop(&mut self) {
        let prev = self.prev;
        IN_POOL_WORKER.with(|w| w.set(prev));
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_returns_input_order() {
        for threads in [1, 2, 5] {
            let out = Pool::new(threads).run(17, |i| i * 2).unwrap();
            assert_eq!(out, (0..17).map(|i| i * 2).collect::<Vec<_>>());
        }
    }

    #[test]
    fn map_over_items() {
        let items = vec![3u32, 1, 4, 1, 5];
        let out = Pool::new(4).map(&items, |x| x + 1).unwrap();
        assert_eq!(out, vec![4, 2, 5, 2, 6]);
    }

    #[test]
    fn zero_items_is_empty_ok() {
        let items: Vec<u32> = Vec::new();
        assert!(Pool::new(4).map(&items, |x| *x).unwrap().is_empty());
        assert!(Pool::new(1).run(0, |i| i).unwrap().is_empty());
    }

    #[test]
    fn nested_pools_serialize() {
        let out = Pool::new(4)
            .run(8, |i| {
                assert!(in_worker());
                assert_eq!(effective_threads(), 1);
                Pool::auto().run(3, move |j| i * 10 + j).unwrap()
            })
            .unwrap();
        assert_eq!(out[2], vec![20, 21, 22]);
        assert!(!in_worker());
    }

    #[test]
    fn single_thread_pool_marks_worker_inline() {
        let out = Pool::new(1).run(2, |i| (i, in_worker())).unwrap();
        assert_eq!(out, vec![(0, true), (1, true)]);
        assert!(!in_worker(), "flag must be restored after the inline run");
    }

    #[test]
    fn panic_is_error_not_hang() {
        for threads in [1, 4] {
            let err = Pool::new(threads)
                .run(32, |i| {
                    assert!(i != 9, "kaboom");
                    i
                })
                .unwrap_err();
            assert!(format!("{err}").contains("panicked"), "got: {err}");
        }
    }

    #[test]
    fn zero_thread_request_clamps_to_one() {
        assert_eq!(Pool::new(0).threads(), 1);
        assert!(default_threads() >= 1);
    }

    #[test]
    fn run_init_reuses_worker_state() {
        // single worker: one state visits every item in order
        let out = Pool::new(1)
            .run_init(
                5,
                || 0usize,
                |seen, i| {
                    *seen += 1;
                    (i, *seen)
                },
            )
            .unwrap();
        assert_eq!(out, vec![(0, 1), (1, 2), (2, 3), (3, 4), (4, 5)]);
        // multi worker: results stay in input order and every item saw a
        // live (>= 1) state; states are per-worker so counts never exceed n
        for threads in [2, 4] {
            let out = Pool::new(threads)
                .run_init(
                    12,
                    || 0usize,
                    |seen, i| {
                        *seen += 1;
                        (i, *seen)
                    },
                )
                .unwrap();
            assert_eq!(
                out.iter().map(|&(i, _)| i).collect::<Vec<_>>(),
                (0..12).collect::<Vec<_>>()
            );
            assert!(out.iter().all(|&(_, s)| (1..=12).contains(&s)));
        }
    }

    #[test]
    fn map_init_over_items() {
        let items = vec![10u32, 20, 30];
        let out = Pool::new(2)
            .map_init(&items, || 1u32, |bias, x| x + *bias)
            .unwrap();
        assert_eq!(out, vec![11, 21, 31]);
    }
}
