//! Small statistics helpers: summary stats, Shannon entropy (Table 4),
//! latency aggregation, and NaN-safe ranking comparators.

/// Total order on f64 that ranks NaN BELOW every real number (including
/// -inf). `max_by(nan_min_cmp)` therefore never selects a NaN entry
/// unless every entry is NaN, and `sort_by(nan_min_cmp)` sinks NaN to
/// the front instead of panicking. This is the one comparator every
/// ranking site uses: `TrialStore::accuracy_table` fills holes with NaN,
/// so a bare `partial_cmp().unwrap()` on anything downstream of it is a
/// latent panic.
pub fn nan_min_cmp(a: &f64, b: &f64) -> std::cmp::Ordering {
    match (a.is_nan(), b.is_nan()) {
        (true, true) => std::cmp::Ordering::Equal,
        (true, false) => std::cmp::Ordering::Less,
        (false, true) => std::cmp::Ordering::Greater,
        (false, false) => a.total_cmp(b),
    }
}

/// [`nan_min_cmp`] for f32 (a bare `total_cmp` would rank positive NaN
/// ABOVE +inf, so a NaN logit would win an argmax).
pub fn nan_min_cmp_f32(a: &f32, b: &f32) -> std::cmp::Ordering {
    match (a.is_nan(), b.is_nan()) {
        (true, true) => std::cmp::Ordering::Equal,
        (true, false) => std::cmp::Ordering::Less,
        (false, true) => std::cmp::Ordering::Greater,
        (false, false) => a.total_cmp(b),
    }
}

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (0..=100) with linear interpolation.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(nan_min_cmp);
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Shannon entropy (natural log, as in the paper's Eq. 22) of a sample of
/// categorical observations.
pub fn shannon_entropy<T: Eq + std::hash::Hash>(obs: &[T]) -> f64 {
    if obs.is_empty() {
        return 0.0;
    }
    let mut counts = std::collections::HashMap::new();
    for o in obs {
        *counts.entry(o).or_insert(0usize) += 1;
    }
    let n = obs.len() as f64;
    -counts
        .values()
        .map(|&c| {
            let p = c as f64 / n;
            p * p.ln()
        })
        .sum::<f64>()
}

/// Latency summary in milliseconds.
#[derive(Clone, Debug)]
pub struct LatencyStats {
    /// Sample mean.
    pub mean_ms: f64,
    /// Median.
    pub p50_ms: f64,
    /// 99th percentile.
    pub p99_ms: f64,
    /// Fastest sample.
    pub min_ms: f64,
    /// Slowest sample.
    pub max_ms: f64,
    /// Sample count.
    pub n: usize,
}

impl LatencyStats {
    /// Summarize a non-empty sample of millisecond timings.
    pub fn from_samples(samples_ms: &[f64]) -> Self {
        assert!(!samples_ms.is_empty());
        LatencyStats {
            mean_ms: mean(samples_ms),
            p50_ms: percentile(samples_ms, 50.0),
            p99_ms: percentile(samples_ms, 99.0),
            min_ms: samples_ms.iter().cloned().fold(f64::INFINITY, f64::min),
            max_ms: samples_ms.iter().cloned().fold(0.0, f64::max),
            n: samples_ms.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nan_ranks_below_everything() {
        use std::cmp::Ordering;
        assert_eq!(nan_min_cmp(&f64::NAN, &f64::NEG_INFINITY), Ordering::Less);
        assert_eq!(nan_min_cmp(&0.0, &f64::NAN), Ordering::Greater);
        assert_eq!(nan_min_cmp(&f64::NAN, &f64::NAN), Ordering::Equal);
        assert_eq!(nan_min_cmp(&1.0, &2.0), Ordering::Less);
        // max_by over a NaN-holed table picks the real maximum
        let t = [0.3, f64::NAN, 0.9, f64::NAN, 0.5];
        let best = t
            .iter()
            .enumerate()
            .max_by(|a, b| nan_min_cmp(a.1, b.1))
            .map(|(i, _)| i);
        assert_eq!(best, Some(2));
        // percentile no longer panics on NaN samples
        let _ = percentile(&[1.0, f64::NAN, 3.0], 50.0);
        // the f32 variant agrees (bare total_cmp would rank NaN above inf)
        assert_eq!(
            nan_min_cmp_f32(&f32::NAN, &f32::INFINITY),
            Ordering::Less
        );
        let row = [0.1f32, f32::NAN, 0.9];
        let best = row
            .iter()
            .enumerate()
            .max_by(|a, b| nan_min_cmp_f32(a.1, b.1))
            .map(|(i, _)| i);
        assert_eq!(best, Some(2));
    }

    #[test]
    fn mean_stddev() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn entropy_uniform_vs_constant() {
        // constant -> zero entropy
        assert_eq!(shannon_entropy(&[1, 1, 1, 1]), 0.0);
        // uniform over 4 -> ln(4)
        let h = shannon_entropy(&[0, 1, 2, 3]);
        assert!((h - 4f64.ln()).abs() < 1e-12);
        // skewed is in between
        let h2 = shannon_entropy(&[0, 0, 0, 1]);
        assert!(h2 > 0.0 && h2 < h);
    }

    #[test]
    fn latency_stats() {
        let s = LatencyStats::from_samples(&[1.0, 2.0, 3.0]);
        assert_eq!(s.mean_ms, 2.0);
        assert_eq!(s.min_ms, 1.0);
        assert_eq!(s.max_ms, 3.0);
        assert_eq!(s.n, 3);
    }
}
