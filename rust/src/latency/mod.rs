//! End-to-end latency measurement (paper §6.5, Fig 9).
//!
//! Measures real single-image inference wallclock of the fp32 vs
//! fake-quantized HLO executables on PJRT-CPU (the `_b1` artifacts), and
//! VTA cycle counts for the integer-only path. The paper's cross-device
//! story (A53 / i7 / 2080ti) is modeled by `coordinator::devices`.

use anyhow::Result;

use crate::calib::{calibrate, CalibBackend};
use crate::coordinator::{act_params_tensor, prepare, Quantune};
use crate::quant::QuantConfig;
use crate::runtime::{tensor_to_literal, Runtime};
use crate::util::{stats::LatencyStats, Timer};
use crate::zoo::ZooModel;

/// fp32-vs-quantized latency of one model.
#[derive(Clone, Debug)]
pub struct LatencyReport {
    /// Model name.
    pub model: String,
    /// Median fp32 single-image latency (milliseconds).
    pub fp32_ms: f64,
    /// Median fake-quantized single-image latency (milliseconds).
    pub fq_ms: f64,
    /// Full sample statistics behind [`LatencyReport::fp32_ms`].
    pub fp32_stats: LatencyStats,
    /// Full sample statistics behind [`LatencyReport::fq_ms`].
    pub fq_stats: LatencyStats,
}

impl LatencyReport {
    /// fp32-over-quantized speedup: >1 means the quantized model is
    /// faster (the paper finds it mostly is NOT, for naive kernels).
    /// `None` when either side is unmeasured, non-finite, or zero --
    /// a 0 ms `fq_ms` (e.g. a clock too coarse for a tiny model) would
    /// otherwise report an infinite speedup, and NaN would poison every
    /// ranking downstream.
    pub fn speedup(&self) -> Option<f64> {
        let ratio = self.fp32_ms / self.fq_ms;
        (self.fp32_ms.is_finite()
            && self.fq_ms.is_finite()
            && self.fp32_ms > 0.0
            && self.fq_ms > 0.0
            && ratio.is_finite())
        .then_some(ratio)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::LatencyStats;

    fn report(fp32_ms: f64, fq_ms: f64) -> LatencyReport {
        let stats = LatencyStats::from_samples(&[1.0]);
        LatencyReport {
            model: "t".into(),
            fp32_ms,
            fq_ms,
            fp32_stats: stats.clone(),
            fq_stats: stats,
        }
    }

    #[test]
    fn speedup_guards_degenerate_measurements() {
        assert_eq!(report(2.0, 1.0).speedup(), Some(2.0));
        assert_eq!(report(1.0, 4.0).speedup(), Some(0.25));
        assert_eq!(report(2.0, 0.0).speedup(), None, "zero fq would be inf");
        assert_eq!(report(0.0, 1.0).speedup(), None);
        assert_eq!(report(f64::NAN, 1.0).speedup(), None);
        assert_eq!(report(2.0, f64::INFINITY).speedup(), None);
    }
}

/// Measure single-image (batch=1 artifacts) latency for `model` using the
/// best-known (or default) config's quantization parameters.
pub fn fp32_vs_fq_b1(
    q: &Quantune,
    model: &ZooModel,
    runtime: &Runtime,
    reps: usize,
) -> Result<LatencyReport> {
    let cfg = q
        .db
        .best_general(&model.name)
        .map(|(c, _)| c)
        .unwrap_or_else(Quantune::tensorrt_like_baseline);
    let cache = calibrate(
        model,
        &q.calib_pool,
        cfg.calib,
        &CalibBackend::Hlo { runtime, artifacts: &q.artifacts },
        q.seed,
    )?;
    let setup = prepare(model, &cache, &cfg.into())?;

    let fp32 = runtime.load(&q.artifacts.join(format!("{}_fp32_b1.hlo.txt", model.name)))?;
    let fq = runtime.load(&q.artifacts.join(format!("{}_fq_b1.hlo.txt", model.name)))?;

    let x = q.eval.batch(&[0]);
    let x_lit = tensor_to_literal(&x)?;
    let ap = act_params_tensor(&setup);
    let ap_lit = tensor_to_literal(&ap)?;
    let w_raw: Vec<xla::Literal> = model
        .weights
        .flat()
        .iter()
        .map(|t| tensor_to_literal(t))
        .collect::<Result<_>>()?;
    let w_fq: Vec<xla::Literal> = setup
        .weights
        .iter()
        .map(|t| tensor_to_literal(t))
        .collect::<Result<_>>()?;

    let mut fp32_args: Vec<&xla::Literal> = vec![&x_lit];
    fp32_args.extend(w_raw.iter());
    let mut fq_args: Vec<&xla::Literal> = vec![&x_lit, &ap_lit];
    fq_args.extend(w_fq.iter());

    let time_exe = |exe: &crate::runtime::Executable,
                    args: &[&xla::Literal]|
     -> Result<LatencyStats> {
        // warmup
        for _ in 0..3 {
            exe.run_literals(args)?;
        }
        let mut samples = Vec::with_capacity(reps);
        for _ in 0..reps {
            let t = Timer::start();
            exe.run_literals(args)?;
            samples.push(t.ms());
        }
        Ok(LatencyStats::from_samples(&samples))
    };

    let fp32_stats = time_exe(&fp32, &fp32_args)?;
    let fq_stats = time_exe(&fq, &fq_args)?;
    Ok(LatencyReport {
        model: model.name.clone(),
        fp32_ms: fp32_stats.p50_ms,
        fq_ms: fq_stats.p50_ms,
        fp32_stats,
        fq_stats,
    })
}

/// QuantConfig whose latency is being measured (exposed for reports).
pub fn latency_config(q: &Quantune, model: &ZooModel) -> QuantConfig {
    q.db
        .best_general(&model.name)
        .map(|(c, _)| c)
        .unwrap_or_else(Quantune::tensorrt_like_baseline)
}
