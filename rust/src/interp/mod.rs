//! Reference interpreters over the graph IR.
//!
//! Three evaluation modes mirror the three HLO artifacts:
//! - `forward`      fp32 (oracle for `{model}_fp32.hlo.txt`)
//! - `forward_fq`   fake-quantized (oracle for `{model}_fq.hlo.txt`)
//! - `forward_acts` fp32 + captured quant-point tensors (calibration)
//!
//! The interpreter is the fallback accuracy-measurement backend when
//! PJRT artifacts are absent, and the parity reference in tests.
//!
//! # Integer fast path
//!
//! In fake-quant mode the interpreter can run conv/dense layers on true
//! integer operands instead of round-tripping through f32: attach a
//! per-layer [`QuantWeight`] map with [`Interpreter::with_int_weights`]
//! and every conv/dense whose input tensor is known to sit exactly on a
//! quantization grid dispatches to the packed [`kernels`] engine
//! (i8 x i8 -> i32, or packed-int4 weights consumed two-per-byte).
//! Zero points are handled with the gemmlowp correction terms, so the
//! centered product `sum (qa - za)(qw - zw)` is computed exactly in
//! integer arithmetic; the i32 accumulator is then scaled once by
//! `scale_a * scale_w` and biased. Layers whose input is not on a grid
//! (bypassed quant points, avg-pooled values, fp32-width weights) fall
//! back to the legacy f32 fake-quant route transparently.

pub mod gemm;
pub mod kernels;

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::ir::{window_out_dim, Act, Graph, Op, PoolKind, Tensor};
use crate::quant::{ActQuantization, IntRepr, QParams, QuantWeight};

use gemm::gemm_f32;
use kernels::{pack_b_i4, pack_b_i8, qgemm_i4, qgemm_i8};

/// Is the integer fake-quant interpreter path enabled? Defaults to on;
/// set `QUANTUNE_INT_INTERP=0` to force the legacy f32 fake-quant route
/// everywhere (kill switch for A/B debugging). Checked by the
/// coordinator when wiring evaluators, not per-layer.
pub fn int_interp_enabled() -> bool {
    match std::env::var("QUANTUNE_INT_INTERP") {
        Ok(v) => v != "0",
        Err(_) => true,
    }
}

/// im2col: [N,H,W,C] -> patches [N*OH*OW, k*k*C] for one channel group.
///
/// `ch_off..ch_off+cg` selects the input-channel slice (grouped convs).
/// `oh`/`ow` must come from [`window_out_dim`], which rejects windows
/// larger than the padded extent (the unchecked subtraction here would
/// underflow on such geometry).
#[allow(clippy::too_many_arguments)]
fn im2col(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    ch_off: usize,
    cg: usize,
    k: usize,
    stride: usize,
    pad: usize,
    oh: usize,
    ow: usize,
    out: &mut Vec<f32>,
) {
    let cols = k * k * cg;
    out.clear();
    out.resize(n * oh * ow * cols, 0.0);
    for ni in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                let row = ((ni * oh + oy) * ow + ox) * cols;
                for ky in 0..k {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let src = ((ni * h + iy as usize) * w + ix as usize) * c + ch_off;
                        let dst = row + (ky * k + kx) * cg;
                        out[dst..dst + cg].copy_from_slice(&x[src..src + cg]);
                    }
                }
            }
        }
    }
}

/// Integer im2col over raw quantized activations. Identical geometry to
/// [`im2col`], but padding cells hold `fill` (= the activation zero
/// point, the raw value whose dequantization is exactly 0.0) so the
/// centered integer product treats padding as real zero.
#[allow(clippy::too_many_arguments)]
fn im2col_i8(
    x: &[i8],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    ch_off: usize,
    cg: usize,
    k: usize,
    stride: usize,
    pad: usize,
    oh: usize,
    ow: usize,
    fill: i8,
    out: &mut Vec<i8>,
) {
    let cols = k * k * cg;
    out.clear();
    out.resize(n * oh * ow * cols, fill);
    for ni in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                let row = ((ni * oh + oy) * ow + ox) * cols;
                for ky in 0..k {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let src = ((ni * h + iy as usize) * w + ix as usize) * c + ch_off;
                        let dst = row + (ky * k + kx) * cg;
                        out[dst..dst + cg].copy_from_slice(&x[src..src + cg]);
                    }
                }
            }
        }
    }
}

/// Repack HWIO weights [k,k,cg,outg] into a [k*k*cg, outg] GEMM operand
/// for group `g` (selecting output channels g*outg..(g+1)*outg).
fn weight_matrix(wt: &Tensor, g: usize, groups: usize) -> (Vec<f32>, usize, usize) {
    let (k1, k2, cg, out_ch) = (wt.shape[0], wt.shape[1], wt.shape[2], wt.shape[3]);
    let outg = out_ch / groups;
    let rows = k1 * k2 * cg;
    let mut m = vec![0.0f32; rows * outg];
    for r in 0..rows {
        let src = r * out_ch + g * outg;
        m[r * outg..(r + 1) * outg].copy_from_slice(&wt.data[src..src + outg]);
    }
    (m, rows, outg)
}

/// Pure-rust reference interpreter for one (graph, weight set) pair.
///
/// Generic over the map's value type so callers can hand either owned
/// tensors (`HashMap<String, Tensor>`, e.g. a model's weight file) or
/// shared cache entries (`HashMap<String, Arc<Tensor>>` from the
/// quantizer's weight cache) without copying tensor data.
pub struct Interpreter<'a, W: std::borrow::Borrow<Tensor> = Tensor> {
    /// The model graph being evaluated.
    pub graph: &'a Graph,
    weights: &'a HashMap<String, W>,
    int_weights: Option<&'a HashMap<String, Arc<QuantWeight>>>,
}

/// Which evaluation semantics to apply.
enum Mode<'q> {
    Fp32,
    FakeQuant(&'q ActQuantization),
    Acts(Vec<Tensor>),
}

impl<'a, W: std::borrow::Borrow<Tensor>> Interpreter<'a, W> {
    /// `weights` must contain every `{layer}_w` / `{layer}_b`. For the
    /// fake-quant mode pass weights already fake-quantized per config.
    pub fn new(graph: &'a Graph, weights: &'a HashMap<String, W>) -> Self {
        Interpreter { graph, weights, int_weights: None }
    }

    /// Attach integer weights (keyed by layer name, not `{layer}_w`) to
    /// enable the integer fast path in fake-quant mode. Layers absent
    /// from the map keep the f32 fake-quant route, so a partial map
    /// (e.g. only the int4/int8 layers of a mixed config) is fine.
    pub fn with_int_weights(mut self, int_weights: &'a HashMap<String, Arc<QuantWeight>>) -> Self {
        self.int_weights = Some(int_weights);
        self
    }

    /// fp32 logits [N, classes].
    pub fn forward(&self, x: &Tensor) -> Result<Tensor> {
        match self.run(x, Mode::Fp32)? {
            (logits, None) => Ok(logits),
            _ => unreachable!(),
        }
    }

    /// Fake-quantized logits (weights must be pre-fake-quantized).
    pub fn forward_fq(&self, x: &Tensor, aq: &ActQuantization) -> Result<Tensor> {
        match self.run(x, Mode::FakeQuant(aq))? {
            (logits, None) => Ok(logits),
            _ => unreachable!(),
        }
    }

    /// fp32 logits + the tensor at every quantization point (calibration).
    pub fn forward_acts(&self, x: &Tensor) -> Result<(Tensor, Vec<Tensor>)> {
        match self.run(x, Mode::Acts(Vec::new()))? {
            (logits, Some(acts)) => Ok((logits, acts)),
            _ => unreachable!(),
        }
    }

    fn weight(&self, name: &str) -> Result<&Tensor> {
        self.weights
            .get(name)
            .map(std::borrow::Borrow::borrow)
            .ok_or_else(|| anyhow!("missing weight {name}"))
    }

    /// Integer-path dispatch test for a conv/dense node: fires only in
    /// fake-quant mode, when the node's input tensor is known to sit
    /// exactly on a quantization grid, and an integer weight exists for
    /// the layer. Returns the input grid params + the integer weight.
    fn int_ctx(
        &self,
        mode: &Mode<'_>,
        grid: &HashMap<String, QParams>,
        node: &crate::ir::Node,
    ) -> Option<(QParams, &'a QuantWeight)> {
        if !matches!(mode, Mode::FakeQuant(_)) {
            return None;
        }
        let iw = self.int_weights?;
        let pa = grid.get(node.inputs[0].as_str()).copied()?;
        let qw = iw.get(node.name.as_str())?;
        Some((pa, qw.as_ref()))
    }

    fn run(&self, x: &Tensor, mut mode: Mode) -> Result<(Tensor, Option<Vec<Tensor>>)> {
        anyhow::ensure!(x.rank() == 4, "input must be NHWC, got {:?}", x.shape);
        let qpoints = self.graph.quant_points();
        let qindex: HashMap<&str, usize> =
            qpoints.iter().enumerate().map(|(i, s)| (s.as_str(), i)).collect();

        // env entries proven to lie exactly on a quantization grid:
        // fake-quant output is (q - zp) * scale by construction, and
        // re-quantizing such a value recovers q exactly (the product's
        // rounding error is far below half a grid step)
        let mut grid: HashMap<String, QParams> = HashMap::new();

        // active (non-bypassed) quant-point params for `name`, if any
        let qp_of = |name: &str, mode: &Mode| -> Option<QParams> {
            match mode {
                Mode::FakeQuant(aq) => qindex
                    .get(name)
                    .copied()
                    .filter(|&i| !aq.is_bypassed(i))
                    .map(|i| aq.params(i)),
                _ => None,
            }
        };

        let apply_q = |name: &str, t: Tensor, mode: &mut Mode| -> Tensor {
            match mode {
                Mode::Fp32 => t,
                Mode::Acts(captured) => {
                    if qindex.contains_key(name) {
                        captured.push(t.clone());
                    }
                    t
                }
                Mode::FakeQuant(aq) => match qindex.get(name) {
                    Some(&i) if !aq.is_bypassed(i) => {
                        let p = aq.params(i);
                        Tensor {
                            shape: t.shape,
                            data: t.data.iter().map(|&v| p.fake_quant(v)).collect(),
                        }
                    }
                    _ => t,
                },
            }
        };

        let mut env: HashMap<&str, Tensor> = HashMap::new();
        if let Some(p) = qp_of("input", &mode) {
            grid.insert("input".to_string(), p);
        }
        env.insert("input", apply_q("input", x.clone(), &mut mode));

        let mut patch_buf = Vec::new();
        for node in &self.graph.nodes {
            let ins: Vec<&Tensor> = node
                .inputs
                .iter()
                .map(|i| env.get(i.as_str()).ok_or_else(|| anyhow!("missing {i}")))
                .collect::<Result<_>>()?;
            let t = match &node.op {
                Op::Conv { k, stride, pad, in_ch, out_ch, groups, act } => {
                    match self.int_ctx(&mode, &grid, node) {
                        Some((pa, qw)) => self.conv_int(
                            ins[0], node, *k, *stride, *pad, *in_ch, *out_ch, *groups,
                            *act, pa, qw,
                        )?,
                        None => self.conv(
                            ins[0], node, *k, *stride, *pad, *in_ch, *out_ch, *groups,
                            *act, &mut patch_buf,
                        )?,
                    }
                }
                Op::Pool { kind, k, stride, pad } => {
                    pool(ins[0], &node.name, *kind, *k, *stride, *pad)?
                }
                Op::Gap => gap(ins[0]),
                Op::Add { act } => {
                    anyhow::ensure!(ins[0].shape == ins[1].shape, "add shape mismatch");
                    Tensor {
                        shape: ins[0].shape.clone(),
                        data: ins[0]
                            .data
                            .iter()
                            .zip(&ins[1].data)
                            .map(|(&a, &b)| act.apply(a + b))
                            .collect(),
                    }
                }
                Op::Concat => concat(&node.name, &ins)?,
                Op::Shuffle { groups } => shuffle(ins[0], *groups),
                Op::Dense { in_dim, out_dim } => {
                    match self.int_ctx(&mode, &grid, node) {
                        Some((pa, qw)) => {
                            self.dense_int(ins[0], node, *in_dim, *out_dim, pa, qw)?
                        }
                        None => {
                            let w = self.weight(&format!("{}_w", node.name))?;
                            let b = self.weight(&format!("{}_b", node.name))?;
                            let n = ins[0].shape[0];
                            let mut out = vec![0.0f32; n * out_dim];
                            for chunk in out.chunks_exact_mut(*out_dim) {
                                chunk.copy_from_slice(&b.data);
                            }
                            gemm_f32(n, *in_dim, *out_dim, &ins[0].data, &w.data, &mut out);
                            Tensor { shape: vec![n, *out_dim], data: out }
                        }
                    }
                }
            };
            let qp = qp_of(&node.name, &mode);
            let t = apply_q(&node.name, t, &mut mode);
            if let Some(p) = qp {
                grid.insert(node.name.clone(), p);
            } else if matches!(
                &node.op,
                Op::Pool { kind: PoolKind::Max, .. } | Op::Shuffle { .. }
            ) {
                // value-preserving ops keep their input's grid (max-pool
                // selects existing values, shuffle permutes them)
                if let Some(p) = grid.get(node.inputs[0].as_str()).copied() {
                    grid.insert(node.name.clone(), p);
                }
            }
            env.insert(node.name.as_str(), t);
        }

        let logits = env.remove(self.graph.output()).expect("output computed");
        match mode {
            Mode::Acts(captured) => Ok((logits, Some(captured))),
            _ => Ok((logits, None)),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn conv(
        &self,
        x: &Tensor,
        node: &crate::ir::Node,
        k: usize,
        stride: usize,
        pad: usize,
        in_ch: usize,
        out_ch: usize,
        groups: usize,
        act: Act,
        patch_buf: &mut Vec<f32>,
    ) -> Result<Tensor> {
        let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
        anyhow::ensure!(c == in_ch, "conv {}: in_ch mismatch", node.name);
        let wt = self.weight(&format!("{}_w", node.name))?;
        let bias = self.weight(&format!("{}_b", node.name))?;
        let cg = in_ch / groups;
        let outg = out_ch / groups;
        let oh = window_out_dim(&node.name, h, k, stride, pad)?;
        let ow = window_out_dim(&node.name, w, k, stride, pad)?;
        // output in group-major scratch, then interleave
        let mut group_out: Vec<Vec<f32>> = Vec::with_capacity(groups);
        for g in 0..groups {
            im2col(&x.data, n, h, w, c, g * cg, cg, k, stride, pad, oh, ow, patch_buf);
            let (wm, rows, cols) = weight_matrix(wt, g, groups);
            let m = n * oh * ow;
            let mut out = vec![0.0f32; m * cols];
            // seed with bias
            for chunk in out.chunks_exact_mut(cols) {
                chunk.copy_from_slice(&bias.data[g * outg..(g + 1) * outg]);
            }
            gemm_f32(m, rows, cols, patch_buf, &wm, &mut out);
            group_out.push(out);
        }
        let m = n * oh * ow;
        let mut data = vec![0.0f32; m * out_ch];
        if groups == 1 {
            data.copy_from_slice(&group_out[0]);
        } else {
            for (g, go) in group_out.iter().enumerate() {
                for r in 0..m {
                    data[r * out_ch + g * outg..r * out_ch + (g + 1) * outg]
                        .copy_from_slice(&go[r * outg..(r + 1) * outg]);
                }
            }
        }
        if act != Act::None {
            for v in &mut data {
                *v = act.apply(*v);
            }
        }
        Ok(Tensor { shape: vec![n, oh, ow, out_ch], data })
    }

    /// Integer conv: the input (already on grid `pa`) is re-quantized to
    /// its raw i8 values, patches are gathered in integer space with the
    /// zero point as padding, and each group runs the packed i8 or
    /// packed-int4 kernel with gemmlowp zero-point corrections. The i32
    /// accumulator is dequantized once per element
    /// (`acc * scale_a * scale_w + bias`), so the only f32 arithmetic
    /// left is the final scaling -- the f32 weight tensor is never read.
    #[allow(clippy::too_many_arguments)]
    fn conv_int(
        &self,
        x: &Tensor,
        node: &crate::ir::Node,
        k: usize,
        stride: usize,
        pad: usize,
        in_ch: usize,
        out_ch: usize,
        groups: usize,
        act: Act,
        pa: QParams,
        qw: &QuantWeight,
    ) -> Result<Tensor> {
        let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
        anyhow::ensure!(c == in_ch, "conv {}: in_ch mismatch", node.name);
        let bias = self.weight(&format!("{}_b", node.name))?;
        let cg = in_ch / groups;
        let outg = out_ch / groups;
        let rows = k * k * cg;
        anyhow::ensure!(
            qw.len() == rows * out_ch,
            "conv {}: int weight holds {} values, expected {}",
            node.name,
            qw.len(),
            rows * out_ch
        );
        let oh = window_out_dim(&node.name, h, k, stride, pad)?;
        let ow = window_out_dim(&node.name, w, k, stride, pad)?;
        let za = pa.zero_point;
        // exact grid recovery: x values are (q - za) * scale, so
        // re-quantizing reproduces q (all grids are signed int8-or-
        // narrower here, so q fits i8)
        let xq: Vec<i8> = x.data.iter().map(|&v| pa.quantize(v) as i8).collect();
        let m = n * oh * ow;
        let mut patches: Vec<i8> = Vec::new();
        let mut acc = vec![0i32; m * outg];
        let mut data = vec![0.0f32; m * out_ch];
        let nscale = qw.scales.len();
        for g in 0..groups {
            im2col_i8(
                &xq, n, h, w, c, g * cg, cg, k, stride, pad, oh, ow, za as i8,
                &mut patches,
            );
            let zb: Vec<i32> = if nscale == 1 {
                vec![qw.zero_points[0]]
            } else {
                qw.zero_points[g * outg..(g + 1) * outg].to_vec()
            };
            match &qw.repr {
                IntRepr::I8(d) => {
                    let pb = pack_b_i8(rows, outg, |p, j| d[p * out_ch + g * outg + j]);
                    qgemm_i8(m, &patches, za, &pb, &zb, &mut acc);
                }
                IntRepr::I4(pk) => {
                    let pb =
                        pack_b_i4(rows, outg, |p, j| pk.get(p * out_ch + g * outg + j));
                    qgemm_i4(m, &patches, za, &pb, &zb, &mut acc);
                }
            }
            for r in 0..m {
                let arow = &acc[r * outg..(r + 1) * outg];
                let drow = &mut data[r * out_ch + g * outg..r * out_ch + (g + 1) * outg];
                for j in 0..outg {
                    let ch = g * outg + j;
                    let sw = qw.scales[ch % nscale];
                    drow[j] = arow[j] as f32 * (pa.scale * sw) + bias.data[ch];
                }
            }
        }
        if act != Act::None {
            for v in &mut data {
                *v = act.apply(*v);
            }
        }
        Ok(Tensor { shape: vec![n, oh, ow, out_ch], data })
    }

    /// Integer dense layer; see [`Interpreter::conv_int`] -- same
    /// quantize / integer GEMM / dequantize-and-bias structure without
    /// the patch gather.
    fn dense_int(
        &self,
        x: &Tensor,
        node: &crate::ir::Node,
        in_dim: usize,
        out_dim: usize,
        pa: QParams,
        qw: &QuantWeight,
    ) -> Result<Tensor> {
        anyhow::ensure!(
            qw.len() == in_dim * out_dim,
            "dense {}: int weight holds {} values, expected {}",
            node.name,
            qw.len(),
            in_dim * out_dim
        );
        let bias = self.weight(&format!("{}_b", node.name))?;
        let n = x.shape[0];
        let za = pa.zero_point;
        let xq: Vec<i8> = x.data.iter().map(|&v| pa.quantize(v) as i8).collect();
        let nscale = qw.scales.len();
        let zb: Vec<i32> =
            if nscale == 1 { vec![qw.zero_points[0]] } else { qw.zero_points.clone() };
        let mut acc = vec![0i32; n * out_dim];
        match &qw.repr {
            IntRepr::I8(d) => {
                let pb = pack_b_i8(in_dim, out_dim, |p, j| d[p * out_dim + j]);
                qgemm_i8(n, &xq, za, &pb, &zb, &mut acc);
            }
            IntRepr::I4(pk) => {
                let pb = pack_b_i4(in_dim, out_dim, |p, j| pk.get(p * out_dim + j));
                qgemm_i4(n, &xq, za, &pb, &zb, &mut acc);
            }
        }
        let mut out = vec![0.0f32; n * out_dim];
        for r in 0..n {
            for j in 0..out_dim {
                let sw = qw.scales[j % nscale];
                out[r * out_dim + j] =
                    acc[r * out_dim + j] as f32 * (pa.scale * sw) + bias.data[j];
            }
        }
        Ok(Tensor { shape: vec![n, out_dim], data: out })
    }
}

/// Pooling over NHWC. The average divisor is the count of *valid*
/// (non-padded) window cells -- the convention of the python reference's
/// `_pool` (padding contributes neither to the sum nor to the divisor).
/// Graph validation rejects `pad >= k`, so every window contains at
/// least one valid cell (the corner nearest the interior) and the
/// divisor is never zero; the same is re-checked here for direct
/// callers.
fn pool(
    x: &Tensor,
    name: &str,
    kind: PoolKind,
    k: usize,
    stride: usize,
    pad: usize,
) -> Result<Tensor> {
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    anyhow::ensure!(
        pad < k,
        "pool {name}: pad {pad} >= window {k} leaves all-padding border windows"
    );
    let oh = window_out_dim(name, h, k, stride, pad)?;
    let ow = window_out_dim(name, w, k, stride, pad)?;
    let mut data = vec![0.0f32; n * oh * ow * c];
    for ni in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                for ci in 0..c {
                    let mut acc = match kind {
                        PoolKind::Max => f32::NEG_INFINITY,
                        PoolKind::Avg => 0.0,
                    };
                    let mut cnt = 0usize;
                    for ky in 0..k {
                        let iy = (oy * stride + ky) as isize - pad as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kx in 0..k {
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            let v = x.data
                                [((ni * h + iy as usize) * w + ix as usize) * c + ci];
                            match kind {
                                PoolKind::Max => acc = acc.max(v),
                                PoolKind::Avg => acc += v,
                            }
                            cnt += 1;
                        }
                    }
                    let out = match kind {
                        PoolKind::Max => acc,
                        // cnt >= 1 is guaranteed by pad < k
                        PoolKind::Avg => acc / cnt as f32,
                    };
                    data[((ni * oh + oy) * ow + ox) * c + ci] = out;
                }
            }
        }
    }
    Ok(Tensor { shape: vec![n, oh, ow, c], data })
}

fn gap(x: &Tensor) -> Tensor {
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let mut data = vec![0.0f32; n * c];
    let inv = 1.0 / (h * w) as f32;
    for ni in 0..n {
        for p in 0..h * w {
            let src = (ni * h * w + p) * c;
            for ci in 0..c {
                data[ni * c + ci] += x.data[src + ci];
            }
        }
    }
    for v in &mut data {
        *v *= inv;
    }
    Tensor { shape: vec![n, c], data }
}

/// Channel concatenation. All inputs must share the leading [n, h, w]
/// dims (only the channel count may differ) -- mismatches previously
/// read out of bounds or silently interleaved garbage.
fn concat(name: &str, ins: &[&Tensor]) -> Result<Tensor> {
    anyhow::ensure!(!ins.is_empty(), "concat {name}: no inputs");
    let lead = &ins[0].shape[..3];
    for t in ins {
        anyhow::ensure!(t.rank() == 4, "concat {name}: non-NHWC input {:?}", t.shape);
        anyhow::ensure!(
            &t.shape[..3] == lead,
            "concat {name}: [n,h,w] mismatch ({:?} vs {:?})",
            &t.shape[..3],
            lead
        );
    }
    let (n, h, w) = (lead[0], lead[1], lead[2]);
    let cs: Vec<usize> = ins.iter().map(|t| t.shape[3]).collect();
    let c_total: usize = cs.iter().sum();
    let mut data = vec![0.0f32; n * h * w * c_total];
    let rows = n * h * w;
    for r in 0..rows {
        let mut off = 0;
        for (t, &ct) in ins.iter().zip(&cs) {
            data[r * c_total + off..r * c_total + off + ct]
                .copy_from_slice(&t.data[r * ct..(r + 1) * ct]);
            off += ct;
        }
    }
    Ok(Tensor { shape: vec![n, h, w, c_total], data })
}

fn shuffle(x: &Tensor, groups: usize) -> Tensor {
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let per = c / groups;
    let mut data = vec![0.0f32; x.data.len()];
    let rows = n * h * w;
    for r in 0..rows {
        let src = &x.data[r * c..(r + 1) * c];
        let dst = &mut data[r * c..(r + 1) * c];
        // [g, per] -> [per, g] transpose
        for g in 0..groups {
            for p in 0..per {
                dst[p * groups + g] = src[g * per + p];
            }
        }
    }
    Tensor { shape: vec![n, h, w, c], data }
}

/// Top-1 predictions from logits [N, classes].
pub fn argmax_batch(logits: &Tensor) -> Vec<usize> {
    let classes = *logits.shape.last().unwrap();
    logits
        .data
        .chunks_exact(classes)
        .map(|row| {
            row.iter()
                .enumerate()
                // NaN-lowest: a NaN logit (overflowed activation) loses
                // to every real logit instead of panicking mid-batch or
                // (under a bare total_cmp) winning the argmax
                .max_by(|a, b| crate::util::stats::nan_min_cmp_f32(a.1, b.1))
                .map(|(i, _)| i)
                .unwrap_or(0)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Json;

    fn graph_1conv() -> Graph {
        Graph::from_meta(
            &Json::parse(
                r#"{"name": "t", "input_shape": [4, 4, 1], "num_classes": 2,
            "nodes": [
              {"name": "c1", "op": "conv", "inputs": ["input"], "k": 3,
               "stride": 1, "pad": 1, "in_ch": 1, "out_ch": 1, "groups": 1,
               "act": "none"},
              {"name": "g1", "op": "gap", "inputs": ["c1"]},
              {"name": "d1", "op": "dense", "inputs": ["g1"], "in_dim": 1,
               "out_dim": 2}]}"#,
            )
            .unwrap(),
        )
        .unwrap()
    }

    fn identity_weights() -> HashMap<String, Tensor> {
        let mut w = HashMap::new();
        // 3x3 kernel with center 1 => identity conv
        let mut kw = vec![0.0; 9];
        kw[4] = 1.0;
        w.insert("c1_w".into(), Tensor::from_vec(&[3, 3, 1, 1], kw).unwrap());
        w.insert("c1_b".into(), Tensor::from_vec(&[1], vec![0.0]).unwrap());
        w.insert(
            "d1_w".into(),
            Tensor::from_vec(&[1, 2], vec![1.0, -1.0]).unwrap(),
        );
        w.insert("d1_b".into(), Tensor::from_vec(&[2], vec![0.0, 0.5]).unwrap());
        w
    }

    #[test]
    fn identity_conv_and_head() {
        let g = graph_1conv();
        let w = identity_weights();
        let interp = Interpreter::new(&g, &w);
        let x = Tensor::from_vec(&[1, 4, 4, 1], vec![1.0; 16]).unwrap();
        let logits = interp.forward(&x).unwrap();
        // gap(identity(ones)) = 1 -> logits = [1*1, 1*-1+0.5] = [1.0, -0.5]
        assert!((logits.data[0] - 1.0).abs() < 1e-6);
        assert!((logits.data[1] + 0.5).abs() < 1e-6);
        assert_eq!(argmax_batch(&logits), vec![0]);
    }

    #[test]
    fn acts_capture_matches_quant_points() {
        let g = graph_1conv();
        let w = identity_weights();
        let interp = Interpreter::new(&g, &w);
        let x = Tensor::from_vec(&[1, 4, 4, 1], vec![0.5; 16]).unwrap();
        let (_, acts) = interp.forward_acts(&x).unwrap();
        assert_eq!(acts.len(), g.quant_points().len());
        // first captured tensor is the input itself
        assert_eq!(acts[0].data, x.data);
    }

    #[test]
    fn pool_maxavg() {
        let x = Tensor::from_vec(&[1, 2, 2, 1], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let mx = pool(&x, "p", PoolKind::Max, 2, 2, 0).unwrap();
        assert_eq!(mx.data, vec![4.0]);
        let av = pool(&x, "p", PoolKind::Avg, 2, 2, 0).unwrap();
        assert_eq!(av.data, vec![2.5]);
    }

    #[test]
    fn padded_avg_pool_divides_by_valid_count() {
        // 2x2 input [[1,2],[3,4]], k=2 s=1 pad=1 -> 3x3 output; border
        // windows average only their valid cells (hand-computed)
        let x = Tensor::from_vec(&[1, 2, 2, 1], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let y = pool(&x, "p", PoolKind::Avg, 2, 1, 1).unwrap();
        assert_eq!(y.shape, vec![1, 3, 3, 1]);
        assert_eq!(y.data, vec![1.0, 1.5, 2.0, 2.0, 2.5, 3.0, 3.0, 3.5, 4.0]);
    }

    #[test]
    fn pool_rejects_all_padding_geometry() {
        let x = Tensor::from_vec(&[1, 2, 2, 1], vec![0.0; 4]).unwrap();
        let err = pool(&x, "pbad", PoolKind::Avg, 2, 1, 2).unwrap_err();
        assert!(err.to_string().contains("pbad"), "{err}");
        assert!(err.to_string().contains("pad"), "{err}");
    }

    #[test]
    fn shuffle_transposes_groups() {
        // c=4, groups=2: [a b c d] -> [a c b d]
        let x = Tensor::from_vec(&[1, 1, 1, 4], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let y = shuffle(&x, 2);
        assert_eq!(y.data, vec![1.0, 3.0, 2.0, 4.0]);
    }

    #[test]
    fn concat_channels() {
        let a = Tensor::from_vec(&[1, 1, 1, 2], vec![1.0, 2.0]).unwrap();
        let b = Tensor::from_vec(&[1, 1, 1, 1], vec![9.0]).unwrap();
        let y = concat("cat", &[&a, &b]).unwrap();
        assert_eq!(y.shape, vec![1, 1, 1, 3]);
        assert_eq!(y.data, vec![1.0, 2.0, 9.0]);
    }

    #[test]
    fn concat_rejects_mismatched_leading_dims() {
        let a = Tensor::from_vec(&[1, 2, 2, 1], vec![0.0; 4]).unwrap();
        let b = Tensor::from_vec(&[1, 1, 2, 1], vec![0.0; 2]).unwrap();
        let err = concat("cat2", &[&a, &b]).unwrap_err();
        assert!(err.to_string().contains("cat2"), "{err}");
        assert!(err.to_string().contains("mismatch"), "{err}");
    }
}
