//! Reference interpreters over the graph IR.
//!
//! Three evaluation modes mirror the three HLO artifacts:
//! - `forward`      fp32 (oracle for `{model}_fp32.hlo.txt`)
//! - `forward_fq`   fake-quantized (oracle for `{model}_fq.hlo.txt`)
//! - `forward_acts` fp32 + captured quant-point tensors (calibration)
//!
//! The interpreter is the fallback accuracy-measurement backend when
//! PJRT artifacts are absent, and the parity reference in tests.
//!
//! # Integer fast path
//!
//! In fake-quant mode the interpreter can run conv/dense layers on true
//! integer operands instead of round-tripping through f32: attach a
//! per-layer [`PreparedWeight`] map with
//! [`Interpreter::with_int_weights`] and every conv/dense whose input
//! sits exactly on a quantization grid dispatches to the packed
//! [`kernels`] engine (i8 x i8 -> i32, or packed-int4 weights consumed
//! two-per-byte). Zero points are handled with the gemmlowp correction
//! terms, so the centered product `sum (qa - za)(qw - zw)` is computed
//! exactly in integer arithmetic; the i32 accumulator is then scaled
//! once by `scale_a * scale_w`, biased, and requantized straight back
//! onto the consumer's grid. Layers whose input is not on a grid
//! (bypassed quant points, fp32-width weights) fall back to the legacy
//! f32 fake-quant route transparently.
//!
//! Three properties make the steady state cheap (PR 7):
//!
//! - **Prepacked panels.** Weight panels are packed once into a
//!   [`PreparedWeight`] (per layer, per group) when the sweep's
//!   [`crate::coordinator::WeightCache`] builds its integer entries,
//!   not per forward call. Packed col-sums and per-group zero-point
//!   slices ride along.
//! - **Integer-resident activations.** Values flowing between integer
//!   layers stay `i8` in a [`QTensor`] (this is the interpreter's own
//!   activation tensor — distinct from the VTA-path `crate::ir::QTensor`
//!   accessor struct). Conv/dense outputs are requantized with the
//!   activation folded into the integer clamp; max-pool, shuffle and
//!   concat consume and produce `i8` directly; average pooling sums in
//!   i32 and divides once. Dequantization to f32 happens only at
//!   genuine f32 boundaries (bypassed points, f32-route layers, Add,
//!   avg-pool output, and the final logits).
//! - **Scratch arena.** All per-forward buffers (im2col patches, i32
//!   accumulators, the env value pool) live in a reusable
//!   [`InterpScratch`], sized once per worker; steady-state forwards
//!   allocate nothing but the returned logits tensor.
//!
//! Bit-exactness: the requantization applies the *same* f32 op sequence
//! as the fake-quant oracle (`acc as f32 * (scale_a * scale_w) + bias`,
//! then `quantize`), so the integer-resident route is bitwise identical
//! to the legacy route at every on-grid point; tests pin this.

pub mod gemm;
pub mod kernels;

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::ir::{window_out_dim, Act, Graph, Op, PoolKind, Tensor};
use crate::metrics::DispatchCounters;
use crate::quant::{ActQuantization, IntRepr, QParams, QuantWeight};

use gemm::gemm_f32;
use kernels::{pack_b_i4, pack_b_i8, qgemm_i4, qgemm_i8};

/// Is the integer fake-quant interpreter path enabled? Defaults to on;
/// set `QUANTUNE_INT_INTERP=0` to force the legacy f32 fake-quant route
/// everywhere (kill switch for A/B debugging). Checked by the
/// coordinator when wiring evaluators, not per-layer.
pub fn int_interp_enabled() -> bool {
    match std::env::var("QUANTUNE_INT_INTERP") {
        Ok(v) => v != "0",
        Err(_) => true,
    }
}

/// An integer-resident activation tensor: raw `i8` grid values plus the
/// [`QParams`] grid they live on. `data[i]` dequantizes to
/// `(data[i] - qp.zero_point) * qp.scale`.
///
/// This is the interpreter's internal activation carrier (PR 7), not
/// the VTA accessor struct of the same name in `crate::ir`.
#[derive(Clone, Debug)]
pub struct QTensor {
    /// NHWC (or [n, c]) shape, like [`Tensor`].
    pub shape: Vec<usize>,
    /// Raw quantized values, row-major.
    pub data: Vec<i8>,
    /// The grid the values live on.
    pub qp: QParams,
}

impl QTensor {
    /// Dequantize to a fresh f32 [`Tensor`].
    pub fn dequantize(&self) -> Tensor {
        let (zp, s) = (self.qp.zero_point, self.qp.scale);
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&q| (q as i32 - zp) as f32 * s).collect(),
        }
    }
}

/// Packed GEMM operand panels for one (layer, group): the int8 or
/// packed-int4 form the [`kernels`] engine consumes directly.
pub enum PackedPanels {
    /// int8 weight panels.
    I8(kernels::PanelsI8),
    /// packed-int4 weight panels.
    I4(kernels::PanelsI4),
}

/// A [`QuantWeight`] prepacked for the integer GEMM engine: per-group
/// panels (with col-sums) and per-group zero-point slices, built once
/// per (layer, config-variant) and `Arc`-shared across a whole sweep.
///
/// Steady-state forwards call no `pack_b_*` and read only the packed
/// form; the original [`QuantWeight`] stays reachable for scales,
/// zero points and metadata.
pub struct PreparedWeight {
    qw: QuantWeight,
    groups: usize,
    panels: Vec<PackedPanels>,
    zbs: Vec<Vec<i32>>,
}

impl PreparedWeight {
    /// Pack `qw` for `groups` convolution groups (1 for dense). The
    /// weight's last shape axis is the output-channel axis; each group
    /// packs a `[rows, out_ch/groups]` panel set.
    pub fn pack(qw: QuantWeight, groups: usize) -> Result<PreparedWeight> {
        anyhow::ensure!(groups >= 1, "prepack: groups must be >= 1");
        let out_ch = *qw
            .shape
            .last()
            .ok_or_else(|| anyhow!("prepack: scalar weight shape"))?;
        anyhow::ensure!(out_ch > 0, "prepack: zero output channels");
        anyhow::ensure!(
            out_ch % groups == 0,
            "prepack: out_ch {out_ch} not divisible by groups {groups}"
        );
        anyhow::ensure!(
            qw.len() % out_ch == 0,
            "prepack: {} values not divisible by out_ch {out_ch}",
            qw.len()
        );
        let nscale = qw.scales.len();
        anyhow::ensure!(
            nscale == 1 || nscale == out_ch,
            "prepack: {nscale} scale groups for {out_ch} channels"
        );
        let rows = qw.len() / out_ch;
        let outg = out_ch / groups;
        let mut panels = Vec::with_capacity(groups);
        let mut zbs = Vec::with_capacity(groups);
        for g in 0..groups {
            let zb: Vec<i32> = if nscale == 1 {
                vec![qw.zero_points[0]]
            } else {
                qw.zero_points[g * outg..(g + 1) * outg].to_vec()
            };
            let p = match &qw.repr {
                IntRepr::I8(d) => PackedPanels::I8(pack_b_i8(rows, outg, |p, j| {
                    d[p * out_ch + g * outg + j]
                })),
                IntRepr::I4(pk) => PackedPanels::I4(pack_b_i4(rows, outg, |p, j| {
                    pk.get(p * out_ch + g * outg + j)
                })),
            };
            panels.push(p);
            zbs.push(zb);
        }
        Ok(PreparedWeight { qw, groups, panels, zbs })
    }

    /// The quantized weight the panels were packed from.
    pub fn qw(&self) -> &QuantWeight {
        &self.qw
    }

    /// Number of groups the panels were packed for.
    pub fn groups(&self) -> usize {
        self.groups
    }

    /// Packed panels + zero-point slice for group `g`.
    pub fn group(&self, g: usize) -> (&PackedPanels, &[i32]) {
        (&self.panels[g], &self.zbs[g])
    }
}

/// Which evaluation semantics to apply.
#[derive(Clone, Copy)]
enum Mode<'q> {
    Fp32,
    FakeQuant(&'q ActQuantization),
    Acts,
}

/// A value in the interpreter environment: plain f32 or
/// integer-resident on a quantization grid.
enum Value {
    F(Tensor),
    Q(QTensor),
}

impl Value {
    fn shape(&self) -> &[usize] {
        match self {
            Value::F(t) => &t.shape,
            Value::Q(q) => &q.shape,
        }
    }
}

/// Borrowed-or-owned f32 view of a [`Value`]: f32 values borrow, i8
/// values dequantize into a pooled scratch tensor (the fallback
/// boundary). Return the owned case with [`recycle_cow`].
enum FCow<'v> {
    B(&'v Tensor),
    O(Tensor),
}

impl FCow<'_> {
    fn t(&self) -> &Tensor {
        match self {
            FCow::B(t) => t,
            FCow::O(t) => t,
        }
    }
}

fn to_f32<'v>(v: &'v Value, scratch: &mut InterpScratch) -> FCow<'v> {
    match v {
        Value::F(t) => FCow::B(t),
        Value::Q(q) => {
            let mut t = scratch.tensor(&q.shape);
            let (zp, s) = (q.qp.zero_point, q.qp.scale);
            for (d, &qv) in t.data.iter_mut().zip(&q.data) {
                *d = (qv as i32 - zp) as f32 * s;
            }
            FCow::O(t)
        }
    }
}

fn recycle_cow(c: FCow<'_>, scratch: &mut InterpScratch) {
    if let FCow::O(t) = c {
        scratch.free_f.push(t);
    }
}

/// Per-worker scratch arena for the interpreter: every buffer a forward
/// pass needs (im2col patches, i8 staging, i32 accumulators, hoisted
/// per-channel combined scales, and a pool of recycled env tensors),
/// reused across layers, batch items and forward calls so the steady
/// state performs no heap allocation.
///
/// Build one per worker with [`InterpScratch::for_graph`] (sizes the
/// pools to the graph's high-water mark) and pass it to
/// [`Interpreter::forward_fq_with`]; the `forward_*` convenience
/// wrappers create a transient arena internally.
#[derive(Default)]
pub struct InterpScratch {
    free_f: Vec<Tensor>,
    free_q: Vec<QTensor>,
    patches_f32: Vec<f32>,
    patches_i8: Vec<i8>,
    acc: Vec<i32>,
    comb: Vec<f32>,
    wbuf: Vec<f32>,
    gbuf: Vec<f32>,
    env: Vec<Option<Value>>,
    uses: Vec<u32>,
}

impl InterpScratch {
    /// An empty arena; buffers grow on first use.
    pub fn new() -> InterpScratch {
        InterpScratch::default()
    }

    /// An arena pre-sized to `graph`'s high-water mark at batch size
    /// `batch`: enough pooled tensors for every live value plus the
    /// largest im2col / accumulator / weight panel any conv or dense
    /// layer needs. If the graph's shapes cannot be inferred the arena
    /// starts empty and grows on demand (behaviourally identical, just
    /// lazier).
    pub fn for_graph(graph: &Graph, batch: usize) -> InterpScratch {
        let mut s = InterpScratch::default();
        let Ok(shapes) = graph.infer_shapes() else { return s };
        let mut max_elems = 0usize;
        for sh in shapes.values() {
            max_elems = max_elems.max(batch * sh.iter().product::<usize>());
        }
        let slots = graph.nodes.len() + 3;
        for _ in 0..slots {
            s.free_f
                .push(Tensor { shape: Vec::new(), data: Vec::with_capacity(max_elems) });
            s.free_q.push(QTensor {
                shape: Vec::new(),
                data: Vec::with_capacity(max_elems),
                qp: QParams::identity(),
            });
        }
        let (mut max_patch, mut max_acc, mut max_ch, mut max_w) =
            (0usize, 0usize, 0usize, 0usize);
        for node in &graph.nodes {
            match &node.op {
                Op::Conv { k, in_ch, out_ch, groups, .. } => {
                    let (kk, icg, oc) = (*k, in_ch / groups, *out_ch);
                    let Some(osh) = shapes.get(node.name.as_str()) else { continue };
                    let out_elems = batch * osh.iter().product::<usize>();
                    let m = out_elems / oc;
                    let rows = kk * kk * icg;
                    max_patch = max_patch.max(m * rows);
                    max_acc = max_acc.max(out_elems / groups);
                    max_ch = max_ch.max(oc);
                    max_w = max_w.max(rows * (oc / groups));
                }
                Op::Dense { in_dim, out_dim } => {
                    max_acc = max_acc.max(batch * out_dim);
                    max_ch = max_ch.max(*out_dim);
                    max_w = max_w.max(in_dim * out_dim);
                }
                _ => {}
            }
        }
        s.patches_f32.reserve(max_patch);
        s.patches_i8.reserve(max_patch);
        s.acc.reserve(max_acc);
        s.comb.reserve(max_ch);
        s.wbuf.reserve(max_w);
        s.gbuf.reserve(max_acc);
        s
    }

    fn tensor(&mut self, shape: &[usize]) -> Tensor {
        let mut t = self
            .free_f
            .pop()
            .unwrap_or(Tensor { shape: Vec::new(), data: Vec::new() });
        t.shape.clear();
        t.shape.extend_from_slice(shape);
        let len = shape.iter().product();
        t.data.clear();
        t.data.resize(len, 0.0);
        t
    }

    fn qtensor(&mut self, shape: &[usize], qp: QParams) -> QTensor {
        let mut q = self.free_q.pop().unwrap_or(QTensor {
            shape: Vec::new(),
            data: Vec::new(),
            qp: QParams::identity(),
        });
        q.shape.clear();
        q.shape.extend_from_slice(shape);
        let len = shape.iter().product();
        q.data.clear();
        q.data.resize(len, 0);
        q.qp = qp;
        q
    }

    fn recycle(&mut self, v: Value) {
        match v {
            Value::F(t) => self.free_f.push(t),
            Value::Q(q) => self.free_q.push(q),
        }
    }
}

/// im2col: [N,H,W,C] -> patches [N*OH*OW, k*k*C] for one channel group.
///
/// `ch_off..ch_off+cg` selects the input-channel slice (grouped convs).
/// `oh`/`ow` must come from [`window_out_dim`], which rejects windows
/// larger than the padded extent (the unchecked subtraction here would
/// underflow on such geometry).
#[allow(clippy::too_many_arguments)]
fn im2col(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    ch_off: usize,
    cg: usize,
    k: usize,
    stride: usize,
    pad: usize,
    oh: usize,
    ow: usize,
    out: &mut Vec<f32>,
) {
    let cols = k * k * cg;
    out.clear();
    out.resize(n * oh * ow * cols, 0.0);
    for ni in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                let row = ((ni * oh + oy) * ow + ox) * cols;
                for ky in 0..k {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let src = ((ni * h + iy as usize) * w + ix as usize) * c + ch_off;
                        let dst = row + (ky * k + kx) * cg;
                        out[dst..dst + cg].copy_from_slice(&x[src..src + cg]);
                    }
                }
            }
        }
    }
}

/// Integer im2col over raw quantized activations. Identical geometry to
/// [`im2col`], but padding cells hold `fill` (= the activation zero
/// point, the raw value whose dequantization is exactly 0.0) so the
/// centered integer product treats padding as real zero.
#[allow(clippy::too_many_arguments)]
fn im2col_i8(
    x: &[i8],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    ch_off: usize,
    cg: usize,
    k: usize,
    stride: usize,
    pad: usize,
    oh: usize,
    ow: usize,
    fill: i8,
    out: &mut Vec<i8>,
) {
    let cols = k * k * cg;
    out.clear();
    out.resize(n * oh * ow * cols, fill);
    for ni in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                let row = ((ni * oh + oy) * ow + ox) * cols;
                for ky in 0..k {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let src = ((ni * h + iy as usize) * w + ix as usize) * c + ch_off;
                        let dst = row + (ky * k + kx) * cg;
                        out[dst..dst + cg].copy_from_slice(&x[src..src + cg]);
                    }
                }
            }
        }
    }
}

/// Repack HWIO weights [k,k,cg,outg] into a [k*k*cg, outg] GEMM operand
/// for group `g` into a reused scratch buffer.
fn weight_matrix_into(
    wt: &Tensor,
    g: usize,
    groups: usize,
    out: &mut Vec<f32>,
) -> (usize, usize) {
    let (k1, k2, cg, out_ch) = (wt.shape[0], wt.shape[1], wt.shape[2], wt.shape[3]);
    let outg = out_ch / groups;
    let rows = k1 * k2 * cg;
    out.clear();
    out.resize(rows * outg, 0.0);
    for r in 0..rows {
        let src = r * out_ch + g * outg;
        out[r * outg..(r + 1) * outg].copy_from_slice(&wt.data[src..src + outg]);
    }
    (rows, outg)
}

/// Precomputed per-node evaluation plan: resolved input value ids,
/// weight-map keys, and the node's quant-point row (if any).
struct NodePlan {
    in_ids: Vec<usize>,
    w_key: String,
    b_key: String,
    qrow: Option<usize>,
}

/// Pure-rust reference interpreter for one (graph, weight set) pair.
///
/// Generic over the map's value type so callers can hand either owned
/// tensors (`HashMap<String, Tensor>`, e.g. a model's weight file) or
/// shared cache entries (`HashMap<String, Arc<Tensor>>` from the
/// quantizer's weight cache) without copying tensor data.
pub struct Interpreter<'a, W: std::borrow::Borrow<Tensor> = Tensor> {
    /// The model graph being evaluated.
    pub graph: &'a Graph,
    weights: &'a HashMap<String, W>,
    int_weights: Option<&'a HashMap<String, Arc<PreparedWeight>>>,
    counters: Option<&'a DispatchCounters>,
    plans: Vec<NodePlan>,
    uses0: Vec<u32>,
    input_qrow: Option<usize>,
    out_id: usize,
}

impl<'a, W: std::borrow::Borrow<Tensor>> Interpreter<'a, W> {
    /// `weights` must contain every `{layer}_w` / `{layer}_b`. For the
    /// fake-quant mode pass weights already fake-quantized per config.
    pub fn new(graph: &'a Graph, weights: &'a HashMap<String, W>) -> Self {
        let qpoints = graph.quant_points();
        let qindex: HashMap<&str, usize> =
            qpoints.iter().enumerate().map(|(i, s)| (s.as_str(), i)).collect();
        let mut ids: HashMap<&str, usize> = HashMap::new();
        ids.insert("input", 0);
        for (i, node) in graph.nodes.iter().enumerate() {
            ids.insert(node.name.as_str(), i + 1);
        }
        let nvals = graph.nodes.len() + 1;
        let mut uses0 = vec![0u32; nvals];
        let mut plans = Vec::with_capacity(graph.nodes.len());
        for node in &graph.nodes {
            let in_ids: Vec<usize> = node
                .inputs
                .iter()
                .map(|n| ids.get(n.as_str()).copied().unwrap_or(usize::MAX))
                .collect();
            for &id in &in_ids {
                if id != usize::MAX {
                    uses0[id] += 1;
                }
            }
            plans.push(NodePlan {
                in_ids,
                w_key: format!("{}_w", node.name),
                b_key: format!("{}_b", node.name),
                qrow: qindex.get(node.name.as_str()).copied(),
            });
        }
        let out_id = if graph.nodes.is_empty() {
            0
        } else {
            ids.get(graph.output()).copied().unwrap_or(0)
        };
        uses0[out_id] += 1;
        let input_qrow = qindex.get("input").copied();
        Interpreter {
            graph,
            weights,
            int_weights: None,
            counters: None,
            plans,
            uses0,
            input_qrow,
            out_id,
        }
    }

    /// Attach prepacked integer weights (keyed by layer name, not
    /// `{layer}_w`) to enable the integer fast path in fake-quant mode.
    /// Layers absent from the map keep the f32 fake-quant route, so a
    /// partial map (e.g. only the int4/int8 layers of a mixed config)
    /// is fine.
    pub fn with_int_weights(
        mut self,
        int_weights: &'a HashMap<String, Arc<PreparedWeight>>,
    ) -> Self {
        self.int_weights = Some(int_weights);
        self
    }

    /// Attach dispatch counters: every fake-quant conv/dense records
    /// whether it ran on the integer engine or the f32 fallback, plus
    /// its MAC count, into `counters` (shared across workers).
    pub fn with_dispatch_counters(mut self, counters: &'a DispatchCounters) -> Self {
        self.counters = Some(counters);
        self
    }

    /// fp32 logits [N, classes].
    pub fn forward(&self, x: &Tensor) -> Result<Tensor> {
        self.forward_with(x, &mut InterpScratch::new())
    }

    /// fp32 logits, reusing a caller-held scratch arena.
    pub fn forward_with(&self, x: &Tensor, scratch: &mut InterpScratch) -> Result<Tensor> {
        Ok(self.run(x, Mode::Fp32, scratch)?.0)
    }

    /// Fake-quantized logits (weights must be pre-fake-quantized).
    pub fn forward_fq(&self, x: &Tensor, aq: &ActQuantization) -> Result<Tensor> {
        self.forward_fq_with(x, aq, &mut InterpScratch::new())
    }

    /// Fake-quantized logits, reusing a caller-held scratch arena — the
    /// allocation-free steady-state entry point for sweeps.
    pub fn forward_fq_with(
        &self,
        x: &Tensor,
        aq: &ActQuantization,
        scratch: &mut InterpScratch,
    ) -> Result<Tensor> {
        Ok(self.run(x, Mode::FakeQuant(aq), scratch)?.0)
    }

    /// fp32 logits + the tensor at every quantization point (calibration).
    pub fn forward_acts(&self, x: &Tensor) -> Result<(Tensor, Vec<Tensor>)> {
        let (logits, acts) = self.run(x, Mode::Acts, &mut InterpScratch::new())?;
        Ok((logits, acts.unwrap_or_default()))
    }

    fn weight(&self, name: &str) -> Result<&Tensor> {
        self.weights
            .get(name)
            .map(std::borrow::Borrow::borrow)
            .ok_or_else(|| anyhow!("missing weight {name}"))
    }

    fn run(
        &self,
        x: &Tensor,
        mode: Mode,
        scratch: &mut InterpScratch,
    ) -> Result<(Tensor, Option<Vec<Tensor>>)> {
        anyhow::ensure!(x.rank() == 4, "input must be NHWC, got {:?}", x.shape);
        let fq = matches!(mode, Mode::FakeQuant(_));
        let integer_resident = fq && self.int_weights.is_some_and(|m| !m.is_empty());
        let mut captured: Vec<Tensor> = Vec::new();

        // the env lives in the arena between calls so its slots (and
        // the tensors they recycle into the free pools) never reallocate
        let nvals = self.graph.nodes.len() + 1;
        let mut env = std::mem::take(&mut scratch.env);
        env.clear();
        env.resize_with(nvals, || None);
        let mut uses = std::mem::take(&mut scratch.uses);
        uses.clear();
        uses.extend_from_slice(&self.uses0);

        // active (non-bypassed) quant-point params for a qindex row
        let qp_at = |row: Option<usize>| -> Option<QParams> {
            match mode {
                Mode::FakeQuant(aq) => {
                    row.filter(|&i| !aq.is_bypassed(i)).map(|i| aq.params(i))
                }
                _ => None,
            }
        };

        if matches!(mode, Mode::Acts) && self.input_qrow.is_some() {
            captured.push(x.clone());
        }
        let input_val = match qp_at(self.input_qrow) {
            // fake-quant output is (q - zp) * scale by construction, so
            // quantizing the input once yields the exact grid the f32
            // route would round-trip through
            Some(p) if integer_resident => {
                let mut q = scratch.qtensor(&x.shape, p);
                for (d, &v) in q.data.iter_mut().zip(&x.data) {
                    *d = p.quantize(v) as i8;
                }
                Value::Q(q)
            }
            qp => {
                let mut t = scratch.tensor(&x.shape);
                t.data.copy_from_slice(&x.data);
                if let Some(p) = qp {
                    for v in &mut t.data {
                        *v = p.fake_quant(*v);
                    }
                }
                Value::F(t)
            }
        };
        env[0] = Some(input_val);

        for (idx, node) in self.graph.nodes.iter().enumerate() {
            let plan = &self.plans[idx];
            for (&id, name) in plan.in_ids.iter().zip(&node.inputs) {
                anyhow::ensure!(id != usize::MAX && env[id].is_some(), "missing {name}");
            }
            let pout = qp_at(plan.qrow);
            let out: Value = match &node.op {
                Op::Conv { k, stride, pad, in_ch, out_ch, groups, act } => {
                    let (kk, st, pd, ic, oc, gr, a) =
                        (*k, *stride, *pad, *in_ch, *out_ch, *groups, *act);
                    let vin = env[plan.in_ids[0]].as_ref().unwrap();
                    let ipw = if fq {
                        self.int_weights.and_then(|m| m.get(node.name.as_str()))
                    } else {
                        None
                    };
                    match (vin, ipw) {
                        (Value::Q(qx), Some(pw)) => {
                            let out = self.conv_int(
                                qx, node, &plan.b_key, kk, st, pd, ic, oc, gr, a,
                                pw.as_ref(), pout, scratch,
                            )?;
                            if let Some(cs) = self.counters {
                                cs.record(true, conv_macs(out.shape(), kk, ic, oc, gr));
                            }
                            out
                        }
                        _ => {
                            let xc = to_f32(vin, scratch);
                            let t = self.conv(
                                xc.t(), node, &plan.w_key, &plan.b_key, kk, st, pd,
                                ic, oc, gr, a, scratch,
                            )?;
                            if fq {
                                if let Some(cs) = self.counters {
                                    cs.record(false, conv_macs(&t.shape, kk, ic, oc, gr));
                                }
                            }
                            recycle_cow(xc, scratch);
                            Value::F(t)
                        }
                    }
                }
                Op::Pool { kind, k, stride, pad } => {
                    let (kk, st, pd) = (*k, *stride, *pad);
                    let vin = env[plan.in_ids[0]].as_ref().unwrap();
                    match (vin, kind) {
                        (Value::Q(qx), PoolKind::Max) => {
                            Value::Q(pool_max_q(qx, &node.name, kk, st, pd, scratch)?)
                        }
                        (Value::Q(qx), PoolKind::Avg) => {
                            Value::F(pool_avg_q(qx, &node.name, kk, st, pd, scratch)?)
                        }
                        (Value::F(t), _) => {
                            Value::F(pool(t, &node.name, *kind, kk, st, pd)?)
                        }
                    }
                }
                Op::Gap => gap_value(env[plan.in_ids[0]].as_ref().unwrap(), scratch),
                Op::Add { act } => {
                    let a = env[plan.in_ids[0]].as_ref().unwrap();
                    let b = env[plan.in_ids[1]].as_ref().unwrap();
                    add_values(a, b, *act, scratch)?
                }
                Op::Concat => {
                    let ins: Vec<&Value> =
                        plan.in_ids.iter().map(|&id| env[id].as_ref().unwrap()).collect();
                    concat_values(&node.name, &ins, scratch)?
                }
                Op::Shuffle { groups } => {
                    match env[plan.in_ids[0]].as_ref().unwrap() {
                        Value::F(t) => Value::F(shuffle(t, *groups)),
                        Value::Q(q) => Value::Q(shuffle_q(q, *groups, scratch)),
                    }
                }
                Op::Dense { in_dim, out_dim } => {
                    let (idim, odim) = (*in_dim, *out_dim);
                    let vin = env[plan.in_ids[0]].as_ref().unwrap();
                    let ipw = if fq {
                        self.int_weights.and_then(|m| m.get(node.name.as_str()))
                    } else {
                        None
                    };
                    match (vin, ipw) {
                        (Value::Q(qx), Some(pw)) => {
                            let macs = (qx.shape[0] * idim * odim) as u64;
                            let out = self.dense_int(
                                qx, node, &plan.b_key, idim, odim, pw.as_ref(), pout,
                                scratch,
                            )?;
                            if let Some(cs) = self.counters {
                                cs.record(true, macs);
                            }
                            out
                        }
                        _ => {
                            let xc = to_f32(vin, scratch);
                            let w = self.weight(&plan.w_key)?;
                            let b = self.weight(&plan.b_key)?;
                            let n = xc.t().shape[0];
                            let mut t = scratch.tensor(&[n, odim]);
                            for chunk in t.data.chunks_exact_mut(odim) {
                                chunk.copy_from_slice(&b.data);
                            }
                            gemm_f32(n, idim, odim, &xc.t().data, &w.data, &mut t.data);
                            if fq {
                                if let Some(cs) = self.counters {
                                    cs.record(false, (n * idim * odim) as u64);
                                }
                            }
                            recycle_cow(xc, scratch);
                            Value::F(t)
                        }
                    }
                }
            };
            let out = match mode {
                Mode::Fp32 => out,
                Mode::Acts => {
                    if plan.qrow.is_some() {
                        if let Value::F(t) = &out {
                            captured.push(t.clone());
                        }
                    }
                    out
                }
                Mode::FakeQuant(_) => match (out, pout) {
                    // integer-path producers already emitted exactly-at-
                    // grid values; non-quant-point passthroughs keep
                    // their input's grid
                    (Value::Q(q), _) => Value::Q(q),
                    (Value::F(mut t), Some(p)) => {
                        if integer_resident {
                            let mut q = scratch.qtensor(&t.shape, p);
                            for (d, &v) in q.data.iter_mut().zip(&t.data) {
                                *d = p.quantize(v) as i8;
                            }
                            scratch.free_f.push(t);
                            Value::Q(q)
                        } else {
                            for v in &mut t.data {
                                *v = p.fake_quant(*v);
                            }
                            Value::F(t)
                        }
                    }
                    (v, None) => v,
                },
            };
            for &id in &plan.in_ids {
                uses[id] -= 1;
                if uses[id] == 0 {
                    if let Some(v) = env[id].take() {
                        scratch.recycle(v);
                    }
                }
            }
            env[idx + 1] = Some(out);
        }

        let vout = env[self.out_id].take().expect("output computed");
        // the one O(1) steady-state allocation: the returned logits
        let logits = match vout {
            Value::F(t) => {
                let out = t.clone();
                scratch.free_f.push(t);
                out
            }
            Value::Q(q) => {
                let out = q.dequantize();
                scratch.free_q.push(q);
                out
            }
        };
        for slot in env.iter_mut() {
            if let Some(v) = slot.take() {
                scratch.recycle(v);
            }
        }
        scratch.env = env;
        scratch.uses = uses;
        match mode {
            Mode::Acts => Ok((logits, Some(captured))),
            _ => Ok((logits, None)),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn conv(
        &self,
        x: &Tensor,
        node: &crate::ir::Node,
        w_key: &str,
        b_key: &str,
        k: usize,
        stride: usize,
        pad: usize,
        in_ch: usize,
        out_ch: usize,
        groups: usize,
        act: Act,
        scratch: &mut InterpScratch,
    ) -> Result<Tensor> {
        let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
        anyhow::ensure!(c == in_ch, "conv {}: in_ch mismatch", node.name);
        let wt = self.weight(w_key)?;
        let bias = self.weight(b_key)?;
        let cg = in_ch / groups;
        let outg = out_ch / groups;
        let oh = window_out_dim(&node.name, h, k, stride, pad)?;
        let ow = window_out_dim(&node.name, w, k, stride, pad)?;
        let m = n * oh * ow;
        let mut t = scratch.tensor(&[n, oh, ow, out_ch]);
        if groups == 1 {
            im2col(
                &x.data, n, h, w, c, 0, cg, k, stride, pad, oh, ow,
                &mut scratch.patches_f32,
            );
            let (rows, cols) = weight_matrix_into(wt, 0, 1, &mut scratch.wbuf);
            // seed with bias
            for chunk in t.data.chunks_exact_mut(cols) {
                chunk.copy_from_slice(&bias.data);
            }
            gemm_f32(m, rows, cols, &scratch.patches_f32, &scratch.wbuf, &mut t.data);
        } else {
            // per-group scratch, then interleave into the NHWC output
            for g in 0..groups {
                im2col(
                    &x.data, n, h, w, c, g * cg, cg, k, stride, pad, oh, ow,
                    &mut scratch.patches_f32,
                );
                let (rows, cols) = weight_matrix_into(wt, g, groups, &mut scratch.wbuf);
                scratch.gbuf.clear();
                scratch.gbuf.resize(m * outg, 0.0);
                for chunk in scratch.gbuf.chunks_exact_mut(cols) {
                    chunk.copy_from_slice(&bias.data[g * outg..(g + 1) * outg]);
                }
                gemm_f32(
                    m, rows, cols, &scratch.patches_f32, &scratch.wbuf,
                    &mut scratch.gbuf,
                );
                for r in 0..m {
                    t.data[r * out_ch + g * outg..r * out_ch + (g + 1) * outg]
                        .copy_from_slice(&scratch.gbuf[r * outg..(r + 1) * outg]);
                }
            }
        }
        if act != Act::None {
            for v in &mut t.data {
                *v = act.apply(*v);
            }
        }
        Ok(t)
    }

    /// Integer conv: the input arrives as raw i8 grid values, patches
    /// are gathered in integer space with the zero point as padding,
    /// and each group runs its prepacked i8 / packed-int4 panels with
    /// gemmlowp zero-point corrections. The i32 accumulator is scaled
    /// once per element (`acc * (scale_a * scale_w) + bias` — the
    /// per-channel combined scale is hoisted out of the inner loop) and,
    /// when the node is an active quant point, requantized directly
    /// onto the output grid with the activation folded into an integer
    /// clamp. That f32 op sequence is exactly the fake-quant oracle's,
    /// so the result is bitwise identical to the legacy route.
    #[allow(clippy::too_many_arguments)]
    fn conv_int(
        &self,
        x: &QTensor,
        node: &crate::ir::Node,
        b_key: &str,
        k: usize,
        stride: usize,
        pad: usize,
        in_ch: usize,
        out_ch: usize,
        groups: usize,
        act: Act,
        pw: &PreparedWeight,
        pout: Option<QParams>,
        scratch: &mut InterpScratch,
    ) -> Result<Value> {
        let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
        anyhow::ensure!(c == in_ch, "conv {}: in_ch mismatch", node.name);
        anyhow::ensure!(
            pw.groups() == groups,
            "conv {}: weight prepacked for {} groups, node has {}",
            node.name,
            pw.groups(),
            groups
        );
        let qw = pw.qw();
        let bias = self.weight(b_key)?;
        let cg = in_ch / groups;
        let outg = out_ch / groups;
        let rows = k * k * cg;
        anyhow::ensure!(
            qw.len() == rows * out_ch,
            "conv {}: int weight holds {} values, expected {}",
            node.name,
            qw.len(),
            rows * out_ch
        );
        let oh = window_out_dim(&node.name, h, k, stride, pad)?;
        let ow = window_out_dim(&node.name, w, k, stride, pad)?;
        let pa = x.qp;
        let za = pa.zero_point;
        let m = n * oh * ow;
        let nscale = qw.scales.len();
        // hoisted per-channel combined scale: no `ch % nscale` lookup
        // in the inner loop
        scratch.comb.clear();
        if nscale == 1 {
            scratch.comb.resize(out_ch, pa.scale * qw.scales[0]);
        } else {
            scratch.comb.extend(qw.scales.iter().map(|&sw| pa.scale * sw));
        }
        scratch.acc.clear();
        scratch.acc.resize(m * outg, 0);
        match pout {
            Some(p) => {
                let (lo, hi) = act_bounds(act, &p);
                let mut out = scratch.qtensor(&[n, oh, ow, out_ch], p);
                for g in 0..groups {
                    im2col_i8(
                        &x.data, n, h, w, c, g * cg, cg, k, stride, pad, oh, ow,
                        za as i8, &mut scratch.patches_i8,
                    );
                    let (panels, zb) = pw.group(g);
                    match panels {
                        PackedPanels::I8(pb) => {
                            qgemm_i8(m, &scratch.patches_i8, za, pb, zb, &mut scratch.acc)
                        }
                        PackedPanels::I4(pb) => {
                            qgemm_i4(m, &scratch.patches_i8, za, pb, zb, &mut scratch.acc)
                        }
                    }
                    let brow = &bias.data[g * outg..(g + 1) * outg];
                    let combg = &scratch.comb[g * outg..(g + 1) * outg];
                    for r in 0..m {
                        let arow = &scratch.acc[r * outg..(r + 1) * outg];
                        let qrow = &mut out.data
                            [r * out_ch + g * outg..r * out_ch + (g + 1) * outg];
                        for j in 0..outg {
                            let v = arow[j] as f32 * combg[j] + brow[j];
                            qrow[j] = p.quantize(v).clamp(lo, hi) as i8;
                        }
                    }
                }
                Ok(Value::Q(out))
            }
            None => {
                let mut out = scratch.tensor(&[n, oh, ow, out_ch]);
                for g in 0..groups {
                    im2col_i8(
                        &x.data, n, h, w, c, g * cg, cg, k, stride, pad, oh, ow,
                        za as i8, &mut scratch.patches_i8,
                    );
                    let (panels, zb) = pw.group(g);
                    match panels {
                        PackedPanels::I8(pb) => {
                            qgemm_i8(m, &scratch.patches_i8, za, pb, zb, &mut scratch.acc)
                        }
                        PackedPanels::I4(pb) => {
                            qgemm_i4(m, &scratch.patches_i8, za, pb, zb, &mut scratch.acc)
                        }
                    }
                    let brow = &bias.data[g * outg..(g + 1) * outg];
                    let combg = &scratch.comb[g * outg..(g + 1) * outg];
                    for r in 0..m {
                        let arow = &scratch.acc[r * outg..(r + 1) * outg];
                        let drow = &mut out.data
                            [r * out_ch + g * outg..r * out_ch + (g + 1) * outg];
                        for j in 0..outg {
                            drow[j] = arow[j] as f32 * combg[j] + brow[j];
                        }
                    }
                }
                if act != Act::None {
                    for v in &mut out.data {
                        *v = act.apply(*v);
                    }
                }
                Ok(Value::F(out))
            }
        }
    }

    /// Integer dense layer; see [`Interpreter::conv_int`] — same
    /// prepacked integer GEMM / scale-and-bias / requantize structure
    /// without the patch gather. The input's i8 grid values feed the
    /// kernel directly (no f32 round-trip).
    #[allow(clippy::too_many_arguments)]
    fn dense_int(
        &self,
        x: &QTensor,
        node: &crate::ir::Node,
        b_key: &str,
        in_dim: usize,
        out_dim: usize,
        pw: &PreparedWeight,
        pout: Option<QParams>,
        scratch: &mut InterpScratch,
    ) -> Result<Value> {
        let qw = pw.qw();
        anyhow::ensure!(
            qw.len() == in_dim * out_dim,
            "dense {}: int weight holds {} values, expected {}",
            node.name,
            qw.len(),
            in_dim * out_dim
        );
        anyhow::ensure!(
            pw.groups() == 1,
            "dense {}: weight prepacked for {} groups",
            node.name,
            pw.groups()
        );
        let bias = self.weight(b_key)?;
        let n = x.shape[0];
        let pa = x.qp;
        let za = pa.zero_point;
        let nscale = qw.scales.len();
        scratch.comb.clear();
        if nscale == 1 {
            scratch.comb.resize(out_dim, pa.scale * qw.scales[0]);
        } else {
            scratch.comb.extend(qw.scales.iter().map(|&sw| pa.scale * sw));
        }
        scratch.acc.clear();
        scratch.acc.resize(n * out_dim, 0);
        let (panels, zb) = pw.group(0);
        match panels {
            PackedPanels::I8(pb) => qgemm_i8(n, &x.data, za, pb, zb, &mut scratch.acc),
            PackedPanels::I4(pb) => qgemm_i4(n, &x.data, za, pb, zb, &mut scratch.acc),
        }
        match pout {
            Some(p) => {
                let mut out = scratch.qtensor(&[n, out_dim], p);
                for r in 0..n {
                    let arow = &scratch.acc[r * out_dim..(r + 1) * out_dim];
                    let qrow = &mut out.data[r * out_dim..(r + 1) * out_dim];
                    for j in 0..out_dim {
                        let v = arow[j] as f32 * scratch.comb[j] + bias.data[j];
                        qrow[j] = p.quantize(v) as i8;
                    }
                }
                Ok(Value::Q(out))
            }
            None => {
                let mut out = scratch.tensor(&[n, out_dim]);
                for r in 0..n {
                    let arow = &scratch.acc[r * out_dim..(r + 1) * out_dim];
                    let drow = &mut out.data[r * out_dim..(r + 1) * out_dim];
                    for j in 0..out_dim {
                        drow[j] = arow[j] as f32 * scratch.comb[j] + bias.data[j];
                    }
                }
                Ok(Value::F(out))
            }
        }
    }
}

/// MAC count of a conv from its output shape [n, oh, ow, out_ch].
fn conv_macs(sh: &[usize], k: usize, in_ch: usize, out_ch: usize, groups: usize) -> u64 {
    (sh[0] * sh[1] * sh[2]) as u64 * (k * k * (in_ch / groups)) as u64 * out_ch as u64
}

/// Integer clamp bounds folding `act` into requantization onto grid
/// `p`: `p.quantize(v).clamp(lo, hi)` equals `p.quantize(act.apply(v))`
/// for the monotone activations (quantize is monotone, so clamping in
/// the quantized domain at the activation endpoints is exact).
fn act_bounds(act: Act, p: &QParams) -> (i32, i32) {
    match act {
        Act::None => (i32::MIN, i32::MAX),
        Act::Relu => (p.quantize(0.0), i32::MAX),
        Act::Relu6 => (p.quantize(0.0), p.quantize(6.0)),
    }
}

/// Pooling over NHWC. The average divisor is the count of *valid*
/// (non-padded) window cells -- the convention of the python reference's
/// `_pool` (padding contributes neither to the sum nor to the divisor).
/// Graph validation rejects `pad >= k`, so every window contains at
/// least one valid cell (the corner nearest the interior) and the
/// divisor is never zero; the same is re-checked here for direct
/// callers.
fn pool(
    x: &Tensor,
    name: &str,
    kind: PoolKind,
    k: usize,
    stride: usize,
    pad: usize,
) -> Result<Tensor> {
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    anyhow::ensure!(
        pad < k,
        "pool {name}: pad {pad} >= window {k} leaves all-padding border windows"
    );
    let oh = window_out_dim(name, h, k, stride, pad)?;
    let ow = window_out_dim(name, w, k, stride, pad)?;
    let mut data = vec![0.0f32; n * oh * ow * c];
    for ni in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                for ci in 0..c {
                    let mut acc = match kind {
                        PoolKind::Max => f32::NEG_INFINITY,
                        PoolKind::Avg => 0.0,
                    };
                    let mut cnt = 0usize;
                    for ky in 0..k {
                        let iy = (oy * stride + ky) as isize - pad as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kx in 0..k {
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            let v = x.data
                                [((ni * h + iy as usize) * w + ix as usize) * c + ci];
                            match kind {
                                PoolKind::Max => acc = acc.max(v),
                                PoolKind::Avg => acc += v,
                            }
                            cnt += 1;
                        }
                    }
                    let out = match kind {
                        PoolKind::Max => acc,
                        // cnt >= 1 is guaranteed by pad < k
                        PoolKind::Avg => acc / cnt as f32,
                    };
                    data[((ni * oh + oy) * ow + ox) * c + ci] = out;
                }
            }
        }
    }
    Ok(Tensor { shape: vec![n, oh, ow, c], data })
}

/// Integer max-pool: the max over raw i8 values equals the quantized
/// max over their dequantizations (dequantize is monotone), so the
/// output stays on the input's grid, bit-exactly.
fn pool_max_q(
    x: &QTensor,
    name: &str,
    k: usize,
    stride: usize,
    pad: usize,
    scratch: &mut InterpScratch,
) -> Result<QTensor> {
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    anyhow::ensure!(
        pad < k,
        "pool {name}: pad {pad} >= window {k} leaves all-padding border windows"
    );
    let oh = window_out_dim(name, h, k, stride, pad)?;
    let ow = window_out_dim(name, w, k, stride, pad)?;
    let mut out = scratch.qtensor(&[n, oh, ow, c], x.qp);
    for ni in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                for ci in 0..c {
                    let mut acc = i8::MIN;
                    for ky in 0..k {
                        let iy = (oy * stride + ky) as isize - pad as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kx in 0..k {
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            let v = x.data
                                [((ni * h + iy as usize) * w + ix as usize) * c + ci];
                            acc = acc.max(v);
                        }
                    }
                    out.data[((ni * oh + oy) * ow + ox) * c + ci] = acc;
                }
            }
        }
    }
    Ok(out)
}

/// Integer-route average pool: sums raw values in i32, subtracts the
/// zero-point mass, and scales/divides once per window. This is a
/// documented f32 boundary — the result is mathematically the window
/// mean but its f32 rounding differs from the oracle's
/// sum-of-dequantized-f32 order, so the output returns to f32 (pool is
/// not a quant point, so no grid claim is made).
fn pool_avg_q(
    x: &QTensor,
    name: &str,
    k: usize,
    stride: usize,
    pad: usize,
    scratch: &mut InterpScratch,
) -> Result<Tensor> {
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    anyhow::ensure!(
        pad < k,
        "pool {name}: pad {pad} >= window {k} leaves all-padding border windows"
    );
    let oh = window_out_dim(name, h, k, stride, pad)?;
    let ow = window_out_dim(name, w, k, stride, pad)?;
    let (zp, s) = (x.qp.zero_point, x.qp.scale);
    let mut out = scratch.tensor(&[n, oh, ow, c]);
    for ni in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                for ci in 0..c {
                    let mut sum = 0i32;
                    let mut cnt = 0i32;
                    for ky in 0..k {
                        let iy = (oy * stride + ky) as isize - pad as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kx in 0..k {
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            sum += x.data
                                [((ni * h + iy as usize) * w + ix as usize) * c + ci]
                                as i32;
                            cnt += 1;
                        }
                    }
                    // cnt >= 1 is guaranteed by pad < k
                    out.data[((ni * oh + oy) * ow + ox) * c + ci] =
                        (sum - cnt * zp) as f32 * s / cnt as f32;
                }
            }
        }
    }
    Ok(out)
}

fn gap(x: &Tensor) -> Tensor {
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let mut data = vec![0.0f32; n * c];
    let inv = 1.0 / (h * w) as f32;
    for ni in 0..n {
        for p in 0..h * w {
            let src = (ni * h * w + p) * c;
            for ci in 0..c {
                data[ni * c + ci] += x.data[src + ci];
            }
        }
    }
    for v in &mut data {
        *v *= inv;
    }
    Tensor { shape: vec![n, c], data }
}

/// Global average pool over a [`Value`]: the f32 arm delegates to
/// [`gap`]; the i8 arm accumulates dequantized values in the same
/// order, so both are bitwise identical to the oracle.
fn gap_value(v: &Value, scratch: &mut InterpScratch) -> Value {
    match v {
        Value::F(t) => Value::F(gap(t)),
        Value::Q(q) => {
            let (n, h, w, c) = (q.shape[0], q.shape[1], q.shape[2], q.shape[3]);
            let mut out = scratch.tensor(&[n, c]);
            let (zp, s) = (q.qp.zero_point, q.qp.scale);
            let inv = 1.0 / (h * w) as f32;
            for ni in 0..n {
                for p in 0..h * w {
                    let src = (ni * h * w + p) * c;
                    for ci in 0..c {
                        out.data[ni * c + ci] +=
                            (q.data[src + ci] as i32 - zp) as f32 * s;
                    }
                }
            }
            for vv in &mut out.data {
                *vv *= inv;
            }
            Value::F(out)
        }
    }
}

/// Channel concatenation. All inputs must share the leading [n, h, w]
/// dims (only the channel count may differ) -- mismatches previously
/// read out of bounds or silently interleaved garbage.
fn concat(name: &str, ins: &[&Tensor]) -> Result<Tensor> {
    anyhow::ensure!(!ins.is_empty(), "concat {name}: no inputs");
    let lead = &ins[0].shape[..3];
    for t in ins {
        anyhow::ensure!(t.rank() == 4, "concat {name}: non-NHWC input {:?}", t.shape);
        anyhow::ensure!(
            &t.shape[..3] == lead,
            "concat {name}: [n,h,w] mismatch ({:?} vs {:?})",
            &t.shape[..3],
            lead
        );
    }
    let (n, h, w) = (lead[0], lead[1], lead[2]);
    let cs: Vec<usize> = ins.iter().map(|t| t.shape[3]).collect();
    let c_total: usize = cs.iter().sum();
    let mut data = vec![0.0f32; n * h * w * c_total];
    let rows = n * h * w;
    for r in 0..rows {
        let mut off = 0;
        for (t, &ct) in ins.iter().zip(&cs) {
            data[r * c_total + off..r * c_total + off + ct]
                .copy_from_slice(&t.data[r * ct..(r + 1) * ct]);
            off += ct;
        }
    }
    Ok(Tensor { shape: vec![n, h, w, c_total], data })
}

/// Concat over [`Value`]s: all-f32 inputs delegate to [`concat`];
/// mixed or all-i8 inputs dequantize row-by-row into a pooled output
/// (each dequantized value is exactly the f32 the oracle holds, so the
/// node's own fake-quant afterwards is bitwise identical).
fn concat_values(name: &str, ins: &[&Value], scratch: &mut InterpScratch) -> Result<Value> {
    anyhow::ensure!(!ins.is_empty(), "concat {name}: no inputs");
    if ins.iter().all(|v| matches!(v, Value::F(_))) {
        let ts: Vec<&Tensor> = ins
            .iter()
            .map(|v| match v {
                Value::F(t) => t,
                Value::Q(_) => unreachable!(),
            })
            .collect();
        return Ok(Value::F(concat(name, &ts)?));
    }
    let lead3 = {
        let sh = ins[0].shape();
        anyhow::ensure!(sh.len() == 4, "concat {name}: non-NHWC input {sh:?}");
        [sh[0], sh[1], sh[2]]
    };
    for v in ins {
        let sh = v.shape();
        anyhow::ensure!(sh.len() == 4, "concat {name}: non-NHWC input {sh:?}");
        anyhow::ensure!(
            sh[..3] == lead3,
            "concat {name}: [n,h,w] mismatch ({:?} vs {:?})",
            &sh[..3],
            &lead3[..]
        );
    }
    let (n, h, w) = (lead3[0], lead3[1], lead3[2]);
    let cs: Vec<usize> = ins.iter().map(|v| v.shape()[3]).collect();
    let c_total: usize = cs.iter().sum();
    let mut out = scratch.tensor(&[n, h, w, c_total]);
    let rows = n * h * w;
    for r in 0..rows {
        let mut off = 0;
        for (v, &ct) in ins.iter().zip(&cs) {
            let dst = &mut out.data[r * c_total + off..r * c_total + off + ct];
            match v {
                Value::F(t) => dst.copy_from_slice(&t.data[r * ct..(r + 1) * ct]),
                Value::Q(q) => {
                    let (zp, s) = (q.qp.zero_point, q.qp.scale);
                    for (d, &qv) in dst.iter_mut().zip(&q.data[r * ct..(r + 1) * ct]) {
                        *d = (qv as i32 - zp) as f32 * s;
                    }
                }
            }
            off += ct;
        }
    }
    Ok(Value::F(out))
}

fn shuffle(x: &Tensor, groups: usize) -> Tensor {
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let per = c / groups;
    let mut data = vec![0.0f32; x.data.len()];
    let rows = n * h * w;
    for r in 0..rows {
        let src = &x.data[r * c..(r + 1) * c];
        let dst = &mut data[r * c..(r + 1) * c];
        // [g, per] -> [per, g] transpose
        for g in 0..groups {
            for p in 0..per {
                dst[p * groups + g] = src[g * per + p];
            }
        }
    }
    Tensor { shape: vec![n, h, w, c], data }
}

/// Integer channel shuffle: a pure permutation of raw i8 values, so the
/// output keeps the input's grid bit-exactly.
fn shuffle_q(x: &QTensor, groups: usize, scratch: &mut InterpScratch) -> QTensor {
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let per = c / groups;
    let mut out = scratch.qtensor(&x.shape, x.qp);
    let rows = n * h * w;
    for r in 0..rows {
        let src = &x.data[r * c..(r + 1) * c];
        let dst = &mut out.data[r * c..(r + 1) * c];
        for g in 0..groups {
            for p in 0..per {
                dst[p * groups + g] = src[g * per + p];
            }
        }
    }
    out
}

/// Elementwise add over two [`Value`]s, dequantizing i8 operands on the
/// fly (the `a + b` order and per-element op sequence match the f32
/// oracle exactly).
fn add_values(a: &Value, b: &Value, act: Act, scratch: &mut InterpScratch) -> Result<Value> {
    anyhow::ensure!(a.shape() == b.shape(), "add shape mismatch");
    let mut out = scratch.tensor(a.shape());
    match (a, b) {
        (Value::F(ta), Value::F(tb)) => {
            for ((d, &va), &vb) in out.data.iter_mut().zip(&ta.data).zip(&tb.data) {
                *d = act.apply(va + vb);
            }
        }
        (Value::Q(qa), Value::F(tb)) => {
            let (zp, s) = (qa.qp.zero_point, qa.qp.scale);
            for ((d, &qv), &vb) in out.data.iter_mut().zip(&qa.data).zip(&tb.data) {
                *d = act.apply((qv as i32 - zp) as f32 * s + vb);
            }
        }
        (Value::F(ta), Value::Q(qb)) => {
            let (zp, s) = (qb.qp.zero_point, qb.qp.scale);
            for ((d, &va), &qv) in out.data.iter_mut().zip(&ta.data).zip(&qb.data) {
                *d = act.apply(va + (qv as i32 - zp) as f32 * s);
            }
        }
        (Value::Q(qa), Value::Q(qb)) => {
            let (za, sa) = (qa.qp.zero_point, qa.qp.scale);
            let (zb, sb) = (qb.qp.zero_point, qb.qp.scale);
            for ((d, &va), &vb) in out.data.iter_mut().zip(&qa.data).zip(&qb.data) {
                *d = act.apply((va as i32 - za) as f32 * sa + (vb as i32 - zb) as f32 * sb);
            }
        }
    }
    Ok(Value::F(out))
}

/// Top-1 predictions from logits [N, classes].
pub fn argmax_batch(logits: &Tensor) -> Vec<usize> {
    let classes = *logits.shape.last().unwrap();
    logits
        .data
        .chunks_exact(classes)
        .map(|row| {
            row.iter()
                .enumerate()
                // NaN-lowest: a NaN logit (overflowed activation) loses
                // to every real logit instead of panicking mid-batch or
                // (under a bare total_cmp) winning the argmax
                .max_by(|a, b| crate::util::stats::nan_min_cmp_f32(a.1, b.1))
                .map(|(i, _)| i)
                .unwrap_or(0)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::BitWidth;
    use crate::util::Json;

    fn graph_1conv() -> Graph {
        Graph::from_meta(
            &Json::parse(
                r#"{"name": "t", "input_shape": [4, 4, 1], "num_classes": 2,
            "nodes": [
              {"name": "c1", "op": "conv", "inputs": ["input"], "k": 3,
               "stride": 1, "pad": 1, "in_ch": 1, "out_ch": 1, "groups": 1,
               "act": "none"},
              {"name": "g1", "op": "gap", "inputs": ["c1"]},
              {"name": "d1", "op": "dense", "inputs": ["g1"], "in_dim": 1,
               "out_dim": 2}]}"#,
            )
            .unwrap(),
        )
        .unwrap()
    }

    fn identity_weights() -> HashMap<String, Tensor> {
        let mut w = HashMap::new();
        // 3x3 kernel with center 1 => identity conv
        let mut kw = vec![0.0; 9];
        kw[4] = 1.0;
        w.insert("c1_w".into(), Tensor::from_vec(&[3, 3, 1, 1], kw).unwrap());
        w.insert("c1_b".into(), Tensor::from_vec(&[1], vec![0.0]).unwrap());
        w.insert(
            "d1_w".into(),
            Tensor::from_vec(&[1, 2], vec![1.0, -1.0]).unwrap(),
        );
        w.insert("d1_b".into(), Tensor::from_vec(&[2], vec![0.0, 0.5]).unwrap());
        w
    }

    #[test]
    fn identity_conv_and_head() {
        let g = graph_1conv();
        let w = identity_weights();
        let interp = Interpreter::new(&g, &w);
        let x = Tensor::from_vec(&[1, 4, 4, 1], vec![1.0; 16]).unwrap();
        let logits = interp.forward(&x).unwrap();
        // gap(identity(ones)) = 1 -> logits = [1*1, 1*-1+0.5] = [1.0, -0.5]
        assert!((logits.data[0] - 1.0).abs() < 1e-6);
        assert!((logits.data[1] + 0.5).abs() < 1e-6);
        assert_eq!(argmax_batch(&logits), vec![0]);
    }

    #[test]
    fn acts_capture_matches_quant_points() {
        let g = graph_1conv();
        let w = identity_weights();
        let interp = Interpreter::new(&g, &w);
        let x = Tensor::from_vec(&[1, 4, 4, 1], vec![0.5; 16]).unwrap();
        let (_, acts) = interp.forward_acts(&x).unwrap();
        assert_eq!(acts.len(), g.quant_points().len());
        // first captured tensor is the input itself
        assert_eq!(acts[0].data, x.data);
    }

    #[test]
    fn pool_maxavg() {
        let x = Tensor::from_vec(&[1, 2, 2, 1], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let mx = pool(&x, "p", PoolKind::Max, 2, 2, 0).unwrap();
        assert_eq!(mx.data, vec![4.0]);
        let av = pool(&x, "p", PoolKind::Avg, 2, 2, 0).unwrap();
        assert_eq!(av.data, vec![2.5]);
    }

    #[test]
    fn padded_avg_pool_divides_by_valid_count() {
        // 2x2 input [[1,2],[3,4]], k=2 s=1 pad=1 -> 3x3 output; border
        // windows average only their valid cells (hand-computed)
        let x = Tensor::from_vec(&[1, 2, 2, 1], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let y = pool(&x, "p", PoolKind::Avg, 2, 1, 1).unwrap();
        assert_eq!(y.shape, vec![1, 3, 3, 1]);
        assert_eq!(y.data, vec![1.0, 1.5, 2.0, 2.0, 2.5, 3.0, 3.0, 3.5, 4.0]);
    }

    #[test]
    fn pool_rejects_all_padding_geometry() {
        let x = Tensor::from_vec(&[1, 2, 2, 1], vec![0.0; 4]).unwrap();
        let err = pool(&x, "pbad", PoolKind::Avg, 2, 1, 2).unwrap_err();
        assert!(err.to_string().contains("pbad"), "{err}");
        assert!(err.to_string().contains("pad"), "{err}");
    }

    #[test]
    fn shuffle_transposes_groups() {
        // c=4, groups=2: [a b c d] -> [a c b d]
        let x = Tensor::from_vec(&[1, 1, 1, 4], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let y = shuffle(&x, 2);
        assert_eq!(y.data, vec![1.0, 3.0, 2.0, 4.0]);
    }

    #[test]
    fn concat_channels() {
        let a = Tensor::from_vec(&[1, 1, 1, 2], vec![1.0, 2.0]).unwrap();
        let b = Tensor::from_vec(&[1, 1, 1, 1], vec![9.0]).unwrap();
        let y = concat("cat", &[&a, &b]).unwrap();
        assert_eq!(y.shape, vec![1, 1, 1, 3]);
        assert_eq!(y.data, vec![1.0, 2.0, 9.0]);
    }

    #[test]
    fn concat_rejects_mismatched_leading_dims() {
        let a = Tensor::from_vec(&[1, 2, 2, 1], vec![0.0; 4]).unwrap();
        let b = Tensor::from_vec(&[1, 1, 2, 1], vec![0.0; 2]).unwrap();
        let err = concat("cat2", &[&a, &b]).unwrap_err();
        assert!(err.to_string().contains("cat2"), "{err}");
        assert!(err.to_string().contains("mismatch"), "{err}");
    }

    #[test]
    fn prepared_weight_pack_validates() {
        let qw = QuantWeight {
            shape: vec![2, 2],
            repr: IntRepr::I8(vec![1, -2, 3, -4]),
            scales: vec![0.5, 0.25],
            zero_points: vec![0, 1],
            width: BitWidth::Int8,
        };
        let pw = PreparedWeight::pack(qw, 1).unwrap();
        assert_eq!(pw.groups(), 1);
        let (panels, zb) = pw.group(0);
        assert_eq!(zb.to_vec(), vec![0, 1]);
        match panels {
            PackedPanels::I8(p) => assert_eq!((p.k, p.n), (2, 2)),
            PackedPanels::I4(_) => panic!("expected i8 panels"),
        }
        // out_ch=2 not divisible by groups=3
        let qw2 = QuantWeight {
            shape: vec![2, 2],
            repr: IntRepr::I8(vec![1, -2, 3, -4]),
            scales: vec![0.5],
            zero_points: vec![0],
            width: BitWidth::Int8,
        };
        assert!(PreparedWeight::pack(qw2, 3).is_err());
    }

    #[test]
    fn act_bounds_fold_is_exact() {
        // quantize(act(v)) == clamp(quantize(v), act_bounds) across a
        // dense sweep, for every activation (monotonicity argument)
        let p = QParams { scale: 0.043, zero_point: -7, qmin: -128.0, qmax: 127.0 };
        for act in [Act::None, Act::Relu, Act::Relu6] {
            let (lo, hi) = act_bounds(act, &p);
            assert!(lo <= hi);
            let mut v = -7.0f32;
            while v < 7.0 {
                let oracle = p.quantize(act.apply(v));
                let folded = p.quantize(v).clamp(lo, hi);
                assert_eq!(oracle, folded, "act {act:?} v {v}");
                v += 0.0137;
            }
        }
    }

    #[test]
    fn scratch_reuse_is_bitwise_stable() {
        let g = graph_1conv();
        let w = identity_weights();
        let interp = Interpreter::new(&g, &w);
        let x = Tensor::from_vec(
            &[2, 4, 4, 1],
            (0..32).map(|i| (i as f32) * 0.11 - 1.5).collect(),
        )
        .unwrap();
        let rows = vec![[0.05f32, -3.0, -128.0, 127.0, 0.0]; g.quant_points().len()];
        let aq = ActQuantization { rows };
        let baseline = interp.forward_fq(&x, &aq).unwrap();
        let mut scratch = InterpScratch::for_graph(&g, 2);
        for _ in 0..3 {
            let got = interp.forward_fq_with(&x, &aq, &mut scratch).unwrap();
            assert_eq!(got.shape, baseline.shape);
            assert_eq!(got.data, baseline.data);
        }
        // fp32 route through the same arena is stable too
        let f0 = interp.forward(&x).unwrap();
        let f1 = interp.forward_with(&x, &mut scratch).unwrap();
        assert_eq!(f0.data, f1.data);
    }

    #[test]
    fn qtensor_ops_preserve_grid() {
        let qp = QParams { scale: 0.1, zero_point: 3, qmin: -128.0, qmax: 127.0 };
        let mut scratch = InterpScratch::new();
        let x = QTensor {
            shape: vec![1, 2, 2, 2],
            data: vec![1, -2, 3, -4, 5, -6, 7, -8],
            qp,
        };
        let mx = pool_max_q(&x, "p", 2, 2, 0, &mut scratch).unwrap();
        assert_eq!(mx.data, vec![7, -2]);
        let sh = shuffle_q(
            &QTensor { shape: vec![1, 1, 1, 4], data: vec![1, 2, 3, 4], qp },
            2,
            &mut scratch,
        );
        assert_eq!(sh.data, vec![1, 3, 2, 4]);
        // avg over the full window equals the mean of dequantized cells
        let av = pool_avg_q(&x, "p", 2, 2, 0, &mut scratch).unwrap();
        let deq = x.dequantize();
        let oracle = pool(&deq, "p", PoolKind::Avg, 2, 2, 0).unwrap();
        for (a, b) in av.data.iter().zip(&oracle.data) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }
}
