//! Packed, register-tiled GEMM kernels: the integer engine behind the
//! fake-quant interpreter (ROADMAP item 1).
//!
//! Three kernel families share one blocking scheme:
//! - **i8 x i8 -> i32** ([`gemm_i8_i32`] / [`qgemm_i8`]): true int8
//!   operands, 32-bit accumulators;
//! - **packed int4 x i8 -> i32** ([`gemm_i4_i32`] / [`qgemm_i4`]):
//!   B stays in the 2-weights-per-byte representation ([`PanelsI4`])
//!   and nibbles are sign-extended in-register -- the f32 weights are
//!   never materialized;
//! - **blocked f32** ([`gemm_f32_blocked`]): the same tiling contract
//!   on floats, kept for the bench A/B against the legacy
//!   [`super::gemm::gemm_f32`] row kernel.
//!
//! Blocking: B is repacked once into [`NR`]-column panels (column-major
//! panels, contiguous per k-step) so the microkernel streams it
//! linearly; A is consumed row-major in [`MR`]-row blocks with an
//! `[MR x NR]` accumulator block held in registers. The inner loop is
//! plain indexed arithmetic over fixed-size arrays, which LLVM
//! autovectorizes (the panel width is two SIMD registers of i32/f32 on
//! AVX2).
//!
//! Contracts shared with [`super::gemm`]:
//! - **bit-exactness across threads**: `_tiled` variants split C's rows
//!   into contiguous blocks running the identical serial kernel, and
//!   per-(row, column) accumulation order is independent of the split,
//!   so serial == tiled at any `QUANTUNE_THREADS` (exactly, including
//!   f32).
//! - **zero-skip keying on A**: an aligned k-quad (k-pair for int4) is
//!   skipped only when *all* its A values are zero; remainder elements
//!   skip individually. See the NaN/Inf notes on
//!   [`super::gemm::gemm_f32`] -- the f32 blocked kernel preserves that
//!   contract verbatim.
//!
//! Overflow: i8 operands bound each product by `128 * 127`, so a k up
//! to ~130k accumulates within i32; our largest conv GEMM k is ~4.6k.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::pool;

use super::gemm::PAR_MIN_MACS;

/// Process-wide count of integer pack calls ([`pack_b_i8`] +
/// [`pack_b_i4`]), for asserting that steady-state forwards run on
/// prepacked panels (PR 7). Relaxed ordering: it is a statistic, not a
/// synchronization point.
static PACK_CALLS: AtomicU64 = AtomicU64::new(0);

/// Total integer pack calls since process start. Steady-state integer
/// forwards must not move this counter -- `bench_interp` and the
/// end-to-end tests assert a zero delta across repeated forward passes.
pub fn pack_calls() -> u64 {
    PACK_CALLS.load(Ordering::Relaxed)
}

/// Microkernel row-block height (A rows per accumulator block).
pub const MR: usize = 4;

/// Panel width: B columns per packed panel (= accumulator block width).
pub const NR: usize = 16;

/// f32 B operand repacked into [`NR`]-column panels.
///
/// Panel `jp` holds columns `jp*NR .. jp*NR+NR` (zero-padded past `n`);
/// within a panel, the `NR` values of k-step `p` are contiguous at
/// `p*NR`, so the microkernel reads one cache line per k-step.
pub struct PanelsF32 {
    /// Shared (inner) dimension.
    pub k: usize,
    /// Logical column count (before panel padding).
    pub n: usize,
    data: Vec<f32>,
}

/// Pack a row-major `[k, n]` f32 matrix into [`PanelsF32`].
pub fn pack_b_f32(k: usize, n: usize, b: &[f32]) -> PanelsF32 {
    debug_assert_eq!(b.len(), k * n);
    let np = n.div_ceil(NR);
    let mut data = vec![0.0f32; np * k * NR];
    for jp in 0..np {
        let j0 = jp * NR;
        let w = NR.min(n - j0);
        let panel = &mut data[jp * k * NR..(jp + 1) * k * NR];
        for p in 0..k {
            for jj in 0..w {
                panel[p * NR + jj] = b[p * n + j0 + jj];
            }
        }
    }
    PanelsF32 { k, n, data }
}

/// i8 B operand repacked into [`NR`]-column panels, with per-column
/// sums for the zero-point correction of [`qgemm_i8`].
///
/// Same layout as [`PanelsF32`] over i8 elements.
pub struct PanelsI8 {
    /// Shared (inner) dimension.
    pub k: usize,
    /// Logical column count (before panel padding).
    pub n: usize,
    data: Vec<i8>,
    /// `col_sums[j] = sum_p B[p, j]` (length `n`).
    pub col_sums: Vec<i32>,
}

/// Pack an i8 B operand into [`PanelsI8`] via an element accessor
/// (`at(p, j)` returns `B[p, j]`), so callers can pack straight from a
/// strided weight tensor without materializing the `[k, n]` matrix.
pub fn pack_b_i8(k: usize, n: usize, at: impl Fn(usize, usize) -> i8) -> PanelsI8 {
    PACK_CALLS.fetch_add(1, Ordering::Relaxed);
    let np = n.div_ceil(NR);
    let mut data = vec![0i8; np * k * NR];
    let mut col_sums = vec![0i32; n];
    for jp in 0..np {
        let j0 = jp * NR;
        let w = NR.min(n - j0);
        let panel = &mut data[jp * k * NR..(jp + 1) * k * NR];
        for p in 0..k {
            for jj in 0..w {
                let v = at(p, j0 + jj);
                panel[p * NR + jj] = v;
                col_sums[j0 + jj] += v as i32;
            }
        }
    }
    PanelsI8 { k, n, data, col_sums }
}

/// Packed-int4 B operand: nibble pairs along k, [`NR`]-column panels,
/// plus per-column sums for the zero-point correction of [`qgemm_i4`].
///
/// Byte `p2*NR + jj` of panel `jp` holds column `jp*NR + jj`'s weights
/// for k-steps `2*p2` (low nibble) and `2*p2 + 1` (high nibble) -- the
/// same low-nibble-first convention as
/// [`PackedI4`](crate::quant::PackedI4), applied down each column. Odd
/// k leaves the final high nibble zero. The microkernel sign-extends
/// nibbles in-register; int4 weights are never widened in memory.
pub struct PanelsI4 {
    /// Shared (inner) dimension (elements, not bytes).
    pub k: usize,
    /// Logical column count (before panel padding).
    pub n: usize,
    data: Vec<u8>,
    /// `col_sums[j] = sum_p B[p, j]` (length `n`).
    pub col_sums: Vec<i32>,
}

/// Pack an int4 B operand into [`PanelsI4`] via an element accessor
/// (`at(p, j)` must return values in [-8, 7]).
pub fn pack_b_i4(k: usize, n: usize, at: impl Fn(usize, usize) -> i8) -> PanelsI4 {
    PACK_CALLS.fetch_add(1, Ordering::Relaxed);
    let kp = k.div_ceil(2);
    let np = n.div_ceil(NR);
    let mut data = vec![0u8; np * kp * NR];
    let mut col_sums = vec![0i32; n];
    for jp in 0..np {
        let j0 = jp * NR;
        let w = NR.min(n - j0);
        let panel = &mut data[jp * kp * NR..(jp + 1) * kp * NR];
        for p2 in 0..kp {
            for jj in 0..w {
                let lo = at(2 * p2, j0 + jj);
                let hi = if 2 * p2 + 1 < k { at(2 * p2 + 1, j0 + jj) } else { 0 };
                debug_assert!(
                    (-8..=7).contains(&lo) && (-8..=7).contains(&hi),
                    "int4 operand out of range: {lo}/{hi}"
                );
                panel[p2 * NR + jj] = ((lo as u8) & 0x0f) | ((hi as u8) << 4);
                col_sums[j0 + jj] += lo as i32 + hi as i32;
            }
        }
    }
    PanelsI4 { k, n, data, col_sums }
}

// ---- f32 blocked kernel ----

/// C += A * B over f32 with B pre-packed into panels. Auto-tiles like
/// [`super::gemm::gemm_f32`]; see [`gemm_f32_blocked_tiled`].
pub fn gemm_f32_blocked(m: usize, a: &[f32], b: &PanelsF32, c: &mut [f32]) {
    let macs = m.saturating_mul(b.k).saturating_mul(b.n);
    let threads = if macs >= PAR_MIN_MACS { pool::effective_threads() } else { 1 };
    gemm_f32_blocked_tiled(m, a, b, c, threads);
}

/// C += A * B (f32, packed B) with an explicit worker count. Bit-exact
/// against `threads == 1` at any count: each worker runs the identical
/// serial kernel over a disjoint row block, and per-element accumulation
/// order does not depend on the block boundaries.
pub fn gemm_f32_blocked_tiled(m: usize, a: &[f32], b: &PanelsF32, c: &mut [f32], threads: usize) {
    debug_assert_eq!(a.len(), m * b.k);
    debug_assert_eq!(c.len(), m * b.n);
    let threads = threads.clamp(1, m.max(1));
    if threads <= 1 || b.k == 0 || b.n == 0 {
        gemm_f32_blocked_serial(m, a, b, c);
        return;
    }
    let rows_per = m.div_ceil(threads);
    std::thread::scope(|scope| {
        for (ab, cb) in a.chunks(rows_per * b.k).zip(c.chunks_mut(rows_per * b.n)) {
            scope.spawn(move || gemm_f32_blocked_serial(cb.len() / b.n, ab, b, cb));
        }
    });
}

fn gemm_f32_blocked_serial(m: usize, a: &[f32], b: &PanelsF32, c: &mut [f32]) {
    let (k, n) = (b.k, b.n);
    if k == 0 || n == 0 {
        return;
    }
    let np = n.div_ceil(NR);
    let k4 = k / 4 * 4;
    for jp in 0..np {
        let panel = &b.data[jp * k * NR..(jp + 1) * k * NR];
        let j0 = jp * NR;
        let w = NR.min(n - j0);
        let mut i = 0;
        while i < m {
            let rows = MR.min(m - i);
            let mut acc = [[0.0f32; NR]; MR];
            let mut p = 0;
            while p < k4 {
                let b0 = &panel[p * NR..(p + 1) * NR];
                let b1 = &panel[(p + 1) * NR..(p + 2) * NR];
                let b2 = &panel[(p + 2) * NR..(p + 3) * NR];
                let b3 = &panel[(p + 3) * NR..(p + 4) * NR];
                for r in 0..rows {
                    let ar = &a[(i + r) * k..(i + r) * k + k];
                    let (a0, a1, a2, a3) = (ar[p], ar[p + 1], ar[p + 2], ar[p + 3]);
                    // zero-skip contract: all-zero quads only (see
                    // super::gemm::gemm_f32)
                    if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
                        continue;
                    }
                    let accr = &mut acc[r];
                    for j in 0..NR {
                        accr[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
                    }
                }
                p += 4;
            }
            while p < k {
                let bp = &panel[p * NR..(p + 1) * NR];
                for r in 0..rows {
                    let av = a[(i + r) * k + p];
                    if av != 0.0 {
                        let accr = &mut acc[r];
                        for j in 0..NR {
                            accr[j] += av * bp[j];
                        }
                    }
                }
                p += 1;
            }
            for r in 0..rows {
                let crow = &mut c[(i + r) * n + j0..(i + r) * n + j0 + w];
                for (cv, &av) in crow.iter_mut().zip(&acc[r][..w]) {
                    *cv += av;
                }
            }
            i += rows;
        }
    }
}

// ---- i8 kernel ----

/// C += A * B over raw i8 operands into i32 (no zero-point handling;
/// see [`qgemm_i8`] for the corrected form). Auto-tiles.
pub fn gemm_i8_i32(m: usize, a: &[i8], b: &PanelsI8, c: &mut [i32]) {
    let macs = m.saturating_mul(b.k).saturating_mul(b.n);
    let threads = if macs >= PAR_MIN_MACS { pool::effective_threads() } else { 1 };
    gemm_i8_i32_tiled(m, a, b, c, threads);
}

/// C += A * B (i8, packed B) with an explicit worker count; integer
/// arithmetic, so serial == tiled exactly at any count.
pub fn gemm_i8_i32_tiled(m: usize, a: &[i8], b: &PanelsI8, c: &mut [i32], threads: usize) {
    debug_assert_eq!(a.len(), m * b.k);
    debug_assert_eq!(c.len(), m * b.n);
    let threads = threads.clamp(1, m.max(1));
    if threads <= 1 || b.k == 0 || b.n == 0 {
        gemm_i8_serial(m, a, b, c);
        return;
    }
    let rows_per = m.div_ceil(threads);
    std::thread::scope(|scope| {
        for (ab, cb) in a.chunks(rows_per * b.k).zip(c.chunks_mut(rows_per * b.n)) {
            scope.spawn(move || gemm_i8_serial(cb.len() / b.n, ab, b, cb));
        }
    });
}

fn gemm_i8_serial(m: usize, a: &[i8], b: &PanelsI8, c: &mut [i32]) {
    let (k, n) = (b.k, b.n);
    if k == 0 || n == 0 {
        return;
    }
    let np = n.div_ceil(NR);
    let k4 = k / 4 * 4;
    for jp in 0..np {
        let panel = &b.data[jp * k * NR..(jp + 1) * k * NR];
        let j0 = jp * NR;
        let w = NR.min(n - j0);
        let mut i = 0;
        while i < m {
            let rows = MR.min(m - i);
            let mut acc = [[0i32; NR]; MR];
            let mut p = 0;
            while p < k4 {
                let b0 = &panel[p * NR..(p + 1) * NR];
                let b1 = &panel[(p + 1) * NR..(p + 2) * NR];
                let b2 = &panel[(p + 2) * NR..(p + 3) * NR];
                let b3 = &panel[(p + 3) * NR..(p + 4) * NR];
                for r in 0..rows {
                    let ar = &a[(i + r) * k..(i + r) * k + k];
                    let (a0, a1, a2, a3) = (
                        ar[p] as i32,
                        ar[p + 1] as i32,
                        ar[p + 2] as i32,
                        ar[p + 3] as i32,
                    );
                    // quantized post-ReLU rows are zero-heavy at
                    // zero_point 0; keep the f32 kernel's skip keying
                    if (a0 | a1 | a2 | a3) == 0 {
                        continue;
                    }
                    let accr = &mut acc[r];
                    for j in 0..NR {
                        accr[j] += a0 * b0[j] as i32
                            + a1 * b1[j] as i32
                            + a2 * b2[j] as i32
                            + a3 * b3[j] as i32;
                    }
                }
                p += 4;
            }
            while p < k {
                let bp = &panel[p * NR..(p + 1) * NR];
                for r in 0..rows {
                    let av = a[(i + r) * k + p] as i32;
                    if av != 0 {
                        let accr = &mut acc[r];
                        for j in 0..NR {
                            accr[j] += av * bp[j] as i32;
                        }
                    }
                }
                p += 1;
            }
            for r in 0..rows {
                let crow = &mut c[(i + r) * n + j0..(i + r) * n + j0 + w];
                for (cv, &av) in crow.iter_mut().zip(&acc[r][..w]) {
                    *cv += av;
                }
            }
            i += rows;
        }
    }
}

// ---- packed-int4 kernel ----

/// C += A * B with B in the packed-int4 panels (raw grid values; see
/// [`qgemm_i4`] for the zero-point-corrected form). Auto-tiles.
pub fn gemm_i4_i32(m: usize, a: &[i8], b: &PanelsI4, c: &mut [i32]) {
    let macs = m.saturating_mul(b.k).saturating_mul(b.n);
    let threads = if macs >= PAR_MIN_MACS { pool::effective_threads() } else { 1 };
    gemm_i4_i32_tiled(m, a, b, c, threads);
}

/// C += A * B (packed int4 B) with an explicit worker count; integer
/// arithmetic, so serial == tiled exactly at any count.
pub fn gemm_i4_i32_tiled(m: usize, a: &[i8], b: &PanelsI4, c: &mut [i32], threads: usize) {
    debug_assert_eq!(a.len(), m * b.k);
    debug_assert_eq!(c.len(), m * b.n);
    let threads = threads.clamp(1, m.max(1));
    if threads <= 1 || b.k == 0 || b.n == 0 {
        gemm_i4_serial(m, a, b, c);
        return;
    }
    let rows_per = m.div_ceil(threads);
    std::thread::scope(|scope| {
        for (ab, cb) in a.chunks(rows_per * b.k).zip(c.chunks_mut(rows_per * b.n)) {
            scope.spawn(move || gemm_i4_serial(cb.len() / b.n, ab, b, cb));
        }
    });
}

fn gemm_i4_serial(m: usize, a: &[i8], b: &PanelsI4, c: &mut [i32]) {
    let (k, n) = (b.k, b.n);
    if k == 0 || n == 0 {
        return;
    }
    let kp = k.div_ceil(2);
    let np = n.div_ceil(NR);
    for jp in 0..np {
        let panel = &b.data[jp * kp * NR..(jp + 1) * kp * NR];
        let j0 = jp * NR;
        let w = NR.min(n - j0);
        let mut i = 0;
        while i < m {
            let rows = MR.min(m - i);
            let mut acc = [[0i32; NR]; MR];
            for p2 in 0..kp {
                let bp = &panel[p2 * NR..(p2 + 1) * NR];
                for r in 0..rows {
                    let ar = &a[(i + r) * k..(i + r) * k + k];
                    let a0 = ar[2 * p2] as i32;
                    let a1 = if 2 * p2 + 1 < k { ar[2 * p2 + 1] as i32 } else { 0 };
                    // zero-skip on the k-pair (the int4 analogue of the
                    // aligned quad): both A values zero -> no work
                    if (a0 | a1) == 0 {
                        continue;
                    }
                    let accr = &mut acc[r];
                    for j in 0..NR {
                        // sign-extend both nibbles in-register
                        let byte = bp[j];
                        let lo = (((byte << 4) as i8) >> 4) as i32;
                        let hi = ((byte as i8) >> 4) as i32;
                        accr[j] += a0 * lo + a1 * hi;
                    }
                }
            }
            for r in 0..rows {
                let crow = &mut c[(i + r) * n + j0..(i + r) * n + j0 + w];
                for (cv, &av) in crow.iter_mut().zip(&acc[r][..w]) {
                    *cv += av;
                }
            }
            i += rows;
        }
    }
}

// ---- zero-point-corrected entry points ----

/// gemmlowp-style zero-point correction applied after a raw-operand
/// GEMM: turns `C_raw[i,j] = sum_p qa[i,p] * qb[p,j]` into the centered
/// product `sum_p (qa - za)(qb - zb_j)` via
/// `C += k*za*zb_j - zb_j*rowsum_i - za*colsum_j`. O(m*n + m*k),
/// negligible next to the O(m*k*n) GEMM.
#[allow(clippy::too_many_arguments)]
fn correct_zero_points(
    m: usize,
    k: usize,
    n: usize,
    a: &[i8],
    za: i32,
    col_sums: &[i32],
    zb: &[i32],
    c: &mut [i32],
) {
    let kk = k as i32;
    for i in 0..m {
        let rowsum: i32 = a[i * k..(i + 1) * k].iter().map(|&v| v as i32).sum();
        let crow = &mut c[i * n..(i + 1) * n];
        for j in 0..n {
            let zbj = zb[if zb.len() == 1 { 0 } else { j }];
            crow[j] += kk * za * zbj - zbj * rowsum - za * col_sums[j];
        }
    }
}

/// Zero-point-corrected i8 GEMM (overwrites `c`):
/// `C[i,j] = sum_p (A[i,p] - za) * (B[p,j] - zb_j)`.
///
/// This is the interpreter's integer conv/dense product: A holds
/// uncentered activation grid values, B uncentered weight grid values,
/// and the correction terms (gemmlowp's trick) reconstruct the centered
/// product exactly in integer arithmetic -- so asymmetric schemes with
/// nonzero zero points run on true i8 operands. `zb` is per-column
/// (length `n`) or broadcast (length 1). Auto-tiles like
/// [`gemm_i8_i32`]; the correction pass is serial and deterministic.
pub fn qgemm_i8(m: usize, a: &[i8], za: i32, b: &PanelsI8, zb: &[i32], c: &mut [i32]) {
    let macs = m.saturating_mul(b.k).saturating_mul(b.n);
    let threads = if macs >= PAR_MIN_MACS { pool::effective_threads() } else { 1 };
    qgemm_i8_tiled(m, a, za, b, zb, c, threads);
}

/// [`qgemm_i8`] with an explicit worker count (bit-exact at any count).
#[allow(clippy::too_many_arguments)]
pub fn qgemm_i8_tiled(
    m: usize,
    a: &[i8],
    za: i32,
    b: &PanelsI8,
    zb: &[i32],
    c: &mut [i32],
    threads: usize,
) {
    debug_assert!(zb.len() == 1 || zb.len() == b.n);
    c.fill(0);
    gemm_i8_i32_tiled(m, a, b, c, threads);
    correct_zero_points(m, b.k, b.n, a, za, &b.col_sums, zb, c);
}

/// Zero-point-corrected packed-int4 GEMM (overwrites `c`); the int4
/// counterpart of [`qgemm_i8`] -- A stays i8 (activations are always on
/// the int8 grid), B stays nibble-packed.
pub fn qgemm_i4(m: usize, a: &[i8], za: i32, b: &PanelsI4, zb: &[i32], c: &mut [i32]) {
    let macs = m.saturating_mul(b.k).saturating_mul(b.n);
    let threads = if macs >= PAR_MIN_MACS { pool::effective_threads() } else { 1 };
    qgemm_i4_tiled(m, a, za, b, zb, c, threads);
}

/// [`qgemm_i4`] with an explicit worker count (bit-exact at any count).
#[allow(clippy::too_many_arguments)]
pub fn qgemm_i4_tiled(
    m: usize,
    a: &[i8],
    za: i32,
    b: &PanelsI4,
    zb: &[i32],
    c: &mut [i32],
    threads: usize,
) {
    debug_assert!(zb.len() == 1 || zb.len() == b.n);
    c.fill(0);
    gemm_i4_i32_tiled(m, a, b, c, threads);
    correct_zero_points(m, b.k, b.n, a, za, &b.col_sums, zb, c);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn rand_i8(n: usize, lo: i8, hi: i8, seed: u64) -> Vec<i8> {
        let mut rng = Pcg32::seeded(seed);
        let span = (hi as i32 - lo as i32 + 1) as usize;
        (0..n)
            .map(|_| {
                // sprinkle zeros to exercise the skip path
                if rng.chance(0.3) {
                    0
                } else {
                    (lo as i32 + rng.below(span) as i32) as i8
                }
            })
            .collect()
    }

    fn naive_i32(m: usize, k: usize, n: usize, a: &[i8], b: &[i8]) -> Vec<i32> {
        let mut c = vec![0i32; m * n];
        for i in 0..m {
            for j in 0..n {
                for p in 0..k {
                    c[i * n + j] += a[i * k + p] as i32 * b[p * n + j] as i32;
                }
            }
        }
        c
    }

    #[test]
    fn pack_i8_layout_and_col_sums() {
        // k=3, n=18 -> 2 panels, second ragged (2 live columns)
        let (k, n) = (3, 18);
        let b: Vec<i8> = (0..k * n).map(|i| (i % 11) as i8 - 5).collect();
        let packed = pack_b_i8(k, n, |p, j| b[p * n + j]);
        for j in 0..n {
            let want: i32 = (0..k).map(|p| b[p * n + j] as i32).sum();
            assert_eq!(packed.col_sums[j], want, "col {j}");
        }
        // spot-check the panel layout: element (p=2, j=17) lives in
        // panel 1 at offset p*NR + (17 - 16)
        assert_eq!(packed.data[k * NR + 2 * NR + 1], b[2 * n + 17]);
    }

    #[test]
    fn pack_i4_nibble_layout() {
        // odd k: the final high nibble is padding and must read as 0
        let (k, n) = (3, 2);
        let b: Vec<i8> = vec![-8, 7, 3, -1, 5, 2]; // row-major [k, n]
        let packed = pack_b_i4(k, n, |p, j| b[p * n + j]);
        // column 0: k-steps (0,1) share byte 0 of panel row 0
        let byte = packed.data[0];
        assert_eq!((((byte << 4) as i8) >> 4), -8, "low nibble = k-step 0");
        assert_eq!(((byte as i8) >> 4), 3, "high nibble = k-step 1");
        for j in 0..n {
            let want: i32 = (0..k).map(|p| b[p * n + j] as i32).sum();
            assert_eq!(packed.col_sums[j], want, "col {j}");
        }
    }

    #[test]
    fn i8_matches_naive_on_ragged_shapes() {
        // shapes straddling the MR/NR block boundaries
        for (m, k, n, seed) in
            [(1, 1, 1, 1), (4, 16, 16, 2), (5, 7, 17, 3), (9, 33, 31, 4), (3, 4, 48, 5)]
        {
            let a = rand_i8(m * k, -128, 127, seed);
            let b = rand_i8(k * n, -128, 127, seed + 100);
            let packed = pack_b_i8(k, n, |p, j| b[p * n + j]);
            let mut c = vec![0i32; m * n];
            gemm_i8_i32_tiled(m, &a, &packed, &mut c, 1);
            assert_eq!(c, naive_i32(m, k, n, &a, &b), "{m}x{k}x{n}");
        }
    }

    #[test]
    fn i4_matches_naive_on_ragged_shapes() {
        // odd and even k (nibble-pair padding on odd)
        for (m, k, n, seed) in
            [(1, 1, 1, 1), (4, 2, 16, 2), (5, 7, 17, 3), (9, 33, 31, 4), (6, 8, 5, 5)]
        {
            let a = rand_i8(m * k, -128, 127, seed);
            let b = rand_i8(k * n, -8, 7, seed + 200);
            let packed = pack_b_i4(k, n, |p, j| b[p * n + j]);
            let mut c = vec![0i32; m * n];
            gemm_i4_i32_tiled(m, &a, &packed, &mut c, 1);
            assert_eq!(c, naive_i32(m, k, n, &a, &b), "{m}x{k}x{n}");
        }
    }

    #[test]
    fn integer_kernels_bit_exact_across_threads() {
        let (m, k, n) = (13, 9, 21);
        let a = rand_i8(m * k, -128, 127, 7);
        let b8 = rand_i8(k * n, -128, 127, 8);
        let b4 = rand_i8(k * n, -8, 7, 9);
        let p8 = pack_b_i8(k, n, |p, j| b8[p * n + j]);
        let p4 = pack_b_i4(k, n, |p, j| b4[p * n + j]);
        let mut c8 = vec![0i32; m * n];
        let mut c4 = vec![0i32; m * n];
        gemm_i8_i32_tiled(m, &a, &p8, &mut c8, 1);
        gemm_i4_i32_tiled(m, &a, &p4, &mut c4, 1);
        for threads in [2, 4, 8] {
            let mut t8 = vec![0i32; m * n];
            let mut t4 = vec![0i32; m * n];
            gemm_i8_i32_tiled(m, &a, &p8, &mut t8, threads);
            gemm_i4_i32_tiled(m, &a, &p4, &mut t4, threads);
            assert_eq!(t8, c8, "i8 threads {threads}");
            assert_eq!(t4, c4, "i4 threads {threads}");
        }
    }

    #[test]
    fn qgemm_matches_centered_reference() {
        // per-column weight zero points (channel granularity) + a
        // nonzero activation zero point: the corrected product must
        // equal the naive centered sum exactly
        let (m, k, n) = (7, 10, 19);
        let a = rand_i8(m * k, -128, 127, 11);
        let b = rand_i8(k * n, -128, 127, 12);
        let za = -3i32;
        let zb: Vec<i32> = (0..n as i32).map(|j| (j % 7) - 3).collect();
        let centered = |bv: &[i8]| -> Vec<i32> {
            let mut c = vec![0i32; m * n];
            for i in 0..m {
                for j in 0..n {
                    for p in 0..k {
                        c[i * n + j] += (a[i * k + p] as i32 - za)
                            * (bv[p * n + j] as i32 - zb[j]);
                    }
                }
            }
            c
        };
        let p8 = pack_b_i8(k, n, |p, j| b[p * n + j]);
        for threads in [1, 2, 4, 8] {
            let mut c = vec![999i32; m * n]; // overwritten, not accumulated
            qgemm_i8_tiled(m, &a, za, &p8, &zb, &mut c, threads);
            assert_eq!(c, centered(&b), "i8 threads {threads}");
        }
        let b4 = rand_i8(k * n, -8, 7, 13);
        let p4 = pack_b_i4(k, n, |p, j| b4[p * n + j]);
        for threads in [1, 2, 4, 8] {
            let mut c = vec![-5i32; m * n];
            qgemm_i4_tiled(m, &a, za, &p4, &zb, &mut c, threads);
            assert_eq!(c, centered(&b4), "i4 threads {threads}");
        }
        // broadcast zero point (tensor granularity)
        let zb1 = vec![5i32];
        let mut c = vec![0i32; m * n];
        qgemm_i8_tiled(m, &a, za, &p8, &zb1, &mut c, 2);
        let mut want = vec![0i32; m * n];
        for i in 0..m {
            for j in 0..n {
                for p in 0..k {
                    want[i * n + j] +=
                        (a[i * k + p] as i32 - za) * (b[p * n + j] as i32 - 5);
                }
            }
        }
        assert_eq!(c, want, "broadcast zb");
    }

    #[test]
    fn f32_blocked_matches_legacy_within_ulp() {
        let (m, k, n) = (11, 14, 27);
        let mut rng = Pcg32::seeded(17);
        let a: Vec<f32> = (0..m * k)
            .map(|_| if rng.chance(0.4) { 0.0 } else { rng.normal() })
            .collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let mut legacy = vec![0.0f32; m * n];
        super::super::gemm::gemm_f32_tiled(m, k, n, &a, &b, &mut legacy, 1);
        let packed = pack_b_f32(k, n, &b);
        let mut blocked = vec![0.0f32; m * n];
        gemm_f32_blocked_tiled(m, &a, &packed, &mut blocked, 1);
        for (i, (x, y)) in blocked.iter().zip(&legacy).enumerate() {
            // identical quad arithmetic, different accumulation nesting:
            // agree to a tight relative tolerance
            assert!(
                (x - y).abs() <= 1e-5 * y.abs().max(1.0),
                "elem {i}: {x} vs {y}"
            );
        }
        // threads bit-exact against the blocked serial result
        for threads in [2, 4, 8] {
            let mut t = vec![0.0f32; m * n];
            gemm_f32_blocked_tiled(m, &a, &packed, &mut t, threads);
            for (x, y) in t.iter().zip(&blocked) {
                assert_eq!(x.to_bits(), y.to_bits(), "threads {threads}");
            }
        }
    }

    #[test]
    fn f32_blocked_keeps_zero_skip_nan_contract() {
        // all-zero A row: NaN/Inf in B never reach C (same pin as the
        // legacy kernel's zero_skip_nan_contract_f32 test)
        let (m, k, n) = (1, 5, 3);
        let a = vec![0.0f32; k];
        let mut b = vec![1.0f32; k * n];
        b[0] = f32::NAN;
        b[4 * n] = f32::INFINITY;
        let packed = pack_b_f32(k, n, &b);
        let mut c = vec![0.5f32; m * n];
        gemm_f32_blocked(m, &a, &packed, &mut c);
        assert_eq!(c, vec![0.5; 3]);
    }

    #[test]
    fn empty_dims_are_safe() {
        let p = pack_b_i8(0, 0, |_, _| 0);
        let mut c: Vec<i32> = Vec::new();
        gemm_i8_i32_tiled(0, &[], &p, &mut c, 8);
        qgemm_i8_tiled(0, &[], 0, &p, &[], &mut c, 8);
        let p4 = pack_b_i4(4, 0, |_, _| 0);
        gemm_i4_i32_tiled(0, &[], &p4, &mut c, 8);
        let pf = pack_b_f32(0, 3, &[]);
        let mut cf = vec![1.0f32; 3];
        gemm_f32_blocked_tiled(1, &[], &pf, &mut cf, 8);
        assert_eq!(cf, vec![1.0; 3]);
    }
}
