//! Small dense GEMM used by the fp32/fake-quant interpreters.
//!
//! C[M,N] += A[M,K] * B[K,N], row-major. The m-k-n loop order keeps the
//! inner loop a contiguous FMA over C/B rows, which LLVM auto-vectorizes;
//! this is the interpreter's hot path (see EXPERIMENTS.md §Perf).
//!
//! Large GEMMs are row-tiled across the worker pool
//! (`util::pool`): each worker owns a disjoint block of C rows and runs
//! the identical serial kernel over it, so the parallel result is
//! bit-exact against the serial one at any thread count. Inside a pool
//! worker (e.g. under the batch-parallel evaluator) the kernel stays
//! serial -- `pool::effective_threads` reports 1 there -- to avoid
//! oversubscription.

use crate::util::pool;

/// MACs below which row tiling is pure overhead: a ~2M-MAC GEMM runs in
/// about a millisecond single-core, ~100x the cost of spawning workers.
/// Shared with the packed kernels in [`super::kernels`] so both engines
/// cross the serial/tiled threshold at the same problem size.
pub(crate) const PAR_MIN_MACS: usize = 1 << 21;

/// C += A * B over f32. Automatically row-tiles across the worker pool
/// when the problem is large enough (see [`gemm_f32_tiled`]).
///
/// # NaN/Inf propagation contract (zero-skip fast path)
///
/// Post-ReLU activation rows are zero-heavy, so the kernel skips work
/// keyed on **A** values being zero -- and skipped work never touches C,
/// even when the corresponding B entries are NaN or Inf:
///
/// - aligned k-quads (`p < k/4*4`): a quad is skipped only when **all
///   four** A values are `0.0`. A partially-zero quad still multiplies
///   through, so a NaN/Inf in B *can* poison C there (`0.0 * NaN` is
///   NaN, per IEEE-754).
/// - the k-remainder loop skips individual `a == 0.0` elements, so a
///   remainder NaN/Inf in B is masked by a zero in A.
///
/// In short: `0 * NaN` never poisons C *from a fully-zero quad or a
/// zero remainder element*; mixed quads follow IEEE-754. The packed
/// kernels in [`super::kernels`] implement the identical contract, and
/// the `zero_skip_nan_contract` tests pin it for both engines.
pub fn gemm_f32(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    let macs = m.saturating_mul(k).saturating_mul(n);
    let threads = if macs >= PAR_MIN_MACS { pool::effective_threads() } else { 1 };
    gemm_f32_tiled(m, k, n, a, b, c, threads);
}

/// C += A * B with an explicit worker count. `threads == 1` is exactly
/// the serial kernel; `threads > 1` splits C's rows into contiguous
/// blocks, one scoped thread per block. Exposed so the parity tests and
/// the perf bench can pin the tiling.
#[allow(clippy::too_many_arguments)]
pub fn gemm_f32_tiled(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    threads: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let threads = threads.clamp(1, m.max(1));
    if threads <= 1 || k == 0 || n == 0 {
        gemm_f32_serial(m, k, n, a, b, c);
        return;
    }
    let rows_per = m.div_ceil(threads);
    std::thread::scope(|scope| {
        for (ab, cb) in a.chunks(rows_per * k).zip(c.chunks_mut(rows_per * n)) {
            scope.spawn(move || gemm_f32_serial(cb.len() / n, k, n, ab, b, cb));
        }
    });
}

/// k is unrolled by 4 (§Perf): each pass over the C row applies four
/// rank-1 updates, which quarters the C-row traffic and gives the
/// autovectorizer four independent FMA streams. Post-ReLU activation
/// rows are zero-heavy, so an all-zero quad still short-circuits.
fn gemm_f32_serial(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    let k4 = k / 4 * 4;
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        let mut p = 0;
        while p < k4 {
            let (a0, a1, a2, a3) = (arow[p], arow[p + 1], arow[p + 2], arow[p + 3]);
            if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
                p += 4;
                continue;
            }
            let b0 = &b[p * n..(p + 1) * n];
            let b1 = &b[(p + 1) * n..(p + 2) * n];
            let b2 = &b[(p + 2) * n..(p + 3) * n];
            let b3 = &b[(p + 3) * n..(p + 4) * n];
            for j in 0..n {
                crow[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
            }
            p += 4;
        }
        for (p, &av) in arow.iter().enumerate().skip(k4) {
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// C += A * B over i32 (VTA accumulator semantics; no saturation --
/// accumulators are 32-bit like the hardware's register file and our
/// operand magnitudes cannot overflow them). Row-tiled like the f32
/// kernel.
///
/// Zero-skip contract: same shape as [`gemm_f32`] -- an aligned k-quad
/// is skipped only when all four A values are 0, the remainder loop
/// skips individual zeros. Integers have no NaN, so here the contract
/// is purely a performance statement (skipped quads do no work), but
/// the skip *keying* must stay identical to the f32 kernel so both
/// engines visit the same (i, p, j) triples.
pub fn gemm_i32(m: usize, k: usize, n: usize, a: &[i32], b: &[i32], c: &mut [i32]) {
    let macs = m.saturating_mul(k).saturating_mul(n);
    let threads = if macs >= PAR_MIN_MACS { pool::effective_threads() } else { 1 };
    gemm_i32_tiled(m, k, n, a, b, c, threads);
}

/// Integer counterpart of [`gemm_f32_tiled`].
#[allow(clippy::too_many_arguments)]
pub fn gemm_i32_tiled(
    m: usize,
    k: usize,
    n: usize,
    a: &[i32],
    b: &[i32],
    c: &mut [i32],
    threads: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let threads = threads.clamp(1, m.max(1));
    if threads <= 1 || k == 0 || n == 0 {
        gemm_i32_serial(m, k, n, a, b, c);
        return;
    }
    let rows_per = m.div_ceil(threads);
    std::thread::scope(|scope| {
        for (ab, cb) in a.chunks(rows_per * k).zip(c.chunks_mut(rows_per * n)) {
            scope.spawn(move || gemm_i32_serial(cb.len() / n, k, n, ab, b, cb));
        }
    });
}

/// Same k-by-4 unroll as the f32 kernel.
fn gemm_i32_serial(m: usize, k: usize, n: usize, a: &[i32], b: &[i32], c: &mut [i32]) {
    let k4 = k / 4 * 4;
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        let mut p = 0;
        while p < k4 {
            let (a0, a1, a2, a3) = (arow[p], arow[p + 1], arow[p + 2], arow[p + 3]);
            if (a0 | a1 | a2 | a3) == 0 {
                p += 4;
                continue;
            }
            let b0 = &b[p * n..(p + 1) * n];
            let b1 = &b[(p + 1) * n..(p + 2) * n];
            let b2 = &b[(p + 2) * n..(p + 3) * n];
            let b3 = &b[(p + 3) * n..(p + 4) * n];
            for j in 0..n {
                crow[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
            }
            p += 4;
        }
        for (p, &av) in arow.iter().enumerate().skip(k4) {
            if av == 0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_naive() {
        let (m, k, n) = (3, 4, 5);
        let a: Vec<f32> = (0..m * k).map(|i| i as f32 * 0.5 - 2.0).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i % 7) as f32 - 3.0).collect();
        let mut c = vec![0.0; m * n];
        gemm_f32(m, k, n, &a, &b, &mut c);
        for i in 0..m {
            for j in 0..n {
                let want: f32 = (0..k).map(|p| a[i * k + p] * b[p * n + j]).sum();
                assert!((c[i * n + j] - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn i32_matches_naive() {
        let (m, k, n) = (4, 3, 2);
        let a: Vec<i32> = (0..m * k).map(|i| i as i32 - 5).collect();
        let b: Vec<i32> = (0..k * n).map(|i| (i as i32 % 5) - 2).collect();
        let mut c = vec![0; m * n];
        gemm_i32(m, k, n, &a, &b, &mut c);
        for i in 0..m {
            for j in 0..n {
                let want: i32 = (0..k).map(|p| a[i * k + p] * b[p * n + j]).sum();
                assert_eq!(c[i * n + j], want);
            }
        }
    }

    #[test]
    fn accumulates_into_c() {
        let mut c = vec![1.0; 1];
        gemm_f32(1, 1, 1, &[2.0], &[3.0], &mut c);
        assert_eq!(c[0], 7.0);
    }

    #[test]
    fn tiled_is_bit_exact_on_ragged_rows() {
        // m = 5 rows over 8 requested workers: more workers than rows
        let (m, k, n) = (5, 7, 3);
        let a: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.37).sin()).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.11).cos()).collect();
        let mut serial = vec![0.5f32; m * n];
        gemm_f32_tiled(m, k, n, &a, &b, &mut serial, 1);
        for threads in [2, 3, 8] {
            let mut par = vec![0.5f32; m * n];
            gemm_f32_tiled(m, k, n, &a, &b, &mut par, threads);
            for (x, y) in par.iter().zip(&serial) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn zero_skip_nan_contract_f32() {
        // k = 5: one aligned quad + one remainder element.
        // Row 0: all-zero quad + zero remainder -> NaN/Inf B fully masked.
        // Row 1: partially-zero quad -> the quad's NaN poisons C (IEEE).
        let (m, k, n) = (2, 5, 3);
        let a = vec![
            0.0, 0.0, 0.0, 0.0, 0.0, // row 0
            0.0, 1.0, 0.0, 0.0, 0.0, // row 1: quad has a nonzero
        ];
        let mut b = vec![1.0f32; k * n];
        b[0] = f32::NAN; // quad row 0 of B, column 0
        b[4 * n] = f32::NAN; // remainder row of B, column 0
        b[4 * n + 1] = f32::INFINITY; // remainder row, column 1
        for threads in [1, 2, 4, 8] {
            let mut c = vec![0.25f32; m * n];
            gemm_f32_tiled(m, k, n, &a, &b, &mut c, threads);
            // row 0: everything in A is zero -> C untouched, no NaN
            assert_eq!(&c[..n], &[0.25; 3], "threads {threads}");
            // row 1: the quad multiplies through; 0*NaN + 1*b1 + ... is
            // NaN only where B's poisoned column lands (column 0)
            assert!(c[n].is_nan(), "threads {threads}: mixed quad must poison");
            assert_eq!(c[n + 1], 0.25 + 1.0);
        }
    }

    #[test]
    fn zero_skip_keys_on_a_only_i32() {
        // the i32 kernel skips the same (all-zero quad, zero remainder)
        // work items; B values under skipped positions never reach C
        let (m, k, n) = (1, 5, 2);
        let a = vec![0, 0, 0, 0, 0];
        let b = vec![i32::MAX; k * n]; // would overflow if touched
        let mut c = vec![7; m * n];
        gemm_i32(m, k, n, &a, &b, &mut c);
        assert_eq!(c, vec![7, 7]);
    }

    #[test]
    fn tiled_handles_empty() {
        let mut c: Vec<f32> = Vec::new();
        gemm_f32_tiled(0, 4, 0, &[], &[], &mut c, 8);
        assert!(c.is_empty());
        let mut c1 = vec![1.0f32; 2];
        gemm_f32_tiled(1, 0, 2, &[], &[], &mut c1, 8);
        assert_eq!(c1, vec![1.0, 1.0]);
    }
}
