//! Small dense GEMM used by the fp32/fake-quant interpreters.
//!
//! C[M,N] += A[M,K] * B[K,N], row-major. The m-k-n loop order keeps the
//! inner loop a contiguous FMA over C/B rows, which LLVM auto-vectorizes;
//! this is the interpreter's hot path (see EXPERIMENTS.md §Perf).

/// C += A * B.
///
/// k is unrolled by 4 (§Perf): each pass over the C row applies four
/// rank-1 updates, which quarters the C-row traffic and gives the
/// autovectorizer four independent FMA streams. Post-ReLU activation
/// rows are zero-heavy, so an all-zero quad still short-circuits.
pub fn gemm_f32(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let k4 = k / 4 * 4;
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        let mut p = 0;
        while p < k4 {
            let (a0, a1, a2, a3) = (arow[p], arow[p + 1], arow[p + 2], arow[p + 3]);
            if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
                p += 4;
                continue;
            }
            let b0 = &b[p * n..(p + 1) * n];
            let b1 = &b[(p + 1) * n..(p + 2) * n];
            let b2 = &b[(p + 2) * n..(p + 3) * n];
            let b3 = &b[(p + 3) * n..(p + 4) * n];
            for j in 0..n {
                crow[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
            }
            p += 4;
        }
        for (p, &av) in arow.iter().enumerate().skip(k4) {
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// C += A * B over i32 (VTA accumulator semantics; no saturation --
/// accumulators are 32-bit like the hardware's register file and our
/// operand magnitudes cannot overflow them). Same k-by-4 unroll as the
/// f32 kernel.
pub fn gemm_i32(m: usize, k: usize, n: usize, a: &[i32], b: &[i32], c: &mut [i32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let k4 = k / 4 * 4;
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        let mut p = 0;
        while p < k4 {
            let (a0, a1, a2, a3) = (arow[p], arow[p + 1], arow[p + 2], arow[p + 3]);
            if (a0 | a1 | a2 | a3) == 0 {
                p += 4;
                continue;
            }
            let b0 = &b[p * n..(p + 1) * n];
            let b1 = &b[(p + 1) * n..(p + 2) * n];
            let b2 = &b[(p + 2) * n..(p + 3) * n];
            let b3 = &b[(p + 3) * n..(p + 4) * n];
            for j in 0..n {
                crow[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
            }
            p += 4;
        }
        for (p, &av) in arow.iter().enumerate().skip(k4) {
            if av == 0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_naive() {
        let (m, k, n) = (3, 4, 5);
        let a: Vec<f32> = (0..m * k).map(|i| i as f32 * 0.5 - 2.0).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i % 7) as f32 - 3.0).collect();
        let mut c = vec![0.0; m * n];
        gemm_f32(m, k, n, &a, &b, &mut c);
        for i in 0..m {
            for j in 0..n {
                let want: f32 = (0..k).map(|p| a[i * k + p] * b[p * n + j]).sum();
                assert!((c[i * n + j] - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn i32_matches_naive() {
        let (m, k, n) = (4, 3, 2);
        let a: Vec<i32> = (0..m * k).map(|i| i as i32 - 5).collect();
        let b: Vec<i32> = (0..k * n).map(|i| (i as i32 % 5) - 2).collect();
        let mut c = vec![0; m * n];
        gemm_i32(m, k, n, &a, &b, &mut c);
        for i in 0..m {
            for j in 0..n {
                let want: i32 = (0..k).map(|p| a[i * k + p] * b[p * n + j]).sum();
                assert_eq!(c[i * n + j], want);
            }
        }
    }

    #[test]
    fn accumulates_into_c() {
        let mut c = vec![1.0; 1];
        gemm_f32(1, 1, 1, &[2.0], &[3.0], &mut c);
        assert_eq!(c[0], 7.0);
    }
}
