//! Quantune: post-training quantization auto-tuning for CNNs.
//!
//! Reproduction of "Quantune: Post-training Quantization of Convolutional
//! Neural Networks using Extreme Gradient Boosting for Fast Deployment"
//! (Lee et al., FGCS 2022) as a three-layer Rust + JAX + Pallas stack.
//! See `rust/ARCHITECTURE.md` for the data-flow picture and
//! `rust/BENCHMARKS.md` for how every table and figure is regenerated.
//!
//! # Paper-section map
//!
//! | Paper section | What it defines | Module |
//! |---|---|---|
//! | §4.1 calibration caches | histogram collection over {1, 64, 512} images | [`calib`], [`quant::Histogram`] |
//! | §4.2 quantization schemes (Eq. 2-13) | asymmetric / symmetric / symmetric-uint8 / pow2 grids | [`quant::scheme`] |
//! | §4.3 range clipping | max vs KL-divergence vs analytical ACIQ thresholds | [`quant::histogram`] |
//! | §4.4 granularity | per-tensor vs per-channel weight scales | [`quant::weights`] |
//! | §4.5 mixed precision | fp32 bypass, generalized to per-layer int4/int8/int16/fp32 | [`quant::space`], [`quant::BitWidth`] |
//! | Eq. 1 / Eq. 23 search spaces | the 288-element general and 12-element VTA spaces | [`quant::config`], [`quant::ConfigSpace`] |
//! | §5.1 features | arch blocks `e` ++ config features `s` | [`zoo`], [`coordinator::features_for`] |
//! | §5.2 XGB cost model + transfer | gradient-boosted trees over the trial database | [`xgb`], [`search::XgbSearch`] |
//! | Algorithm 1 / Fig 5-6 | the five scalar search drivers + NSGA-II Pareto search | [`search`], [`search::ParetoSearch`] |
//! | Fig 4 coordinator | artifact loading, sweeps, database `D`, objectives | [`coordinator`] |
//! | §6.4 integer-only deployment | VTA simulator + cycle model | [`vta`] |
//! | §6.5 latency | PJRT batch-1 wallclock | [`latency`], [`runtime`] |
//! | Tables/Figures | experiment drivers + CSV emitters | [`experiments`] |
//!
//! # Layers
//!
//! - L3 (this crate): the Quantune coordinator — quantization config search
//!   (XGBoost cost model + transfer learning), calibration, the quantization
//!   substrate (our mini-Glow graph IR + quantizers), the VTA integer-only
//!   simulator, and the PJRT runtime that executes AOT-lowered JAX models.
//!   Search, sweep, and the trial database are generic over a
//!   [`quant::ConfigSpace`]: the 288-element general space (Eq. 1 plus
//!   the ACIQ clipping and bias-correction axes), the
//!   12-element VTA integer-only space (Eq. 23), and per-model layer-wise
//!   mixed-precision spaces ([`quant::LayerwiseSpace`]) all flow through
//!   the same driver, and database records carry a space tag so transfer
//!   learning never mixes incompatible feature vectors. The layer-wise
//!   space is a mixed-radix genome: each fragile layer independently
//!   chooses a weight [`quant::BitWidth`] (int4 / int8 / int16 / fp32),
//!   with bytes and modeled latency priced per width. The driver is
//!   also objective-agnostic: [`coordinator::objective`] scalarizes
//!   (Top-1, modeled latency, serialized bytes) so every algorithm and
//!   space tunes deployment trade-offs unchanged, with trials, traces,
//!   and records carrying the per-component breakdown. On top of the
//!   scalarization sit hard deployment budgets
//!   ([`coordinator::Budget`], epsilon-constraint: over-budget configs
//!   are rejected from the static cost table before any accuracy
//!   measurement) and a Pareto-front search
//!   ([`search::ParetoSearch`], NSGA-II: non-dominated sorting +
//!   crowding distance over the component vectors, returning the
//!   recovered frontier as a [`search::ParetoTrace`]); rust/SEARCH.md
//!   is the user-facing guide to all six algorithms.
//! - L2 (python/compile/model.py): JAX forward graphs for the six CNN
//!   models, fp32 + fake-quant parameterized variants, AOT-lowered to HLO
//!   text artifacts at build time.
//! - L1 (python/compile/kernels/): Pallas kernels for the quantization
//!   hot-spot (fake-quant elementwise + int8 GEMM requantization), checked
//!   against pure-jnp oracles.
//!
//! # Parallel evaluation engine
//!
//! [`util::pool`] is a dependency-free worker pool (std scoped threads,
//! `QUANTUNE_THREADS` knob) that three layers of the accuracy-measurement
//! path schedule through -- the row-tiled GEMM in [`interp::gemm`],
//! batch-level Top-1 measurement in [`coordinator::InterpEvaluator`]
//! (plus the parallel sweep `Quantune::sweep_parallel` over its
//! `SharedEvaluator` form), and the (algorithm x seed) / (VTA config)
//! fan-outs in [`experiments`]. All parallel paths reduce in input order,
//! so results are bit-identical to the serial ones at any thread count
//! (rust/tests/parallel.rs enforces this); see rust/BENCHMARKS.md for
//! the speedup methodology.

#![warn(missing_docs)]

pub mod calib;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod interp;
pub mod ir;
pub mod latency;
pub mod metrics;
pub mod quant;
pub mod runtime;
pub mod search;
pub mod util;
pub mod vta;
pub mod xgb;
pub mod zoo;
