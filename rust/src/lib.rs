//! Quantune: post-training quantization auto-tuning for CNNs.
//!
//! Reproduction of "Quantune: Post-training Quantization of Convolutional
//! Neural Networks using Extreme Gradient Boosting for Fast Deployment"
//! (Lee et al., FGCS 2022) as a three-layer Rust + JAX + Pallas stack.
//!
//! Layers:
//! - L3 (this crate): the Quantune coordinator — quantization config search
//!   (XGBoost cost model + transfer learning), calibration, the quantization
//!   substrate (our mini-Glow graph IR + quantizers), the VTA integer-only
//!   simulator, and the PJRT runtime that executes AOT-lowered JAX models.
//!   Search, sweep, and the trial database are generic over a
//!   [`quant::ConfigSpace`]: the 96-element general space (Eq. 1), the
//!   12-element VTA integer-only space (Eq. 23), and per-model layer-wise
//!   mixed-precision spaces ([`quant::LayerwiseSpace`]) all flow through
//!   the same driver, and database records carry a space tag so transfer
//!   learning never mixes incompatible feature vectors. The driver is
//!   also objective-agnostic: [`coordinator::objective`] scalarizes
//!   (Top-1, modeled latency, serialized bytes) so every algorithm and
//!   space tunes deployment trade-offs unchanged, with trials, traces,
//!   and records carrying the per-component breakdown.
//! - L2 (python/compile/model.py): JAX forward graphs for the six CNN
//!   models, fp32 + fake-quant parameterized variants, AOT-lowered to HLO
//!   text artifacts at build time.
//! - L1 (python/compile/kernels/): Pallas kernels for the quantization
//!   hot-spot (fake-quant elementwise + int8 GEMM requantization), checked
//!   against pure-jnp oracles.
//!
//! Parallel evaluation engine: [`util::pool`] is a dependency-free
//! worker pool (std scoped threads, `QUANTUNE_THREADS` knob) that three
//! layers of the accuracy-measurement path schedule through -- the
//! row-tiled GEMM in [`interp::gemm`], batch-level Top-1 measurement in
//! [`coordinator::InterpEvaluator`] (plus the parallel sweep
//! `Quantune::sweep_parallel` over its `SharedEvaluator` form), and the
//! (algorithm x seed) / (VTA config) fan-outs in [`experiments`]. All
//! parallel paths reduce in input order, so results are bit-identical to
//! the serial ones at any thread count (rust/tests/parallel.rs enforces
//! this); see rust/BENCHMARKS.md for the speedup methodology.

pub mod calib;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod interp;
pub mod ir;
pub mod latency;
pub mod metrics;
pub mod quant;
pub mod runtime;
pub mod search;
pub mod util;
pub mod vta;
pub mod xgb;
pub mod zoo;
