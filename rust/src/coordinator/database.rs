//! The trial database D = {(e_i, s_i, c_i)} (paper §5.2).
//!
//! Every measured (model, config, accuracy) triple is appended here; the
//! transfer-learning search (XGB-T) warm-starts from the records of
//! *other* models. Persisted as JSON so runs accumulate across processes.

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::quant::QuantConfig;
use crate::search::TransferRecord;
use crate::util::Json;

#[derive(Clone, Debug)]
pub struct Record {
    pub model: String,
    pub config: usize,
    pub accuracy: f64,
    /// seconds it took to measure (Table 2 bookkeeping)
    pub measure_secs: f64,
}

#[derive(Default)]
pub struct Database {
    pub records: Vec<Record>,
    path: Option<PathBuf>,
}

impl Database {
    pub fn in_memory() -> Database {
        Database::default()
    }

    /// Open (or create) a JSON-backed database.
    pub fn open(path: &Path) -> Result<Database> {
        if !path.exists() {
            return Ok(Database { records: Vec::new(), path: Some(path.to_path_buf()) });
        }
        let json = Json::from_file(path)?;
        let mut records = Vec::new();
        for r in json.get("records")?.as_arr()? {
            records.push(Record {
                model: r.get("model")?.as_str()?.to_string(),
                config: r.get("config")?.as_usize()?,
                accuracy: r.get("accuracy")?.as_f64()?,
                measure_secs: r.get("measure_secs")?.as_f64()?,
            });
        }
        Ok(Database { records, path: Some(path.to_path_buf()) })
    }

    pub fn add(&mut self, r: Record) {
        self.records.push(r);
    }

    pub fn save(&self) -> Result<()> {
        let Some(path) = &self.path else { return Ok(()) };
        let records: Vec<Json> = self
            .records
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("model", Json::str(r.model.clone())),
                    ("config", Json::num(r.config as f64)),
                    ("accuracy", Json::num(r.accuracy)),
                    ("measure_secs", Json::num(r.measure_secs)),
                ])
            })
            .collect();
        Json::obj(vec![("records", Json::Arr(records))]).write_file(path)
    }

    /// Accuracy table (index -> best-known accuracy) for one model; holes
    /// are NaN.
    pub fn accuracy_table(&self, model: &str, space: usize) -> Vec<f64> {
        let mut t = vec![f64::NAN; space];
        for r in self.records.iter().filter(|r| r.model == model) {
            if r.config < space {
                t[r.config] = r.accuracy;
            }
        }
        t
    }

    /// Does the database hold a full sweep for `model`?
    pub fn has_full_sweep(&self, model: &str, space: usize) -> bool {
        self.accuracy_table(model, space).iter().all(|a| !a.is_nan())
    }

    /// Transfer-learning records from every model EXCEPT `exclude`.
    /// `features` maps (model, config index) -> feature vector.
    pub fn transfer_records(
        &self,
        exclude: &str,
        mut features: impl FnMut(&str, usize) -> Option<Vec<f32>>,
    ) -> Vec<TransferRecord> {
        let mut out = Vec::new();
        for r in &self.records {
            if r.model == exclude {
                continue;
            }
            if let Some(f) = features(&r.model, r.config) {
                out.push(TransferRecord { features: f, accuracy: r.accuracy as f32 });
            }
        }
        out
    }

    /// Best (config, accuracy) for a model.
    pub fn best_for(&self, model: &str) -> Option<(QuantConfig, f64)> {
        self.records
            .iter()
            .filter(|r| r.model == model)
            .max_by(|a, b| a.accuracy.partial_cmp(&b.accuracy).unwrap())
            .and_then(|r| QuantConfig::from_index(r.config).ok().map(|c| (c, r.accuracy)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(model: &str, config: usize, acc: f64) -> Record {
        Record { model: model.into(), config, accuracy: acc, measure_secs: 0.1 }
    }

    #[test]
    fn roundtrip_persistence() {
        let dir = std::env::temp_dir().join("quantune_db_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("db.json");
        let _ = std::fs::remove_file(&path);
        {
            let mut db = Database::open(&path).unwrap();
            db.add(rec("mn", 3, 0.7));
            db.add(rec("shn", 5, 0.6));
            db.save().unwrap();
        }
        let db = Database::open(&path).unwrap();
        assert_eq!(db.records.len(), 2);
        assert_eq!(db.records[0].model, "mn");
        assert_eq!(db.records[0].config, 3);
    }

    #[test]
    fn transfer_excludes_target_model() {
        let mut db = Database::in_memory();
        db.add(rec("mn", 0, 0.5));
        db.add(rec("shn", 1, 0.6));
        let recs = db.transfer_records("mn", |_, i| Some(vec![i as f32]));
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].accuracy, 0.6);
    }

    #[test]
    fn accuracy_table_and_best() {
        let mut db = Database::in_memory();
        db.add(rec("mn", 0, 0.5));
        db.add(rec("mn", 2, 0.9));
        let t = db.accuracy_table("mn", 4);
        assert_eq!(t[0], 0.5);
        assert!(t[1].is_nan());
        assert_eq!(t[2], 0.9);
        assert!(!db.has_full_sweep("mn", 4));
        let (cfg, acc) = db.best_for("mn").unwrap();
        assert_eq!(cfg.index(), 2);
        assert_eq!(acc, 0.9);
    }
}
