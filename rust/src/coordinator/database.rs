//! The legacy JSON backend of the trial store (paper §5.2's database D).
//!
//! Every measured (model, space, config, accuracy) record is appended
//! here; the transfer-learning search (XGB-T) warm-starts from the
//! records of *other* models measured in the *same* space -- the space
//! tag keeps feature vectors from incompatible spaces (general vs VTA vs
//! a layer-wise space) from ever being mixed into one cost model.
//!
//! This whole-file JSON format predates the segmented log
//! ([`super::store::LogStore`]); it is kept so old `database.json`
//! artifacts open transparently and as the export/migration schema.
//! Records written before the space tag existed load as the general
//! space, and records written before the multi-objective fields existed
//! load with unknown latency/size components. Since the store refactor,
//! `save` is crash-safe: the document lands via a temp file + atomic
//! rename, so a crash mid-write can never destroy an existing database.
//!
//! Ranking over records is NaN-safe: a null accuracy loads as NaN
//! ("poisoned measurement") and every query of the
//! [`super::store::TrialStore`] trait treats NaN as "worse than any
//! measurement" instead of panicking.

#![deny(clippy::unwrap_used)]

use std::path::{Path, PathBuf};

use anyhow::Result;

use super::store::{write_atomic, RecordIndex, TrialStore};
use crate::util::Json;

/// Space tag of the general config space (the pre-tag default). Legacy
/// rows recorded under the 96-config space keep this tag: the 288-config
/// space extends it with the same index order for the first 96 entries.
pub const GENERAL_SPACE_TAG: &str = "general";

/// One measured trial: a (model, space, config) triple with its Top-1
/// accuracy and optional deployment-cost components.
#[derive(Clone, Debug)]
pub struct Record {
    /// Model the trial measured.
    pub model: String,
    /// `ConfigSpace::tag()` of the space `config` indexes into.
    pub space: String,
    /// Config index within the space.
    pub config: usize,
    /// Measured Top-1 (NaN = poisoned measurement).
    pub accuracy: f64,
    /// seconds it took to measure (Table 2 bookkeeping)
    pub measure_secs: f64,
    /// Modeled per-image deployment latency (ms) on `device`; `None`
    /// for legacy and accuracy-only records.
    pub latency_ms: Option<f64>,
    /// The latency pricing source ("CPU(i7-8700)", "VTA@100MHz", ...):
    /// latencies from different devices are NOT comparable, so every
    /// priced record says which table it belongs to.
    pub device: Option<String>,
    /// Serialized quantized model bytes (Table 5 accounting); `None`
    /// for legacy records.
    pub size_bytes: Option<f64>,
    /// Fraction of the evaluation set the accuracy was measured on
    /// (multi-fidelity racing). `None` means full fidelity -- the
    /// legacy shape and the common non-racing case.
    pub fidelity: Option<f64>,
}

impl Record {
    /// Accuracy-only record (no deployment-cost components).
    pub fn new(
        model: String,
        space: String,
        config: usize,
        accuracy: f64,
        measure_secs: f64,
    ) -> Record {
        Record {
            model,
            space,
            config,
            accuracy,
            measure_secs,
            latency_ms: None,
            size_bytes: None,
            device: None,
            fidelity: None,
        }
    }

    /// Was the accuracy measured on the full evaluation set? (Partial
    /// racing estimates are excluded from best-config and
    /// accuracy-table queries.)
    pub fn is_full_fidelity(&self) -> bool {
        self.fidelity.is_none_or(|f| f >= 1.0)
    }

    /// The record as a JSON object -- the schema shared by the legacy
    /// whole-file database, the log-segment frames, and `db export`.
    /// JSON has no NaN: a poisoned accuracy serializes as null and
    /// non-finite optional components are dropped.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("model", Json::str(self.model.clone())),
            ("space", Json::str(self.space.clone())),
            ("config", Json::num(self.config as f64)),
            (
                "accuracy",
                if self.accuracy.is_finite() {
                    Json::num(self.accuracy)
                } else {
                    Json::Null
                },
            ),
            ("measure_secs", Json::num(self.measure_secs)),
        ];
        if let Some(l) = self.latency_ms.filter(|l| l.is_finite()) {
            fields.push(("latency_ms", Json::num(l)));
        }
        if let Some(b) = self.size_bytes.filter(|b| b.is_finite()) {
            fields.push(("size_bytes", Json::num(b)));
        }
        if let Some(d) = &self.device {
            fields.push(("device", Json::str(d.clone())));
        }
        if let Some(f) = self.fidelity.filter(|f| f.is_finite()) {
            fields.push(("fidelity", Json::num(f)));
        }
        Json::obj(fields)
    }

    /// Parse one record object (the inverse of [`Record::to_json`]).
    /// Tolerant of legacy shapes: a missing space tag loads as the
    /// general space, a null accuracy loads as NaN, and the
    /// latency/size/device/fidelity fields are optional (a record
    /// without a fidelity field loads as a full-fidelity measurement).
    pub fn from_json(v: &Json) -> Result<Record> {
        let default_space = Json::Str(GENERAL_SPACE_TAG.to_string());
        let opt = |key: &str| -> Option<f64> { v.get(key).ok().and_then(|x| x.as_f64().ok()) };
        Ok(Record {
            model: v.get("model")?.as_str()?.to_string(),
            space: v.get_or("space", &default_space).as_str()?.to_string(),
            config: v.get("config")?.as_usize()?,
            accuracy: match v.get("accuracy")? {
                Json::Null => f64::NAN,
                x => x.as_f64()?,
            },
            measure_secs: v.get("measure_secs")?.as_f64()?,
            latency_ms: opt("latency_ms"),
            size_bytes: opt("size_bytes"),
            device: v.get("device").ok().and_then(|x| x.as_str().ok()).map(str::to_string),
            fidelity: opt("fidelity"),
        })
    }
}

/// The legacy JSON trial database: an append-only record list plus its
/// [`RecordIndex`], optionally backed by a whole-file JSON document.
/// Queries come from the [`TrialStore`] trait it implements.
#[derive(Default)]
pub struct Database {
    records: Vec<Record>,
    index: RecordIndex,
    path: Option<PathBuf>,
}

impl Database {
    /// A database with no backing file (`save` is a no-op).
    pub fn in_memory() -> Database {
        Database::default()
    }

    /// Open (or create) a JSON-backed database.
    pub fn open(path: &Path) -> Result<Database> {
        if !path.exists() {
            return Ok(Database {
                records: Vec::new(),
                index: RecordIndex::default(),
                path: Some(path.to_path_buf()),
            });
        }
        let json = Json::from_file(path)?;
        let mut records = Vec::new();
        for r in json.get("records")?.as_arr()? {
            records.push(Record::from_json(r)?);
        }
        let index = RecordIndex::build(&records);
        Ok(Database { records, index, path: Some(path.to_path_buf()) })
    }
}

impl TrialStore for Database {
    fn records(&self) -> &[Record] {
        &self.records
    }

    fn index(&self) -> &RecordIndex {
        &self.index
    }

    fn add(&mut self, r: Record) -> Result<u64> {
        let seq = self.records.len() as u64;
        self.index.insert(self.records.len(), &r);
        self.records.push(r);
        Ok(seq)
    }

    /// Persist to the backing file (no-op for in-memory databases).
    /// Crash-safe: the whole document is rewritten to a temp file and
    /// atomically renamed over the old one.
    fn save(&self) -> Result<()> {
        let Some(path) = &self.path else { return Ok(()) };
        let records: Vec<Json> = self.records.iter().map(Record::to_json).collect();
        let doc = Json::obj(vec![("records", Json::Arr(records))]);
        write_atomic(path, doc.pretty().as_bytes())
    }

    fn location(&self) -> Option<&Path> {
        self.path.as_deref()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    fn rec(model: &str, config: usize, acc: f64) -> Record {
        Record::new(model.into(), GENERAL_SPACE_TAG.into(), config, acc, 0.1)
    }

    #[test]
    fn roundtrip_persistence() {
        let dir = std::env::temp_dir().join("quantune_db_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("db.json");
        let _ = std::fs::remove_file(&path);
        {
            let mut db = Database::open(&path).unwrap();
            db.add(rec("mn", 3, 0.7)).unwrap();
            db.add(Record { space: "vta".into(), ..rec("shn", 5, 0.6) }).unwrap();
            db.save().unwrap();
        }
        let db = Database::open(&path).unwrap();
        assert_eq!(db.records().len(), 2);
        assert_eq!(db.records()[0].model, "mn");
        assert_eq!(db.records()[0].config, 3);
        assert_eq!(db.records()[0].space, GENERAL_SPACE_TAG);
        assert_eq!(db.records()[1].space, "vta");
        assert!(!dir.join("db.json.tmp").exists(), "atomic save leaves no temp file");
    }

    #[test]
    fn legacy_records_without_space_load_as_general() {
        let dir = std::env::temp_dir().join("quantune_db_legacy_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("db.json");
        std::fs::write(
            &path,
            r#"{"records": [{"model": "mn", "config": 4, "accuracy": 0.5,
                "measure_secs": 0.1}]}"#,
        )
        .unwrap();
        let db = Database::open(&path).unwrap();
        assert_eq!(db.records().len(), 1);
        assert_eq!(db.records()[0].space, GENERAL_SPACE_TAG);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn transfer_excludes_target_model_and_other_spaces() {
        let mut db = Database::in_memory();
        db.add(rec("mn", 0, 0.5)).unwrap();
        db.add(rec("shn", 1, 0.6)).unwrap();
        db.add(Record { space: "vta".into(), ..rec("shn", 2, 0.9) }).unwrap();
        let recs =
            db.transfer_records("mn", GENERAL_SPACE_TAG, &mut |_, i| Some(vec![i as f32]));
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].accuracy, 0.6);
        let vta = db.transfer_records("mn", "vta", &mut |_, i| Some(vec![i as f32]));
        assert_eq!(vta.len(), 1);
        assert_eq!(vta[0].accuracy, 0.9);
        // the cheap pre-check agrees with the full extraction
        assert!(db.has_transfer_records("mn", GENERAL_SPACE_TAG));
        assert!(db.has_transfer_records("mn", "vta"));
        assert!(!db.has_transfer_records("shn", "vta"));
        assert!(!db.has_transfer_records("mn", "layerwise/x"));
    }

    #[test]
    fn accuracy_table_and_best() {
        let mut db = Database::in_memory();
        db.add(rec("mn", 0, 0.5)).unwrap();
        db.add(rec("mn", 2, 0.9)).unwrap();
        let t = db.accuracy_table("mn", GENERAL_SPACE_TAG, 4);
        assert_eq!(t[0], 0.5);
        assert!(t[1].is_nan());
        assert_eq!(t[2], 0.9);
        assert!(!db.has_full_sweep("mn", GENERAL_SPACE_TAG, 4));
        let (cfg, acc) = db.best_general("mn").unwrap();
        assert_eq!(cfg.index(), 2);
        assert_eq!(acc, 0.9);
        // the generalized query agrees with the wrapper
        assert_eq!(db.best_for("mn", GENERAL_SPACE_TAG), Some((2, 0.9)));
    }

    #[test]
    fn accuracy_table_keeps_the_max_on_duplicates() {
        // a re-measured config must never degrade the table ("best-known
        // accuracy"), regardless of record order
        let mut db = Database::in_memory();
        db.add(rec("mn", 1, 0.8)).unwrap();
        db.add(rec("mn", 1, 0.3)).unwrap(); // noisy re-measurement, later in time
        db.add(rec("mn", 0, 0.1)).unwrap();
        db.add(rec("mn", 0, 0.4)).unwrap();
        let t = db.accuracy_table("mn", GENERAL_SPACE_TAG, 2);
        assert_eq!(t[0], 0.4);
        assert_eq!(t[1], 0.8);
    }

    #[test]
    fn nan_records_degrade_instead_of_panicking() {
        // a NaN accuracy record (a re-persisted table hole, a poisoned
        // measurement) used to panic best_for's comparator
        let mut db = Database::in_memory();
        db.add(rec("mn", 0, f64::NAN)).unwrap();
        db.add(rec("mn", 2, 0.9)).unwrap();
        db.add(rec("mn", 1, f64::NAN)).unwrap();
        let (cfg, acc) = db.best_general("mn").unwrap();
        assert_eq!(cfg.index(), 2);
        assert_eq!(acc, 0.9);
        // table keeps the real value for config 2 and NaN elsewhere
        let t = db.accuracy_table("mn", GENERAL_SPACE_TAG, 3);
        assert!(t[0].is_nan() && t[1].is_nan());
        assert_eq!(t[2], 0.9);
        // all-NaN: no best, not a panic
        let mut only_nan = Database::in_memory();
        only_nan.add(rec("shn", 0, f64::NAN)).unwrap();
        assert!(only_nan.best_general("shn").is_none());
    }

    #[test]
    fn component_fields_roundtrip_and_skip_nonfinite() {
        let dir = std::env::temp_dir().join("quantune_db_components_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("db.json");
        let _ = std::fs::remove_file(&path);
        {
            let mut db = Database::open(&path).unwrap();
            db.add(Record {
                latency_ms: Some(3.25),
                size_bytes: Some(1944.0),
                device: Some("CPU(i7-8700)".into()),
                ..rec("mn", 7, 0.8)
            })
            .unwrap();
            db.add(Record {
                latency_ms: Some(f64::NAN), // must not serialize as NaN
                size_bytes: None,
                ..rec("mn", 8, 0.7)
            })
            .unwrap();
            db.add(rec("mn", 9, 0.6)).unwrap();
            db.save().unwrap();
        }
        let db = Database::open(&path).unwrap();
        assert_eq!(db.records()[0].latency_ms, Some(3.25));
        assert_eq!(db.records()[0].size_bytes, Some(1944.0));
        assert_eq!(db.records()[0].device.as_deref(), Some("CPU(i7-8700)"));
        assert_eq!(db.records()[1].latency_ms, None);
        assert_eq!(db.records()[1].device, None);
        assert_eq!(db.records()[2].latency_ms, None);
        assert_eq!(db.records()[2].size_bytes, None);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn fidelity_roundtrips_and_legacy_records_default_to_full() {
        let dir = std::env::temp_dir().join("quantune_db_fidelity_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("db.json");
        let _ = std::fs::remove_file(&path);
        {
            let mut db = Database::open(&path).unwrap();
            db.add(Record { fidelity: Some(0.25), ..rec("mn", 1, 0.6) }).unwrap();
            db.add(Record { fidelity: Some(1.0), ..rec("mn", 2, 0.7) }).unwrap();
            db.add(rec("mn", 3, 0.8)).unwrap(); // legacy shape: no field
            db.save().unwrap();
        }
        let db = Database::open(&path).unwrap();
        assert_eq!(db.records()[0].fidelity, Some(0.25));
        assert!(!db.records()[0].is_full_fidelity());
        assert_eq!(db.records()[1].fidelity, Some(1.0));
        assert!(db.records()[1].is_full_fidelity());
        assert_eq!(db.records()[2].fidelity, None, "missing field loads as None");
        assert!(db.records()[2].is_full_fidelity(), "None means full fidelity");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn partial_fidelity_records_do_not_enter_tables_or_best() {
        // a low-fidelity racing estimate is an approximation; only
        // full-fidelity measurements may win best_for or fill the
        // accuracy table a sweep-completeness check reads
        let mut db = Database::in_memory();
        db.add(Record { fidelity: Some(0.25), ..rec("mn", 0, 0.99) }).unwrap();
        db.add(rec("mn", 1, 0.7)).unwrap();
        let t = db.accuracy_table("mn", GENERAL_SPACE_TAG, 2);
        assert!(t[0].is_nan(), "partial record must not fill the table");
        assert_eq!(t[1], 0.7);
        assert_eq!(db.best_for("mn", GENERAL_SPACE_TAG), Some((1, 0.7)));
        assert!(!db.has_full_sweep("mn", GENERAL_SPACE_TAG, 2));
    }

    #[test]
    fn nan_accuracy_persists_as_null_and_reloads_as_nan() {
        let dir = std::env::temp_dir().join("quantune_db_nan_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("db.json");
        let _ = std::fs::remove_file(&path);
        {
            let mut db = Database::open(&path).unwrap();
            db.add(rec("mn", 1, f64::NAN)).unwrap();
            db.add(rec("mn", 2, 0.7)).unwrap();
            db.save().unwrap();
        }
        let db = Database::open(&path).unwrap();
        assert!(db.records()[0].accuracy.is_nan());
        assert_eq!(db.records()[1].accuracy, 0.7);
        let (cfg, _) = db.best_general("mn").unwrap();
        assert_eq!(cfg.index(), 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn tables_are_separated_by_space() {
        let mut db = Database::in_memory();
        db.add(rec("mn", 0, 0.5)).unwrap();
        db.add(Record { space: "vta".into(), ..rec("mn", 0, 0.9) }).unwrap();
        let g = db.accuracy_table("mn", GENERAL_SPACE_TAG, 1);
        let v = db.accuracy_table("mn", "vta", 1);
        assert_eq!(g[0], 0.5);
        assert_eq!(v[0], 0.9);
        assert!(db.has_full_sweep("mn", "vta", 1));
        // best_for sees the per-space winners too
        assert_eq!(db.best_for("mn", "vta"), Some((0, 0.9)));
    }
}
