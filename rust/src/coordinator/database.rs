//! The trial database D = {(e_i, s_i, c_i)} (paper §5.2).
//!
//! Every measured (model, space, config, accuracy) record is appended
//! here; the transfer-learning search (XGB-T) warm-starts from the
//! records of *other* models measured in the *same* space -- the space
//! tag keeps feature vectors from incompatible spaces (general vs VTA vs
//! a layer-wise space) from ever being mixed into one cost model.
//! Persisted as JSON so runs accumulate across processes; records
//! written before the space tag existed load as the general space.

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::quant::QuantConfig;
use crate::search::TransferRecord;
use crate::util::Json;

/// Space tag of the 96-element general space (the pre-tag default).
pub const GENERAL_SPACE_TAG: &str = "general";

#[derive(Clone, Debug)]
pub struct Record {
    pub model: String,
    /// `ConfigSpace::tag()` of the space `config` indexes into.
    pub space: String,
    pub config: usize,
    pub accuracy: f64,
    /// seconds it took to measure (Table 2 bookkeeping)
    pub measure_secs: f64,
}

#[derive(Default)]
pub struct Database {
    pub records: Vec<Record>,
    path: Option<PathBuf>,
}

impl Database {
    pub fn in_memory() -> Database {
        Database::default()
    }

    /// Open (or create) a JSON-backed database.
    pub fn open(path: &Path) -> Result<Database> {
        if !path.exists() {
            return Ok(Database { records: Vec::new(), path: Some(path.to_path_buf()) });
        }
        let json = Json::from_file(path)?;
        let mut records = Vec::new();
        let default_space = Json::Str(GENERAL_SPACE_TAG.to_string());
        for r in json.get("records")?.as_arr()? {
            records.push(Record {
                model: r.get("model")?.as_str()?.to_string(),
                space: r.get_or("space", &default_space).as_str()?.to_string(),
                config: r.get("config")?.as_usize()?,
                accuracy: r.get("accuracy")?.as_f64()?,
                measure_secs: r.get("measure_secs")?.as_f64()?,
            });
        }
        Ok(Database { records, path: Some(path.to_path_buf()) })
    }

    pub fn add(&mut self, r: Record) {
        self.records.push(r);
    }

    pub fn save(&self) -> Result<()> {
        let Some(path) = &self.path else { return Ok(()) };
        let records: Vec<Json> = self
            .records
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("model", Json::str(r.model.clone())),
                    ("space", Json::str(r.space.clone())),
                    ("config", Json::num(r.config as f64)),
                    ("accuracy", Json::num(r.accuracy)),
                    ("measure_secs", Json::num(r.measure_secs)),
                ])
            })
            .collect();
        Json::obj(vec![("records", Json::Arr(records))]).write_file(path)
    }

    /// Accuracy table (index -> best-known accuracy) for one model in
    /// one space; holes are NaN. Duplicate (model, config) records keep
    /// the maximum measured accuracy, so a re-measured config can only
    /// improve the table.
    pub fn accuracy_table(&self, model: &str, space: &str, size: usize) -> Vec<f64> {
        let mut t = vec![f64::NAN; size];
        for r in
            self.records.iter().filter(|r| r.model == model && r.space == space)
        {
            if r.config < size && (t[r.config].is_nan() || r.accuracy > t[r.config]) {
                t[r.config] = r.accuracy;
            }
        }
        t
    }

    /// Does the database hold a full sweep for `model` in `space`?
    pub fn has_full_sweep(&self, model: &str, space: &str, size: usize) -> bool {
        self.accuracy_table(model, space, size).iter().all(|a| !a.is_nan())
    }

    /// Transfer-learning records in `space` from every model EXCEPT
    /// `exclude`. `features` maps (model, config index) -> feature
    /// vector.
    pub fn transfer_records(
        &self,
        exclude: &str,
        space: &str,
        mut features: impl FnMut(&str, usize) -> Option<Vec<f32>>,
    ) -> Vec<TransferRecord> {
        let mut out = Vec::new();
        for r in &self.records {
            if r.model == exclude || r.space != space {
                continue;
            }
            if let Some(f) = features(&r.model, r.config) {
                out.push(TransferRecord { features: f, accuracy: r.accuracy as f32 });
            }
        }
        out
    }

    /// Best (config, accuracy) for a model in the general space.
    pub fn best_for(&self, model: &str) -> Option<(QuantConfig, f64)> {
        self.records
            .iter()
            .filter(|r| r.model == model && r.space == GENERAL_SPACE_TAG)
            .max_by(|a, b| a.accuracy.partial_cmp(&b.accuracy).unwrap())
            .and_then(|r| QuantConfig::from_index(r.config).ok().map(|c| (c, r.accuracy)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(model: &str, config: usize, acc: f64) -> Record {
        Record {
            model: model.into(),
            space: GENERAL_SPACE_TAG.into(),
            config,
            accuracy: acc,
            measure_secs: 0.1,
        }
    }

    #[test]
    fn roundtrip_persistence() {
        let dir = std::env::temp_dir().join("quantune_db_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("db.json");
        let _ = std::fs::remove_file(&path);
        {
            let mut db = Database::open(&path).unwrap();
            db.add(rec("mn", 3, 0.7));
            db.add(Record { space: "vta".into(), ..rec("shn", 5, 0.6) });
            db.save().unwrap();
        }
        let db = Database::open(&path).unwrap();
        assert_eq!(db.records.len(), 2);
        assert_eq!(db.records[0].model, "mn");
        assert_eq!(db.records[0].config, 3);
        assert_eq!(db.records[0].space, GENERAL_SPACE_TAG);
        assert_eq!(db.records[1].space, "vta");
    }

    #[test]
    fn legacy_records_without_space_load_as_general() {
        let dir = std::env::temp_dir().join("quantune_db_legacy_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("db.json");
        std::fs::write(
            &path,
            r#"{"records": [{"model": "mn", "config": 4, "accuracy": 0.5,
                "measure_secs": 0.1}]}"#,
        )
        .unwrap();
        let db = Database::open(&path).unwrap();
        assert_eq!(db.records.len(), 1);
        assert_eq!(db.records[0].space, GENERAL_SPACE_TAG);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn transfer_excludes_target_model_and_other_spaces() {
        let mut db = Database::in_memory();
        db.add(rec("mn", 0, 0.5));
        db.add(rec("shn", 1, 0.6));
        db.add(Record { space: "vta".into(), ..rec("shn", 2, 0.9) });
        let recs =
            db.transfer_records("mn", GENERAL_SPACE_TAG, |_, i| Some(vec![i as f32]));
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].accuracy, 0.6);
        let vta = db.transfer_records("mn", "vta", |_, i| Some(vec![i as f32]));
        assert_eq!(vta.len(), 1);
        assert_eq!(vta[0].accuracy, 0.9);
    }

    #[test]
    fn accuracy_table_and_best() {
        let mut db = Database::in_memory();
        db.add(rec("mn", 0, 0.5));
        db.add(rec("mn", 2, 0.9));
        let t = db.accuracy_table("mn", GENERAL_SPACE_TAG, 4);
        assert_eq!(t[0], 0.5);
        assert!(t[1].is_nan());
        assert_eq!(t[2], 0.9);
        assert!(!db.has_full_sweep("mn", GENERAL_SPACE_TAG, 4));
        let (cfg, acc) = db.best_for("mn").unwrap();
        assert_eq!(cfg.index(), 2);
        assert_eq!(acc, 0.9);
    }

    #[test]
    fn accuracy_table_keeps_the_max_on_duplicates() {
        // a re-measured config must never degrade the table ("best-known
        // accuracy"), regardless of record order
        let mut db = Database::in_memory();
        db.add(rec("mn", 1, 0.8));
        db.add(rec("mn", 1, 0.3)); // noisy re-measurement, later in time
        db.add(rec("mn", 0, 0.1));
        db.add(rec("mn", 0, 0.4));
        let t = db.accuracy_table("mn", GENERAL_SPACE_TAG, 2);
        assert_eq!(t[0], 0.4);
        assert_eq!(t[1], 0.8);
    }

    #[test]
    fn tables_are_separated_by_space() {
        let mut db = Database::in_memory();
        db.add(rec("mn", 0, 0.5));
        db.add(Record { space: "vta".into(), ..rec("mn", 0, 0.9) });
        let g = db.accuracy_table("mn", GENERAL_SPACE_TAG, 1);
        let v = db.accuracy_table("mn", "vta", 1);
        assert_eq!(g[0], 0.5);
        assert_eq!(v[0], 0.9);
        assert!(db.has_full_sweep("mn", "vta", 1));
    }
}
