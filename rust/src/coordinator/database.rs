//! The trial database D = {(e_i, s_i, c_i)} (paper §5.2).
//!
//! Every measured (model, space, config, accuracy) record is appended
//! here; the transfer-learning search (XGB-T) warm-starts from the
//! records of *other* models measured in the *same* space -- the space
//! tag keeps feature vectors from incompatible spaces (general vs VTA vs
//! a layer-wise space) from ever being mixed into one cost model.
//! Persisted as JSON so runs accumulate across processes; records
//! written before the space tag existed load as the general space (and
//! records written before the multi-objective fields existed load with
//! unknown latency/size components).
//!
//! Ranking over records is NaN-safe: `accuracy_table` explicitly fills
//! holes with NaN, so everything that sorts or maxes accuracies treats
//! NaN as "worse than any measurement" instead of panicking.

#![deny(clippy::unwrap_used)]

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::quant::QuantConfig;
use crate::search::TransferRecord;
use crate::util::{nan_min_cmp, Json};

/// Space tag of the 96-element general space (the pre-tag default).
pub const GENERAL_SPACE_TAG: &str = "general";

/// One measured trial: a (model, space, config) triple with its Top-1
/// accuracy and optional deployment-cost components.
#[derive(Clone, Debug)]
pub struct Record {
    /// Model the trial measured.
    pub model: String,
    /// `ConfigSpace::tag()` of the space `config` indexes into.
    pub space: String,
    /// Config index within the space.
    pub config: usize,
    /// Measured Top-1 (NaN = poisoned measurement).
    pub accuracy: f64,
    /// seconds it took to measure (Table 2 bookkeeping)
    pub measure_secs: f64,
    /// Modeled per-image deployment latency (ms) on `device`; `None`
    /// for legacy and accuracy-only records.
    pub latency_ms: Option<f64>,
    /// The latency pricing source ("CPU(i7-8700)", "VTA@100MHz", ...):
    /// latencies from different devices are NOT comparable, so every
    /// priced record says which table it belongs to.
    pub device: Option<String>,
    /// Serialized quantized model bytes (Table 5 accounting); `None`
    /// for legacy records.
    pub size_bytes: Option<f64>,
}

impl Record {
    /// Accuracy-only record (no deployment-cost components).
    pub fn new(
        model: String,
        space: String,
        config: usize,
        accuracy: f64,
        measure_secs: f64,
    ) -> Record {
        Record {
            model,
            space,
            config,
            accuracy,
            measure_secs,
            latency_ms: None,
            size_bytes: None,
            device: None,
        }
    }
}

/// The trial database `D`: an append-only record list, optionally
/// JSON-backed.
#[derive(Default)]
pub struct Database {
    /// Every measured trial, in insertion order.
    pub records: Vec<Record>,
    path: Option<PathBuf>,
}

impl Database {
    /// A database with no backing file (`save` is a no-op).
    pub fn in_memory() -> Database {
        Database::default()
    }

    /// Open (or create) a JSON-backed database.
    pub fn open(path: &Path) -> Result<Database> {
        if !path.exists() {
            return Ok(Database { records: Vec::new(), path: Some(path.to_path_buf()) });
        }
        let json = Json::from_file(path)?;
        let mut records = Vec::new();
        let default_space = Json::Str(GENERAL_SPACE_TAG.to_string());
        for r in json.get("records")?.as_arr()? {
            // optional component fields: absent on legacy records
            let opt = |key: &str| -> Option<f64> {
                r.get(key).ok().and_then(|v| v.as_f64().ok())
            };
            records.push(Record {
                model: r.get("model")?.as_str()?.to_string(),
                space: r.get_or("space", &default_space).as_str()?.to_string(),
                config: r.get("config")?.as_usize()?,
                // a null accuracy is a persisted poisoned measurement;
                // it loads as NaN and degrades in every ranking site
                accuracy: match r.get("accuracy")? {
                    Json::Null => f64::NAN,
                    v => v.as_f64()?,
                },
                measure_secs: r.get("measure_secs")?.as_f64()?,
                latency_ms: opt("latency_ms"),
                size_bytes: opt("size_bytes"),
                device: r
                    .get("device")
                    .ok()
                    .and_then(|v| v.as_str().ok())
                    .map(str::to_string),
            });
        }
        Ok(Database { records, path: Some(path.to_path_buf()) })
    }

    /// Append one record.
    pub fn add(&mut self, r: Record) {
        self.records.push(r);
    }

    /// Persist to the backing file (no-op for in-memory databases).
    pub fn save(&self) -> Result<()> {
        let Some(path) = &self.path else { return Ok(()) };
        let records: Vec<Json> = self
            .records
            .iter()
            .map(|r| {
                let mut fields = vec![
                    ("model", Json::str(r.model.clone())),
                    ("space", Json::str(r.space.clone())),
                    ("config", Json::num(r.config as f64)),
                    // JSON has no NaN: a poisoned accuracy persists as
                    // null and round-trips back to NaN on load
                    (
                        "accuracy",
                        if r.accuracy.is_finite() {
                            Json::num(r.accuracy)
                        } else {
                            Json::Null
                        },
                    ),
                    ("measure_secs", Json::num(r.measure_secs)),
                ];
                // only finite components serialize (JSON has no NaN)
                if let Some(l) = r.latency_ms.filter(|l| l.is_finite()) {
                    fields.push(("latency_ms", Json::num(l)));
                }
                if let Some(b) = r.size_bytes.filter(|b| b.is_finite()) {
                    fields.push(("size_bytes", Json::num(b)));
                }
                if let Some(d) = &r.device {
                    fields.push(("device", Json::str(d.clone())));
                }
                Json::obj(fields)
            })
            .collect();
        Json::obj(vec![("records", Json::Arr(records))]).write_file(path)
    }

    /// Accuracy table (index -> best-known accuracy) for one model in
    /// one space; holes are NaN. Duplicate (model, config) records keep
    /// the maximum measured accuracy, so a re-measured config can only
    /// improve the table.
    pub fn accuracy_table(&self, model: &str, space: &str, size: usize) -> Vec<f64> {
        let mut t = vec![f64::NAN; size];
        for r in
            self.records.iter().filter(|r| r.model == model && r.space == space)
        {
            if r.config < size && (t[r.config].is_nan() || r.accuracy > t[r.config]) {
                t[r.config] = r.accuracy;
            }
        }
        t
    }

    /// Does the database hold a full sweep for `model` in `space`?
    pub fn has_full_sweep(&self, model: &str, space: &str, size: usize) -> bool {
        self.accuracy_table(model, space, size).iter().all(|a| !a.is_nan())
    }

    /// Are there any records from models other than `exclude` in
    /// `space`? Cheap pre-check for xgb_t's transfer requirement (a
    /// `true` can still yield no transfer records when the other
    /// models' feature metadata is missing -- the search then errors
    /// descriptively, which is the right surface for that broken state).
    pub fn has_transfer_records(&self, exclude: &str, space: &str) -> bool {
        self.records.iter().any(|r| r.model != exclude && r.space == space)
    }

    /// Transfer-learning records in `space` from every model EXCEPT
    /// `exclude`. `features` maps (model, config index) -> feature
    /// vector.
    pub fn transfer_records(
        &self,
        exclude: &str,
        space: &str,
        mut features: impl FnMut(&str, usize) -> Option<Vec<f32>>,
    ) -> Vec<TransferRecord> {
        let mut out = Vec::new();
        for r in &self.records {
            if r.model == exclude || r.space != space {
                continue;
            }
            if let Some(f) = features(&r.model, r.config) {
                out.push(TransferRecord { features: f, accuracy: r.accuracy as f32 });
            }
        }
        out
    }

    /// Best (config, accuracy) for a model in the general space. NaN
    /// accuracies (holes re-persisted from a table, poisoned
    /// measurements) are skipped entirely: a database of only-NaN
    /// records reports `None` instead of panicking mid-comparison.
    pub fn best_for(&self, model: &str) -> Option<(QuantConfig, f64)> {
        self.records
            .iter()
            .filter(|r| {
                r.model == model && r.space == GENERAL_SPACE_TAG && !r.accuracy.is_nan()
            })
            .max_by(|a, b| nan_min_cmp(&a.accuracy, &b.accuracy))
            .and_then(|r| QuantConfig::from_index(r.config).ok().map(|c| (c, r.accuracy)))
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    fn rec(model: &str, config: usize, acc: f64) -> Record {
        Record::new(model.into(), GENERAL_SPACE_TAG.into(), config, acc, 0.1)
    }

    #[test]
    fn roundtrip_persistence() {
        let dir = std::env::temp_dir().join("quantune_db_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("db.json");
        let _ = std::fs::remove_file(&path);
        {
            let mut db = Database::open(&path).unwrap();
            db.add(rec("mn", 3, 0.7));
            db.add(Record { space: "vta".into(), ..rec("shn", 5, 0.6) });
            db.save().unwrap();
        }
        let db = Database::open(&path).unwrap();
        assert_eq!(db.records.len(), 2);
        assert_eq!(db.records[0].model, "mn");
        assert_eq!(db.records[0].config, 3);
        assert_eq!(db.records[0].space, GENERAL_SPACE_TAG);
        assert_eq!(db.records[1].space, "vta");
    }

    #[test]
    fn legacy_records_without_space_load_as_general() {
        let dir = std::env::temp_dir().join("quantune_db_legacy_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("db.json");
        std::fs::write(
            &path,
            r#"{"records": [{"model": "mn", "config": 4, "accuracy": 0.5,
                "measure_secs": 0.1}]}"#,
        )
        .unwrap();
        let db = Database::open(&path).unwrap();
        assert_eq!(db.records.len(), 1);
        assert_eq!(db.records[0].space, GENERAL_SPACE_TAG);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn transfer_excludes_target_model_and_other_spaces() {
        let mut db = Database::in_memory();
        db.add(rec("mn", 0, 0.5));
        db.add(rec("shn", 1, 0.6));
        db.add(Record { space: "vta".into(), ..rec("shn", 2, 0.9) });
        let recs =
            db.transfer_records("mn", GENERAL_SPACE_TAG, |_, i| Some(vec![i as f32]));
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].accuracy, 0.6);
        let vta = db.transfer_records("mn", "vta", |_, i| Some(vec![i as f32]));
        assert_eq!(vta.len(), 1);
        assert_eq!(vta[0].accuracy, 0.9);
        // the cheap pre-check agrees with the full extraction
        assert!(db.has_transfer_records("mn", GENERAL_SPACE_TAG));
        assert!(db.has_transfer_records("mn", "vta"));
        assert!(!db.has_transfer_records("shn", "vta"));
        assert!(!db.has_transfer_records("mn", "layerwise/x"));
    }

    #[test]
    fn accuracy_table_and_best() {
        let mut db = Database::in_memory();
        db.add(rec("mn", 0, 0.5));
        db.add(rec("mn", 2, 0.9));
        let t = db.accuracy_table("mn", GENERAL_SPACE_TAG, 4);
        assert_eq!(t[0], 0.5);
        assert!(t[1].is_nan());
        assert_eq!(t[2], 0.9);
        assert!(!db.has_full_sweep("mn", GENERAL_SPACE_TAG, 4));
        let (cfg, acc) = db.best_for("mn").unwrap();
        assert_eq!(cfg.index(), 2);
        assert_eq!(acc, 0.9);
    }

    #[test]
    fn accuracy_table_keeps_the_max_on_duplicates() {
        // a re-measured config must never degrade the table ("best-known
        // accuracy"), regardless of record order
        let mut db = Database::in_memory();
        db.add(rec("mn", 1, 0.8));
        db.add(rec("mn", 1, 0.3)); // noisy re-measurement, later in time
        db.add(rec("mn", 0, 0.1));
        db.add(rec("mn", 0, 0.4));
        let t = db.accuracy_table("mn", GENERAL_SPACE_TAG, 2);
        assert_eq!(t[0], 0.4);
        assert_eq!(t[1], 0.8);
    }

    #[test]
    fn nan_records_degrade_instead_of_panicking() {
        // a NaN accuracy record (a re-persisted table hole, a poisoned
        // measurement) used to panic best_for's comparator
        let mut db = Database::in_memory();
        db.add(rec("mn", 0, f64::NAN));
        db.add(rec("mn", 2, 0.9));
        db.add(rec("mn", 1, f64::NAN));
        let (cfg, acc) = db.best_for("mn").unwrap();
        assert_eq!(cfg.index(), 2);
        assert_eq!(acc, 0.9);
        // table keeps the real value for config 2 and NaN elsewhere
        let t = db.accuracy_table("mn", GENERAL_SPACE_TAG, 3);
        assert!(t[0].is_nan() && t[1].is_nan());
        assert_eq!(t[2], 0.9);
        // all-NaN: no best, not a panic
        let mut only_nan = Database::in_memory();
        only_nan.add(rec("shn", 0, f64::NAN));
        assert!(only_nan.best_for("shn").is_none());
    }

    #[test]
    fn component_fields_roundtrip_and_skip_nonfinite() {
        let dir = std::env::temp_dir().join("quantune_db_components_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("db.json");
        let _ = std::fs::remove_file(&path);
        {
            let mut db = Database::open(&path).unwrap();
            db.add(Record {
                latency_ms: Some(3.25),
                size_bytes: Some(1944.0),
                device: Some("CPU(i7-8700)".into()),
                ..rec("mn", 7, 0.8)
            });
            db.add(Record {
                latency_ms: Some(f64::NAN), // must not serialize as NaN
                size_bytes: None,
                ..rec("mn", 8, 0.7)
            });
            db.add(rec("mn", 9, 0.6));
            db.save().unwrap();
        }
        let db = Database::open(&path).unwrap();
        assert_eq!(db.records[0].latency_ms, Some(3.25));
        assert_eq!(db.records[0].size_bytes, Some(1944.0));
        assert_eq!(db.records[0].device.as_deref(), Some("CPU(i7-8700)"));
        assert_eq!(db.records[1].latency_ms, None);
        assert_eq!(db.records[1].device, None);
        assert_eq!(db.records[2].latency_ms, None);
        assert_eq!(db.records[2].size_bytes, None);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn nan_accuracy_persists_as_null_and_reloads_as_nan() {
        let dir = std::env::temp_dir().join("quantune_db_nan_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("db.json");
        let _ = std::fs::remove_file(&path);
        {
            let mut db = Database::open(&path).unwrap();
            db.add(rec("mn", 1, f64::NAN));
            db.add(rec("mn", 2, 0.7));
            db.save().unwrap();
        }
        let db = Database::open(&path).unwrap();
        assert!(db.records[0].accuracy.is_nan());
        assert_eq!(db.records[1].accuracy, 0.7);
        let (cfg, _) = db.best_for("mn").unwrap();
        assert_eq!(cfg.index(), 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn tables_are_separated_by_space() {
        let mut db = Database::in_memory();
        db.add(rec("mn", 0, 0.5));
        db.add(Record { space: "vta".into(), ..rec("mn", 0, 0.9) });
        let g = db.accuracy_table("mn", GENERAL_SPACE_TAG, 1);
        let v = db.accuracy_table("mn", "vta", 1);
        assert_eq!(g[0], 0.5);
        assert_eq!(v[0], 0.9);
        assert!(db.has_full_sweep("mn", "vta", 1));
    }
}
