//! Crash-safe append-only segmented trial log (the production backend
//! of the [`super::TrialStore`]).
//!
//! On-disk layout: a directory of `segment-NNNNN.qlog` files. Each
//! segment starts with an 8-byte header (`QTLG` magic + u32 LE format
//! version) and is followed by frames of
//! `[u32 LE payload length][u32 LE CRC32][payload]`, where the payload
//! is the compact-JSON serialization of one [`Record`] (the same schema
//! the legacy `database.json` uses per record, so migration is a
//! replay). Dependency-free by design: the CRC32 (IEEE) is implemented
//! here.
//!
//! Crash-safety invariants:
//!
//! - segments are created atomically (header written to a `.tmp`
//!   sibling, then renamed), so a segment file always has a valid
//!   header; leftover `.tmp` files from a crashed creation are removed
//!   on open;
//! - records are appended frame-at-a-time; a crash mid-append leaves a
//!   torn final frame, which `open` detects (length/CRC/parse check),
//!   truncates away -- on the *tail* segment only -- and logs;
//! - a bad frame in a sealed (non-tail) segment is real corruption and
//!   refuses to open rather than silently dropping interior records;
//! - the highest-numbered segment is the active tail; it rotates
//!   (seal + start the next id) once it would exceed the size
//!   threshold.

#![deny(clippy::unwrap_used)]

use std::fs::{self, File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, ensure, Result};

use super::super::database::Record;
use super::{RecordIndex, TrialStore};
use crate::util::Json;

/// Segment header magic.
const MAGIC: &[u8; 4] = b"QTLG";
/// On-disk format version (bumped on incompatible frame changes).
const FORMAT_VERSION: u32 = 1;
/// Segment header length in bytes (magic + version).
const HEADER_LEN: usize = 8;
/// Frame header length in bytes (payload length + CRC32).
const FRAME_HEADER_LEN: usize = 8;
/// Default segment-rotation threshold.
const DEFAULT_SEGMENT_BYTES: u64 = 1 << 20;

/// CRC32 (IEEE 802.3, reflected polynomial 0xEDB88320) -- the checksum
/// guarding every frame payload. Bitwise, table-free: trial records are
/// tiny and appended at human-experiment rates.
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

fn segment_name(id: u32) -> String {
    format!("segment-{id:05}.qlog")
}

fn parse_segment_id(name: &str) -> Option<u32> {
    name.strip_prefix("segment-")?.strip_suffix(".qlog")?.parse().ok()
}

/// The append-only segmented log store. All reads are served from
/// memory (records are replayed into a `Vec` + [`RecordIndex`] on
/// open); every [`TrialStore::add`] writes one framed record to the
/// tail segment before returning.
pub struct LogStore {
    dir: PathBuf,
    records: Vec<Record>,
    index: RecordIndex,
    /// Open append handle on the tail segment (opened lazily on the
    /// first add, so opening a store never creates files).
    tail: Option<File>,
    /// Id of the tail segment (the next one to create, if its file
    /// doesn't exist yet).
    tail_id: u32,
    /// Bytes of the tail segment (header included).
    tail_bytes: u64,
    /// Segment files on disk.
    segments: usize,
    /// Rotation threshold: a frame that would push the tail past this
    /// seals it and starts the next segment.
    segment_bytes: u64,
}

impl LogStore {
    /// Open (or lazily create) the log at `dir` with the default
    /// segment-rotation threshold. A missing directory is an empty
    /// store; nothing is written until the first append.
    pub fn open(dir: &Path) -> Result<LogStore> {
        LogStore::open_with(dir, DEFAULT_SEGMENT_BYTES)
    }

    /// [`LogStore::open`] with an explicit rotation threshold (tests
    /// use tiny thresholds to force multi-segment stores).
    pub fn open_with(dir: &Path, segment_bytes: u64) -> Result<LogStore> {
        let mut ids: Vec<u32> = Vec::new();
        if dir.is_dir() {
            for entry in
                fs::read_dir(dir).map_err(|e| anyhow!("reading {}: {e}", dir.display()))?
            {
                let entry = entry?;
                let name = entry.file_name();
                let name = name.to_string_lossy();
                if let Some(id) = parse_segment_id(&name) {
                    ids.push(id);
                } else if name.ends_with(".tmp") {
                    // leftover from a crashed atomic segment creation:
                    // never renamed into place, so it holds no records
                    let _ = fs::remove_file(entry.path());
                }
            }
        }
        ids.sort_unstable();
        for (k, &id) in ids.iter().enumerate() {
            ensure!(
                id as usize == k,
                "trial log {} is missing segment-{k:05}.qlog (found segment-{id:05}.qlog) \
                 -- refusing to open with a sequence gap",
                dir.display()
            );
        }
        let mut records = Vec::new();
        let mut tail_bytes = 0u64;
        for (k, &id) in ids.iter().enumerate() {
            let is_tail = k + 1 == ids.len();
            let n = read_segment(&dir.join(segment_name(id)), &mut records, is_tail)?;
            if is_tail {
                tail_bytes = n;
            }
        }
        let index = RecordIndex::build(&records);
        Ok(LogStore {
            dir: dir.to_path_buf(),
            records,
            index,
            tail: None,
            tail_id: ids.last().copied().unwrap_or(0),
            tail_bytes,
            segments: ids.len(),
            segment_bytes: segment_bytes.max(HEADER_LEN as u64 + 1),
        })
    }

    /// Segment files on disk.
    pub fn segment_count(&self) -> usize {
        self.segments
    }

    /// The log directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn tail_exists(&self) -> bool {
        self.segments == self.tail_id as usize + 1
    }

    /// Open (creating if needed) the append handle on the tail segment.
    fn ensure_tail(&mut self) -> Result<()> {
        if self.tail.is_some() {
            return Ok(());
        }
        fs::create_dir_all(&self.dir)
            .map_err(|e| anyhow!("creating trial log dir {}: {e}", self.dir.display()))?;
        if !self.tail_exists() {
            // atomic creation: the full header lands via tmp + rename,
            // so no reader can ever see a header-less segment
            let name = segment_name(self.tail_id);
            let tmp = self.dir.join(format!("{name}.tmp"));
            let mut header = Vec::with_capacity(HEADER_LEN);
            header.extend_from_slice(MAGIC);
            header.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
            fs::write(&tmp, &header)
                .map_err(|e| anyhow!("writing {}: {e}", tmp.display()))?;
            fs::rename(&tmp, self.dir.join(&name))
                .map_err(|e| anyhow!("renaming {} into place: {e}", tmp.display()))?;
            self.segments = self.tail_id as usize + 1;
            self.tail_bytes = HEADER_LEN as u64;
        }
        let path = self.dir.join(segment_name(self.tail_id));
        let file = OpenOptions::new()
            .append(true)
            .open(&path)
            .map_err(|e| anyhow!("opening {}: {e}", path.display()))?;
        self.tail = Some(file);
        Ok(())
    }
}

/// Optional cost components that aren't finite can't round-trip through
/// JSON (it has no NaN/inf); normalize them to `None` up front so the
/// in-memory state always equals what a reopen would replay.
fn normalize(mut r: Record) -> Record {
    r.latency_ms = r.latency_ms.filter(|v| v.is_finite());
    r.size_bytes = r.size_bytes.filter(|v| v.is_finite());
    r
}

impl TrialStore for LogStore {
    fn records(&self) -> &[Record] {
        &self.records
    }

    fn index(&self) -> &RecordIndex {
        &self.index
    }

    fn add(&mut self, r: Record) -> Result<u64> {
        let r = normalize(r);
        let payload = r.to_json().dump().into_bytes();
        let frame_len = (FRAME_HEADER_LEN + payload.len()) as u64;
        // seal a non-empty tail the incoming frame would overflow (an
        // oversized single record still lands in its own segment)
        if self.tail_exists()
            && self.tail_bytes > HEADER_LEN as u64
            && self.tail_bytes + frame_len > self.segment_bytes
        {
            if let Some(f) = self.tail.take() {
                f.sync_data()?;
            }
            self.tail_id += 1;
            self.tail_bytes = 0;
        }
        self.ensure_tail()?;
        let mut frame = Vec::with_capacity(FRAME_HEADER_LEN + payload.len());
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        frame.extend_from_slice(&crc32(&payload).to_le_bytes());
        frame.extend_from_slice(&payload);
        let file = match self.tail.as_mut() {
            Some(f) => f,
            None => bail!("trial log tail unavailable (internal bug)"),
        };
        file.write_all(&frame)
            .map_err(|e| anyhow!("appending to trial log {}: {e}", self.dir.display()))?;
        self.tail_bytes += frame_len;
        let seq = self.records.len() as u64;
        self.index.insert(self.records.len(), &r);
        self.records.push(r);
        Ok(seq)
    }

    fn save(&self) -> Result<()> {
        if let Some(f) = &self.tail {
            f.sync_data()?;
        }
        Ok(())
    }

    fn location(&self) -> Option<&Path> {
        Some(&self.dir)
    }
}

/// Replay one segment into `records`, returning its valid byte length.
/// A torn/corrupt frame truncates the file there when `is_tail`, and is
/// a hard error otherwise.
fn read_segment(path: &Path, records: &mut Vec<Record>, is_tail: bool) -> Result<u64> {
    let data = fs::read(path).map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
    ensure!(
        data.len() >= HEADER_LEN && data[..4] == *MAGIC,
        "{} is not a quantune trial-log segment",
        path.display()
    );
    let version = u32::from_le_bytes([data[4], data[5], data[6], data[7]]);
    ensure!(
        version == FORMAT_VERSION,
        "{}: unsupported trial-log format version {version} (this build reads v{FORMAT_VERSION})",
        path.display()
    );
    let mut off = HEADER_LEN;
    let valid = loop {
        if off == data.len() {
            break off;
        }
        match decode_frame(&data[off..]) {
            Some((rec, consumed)) => {
                records.push(rec);
                off += consumed;
            }
            None => break off,
        }
    };
    if valid < data.len() {
        ensure!(
            is_tail,
            "corrupt frame in sealed trial-log segment {} at byte {valid} -- refusing to \
             open (only the tail segment may have a torn frame)",
            path.display()
        );
        eprintln!(
            "quantune: truncating torn tail of {} at byte {valid} ({} byte(s) dropped)",
            path.display(),
            data.len() - valid
        );
        let f = OpenOptions::new()
            .write(true)
            .open(path)
            .map_err(|e| anyhow!("truncating {}: {e}", path.display()))?;
        f.set_len(valid as u64)?;
    }
    Ok(valid as u64)
}

/// Decode one frame from `buf`: `Some((record, bytes consumed))`, or
/// `None` for a torn or corrupt frame (incomplete header or payload,
/// CRC mismatch, unparsable payload).
fn decode_frame(buf: &[u8]) -> Option<(Record, usize)> {
    if buf.len() < FRAME_HEADER_LEN {
        return None;
    }
    let len = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]) as usize;
    let crc = u32::from_le_bytes([buf[4], buf[5], buf[6], buf[7]]);
    let end = FRAME_HEADER_LEN.checked_add(len)?;
    if buf.len() < end {
        return None;
    }
    let payload = &buf[FRAME_HEADER_LEN..end];
    if crc32(payload) != crc {
        return None;
    }
    let text = std::str::from_utf8(payload).ok()?;
    let rec = Json::parse(text).ok().and_then(|j| Record::from_json(&j).ok())?;
    Some((rec, end))
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::super::records_equal;
    use super::*;

    fn tmpdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(name);
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn rec(config: usize, acc: f64) -> Record {
        Record::new("mn".into(), "general".into(), config, acc, 0.1)
    }

    #[test]
    fn crc32_check_value() {
        // the standard CRC-32/ISO-HDLC check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn roundtrip_and_reopen() {
        let dir = tmpdir("quantune_log_roundtrip_test");
        {
            let mut log = LogStore::open(&dir).unwrap();
            assert!(log.is_empty());
            log.add(rec(0, 0.5)).unwrap();
            log.add(rec(1, f64::NAN)).unwrap();
            log.add(Record {
                latency_ms: Some(3.25),
                size_bytes: Some(f64::INFINITY), // normalized to None
                device: Some("CPU(i7-8700)".into()),
                ..rec(2, 0.9)
            })
            .unwrap();
            log.save().unwrap();
            assert_eq!(log.records[2].size_bytes, None, "non-finite normalizes");
        }
        let log = LogStore::open(&dir).unwrap();
        assert_eq!(log.len(), 3);
        assert!(log.records[1].accuracy.is_nan());
        assert_eq!(log.records[2].latency_ms, Some(3.25));
        assert_eq!(log.records[2].size_bytes, None);
        assert_eq!(log.records[2].device.as_deref(), Some("CPU(i7-8700)"));
        assert_eq!(log.segment_count(), 1);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn open_missing_dir_is_empty_and_lazy() {
        let dir = tmpdir("quantune_log_lazy_test");
        let log = LogStore::open(&dir).unwrap();
        assert!(log.is_empty());
        assert!(!dir.exists(), "open must not create files");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn tiny_threshold_rotates_segments() {
        let dir = tmpdir("quantune_log_rotate_test");
        let n = 10;
        {
            // threshold below one frame: every record seals its segment
            let mut log = LogStore::open_with(&dir, 16).unwrap();
            for i in 0..n {
                log.add(rec(i, 0.5 + i as f64 / 100.0)).unwrap();
            }
            log.save().unwrap();
            assert_eq!(log.segment_count(), n);
        }
        let log = LogStore::open(&dir).unwrap();
        assert_eq!(log.len(), n);
        assert_eq!(log.segment_count(), n);
        for (i, r) in log.records().iter().enumerate() {
            assert_eq!(r.config, i, "replay must preserve sequence order");
        }
        // appends keep working across a reopen
        let mut log = LogStore::open_with(&dir, 16).unwrap();
        log.add(rec(n, 0.99)).unwrap();
        assert_eq!(log.segment_count(), n + 1);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tail_truncates_to_valid_prefix() {
        let dir = tmpdir("quantune_log_torn_test");
        let originals = [rec(0, 0.5), rec(1, 0.6), rec(2, 0.7)];
        {
            let mut log = LogStore::open(&dir).unwrap();
            for r in &originals {
                log.add(r.clone()).unwrap();
            }
            log.save().unwrap();
        }
        // simulate a crash mid-append: garbage after the last frame
        let path = dir.join(segment_name(0));
        let good_len = fs::metadata(&path).unwrap().len();
        let mut data = fs::read(&path).unwrap();
        data.extend_from_slice(&[0x12, 0x00, 0x00, 0x00, 0xde, 0xad]);
        fs::write(&path, &data).unwrap();
        let log = LogStore::open(&dir).unwrap();
        assert_eq!(log.len(), 3, "valid prefix survives");
        for (a, b) in originals.iter().zip(log.records()) {
            assert!(records_equal(a, b));
        }
        assert_eq!(fs::metadata(&path).unwrap().len(), good_len, "file truncated");
        // and a truncated final frame (partial payload) drops only it
        let mut data = fs::read(&path).unwrap();
        data.truncate(data.len() - 3);
        fs::write(&path, &data).unwrap();
        let mut log = LogStore::open(&dir).unwrap();
        assert_eq!(log.len(), 2, "only the torn record is lost");
        // the store stays appendable after recovery
        log.add(rec(9, 0.9)).unwrap();
        drop(log);
        assert_eq!(LogStore::open(&dir).unwrap().len(), 3);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_payload_in_tail_is_dropped_via_crc() {
        let dir = tmpdir("quantune_log_crc_test");
        {
            let mut log = LogStore::open(&dir).unwrap();
            log.add(rec(0, 0.5)).unwrap();
            log.add(rec(1, 0.6)).unwrap();
            log.save().unwrap();
        }
        let path = dir.join(segment_name(0));
        let mut data = fs::read(&path).unwrap();
        let last = data.len() - 1;
        data[last] ^= 0xFF; // flip a payload byte of the final frame
        fs::write(&path, &data).unwrap();
        let log = LogStore::open(&dir).unwrap();
        assert_eq!(log.len(), 1, "CRC catches the flipped byte");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_sealed_segment_refuses_to_open() {
        let dir = tmpdir("quantune_log_sealed_test");
        {
            let mut log = LogStore::open_with(&dir, 16).unwrap();
            log.add(rec(0, 0.5)).unwrap();
            log.add(rec(1, 0.6)).unwrap(); // rotates: segment 0 is sealed
            log.save().unwrap();
            assert_eq!(log.segment_count(), 2);
        }
        let path = dir.join(segment_name(0));
        let mut data = fs::read(&path).unwrap();
        let last = data.len() - 1;
        data[last] ^= 0xFF;
        fs::write(&path, &data).unwrap();
        let err = LogStore::open(&dir).unwrap_err().to_string();
        assert!(err.contains("sealed"), "got: {err}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn segment_gap_refuses_to_open() {
        let dir = tmpdir("quantune_log_gap_test");
        {
            let mut log = LogStore::open_with(&dir, 16).unwrap();
            for i in 0..3 {
                log.add(rec(i, 0.5)).unwrap();
            }
            log.save().unwrap();
        }
        fs::remove_file(dir.join(segment_name(1))).unwrap();
        let err = LogStore::open(&dir).unwrap_err().to_string();
        assert!(err.contains("sequence gap"), "got: {err}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn leftover_tmp_files_are_cleaned_up() {
        let dir = tmpdir("quantune_log_tmp_test");
        {
            let mut log = LogStore::open(&dir).unwrap();
            log.add(rec(0, 0.5)).unwrap();
            log.save().unwrap();
        }
        let stray = dir.join("segment-00001.qlog.tmp");
        fs::write(&stray, b"half-written").unwrap();
        let log = LogStore::open(&dir).unwrap();
        assert_eq!(log.len(), 1);
        assert!(!stray.exists(), "crashed-creation leftovers are removed");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn sequence_numbers_are_stable_across_reopen() {
        let dir = tmpdir("quantune_log_seq_test");
        {
            let mut log = LogStore::open(&dir).unwrap();
            assert_eq!(log.add(rec(0, 0.5)).unwrap(), 0);
            assert_eq!(log.add(rec(1, 0.6)).unwrap(), 1);
            log.save().unwrap();
        }
        let mut log = LogStore::open(&dir).unwrap();
        assert_eq!(log.next_seq(), 2);
        assert_eq!(log.add(rec(2, 0.7)).unwrap(), 2);
        assert_eq!(log.records_since(2).len(), 1);
        let _ = fs::remove_dir_all(&dir);
    }
}
