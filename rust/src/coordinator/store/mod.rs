//! The persistent trial store: the paper's database D = {(e_i, s_i, c_i)}
//! (§5.2) behind one backend-independent [`TrialStore`] interface.
//!
//! Two backends implement it:
//!
//! - [`Database`] -- the legacy whole-file JSON format, kept for
//!   transparent opening of old artifacts, migration, and export;
//! - [`LogStore`] -- a crash-safe append-only segmented log
//!   (dependency-free: per-record length + CRC32 framing, atomic
//!   tmp+rename segment creation, torn-tail truncation on open).
//!
//! [`Store`] is the dispatching handle `Quantune` owns; it auto-detects
//! the backend from the artifacts directory. Both backends share the
//! [`RecordIndex`] -- positions grouped by (space tag, model) plus
//! device counts -- so `accuracy_table`, `has_full_sweep`, `best_for`,
//! and `transfer_records` are O(matching records) index probes instead
//! of O(all records) scans, and both are append-only with stable
//! sequence numbers, which gives consumers a watermark API
//! ([`TrialStore::records_since`], [`TransferCursor`]) for incremental
//! XGB refits. [`StoreWriter`] is the concurrency story: parallel sweep
//! workers append durably as trials complete while the persisted order
//! stays bit-identical to the serial sweep.

#![deny(clippy::unwrap_used)]

pub mod log;

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, MutexGuard};

use anyhow::{anyhow, ensure, Result};

pub use log::LogStore;

use super::database::{Database, Record, GENERAL_SPACE_TAG};
use crate::quant::QuantConfig;
use crate::search::TransferRecord;

/// In-memory secondary index over a record list: record positions
/// grouped by (space tag, model), plus per-device record counts.
/// Building is O(records); probing is O(matching records).
#[derive(Clone, Debug, Default)]
pub struct RecordIndex {
    by_space: BTreeMap<String, BTreeMap<String, Vec<usize>>>,
    devices: BTreeMap<String, usize>,
}

impl RecordIndex {
    /// Index every record of `records` (positions are sequence numbers).
    pub fn build(records: &[Record]) -> RecordIndex {
        let mut idx = RecordIndex::default();
        for (pos, r) in records.iter().enumerate() {
            idx.insert(pos, r);
        }
        idx
    }

    /// Register the record stored at position `pos`.
    pub fn insert(&mut self, pos: usize, r: &Record) {
        self.by_space
            .entry(r.space.clone())
            .or_default()
            .entry(r.model.clone())
            .or_default()
            .push(pos);
        if let Some(d) = &r.device {
            *self.devices.entry(d.clone()).or_default() += 1;
        }
    }

    /// Positions of every (model, space) record, in insertion order.
    pub fn positions(&self, space: &str, model: &str) -> &[usize] {
        match self.by_space.get(space).and_then(|m| m.get(model)) {
            Some(v) => v,
            None => &[],
        }
    }

    /// (model, positions) pairs for one space, models in sorted order.
    pub fn models_in<'a>(
        &'a self,
        space: &str,
    ) -> impl Iterator<Item = (&'a str, &'a [usize])> + 'a {
        self.by_space
            .get(space)
            .into_iter()
            .flat_map(|m| m.iter().map(|(k, v)| (k.as_str(), v.as_slice())))
    }

    /// Record count per space tag, sorted by tag.
    pub fn space_counts(&self) -> Vec<(&str, usize)> {
        self.by_space
            .iter()
            .map(|(s, models)| (s.as_str(), models.values().map(Vec::len).sum()))
            .collect()
    }

    /// Record count per model, aggregated across spaces.
    pub fn model_counts(&self) -> BTreeMap<&str, usize> {
        let mut out: BTreeMap<&str, usize> = BTreeMap::new();
        for models in self.by_space.values() {
            for (m, v) in models {
                *out.entry(m.as_str()).or_default() += v.len();
            }
        }
        out
    }

    /// Record count per device tag (device-less records don't count).
    pub fn device_counts(&self) -> &BTreeMap<String, usize> {
        &self.devices
    }
}

/// Backend-independent view of the trial database `D`: an append-only,
/// sequence-numbered record list plus the [`RecordIndex`] over it.
/// Every query is a provided method over those two accessors, so all
/// backends answer them identically.
pub trait TrialStore: Send {
    /// Every record in sequence order (position == sequence number).
    fn records(&self) -> &[Record];

    /// The secondary index over [`TrialStore::records`].
    fn index(&self) -> &RecordIndex;

    /// Append one record, returning its sequence number. Log-backed
    /// stores write the record to disk before returning.
    fn add(&mut self, r: Record) -> Result<u64>;

    /// Durability point: atomic whole-file rewrite (JSON backend), data
    /// sync of the active segment (log backend), no-op in memory.
    fn save(&self) -> Result<()>;

    /// Where the records live on disk (`None` for in-memory stores).
    fn location(&self) -> Option<&Path>;

    /// Number of records.
    fn len(&self) -> usize {
        self.records().len()
    }

    /// True when no trials have been recorded.
    fn is_empty(&self) -> bool {
        self.records().is_empty()
    }

    /// The sequence number the next [`TrialStore::add`] will return --
    /// the watermark a consumer saves to resume from later.
    fn next_seq(&self) -> u64 {
        self.records().len() as u64
    }

    /// Records appended at or after sequence number `seq`, in order --
    /// the incremental-refit API: a consumer that remembers the
    /// `next_seq` of its last visit sees exactly the trials a full
    /// re-scan would have added.
    fn records_since(&self, seq: u64) -> &[Record] {
        let start = (seq as usize).min(self.records().len());
        &self.records()[start..]
    }

    /// Accuracy table (config index -> best-known accuracy) for one
    /// model in one space; holes are NaN. Duplicate (model, config)
    /// records keep the maximum measured accuracy, so a re-measured
    /// config can only improve the table. Partial-fidelity racing
    /// records (see [`Record::is_full_fidelity`]) are estimates, not
    /// measurements, and never fill the table.
    fn accuracy_table(&self, model: &str, space: &str, size: usize) -> Vec<f64> {
        let recs = self.records();
        let mut t = vec![f64::NAN; size];
        for &pos in self.index().positions(space, model) {
            let r = &recs[pos];
            if !r.is_full_fidelity() {
                continue;
            }
            if r.config < size && (t[r.config].is_nan() || r.accuracy > t[r.config]) {
                t[r.config] = r.accuracy;
            }
        }
        t
    }

    /// Does the store hold a full sweep for `model` in `space`?
    fn has_full_sweep(&self, model: &str, space: &str, size: usize) -> bool {
        self.accuracy_table(model, space, size).iter().all(|a| !a.is_nan())
    }

    /// Are there any records from models other than `exclude` in
    /// `space`? Cheap pre-check for xgb_t's transfer requirement (a
    /// `true` can still yield no transfer records when the other
    /// models' feature metadata is missing -- the search then errors
    /// descriptively, which is the right surface for that broken state).
    fn has_transfer_records(&self, exclude: &str, space: &str) -> bool {
        self.index().models_in(space).any(|(m, v)| m != exclude && !v.is_empty())
    }

    /// Transfer-learning records in `space` from every model EXCEPT
    /// `exclude`. `features` maps (model, config index) -> feature
    /// vector; records it returns `None` for are skipped. Partial
    /// racing records DO feed transfer -- they carry their fidelity
    /// fraction so the fidelity-aware XGB feature column can learn the
    /// estimate/measurement distinction instead of discarding the rows.
    fn transfer_records(
        &self,
        exclude: &str,
        space: &str,
        features: &mut dyn FnMut(&str, usize) -> Option<Vec<f32>>,
    ) -> Vec<TransferRecord> {
        let recs = self.records();
        let mut positions: Vec<usize> = self
            .index()
            .models_in(space)
            .filter(|&(m, _)| m != exclude)
            .flat_map(|(_, v)| v.iter().copied())
            .collect();
        // merge the per-model position lists back into global sequence
        // order: row order feeds the XGB fit, so it must match what a
        // full scan of `records()` produces
        positions.sort_unstable();
        let mut out = Vec::with_capacity(positions.len());
        for pos in positions {
            let r = &recs[pos];
            if let Some(f) = features(&r.model, r.config) {
                out.push(TransferRecord {
                    features: f,
                    accuracy: r.accuracy as f32,
                    fidelity: r.fidelity.unwrap_or(1.0) as f32,
                });
            }
        }
        out
    }

    /// Best finite-accuracy (config, accuracy) for `model` in `space`
    /// -- any space, not just the general one. NaN accuracies and
    /// partial-fidelity racing estimates are skipped entirely (a store
    /// of only-NaN records reports `None`); accuracy ties keep the
    /// newest record, matching the legacy full-scan `max_by` semantics.
    fn best_for(&self, model: &str, space: &str) -> Option<(usize, f64)> {
        let recs = self.records();
        let mut best: Option<(usize, f64)> = None;
        for &pos in self.index().positions(space, model) {
            let r = &recs[pos];
            if r.accuracy.is_nan() || !r.is_full_fidelity() {
                continue;
            }
            let better = match best {
                Some((_, acc)) => r.accuracy >= acc,
                None => true,
            };
            if better {
                best = Some((r.config, r.accuracy));
            }
        }
        best
    }

    /// General-space wrapper over [`TrialStore::best_for`] for the
    /// legacy call sites: decodes the winner into a [`QuantConfig`].
    fn best_general(&self, model: &str) -> Option<(QuantConfig, f64)> {
        self.best_for(model, GENERAL_SPACE_TAG)
            .and_then(|(cfg, acc)| QuantConfig::from_index(cfg).ok().map(|c| (c, acc)))
    }

    /// Up to `k` distinct configs for (model, space) ranked by
    /// best-known accuracy (descending; the config index breaks ties)
    /// -- the warm-start query behind database-seeded GA / NSGA-II
    /// populations. Partial-fidelity racing records are excluded like
    /// NaNs: a seeded population must start from real measurements.
    fn best_configs(&self, model: &str, space: &str, k: usize) -> Vec<(usize, f64)> {
        let recs = self.records();
        let mut best: BTreeMap<usize, f64> = BTreeMap::new();
        for &pos in self.index().positions(space, model) {
            let r = &recs[pos];
            if r.accuracy.is_nan() || !r.is_full_fidelity() {
                continue;
            }
            let e = best.entry(r.config).or_insert(f64::NEG_INFINITY);
            if r.accuracy > *e {
                *e = r.accuracy;
            }
        }
        let mut out: Vec<(usize, f64)> = best.into_iter().collect();
        out.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
        out.truncate(k);
        out
    }
}

/// The backend-dispatching store handle [`crate::coordinator::Quantune`]
/// owns. All [`TrialStore`] queries are re-exposed as inherent methods,
/// so call sites don't need the trait in scope.
///
/// # Examples
///
/// ```
/// use quantune::coordinator::{Record, Store};
///
/// # fn main() -> anyhow::Result<()> {
/// let mut store = Store::in_memory();
/// store.add(Record::new("mn".into(), "general".into(), 3, 0.71, 0.1))?;
/// store.add(Record::new("mn".into(), "general".into(), 7, 0.84, 0.1))?;
/// assert_eq!(store.best_for("mn", "general"), Some((7, 0.84)));
/// assert_eq!(store.records_since(1).len(), 1);
/// # Ok(())
/// # }
/// ```
pub enum Store {
    /// Legacy whole-file JSON database (also the in-memory backend).
    Json(Database),
    /// Crash-safe append-only segmented log.
    Log(LogStore),
}

impl Store {
    /// A store with no backing file (`save` is a no-op).
    pub fn in_memory() -> Store {
        Store::Json(Database::in_memory())
    }

    /// Open the trial store of an artifacts directory, auto-detecting
    /// the backend: an existing `trials/` log wins, else an existing
    /// legacy `database.json` opens transparently on the JSON backend,
    /// else a fresh log store is created (lazily -- nothing touches the
    /// disk until the first append).
    pub fn open(artifacts: &Path) -> Result<Store> {
        let log_dir = artifacts.join("trials");
        if log_dir.is_dir() {
            return Ok(Store::Log(LogStore::open(&log_dir)?));
        }
        let legacy = artifacts.join("database.json");
        if legacy.exists() {
            return Ok(Store::Json(Database::open(&legacy)?));
        }
        Ok(Store::Log(LogStore::open(&log_dir)?))
    }

    /// Open a specific legacy JSON file (migration / export tooling).
    pub fn open_json(path: &Path) -> Result<Store> {
        Ok(Store::Json(Database::open(path)?))
    }

    /// Open a specific log directory.
    pub fn open_log(dir: &Path) -> Result<Store> {
        Ok(Store::Log(LogStore::open(dir)?))
    }

    /// Backend name for status displays: "memory", "json", or "log".
    pub fn backend(&self) -> &'static str {
        match self {
            Store::Json(db) => {
                if db.location().is_none() {
                    "memory"
                } else {
                    "json"
                }
            }
            Store::Log(_) => "log",
        }
    }

    /// Segment count (0 for the JSON / in-memory backends).
    pub fn segments(&self) -> usize {
        match self {
            Store::Json(_) => 0,
            Store::Log(log) => log.segment_count(),
        }
    }

    /// A cloneable, mutex-guarded appender handle for parallel
    /// producers; see [`StoreWriter`].
    pub fn writer(&mut self) -> StoreWriter<'_> {
        StoreWriter::new(self)
    }

    /// See [`TrialStore::records`].
    pub fn records(&self) -> &[Record] {
        TrialStore::records(self)
    }

    /// See [`TrialStore::len`].
    pub fn len(&self) -> usize {
        TrialStore::len(self)
    }

    /// See [`TrialStore::is_empty`].
    pub fn is_empty(&self) -> bool {
        TrialStore::is_empty(self)
    }

    /// See [`TrialStore::add`].
    pub fn add(&mut self, r: Record) -> Result<u64> {
        TrialStore::add(self, r)
    }

    /// See [`TrialStore::save`].
    pub fn save(&self) -> Result<()> {
        TrialStore::save(self)
    }

    /// See [`TrialStore::location`].
    pub fn location(&self) -> Option<&Path> {
        TrialStore::location(self)
    }

    /// See [`TrialStore::index`].
    pub fn index(&self) -> &RecordIndex {
        TrialStore::index(self)
    }

    /// See [`TrialStore::next_seq`].
    pub fn next_seq(&self) -> u64 {
        TrialStore::next_seq(self)
    }

    /// See [`TrialStore::records_since`].
    pub fn records_since(&self, seq: u64) -> &[Record] {
        TrialStore::records_since(self, seq)
    }

    /// See [`TrialStore::accuracy_table`].
    pub fn accuracy_table(&self, model: &str, space: &str, size: usize) -> Vec<f64> {
        TrialStore::accuracy_table(self, model, space, size)
    }

    /// See [`TrialStore::has_full_sweep`].
    pub fn has_full_sweep(&self, model: &str, space: &str, size: usize) -> bool {
        TrialStore::has_full_sweep(self, model, space, size)
    }

    /// See [`TrialStore::has_transfer_records`].
    pub fn has_transfer_records(&self, exclude: &str, space: &str) -> bool {
        TrialStore::has_transfer_records(self, exclude, space)
    }

    /// See [`TrialStore::transfer_records`].
    pub fn transfer_records(
        &self,
        exclude: &str,
        space: &str,
        mut features: impl FnMut(&str, usize) -> Option<Vec<f32>>,
    ) -> Vec<TransferRecord> {
        TrialStore::transfer_records(self, exclude, space, &mut features)
    }

    /// See [`TrialStore::best_for`].
    pub fn best_for(&self, model: &str, space: &str) -> Option<(usize, f64)> {
        TrialStore::best_for(self, model, space)
    }

    /// See [`TrialStore::best_general`].
    pub fn best_general(&self, model: &str) -> Option<(QuantConfig, f64)> {
        TrialStore::best_general(self, model)
    }

    /// See [`TrialStore::best_configs`].
    pub fn best_configs(&self, model: &str, space: &str, k: usize) -> Vec<(usize, f64)> {
        TrialStore::best_configs(self, model, space, k)
    }
}

impl TrialStore for Store {
    fn records(&self) -> &[Record] {
        match self {
            Store::Json(db) => db.records(),
            Store::Log(log) => log.records(),
        }
    }

    fn index(&self) -> &RecordIndex {
        match self {
            Store::Json(db) => db.index(),
            Store::Log(log) => log.index(),
        }
    }

    fn add(&mut self, r: Record) -> Result<u64> {
        match self {
            Store::Json(db) => db.add(r),
            Store::Log(log) => log.add(r),
        }
    }

    fn save(&self) -> Result<()> {
        match self {
            Store::Json(db) => db.save(),
            Store::Log(log) => log.save(),
        }
    }

    fn location(&self) -> Option<&Path> {
        match self {
            Store::Json(db) => db.location(),
            Store::Log(log) => log.location(),
        }
    }
}

/// State behind a [`StoreWriter`]: the borrowed store plus the reorder
/// buffer of completed-but-not-yet-sequenced trials.
struct WriterState<'s> {
    store: &'s mut dyn TrialStore,
    /// Completed trials waiting for their slot's turn.
    staged: BTreeMap<u64, Record>,
    /// Next slot to append (slots are writer-relative, starting at 0).
    next: u64,
    appended: usize,
}

/// A cloneable, mutex-guarded appender over a store: parallel sweep
/// workers [`StoreWriter::submit`] completed trials under a *slot*
/// number (their config index) and the writer appends the contiguous
/// completed prefix in slot order. The persisted sequence is therefore
/// bit-identical to the serial sweep at any `QUANTUNE_THREADS`, while
/// every record still lands durably the moment its slot's turn comes
/// instead of at sweep end -- a crash loses only the trailing trials
/// whose slot predecessors hadn't finished yet.
pub struct StoreWriter<'s> {
    inner: Arc<Mutex<WriterState<'s>>>,
}

impl<'s> StoreWriter<'s> {
    /// Wrap a store. Dropping the writer releases the borrow; call
    /// [`StoreWriter::finish`] first to assert completeness and sync.
    pub fn new(store: &'s mut dyn TrialStore) -> StoreWriter<'s> {
        StoreWriter {
            inner: Arc::new(Mutex::new(WriterState {
                store,
                staged: BTreeMap::new(),
                next: 0,
                appended: 0,
            })),
        }
    }

    fn lock(&self) -> Result<MutexGuard<'_, WriterState<'s>>> {
        self.inner
            .lock()
            .map_err(|_| anyhow!("trial-store writer poisoned by a panicked producer"))
    }

    /// Stage the record for `slot`, then append every staged record
    /// that continues the contiguous slot prefix. Each slot must be
    /// submitted exactly once.
    pub fn submit(&self, slot: usize, r: Record) -> Result<()> {
        let mut guard = self.lock()?;
        let st = &mut *guard;
        let slot = slot as u64;
        ensure!(
            slot >= st.next && !st.staged.contains_key(&slot),
            "slot {slot} submitted twice to the store writer"
        );
        st.staged.insert(slot, r);
        while let Some(rec) = st.staged.remove(&st.next) {
            st.store.add(rec)?;
            st.next += 1;
            st.appended += 1;
        }
        Ok(())
    }

    /// Assert every submitted slot was appended (no gaps), sync the
    /// store, and return how many records this writer appended.
    pub fn finish(&self) -> Result<usize> {
        let guard = self.lock()?;
        ensure!(
            guard.staged.is_empty(),
            "store writer finished with {} record(s) stuck behind missing slot {}",
            guard.staged.len(),
            guard.next
        );
        guard.store.save()?;
        Ok(guard.appended)
    }
}

impl Clone for StoreWriter<'_> {
    fn clone(&self) -> Self {
        StoreWriter { inner: Arc::clone(&self.inner) }
    }
}

/// Watermark-incremental extractor of transfer rows (paper §5.2): the
/// cursor remembers the last sequence number it consumed and converts
/// only records appended since into [`TransferRecord`]s, so the XGB-T
/// fit ingests new trials without re-scanning the whole store each
/// generation. A refresh from watermark 0 is exactly the full
/// [`TrialStore::transfer_records`] scan.
pub struct TransferCursor {
    exclude: String,
    space: String,
    watermark: u64,
    records: Vec<TransferRecord>,
}

impl TransferCursor {
    /// Cursor over `space` records of every model except `exclude`.
    pub fn new(exclude: impl Into<String>, space: impl Into<String>) -> TransferCursor {
        TransferCursor {
            exclude: exclude.into(),
            space: space.into(),
            watermark: 0,
            records: Vec::new(),
        }
    }

    /// Consume records appended since the watermark, mapping (model,
    /// config) to feature vectors (`None` skips the record); returns
    /// how many rows were added. Afterwards the watermark equals the
    /// store's [`TrialStore::next_seq`].
    pub fn refresh<S: TrialStore + ?Sized>(
        &mut self,
        store: &S,
        mut features: impl FnMut(&str, usize) -> Option<Vec<f32>>,
    ) -> usize {
        let mut added = 0;
        for r in store.records_since(self.watermark) {
            if r.model != self.exclude && r.space == self.space {
                if let Some(f) = features(&r.model, r.config) {
                    self.records.push(TransferRecord {
                        features: f,
                        accuracy: r.accuracy as f32,
                        fidelity: r.fidelity.unwrap_or(1.0) as f32,
                    });
                    added += 1;
                }
            }
        }
        self.watermark = store.next_seq();
        added
    }

    /// Sequence number the next refresh resumes from.
    pub fn watermark(&self) -> u64 {
        self.watermark
    }

    /// Every row extracted so far, in sequence order.
    pub fn records(&self) -> &[TransferRecord] {
        &self.records
    }

    /// Consume the cursor, returning the extracted rows.
    pub fn into_records(self) -> Vec<TransferRecord> {
        self.records
    }
}

/// Bit-exact record equality (NaN == NaN): migration verification and
/// determinism tests compare floats by bit pattern, not `==`.
pub fn records_equal(a: &Record, b: &Record) -> bool {
    let bits = f64::to_bits;
    a.model == b.model
        && a.space == b.space
        && a.config == b.config
        && bits(a.accuracy) == bits(b.accuracy)
        && bits(a.measure_secs) == bits(b.measure_secs)
        && a.latency_ms.map(bits) == b.latency_ms.map(bits)
        && a.size_bytes.map(bits) == b.size_bytes.map(bits)
        && a.device == b.device
        && a.fidelity.map(bits) == b.fidelity.map(bits)
}

/// Write `bytes` to `path` through a same-directory temp file + atomic
/// rename, so a crash mid-write can never destroy an existing file.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let mut tmp = path.as_os_str().to_owned();
    tmp.push(".tmp");
    let tmp = PathBuf::from(tmp);
    fs::write(&tmp, bytes).map_err(|e| anyhow!("writing {}: {e}", tmp.display()))?;
    fs::rename(&tmp, path)
        .map_err(|e| anyhow!("renaming {} into place: {e}", tmp.display()))?;
    Ok(())
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    fn rec(model: &str, space: &str, config: usize, acc: f64) -> Record {
        Record::new(model.into(), space.into(), config, acc, 0.1)
    }

    #[test]
    fn index_queries_match_full_scans() {
        let mut s = Store::in_memory();
        s.add(rec("mn", "general", 0, 0.5)).unwrap();
        s.add(rec("shn", "general", 1, 0.6)).unwrap();
        s.add(rec("mn", "vta", 0, 0.9)).unwrap();
        s.add(rec("mn", "general", 2, 0.7)).unwrap();
        let t = s.accuracy_table("mn", "general", 4);
        assert_eq!(t[0], 0.5);
        assert!(t[1].is_nan());
        assert_eq!(t[2], 0.7);
        assert!(s.has_transfer_records("mn", "general"));
        assert!(!s.has_transfer_records("shn", "vta"));
        let rows = s.transfer_records("mn", "general", |_, i| Some(vec![i as f32]));
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].accuracy, 0.6);
    }

    #[test]
    fn transfer_rows_keep_global_sequence_order() {
        // two other models interleaved: the per-model index lists must
        // merge back into insertion order, as the XGB row order depends
        // on it
        let mut s = Store::in_memory();
        s.add(rec("a", "general", 0, 0.1)).unwrap();
        s.add(rec("b", "general", 1, 0.2)).unwrap();
        s.add(rec("a", "general", 2, 0.3)).unwrap();
        s.add(rec("b", "general", 3, 0.4)).unwrap();
        let rows = s.transfer_records("mn", "general", |_, i| Some(vec![i as f32]));
        let configs: Vec<f32> = rows.iter().map(|r| r.features[0]).collect();
        assert_eq!(configs, vec![0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn best_for_any_space_ties_keep_newest() {
        let mut s = Store::in_memory();
        s.add(rec("mn", "vta", 2, 0.8)).unwrap();
        s.add(rec("mn", "vta", 5, 0.8)).unwrap(); // tie: newest wins
        s.add(rec("mn", "vta", 1, f64::NAN)).unwrap();
        assert_eq!(s.best_for("mn", "vta"), Some((5, 0.8)));
        assert_eq!(s.best_for("mn", "general"), None);
        // all-NaN space reports None, not a panic
        s.add(rec("shn", "vta", 0, f64::NAN)).unwrap();
        assert_eq!(s.best_for("shn", "vta"), None);
    }

    #[test]
    fn best_configs_ranks_unique_configs() {
        let mut s = Store::in_memory();
        s.add(rec("mn", "general", 3, 0.5)).unwrap();
        s.add(rec("mn", "general", 3, 0.9)).unwrap(); // re-measured, better
        s.add(rec("mn", "general", 7, 0.7)).unwrap();
        s.add(rec("mn", "general", 1, f64::NAN)).unwrap();
        s.add(rec("mn", "general", 4, 0.7)).unwrap(); // accuracy tie with 7
        let top = s.best_configs("mn", "general", 2);
        assert_eq!(top, vec![(3, 0.9), (4, 0.7)]);
        let all = s.best_configs("mn", "general", 10);
        assert_eq!(all, vec![(3, 0.9), (4, 0.7), (7, 0.7)]);
    }

    #[test]
    fn records_since_is_a_watermark() {
        let mut s = Store::in_memory();
        assert_eq!(s.next_seq(), 0);
        assert_eq!(s.add(rec("mn", "general", 0, 0.5)).unwrap(), 0);
        assert_eq!(s.add(rec("mn", "general", 1, 0.6)).unwrap(), 1);
        let mark = s.next_seq();
        assert_eq!(mark, 2);
        s.add(rec("mn", "general", 2, 0.7)).unwrap();
        let new = s.records_since(mark);
        assert_eq!(new.len(), 1);
        assert_eq!(new[0].config, 2);
        // past-the-end watermark is empty, not a panic
        assert!(s.records_since(99).is_empty());
    }

    #[test]
    fn writer_reorders_out_of_order_slots() {
        let mut s = Store::in_memory();
        let w = s.writer();
        w.submit(2, rec("mn", "general", 2, 0.3)).unwrap();
        w.submit(0, rec("mn", "general", 0, 0.1)).unwrap();
        w.submit(1, rec("mn", "general", 1, 0.2)).unwrap();
        assert_eq!(w.finish().unwrap(), 3);
        drop(w);
        let configs: Vec<usize> = s.records().iter().map(|r| r.config).collect();
        assert_eq!(configs, vec![0, 1, 2]);
    }

    #[test]
    fn writer_rejects_duplicate_slots_and_gapped_finish() {
        let mut s = Store::in_memory();
        let w = s.writer();
        w.submit(0, rec("mn", "general", 0, 0.1)).unwrap();
        assert!(w.submit(0, rec("mn", "general", 0, 0.1)).is_err());
        w.submit(2, rec("mn", "general", 2, 0.3)).unwrap();
        assert!(w.submit(2, rec("mn", "general", 2, 0.3)).is_err());
        let err = w.finish().unwrap_err().to_string();
        assert!(err.contains("missing slot 1"), "got: {err}");
    }

    #[test]
    fn cursor_refresh_matches_full_extraction() {
        let mut s = Store::in_memory();
        s.add(rec("a", "general", 0, 0.1)).unwrap();
        s.add(rec("mn", "general", 1, 0.9)).unwrap(); // excluded
        let mut cursor = TransferCursor::new("mn", "general");
        assert_eq!(cursor.refresh(&s, |_, i| Some(vec![i as f32])), 1);
        s.add(rec("b", "vta", 2, 0.2)).unwrap(); // wrong space
        s.add(rec("b", "general", 3, 0.3)).unwrap();
        assert_eq!(cursor.refresh(&s, |_, i| Some(vec![i as f32])), 1);
        // nothing new: refresh is a no-op
        assert_eq!(cursor.refresh(&s, |_, i| Some(vec![i as f32])), 0);
        let full = s.transfer_records("mn", "general", |_, i| Some(vec![i as f32]));
        assert_eq!(cursor.records().len(), full.len());
        for (a, b) in cursor.records().iter().zip(&full) {
            assert_eq!(a.features, b.features);
            assert_eq!(a.accuracy, b.accuracy);
        }
        assert_eq!(cursor.watermark(), s.next_seq());
    }

    #[test]
    fn records_equal_is_bit_exact_and_nan_aware() {
        let a = rec("mn", "general", 1, f64::NAN);
        let b = rec("mn", "general", 1, f64::NAN);
        assert!(records_equal(&a, &b));
        let c = Record { latency_ms: Some(1.5), ..a.clone() };
        assert!(!records_equal(&a, &c));
        assert!(records_equal(&c, &c.clone()));
        let d = Record { device: Some("x".into()), ..a.clone() };
        assert!(!records_equal(&a, &d));
        let e = Record { fidelity: Some(0.25), ..a.clone() };
        assert!(!records_equal(&a, &e), "fidelity is part of record identity");
        assert!(records_equal(&e, &e.clone()));
    }

    #[test]
    fn transfer_rows_carry_the_record_fidelity() {
        let mut s = Store::in_memory();
        s.add(Record { fidelity: Some(0.0625), ..rec("a", "general", 0, 0.4) }).unwrap();
        s.add(rec("a", "general", 1, 0.6)).unwrap(); // legacy: full
        s.add(Record { fidelity: Some(1.0), ..rec("b", "general", 2, 0.7) }).unwrap();
        let rows = s.transfer_records("mn", "general", |_, i| Some(vec![i as f32]));
        let fids: Vec<f32> = rows.iter().map(|r| r.fidelity).collect();
        assert_eq!(fids, vec![0.0625, 1.0, 1.0]);
        // the cursor agrees with the full extraction
        let mut cursor = TransferCursor::new("mn", "general");
        cursor.refresh(&s, |_, i| Some(vec![i as f32]));
        let cfids: Vec<f32> = cursor.records().iter().map(|r| r.fidelity).collect();
        assert_eq!(cfids, fids);
        // but partial estimates never win best_for / best_configs
        s.add(Record { fidelity: Some(0.25), ..rec("a", "general", 9, 0.99) }).unwrap();
        assert_eq!(s.best_for("a", "general"), Some((1, 0.6)));
        assert_eq!(s.best_configs("a", "general", 4), vec![(1, 0.6)]);
    }

    #[test]
    fn write_atomic_replaces_and_leaves_no_tmp() {
        let dir = std::env::temp_dir().join("quantune_store_atomic_test");
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("f.json");
        write_atomic(&path, b"one").unwrap();
        write_atomic(&path, b"two").unwrap();
        assert_eq!(fs::read_to_string(&path).unwrap(), "two");
        assert!(!dir.join("f.json.tmp").exists());
        let _ = fs::remove_dir_all(&dir);
    }
}
