//! Accuracy measurement backends: `f(g(e, s))` of the paper's Eq. 14.
//!
//! Three interchangeable evaluators measure the Top-1 accuracy of a
//! quantized model variant on the held-out eval split:
//! - [`HloEvaluator`]: the production path -- the parameterized
//!   `{model}_fq.hlo.txt` PJRT executable fed with fake-quantized weights
//!   and activation parameter rows;
//! - [`InterpEvaluator`]: the pure-rust oracle (bit-equivalent modulo
//!   float associativity);
//! - [`OracleEvaluator`]: a precomputed accuracy table (used to compare
//!   search algorithms on identical ground truth, and in tests).
//!
//! All evaluators memoize per config index: re-measuring an explored
//! config is free, which matches how the search driver accounts trials.

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::Result;

use crate::calib::{calibrate, CalibBackend, CalibrationCache};
use crate::data::Dataset;
use crate::interp::{argmax_batch, Interpreter};
use crate::quant::{CalibCount, QuantConfig};
use crate::runtime::{tensor_to_literal, Runtime};
use crate::util::Timer;
use crate::zoo::ZooModel;

use super::quantizer::{act_params_tensor, prepare};

/// Top-1 accuracy measurement of one config of one model.
pub trait Evaluator {
    /// Measure (or return the memoized) Top-1 for a config index.
    fn measure(&mut self, config: usize) -> Result<f64>;
    /// Mean wall-clock seconds of a non-memoized measurement.
    fn mean_measure_secs(&self) -> f64;
}

/// Shared calibration-cache store (3 caches per model, built lazily).
pub struct CalibStore {
    caches: HashMap<CalibCount, CalibrationCache>,
    pub seed: u64,
}

impl CalibStore {
    pub fn new(seed: u64) -> Self {
        CalibStore { caches: HashMap::new(), seed }
    }

    pub fn get(
        &mut self,
        model: &ZooModel,
        pool: &Dataset,
        count: CalibCount,
        backend: &CalibBackend,
    ) -> Result<&CalibrationCache> {
        if !self.caches.contains_key(&count) {
            let cache = calibrate(model, pool, count, backend, self.seed)?;
            self.caches.insert(count, cache);
        }
        Ok(&self.caches[&count])
    }
}

/// PJRT-backed evaluator (the production path).
pub struct HloEvaluator<'a> {
    pub model: &'a ZooModel,
    pub runtime: &'a Runtime,
    pub artifacts: PathBuf,
    pub calib_pool: &'a Dataset,
    pub eval: &'a Dataset,
    calib: CalibStore,
    memo: HashMap<usize, f64>,
    measure_times: Vec<f64>,
}

impl<'a> HloEvaluator<'a> {
    pub fn new(
        model: &'a ZooModel,
        runtime: &'a Runtime,
        artifacts: PathBuf,
        calib_pool: &'a Dataset,
        eval: &'a Dataset,
        seed: u64,
    ) -> Self {
        HloEvaluator {
            model,
            runtime,
            artifacts,
            calib_pool,
            eval,
            calib: CalibStore::new(seed),
            memo: HashMap::new(),
            measure_times: Vec::new(),
        }
    }

    fn top1_fq(&mut self, cfg: &QuantConfig) -> Result<f64> {
        let backend =
            CalibBackend::Hlo { runtime: self.runtime, artifacts: &self.artifacts };
        let cache = self.calib.get(self.model, self.calib_pool, cfg.calib, &backend)?;
        let setup = prepare(self.model, cache, cfg)?;
        let exe = self
            .runtime
            .load(&self.artifacts.join(format!("{}_fq.hlo.txt", self.model.name)))?;

        // constant operands (act params + weights) are uploaded once and
        // borrowed across all eval batches
        let ap = act_params_tensor(&setup);
        let ap_lit = tensor_to_literal(&ap)?;
        let w_lits: Vec<xla::Literal> = setup
            .weights
            .iter()
            .map(tensor_to_literal)
            .collect::<Result<_>>()?;

        let batch = self.model.batch;
        let mut hits = 0usize;
        let mut total = 0usize;
        let idx_all: Vec<usize> = (0..self.eval.n).collect();
        for chunk in idx_all.chunks(batch) {
            let (x, valid) = self.eval.batch_padded(chunk, batch);
            let x_lit = tensor_to_literal(&x)?;
            let mut literals: Vec<&xla::Literal> = Vec::with_capacity(2 + w_lits.len());
            literals.push(&x_lit);
            literals.push(&ap_lit);
            literals.extend(w_lits.iter());
            let out = exe.run_literals(&literals)?;
            let preds = argmax_batch(&out[0]);
            let labels = self.eval.labels_for(chunk);
            hits += preds
                .iter()
                .take(valid)
                .zip(&labels)
                .filter(|(&p, &l)| p == l as usize)
                .count();
            total += valid;
        }
        Ok(hits as f64 / total as f64)
    }
}

impl Evaluator for HloEvaluator<'_> {
    fn measure(&mut self, config: usize) -> Result<f64> {
        if let Some(&a) = self.memo.get(&config) {
            return Ok(a);
        }
        let cfg = QuantConfig::from_index(config)?;
        let t = Timer::start();
        let acc = self.top1_fq(&cfg)?;
        self.measure_times.push(t.secs());
        self.memo.insert(config, acc);
        Ok(acc)
    }

    fn mean_measure_secs(&self) -> f64 {
        crate::util::stats::mean(&self.measure_times)
    }
}

/// Interpreter-backed evaluator (identical pipeline, no PJRT).
pub struct InterpEvaluator<'a> {
    pub model: &'a ZooModel,
    pub calib_pool: &'a Dataset,
    pub eval: &'a Dataset,
    calib: CalibStore,
    memo: HashMap<usize, f64>,
    measure_times: Vec<f64>,
}

impl<'a> InterpEvaluator<'a> {
    pub fn new(
        model: &'a ZooModel,
        calib_pool: &'a Dataset,
        eval: &'a Dataset,
        seed: u64,
    ) -> Self {
        InterpEvaluator {
            model,
            calib_pool,
            eval,
            calib: CalibStore::new(seed),
            memo: HashMap::new(),
            measure_times: Vec::new(),
        }
    }
}

impl Evaluator for InterpEvaluator<'_> {
    fn measure(&mut self, config: usize) -> Result<f64> {
        if let Some(&a) = self.memo.get(&config) {
            return Ok(a);
        }
        let cfg = QuantConfig::from_index(config)?;
        let t = Timer::start();
        let cache = self.calib.get(
            self.model,
            self.calib_pool,
            cfg.calib,
            &CalibBackend::Interp,
        )?;
        let setup = prepare(self.model, cache, &cfg)?;
        let weights: HashMap<String, crate::ir::Tensor> = self
            .model
            .weights
            .order
            .iter()
            .cloned()
            .zip(setup.weights.iter().cloned())
            .collect();
        let interp = Interpreter::new(&self.model.graph, &weights);
        let mut hits = 0;
        let idx_all: Vec<usize> = (0..self.eval.n).collect();
        for chunk in idx_all.chunks(64) {
            let x = self.eval.batch(chunk);
            let logits = interp.forward_fq(&x, &setup.aq)?;
            let preds = argmax_batch(&logits);
            let labels = self.eval.labels_for(chunk);
            hits +=
                preds.iter().zip(&labels).filter(|(&p, &l)| p == l as usize).count();
        }
        let acc = hits as f64 / self.eval.n as f64;
        self.measure_times.push(t.secs());
        self.memo.insert(config, acc);
        Ok(acc)
    }

    fn mean_measure_secs(&self) -> f64 {
        crate::util::stats::mean(&self.measure_times)
    }
}

/// Precomputed accuracy table (search-algorithm comparisons, tests).
pub struct OracleEvaluator {
    pub table: Vec<f64>,
    /// simulated per-measurement cost (for search-time accounting)
    pub secs_per_measure: f64,
}

impl OracleEvaluator {
    pub fn new(table: Vec<f64>) -> Self {
        OracleEvaluator { table, secs_per_measure: 0.0 }
    }
}

impl Evaluator for OracleEvaluator {
    fn measure(&mut self, config: usize) -> Result<f64> {
        self.table
            .get(config)
            .copied()
            .filter(|a| !a.is_nan())
            .ok_or_else(|| anyhow::anyhow!("oracle has no entry for config {config}"))
    }

    fn mean_measure_secs(&self) -> f64 {
        self.secs_per_measure
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_returns_table_values() {
        let mut o = OracleEvaluator::new(vec![0.1, 0.9]);
        assert_eq!(o.measure(1).unwrap(), 0.9);
        assert!(o.measure(5).is_err());
    }
}
