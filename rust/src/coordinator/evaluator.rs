//! Accuracy measurement backends: `f(g(e, s))` of the paper's Eq. 14.
//!
//! Three interchangeable evaluators measure the Top-1 accuracy of a
//! quantized model variant on the held-out eval split:
//! - [`HloEvaluator`]: the production path -- the parameterized
//!   `{model}_fq.hlo.txt` PJRT executable fed with fake-quantized weights
//!   and activation parameter rows;
//! - [`InterpEvaluator`]: the pure-rust oracle (bit-equivalent modulo
//!   float associativity);
//! - [`OracleEvaluator`]: a precomputed accuracy table (used to compare
//!   search algorithms on identical ground truth, and in tests).
//!
//! All evaluators memoize per config index: re-measuring an explored
//! config is free, which matches how the search driver accounts trials.
//! Memoization, the calibration-cache store, and the prepared-weight
//! cache are interior-mutable (`Mutex`/`Arc`), so one evaluator can be
//! shared by the worker pool; [`SharedEvaluator`] is the thread-safe
//! measurement entry point the parallel sweep drives. `InterpEvaluator`
//! additionally fans its per-batch Top-1 counting out across the pool,
//! reducing hit counts in input order so the measured accuracy is
//! identical at any thread count.
//!
//! [`ObjectiveEvaluator`] wraps any of them for multi-objective tuning:
//! accuracy is measured, predicted latency and model bytes come from the
//! static per-config [`CostModel`](super::objective::CostModel), and the
//! weighted scalarization is what the search maximizes.
//!
//! Multi-fidelity racing: both traits carry a provided
//! `measure_fidelity*` entry point taking a [`Fidelity`] fraction of
//! the evaluation set. The default ignores the fraction and measures at
//! full fidelity (correct for [`OracleEvaluator`] table lookups, which
//! are free anyway); [`InterpEvaluator`] overrides it to score the
//! config on a nested, label-stratified prefix of the eval batches
//! (see `data::stratified_order`), memoized per (config, prefix).

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::calib::{calibrate, CalibBackend, CalibrationCache};
use crate::data::Dataset;
use crate::interp::{argmax_batch, InterpScratch, Interpreter};
use crate::ir::Tensor;
use crate::metrics::{DispatchCounters, DispatchStats};
use crate::quant::{general_space, CalibCount, ConfigSpace, QuantPlan, SpaceRef};
use crate::runtime::{tensor_to_literal, Runtime};
use crate::search::Fidelity;
use crate::util::pool::Pool;
use crate::util::Timer;
use crate::zoo::ZooModel;

use super::quantizer::{act_params_tensor, prepare_cached, WeightCache};

/// Top-1 accuracy measurement of one config of one model.
pub trait Evaluator {
    /// Measure (or return the memoized) Top-1 for a config index.
    fn measure(&mut self, config: usize) -> Result<f64>;
    /// Mean wall-clock seconds of a non-memoized measurement.
    fn mean_measure_secs(&self) -> f64;
    /// Measure Top-1 on a `fidelity` fraction of the evaluation set
    /// (multi-fidelity racing). Fidelity-oblivious evaluators keep this
    /// default, which measures the full set whatever the fraction --
    /// still correct, just never cheaper.
    fn measure_fidelity(&mut self, config: usize, fidelity: Fidelity) -> Result<f64> {
        let _ = fidelity;
        self.measure(config)
    }
}

/// Thread-safe measurement: evaluators whose `measure` may be called
/// from several pool workers at once (the parallel sweep and the
/// experiment fan-outs). `HloEvaluator` is excluded: the PJRT client is
/// single-threaded on our side.
pub trait SharedEvaluator: Sync {
    /// Measure (or return the memoized) Top-1 for a config index.
    fn measure_shared(&self, config: usize) -> Result<f64>;
    /// Measure Top-1 on a `fidelity` fraction of the evaluation set
    /// (multi-fidelity racing; see [`Evaluator::measure_fidelity`] for
    /// the default's contract).
    fn measure_fidelity_shared(
        &self,
        config: usize,
        fidelity: Fidelity,
    ) -> Result<f64> {
        let _ = fidelity;
        self.measure_shared(config)
    }
}

/// Objective-aware measurement: Top-1 accuracy comes from the wrapped
/// evaluator, predicted latency and serialized bytes from the static
/// [`CostModel`](super::objective::CostModel), and the three fold into
/// the scalar the search maximizes via
/// [`ObjectiveWeights::score`](super::objective::ObjectiveWeights).
/// This is what `Quantune::search_objective` drives, and why every
/// algorithm and space tunes any objective unchanged: they only ever
/// see the scalar.
///
/// Constrained search: when a [`Budget`](super::objective::Budget) is
/// set, a config whose static cost exceeds it is rejected **without
/// measuring accuracy** -- the trial is recorded with a `-inf` score
/// (never the best) and a NaN-accuracy component vector (ranked last by
/// every dominance/ranking site), and the wrapped evaluator is never
/// called. The epsilon-constraint therefore costs zero evaluations.
pub struct ObjectiveEvaluator<'a> {
    /// The accuracy-measuring evaluator being wrapped.
    pub inner: &'a mut dyn Evaluator,
    /// Static per-config (latency, bytes) table.
    pub cost: &'a super::objective::CostModel,
    /// Scalarization weights.
    pub weights: super::objective::ObjectiveWeights,
    /// Hard latency/size budgets
    /// ([`Budget::unlimited`](super::objective::Budget::unlimited)
    /// admits all).
    pub budget: super::objective::Budget,
}

impl ObjectiveEvaluator<'_> {
    /// Measure config `i` and return (scalar score, component breakdown)
    /// in the shape `run_search` consumes. Over-budget configs short-
    /// circuit before the accuracy measurement (see the type docs).
    pub fn measure_scored(
        &mut self,
        config: usize,
    ) -> Result<(f64, crate::search::Components)> {
        self.measure_scored_fidelity(config, Fidelity::full())
    }

    /// [`ObjectiveEvaluator::measure_scored`] at a racing fidelity: the
    /// budget gate fires first exactly as at full fidelity (static
    /// costs don't depend on how much of the eval set is scored), then
    /// accuracy is measured on the `fidelity` fraction.
    pub fn measure_scored_fidelity(
        &mut self,
        config: usize,
        fidelity: Fidelity,
    ) -> Result<(f64, crate::search::Components)> {
        let cost = self.cost.cost(config)?;
        if !self.budget.admits(cost) {
            return Ok((
                f64::NEG_INFINITY,
                crate::search::Components {
                    accuracy: f64::NAN,
                    latency_ms: cost.latency_ms,
                    size_bytes: cost.size_bytes,
                },
            ));
        }
        let accuracy = self.inner.measure_fidelity(config, fidelity)?;
        let score = self.weights.score(accuracy, cost, &self.cost.refs);
        let components = crate::search::Components {
            accuracy,
            latency_ms: cost.latency_ms,
            size_bytes: cost.size_bytes,
        };
        Ok((score, components))
    }
}

/// One calibration cache slot: its own lock so a count is built exactly
/// once while requesters of *other* counts proceed unblocked.
type CalibSlot = Arc<Mutex<Option<Arc<CalibrationCache>>>>;

/// Shared calibration-cache store (3 caches per model, built lazily,
/// shareable across worker threads).
pub struct CalibStore {
    caches: Mutex<HashMap<CalibCount, CalibSlot>>,
    /// Seed controlling the calibration image draw.
    pub seed: u64,
}

impl CalibStore {
    /// An empty store; caches build lazily on first request.
    pub fn new(seed: u64) -> Self {
        CalibStore { caches: Mutex::new(HashMap::new()), seed }
    }

    /// The cache for `count`, building it on first request (concurrent
    /// requesters of the same count wait for the one build).
    pub fn get(
        &self,
        model: &ZooModel,
        calib_pool: &Dataset,
        count: CalibCount,
        backend: &CalibBackend,
    ) -> Result<Arc<CalibrationCache>> {
        let slot: CalibSlot = self
            .caches
            .lock()
            .unwrap()
            .entry(count)
            .or_insert_with(|| Arc::new(Mutex::new(None)))
            .clone();
        // per-count lock: concurrent workers wanting this count wait for
        // the one build instead of each recalibrating (a failed build
        // leaves the slot empty so the next caller retries)
        let mut guard = slot.lock().unwrap();
        if let Some(c) = guard.as_ref() {
            return Ok(c.clone());
        }
        let built = Arc::new(calibrate(model, calib_pool, count, backend, self.seed)?);
        *guard = Some(built.clone());
        Ok(built)
    }

    /// Seed a prebuilt cache so the first measurement at `count` reuses
    /// it instead of recalibrating (callers that already calibrated --
    /// e.g. to rank layer sensitivity -- hand their cache over here).
    pub fn put(&self, count: CalibCount, cache: Arc<CalibrationCache>) {
        let slot: CalibSlot = self
            .caches
            .lock()
            .unwrap()
            .entry(count)
            .or_insert_with(|| Arc::new(Mutex::new(None)))
            .clone();
        *slot.lock().unwrap() = Some(cache);
    }
}

/// PJRT-backed evaluator (the production path).
pub struct HloEvaluator<'a> {
    /// Model under measurement.
    pub model: &'a ZooModel,
    /// PJRT runtime executing the artifacts.
    pub runtime: &'a Runtime,
    /// Artifacts directory holding the HLO files.
    pub artifacts: PathBuf,
    /// Calibration image pool.
    pub calib_pool: &'a Dataset,
    /// Held-out eval split Top-1 is measured on.
    pub eval: &'a Dataset,
    space: SpaceRef,
    calib: CalibStore,
    wcache: WeightCache,
    memo: Mutex<HashMap<usize, f64>>,
    measure_times: Mutex<Vec<f64>>,
}

impl<'a> HloEvaluator<'a> {
    /// Evaluator over the default general space.
    pub fn new(
        model: &'a ZooModel,
        runtime: &'a Runtime,
        artifacts: PathBuf,
        calib_pool: &'a Dataset,
        eval: &'a Dataset,
        seed: u64,
    ) -> Self {
        HloEvaluator {
            model,
            runtime,
            artifacts,
            calib_pool,
            eval,
            space: general_space(),
            calib: CalibStore::new(seed),
            wcache: WeightCache::new(),
            memo: Mutex::new(HashMap::new()),
            measure_times: Mutex::new(Vec::new()),
        }
    }

    /// Measure configs of `space` instead of the default general space
    /// (config indices passed to `measure` are then indices into it).
    pub fn with_space(mut self, space: SpaceRef) -> Self {
        self.space = space;
        self
    }

    fn top1_fq(&self, plan: &QuantPlan) -> Result<f64> {
        let backend =
            CalibBackend::Hlo { runtime: self.runtime, artifacts: &self.artifacts };
        let cache =
            self.calib.get(self.model, self.calib_pool, plan.base.calib, &backend)?;
        let setup = prepare_cached(self.model, cache.as_ref(), plan, &self.wcache)?;
        let exe = self
            .runtime
            .load(&self.artifacts.join(format!("{}_fq.hlo.txt", self.model.name)))?;

        // constant operands (act params + weights) are uploaded once and
        // borrowed across all eval batches
        let ap = act_params_tensor(&setup);
        let ap_lit = tensor_to_literal(&ap)?;
        let w_lits: Vec<xla::Literal> = setup
            .weights
            .iter()
            .map(|t| tensor_to_literal(t))
            .collect::<Result<_>>()?;

        // batch preparation (index gather + u8 -> padded f32 normalize)
        // fans out across the pool one window at a time, so only a few
        // prepared f32 batches are resident while execution drains them
        // on this thread (the PJRT client is not Sync and has its own
        // intra-op parallelism)
        let batch = self.model.batch;
        let idx_all: Vec<usize> = (0..self.eval.n).collect();
        let chunks: Vec<&[usize]> = idx_all.chunks(batch).collect();
        // borrow only the dataset into the closure: `self` holds the
        // non-Sync PJRT runtime handle
        let eval = self.eval;
        let workers = Pool::auto();
        let mut hits = 0usize;
        let mut total = 0usize;
        for window in chunks.chunks(workers.threads().saturating_mul(2).max(1)) {
            let prepped: Vec<(Tensor, usize, Vec<u8>)> = workers.map(window, |chunk| {
                let (x, valid) = eval.batch_padded(chunk, batch);
                let labels = eval.labels_for(chunk);
                (x, valid, labels)
            })?;
            for (x, valid, labels) in &prepped {
                let x_lit = tensor_to_literal(x)?;
                let mut literals: Vec<&xla::Literal> =
                    Vec::with_capacity(2 + w_lits.len());
                literals.push(&x_lit);
                literals.push(&ap_lit);
                literals.extend(w_lits.iter());
                let out = exe.run_literals(&literals)?;
                let preds = argmax_batch(&out[0]);
                hits += preds
                    .iter()
                    .take(*valid)
                    .zip(labels)
                    .filter(|(&p, &l)| p == l as usize)
                    .count();
                total += valid;
            }
        }
        Ok(hits as f64 / total.max(1) as f64)
    }

    fn measure_at(&self, config: usize) -> Result<f64> {
        if let Some(&a) = self.memo.lock().unwrap().get(&config) {
            return Ok(a);
        }
        let plan = self.space.plan(config)?;
        let t = Timer::start();
        let acc = self.top1_fq(&plan)?;
        self.measure_times.lock().unwrap().push(t.secs());
        self.memo.lock().unwrap().insert(config, acc);
        Ok(acc)
    }
}

impl Evaluator for HloEvaluator<'_> {
    fn measure(&mut self, config: usize) -> Result<f64> {
        self.measure_at(config)
    }

    fn mean_measure_secs(&self) -> f64 {
        crate::util::stats::mean(&self.measure_times.lock().unwrap())
    }
}

/// Interpreter-backed evaluator (identical pipeline, no PJRT). Batch
/// Top-1 counting fans out across the worker pool.
pub struct InterpEvaluator<'a> {
    /// Model under measurement.
    pub model: &'a ZooModel,
    /// Calibration image pool.
    pub calib_pool: &'a Dataset,
    /// Held-out eval split Top-1 is measured on.
    pub eval: &'a Dataset,
    space: SpaceRef,
    calib: CalibStore,
    wcache: WeightCache,
    memo: Mutex<HashMap<usize, f64>>,
    // racing memo: (config, eval batches scored) -> Top-1 estimate, so
    // re-racing a config at the same rung is free like a full measure
    partial_memo: Mutex<HashMap<(usize, usize), f64>>,
    measure_times: Mutex<Vec<f64>>,
    workers: Pool,
    counters: DispatchCounters,
}

impl<'a> InterpEvaluator<'a> {
    /// Evaluator over the default general space.
    pub fn new(
        model: &'a ZooModel,
        calib_pool: &'a Dataset,
        eval: &'a Dataset,
        seed: u64,
    ) -> Self {
        InterpEvaluator {
            model,
            calib_pool,
            eval,
            space: general_space(),
            calib: CalibStore::new(seed),
            wcache: WeightCache::new(),
            memo: Mutex::new(HashMap::new()),
            partial_memo: Mutex::new(HashMap::new()),
            measure_times: Mutex::new(Vec::new()),
            workers: Pool::auto(),
            counters: DispatchCounters::new(),
        }
    }

    /// Pin the batch-level worker count (parity/determinism tests; the
    /// default follows `QUANTUNE_THREADS`).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.workers = Pool::new(threads);
        self
    }

    /// Measure configs of `space` instead of the default general space
    /// (config indices passed to `measure` are then indices into it).
    pub fn with_space(mut self, space: SpaceRef) -> Self {
        self.space = space;
        self
    }

    /// Seed a prebuilt calibration cache (must match this evaluator's
    /// model and seed) so measurements at `count` skip recalibration.
    pub fn with_calibration(self, count: CalibCount, cache: Arc<CalibrationCache>) -> Self {
        self.calib.put(count, cache);
        self
    }

    /// Cumulative dispatch accounting across every measurement so far:
    /// integer-engine vs f32-fallback layer/MAC tallies from the
    /// interpreter, plus the prepacked-weight cache's hit/build counts.
    pub fn dispatch_stats(&self) -> DispatchStats {
        let mut s = self.counters.snapshot();
        let (hits, builds) = self.wcache.int_cache_stats();
        s.prepack_hits = hits;
        s.prepack_builds = builds;
        s
    }

    /// Top-1 of `config` over exactly the eval-image chunks given: the
    /// shared measurement core behind full- and partial-fidelity
    /// scoring. Per-chunk hit counts fan out across the pool and reduce
    /// in input order, so the result is identical at any thread count.
    fn top1_on(&self, config: usize, chunks: &[&[usize]]) -> Result<f64> {
        let plan = self.space.plan(config)?;
        let cache = self.calib.get(
            self.model,
            self.calib_pool,
            plan.base.calib,
            &CalibBackend::Interp,
        )?;
        let setup = prepare_cached(self.model, cache.as_ref(), &plan, &self.wcache)?;
        // Arc clones only: warm weight-cache hits share tensor storage
        // with the cache instead of copying it per measurement
        let weights: HashMap<String, Arc<Tensor>> = self
            .model
            .weights
            .order
            .iter()
            .cloned()
            .zip(setup.weights.iter().cloned())
            .collect();
        let interp = Interpreter::new(&self.model.graph, &weights)
            .with_dispatch_counters(&self.counters);
        // int4/int8 conv/dense layers run on the packed integer kernels
        // (QUANTUNE_INT_INTERP=0 forces the legacy f32 fake-quant route)
        let interp = if crate::interp::int_interp_enabled() {
            interp.with_int_weights(&setup.int_weights)
        } else {
            interp
        };
        // per-batch hit counts fan out, then reduce in input order: the
        // integer sum is identical at any thread count. When this
        // measurement itself runs on a pool worker (parallel sweep), the
        // batch level serializes instead of oversubscribing.
        let workers = if crate::util::pool::in_worker() {
            Pool::new(1)
        } else {
            self.workers
        };
        // each worker builds one scratch arena sized to the graph's
        // high-water mark and reuses it across every batch it steals --
        // steady-state forwards then allocate nothing but the logits
        let hits_per = workers.map_init(
            chunks,
            || InterpScratch::for_graph(&self.model.graph, 64),
            |scratch, chunk| -> Result<usize> {
                let x = self.eval.batch(chunk);
                let logits = interp.forward_fq_with(&x, &setup.aq, scratch)?;
                let preds = argmax_batch(&logits);
                let labels = self.eval.labels_for(chunk);
                Ok(preds.iter().zip(&labels).filter(|(&p, &l)| p == l as usize).count())
            },
        )?;
        let mut hits = 0usize;
        for h in hits_per {
            hits += h?;
        }
        let images: usize = chunks.iter().map(|c| c.len()).sum();
        Ok(hits as f64 / images.max(1) as f64)
    }
}

impl SharedEvaluator for InterpEvaluator<'_> {
    fn measure_shared(&self, config: usize) -> Result<f64> {
        if let Some(&a) = self.memo.lock().unwrap().get(&config) {
            return Ok(a);
        }
        let t = Timer::start();
        let idx_all: Vec<usize> = (0..self.eval.n).collect();
        let chunks: Vec<&[usize]> = idx_all.chunks(64).collect();
        let acc = self.top1_on(config, &chunks)?;
        self.measure_times.lock().unwrap().push(t.secs());
        self.memo.lock().unwrap().insert(config, acc);
        Ok(acc)
    }

    fn measure_fidelity_shared(&self, config: usize, fidelity: Fidelity) -> Result<f64> {
        // full fidelity takes the plain path (same memo, same chunk
        // order): racing with fidelity_min = 1 is bit-identical to the
        // unraced evaluator
        if fidelity.is_full() {
            return self.measure_shared(config);
        }
        let batches = self.eval.stratified_batches(64);
        let take = fidelity.batches_of(batches.len());
        if let Some(&a) = self.partial_memo.lock().unwrap().get(&(config, take)) {
            return Ok(a);
        }
        let t = Timer::start();
        // a PREFIX of the stratified batch order: rung k's images are a
        // subset of rung k+1's, and every prefix is label-balanced
        let chunks: Vec<&[usize]> =
            batches[..take].iter().map(|b| b.as_slice()).collect();
        let acc = self.top1_on(config, &chunks)?;
        self.measure_times.lock().unwrap().push(t.secs());
        self.partial_memo.lock().unwrap().insert((config, take), acc);
        Ok(acc)
    }
}

impl Evaluator for InterpEvaluator<'_> {
    fn measure(&mut self, config: usize) -> Result<f64> {
        self.measure_shared(config)
    }

    fn measure_fidelity(&mut self, config: usize, fidelity: Fidelity) -> Result<f64> {
        self.measure_fidelity_shared(config, fidelity)
    }

    fn mean_measure_secs(&self) -> f64 {
        crate::util::stats::mean(&self.measure_times.lock().unwrap())
    }
}

/// Precomputed accuracy table (search-algorithm comparisons, tests).
pub struct OracleEvaluator {
    /// Accuracy per config index (NaN = unmeasured hole).
    pub table: Vec<f64>,
    /// simulated per-measurement cost (for search-time accounting)
    pub secs_per_measure: f64,
}

impl OracleEvaluator {
    /// Oracle over a precomputed accuracy table.
    pub fn new(table: Vec<f64>) -> Self {
        OracleEvaluator { table, secs_per_measure: 0.0 }
    }

    /// Out-of-range indices are an error (the caller paired the wrong
    /// space with this table); a NaN entry -- an unmeasured hole of
    /// `TrialStore::accuracy_table` -- is returned as NaN so a search over
    /// a partial table degrades (NaN ranks below every real score)
    /// instead of aborting.
    fn lookup(&self, config: usize) -> Result<f64> {
        self.table
            .get(config)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("oracle has no entry for config {config}"))
    }
}

impl Evaluator for OracleEvaluator {
    fn measure(&mut self, config: usize) -> Result<f64> {
        self.lookup(config)
    }

    fn mean_measure_secs(&self) -> f64 {
        self.secs_per_measure
    }
}

impl SharedEvaluator for OracleEvaluator {
    fn measure_shared(&self, config: usize) -> Result<f64> {
        self.lookup(config)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_returns_table_values() {
        let mut o = OracleEvaluator::new(vec![0.1, 0.9, f64::NAN]);
        assert_eq!(o.measure(1).unwrap(), 0.9);
        assert!(o.measure(5).is_err());
        // a NaN hole degrades (ranks last downstream) instead of erroring
        assert!(o.measure(2).unwrap().is_nan());
        // shared entry point agrees with the &mut one
        assert_eq!(o.measure_shared(0).unwrap(), 0.1);
    }

    #[test]
    fn fidelity_oblivious_defaults_measure_full() {
        // the provided trait defaults ignore the fraction: a table
        // lookup is already free, so racing an oracle stays exact
        let mut o = OracleEvaluator::new(vec![0.1, 0.9]);
        let f = Fidelity::fraction(0.25).unwrap();
        assert_eq!(o.measure_fidelity(1, f).unwrap(), 0.9);
        assert_eq!(o.measure_fidelity_shared(0, f).unwrap(), 0.1);
    }
}
