//! Multi-objective evaluation layer: weighted scalarization of Top-1
//! accuracy, predicted deployment latency, and quantized model bytes.
//!
//! The paper tunes for accuracy alone, but its deployment story (§6.5
//! latency, the VTA integer-only path, Table 5 sizes) only pays off when
//! the tuner can trade the three against each other. This module keeps
//! the search algorithms objective-agnostic: a [`CostModel`] precomputes
//! the static (latency, bytes) cost of every config in a space, and
//! [`ObjectiveWeights::score`] folds a measured accuracy and that cost
//! into the single scalar `run_search` maximizes. Trials then carry the
//! full [`crate::search::Components`] breakdown, so traces, database
//! records, and the Pareto experiment can all report per-axis numbers.
//!
//! Latency sources:
//! - general / layer-wise spaces: the analytical
//!   [`DeviceProfile`](super::devices::DeviceProfile) cost model, at
//!   per-layer resolution and per-layer bit-width (fp32 layers take the
//!   fp32 path, integer layers the naive kernel at that width's
//!   throughput factor -- naive int8 is *slower* than fp32 on CPUs, the
//!   paper's own finding, while int4 claws back a memory-bandwidth win);
//! - VTA space: [`crate::vta::estimate_cycles`] totals at the deploy
//!   clock, which exactly replay the simulator's cycle counters.
//!
//! Size is the serialized-bytes accounting of Table 5
//! ([`crate::quant::model_size_bytes_at`]), priced per bit-width (int4
//! packs two weights per byte) so the radix search sees real deltas.
//!
//! Scalarization: `w_acc * acc - w_lat * lat/lat_ref - w_size *
//! size/size_ref`, with the fp32 deployment as the reference point, so
//! all three terms live on comparable O(1) scales and a NaN accuracy
//! propagates to a NaN score (which every ranking site degrades on
//! instead of panicking).

use std::collections::HashMap;

use anyhow::Result;

use crate::quant::{model_size_bytes_at, model_size_fp32, ConfigSpace, VtaConfig};
use crate::vta::estimate_cycles;
use crate::zoo::ZooModel;

use super::devices::DeviceProfile;

/// The objective presets the CLI exposes (`--objective`).
pub const OBJECTIVES: [&str; 4] = ["acc", "lat", "size", "balanced"];

/// Non-negative weights of the scalarized objective. `accuracy` weighs
/// the measured Top-1; `latency` and `size` weigh the *relative* cost
/// against the fp32 deployment (so a weight of 1 means "one accuracy
/// point is worth the entire fp32 latency/size budget").
///
/// # Examples
///
/// ```
/// use quantune::coordinator::{ConfigCost, ObjectiveWeights};
/// use quantune::coordinator::objective::CostRefs;
///
/// # fn main() -> anyhow::Result<()> {
/// let w = ObjectiveWeights::parse("balanced")?;
/// let refs = CostRefs { latency_ms: 10.0, size_bytes: 1000.0 };
/// let cheap = ConfigCost { latency_ms: 5.0, size_bytes: 250.0 };
/// let dear = ConfigCost { latency_ms: 20.0, size_bytes: 1000.0 };
/// // at equal accuracy the cheaper deployment scores higher...
/// assert!(w.score(0.7, cheap, &refs) > w.score(0.7, dear, &refs));
/// // ...and accuracy-only tuning ignores cost entirely
/// let acc = ObjectiveWeights::accuracy_only();
/// assert_eq!(acc.score(0.5, dear, &refs), 0.5);
/// # Ok(())
/// # }
/// ```
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ObjectiveWeights {
    /// Weight on measured Top-1.
    pub accuracy: f64,
    /// Weight on relative modeled latency.
    pub latency: f64,
    /// Weight on relative serialized bytes.
    pub size: f64,
}

impl ObjectiveWeights {
    /// Accuracy-only tuning (the paper's objective).
    pub fn accuracy_only() -> ObjectiveWeights {
        ObjectiveWeights { accuracy: 1.0, latency: 0.0, size: 0.0 }
    }

    /// Parse a CLI preset. Unknown names are a descriptive error, not a
    /// silent default.
    pub fn parse(name: &str) -> Result<ObjectiveWeights> {
        Ok(match name {
            "acc" => Self::accuracy_only(),
            "lat" => ObjectiveWeights { accuracy: 0.6, latency: 0.4, size: 0.0 },
            "size" => ObjectiveWeights { accuracy: 0.6, latency: 0.0, size: 0.4 },
            "balanced" => ObjectiveWeights { accuracy: 0.6, latency: 0.2, size: 0.2 },
            other => {
                anyhow::bail!("unknown objective {other:?} (try one of {OBJECTIVES:?})")
            }
        })
    }

    /// Is this plain accuracy tuning (no cost model needed)?
    pub fn is_accuracy_only(&self) -> bool {
        self.latency == 0.0 && self.size == 0.0
    }

    /// Compact label for CSVs and logs.
    pub fn slug(&self) -> String {
        format!("a{:.2}_l{:.2}_s{:.2}", self.accuracy, self.latency, self.size)
    }

    /// Fold a measured accuracy and a config's static cost into the
    /// scalar the search maximizes (see the module docs for the formula).
    pub fn score(&self, accuracy: f64, cost: ConfigCost, refs: &CostRefs) -> f64 {
        self.accuracy * accuracy
            - self.latency * (cost.latency_ms / refs.latency_ms)
            - self.size * (cost.size_bytes / refs.size_bytes)
    }
}

/// Hard deployment budgets for constrained search (the epsilon-constraint
/// formulation: optimize accuracy subject to `latency <= eps_lat` and
/// `bytes <= eps_size`, as in the integer-programming layer-wise
/// calibration setting of Hubara et al.). A config whose *static*
/// [`ConfigCost`] exceeds either bound is rejected **before** its
/// accuracy is measured -- see
/// [`ObjectiveEvaluator`](super::evaluator::ObjectiveEvaluator) -- so an
/// over-budget config never costs an evaluation. `None` on an axis means
/// unconstrained; [`Budget::unlimited`] (the default) admits everything.
///
/// # Examples
///
/// ```
/// use quantune::coordinator::{Budget, ConfigCost};
///
/// let budget = Budget { max_latency_ms: Some(10.0), max_size_bytes: None };
/// assert!(budget.admits(ConfigCost { latency_ms: 9.0, size_bytes: 1e9 }));
/// assert!(!budget.admits(ConfigCost { latency_ms: 10.5, size_bytes: 1.0 }));
/// // boundary costs are within budget (<=, not <)
/// assert!(budget.admits(ConfigCost { latency_ms: 10.0, size_bytes: 0.0 }));
/// assert!(Budget::unlimited().admits(ConfigCost {
///     latency_ms: f64::INFINITY,
///     size_bytes: f64::INFINITY,
/// }));
/// ```
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Budget {
    /// Hard cap on modeled per-image latency (milliseconds), if any.
    pub max_latency_ms: Option<f64>,
    /// Hard cap on serialized model bytes, if any.
    pub max_size_bytes: Option<f64>,
}

impl Budget {
    /// No constraints: every config is admitted.
    pub fn unlimited() -> Budget {
        Budget::default()
    }

    /// Is any axis actually constrained?
    pub fn is_limited(&self) -> bool {
        self.max_latency_ms.is_some() || self.max_size_bytes.is_some()
    }

    /// Does `cost` fit inside the budget (inclusive bounds)? A NaN cost
    /// component never fits a constrained axis (`NaN <= cap` is false),
    /// so an unpriceable config cannot sneak under a budget.
    pub fn admits(&self, cost: ConfigCost) -> bool {
        self.max_latency_ms.map_or(true, |cap| cost.latency_ms <= cap)
            && self.max_size_bytes.map_or(true, |cap| cost.size_bytes <= cap)
    }

    /// Compact label for CSVs and logs ("lat<=10ms,bytes<=4096" or
    /// "unlimited").
    pub fn slug(&self) -> String {
        let mut parts = Vec::new();
        if let Some(l) = self.max_latency_ms {
            parts.push(format!("lat<={l}ms"));
        }
        if let Some(b) = self.max_size_bytes {
            parts.push(format!("bytes<={b}"));
        }
        if parts.is_empty() {
            "unlimited".to_string()
        } else {
            parts.join(",")
        }
    }
}

/// Static per-config deployment cost (accuracy is measured, these two
/// are modeled).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ConfigCost {
    /// Modeled per-image latency (milliseconds).
    pub latency_ms: f64,
    /// Serialized quantized model bytes.
    pub size_bytes: f64,
}

/// Reference (fp32) costs the relative terms normalize against.
#[derive(Clone, Copy, Debug)]
pub struct CostRefs {
    /// fp32 per-image latency (milliseconds).
    pub latency_ms: f64,
    /// fp32 serialized bytes.
    pub size_bytes: f64,
}

/// Per-config (latency, bytes) table for one (model, space, device):
/// built once per search, O(|S|) cheap shape arithmetic, no measurement.
pub struct CostModel {
    costs: Vec<ConfigCost>,
    /// Reference costs the relative terms normalize against.
    pub refs: CostRefs,
    /// Human-readable latency source ("CPU(i7-8700)" or "VTA@100MHz").
    pub target: String,
}

impl CostModel {
    /// Build the cost table for every config of `space`. The latency
    /// source is the space-appropriate one: VTA cycle totals for the
    /// integer-only space (`device` is ignored there -- the model
    /// deploys on the accelerator, not the CPU/GPU), the analytical
    /// `device` profile at per-layer resolution otherwise.
    pub fn build(
        model: &ZooModel,
        space: &dyn ConfigSpace,
        device: &DeviceProfile,
        vta_clock_mhz: f64,
    ) -> Result<CostModel> {
        let graph = &model.graph;
        let layer_macs = graph.layer_macs()?;
        let n_layers = layer_macs.len();
        // resolve every layer's weight/bias element counts up front so a
        // model with a broken weight map fails loudly here instead of
        // silently pricing size_bytes = 0 (the size accounting callbacks
        // below are infallible by signature)
        let mut layer_dims: HashMap<String, (usize, usize)> = HashMap::new();
        for layer in graph.layers() {
            let w = model.weights.get(&format!("{layer}_w"))?.len();
            let b = model.weights.get(&format!("{layer}_b"))?.len();
            layer_dims.insert(layer, (w, b));
        }
        let dims = |layer: &str| layer_dims[layer];
        let is_vta = space.tag() == "vta";

        // VTA latency depends only on the fusion bit: walk the graph
        // twice up front instead of once per config
        let vta_ms = if is_vta {
            Some((
                estimate_cycles(graph, true, 1)?.ms_at(vta_clock_mhz),
                estimate_cycles(graph, false, 1)?.ms_at(vta_clock_mhz),
            ))
        } else {
            None
        };
        let refs = CostRefs {
            latency_ms: match vta_ms {
                // the VTA reference is the slower (unfused) deployment;
                // there is no fp32 path on the integer-only accelerator
                Some((_, unfused)) => unfused,
                None => device.fp32_latency_s(graph.macs()?, n_layers) * 1e3,
            },
            size_bytes: model_size_fp32(graph, &dims).max(1) as f64,
        };

        let mut costs = Vec::with_capacity(space.size());
        for i in 0..space.size() {
            let plan = space.plan(i)?;
            let widths = plan.resolve_widths(n_layers)?;
            let latency_ms = match vta_ms {
                Some((fused, unfused)) => {
                    if VtaConfig::from_index(i)?.fusion {
                        fused
                    } else {
                        unfused
                    }
                }
                None => device.widths_latency_ms(&layer_macs, &widths),
            };
            let size_bytes =
                model_size_bytes_at(graph, &dims, plan.base.gran, &widths) as f64;
            costs.push(ConfigCost { latency_ms, size_bytes });
        }
        Ok(CostModel {
            costs,
            refs,
            target: if is_vta {
                format!("VTA@{vta_clock_mhz}MHz")
            } else {
                device.name.to_string()
            },
        })
    }

    /// Static cost of config `i`.
    pub fn cost(&self, i: usize) -> Result<ConfigCost> {
        self.costs
            .get(i)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("no cost entry for config {i}"))
    }

    /// How many configs of the priced space fit inside `budget` (the
    /// feasible-set size of a constrained search). Zero means the budget
    /// is unsatisfiable for this (model, space, device) and a search
    /// under it would measure nothing.
    pub fn feasible_count(&self, budget: &Budget) -> usize {
        self.costs.iter().filter(|&&c| budget.admits(c)).count()
    }

    /// Number of priced configs.
    pub fn len(&self) -> usize {
        self.costs.len()
    }

    /// Is the table empty?
    pub fn is_empty(&self) -> bool {
        self.costs.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::vta_space;
    use crate::zoo::synthetic_model;

    #[test]
    fn presets_parse_and_unknowns_error() {
        for name in OBJECTIVES {
            let w = ObjectiveWeights::parse(name).unwrap();
            assert!(w.accuracy > 0.0, "{name} must keep accuracy in the loop");
        }
        assert!(ObjectiveWeights::parse("acc").unwrap().is_accuracy_only());
        assert!(!ObjectiveWeights::parse("balanced").unwrap().is_accuracy_only());
        let err = ObjectiveWeights::parse("speed").unwrap_err().to_string();
        assert!(err.contains("speed") && err.contains("balanced"), "{err}");
    }

    #[test]
    fn vta_cost_model_prices_fusion() {
        let model = synthetic_model(8, 4, 4, 3).unwrap();
        let space = vta_space();
        let cm = CostModel::build(&model, space.as_ref(), &super::super::DEVICES[1], 100.0)
            .unwrap();
        assert_eq!(cm.len(), 12);
        assert!(cm.target.starts_with("VTA@"));
        for i in 0..space.size() {
            let cfg = VtaConfig::from_index(i).unwrap();
            let cost = cm.cost(i).unwrap();
            // size is fusion/calib-independent on the VTA (same int8 tensors)
            assert_eq!(cost.size_bytes, cm.cost(0).unwrap().size_bytes);
            // fused configs are strictly faster, and nothing beats the
            // unfused reference
            if cfg.fusion {
                assert!(cost.latency_ms < cm.refs.latency_ms);
            } else {
                assert_eq!(cost.latency_ms, cm.refs.latency_ms);
            }
        }
        assert!(cm.cost(12).is_err());
    }

    #[test]
    fn device_cost_model_prices_fp32_layers() {
        let model = synthetic_model(8, 4, 4, 3).unwrap();
        let space = crate::quant::general_space();
        let dev = &super::super::DEVICES[1]; // i7: naive int8 slower than fp32
        let cm = CostModel::build(&model, space.as_ref(), dev, 100.0).unwrap();
        assert_eq!(cm.len(), crate::quant::QuantConfig::SPACE_SIZE);
        for i in 0..space.size() {
            let plan = space.plan(i).unwrap();
            let cost = cm.cost(i).unwrap();
            if plan.base.mixed {
                // mixed precision keeps first+last fp32: cheaper latency
                // on naive-int8 CPUs, bigger serialized size than the
                // same config without the bypass
                let int8_twin = crate::quant::QuantConfig {
                    mixed: false,
                    ..plan.base
                };
                let base = cm.cost(int8_twin.index()).unwrap();
                assert!(cost.latency_ms < base.latency_ms, "config {i}");
                assert!(cost.size_bytes > base.size_bytes, "config {i}");
            }
            assert!(cost.size_bytes < cm.refs.size_bytes, "int8 must shrink");
        }
    }

    #[test]
    fn budget_admission_and_feasible_count() {
        let cheap = ConfigCost { latency_ms: 1.0, size_bytes: 100.0 };
        let dear = ConfigCost { latency_ms: 20.0, size_bytes: 4000.0 };
        assert!(Budget::unlimited().admits(dear));
        assert!(!Budget::unlimited().is_limited());
        let lat = Budget { max_latency_ms: Some(5.0), max_size_bytes: None };
        assert!(lat.is_limited() && lat.admits(cheap) && !lat.admits(dear));
        let both =
            Budget { max_latency_ms: Some(5.0), max_size_bytes: Some(50.0) };
        assert!(!both.admits(cheap), "size axis must also bind");
        // NaN costs never fit a constrained axis
        let nan = ConfigCost { latency_ms: f64::NAN, size_bytes: 1.0 };
        assert!(!lat.admits(nan));
        assert!(Budget::unlimited().admits(nan), "unconstrained axes ignore NaN");
        assert_eq!(both.slug(), "lat<=5ms,bytes<=50");
        assert_eq!(Budget::unlimited().slug(), "unlimited");

        // feasible_count over a real cost table: tightening the latency
        // budget below the fused VTA cycle time keeps only fused configs
        let model = synthetic_model(8, 4, 4, 3).unwrap();
        let space = vta_space();
        let cm = CostModel::build(&model, space.as_ref(), &super::super::DEVICES[1], 100.0)
            .unwrap();
        assert_eq!(cm.feasible_count(&Budget::unlimited()), 12);
        let fused_ms = (0..12)
            .map(|i| cm.cost(i).unwrap().latency_ms)
            .fold(f64::INFINITY, f64::min);
        let tight = Budget {
            max_latency_ms: Some(fused_ms),
            max_size_bytes: None,
        };
        assert_eq!(cm.feasible_count(&tight), 6, "half the space is fused");
        let impossible = Budget {
            max_latency_ms: Some(fused_ms / 2.0),
            max_size_bytes: None,
        };
        assert_eq!(cm.feasible_count(&impossible), 0);
    }

    #[test]
    fn scalarization_trades_accuracy_against_cost() {
        let w = ObjectiveWeights::parse("balanced").unwrap();
        let refs = CostRefs { latency_ms: 10.0, size_bytes: 1000.0 };
        let cheap = ConfigCost { latency_ms: 5.0, size_bytes: 250.0 };
        let dear = ConfigCost { latency_ms: 20.0, size_bytes: 1000.0 };
        // equal accuracy: the cheaper deployment must score higher
        assert!(w.score(0.7, cheap, &refs) > w.score(0.7, dear, &refs));
        // a big enough accuracy edge outweighs the cost gap
        assert!(w.score(0.95, dear, &refs) > w.score(0.2, cheap, &refs));
        // NaN accuracy propagates instead of masquerading as a number
        assert!(w.score(f64::NAN, cheap, &refs).is_nan());
        // accuracy-only ignores cost entirely
        let a = ObjectiveWeights::accuracy_only();
        assert_eq!(a.score(0.5, dear, &refs), 0.5);
    }
}
