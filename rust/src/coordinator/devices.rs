//! Analytical device cost model (paper Table 2 + Fig 9 context).
//!
//! The paper measures accuracy-evaluation time on an ARM A53, an Intel
//! i7-8700, and an NVIDIA 2080 Ti. None of those are available here, so
//! we model per-image inference time from each device's effective
//! arithmetic throughput plus a per-layer dispatch overhead, calibrated
//! so the *ratios* between devices match the paper's Table 2 (a53 : i7 :
//! 2080ti measurement times of roughly 200 : 8 : 1 for the heavy models
//! and a larger overhead share for the small ones).
//!
//! Real wallclock numbers for Fig 9 come from actual PJRT / VTA-simulator
//! runs; this model only supplies the cross-device scaling story.

use crate::quant::BitWidth;

/// Effective single-stream inference characteristics of one target.
#[derive(Clone, Copy, Debug)]
pub struct DeviceProfile {
    /// Display name ("CPU(i7-8700)", ...).
    pub name: &'static str,
    /// effective GFLOP/s sustained on conv workloads (fp32)
    pub gflops_fp32: f64,
    /// multiplier on fp32 throughput when running naive int8 kernels
    /// (the paper: quantized kernels are often *slower* because codegen
    /// does not use vmlal/VNNI/DP4A; values < 1 mean slowdown)
    pub int8_naive_factor: f64,
    /// fixed per-layer dispatch overhead (seconds)
    pub layer_overhead_s: f64,
}

/// The paper's three measurement targets.
pub const DEVICES: [DeviceProfile; 3] = [
    DeviceProfile {
        name: "CPU(a53)",
        gflops_fp32: 4.0,
        int8_naive_factor: 0.75,
        layer_overhead_s: 120e-6,
    },
    DeviceProfile {
        name: "CPU(i7-8700)",
        gflops_fp32: 90.0,
        int8_naive_factor: 0.80,
        layer_overhead_s: 20e-6,
    },
    DeviceProfile {
        name: "GPU(2080ti)",
        gflops_fp32: 2600.0,
        int8_naive_factor: 1.10,
        layer_overhead_s: 35e-6,
    },
];

impl DeviceProfile {
    /// Short CLI keys for [`DEVICES`], in the same order.
    pub const KEYS: [&'static str; 3] = ["a53", "i7", "2080ti"];

    /// Look a profile up by its short CLI key (`a53` / `i7` / `2080ti`).
    pub fn by_key(key: &str) -> Option<&'static DeviceProfile> {
        Self::KEYS.iter().position(|&k| k == key).map(|i| &DEVICES[i])
    }

    /// The short CLI key of this profile.
    pub fn key(&self) -> &'static str {
        DEVICES
            .iter()
            .position(|d| d.name == self.name)
            .map(|i| Self::KEYS[i])
            .unwrap_or("custom")
    }

    /// Modeled fp32 per-image latency (seconds).
    pub fn fp32_latency_s(&self, macs: u64, layers: usize) -> f64 {
        2.0 * macs as f64 / (self.gflops_fp32 * 1e9) + layers as f64 * self.layer_overhead_s
    }

    /// Naive integer-kernel latency (seconds) at an explicit throughput
    /// factor; the shared body behind the per-width pricing.
    fn int_latency_at(&self, macs: u64, layers: usize, factor: f64) -> f64 {
        2.0 * macs as f64 / (self.gflops_fp32 * factor * 1e9)
            + layers as f64 * self.layer_overhead_s * 1.4
    }

    /// Modeled naive-int8 per-image latency (seconds); includes the
    /// quantize/dequantize epilogues that make naive kernels slower.
    pub fn int8_latency_s(&self, macs: u64, layers: usize) -> f64 {
        self.int_latency_at(macs, layers, self.int8_naive_factor)
    }

    /// Throughput multiplier of a naive integer kernel at `width`,
    /// relative to fp32. Anchored at [`DeviceProfile::int8_naive_factor`]
    /// and scaled by `sqrt(8 / bits)`: narrower grids move half as many
    /// bytes per MAC (memory-bound win) but pay unpack/requantize cost,
    /// so int4 is modestly faster than int8 and int16 modestly slower --
    /// the MACs themselves run on the same ALUs either way. fp32 is 1.0
    /// by definition.
    pub fn width_factor(&self, width: BitWidth) -> f64 {
        match width {
            BitWidth::Fp32 => 1.0,
            w => self.int8_naive_factor * (8.0 / w.bits() as f64).sqrt(),
        }
    }

    /// Per-image latency (milliseconds) of a mixed-precision deployment:
    /// layer `i` of `layer_macs` runs at `widths[i]` (fp32 layers take
    /// the fp32 path, integer layers the naive kernel at that width's
    /// [`DeviceProfile::width_factor`]). With an all-fp32 vector this
    /// sums to exactly [`DeviceProfile::fp32_latency_s`] of the summed
    /// MACs; with an all-int8 vector, to
    /// [`DeviceProfile::int8_latency_s`].
    pub fn widths_latency_ms(&self, layer_macs: &[u64], widths: &[BitWidth]) -> f64 {
        let s: f64 = layer_macs
            .iter()
            .enumerate()
            .map(|(i, &macs)| {
                match widths.get(i).copied().unwrap_or(BitWidth::Int8) {
                    BitWidth::Fp32 => self.fp32_latency_s(macs, 1),
                    w => self.int_latency_at(macs, 1, self.width_factor(w)),
                }
            })
            .sum();
        s * 1e3
    }

    /// Per-image latency (milliseconds) of a binary {int8, fp32}
    /// deployment: layer `i` runs in fp32 when `fp32_mask[i]`, naive
    /// int8 otherwise (the width-vector form is
    /// [`DeviceProfile::widths_latency_ms`]).
    pub fn masked_latency_ms(&self, layer_macs: &[u64], fp32_mask: &[bool]) -> f64 {
        let widths: Vec<BitWidth> = (0..layer_macs.len())
            .map(|i| {
                if fp32_mask.get(i).copied().unwrap_or(false) {
                    BitWidth::Fp32
                } else {
                    BitWidth::Int8
                }
            })
            .collect();
        self.widths_latency_ms(layer_macs, &widths)
    }

    /// Modeled time to measure Top-1 over `images` images (Table 2),
    /// in hours.
    pub fn accuracy_measurement_hours(
        &self,
        macs: u64,
        layers: usize,
        images: usize,
    ) -> f64 {
        self.fp32_latency_s(macs, layers) * images as f64 / 3600.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn device_ordering_matches_paper() {
        // for a mid-size model the a53 must be slowest and the GPU fastest
        let macs = 2_000_000_000u64; // ~ResNet18-class
        let layers = 40;
        let t: Vec<f64> =
            DEVICES.iter().map(|d| d.fp32_latency_s(macs, layers)).collect();
        assert!(t[0] > 10.0 * t[1], "a53 {} vs i7 {}", t[0], t[1]);
        assert!(t[1] > 3.0 * t[2], "i7 {} vs gpu {}", t[1], t[2]);
    }

    #[test]
    fn naive_int8_slower_on_cpus_faster_on_gpu() {
        let macs = 500_000_000u64;
        let layers = 30;
        for d in &DEVICES[..2] {
            assert!(d.int8_latency_s(macs, layers) > d.fp32_latency_s(macs, layers));
        }
        // dp4a gives the GPU a small win on compute-bound models
        let gpu = DEVICES[2];
        let big = 20_000_000_000u64;
        assert!(gpu.int8_latency_s(big, layers) < gpu.fp32_latency_s(big, layers));
    }

    #[test]
    fn masked_latency_interpolates_between_the_pure_paths() {
        let macs = [400_000_000u64, 900_000_000, 30_000_000];
        let total: u64 = macs.iter().sum();
        for d in &DEVICES {
            let all_fp32 = d.masked_latency_ms(&macs, &[true; 3]);
            let all_int8 = d.masked_latency_ms(&macs, &[false; 3]);
            assert!((all_fp32 - d.fp32_latency_s(total, 3) * 1e3).abs() < 1e-9);
            assert!((all_int8 - d.int8_latency_s(total, 3) * 1e3).abs() < 1e-9);
            let mixed = d.masked_latency_ms(&macs, &[false, true, false]);
            let (lo, hi) = if all_fp32 < all_int8 {
                (all_fp32, all_int8)
            } else {
                (all_int8, all_fp32)
            };
            assert!(mixed >= lo && mixed <= hi, "{}: {mixed} vs [{lo}, {hi}]", d.name);
        }
    }

    #[test]
    fn width_pricing_is_monotone_in_bits() {
        let macs = [400_000_000u64, 900_000_000, 30_000_000];
        for d in &DEVICES {
            // narrower integer grids are faster: int4 < int8 < int16
            let t4 = d.widths_latency_ms(&macs, &[BitWidth::Int4; 3]);
            let t8 = d.widths_latency_ms(&macs, &[BitWidth::Int8; 3]);
            let t16 = d.widths_latency_ms(&macs, &[BitWidth::Int16; 3]);
            assert!(t4 < t8 && t8 < t16, "{}: {t4} {t8} {t16}", d.name);
            // the all-int8 vector reproduces the legacy mask pricing
            assert_eq!(t8, d.masked_latency_ms(&macs, &[false; 3]));
            let all_fp32 = d.widths_latency_ms(&macs, &[BitWidth::Fp32; 3]);
            assert_eq!(all_fp32, d.masked_latency_ms(&macs, &[true; 3]));
            // a mixed vector lands strictly between its extremes
            let mix = d.widths_latency_ms(
                &macs,
                &[BitWidth::Int4, BitWidth::Fp32, BitWidth::Int16],
            );
            assert!(mix > t4.min(all_fp32) && mix < t16.max(all_fp32), "{}", d.name);
            assert_eq!(d.width_factor(BitWidth::Fp32), 1.0);
        }
    }

    #[test]
    fn device_lookup_by_key() {
        assert_eq!(DeviceProfile::by_key("a53").unwrap().name, "CPU(a53)");
        assert_eq!(DeviceProfile::by_key("i7").unwrap().name, "CPU(i7-8700)");
        assert_eq!(DeviceProfile::by_key("2080ti").unwrap().name, "GPU(2080ti)");
        assert!(DeviceProfile::by_key("m1").is_none());
        for d in &DEVICES {
            assert_eq!(DeviceProfile::by_key(d.key()).unwrap().name, d.name);
        }
    }

    #[test]
    fn small_models_are_overhead_dominated() {
        // the paper's SQN takes 0.03h on GPU vs GN 0.58h -- overhead, not
        // FLOPs, dominates tiny models
        let gpu = DEVICES[2];
        let small = gpu.fp32_latency_s(5_000_000, 20);
        let overhead = 20.0 * gpu.layer_overhead_s;
        assert!(overhead / small > 0.5);
    }
}
