//! Turns (model, calibration cache, QuantPlan) into the concrete
//! quantization artifacts the evaluators consume: the activation
//! parameter rows and the fake-quantized weight set.
//!
//! This is the rust side of the paper's `g(e, s)` -- the Glow-extension
//! model generator of Eq. 14. A [`QuantPlan`] is the decoded form of one
//! point of any [`crate::quant::ConfigSpace`]: the base axes plus a
//! per-layer [`BitWidth`] vector (the general space derives its widths
//! from the `mixed` bit; the layer-wise space supplies an arbitrary
//! int4/int8/int16/fp32 assignment).
//!
//! Weight preparation is memoized in a [`WeightCache`]: calibration count
//! and clipping policy only shape *activation* ranges, so a sweep reuses
//! at most one fake-quantized tensor per (layer, scheme, granularity,
//! bit-width), one corrected bias per quantized grid (the `bias_correct`
//! axis; corrected and uncorrected variants coexist under distinct
//! [`WeightVariant`] keys), plus one fp32 passthrough per tensor. Configs
//! that share a layer's setting skip requantization entirely, and the
//! cache is interior-mutable so the parallel sweep's workers share it.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::calib::CalibrationCache;
use crate::interp::PreparedWeight;
use crate::ir::{Op, Tensor};
use crate::quant::{
    correct_bias, fake_quant_weights_at, quantize_weights_int, ActQuantization,
    BitWidth, Granularity, QuantPlan, Scheme,
};
use crate::zoo::ZooModel;

/// Everything needed to evaluate one quantized model variant.
pub struct QuantizedSetup {
    /// Activation quantization rows for every quant point.
    pub aq: ActQuantization,
    /// weights in ABI order (fake-quantized at each layer's width,
    /// except fp32 layers); `Arc`d so cache hits share storage instead
    /// of copying tensors
    pub weights: Vec<Arc<Tensor>>,
    /// Prepacked true-integer weights for the interpreter's integer
    /// fast path, keyed by *layer* name: present for every int4/int8
    /// `_w` tensor (the widths the packed kernels cover), absent for
    /// fp32/int16 layers, which stay on the f32 fake-quant route. The
    /// GEMM panels are packed once here and `Arc`-shared across every
    /// evaluation of the sweep — steady-state forwards never pack.
    pub int_weights: HashMap<String, Arc<PreparedWeight>>,
    /// The plan this setup realizes.
    pub plan: QuantPlan,
}

/// How one weight tensor is prepared for evaluation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum WeightVariant {
    /// fp32 passthrough (biases; fp32-width layers)
    Fp32,
    /// fake-quantized onto the (scheme, granularity, width) grid
    Quant(Scheme, Granularity, BitWidth),
    /// bias with the per-channel weight quantization error of the named
    /// layer's (scheme, granularity, width) grid folded in (the
    /// `bias_correct` axis). The correction depends only on the weight
    /// tensor and its grid -- no calibration statistics -- so the key
    /// carries exactly the grid, and corrected and uncorrected variants
    /// of the same bias coexist in one cache.
    CorrectedBias(Scheme, Granularity, BitWidth),
}

/// Cache of prepared weight tensors keyed by (weight name, variant).
/// Fake-quantized f32 tensors and their true-integer counterparts are
/// cached separately: fp32 passthroughs have no integer form, and a
/// mixed sweep may hit one map without the other.
#[derive(Default)]
pub struct WeightCache {
    cached: Mutex<HashMap<(String, WeightVariant), Arc<Tensor>>>,
    cached_int: Mutex<HashMap<(String, WeightVariant), Arc<PreparedWeight>>>,
    int_hits: AtomicU64,
    int_builds: AtomicU64,
}

impl WeightCache {
    /// An empty cache.
    pub fn new() -> WeightCache {
        WeightCache::default()
    }

    /// Number of distinct prepared tensors held.
    pub fn entries(&self) -> usize {
        self.cached.lock().unwrap().len()
    }

    /// Number of distinct true-integer weights held.
    pub fn int_entries(&self) -> usize {
        self.cached_int.lock().unwrap().len()
    }

    /// (hits, builds) of the prepacked-weight cache: how many integer
    /// lookups reused an existing panel set vs packed a new one.
    pub fn int_cache_stats(&self) -> (u64, u64) {
        (self.int_hits.load(Ordering::Relaxed), self.int_builds.load(Ordering::Relaxed))
    }

    fn get_or_build(
        &self,
        name: &str,
        variant: WeightVariant,
        build: impl FnOnce() -> Tensor,
    ) -> Arc<Tensor> {
        if let Some(t) = self.cached.lock().unwrap().get(&(name.to_string(), variant)) {
            return t.clone();
        }
        // build outside the lock so concurrent workers never serialize on
        // the quantization math; racers produce identical tensors (the
        // build is deterministic) and the first insert wins
        let built = Arc::new(build());
        self.cached
            .lock()
            .unwrap()
            .entry((name.to_string(), variant))
            .or_insert(built)
            .clone()
    }

    fn get_or_build_int(
        &self,
        name: &str,
        variant: WeightVariant,
        build: impl FnOnce() -> PreparedWeight,
    ) -> Arc<PreparedWeight> {
        if let Some(q) =
            self.cached_int.lock().unwrap().get(&(name.to_string(), variant))
        {
            self.int_hits.fetch_add(1, Ordering::Relaxed);
            return q.clone();
        }
        // same first-insert-wins protocol as get_or_build
        self.int_builds.fetch_add(1, Ordering::Relaxed);
        let built = Arc::new(build());
        self.cached_int
            .lock()
            .unwrap()
            .entry((name.to_string(), variant))
            .or_insert(built)
            .clone()
    }
}

/// Quant-point bypass rows for an arbitrary per-layer precision
/// assignment (`widths` follows `graph.layers()` order): each fp32
/// layer's output quant point stays fp32, and the network input does too
/// when the first weighted layer is fp32 (the input row feeds that
/// layer). Integer widths (int4/int8/int16) keep their activations on
/// the int8 grid -- the radix search is weight-only mixed precision, as
/// in Banner et al.'s post-training 4-bit setting.
pub fn layer_precision_overrides(model: &ZooModel, widths: &[BitWidth]) -> Vec<bool> {
    let qpoints = model.graph.quant_points();
    let layers = model.graph.layers();
    let fp32: std::collections::HashSet<&str> = layers
        .iter()
        .zip(widths)
        .filter(|(_, w)| w.is_float())
        .map(|(l, _)| l.as_str())
        .collect();
    let first_fp32 = widths.first().copied().is_some_and(BitWidth::is_float);
    qpoints
        .iter()
        .map(|q| (q == "input" && first_fp32) || fp32.contains(q.as_str()))
        .collect()
}

/// Quant-point bypass rows for the paper's §4.5 mixed precision: the
/// network input, the first weighted layer's output, and the last
/// weighted layer's output stay fp32.
pub fn mixed_precision_bypass(model: &ZooModel, mixed: bool) -> Vec<bool> {
    let n = model.graph.layers().len();
    let widths: Vec<BitWidth> = (0..n)
        .map(|i| {
            if mixed && (i == 0 || i == n.saturating_sub(1)) {
                BitWidth::Fp32
            } else {
                BitWidth::Int8
            }
        })
        .collect();
    layer_precision_overrides(model, &widths)
}

/// Build the evaluation setup for one plan, reusing prepared weights
/// from `wcache` when a previous config shared the layer setting.
pub fn prepare_cached(
    model: &ZooModel,
    cache: &CalibrationCache,
    plan: &QuantPlan,
    wcache: &WeightCache,
) -> Result<QuantizedSetup> {
    anyhow::ensure!(cache.model == model.name, "calibration cache model mismatch");
    let layers = model.graph.layers();
    let widths = plan.resolve_widths(layers.len())?;
    let bypass = layer_precision_overrides(model, &widths);
    let aq = ActQuantization::from_histograms(
        &cache.hists,
        plan.base.scheme,
        plan.base.clip,
        &bypass,
    )?;

    let layer_pos: HashMap<&str, usize> =
        layers.iter().enumerate().map(|(i, l)| (l.as_str(), i)).collect();
    let mut weights = Vec::new();
    let mut int_weights = HashMap::new();
    for name in &model.weights.order {
        let t = model.weights.get(name)?;
        let layer = name.trim_end_matches("_w").trim_end_matches("_b");
        let width = layer_pos
            .get(layer)
            .map_or(BitWidth::Fp32, |&i| widths[i]);
        // biases stay fp32 in the fake-quant evaluation (they are int32
        // at accumulator scale on true integer hardware, which the VTA
        // path models exactly) -- but under the bias_correct axis a
        // quantized layer's bias absorbs the per-channel weight rounding
        // error of its grid (still fp32-valued)
        let wname = format!("{layer}_w");
        let variant = if name.ends_with("_w") && !width.is_float() {
            WeightVariant::Quant(plan.base.scheme, plan.base.gran, width)
        } else if name.ends_with("_b")
            && plan.base.bias_correct
            && !width.is_float()
            && model.weights.get(&wname).is_ok()
        {
            WeightVariant::CorrectedBias(plan.base.scheme, plan.base.gran, width)
        } else {
            WeightVariant::Fp32
        };
        weights.push(wcache.get_or_build(name, variant, || match variant {
            WeightVariant::Quant(scheme, gran, width) => {
                fake_quant_weights_at(t, scheme, gran, width)
            }
            WeightVariant::CorrectedBias(scheme, gran, width) => {
                let w = model.weights.get(&wname).expect("checked above");
                correct_bias(t, w, scheme, gran, width)
            }
            WeightVariant::Fp32 => t.clone(),
        }));
        // int4/int8 layers additionally get a true-integer weight,
        // prepacked into GEMM panels per group, so the interpreter can
        // run them on the packed kernels without per-call packing; it
        // shares the fake-quant tensor's grid exactly (same params), so
        // both routes see identical quantized values
        if let WeightVariant::Quant(scheme, gran, width) = variant {
            if matches!(width, BitWidth::Int4 | BitWidth::Int8) {
                let groups = match model.graph.node(layer).map(|n| &n.op) {
                    Some(Op::Conv { groups, .. }) => *groups,
                    _ => 1,
                };
                let pw = wcache.get_or_build_int(name, variant, || {
                    let qw = quantize_weights_int(t, scheme, gran, width)
                        .expect("int4/int8 widths always quantize");
                    PreparedWeight::pack(qw, groups)
                        .expect("layer weights always pack for their groups")
                });
                int_weights.insert(layer.to_string(), pw);
            }
        }
    }
    Ok(QuantizedSetup { aq, weights, int_weights, plan: plan.clone() })
}

/// Build the evaluation setup for one plan (uncached form).
pub fn prepare(
    model: &ZooModel,
    cache: &CalibrationCache,
    plan: &QuantPlan,
) -> Result<QuantizedSetup> {
    prepare_cached(model, cache, plan, &WeightCache::new())
}

/// The act_params tensor ([L, 5]) for a setup.
pub fn act_params_tensor(setup: &QuantizedSetup) -> Tensor {
    let rows = setup.aq.rows.len();
    Tensor { shape: vec![rows, 5], data: setup.aq.flat() }
}

#[cfg(test)]
mod tests {
    use super::*;

    // integration-level tests live in rust/tests; here we only cover the
    // pieces that need no artifacts
    #[test]
    fn bypass_arity_matches_quant_points() {
        // see rust/tests/integration.rs::mixed_precision_bypass_rows for
        // the artifact-backed version of this test
    }

    #[test]
    fn weight_cache_shares_entries() {
        let wcache = WeightCache::new();
        let build_count = std::sync::atomic::AtomicUsize::new(0);
        let build = || {
            build_count.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            Tensor { shape: vec![2], data: vec![1.0, 2.0] }
        };
        let variant =
            WeightVariant::Quant(Scheme::Symmetric, Granularity::Tensor, BitWidth::Int8);
        let a = wcache.get_or_build("l1_w", variant, build);
        let b = wcache.get_or_build("l1_w", variant, build);
        assert!(Arc::ptr_eq(&a, &b), "second lookup must hit the cache");
        assert_eq!(build_count.load(std::sync::atomic::Ordering::Relaxed), 1);
        // a different variant of the same tensor is a distinct entry
        let c = wcache.get_or_build(
            "l1_w",
            WeightVariant::Quant(Scheme::Pow2, Granularity::Tensor, BitWidth::Int8),
            build,
        );
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(wcache.entries(), 2);
        // ...and so is the same scheme at a different bit-width
        let d = wcache.get_or_build(
            "l1_w",
            WeightVariant::Quant(Scheme::Symmetric, Granularity::Tensor, BitWidth::Int4),
            build,
        );
        assert!(!Arc::ptr_eq(&a, &d));
        assert_eq!(wcache.entries(), 3);
    }

    #[test]
    fn corrected_and_plain_bias_coexist() {
        // the bias_correct axis must never evict or alias the fp32 bias:
        // the corrected variant is a distinct cache key
        let wcache = WeightCache::new();
        let b = Tensor { shape: vec![2], data: vec![0.5, -0.5] };
        let plain = wcache.get_or_build("l1_b", WeightVariant::Fp32, || b.clone());
        let corrected = wcache.get_or_build(
            "l1_b",
            WeightVariant::CorrectedBias(
                Scheme::Symmetric,
                Granularity::Tensor,
                BitWidth::Int4,
            ),
            || Tensor { shape: vec![2], data: vec![0.625, -0.375] },
        );
        assert!(!Arc::ptr_eq(&plain, &corrected));
        assert_eq!(wcache.entries(), 2);
        // a second corrected lookup on the same grid hits the cache
        let again = wcache.get_or_build(
            "l1_b",
            WeightVariant::CorrectedBias(
                Scheme::Symmetric,
                Granularity::Tensor,
                BitWidth::Int4,
            ),
            || unreachable!("must hit the cache"),
        );
        assert!(Arc::ptr_eq(&corrected, &again));
    }

    #[test]
    fn int_weight_cache_shares_entries() {
        let wcache = WeightCache::new();
        let t = Tensor { shape: vec![4], data: vec![-1.0, -0.25, 0.5, 1.0] };
        let variant =
            WeightVariant::Quant(Scheme::Symmetric, Granularity::Tensor, BitWidth::Int8);
        let build = || {
            let qw = quantize_weights_int(
                &t,
                Scheme::Symmetric,
                Granularity::Tensor,
                BitWidth::Int8,
            )
            .unwrap();
            PreparedWeight::pack(qw, 1).unwrap()
        };
        let a = wcache.get_or_build_int("l1_w", variant, build);
        let b = wcache.get_or_build_int("l1_w", variant, build);
        assert!(Arc::ptr_eq(&a, &b), "second lookup must hit the int cache");
        assert_eq!(wcache.int_entries(), 1);
        // the integer map is independent of the f32 map
        assert_eq!(wcache.entries(), 0);
        // the prepack tallies saw one build and one reuse
        assert_eq!(wcache.int_cache_stats(), (1, 1));
    }
}
