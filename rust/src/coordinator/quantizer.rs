//! Turns (model, calibration cache, QuantConfig) into the concrete
//! quantization artifacts the evaluators consume: the activation
//! parameter rows and the fake-quantized weight set.
//!
//! This is the rust side of the paper's `g(e, s)` -- the Glow-extension
//! model generator of Eq. 14.

use anyhow::Result;

use crate::calib::CalibrationCache;
use crate::ir::Tensor;
use crate::quant::{fake_quant_weights, ActQuantization, QuantConfig};
use crate::zoo::ZooModel;

/// Everything needed to evaluate one quantized model variant.
pub struct QuantizedSetup {
    pub aq: ActQuantization,
    /// weights in ABI order (fake-quantized, except fp32 mixed layers)
    pub weights: Vec<Tensor>,
    pub config: QuantConfig,
}

/// Quant-point bypass rows for mixed precision: the network input (which
/// feeds the first layer), the first weighted layer's output, and the
/// last weighted layer's output stay fp32 (paper §4.5).
pub fn mixed_precision_bypass(model: &ZooModel, mixed: bool) -> Vec<bool> {
    let qpoints = model.graph.quant_points();
    let mut bypass = vec![false; qpoints.len()];
    if !mixed {
        return bypass;
    }
    let layers = model.graph.layers();
    let first = layers.first().cloned().unwrap_or_default();
    let last = layers.last().cloned().unwrap_or_default();
    for (i, q) in qpoints.iter().enumerate() {
        if q == "input" || *q == first || *q == last {
            bypass[i] = true;
        }
    }
    bypass
}

/// Build the evaluation setup for one configuration.
pub fn prepare(
    model: &ZooModel,
    cache: &CalibrationCache,
    cfg: &QuantConfig,
) -> Result<QuantizedSetup> {
    anyhow::ensure!(cache.model == model.name, "calibration cache model mismatch");
    let bypass = mixed_precision_bypass(model, cfg.mixed);
    let aq =
        ActQuantization::from_histograms(&cache.hists, cfg.scheme, cfg.clip, &bypass)?;

    let layers = model.graph.layers();
    let first = layers.first().cloned().unwrap_or_default();
    let last = layers.last().cloned().unwrap_or_default();
    let mut weights = Vec::new();
    for name in &model.weights.order {
        let t = model.weights.get(name)?;
        let layer = name.trim_end_matches("_w").trim_end_matches("_b");
        let keep_fp32 = cfg.mixed && (layer == first || layer == last);
        if name.ends_with("_w") && !keep_fp32 {
            weights.push(fake_quant_weights(t, cfg.scheme, cfg.gran));
        } else {
            // biases stay fp32 in the fake-quant evaluation (they are
            // int32 at accumulator scale on true integer hardware, which
            // the VTA path models exactly)
            weights.push(t.clone());
        }
    }
    Ok(QuantizedSetup { aq, weights, config: *cfg })
}

/// The act_params tensor ([L, 5]) for a setup.
pub fn act_params_tensor(setup: &QuantizedSetup) -> Tensor {
    let rows = setup.aq.rows.len();
    Tensor { shape: vec![rows, 5], data: setup.aq.flat() }
}

#[cfg(test)]
mod tests {
    use super::*;

    // integration-level tests live in rust/tests; here we only cover the
    // bypass-row logic which needs no artifacts
    #[test]
    fn bypass_arity_matches_quant_points() {
        // see rust/tests/integration.rs::mixed_precision_bypass_rows for
        // the artifact-backed version of this test
    }
}
