//! The Quantune coordinator: the paper's auto-tuner (Fig 4, Algorithm 1)
//! plus the experiment drivers that regenerate its tables and figures.
//!
//! This is the L3 layer: it owns artifact loading, calibration, the
//! search loop, the trial database `D`, and accuracy measurement through
//! the PJRT runtime / interpreter / VTA simulator backends. Python never
//! appears here -- the HLO artifacts are self-contained.
//!
//! Everything is generic over a [`ConfigSpace`]: the same sweep, search,
//! transfer-learning, and database plumbing drives the 288-element
//! general space, the 12-element VTA space, and per-model layer-wise
//! mixed-precision spaces (`Quantune::layerwise_space`). It is also
//! generic over the objective: [`objective`] scalarizes (Top-1, modeled
//! latency, serialized bytes) so `Quantune::search_objective` tunes
//! deployment trade-offs through the identical driver.

pub mod database;
pub mod devices;
pub mod evaluator;
pub mod objective;
pub mod quantizer;
pub mod store;

pub use database::{Database, Record, GENERAL_SPACE_TAG};
pub use store::{
    records_equal, write_atomic, LogStore, RecordIndex, Store, StoreWriter, TransferCursor,
    TrialStore,
};
pub use devices::{DeviceProfile, DEVICES};
pub use evaluator::{
    Evaluator, HloEvaluator, InterpEvaluator, ObjectiveEvaluator, OracleEvaluator,
    SharedEvaluator,
};
pub use objective::{Budget, ConfigCost, CostModel, ObjectiveWeights, OBJECTIVES};
pub use quantizer::{
    act_params_tensor, layer_precision_overrides, mixed_precision_bypass, prepare,
    prepare_cached, QuantizedSetup, WeightCache, WeightVariant,
};

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::calib::{calibrate, CalibBackend};
use crate::data::Dataset;
use crate::quant::{BitWidth, ConfigSpace, LayerwiseSpace, QuantConfig, SpaceRef};
use crate::search::{
    run_racing, run_search, GeneticSearch, GridSearch, ParetoSearch, ParetoTrace,
    RacingOptions, RandomSearch, SearchAlgo, SearchTrace, TransferRecord, XgbSearch,
};
use crate::util::pool::Pool;
use crate::util::Timer;
use crate::zoo::{self, ZooModel};

/// The proposer algorithms [`make_algorithm`] can construct: the
/// paper's five (Fig 5/6) plus the NSGA-II Pareto-front search
/// (`nsga2`, see [`crate::search::ParetoSearch`]). Iterate this, not
/// [`ALGORITHMS`], when every name must build a [`SearchAlgo`].
pub const PROPOSERS: [&str; 6] = ["random", "grid", "genetic", "xgb", "xgb_t", "nsga2"];

/// Every CLI algorithm name: the [`PROPOSERS`] plus the multi-fidelity
/// racing scheduler (`sh`, successive halving over random proposals --
/// see [`crate::search::SuccessiveHalving`] and rust/SEARCH.md), which
/// is a scheduler wrapping a proposer rather than a proposer itself.
pub const ALGORITHMS: [&str; 7] = ["random", "grid", "genetic", "xgb", "xgb_t", "nsga2", "sh"];

/// Feature vector of (model, config): arch blocks `e` ++ the space's
/// config features `s` (paper §5.1; 10 + 13 = 23 dims for the general
/// space).
pub fn features_for(
    model: &ZooModel,
    space: &dyn ConfigSpace,
    config: usize,
) -> Result<Vec<f32>> {
    let mut f = model.arch_features();
    f.extend(space.features(config)?);
    Ok(f)
}

/// Feature vectors for the whole space of one model.
pub fn space_features(model: &ZooModel, space: &dyn ConfigSpace) -> Result<Vec<Vec<f32>>> {
    (0..space.size()).map(|i| features_for(model, space, i)).collect()
}

/// Construct a search algorithm by name over `space`. `transfer` is only
/// consumed by `xgb_t` (the paper's XGB+transfer-learning variant).
pub fn make_algorithm(
    name: &str,
    model: &ZooModel,
    space: &SpaceRef,
    transfer: Vec<TransferRecord>,
    seed: u64,
) -> Result<Box<dyn SearchAlgo>> {
    Ok(match name {
        "random" => Box::new(RandomSearch::new(space.size(), seed)),
        "grid" => Box::new(GridSearch::new(space.size(), seed)),
        "genetic" => Box::new(GeneticSearch::new(space.clone(), seed)),
        "xgb" => Box::new(XgbSearch::new(space_features(model, space.as_ref())?, seed)),
        "xgb_t" => Box::new(XgbSearch::with_transfer(
            space_features(model, space.as_ref())?,
            transfer,
            seed,
        )),
        "nsga2" => Box::new(ParetoSearch::new(space.clone(), seed)),
        "sh" => anyhow::bail!(
            "\"sh\" is a racing scheduler, not a proposer -- run it through \
             Quantune::search_racing / run_racing (CLI: `search --algo sh`)"
        ),
        other => anyhow::bail!("unknown algorithm {other:?} (try {ALGORITHMS:?})"),
    })
}

/// Holds the shared experiment state: artifacts dir, datasets, trial
/// store, and the deployment device the latency-aware objective prices
/// against.
pub struct Quantune {
    /// Artifacts directory (HLO files, datasets, trial store).
    pub artifacts: PathBuf,
    /// Calibration image pool.
    pub calib_pool: Dataset,
    /// Held-out eval split.
    pub eval: Dataset,
    /// The trial store holding the database `D` (backend auto-detected
    /// by [`Store::open`]: segmented log or legacy JSON).
    pub db: Store,
    /// Seed for calibration draws and searches.
    pub seed: u64,
    /// Deploy target for modeled latency (general / layer-wise spaces;
    /// the VTA space always prices by cycle counts). Default: i7-8700.
    pub device: DeviceProfile,
    /// Warm-start GA / NSGA-II populations from the store's best-known
    /// configs for (model, space) instead of fully random init
    /// (`--seed-from-db`). Falls back to random when the store holds
    /// nothing for the pair.
    pub seed_from_db: bool,
}

impl Quantune {
    /// Open an artifacts directory (created by `make artifacts`).
    pub fn open(artifacts: PathBuf) -> Result<Quantune> {
        let calib_pool = Dataset::load(&artifacts.join("dataset_calib.qtd"))
            .context("calibration pool (run `make artifacts`)")?;
        let eval = Dataset::load(&artifacts.join("dataset_eval.qtd"))?;
        let db = Store::open(&artifacts)?;
        Ok(Quantune {
            artifacts,
            calib_pool,
            eval,
            db,
            seed: 20220205,
            device: DEVICES[1],
            seed_from_db: false,
        })
    }

    /// A self-contained instance over the synthetic model's datasets and
    /// an in-memory store -- every search path works without artifact
    /// files (the CLI falls back to this so `quantune search` runs from
    /// a clean checkout).
    pub fn synthetic() -> Quantune {
        Quantune {
            artifacts: PathBuf::from("."),
            calib_pool: crate::data::synthetic_dataset(64, 8, 8, 4, 4, 5),
            eval: crate::data::synthetic_dataset(256, 8, 8, 4, 4, 6),
            db: Store::in_memory(),
            seed: 20220205,
            device: DEVICES[1],
            seed_from_db: false,
        }
    }

    /// The model `Quantune::synthetic()`'s datasets are shaped for.
    pub fn synthetic_model() -> Result<ZooModel> {
        zoo::synthetic_model(8, 4, 4, 3)
    }

    /// Load one zoo model from the artifacts directory.
    pub fn load_model(&self, name: &str) -> Result<ZooModel> {
        zoo::ZooModel::load(&self.artifacts, name)
    }

    /// Build the layer-wise mixed-precision space for `model` on top of
    /// `base`: calibrate through the interpreter, rank every weighted
    /// layer's quantization fragility, and free the top-`k` layers to
    /// each choose a weight bit-width from `widths` (fp32 is always
    /// available; pass [`crate::quant::BINARY_WIDTHS`] for the legacy
    /// {int8, fp32} mask space, or e.g. `[Int4, Int8, Int16]` for the
    /// full radix genome -- see [`crate::quant::max_layers_for`] for the
    /// `k` cap each menu implies).
    pub fn layerwise_space(
        &self,
        model: &ZooModel,
        base: QuantConfig,
        k: usize,
        widths: &[BitWidth],
    ) -> Result<SpaceRef> {
        let cache = calibrate(
            model,
            &self.calib_pool,
            base.calib,
            &CalibBackend::Interp,
            self.seed,
        )?;
        Ok(Arc::new(LayerwiseSpace::rank(
            &model.name,
            &model.graph,
            model.weights_map(),
            &cache.hists,
            base,
            k,
            widths,
        )?))
    }

    /// Exhaustive sweep of `space` for one model (Table 1 / Fig 2 ground
    /// truth for the general space). Results are persisted in the
    /// database under the space's tag; an existing full sweep is reused
    /// unless `force`.
    pub fn sweep(
        &mut self,
        model: &ZooModel,
        space: &dyn ConfigSpace,
        evaluator: &mut dyn Evaluator,
        force: bool,
        mut progress: impl FnMut(usize, f64),
    ) -> Result<Vec<f64>> {
        let tag = space.tag();
        let size = space.size();
        if !force && self.db.has_full_sweep(&model.name, &tag, size) {
            return Ok(self.db.accuracy_table(&model.name, &tag, size));
        }
        let cost = CostModel::build(model, space, &self.device, crate::vta::PYNQ_CLOCK_MHZ)?;
        let mut table = vec![f64::NAN; size];
        for (i, slot) in table.iter_mut().enumerate() {
            let t = Timer::start();
            let acc = evaluator.measure(i)?;
            *slot = acc;
            let c = cost.cost(i)?;
            self.db.add(Record {
                model: model.name.clone(),
                space: tag.clone(),
                config: i,
                accuracy: acc,
                measure_secs: t.secs(),
                latency_ms: Some(c.latency_ms),
                size_bytes: Some(c.size_bytes),
                device: Some(cost.target.clone()),
                fidelity: None,
            })?;
            progress(i, acc);
        }
        self.db.save()?;
        Ok(table)
    }

    /// Exhaustive sweep through a thread-safe evaluator: the configs fan
    /// out across `workers`, and completed trials stream through a
    /// [`StoreWriter`], which appends them durably in config order
    /// (0..size) as their slot's turn comes -- so the table and the
    /// persisted records are bit-identical to the serial
    /// [`Quantune::sweep`] at any thread count, and a crash mid-sweep
    /// loses only the trailing configs whose predecessors hadn't
    /// finished. On a measurement error, the durable prefix up to the
    /// first failed config is kept.
    ///
    /// `progress(done, acc)` is called from worker threads with the
    /// *completed-measurement count* (configs finish out of order, so
    /// unlike [`Quantune::sweep`] it does not receive the config index).
    pub fn sweep_parallel<E: SharedEvaluator + ?Sized>(
        &mut self,
        model: &ZooModel,
        space: &dyn ConfigSpace,
        evaluator: &E,
        force: bool,
        workers: &Pool,
        progress: impl Fn(usize, f64) + Sync,
    ) -> Result<Vec<f64>> {
        let tag = space.tag();
        let size = space.size();
        if !force && self.db.has_full_sweep(&model.name, &tag, size) {
            return Ok(self.db.accuracy_table(&model.name, &tag, size));
        }
        let cost = CostModel::build(model, space, &self.device, crate::vta::PYNQ_CLOCK_MHZ)?;
        let done = std::sync::atomic::AtomicUsize::new(0);
        let writer = self.db.writer();
        let measured = workers.run(size, |i| -> Result<f64> {
            let t = Timer::start();
            let acc = evaluator.measure_shared(i)?;
            let secs = t.secs();
            let c = cost.cost(i)?;
            writer.submit(
                i,
                Record {
                    model: model.name.clone(),
                    space: tag.clone(),
                    config: i,
                    accuracy: acc,
                    measure_secs: secs,
                    latency_ms: Some(c.latency_ms),
                    size_bytes: Some(c.size_bytes),
                    device: Some(cost.target.clone()),
                    fidelity: None,
                },
            )?;
            let n = done.fetch_add(1, std::sync::atomic::Ordering::Relaxed) + 1;
            progress(n, acc);
            Ok(acc)
        })?;
        let mut table = vec![f64::NAN; size];
        for (i, r) in measured.into_iter().enumerate() {
            table[i] = r?;
        }
        writer.finish()?;
        Ok(table)
    }

    /// Transfer records from every other model's trials in `space` (the
    /// database D, filtered to the space's tag so feature vectors stay
    /// compatible). One-shot: drains a [`TransferCursor`] from watermark
    /// 0, so it extracts exactly what the incremental path does.
    pub fn transfer_for(
        &self,
        target: &ZooModel,
        space: &dyn ConfigSpace,
    ) -> Result<Vec<TransferRecord>> {
        let mut cursor = self.transfer_cursor(target, space);
        self.refresh_transfer(&mut cursor, target, space)?;
        Ok(cursor.into_records())
    }

    /// A watermark cursor over `space` trials of every model except
    /// `target` -- feed it to [`Quantune::refresh_transfer`] between
    /// search generations for incremental XGB-T refits.
    pub fn transfer_cursor(&self, target: &ZooModel, space: &dyn ConfigSpace) -> TransferCursor {
        TransferCursor::new(target.name.clone(), space.tag())
    }

    /// Pull the records appended since the cursor's watermark into it
    /// (mapping each to the arch ++ space feature vector); returns how
    /// many transfer rows were added.
    pub fn refresh_transfer(
        &self,
        cursor: &mut TransferCursor,
        target: &ZooModel,
        space: &dyn ConfigSpace,
    ) -> Result<usize> {
        let mut feats: std::collections::HashMap<String, Vec<f32>> = Default::default();
        for name in zoo::MODELS {
            if name == target.name {
                continue;
            }
            if self.artifacts.join(format!("{name}_meta.json")).exists() {
                feats.insert(
                    name.to_string(),
                    self.load_model(name)?.arch_features(),
                );
            }
        }
        Ok(cursor.refresh(&self.db, |m, cfg| {
            let arch = feats.get(m)?;
            let mut f = arch.clone();
            f.extend(space.features(cfg).ok()?);
            Some(f)
        }))
    }

    /// Run one search algorithm over `space` against an evaluator
    /// (Algorithm 1 when the algorithm is xgb/xgb_t). The evaluator must
    /// measure indices of the same space (see `with_space`). `&self`:
    /// independent runs (algorithm x seed) may fan out across workers
    /// sharing one `Quantune`. Tunes plain Top-1 accuracy; see
    /// [`Quantune::search_objective`] for multi-objective tuning.
    ///
    /// # Examples
    ///
    /// Tune the self-contained synthetic model through the interpreter
    /// -- runs from a clean checkout, no artifact files needed:
    ///
    /// ```
    /// use quantune::coordinator::{InterpEvaluator, Quantune};
    /// use quantune::quant::general_space;
    ///
    /// # fn main() -> anyhow::Result<()> {
    /// let q = Quantune::synthetic();
    /// let model = Quantune::synthetic_model()?;
    /// let space = general_space();
    /// let mut ev = InterpEvaluator::new(&model, &q.calib_pool, &q.eval, q.seed)
    ///     .with_threads(1)
    ///     .with_space(space.clone());
    /// let trace = q.search(&model, &space, "random", &mut ev, 2, 7)?;
    /// assert_eq!(trace.trials.len(), 2);
    /// assert!(trace.best_config < space.size());
    /// # Ok(())
    /// # }
    /// ```
    pub fn search(
        &self,
        model: &ZooModel,
        space: &SpaceRef,
        algo_name: &str,
        evaluator: &mut dyn Evaluator,
        budget: usize,
        seed: u64,
    ) -> Result<SearchTrace> {
        let mut algo = self.make_algo(model, space, algo_name, seed)?;
        run_search(algo.as_mut(), budget, |cfg| evaluator.measure(cfg))
    }

    /// Multi-fidelity racing search: the same proposer algorithms,
    /// scheduled by successive halving ([`crate::search::SuccessiveHalving`]).
    /// Whole generations are ranked on a cheap fraction of the eval set
    /// and only the top `1/eta` survive to the next (larger) rung, so
    /// most configs are rejected at a fraction of the full measurement
    /// cost. `algo_name` `"sh"` means "the plain scheduler" (random
    /// proposals); any proposer except `nsga2` composes (`"xgb"` gives
    /// fidelity-aware XGB racing). `budget` counts *base-rung*
    /// proposals, so a racing run at budget B explores the same number
    /// of configs as a plain search at budget B -- at a fraction of the
    /// evaluation cost ([`SearchTrace::total_cost`] reports it in
    /// full-evaluation units).
    ///
    /// With `opts.fidelity_min == 1.0` the ladder collapses to a single
    /// full rung and the result is trial-for-trial bit-identical to
    /// [`Quantune::search`].
    ///
    /// # Examples
    ///
    /// ```
    /// use quantune::coordinator::{InterpEvaluator, Quantune};
    /// use quantune::quant::general_space;
    /// use quantune::search::RacingOptions;
    ///
    /// # fn main() -> anyhow::Result<()> {
    /// let q = Quantune::synthetic();
    /// let model = Quantune::synthetic_model()?;
    /// let space = general_space();
    /// let mut ev = InterpEvaluator::new(&model, &q.calib_pool, &q.eval, q.seed)
    ///     .with_threads(1)
    ///     .with_space(space.clone());
    /// let opts = RacingOptions { eta: 4, fidelity_min: 0.25 };
    /// let trace = q.search_racing(&model, &space, "sh", &mut ev, 4, 7, opts)?;
    /// assert_eq!(trace.algo, "sh(random)");
    /// // the winner is always a full-fidelity measurement
    /// assert!(trace.trials.iter().any(|t| t.config == trace.best_config && t.fidelity >= 1.0));
    /// assert!(trace.total_cost() < trace.trials.len() as f64);
    /// # Ok(())
    /// # }
    /// ```
    #[allow(clippy::too_many_arguments)]
    pub fn search_racing(
        &self,
        model: &ZooModel,
        space: &SpaceRef,
        algo_name: &str,
        evaluator: &mut dyn Evaluator,
        budget: usize,
        seed: u64,
        opts: RacingOptions,
    ) -> Result<SearchTrace> {
        let mut algo = self.make_racing_algo(model, space, algo_name, seed)?;
        run_racing(algo.as_mut(), budget, opts, |cfg, fid| {
            evaluator.measure_fidelity(cfg, fid)
        })
    }

    /// Racing under the multi-objective scalarization: exactly
    /// [`Quantune::search_objective`] with the successive-halving
    /// scheduler in place of the flat trial loop. The epsilon-constraint
    /// applies at every rung (over-budget configs are rejected before
    /// any accuracy is measured and charge no evaluation cost).
    #[allow(clippy::too_many_arguments)]
    pub fn search_racing_objective(
        &self,
        model: &ZooModel,
        space: &SpaceRef,
        algo_name: &str,
        evaluator: &mut dyn Evaluator,
        budget: usize,
        seed: u64,
        weights: ObjectiveWeights,
        limits: Budget,
        opts: RacingOptions,
    ) -> Result<SearchTrace> {
        let cost =
            CostModel::build(model, space.as_ref(), &self.device, crate::vta::PYNQ_CLOCK_MHZ)?;
        Self::ensure_feasible(&cost, &limits, &space.tag())?;
        let mut scored =
            ObjectiveEvaluator { inner: evaluator, cost: &cost, weights, budget: limits };
        let mut algo = self.make_racing_algo(model, space, algo_name, seed)?;
        let trace = run_racing(algo.as_mut(), budget, opts, |cfg, fid| {
            scored.measure_scored_fidelity(cfg, fid)
        })?;
        Self::ensure_measured(&trace, &limits)?;
        Ok(trace)
    }

    /// Resolve the proposer behind a racing run: `"sh"` is the plain
    /// scheduler (random proposals); `nsga2` is refused -- its
    /// non-dominated ranking reads full component vectors, which
    /// partial-fidelity estimates would corrupt.
    fn make_racing_algo(
        &self,
        model: &ZooModel,
        space: &SpaceRef,
        algo_name: &str,
        seed: u64,
    ) -> Result<Box<dyn SearchAlgo>> {
        anyhow::ensure!(
            algo_name != "nsga2",
            "racing composes with scalar proposers only -- nsga2 ranks Pareto \
             fronts from full component vectors; drop --fidelity-min/--eta"
        );
        let proposer = if algo_name == "sh" { "random" } else { algo_name };
        self.make_algo(model, space, proposer, seed)
    }

    /// Multi-objective search: same driver, but every measurement is
    /// scalarized from (measured accuracy, modeled latency, serialized
    /// bytes) under `weights`, with latency priced on [`Quantune::device`]
    /// (general / layer-wise spaces) or VTA cycle totals (VTA space).
    /// The returned trace's trials carry the per-component breakdown.
    ///
    /// Every algorithm tunes the scalar unchanged -- including the XGB
    /// cost model, which then learns to *predict the objective*, not
    /// accuracy.
    ///
    /// `limits` is the epsilon-constraint: configs whose static cost
    /// exceeds it are rejected before their accuracy is measured (they
    /// appear in the trace with a `-inf` score and NaN accuracy). Pass
    /// [`Budget::unlimited`] for unconstrained tuning. An unsatisfiable
    /// budget -- no config of the space fits -- is a descriptive error
    /// up front, not a search that silently measures nothing.
    #[allow(clippy::too_many_arguments)]
    pub fn search_objective(
        &self,
        model: &ZooModel,
        space: &SpaceRef,
        algo_name: &str,
        evaluator: &mut dyn Evaluator,
        budget: usize,
        seed: u64,
        weights: ObjectiveWeights,
        limits: Budget,
    ) -> Result<SearchTrace> {
        let cost =
            CostModel::build(model, space.as_ref(), &self.device, crate::vta::PYNQ_CLOCK_MHZ)?;
        Self::ensure_feasible(&cost, &limits, &space.tag())?;
        let mut scored =
            ObjectiveEvaluator { inner: evaluator, cost: &cost, weights, budget: limits };
        let mut algo = self.make_algo(model, space, algo_name, seed)?;
        let trace = run_search(algo.as_mut(), budget, |cfg| scored.measure_scored(cfg))?;
        Self::ensure_measured(&trace, &limits)?;
        Ok(trace)
    }

    /// Pareto-front search: NSGA-II ([`ParetoSearch`]) evolves `space`'s
    /// genome by non-dominated sorting + crowding distance over the
    /// (accuracy, latency, bytes) component vectors, under the same
    /// epsilon-constraint semantics as [`Quantune::search_objective`].
    /// Returns the scalar [`SearchTrace`] (whose `best_*` fields rank by
    /// the `weights` scalarization, for parity with the other
    /// algorithms) alongside the [`ParetoTrace`] frontier view.
    ///
    /// # Examples
    ///
    /// Recover a latency/size/accuracy frontier of the self-contained
    /// synthetic model -- runs from a clean checkout:
    ///
    /// ```
    /// use quantune::coordinator::{Budget, InterpEvaluator, ObjectiveWeights, Quantune};
    /// use quantune::quant::vta_space;
    ///
    /// # fn main() -> anyhow::Result<()> {
    /// let q = Quantune::synthetic();
    /// let model = Quantune::synthetic_model()?;
    /// let space = vta_space();
    /// let mut ev = InterpEvaluator::new(&model, &q.calib_pool, &q.eval, q.seed)
    ///     .with_threads(1)
    ///     .with_space(space.clone());
    /// let (trace, pareto) = q.search_pareto(
    ///     &model,
    ///     &space,
    ///     &mut ev,
    ///     8,
    ///     7,
    ///     ObjectiveWeights::parse("balanced")?,
    ///     Budget::unlimited(),
    /// )?;
    /// assert_eq!(trace.trials.len(), 8);
    /// assert!(!pareto.front.is_empty());
    /// // every frontier member is a measured trial of the trace
    /// for f in &pareto.front {
    ///     assert!(trace.trials.iter().any(|t| t.config == f.config));
    /// }
    /// # Ok(())
    /// # }
    /// ```
    #[allow(clippy::too_many_arguments)]
    pub fn search_pareto(
        &self,
        model: &ZooModel,
        space: &SpaceRef,
        evaluator: &mut dyn Evaluator,
        budget: usize,
        seed: u64,
        weights: ObjectiveWeights,
        limits: Budget,
    ) -> Result<(SearchTrace, ParetoTrace)> {
        // one orchestration pipeline for every algorithm: this IS
        // search_objective with the nsga2 driver, plus the frontier view
        let trace = self
            .search_objective(model, space, "nsga2", evaluator, budget, seed, weights, limits)?;
        let pareto = ParetoTrace::from_trials(&trace.algo, &trace.trials);
        Ok((trace, pareto))
    }

    /// Constrained searches over an empty feasible set would measure
    /// nothing; fail with the budget and space spelled out instead.
    fn ensure_feasible(cost: &CostModel, limits: &Budget, tag: &str) -> Result<()> {
        anyhow::ensure!(
            !limits.is_limited() || cost.feasible_count(limits) > 0,
            "budget {} admits no config of the {tag:?} space on {} -- relax \
             --budget-lat-ms / --budget-bytes",
            limits.slug(),
            cost.target,
        );
        Ok(())
    }

    /// A constrained search whose every proposal was rejected never
    /// measured anything: its "best" would be an over-budget config with
    /// a `-inf` score and NaN accuracy. Refuse to report that as a
    /// result (only a budget can produce an all-`-inf` trace: without
    /// one, scores are finite or NaN).
    fn ensure_measured(trace: &SearchTrace, limits: &Budget) -> Result<()> {
        anyhow::ensure!(
            !(limits.is_limited() && trace.best_score == f64::NEG_INFINITY),
            "all {} trial(s) were over budget ({}) -- the feasible region was never \
             sampled; raise --budget (trial count) or relax the constraint",
            trace.trials.len(),
            limits.slug(),
        );
        Ok(())
    }

    fn make_algo(
        &self,
        model: &ZooModel,
        space: &SpaceRef,
        algo_name: &str,
        seed: u64,
    ) -> Result<Box<dyn SearchAlgo>> {
        let transfer = if algo_name == "xgb_t" {
            self.transfer_for(model, space.as_ref())?
        } else {
            Vec::new()
        };
        anyhow::ensure!(
            algo_name != "xgb_t" || !transfer.is_empty(),
            "xgb_t needs trials of other models in the {:?} space first",
            space.tag()
        );
        // database-seeded warm start: the population algorithms can
        // begin from the store's best-known configs for (model, space)
        if self.seed_from_db && matches!(algo_name, "genetic" | "nsga2") {
            let seeds: Vec<usize> = self
                .db
                .best_configs(&model.name, &space.tag(), 4)
                .into_iter()
                .map(|(cfg, _)| cfg)
                .filter(|&cfg| cfg < space.size())
                .collect();
            if !seeds.is_empty() {
                return Ok(if algo_name == "genetic" {
                    Box::new(GeneticSearch::with_seeds(space.clone(), seed, &seeds)?)
                } else {
                    Box::new(ParetoSearch::with_seeds(space.clone(), seed, &seeds)?)
                });
            }
        }
        make_algorithm(algo_name, model, space, transfer, seed)
    }

    /// The fixed vendor-default PTQ baseline standing in for TensorRT
    /// (Fig 7): 512-image cache, per-channel weights, entropy (KL)
    /// calibration, full int8 -- TensorRT's documented defaults.
    pub fn tensorrt_like_baseline() -> QuantConfig {
        QuantConfig {
            calib: crate::quant::CalibCount::C512,
            scheme: crate::quant::Scheme::Symmetric,
            clip: crate::quant::Clipping::Kl,
            gran: crate::quant::Granularity::Channel,
            mixed: false,
            bias_correct: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{general_space, vta_space};

    #[test]
    fn algorithm_names_construct() {
        // constructing by name needs a model only for xgb variants; use
        // the error path to validate the name check
        assert!(ALGORITHMS.contains(&"xgb_t"));
        // ALGORITHMS is exactly the proposers plus the racing scheduler
        assert_eq!(&ALGORITHMS[..PROPOSERS.len()], &PROPOSERS[..]);
        assert_eq!(ALGORITHMS[PROPOSERS.len()..], ["sh"]);
    }

    #[test]
    fn trt_baseline_is_in_space() {
        let cfg = Quantune::tensorrt_like_baseline();
        let idx = cfg.index();
        assert_eq!(QuantConfig::from_index(idx).unwrap(), cfg);
    }

    #[test]
    fn features_concat_arch_and_space() {
        let model = zoo::synthetic_model(8, 4, 4, 3).unwrap();
        let g = general_space();
        let f = features_for(&model, g.as_ref(), 0).unwrap();
        assert_eq!(f.len(), 10 + QuantConfig::ONE_HOT_DIM);
        let v = vta_space();
        let fv = features_for(&model, v.as_ref(), 0).unwrap();
        assert_eq!(fv.len(), 10 + 7);
        assert_eq!(space_features(&model, v.as_ref()).unwrap().len(), 12);
    }
}
