//! The Quantune coordinator: the paper's auto-tuner (Fig 4, Algorithm 1)
//! plus the experiment drivers that regenerate its tables and figures.
//!
//! This is the L3 layer: it owns artifact loading, calibration, the
//! search loop, the trial database `D`, and accuracy measurement through
//! the PJRT runtime / interpreter / VTA simulator backends. Python never
//! appears here -- the HLO artifacts are self-contained.

pub mod database;
pub mod devices;
pub mod evaluator;
pub mod quantizer;

pub use database::{Database, Record};
pub use devices::{DeviceProfile, DEVICES};
pub use evaluator::{
    Evaluator, HloEvaluator, InterpEvaluator, OracleEvaluator, SharedEvaluator,
};
pub use quantizer::{
    act_params_tensor, mixed_precision_bypass, prepare, prepare_cached, QuantizedSetup,
    WeightCache, WeightVariant,
};

use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::data::Dataset;
use crate::quant::QuantConfig;
use crate::search::{
    run_search, GeneticSearch, GridSearch, RandomSearch, SearchAlgo, SearchTrace,
    TransferRecord, XgbSearch,
};
use crate::util::pool::Pool;
use crate::util::Timer;
use crate::zoo::{self, ZooModel};

/// The five search algorithms of Fig 5/6, by CLI name.
pub const ALGORITHMS: [&str; 5] = ["random", "grid", "genetic", "xgb", "xgb_t"];

/// Feature vector of (model, config): arch blocks `e` ++ config one-hot
/// `s` (paper §5.1; 10 + 13 = 23 dims).
pub fn features_for(model: &ZooModel, config: usize) -> Result<Vec<f32>> {
    let mut f = model.arch_features();
    f.extend(QuantConfig::from_index(config)?.one_hot());
    Ok(f)
}

/// Feature vectors for the whole space of one model.
pub fn space_features(model: &ZooModel) -> Result<Vec<Vec<f32>>> {
    (0..QuantConfig::SPACE_SIZE).map(|i| features_for(model, i)).collect()
}

/// Construct a search algorithm by name. `transfer` is only consumed by
/// `xgb_t` (the paper's XGB+transfer-learning variant).
pub fn make_algorithm(
    name: &str,
    model: &ZooModel,
    transfer: Vec<TransferRecord>,
    seed: u64,
) -> Result<Box<dyn SearchAlgo>> {
    Ok(match name {
        "random" => Box::new(RandomSearch::new(QuantConfig::SPACE_SIZE, seed)),
        "grid" => Box::new(GridSearch::new(QuantConfig::SPACE_SIZE, seed)),
        "genetic" => Box::new(GeneticSearch::new(seed)),
        "xgb" => Box::new(XgbSearch::new(space_features(model)?, seed)),
        "xgb_t" => {
            Box::new(XgbSearch::with_transfer(space_features(model)?, transfer, seed))
        }
        other => anyhow::bail!("unknown algorithm {other:?} (try {ALGORITHMS:?})"),
    })
}

/// Holds the shared experiment state: artifacts dir, datasets, database.
pub struct Quantune {
    pub artifacts: PathBuf,
    pub calib_pool: Dataset,
    pub eval: Dataset,
    pub db: Database,
    pub seed: u64,
}

impl Quantune {
    /// Open an artifacts directory (created by `make artifacts`).
    pub fn open(artifacts: PathBuf) -> Result<Quantune> {
        let calib_pool = Dataset::load(&artifacts.join("dataset_calib.qtd"))
            .context("calibration pool (run `make artifacts`)")?;
        let eval = Dataset::load(&artifacts.join("dataset_eval.qtd"))?;
        let db = Database::open(&artifacts.join("database.json"))?;
        Ok(Quantune { artifacts, calib_pool, eval, db, seed: 20220205 })
    }

    pub fn load_model(&self, name: &str) -> Result<ZooModel> {
        zoo::ZooModel::load(&self.artifacts, name)
    }

    /// Exhaustive sweep of the 96-config space for one model (Table 1 /
    /// Fig 2 ground truth). Results are persisted in the database; an
    /// existing full sweep is reused unless `force`.
    pub fn sweep(
        &mut self,
        model: &ZooModel,
        evaluator: &mut dyn Evaluator,
        force: bool,
        mut progress: impl FnMut(usize, f64),
    ) -> Result<Vec<f64>> {
        if !force && self.db.has_full_sweep(&model.name, QuantConfig::SPACE_SIZE) {
            return Ok(self.db.accuracy_table(&model.name, QuantConfig::SPACE_SIZE));
        }
        let mut table = vec![f64::NAN; QuantConfig::SPACE_SIZE];
        for i in 0..QuantConfig::SPACE_SIZE {
            let t = Timer::start();
            let acc = evaluator.measure(i)?;
            table[i] = acc;
            self.db.add(Record {
                model: model.name.clone(),
                config: i,
                accuracy: acc,
                measure_secs: t.secs(),
            });
            progress(i, acc);
        }
        self.db.save()?;
        Ok(table)
    }

    /// Exhaustive sweep through a thread-safe evaluator: the 96 configs
    /// fan out across `workers`, and results land in the database in
    /// config order (0..95), so the table and the persisted records are
    /// identical to the serial [`Quantune::sweep`] at any thread count.
    ///
    /// `progress(done, acc)` is called from worker threads with the
    /// *completed-measurement count* (configs finish out of order, so
    /// unlike [`Quantune::sweep`] it does not receive the config index).
    pub fn sweep_parallel<E: SharedEvaluator + ?Sized>(
        &mut self,
        model: &ZooModel,
        evaluator: &E,
        force: bool,
        workers: &Pool,
        progress: impl Fn(usize, f64) + Sync,
    ) -> Result<Vec<f64>> {
        if !force && self.db.has_full_sweep(&model.name, QuantConfig::SPACE_SIZE) {
            return Ok(self.db.accuracy_table(&model.name, QuantConfig::SPACE_SIZE));
        }
        let done = std::sync::atomic::AtomicUsize::new(0);
        let measured = workers.run(QuantConfig::SPACE_SIZE, |i| {
            let t = Timer::start();
            let r = evaluator.measure_shared(i).map(|acc| (acc, t.secs()));
            if let Ok((acc, _)) = &r {
                let n = done.fetch_add(1, std::sync::atomic::Ordering::Relaxed) + 1;
                progress(n, *acc);
            }
            r
        })?;
        let mut table = vec![f64::NAN; QuantConfig::SPACE_SIZE];
        for (i, r) in measured.into_iter().enumerate() {
            let (acc, secs) = r?;
            table[i] = acc;
            self.db.add(Record {
                model: model.name.clone(),
                config: i,
                accuracy: acc,
                measure_secs: secs,
            });
        }
        self.db.save()?;
        Ok(table)
    }

    /// Transfer records from every other model's sweep (database D).
    pub fn transfer_for(&self, target: &ZooModel) -> Result<Vec<TransferRecord>> {
        let mut feats: std::collections::HashMap<String, Vec<f32>> = Default::default();
        for name in zoo::MODELS {
            if name == target.name {
                continue;
            }
            if self.artifacts.join(format!("{name}_meta.json")).exists() {
                feats.insert(
                    name.to_string(),
                    self.load_model(name)?.arch_features(),
                );
            }
        }
        Ok(self.db.transfer_records(&target.name, |m, cfg| {
            let arch = feats.get(m)?;
            let mut f = arch.clone();
            f.extend(QuantConfig::from_index(cfg).ok()?.one_hot());
            Some(f)
        }))
    }

    /// Run one search algorithm against an evaluator (Algorithm 1 when
    /// the algorithm is xgb/xgb_t). `&self`: independent runs (algorithm
    /// x seed) may fan out across workers sharing one `Quantune`.
    pub fn search(
        &self,
        model: &ZooModel,
        algo_name: &str,
        evaluator: &mut dyn Evaluator,
        budget: usize,
        seed: u64,
    ) -> Result<SearchTrace> {
        let transfer = if algo_name == "xgb_t" {
            self.transfer_for(model)?
        } else {
            Vec::new()
        };
        anyhow::ensure!(
            algo_name != "xgb_t" || !transfer.is_empty(),
            "xgb_t needs sweeps of other models in the database first"
        );
        let mut algo = make_algorithm(algo_name, model, transfer, seed)?;
        run_search(algo.as_mut(), budget, |cfg| evaluator.measure(cfg))
    }

    /// The fixed vendor-default PTQ baseline standing in for TensorRT
    /// (Fig 7): 512-image cache, per-channel weights, entropy (KL)
    /// calibration, full int8 -- TensorRT's documented defaults.
    pub fn tensorrt_like_baseline() -> QuantConfig {
        QuantConfig {
            calib: crate::quant::CalibCount::C512,
            scheme: crate::quant::Scheme::Symmetric,
            clip: crate::quant::Clipping::Kl,
            gran: crate::quant::Granularity::Channel,
            mixed: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algorithm_names_construct() {
        // constructing by name needs a model only for xgb variants; use
        // the error path to validate the name check
        assert!(ALGORITHMS.contains(&"xgb_t"));
    }

    #[test]
    fn trt_baseline_is_in_space() {
        let cfg = Quantune::tensorrt_like_baseline();
        let idx = cfg.index();
        assert_eq!(QuantConfig::from_index(idx).unwrap(), cfg);
    }
}
