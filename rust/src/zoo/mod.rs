//! Model zoo: loads the six mini CNNs exported by python/compile/aot.py
//! and extracts the macro-architecture block features `e` the XGBoost
//! cost model consumes (paper §5.1: "the number of layers, convolutions,
//! activation functions, skip-layers, and depth-wise and pointwise
//! convolutions").

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::data::Weights;
use crate::ir::{Graph, Op, Tensor};
use crate::util::{Json, Pcg32};

/// The six paper models, in the paper's order.
pub const MODELS: [&str; 6] = ["mn", "shn", "sqn", "gn", "rn18", "rn50"];

/// Paper abbreviation -> full name (Table 1).
pub fn full_name(model: &str) -> &'static str {
    match model {
        "mn" => "MobileNetV2-mini",
        "shn" => "ShuffleNetV1-mini",
        "sqn" => "SqueezeNetV1-mini",
        "gn" => "GoogLeNet-mini",
        "rn18" => "ResNet18-mini",
        "rn50" => "ResNet50-mini",
        _ => "unknown",
    }
}

/// A loaded model: graph + trained weights + metadata.
pub struct ZooModel {
    /// Paper abbreviation ("mn", "rn18", ...).
    pub name: String,
    /// The model graph.
    pub graph: Graph,
    /// Trained weights in ABI order.
    pub weights: Weights,
    /// fp32 Top-1 measured by the python trainer on the eval split
    pub fp32_top1: f64,
    /// Batch dimension the HLO artifacts were lowered with.
    pub batch: usize,
}

impl ZooModel {
    /// Load `{name}_meta.json` + `{name}_weights.qtw` from `artifacts`.
    pub fn load(artifacts: &Path, name: &str) -> Result<ZooModel> {
        let meta = Json::from_file(&artifacts.join(format!("{name}_meta.json")))
            .with_context(|| format!("loading {name} metadata"))?;
        let graph = Graph::from_meta(&meta)?;
        let weights = Weights::load(&artifacts.join(format!("{name}_weights.qtw")))?;
        // sanity: the weight file must cover the graph ABI, in order
        let want = graph.weight_names();
        anyhow::ensure!(
            weights.order == want,
            "{name}: weight order mismatch (file {:?}... vs graph {:?}...)",
            &weights.order[..2.min(weights.order.len())],
            &want[..2.min(want.len())]
        );
        Ok(ZooModel {
            name: name.to_string(),
            graph,
            weights,
            fp32_top1: meta.get("fp32_top1")?.as_f64()?,
            batch: meta.get("batch")?.as_usize()?,
        })
    }

    /// The weight tensors as a name-keyed map.
    pub fn weights_map(&self) -> &HashMap<String, Tensor> {
        &self.weights.tensors
    }

    /// Macro-architecture block features `e` (fixed 10-dim vector).
    pub fn arch_features(&self) -> Vec<f32> {
        arch_features(&self.graph)
    }
}

/// Names of the architecture features (order matches `arch_features`).
pub const ARCH_FEATURE_NAMES: [&str; 10] = [
    "num_nodes",
    "num_convs",
    "num_depthwise",
    "num_grouped",
    "num_pointwise",
    "num_skip_adds",
    "num_concats",
    "log_params",
    "log_macs",
    "min_channel",
];

/// Extract the block-expression features of a graph.
pub fn arch_features(g: &Graph) -> Vec<f32> {
    let mut num_convs = 0f32;
    let mut num_dw = 0f32;
    let mut num_grouped = 0f32;
    let mut num_pw = 0f32;
    let mut num_adds = 0f32;
    let mut num_concats = 0f32;
    let mut min_channel = f32::INFINITY;
    for n in &g.nodes {
        match &n.op {
            Op::Conv { k, in_ch, out_ch, groups, .. } => {
                num_convs += 1.0;
                if *groups == *in_ch && *groups == *out_ch {
                    num_dw += 1.0;
                } else if *groups > 1 {
                    num_grouped += 1.0;
                }
                if *k == 1 {
                    num_pw += 1.0;
                }
                min_channel = min_channel.min(*out_ch as f32);
            }
            Op::Add { .. } => num_adds += 1.0,
            Op::Concat => num_concats += 1.0,
            _ => {}
        }
    }
    vec![
        g.nodes.len() as f32,
        num_convs,
        num_dw,
        num_grouped,
        num_pw,
        num_adds,
        num_concats,
        (g.num_params() as f32).ln(),
        (g.macs().unwrap_or(1) as f32).ln(),
        if min_channel.is_finite() { min_channel } else { 0.0 },
    ]
}

/// All models found in an artifacts directory (subset of MODELS).
pub fn load_all(artifacts: &Path) -> Result<Vec<ZooModel>> {
    let mut out = Vec::new();
    for m in MODELS {
        if artifacts.join(format!("{m}_meta.json")).exists() {
            out.push(ZooModel::load(artifacts, m)?);
        }
    }
    anyhow::ensure!(!out.is_empty(), "no models in {}", artifacts.display());
    Ok(out)
}

/// A small self-contained model (graph + seeded random weights) that
/// needs no artifact files: conv(3x3, c->2c, relu) -> conv(3x3, 2c->2c,
/// relu) -> gap -> dense(2c -> classes) on a `hw`x`hw`x`c` input. Used
/// by the perf bench and the parallel engine's parity/determinism tests.
pub fn synthetic_model(hw: usize, c: usize, classes: usize, seed: u64) -> Result<ZooModel> {
    let c2 = 2 * c;
    let meta_text = format!(
        r#"{{"name": "syn", "input_shape": [{hw}, {hw}, {c}], "num_classes": {classes},
        "nodes": [
          {{"name": "c1", "op": "conv", "inputs": ["input"], "k": 3, "stride": 1,
           "pad": 1, "in_ch": {c}, "out_ch": {c2}, "groups": 1, "act": "relu"}},
          {{"name": "c2", "op": "conv", "inputs": ["c1"], "k": 3, "stride": 1,
           "pad": 1, "in_ch": {c2}, "out_ch": {c2}, "groups": 1, "act": "relu"}},
          {{"name": "g", "op": "gap", "inputs": ["c2"]}},
          {{"name": "d", "op": "dense", "inputs": ["g"], "in_dim": {c2},
           "out_dim": {classes}}}]}}"#
    );
    let graph = Graph::from_meta(&Json::parse(&meta_text)?)?;
    let mut rng = Pcg32::new(seed, 41);
    let mut tensors = HashMap::new();
    let mut order = Vec::new();
    for node in &graph.nodes {
        let (w_shape, b_len): (Vec<usize>, usize) = match &node.op {
            Op::Conv { k, in_ch, out_ch, groups, .. } => {
                (vec![*k, *k, in_ch / groups, *out_ch], *out_ch)
            }
            Op::Dense { in_dim, out_dim } => (vec![*in_dim, *out_dim], *out_dim),
            _ => continue,
        };
        let fan_in: usize = w_shape[..w_shape.len() - 1].iter().product();
        let scale = (2.0 / fan_in.max(1) as f32).sqrt();
        let wn: usize = w_shape.iter().product();
        let w = Tensor {
            shape: w_shape,
            data: (0..wn).map(|_| rng.normal() * scale).collect(),
        };
        let b = Tensor {
            shape: vec![b_len],
            data: (0..b_len).map(|_| rng.normal() * 0.05).collect(),
        };
        for (suffix, t) in [("w", w), ("b", b)] {
            let name = format!("{}_{suffix}", node.name);
            order.push(name.clone());
            tensors.insert(name, t);
        }
    }
    let weights = Weights { tensors, order };
    debug_assert_eq!(weights.order, graph.weight_names());
    Ok(ZooModel { name: "syn".to_string(), graph, weights, fp32_top1: 0.5, batch: 16 })
}

/// Default artifacts directory: $QUANTUNE_ARTIFACTS or ./artifacts.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("QUANTUNE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arch_features_tiny_graph() {
        let g = Graph::from_meta(
            &Json::parse(
                r#"{"name": "t", "input_shape": [8, 8, 4], "num_classes": 2,
            "nodes": [
              {"name": "c1", "op": "conv", "inputs": ["input"], "k": 1,
               "stride": 1, "pad": 0, "in_ch": 4, "out_ch": 4, "groups": 4,
               "act": "relu"},
              {"name": "a1", "op": "add", "inputs": ["input", "c1"],
               "act": "none"},
              {"name": "g1", "op": "gap", "inputs": ["a1"]},
              {"name": "d1", "op": "dense", "inputs": ["g1"], "in_dim": 4,
               "out_dim": 2}]}"#,
            )
            .unwrap(),
        )
        .unwrap();
        let f = arch_features(&g);
        assert_eq!(f.len(), ARCH_FEATURE_NAMES.len());
        assert_eq!(f[0], 4.0); // nodes
        assert_eq!(f[1], 1.0); // convs
        assert_eq!(f[2], 1.0); // depthwise (groups == in == out)
        assert_eq!(f[4], 1.0); // pointwise (k = 1)
        assert_eq!(f[5], 1.0); // skip add
    }
}
