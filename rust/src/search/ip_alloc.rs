//! Integer-programming bit-width allocation (the AdaQuant-style
//! baseline of Hubara et al., arXiv:2006.10518, adapted to the
//! layer-wise radix spaces).
//!
//! The allocator answers "which per-layer width assignment minimizes
//! summed layer-wise weight quantization MSE under a model-size
//! budget?" *without searching*: each candidate layer independently
//! prices every menu width (MSE from [`weight_mse_at`], bytes from
//! [`layer_size_bytes_at`] -- the same accounting the experiment CSVs
//! use), and a dynamic program over Pareto-pruned (bytes, mse) states
//! solves the resulting multiple-choice knapsack *exactly*. The result
//! is wired into the radix experiments as a non-search baseline column
//! (`ip_baseline`) that the XGB tuner must beat: the IP optimum is
//! blind to cross-layer error interaction and to accuracy, so a tuner
//! that measures real accuracy should dominate or match it.
//!
//! Exactness matters here because the oracle test compares the DP
//! against exhaustive enumeration on every <= 64-config radix space;
//! dominance pruning is lossless for this objective (two partial
//! assignments with the same remaining layers differ only by their
//! accumulated (bytes, mse), so a dominated state can never finish
//! ahead).

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::ir::{Graph, Tensor};
use crate::quant::{
    layer_size_bytes_at, weight_mse_at, BitWidth, LayerwiseSpace,
};

/// One priced width choice for one layer.
#[derive(Clone, Copy, Debug)]
pub struct AllocOption {
    /// Weight quantization MSE of the layer at this width.
    pub mse: f64,
    /// Serialized bytes of the layer at this width.
    pub bytes: u64,
}

/// An exact optimum of the multiple-choice knapsack.
#[derive(Clone, Debug)]
pub struct Allocation {
    /// Chosen option index per layer (same order as the input table).
    pub picks: Vec<usize>,
    /// Total objective: summed per-layer MSE of the picks.
    pub mse: f64,
    /// Total bytes: fixed bytes plus the picks' bytes.
    pub bytes: u64,
}

/// One DP state: accumulated (bytes, mse) plus the picks that got here.
struct State {
    bytes: u64,
    mse: f64,
    picks: Vec<usize>,
}

/// Exactly minimize summed MSE over one option pick per layer, subject
/// to `fixed_bytes + sum(bytes) <= budget_bytes` (no constraint when
/// `None`). Errors when a layer has no options or no assignment fits
/// the budget.
pub fn allocate(
    options: &[Vec<AllocOption>],
    fixed_bytes: u64,
    budget_bytes: Option<u64>,
) -> Result<Allocation> {
    let remaining = match budget_bytes {
        Some(b) => match b.checked_sub(fixed_bytes) {
            Some(r) => Some(r),
            None => bail!(
                "budget {b} B below the fixed cost {fixed_bytes} B of the \
                 non-candidate layers"
            ),
        },
        None => None,
    };
    let mut states = vec![State { bytes: 0, mse: 0.0, picks: Vec::new() }];
    for (li, opts) in options.iter().enumerate() {
        if opts.is_empty() {
            bail!("layer {li} has no width options");
        }
        let mut next: Vec<State> = Vec::with_capacity(states.len() * opts.len());
        for s in &states {
            for (oi, o) in opts.iter().enumerate() {
                let bytes = s.bytes.saturating_add(o.bytes);
                if remaining.is_some_and(|r| bytes > r) {
                    continue; // already over budget; extensions only grow
                }
                let mut picks = Vec::with_capacity(options.len());
                picks.extend_from_slice(&s.picks);
                picks.push(oi);
                next.push(State { bytes, mse: s.mse + o.mse, picks });
            }
        }
        if next.is_empty() {
            bail!(
                "no width assignment fits the {} B budget at layer {li}",
                budget_bytes.unwrap_or(0)
            );
        }
        // Pareto prune: keep, in ascending byte order, only states with
        // strictly decreasing mse. Ties sort cheaper-bytes first, so a
        // same-mse-more-bytes state is dropped too.
        next.sort_by(|a, b| {
            a.bytes.cmp(&b.bytes).then(a.mse.total_cmp(&b.mse))
        });
        let mut pruned: Vec<State> = Vec::with_capacity(next.len());
        for s in next {
            if pruned.last().is_none_or(|p| s.mse < p.mse) {
                pruned.push(s);
            }
        }
        states = pruned;
    }
    // every surviving state is feasible; the best objective is the last
    // (mse strictly decreases along the list)
    let best = match states.last() {
        Some(s) => s,
        None => bail!("empty option table"),
    };
    Ok(Allocation {
        picks: best.picks.clone(),
        mse: best.mse,
        bytes: fixed_bytes + best.bytes,
    })
}

/// Run the allocator over a [`LayerwiseSpace`]: price every candidate
/// layer's menu widths from the weights (fp32 entries cost zero MSE),
/// charge non-candidate layers their fixed int8 bytes, solve, and map
/// the picks back to a space index via
/// [`LayerwiseSpace::index_of_digits`]. `dims` maps a layer name to its
/// (weight elements, output channels), exactly as the model-size
/// accounting takes it, so the returned [`Allocation::bytes`] equals
/// `model_size_bytes_at` of the chosen widths.
pub fn allocate_for_space(
    space: &LayerwiseSpace,
    graph: &Graph,
    weights: &HashMap<String, Tensor>,
    dims: &dyn Fn(&str) -> (usize, usize),
    budget_bytes: Option<u64>,
) -> Result<(usize, Allocation)> {
    let base = space.base();
    let candidate_layers: std::collections::HashSet<usize> =
        space.candidates().iter().map(|c| c.layer_index).collect();
    let mut fixed_bytes = 0u64;
    for (li, layer) in graph.layers().iter().enumerate() {
        if !candidate_layers.contains(&li) {
            let (w_elems, channels) = dims(layer);
            fixed_bytes +=
                layer_size_bytes_at(w_elems, channels, base.gran, BitWidth::Int8);
        }
    }
    let mut options = Vec::with_capacity(space.candidates().len());
    for c in space.candidates() {
        let w = match weights.get(&format!("{}_w", c.name)) {
            Some(w) => w,
            None => bail!("missing weight tensor {}_w", c.name),
        };
        let (w_elems, channels) = dims(&c.name);
        let opts: Vec<AllocOption> = space
            .width_menu()
            .iter()
            .map(|&width| AllocOption {
                mse: weight_mse_at(w, base.scheme, base.gran, width),
                bytes: layer_size_bytes_at(w_elems, channels, base.gran, width),
            })
            .collect();
        options.push(opts);
    }
    let alloc = allocate(&options, fixed_bytes, budget_bytes)?;
    let index = space.index_of_digits(&alloc.picks)?;
    Ok((index, alloc))
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::ir::Op;
    use crate::quant::{
        model_size_bytes_at, CalibCount, Clipping, Granularity, Histogram,
        QuantConfig, Scheme,
    };
    use crate::util::{Json, Pcg32};

    /// Exhaustive reference: try every combination.
    fn exhaustive(
        options: &[Vec<AllocOption>],
        fixed: u64,
        budget: Option<u64>,
    ) -> Option<(f64, u64)> {
        let n: usize = options.iter().map(Vec::len).product();
        let mut best: Option<(f64, u64)> = None;
        for mut i in 0..n {
            let (mut mse, mut bytes) = (0.0f64, fixed);
            for opts in options {
                let o = &opts[i % opts.len()];
                i /= opts.len();
                mse += o.mse;
                bytes += o.bytes;
            }
            if budget.is_some_and(|b| bytes > b) {
                continue;
            }
            let better = match best {
                None => true,
                Some((bm, bb)) => mse < bm || (mse == bm && bytes < bb),
            };
            if better {
                best = Some((mse, bytes));
            }
        }
        best
    }

    #[test]
    fn dp_matches_exhaustive_on_random_tables() {
        let mut rng = Pcg32::seeded(11);
        for trial in 0..30 {
            let layers = 1 + (trial % 6);
            let options: Vec<Vec<AllocOption>> = (0..layers)
                .map(|_| {
                    (0..4)
                        .map(|_| AllocOption {
                            mse: f64::from(rng.range_f32(0.0, 1.0)),
                            bytes: 10 + f64::from(rng.range_f32(0.0, 90.0)) as u64,
                        })
                        .collect()
                })
                .collect();
            let fixed = 17u64;
            for budget in [None, Some(fixed + 60 * layers as u64), Some(fixed + 25 * layers as u64)]
            {
                let want = exhaustive(&options, fixed, budget);
                match allocate(&options, fixed, budget) {
                    Ok(got) => {
                        let (wm, wb) = want.expect("DP found a solution, so must brute force");
                        assert!(
                            (got.mse - wm).abs() < 1e-12,
                            "trial {trial} budget {budget:?}: DP mse {} vs exhaustive {wm}",
                            got.mse
                        );
                        assert_eq!(got.bytes, wb, "trial {trial} budget {budget:?}");
                        assert!(budget.is_none_or(|b| got.bytes <= b));
                        assert_eq!(got.picks.len(), layers);
                    }
                    Err(_) => {
                        assert!(want.is_none(), "trial {trial}: DP infeasible but exhaustive found {want:?}");
                    }
                }
            }
        }
    }

    #[test]
    fn infeasible_budget_errors() {
        let options =
            vec![vec![AllocOption { mse: 0.1, bytes: 100 }, AllocOption { mse: 0.0, bytes: 200 }]];
        assert!(allocate(&options, 0, Some(50)).is_err());
        assert!(allocate(&options, 60, Some(50)).is_err()); // fixed alone too big
        assert!(allocate(&[vec![]], 0, None).is_err()); // option-less layer
        let ok = allocate(&options, 0, Some(100)).unwrap();
        assert_eq!(ok.picks, vec![0]); // only the 100 B pick fits
        let free = allocate(&options, 0, None).unwrap();
        assert_eq!(free.picks, vec![1]); // unconstrained takes the lower mse
    }

    fn tiny_graph() -> Graph {
        Graph::from_meta(
            &Json::parse(
                r#"{"name": "t", "input_shape": [8, 8, 2], "num_classes": 3,
            "nodes": [
              {"name": "c1", "op": "conv", "inputs": ["input"], "k": 3,
               "stride": 1, "pad": 1, "in_ch": 2, "out_ch": 4, "groups": 1,
               "act": "relu"},
              {"name": "c2", "op": "conv", "inputs": ["c1"], "k": 3,
               "stride": 1, "pad": 1, "in_ch": 4, "out_ch": 4, "groups": 1,
               "act": "relu"},
              {"name": "g", "op": "gap", "inputs": ["c2"]},
              {"name": "d", "op": "dense", "inputs": ["g"], "in_dim": 4,
               "out_dim": 3}]}"#,
            )
            .unwrap(),
        )
        .unwrap()
    }

    fn tiny_weights(graph: &Graph) -> HashMap<String, Tensor> {
        let mut rng = Pcg32::seeded(5);
        let mut out = HashMap::new();
        for n in &graph.nodes {
            let (w_shape, b_len): (Vec<usize>, usize) = match &n.op {
                Op::Conv { k, in_ch, out_ch, groups, .. } => {
                    (vec![*k, *k, in_ch / groups, *out_ch], *out_ch)
                }
                Op::Dense { in_dim, out_dim } => (vec![*in_dim, *out_dim], *out_dim),
                _ => continue,
            };
            let wn: usize = w_shape.iter().product();
            let data: Vec<f32> = (0..wn).map(|_| rng.normal() * 0.1).collect();
            out.insert(format!("{}_w", n.name), Tensor { shape: w_shape, data });
            out.insert(
                format!("{}_b", n.name),
                Tensor { shape: vec![b_len], data: vec![0.0; b_len] },
            );
        }
        out
    }

    #[test]
    fn allocator_is_optimal_on_radix_spaces() {
        // the acceptance oracle: on every <= 64-config radix space the
        // DP pick must match exhaustive enumeration of the space itself,
        // and its byte accounting must agree with model_size_bytes_at
        let graph = tiny_graph();
        let weights = tiny_weights(&graph);
        let mut rng = Pcg32::seeded(6);
        let hists: Vec<Histogram> = graph
            .quant_points()
            .iter()
            .map(|_| {
                let mut h = Histogram::new();
                let xs: Vec<f32> = (0..512).map(|_| rng.normal()).collect();
                h.update(&xs);
                h
            })
            .collect();
        let base = QuantConfig {
            calib: CalibCount::C64,
            scheme: Scheme::Symmetric,
            clip: Clipping::Max,
            gran: Granularity::Tensor,
            mixed: false,
            bias_correct: false,
        };
        let dims = |name: &str| {
            let w = &weights[&format!("{name}_w")];
            (w.data.len(), *w.shape.last().unwrap())
        };
        let menus: [&[BitWidth]; 3] = [
            &[BitWidth::Int4, BitWidth::Int8, BitWidth::Int16, BitWidth::Fp32],
            &[BitWidth::Int8, BitWidth::Fp32],
            &[BitWidth::Int4, BitWidth::Int8],
        ];
        for menu in menus {
            for k in 1..=3usize {
                let space = LayerwiseSpace::rank(
                    "t", &graph, &weights, &hists, base, k, menu,
                )
                .unwrap();
                assert!(space.size() <= 64);
                // objective + bytes of an arbitrary space index, from the
                // space's own width vectors and the model-level accounting
                let eval = |i: usize| {
                    let widths = space.widths_of(i);
                    let mse: f64 = space
                        .candidates()
                        .iter()
                        .map(|c| {
                            weight_mse_at(
                                &weights[&format!("{}_w", c.name)],
                                base.scheme,
                                base.gran,
                                widths[c.layer_index],
                            )
                        })
                        .sum();
                    let bytes = model_size_bytes_at(&graph, &dims, base.gran, &widths);
                    (mse, bytes)
                };
                let all_int8 = eval(0).1; // index 0 is the all-int8 plan
                for budget in [None, Some(all_int8), Some(all_int8 * 2)] {
                    let (index, alloc) =
                        allocate_for_space(&space, &graph, &weights, &dims, budget)
                            .unwrap();
                    let (got_mse, got_bytes) = eval(index);
                    assert!((alloc.mse - got_mse).abs() < 1e-12);
                    assert_eq!(alloc.bytes, got_bytes, "accounting mismatch");
                    // exhaustive optimum over the whole space
                    let best = (0..space.size())
                        .map(eval)
                        .filter(|&(_, b)| budget.is_none_or(|l| b <= l))
                        .min_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)))
                        .expect("budget admits at least all-int8");
                    assert!(
                        (alloc.mse - best.0).abs() < 1e-12,
                        "menu {menu:?} k={k} budget {budget:?}: DP mse {} vs exhaustive {}",
                        alloc.mse,
                        best.0
                    );
                    assert!(budget.is_none_or(|l| alloc.bytes <= l));
                }
            }
        }
    }
}
