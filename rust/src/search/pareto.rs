//! NSGA-II Pareto-front search over the three deployment objectives.
//!
//! [`ParetoSearch`] evolves any [`crate::quant::ConfigSpace`] genome --
//! the same plumbing [`super::GeneticSearch`] uses -- but selects by
//! *dominance* over the full [`Components`] vector (maximize accuracy,
//! minimize modeled latency, minimize serialized bytes) instead of a
//! scalarized score: fast non-dominated sorting ranks the population
//! into fronts, and crowding distance spreads the survivors along each
//! front (Deb et al., "A fast and elitist multiobjective genetic
//! algorithm: NSGA-II", 2002). The paper's tuner scalarizes (PR 3);
//! this module searches for the whole trade-off frontier in one run.
//!
//! NaN / infeasibility contract (constrained domination): a point whose
//! accuracy is NaN -- a budget-rejected config that was never measured
//! (see [`crate::coordinator::Budget`]) or a poisoned database hole --
//! is dominated by every measured point and never enters a
//! [`ParetoTrace`] front. NaN latency/size components order as +inf on
//! their axis, mirroring [`crate::util::nan_min_cmp`]'s "NaN ranks
//! worst" convention. All tie-breaks are by index, so the evolution is
//! deterministic for a fixed seed at any evaluator thread count
//! (rust/tests/parallel.rs enforces this end to end).

use crate::quant::{ConfigSpace, SpaceRef};
use crate::util::Pcg32;

use super::{breed, random_population, Components, SearchAlgo, Trial};

/// Canonical minimization triple of a [`Components`] point: negated
/// accuracy, latency, bytes, with NaN mapped to +inf on every axis so
/// comparisons are total.
fn min_key(c: &Components) -> [f64; 3] {
    let flip = |v: f64, neg: bool| {
        if v.is_nan() {
            f64::INFINITY
        } else if neg {
            -v
        } else {
            v
        }
    };
    [
        flip(c.accuracy, true),
        flip(c.latency_ms, false),
        flip(c.size_bytes, false),
    ]
}

/// Does `a` Pareto-dominate `b`? `a` must be at least as good on all of
/// (accuracy up, latency down, bytes down) and strictly better on one.
///
/// Constrained domination: a point with measured (non-NaN) accuracy
/// dominates any point whose accuracy is NaN (budget-rejected before
/// measurement, or a poisoned record), regardless of the cost axes --
/// so infeasible points always sink to the last front. Two NaN-accuracy
/// points never dominate each other.
pub fn dominates(a: &Components, b: &Components) -> bool {
    match (a.accuracy.is_nan(), b.accuracy.is_nan()) {
        (false, true) => return true,
        (true, _) => return false,
        _ => {}
    }
    let (ka, kb) = (min_key(a), min_key(b));
    ka.iter().zip(&kb).all(|(x, y)| x <= y) && ka.iter().zip(&kb).any(|(x, y)| x < y)
}

/// Fast non-dominated sorting: partition point indices into fronts,
/// front 0 holding every non-dominated point, front 1 the points only
/// dominated by front 0, and so on. Within a front, indices keep their
/// input order (deterministic). Empty input gives no fronts.
pub fn non_dominated_sort(pts: &[Components]) -> Vec<Vec<usize>> {
    let n = pts.len();
    let mut dominated: Vec<Vec<usize>> = vec![Vec::new(); n]; // i -> set i dominates
    let mut count = vec![0usize; n]; // how many dominate i
    let mut fronts: Vec<Vec<usize>> = Vec::new();
    // each unordered pair is compared once (dominance is asymmetric, so
    // at most one direction holds)
    for i in 0..n {
        for j in (i + 1)..n {
            if dominates(&pts[i], &pts[j]) {
                dominated[i].push(j);
                count[j] += 1;
            } else if dominates(&pts[j], &pts[i]) {
                dominated[j].push(i);
                count[i] += 1;
            }
        }
    }
    let mut current: Vec<usize> = (0..n).filter(|&i| count[i] == 0).collect();
    while !current.is_empty() {
        let mut next = Vec::new();
        for &i in &current {
            for &j in &dominated[i] {
                count[j] -= 1;
                if count[j] == 0 {
                    next.push(j);
                }
            }
        }
        next.sort_unstable(); // input order within the front
        fronts.push(std::mem::replace(&mut current, next));
    }
    fronts
}

/// Crowding distance of each member of `front` (indices into `pts`),
/// returned in `front` order: per axis, boundary points get +inf and
/// interior points the normalized gap between their neighbours. Ties in
/// the per-axis ordering break by position in `front`, so the result is
/// deterministic under any input permutation of equal points.
pub fn crowding_distance(pts: &[Components], front: &[usize]) -> Vec<f64> {
    let m = front.len();
    if m <= 2 {
        return vec![f64::INFINITY; m];
    }
    let mut dist = vec![0.0f64; m];
    for axis in 0..3 {
        let key = |w: usize| min_key(&pts[front[w]])[axis];
        let mut order: Vec<usize> = (0..m).collect();
        order.sort_by(|&a, &b| key(a).total_cmp(&key(b)).then(a.cmp(&b)));
        dist[order[0]] = f64::INFINITY;
        dist[order[m - 1]] = f64::INFINITY;
        let span = key(order[m - 1]) - key(order[0]);
        if !span.is_finite() || span <= 0.0 {
            continue;
        }
        for w in 1..m - 1 {
            let gap = (key(order[w + 1]) - key(order[w - 1])) / span;
            if gap.is_finite() {
                dist[order[w]] += gap;
            }
        }
    }
    dist
}

/// Objective vector of one trial: its component breakdown when the
/// measurement was multi-objective, else the scalar score standing in
/// for accuracy with zero costs (so an accuracy-only run degrades to
/// single-objective dominance = plain ranking).
fn components_of(t: &Trial) -> Components {
    t.components.unwrap_or(Components {
        accuracy: t.score,
        latency_ms: 0.0,
        size_bytes: 0.0,
    })
}

// ---------------------------------------------------------------------------
// ParetoTrace
// ---------------------------------------------------------------------------

/// The multi-objective view of a finished search: the non-dominated
/// front over every *measured* trial (unique by config, NaN-accuracy
/// points excluded), plus how the frontier grew while the search ran.
/// Built by [`ParetoTrace::from_trials`], usually on the trials of the
/// scalar [`super::SearchTrace`] the same run produced.
#[derive(Clone, Debug)]
pub struct ParetoTrace {
    /// Name of the algorithm that ran ("nsga2" for [`ParetoSearch`]).
    pub algo: String,
    /// Non-dominated measured trials, in config-index order. Empty only
    /// when every trial's accuracy was NaN.
    pub front: Vec<Trial>,
    /// Unique configs with a real (non-NaN-accuracy) measurement. This
    /// -- not the trial count -- is the evaluation cost: memoized repeat
    /// proposals are free, and budget-rejected proposals never reached
    /// the evaluator at all (see
    /// [`crate::coordinator::Budget`]), so neither is counted.
    pub evaluations: usize,
    /// Size of the running frontier after each trial, in trial order
    /// (the convergence curve of the frontier search).
    pub front_sizes: Vec<usize>,
}

impl ParetoTrace {
    /// Compute the frontier view of a trial sequence. Later re-measures
    /// of the same config replace earlier ones; trials whose accuracy is
    /// NaN (budget-rejected or poisoned) can never enter the front and
    /// are not counted as evaluations.
    ///
    /// The running front is maintained incrementally -- O(|front|) per
    /// new point instead of a from-scratch O(k^2) recompute -- falling
    /// back to a rebuild only when a config is re-measured with a
    /// *different* value (a removal can resurrect previously-dominated
    /// points, which the incremental form cannot see).
    pub fn from_trials(algo: &str, trials: &[Trial]) -> ParetoTrace {
        let mut seen: std::collections::BTreeMap<usize, Trial> = Default::default();
        let mut front: Vec<Trial> = Vec::new();
        let mut front_sizes = Vec::with_capacity(trials.len());
        for t in trials {
            match seen.insert(t.config, *t) {
                None => front_insert(&mut front, t),
                Some(old) if !same_measurement(&old, t) => {
                    let unique: Vec<Trial> = seen.values().copied().collect();
                    front = front_of(&unique);
                }
                Some(_) => {} // memoized repeat: front unchanged
            }
            front_sizes.push(front.len());
        }
        front.sort_by_key(|t| t.config);
        let evaluations =
            seen.values().filter(|t| !components_of(t).accuracy.is_nan()).count();
        ParetoTrace { algo: algo.to_string(), front, evaluations, front_sizes }
    }

    /// Config indices of the front, ascending.
    pub fn front_configs(&self) -> Vec<usize> {
        self.front.iter().map(|t| t.config).collect()
    }

    /// Exact hypervolume of the front with respect to `reference` --
    /// the volume of objective space the front dominates, bounded by
    /// the reference point (a corner at least as bad as every point:
    /// lower accuracy, higher latency, more bytes). Points not strictly
    /// better than the reference on all three axes contribute nothing.
    /// This is the standard frontier-recovery metric: a searched front
    /// recovering `hv_searched / hv_true` of the exhaustive frontier's
    /// hypervolume (see `experiments::pareto_search_synthetic`).
    ///
    /// # Examples
    ///
    /// ```
    /// use quantune::search::{Components, ParetoTrace, Trial};
    ///
    /// let t = |config, acc, lat, bytes| Trial::scored(
    ///     config,
    ///     acc,
    ///     Components { accuracy: acc, latency_ms: lat, size_bytes: bytes },
    /// );
    /// // configs 0 and 1 trade accuracy against cost; 2 is dominated
    /// let trace = ParetoTrace::from_trials(
    ///     "nsga2",
    ///     &[t(0, 0.8, 2.0, 100.0), t(1, 0.6, 1.0, 50.0), t(2, 0.5, 3.0, 200.0)],
    /// );
    /// assert_eq!(trace.front_configs(), vec![0, 1]);
    ///
    /// let reference = Components { accuracy: 0.0, latency_ms: 4.0, size_bytes: 400.0 };
    /// let hv = trace.hypervolume(reference);
    /// // dropping a frontier point can only shrink the hypervolume
    /// let smaller = ParetoTrace::from_trials("nsga2", &[t(1, 0.6, 1.0, 50.0)]);
    /// assert!(smaller.hypervolume(reference) < hv);
    /// // a reference the front does not strictly beat contributes nothing
    /// let inside = Components { accuracy: 0.9, latency_ms: 0.5, size_bytes: 10.0 };
    /// assert_eq!(trace.hypervolume(inside), 0.0);
    /// ```
    pub fn hypervolume(&self, reference: Components) -> f64 {
        let pts: Vec<[f64; 3]> =
            self.front.iter().map(|t| min_key(&components_of(t))).collect();
        hypervolume3(&pts, min_key(&reference))
    }
}

/// Did two trials of the same config record bit-identical measurements?
/// (Memoized re-proposals do; a genuinely re-measured config may not.)
fn same_measurement(a: &Trial, b: &Trial) -> bool {
    let comp_bits = |c: Components| {
        (c.accuracy.to_bits(), c.latency_ms.to_bits(), c.size_bytes.to_bits())
    };
    a.score.to_bits() == b.score.to_bits()
        && comp_bits(components_of(a)) == comp_bits(components_of(b))
}

/// Insert one measured point into an incrementally-maintained front:
/// NaN accuracy never enters; a point dominated by a front member is
/// discarded (transitivity: a dominator outside the front would itself
/// be dominated by a member, which would then dominate the point); an
/// entering point evicts the members it dominates.
fn front_insert(front: &mut Vec<Trial>, t: &Trial) {
    let p = components_of(t);
    if p.accuracy.is_nan() {
        return;
    }
    if front.iter().any(|f| dominates(&components_of(f), &p)) {
        return;
    }
    front.retain(|f| !dominates(&p, &components_of(f)));
    front.push(*t);
}

/// The non-dominated subset of `trials` (each config assumed unique),
/// NaN-accuracy points excluded, in input order.
fn front_of(trials: &[Trial]) -> Vec<Trial> {
    let pts: Vec<Components> = trials.iter().map(components_of).collect();
    let mut front = Vec::new();
    for (i, t) in trials.iter().enumerate() {
        if pts[i].accuracy.is_nan() {
            continue;
        }
        if !pts.iter().any(|q| dominates(q, &pts[i])) {
            front.push(*t);
        }
    }
    front
}

/// Exact 3D hypervolume of minimization points w.r.t. reference `r`:
/// sweep the first axis, integrating the 2D staircase area of the
/// prefix over each slab. O(n^2 log n) -- plenty for config spaces.
fn hypervolume3(pts: &[[f64; 3]], r: [f64; 3]) -> f64 {
    let mut pts: Vec<[f64; 3]> = pts
        .iter()
        .copied()
        .filter(|p| p[0] < r[0] && p[1] < r[1] && p[2] < r[2])
        .collect();
    pts.sort_by(|a, b| {
        a[0].total_cmp(&b[0]).then(a[1].total_cmp(&b[1])).then(a[2].total_cmp(&b[2]))
    });
    let mut hv = 0.0;
    for i in 0..pts.len() {
        let z0 = pts[i][0];
        let z1 = if i + 1 < pts.len() { pts[i + 1][0] } else { r[0] };
        if z1 <= z0 {
            continue; // zero-width slab (tied first axis)
        }
        hv += staircase_area(&pts[..=i], r[1], r[2]) * (z1 - z0);
    }
    hv
}

/// Area of the union of boxes `[p1, r1] x [p2, r2]` over the (axis 1,
/// axis 2) projections of `pts`.
fn staircase_area(pts: &[[f64; 3]], r1: f64, r2: f64) -> f64 {
    let mut ps: Vec<(f64, f64)> = pts.iter().map(|p| (p[1], p[2])).collect();
    ps.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.total_cmp(&b.1)));
    let mut area = 0.0;
    let mut min2 = f64::INFINITY;
    for i in 0..ps.len() {
        let x0 = ps[i].0;
        let x1 = if i + 1 < ps.len() { ps[i + 1].0 } else { r1 };
        min2 = min2.min(ps[i].1);
        if x1 > x0 {
            area += (x1 - x0) * (r2 - min2);
        }
    }
    area
}

// ---------------------------------------------------------------------------
// ParetoSearch (NSGA-II)
// ---------------------------------------------------------------------------

/// NSGA-II over a [`crate::quant::ConfigSpace`] genome: a (mu + lambda)
/// generational loop where survivors are selected by (non-domination
/// rank, crowding distance) over the measured [`Components`] vectors,
/// and offspring come from crowded binary tournaments with the same
/// single-point crossover (p=0.8) and bit-flip mutation (p=0.1) the
/// scalar GA uses. Drive it through [`super::run_search`] with a
/// measure closure that returns `(score, Components)` -- e.g.
/// [`crate::coordinator::ObjectiveEvaluator::measure_scored`] -- then
/// build the frontier view with [`ParetoTrace::from_trials`] (or use
/// `Quantune::search_pareto`, which does both).
pub struct ParetoSearch {
    rng: Pcg32,
    space: SpaceRef,
    bits: usize,
    pop_size: usize,
    /// survivors of the last environmental selection
    parents: Vec<Vec<bool>>,
    /// generation currently being proposed / measured
    offspring: Vec<Vec<bool>>,
    pending: Vec<usize>, // offspring not yet proposed this generation
}

impl ParetoSearch {
    /// NSGA-II over `space`'s genome. Population size 8 (matching
    /// [`super::GeneticSearch`]), so a budget of `8 * g` proposals runs
    /// `g` generations.
    pub fn new(space: SpaceRef, seed: u64) -> Self {
        let mut rng = Pcg32::new(seed, 29);
        let pop_size = 8;
        let bits = space.genome_bits().max(1);
        let offspring = random_population(&mut rng, pop_size, bits);
        ParetoSearch {
            rng,
            space,
            bits,
            pop_size,
            parents: Vec::new(),
            offspring,
            pending: (0..pop_size).rev().collect(),
        }
    }

    /// NSGA-II whose first offspring generation is warm-started from
    /// `seeds` (config indices, best first -- e.g. the trial store's
    /// best-known configs for this model x space). Up to a full
    /// population of seeds is encoded as genomes (proposed first, in
    /// order); the remainder stays random. The RNG is constructed
    /// exactly as in [`ParetoSearch::new`], so an empty `seeds` slice
    /// reproduces the unseeded search bit-for-bit. Errors if a seed
    /// index is outside the space.
    pub fn with_seeds(space: SpaceRef, seed: u64, seeds: &[usize]) -> anyhow::Result<Self> {
        let mut rng = Pcg32::new(seed, 29);
        let pop_size = 8;
        let bits = space.genome_bits().max(1);
        let mut offspring: Vec<Vec<bool>> = Vec::with_capacity(pop_size);
        for &cfg in seeds.iter().take(pop_size) {
            let mut genome = space.encode(cfg)?;
            genome.resize(bits, false);
            offspring.push(genome);
        }
        let fill = pop_size - offspring.len();
        offspring.extend(random_population(&mut rng, fill, bits));
        Ok(ParetoSearch {
            rng,
            space,
            bits,
            pop_size,
            parents: Vec::new(),
            offspring,
            pending: (0..pop_size).rev().collect(),
        })
    }

    /// Objective vector of a genome: the latest measurement of its
    /// decoded config, or an all-worst point (NaN accuracy, +inf costs)
    /// when it was never measured -- so unmeasured genomes can never
    /// displace measured ones in selection.
    fn objective_of(space: &dyn ConfigSpace, genome: &[bool], history: &[Trial]) -> Components {
        let idx = space.decode(genome);
        history
            .iter()
            .rev()
            .find(|t| t.config == idx)
            .map(components_of)
            .unwrap_or(Components {
                accuracy: f64::NAN,
                latency_ms: f64::INFINITY,
                size_bytes: f64::INFINITY,
            })
    }

    /// Environmental selection + variation: (parents ++ offspring) are
    /// ranked by non-dominated sorting, fronts fill the next parent set
    /// in order, the split front is trimmed by descending crowding
    /// distance (index tie-break), and crowded binary tournaments breed
    /// the next offspring generation.
    fn evolve(&mut self, history: &[Trial]) {
        let mut pool = std::mem::take(&mut self.parents);
        pool.append(&mut self.offspring);
        let pts: Vec<Components> = pool
            .iter()
            .map(|g| Self::objective_of(self.space.as_ref(), g, history))
            .collect();
        let fronts = non_dominated_sort(&pts);
        let mut rank = vec![0usize; pool.len()];
        let mut crowd = vec![0.0f64; pool.len()];
        for (r, front) in fronts.iter().enumerate() {
            for (&i, d) in front.iter().zip(crowding_distance(&pts, front)) {
                rank[i] = r;
                crowd[i] = d;
            }
        }
        let mut survivors: Vec<usize> = Vec::with_capacity(self.pop_size);
        for front in &fronts {
            if survivors.len() + front.len() <= self.pop_size {
                survivors.extend(front.iter().copied());
            } else {
                let mut rest = front.clone();
                rest.sort_by(|&a, &b| crowd[b].total_cmp(&crowd[a]).then(a.cmp(&b)));
                rest.truncate(self.pop_size - survivors.len());
                survivors.extend(rest);
            }
            if survivors.len() == self.pop_size {
                break;
            }
        }
        let sel: Vec<(usize, f64)> =
            survivors.iter().map(|&i| (rank[i], crowd[i])).collect();
        self.parents = survivors.iter().map(|&i| pool[i].clone()).collect();
        // crowded binary tournament: lower rank wins; equal rank prefers
        // the larger crowding distance; full tie keeps the first draw
        self.offspring = breed(
            &mut self.rng,
            &self.parents,
            self.bits,
            self.pop_size,
            |rng| {
                let a = rng.below(sel.len());
                let b = rng.below(sel.len());
                let a_wins = sel[a].0 < sel[b].0
                    || (sel[a].0 == sel[b].0 && sel[a].1 >= sel[b].1);
                if a_wins {
                    a
                } else {
                    b
                }
            },
        );
        self.pending = (0..self.pop_size).rev().collect();
    }
}

impl SearchAlgo for ParetoSearch {
    fn name(&self) -> &'static str {
        "nsga2"
    }

    fn propose(&mut self, history: &[Trial]) -> Option<usize> {
        if self.pending.is_empty() {
            self.evolve(history);
        }
        let member = self.pending.pop()?;
        Some(self.space.decode(&self.offspring[member]))
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::super::run_search;
    use super::*;
    use crate::quant::general_space;

    fn c(acc: f64, lat: f64, size: f64) -> Components {
        Components { accuracy: acc, latency_ms: lat, size_bytes: size }
    }

    #[test]
    fn dominance_is_strict_and_nan_safe() {
        assert!(dominates(&c(0.9, 1.0, 10.0), &c(0.8, 1.0, 10.0)));
        assert!(dominates(&c(0.9, 1.0, 10.0), &c(0.9, 2.0, 10.0)));
        // equal points never dominate each other
        assert!(!dominates(&c(0.9, 1.0, 10.0), &c(0.9, 1.0, 10.0)));
        // trade-offs are incomparable
        assert!(!dominates(&c(0.9, 2.0, 10.0), &c(0.8, 1.0, 10.0)));
        assert!(!dominates(&c(0.8, 1.0, 10.0), &c(0.9, 2.0, 10.0)));
        // a measured point dominates any NaN-accuracy point, even one
        // with better costs; never the other way around
        assert!(dominates(&c(0.1, 9.0, 99.0), &c(f64::NAN, 0.0, 0.0)));
        assert!(!dominates(&c(f64::NAN, 0.0, 0.0), &c(0.1, 9.0, 99.0)));
        assert!(!dominates(&c(f64::NAN, 0.0, 0.0), &c(f64::NAN, 1.0, 1.0)));
        // NaN costs order as +inf on their axis
        assert!(dominates(&c(0.9, 1.0, 10.0), &c(0.9, f64::NAN, 10.0)));
    }

    #[test]
    fn non_dominated_sort_layers_fronts() {
        let pts = vec![
            c(0.9, 1.0, 10.0), // front 0
            c(0.5, 0.5, 5.0),  // front 0 (cheaper)
            c(0.8, 2.0, 20.0), // front 1: dominated by 0 only
            c(0.4, 3.0, 30.0), // front 2
            c(f64::NAN, 0.1, 1.0), // last front (infeasible)
        ];
        let fronts = non_dominated_sort(&pts);
        assert_eq!(fronts, vec![vec![0, 1], vec![2], vec![3], vec![4]]);
        // every index appears exactly once
        let mut all: Vec<usize> = fronts.concat();
        all.sort_unstable();
        assert_eq!(all, (0..pts.len()).collect::<Vec<_>>());
    }

    #[test]
    fn crowding_rewards_boundary_and_spread() {
        // four points on a line: boundaries get +inf, the middle pair
        // finite positive distances
        let pts = vec![
            c(0.9, 1.0, 10.0),
            c(0.7, 0.8, 8.0),
            c(0.5, 0.6, 6.0),
            c(0.1, 0.2, 2.0),
        ];
        let front: Vec<usize> = (0..4).collect();
        let d = crowding_distance(&pts, &front);
        assert!(d[0].is_infinite() && d[3].is_infinite());
        assert!(d[1].is_finite() && d[1] > 0.0);
        assert!(d[2].is_finite() && d[2] > 0.0);
        // the point next to the big gap (0.5 -> 0.1) is less crowded
        assert!(d[2] > d[1], "{d:?}");
        // tiny fronts are all boundary
        assert_eq!(crowding_distance(&pts, &[0, 1]), vec![f64::INFINITY; 2]);
    }

    #[test]
    fn crowding_is_deterministic_under_duplicate_points() {
        let pts = vec![c(0.5, 1.0, 10.0); 5];
        let front: Vec<usize> = (0..5).collect();
        let a = crowding_distance(&pts, &front);
        let b = crowding_distance(&pts, &front);
        assert_eq!(a, b);
        // all gaps are zero-span: only the per-axis boundaries get +inf,
        // and they are the same members every time (index tie-break)
        assert!(a[0].is_infinite() && a[4].is_infinite());
    }

    #[test]
    fn hypervolume_of_known_boxes() {
        let t = |config, acc, lat, size| Trial::scored(config, acc, c(acc, lat, size));
        // one point: volume is the product of its gaps to the reference
        let one = ParetoTrace::from_trials("nsga2", &[t(0, 0.5, 1.0, 10.0)]);
        let r = c(0.0, 2.0, 20.0);
        assert!((one.hypervolume(r) - 0.5 * 1.0 * 10.0).abs() < 1e-12);
        // two incomparable points: inclusion-exclusion by hand.
        // a=(0.5,1,10), b=(0.8,1.5,15) vs r=(0,2,20):
        //   vol(a)=0.5*1*10=5, vol(b)=0.8*0.5*5=2,
        //   overlap=(min .5,.8)*(2-1.5)*(20-15)=0.5*0.5*5=1.25
        let two = ParetoTrace::from_trials(
            "nsga2",
            &[t(0, 0.5, 1.0, 10.0), t(1, 0.8, 1.5, 15.0)],
        );
        assert!((two.hypervolume(r) - (5.0 + 2.0 - 1.25)).abs() < 1e-12);
        // dominated and NaN points add nothing / are excluded
        let noisy = ParetoTrace::from_trials(
            "nsga2",
            &[
                t(0, 0.5, 1.0, 10.0),
                t(1, 0.8, 1.5, 15.0),
                t(2, 0.4, 1.8, 18.0),            // dominated by both
                t(3, f64::NAN, 0.0, 0.0),        // infeasible
            ],
        );
        assert_eq!(noisy.front_configs(), vec![0, 1]);
        assert!((noisy.hypervolume(r) - two.hypervolume(r)).abs() < 1e-12);
    }

    #[test]
    fn trace_tracks_front_growth_and_unique_evaluations() {
        let t = |config, acc, lat, size| Trial::scored(config, acc, c(acc, lat, size));
        let rejected = |config| Trial {
            config,
            score: f64::NEG_INFINITY,
            components: Some(c(f64::NAN, 5.0, 50.0)),
            fidelity: 1.0,
            cost: 0.0,
        };
        let trials = [
            t(3, 0.5, 1.0, 10.0),
            t(7, 0.8, 2.0, 20.0),
            t(3, 0.5, 1.0, 10.0), // memoized repeat
            rejected(5),          // over budget: never measured
            t(1, 0.4, 3.0, 30.0), // dominated
        ];
        let trace = ParetoTrace::from_trials("nsga2", &trials);
        assert_eq!(
            trace.evaluations, 3,
            "repeats are free and budget rejections are never measured"
        );
        assert_eq!(trace.front_sizes, vec![1, 2, 2, 2, 2]);
        assert_eq!(trace.front_configs(), vec![3, 7]);
    }

    #[test]
    fn re_measured_config_rebuilds_the_front() {
        let t = |config, acc, lat, size| Trial::scored(config, acc, c(acc, lat, size));
        // config 2 first dominates config 0; its re-measure drops below,
        // which must resurrect config 0 onto the front
        let trials = [
            t(0, 0.5, 1.0, 10.0),
            t(2, 0.6, 1.0, 10.0),
            t(2, 0.3, 2.0, 20.0),
        ];
        let trace = ParetoTrace::from_trials("nsga2", &trials);
        assert_eq!(trace.front_sizes, vec![1, 1, 1]);
        assert_eq!(trace.front_configs(), vec![0]);
        assert_eq!(trace.evaluations, 2);
    }

    #[test]
    fn nsga2_front_members_are_never_dominated_by_any_trial() {
        // synthetic 3-objective landscape over the general space with a
        // genuine trade-off: accuracy and latency pull opposite ways
        let measure = |i: usize| {
            let acc = 0.3 + 0.7 * ((i % 31) as f64 / 31.0);
            let lat = 1.0 + 9.0 * acc * acc + 0.05 * ((i % 7) as f64);
            let size = 100.0 + ((i * 13) % 97) as f64;
            (acc - 0.01 * lat, c(acc, lat, size))
        };
        let mut s = ParetoSearch::new(general_space(), 5);
        let trace = run_search(&mut s, 48, |i| Ok(measure(i))).unwrap();
        let pareto = ParetoTrace::from_trials("nsga2", &trace.trials);
        assert!(!pareto.front.is_empty());
        for f in &pareto.front {
            let fc = f.components.unwrap();
            for t in &trace.trials {
                let tc = t.components.unwrap();
                assert!(
                    !dominates(&tc, &fc),
                    "front config {} dominated by trial config {}",
                    f.config,
                    t.config
                );
            }
        }
        // the running frontier size is monotone in coverage quality but
        // never exceeds the number of unique configs seen
        assert!(pareto.front_sizes.iter().all(|&s| s >= 1));
        assert!(pareto.evaluations <= trace.trials.len());
    }

    #[test]
    fn nsga2_is_deterministic_for_a_seed() {
        let measure = |i: usize| {
            let acc = (i % 17) as f64 / 17.0;
            (acc, c(acc, 1.0 + (i % 5) as f64, 10.0 + (i % 3) as f64))
        };
        let run = || {
            let mut s = ParetoSearch::new(general_space(), 11);
            run_search(&mut s, 40, |i| Ok(measure(i))).unwrap()
        };
        let (a, b) = (run(), run());
        let cfg = |t: &super::super::SearchTrace| {
            t.trials.iter().map(|x| x.config).collect::<Vec<_>>()
        };
        assert_eq!(cfg(&a), cfg(&b));
    }

    #[test]
    fn nsga2_survives_all_nan_measurements() {
        let mut s = ParetoSearch::new(general_space(), 3);
        let trace = run_search(&mut s, 24, |_| {
            Ok((f64::NAN, c(f64::NAN, 1.0, 1.0)))
        })
        .unwrap();
        assert_eq!(trace.trials.len(), 24);
        let pareto = ParetoTrace::from_trials("nsga2", &trace.trials);
        assert!(pareto.front.is_empty(), "NaN accuracy never enters the front");
        assert!(pareto.front_sizes.iter().all(|&s| s == 0));
        assert_eq!(pareto.evaluations, 0, "nothing real was ever measured");
    }
}
