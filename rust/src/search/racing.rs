//! Multi-fidelity racing: successive halving over any search space.
//!
//! Every algorithm in this crate historically paid full evaluation-set
//! fidelity for every candidate. Racing (successive halving, Li et al.;
//! see rust/SEARCH.md "Racing") spends a [`Fidelity`] *fraction* of the
//! evaluation set on each trial instead: a generation of candidates is
//! scored on the smallest rung, the top `1/eta` fraction is promoted to
//! an `eta`-times-larger slice, and only the survivors of the last
//! promotion pay for a full-fidelity measurement.
//!
//! Rung math: with `eta` and `fidelity_min`, the rung fractions are
//! `[eta^-k, ..., eta^-1, 1]` where `k` is the largest power with
//! `eta^-k >= fidelity_min` (so `fidelity_min = 1` degenerates to the
//! single full-fidelity rung and racing reproduces [`run_search`]
//! trial-for-trial). A generation holds `eta^k` candidates, so each
//! rung after a promotion races `1/eta` of the previous rung's
//! survivors at `eta`x the fidelity -- every rung of a full generation
//! costs exactly one full-fidelity-evaluation equivalent, and a whole
//! generation costs `k + 1` equivalents instead of `eta^k`.
//!
//! Low-fidelity scores are *estimates*: they enter the trial history
//! (so an XGB proposer can learn from them -- see the fidelity feature
//! column on [`super::XgbSearch`]) and they accrue evaluation cost, but
//! the best config reported by a racing trace comes from full-fidelity
//! measurements only.
//!
//! [`run_search`]: super::run_search

use super::{Measured, SearchAlgo, SearchTrace, Trial};
use crate::util::nan_min_cmp;

/// Fraction of the evaluation set a trial is scored on, in `(0, 1]`.
///
/// A fidelity maps to a *prefix* of the evaluation set's deterministic
/// stratified batch order (see `data::Dataset::stratified_batches`), so
/// rung k's images are a subset of rung k+1's and scores are comparable
/// across promotions.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Fidelity(f64);

impl Fidelity {
    /// Full fidelity: the whole evaluation set.
    pub fn full() -> Fidelity {
        Fidelity(1.0)
    }

    /// A fractional fidelity. Errors unless `f` is finite and in
    /// `(0, 1]`.
    pub fn fraction(f: f64) -> anyhow::Result<Fidelity> {
        anyhow::ensure!(
            f.is_finite() && f > 0.0 && f <= 1.0,
            "fidelity fraction must be in (0, 1], got {f}"
        );
        Ok(Fidelity(f))
    }

    /// The fraction itself.
    pub fn value(self) -> f64 {
        self.0
    }

    /// Whether this is the full evaluation set.
    pub fn is_full(self) -> bool {
        self.0 >= 1.0
    }

    /// How many of `total` evaluation batches this fidelity covers:
    /// `ceil(fraction * total)`, at least 1 so a rung is never empty
    /// (and 0 only for an empty evaluation set).
    pub fn batches_of(self, total: usize) -> usize {
        if total == 0 {
            return 0;
        }
        if self.is_full() {
            return total;
        }
        (((self.0 * total as f64).ceil()) as usize).clamp(1, total)
    }
}

/// The ascending rung fractions `[eta^-k, ..., eta^-1, 1]` for the
/// largest `k` with `eta^-k >= fidelity_min`. Always ends at 1.0 and
/// never goes below `fidelity_min`; `fidelity_min = 1` yields `[1.0]`.
pub fn rung_fractions(fidelity_min: f64, eta: usize) -> Vec<f64> {
    let mut out = vec![1.0];
    let mut v = 1.0;
    while v / eta as f64 >= fidelity_min {
        v /= eta as f64;
        out.push(v);
    }
    out.reverse();
    out
}

/// How many of `n` rung members are promoted to the next rung: the top
/// `ceil(n / eta)`, so at least one candidate always survives.
pub fn promotion_count(n: usize, eta: usize) -> usize {
    n.div_ceil(eta.max(1))
}

/// Knobs of the successive-halving scheduler (`--eta` /
/// `--fidelity-min` on the CLI).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RacingOptions {
    /// Promotion factor: each rung keeps the top `1/eta` fraction and
    /// multiplies the fidelity by `eta`. Must be >= 2.
    pub eta: usize,
    /// Smallest rung fraction (the base-rung fidelity is the largest
    /// `eta^-k >= fidelity_min`). `1.0` disables racing: a single
    /// full-fidelity rung, trial-for-trial identical to
    /// [`super::run_search`].
    pub fidelity_min: f64,
}

impl Default for RacingOptions {
    fn default() -> Self {
        RacingOptions { eta: 4, fidelity_min: 1.0 / 16.0 }
    }
}

impl RacingOptions {
    /// Validate the knobs (finite `fidelity_min` in `(0, 1]`, `eta >= 2`).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.eta >= 2, "--eta must be >= 2, got {}", self.eta);
        anyhow::ensure!(
            self.fidelity_min.is_finite()
                && self.fidelity_min > 0.0
                && self.fidelity_min <= 1.0,
            "--fidelity-min must be in (0, 1], got {}",
            self.fidelity_min
        );
        Ok(())
    }
}

/// The successive-halving rung scheduler: races generations of
/// candidates from any [`SearchAlgo`] through ascending-fidelity rungs,
/// promoting the top `1/eta` fraction at each step.
pub struct SuccessiveHalving {
    opts: RacingOptions,
    rungs: Vec<Fidelity>,
}

impl SuccessiveHalving {
    /// Build the scheduler, validating `opts` and deriving the rung
    /// ladder.
    pub fn new(opts: RacingOptions) -> anyhow::Result<SuccessiveHalving> {
        opts.validate()?;
        let rungs = rung_fractions(opts.fidelity_min, opts.eta)
            .into_iter()
            .map(Fidelity::fraction)
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(SuccessiveHalving { opts, rungs })
    }

    /// The ascending rung fidelities (always ends at full).
    pub fn rungs(&self) -> &[Fidelity] {
        &self.rungs
    }

    /// Candidates per generation: `eta^(rungs - 1)`, sized so each
    /// promotion divides the cohort by exactly `eta` down to one
    /// full-fidelity survivor.
    pub fn generation_size(&self) -> usize {
        self.opts
            .eta
            .checked_pow((self.rungs.len() - 1) as u32)
            .unwrap_or(usize::MAX)
    }

    /// Race `algo` for up to `budget` *base-rung* proposals. `measure`
    /// is called as `(config, fidelity)` and may return anything
    /// [`super::run_search`] accepts. Every measurement (all rungs)
    /// lands in the trace with its fidelity and cost, so the history
    /// the proposer sees includes the low-fidelity estimates; the
    /// reported best comes from full-fidelity trials only.
    ///
    /// Budget accounting: `budget` bounds how many candidates the
    /// proposer contributes (the base rung); promoted re-measurements
    /// are the scheduler's own and are charged through [`Trial::cost`]
    /// instead. With `fidelity_min = 1` this is trial-for-trial
    /// identical to `run_search(algo, budget, ..)`.
    ///
    /// Errors when no full-fidelity trial ran at all (zero budget, or
    /// an algorithm that declines its first proposal).
    pub fn run<M: Into<Measured>>(
        &self,
        algo: &mut dyn SearchAlgo,
        budget: usize,
        mut measure: impl FnMut(usize, Fidelity) -> anyhow::Result<M>,
    ) -> anyhow::Result<SearchTrace> {
        let gen_size = self.generation_size();
        let mut trials: Vec<Trial> = Vec::new();
        let mut proposed = 0usize;
        let mut exhausted = false;
        while proposed < budget && !exhausted {
            // one generation of candidates from the proposer (short at
            // the budget tail or when the algorithm runs dry)
            let want = gen_size.min(budget - proposed);
            let mut cohort: Vec<usize> = Vec::with_capacity(want);
            // the whole cohort is proposed before anything is measured,
            // so a proposer that re-ranks only on new scores (the XGB
            // surrogate's argmax) may keep repeating itself: skip
            // in-cohort duplicates, bounded so a degenerate proposer
            // still yields a (short) generation instead of stalling
            let mut attempts = 0usize;
            while cohort.len() < want && attempts < 4 * want + 16 {
                attempts += 1;
                match algo.propose(&trials) {
                    Some(c) if cohort.contains(&c) => continue,
                    Some(c) => cohort.push(c),
                    None => {
                        exhausted = true;
                        break;
                    }
                }
            }
            if cohort.is_empty() {
                break;
            }
            proposed += cohort.len();
            // race the cohort up the rung ladder
            for (r, &fid) in self.rungs.iter().enumerate() {
                let mut scored: Vec<(usize, f64)> = Vec::with_capacity(cohort.len());
                for &config in &cohort {
                    let m: Measured = measure(config, fid)?.into();
                    // a budget-rejected config (-inf sentinel, see
                    // coordinator::Budget) was never actually measured,
                    // so it charges nothing
                    let cost =
                        if m.score == f64::NEG_INFINITY { 0.0 } else { fid.value() };
                    trials.push(Trial {
                        config,
                        score: m.score,
                        components: m.components,
                        fidelity: fid.value(),
                        cost,
                    });
                    scored.push((config, m.score));
                }
                if r + 1 == self.rungs.len() {
                    break;
                }
                // promote the top 1/eta (NaN ranks worst; ties keep the
                // earlier rung position, so promotion is deterministic)
                let keep = promotion_count(scored.len(), self.opts.eta);
                let mut order: Vec<usize> = (0..scored.len()).collect();
                order.sort_by(|&a, &b| {
                    nan_min_cmp(&scored[b].1, &scored[a].1).then(a.cmp(&b))
                });
                cohort = order[..keep].iter().map(|&i| scored[i].0).collect();
            }
        }
        let Some(best) = trials
            .iter()
            .copied()
            .filter(|t| t.fidelity >= 1.0)
            .max_by(|a, b| nan_min_cmp(&a.score, &b.score))
        else {
            anyhow::bail!(
                "racing over {:?} ran no full-fidelity trials (budget {budget}); \
                 raise the budget or check why the algorithm declined to propose",
                algo.name()
            );
        };
        Ok(SearchTrace {
            algo: format!("sh({})", algo.name()),
            trials,
            best_score: best.score,
            best_config: best.config,
            best_components: best.components,
        })
    }
}

/// Convenience wrapper: build a [`SuccessiveHalving`] from `opts` and
/// race `algo` for `budget` base-rung proposals.
pub fn run_racing<M: Into<Measured>>(
    algo: &mut dyn SearchAlgo,
    budget: usize,
    opts: RacingOptions,
    measure: impl FnMut(usize, Fidelity) -> anyhow::Result<M>,
) -> anyhow::Result<SearchTrace> {
    SuccessiveHalving::new(opts)?.run(algo, budget, measure)
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::super::{run_search, GridSearch, RandomSearch};
    use super::*;

    /// Synthetic oracle whose optimum orders the same at every rung:
    /// config 7 scores 1.0 everywhere, everything else strictly less.
    fn oracle(i: usize, fid: Fidelity) -> f64 {
        if i == 7 {
            1.0
        } else {
            // a touch of fidelity-dependent noise: low rungs are noisy
            // estimates, but never enough to outrank the optimum
            0.5 + 0.3 * (i % 5) as f64 / 5.0 + 0.01 * fid.value()
        }
    }

    #[test]
    fn rung_fractions_ladder() {
        assert_eq!(rung_fractions(1.0 / 16.0, 4), vec![1.0 / 16.0, 0.25, 1.0]);
        assert_eq!(rung_fractions(0.25, 2), vec![0.25, 0.5, 1.0]);
        assert_eq!(rung_fractions(1.0, 4), vec![1.0]);
        assert_eq!(rung_fractions(0.3, 4), vec![1.0]); // 1/4 < 0.3
    }

    #[test]
    fn promotion_counts() {
        assert_eq!(promotion_count(16, 4), 4);
        assert_eq!(promotion_count(4, 4), 1);
        assert_eq!(promotion_count(5, 4), 2);
        assert_eq!(promotion_count(1, 4), 1, "a lone candidate survives");
    }

    #[test]
    fn fidelity_batch_counts() {
        let f = Fidelity::fraction(1.0 / 16.0).unwrap();
        assert_eq!(f.batches_of(16), 1);
        assert_eq!(f.batches_of(4), 1, "rounds up to a whole batch");
        assert_eq!(f.batches_of(0), 0, "empty eval set stays empty");
        assert_eq!(Fidelity::full().batches_of(5), 5);
        assert!(Fidelity::fraction(0.0).is_err());
        assert!(Fidelity::fraction(1.1).is_err());
        assert!(Fidelity::fraction(f64::NAN).is_err());
    }

    #[test]
    fn invalid_options_error() {
        assert!(SuccessiveHalving::new(RacingOptions { eta: 1, fidelity_min: 0.5 })
            .is_err());
        assert!(SuccessiveHalving::new(RacingOptions { eta: 4, fidelity_min: 0.0 })
            .is_err());
        assert!(SuccessiveHalving::new(RacingOptions { eta: 4, fidelity_min: 2.0 })
            .is_err());
    }

    #[test]
    fn full_fidelity_degenerates_to_run_search() {
        // identical RNG stream on both sides => identical proposals;
        // the traces must agree trial-for-trial
        let budget = 20;
        let mut a = RandomSearch::new(96, 3);
        let plain = run_search(&mut a, budget, |i| Ok(oracle(i, Fidelity::full())))
            .unwrap();
        let mut b = RandomSearch::new(96, 3);
        let opts = RacingOptions { eta: 4, fidelity_min: 1.0 };
        let raced =
            run_racing(&mut b, budget, opts, |i, fid| Ok(oracle(i, fid))).unwrap();
        assert_eq!(raced.algo, "sh(random)");
        assert_eq!(plain.trials.len(), raced.trials.len());
        for (p, r) in plain.trials.iter().zip(&raced.trials) {
            assert_eq!(p.config, r.config);
            assert_eq!(p.score.to_bits(), r.score.to_bits());
            assert_eq!(r.fidelity, 1.0);
            assert_eq!(p.cost, r.cost);
        }
        assert_eq!(plain.best_config, raced.best_config);
        assert_eq!(plain.best_score.to_bits(), raced.best_score.to_bits());
    }

    #[test]
    fn known_best_survives_every_rung() {
        let opts = RacingOptions { eta: 4, fidelity_min: 1.0 / 16.0 };
        let sh = SuccessiveHalving::new(opts).unwrap();
        assert_eq!(sh.rungs().len(), 3);
        assert_eq!(sh.generation_size(), 16);
        let mut algo = RandomSearch::new(96, 1);
        let trace = sh.run(&mut algo, 96, |i, fid| Ok(oracle(i, fid))).unwrap();
        assert_eq!(trace.best_config, 7);
        assert_eq!(trace.best_score, 1.0);
        // config 7 was measured once at every rung fraction
        for &fid in sh.rungs() {
            assert!(
                trace
                    .trials
                    .iter()
                    .any(|t| t.config == 7 && t.fidelity == fid.value()),
                "optimum missing from rung {}",
                fid.value()
            );
        }
        // racing cost: 6 generations of 16 -> 96/16 + 24/4 + 6 = 18
        // full-fidelity equivalents vs 96 for the exhaustive sweep
        assert!((trace.total_cost() - 18.0).abs() < 1e-9, "{}", trace.total_cost());
        assert!(trace.total_cost() < 0.4 * 96.0);
    }

    #[test]
    fn budget_bounds_base_rung_proposals() {
        let opts = RacingOptions { eta: 2, fidelity_min: 0.25 };
        for budget in [1usize, 3, 4, 7, 12] {
            let mut algo = RandomSearch::new(96, 5);
            let trace =
                run_racing(&mut algo, budget, opts, |i, fid| Ok(oracle(i, fid)))
                    .unwrap();
            let base = trace
                .trials
                .iter()
                .filter(|t| t.fidelity == 0.25)
                .count();
            assert!(base <= budget, "{base} base-rung trials > budget {budget}");
            assert!(trace.trials.iter().any(|t| t.fidelity >= 1.0));
        }
    }

    #[test]
    fn algorithm_exhaustion_ends_the_race() {
        // a 6-config space exhausts mid-generation; the partial cohort
        // still races to full fidelity and the search terminates
        let opts = RacingOptions { eta: 4, fidelity_min: 1.0 / 16.0 };
        let mut algo = RandomSearch::new(6, 2);
        let trace =
            run_racing(&mut algo, 96, opts, |i, fid| Ok(oracle(i, fid))).unwrap();
        let base = trace.trials.iter().filter(|t| t.fidelity < 0.1).count();
        assert_eq!(base, 6, "every config proposed exactly once");
        assert!(trace.trials.iter().any(|t| t.fidelity >= 1.0));
    }

    #[test]
    fn zero_budget_is_an_error() {
        let opts = RacingOptions::default();
        let mut algo = GridSearch::new(12, 0);
        let err = run_racing(&mut algo, 0, opts, |i, fid| Ok(oracle(i, fid)))
            .unwrap_err();
        assert!(err.to_string().contains("no full-fidelity trials"), "{err}");
    }

    #[test]
    fn nan_scores_are_demoted_not_promoted() {
        // configs measuring NaN on the base rung must never crowd out
        // real measurements in the promotion set
        let opts = RacingOptions { eta: 4, fidelity_min: 0.25 };
        let mut algo = GridSearch::new(16, 0);
        let trace = run_racing(&mut algo, 16, opts, |i, fid| {
            Ok(if i % 2 == 0 { f64::NAN } else { oracle(i, fid) })
        })
        .unwrap();
        assert!(!trace.best_score.is_nan());
        assert_eq!(trace.best_config % 2, 1);
        for t in trace.trials.iter().filter(|t| t.fidelity >= 1.0) {
            assert!(!t.score.is_nan(), "a NaN config was promoted to full fidelity");
        }
    }

    #[test]
    fn budget_rejections_charge_nothing() {
        let opts = RacingOptions { eta: 2, fidelity_min: 0.5 };
        let mut algo = GridSearch::new(8, 0);
        let trace = run_racing(&mut algo, 8, opts, |i, fid| {
            Ok(if i >= 4 { f64::NEG_INFINITY } else { oracle(i, fid) })
        })
        .unwrap();
        for t in &trace.trials {
            if t.score == f64::NEG_INFINITY {
                assert_eq!(t.cost, 0.0);
            } else {
                assert_eq!(t.cost, t.fidelity);
            }
        }
        assert!(trace.best_score.is_finite());
    }
}
