//! Configuration search algorithms (paper §5-6.2, Fig 5/6).
//!
//! Five algorithms share one driver interface: given the history of
//! (config index, measured accuracy) pairs, propose the next config to
//! measure. `random`, `grid`, and `genetic` are the paper's baselines;
//! `xgb` is the cost-model search (Algorithm 1), and `xgb_t` adds
//! transfer learning from other models' trial databases.

use crate::quant::{ConfigSpace, SpaceRef};
use crate::util::Pcg32;
use crate::xgb::{XgbModel, XgbParams};

/// One measured trial.
#[derive(Clone, Copy, Debug)]
pub struct Trial {
    pub config: usize,
    pub accuracy: f64,
}

/// A search algorithm proposing config indices in `0..space`.
pub trait SearchAlgo {
    fn name(&self) -> &'static str;
    /// Propose the next config to measure. `history` holds every prior
    /// trial in order. Returning `None` ends the search early.
    fn propose(&mut self, history: &[Trial]) -> Option<usize>;
}

// ---------------------------------------------------------------------------
// Random search
// ---------------------------------------------------------------------------

/// Uniform random draw without replacement.
pub struct RandomSearch {
    order: Vec<usize>,
    next: usize,
}

impl RandomSearch {
    pub fn new(space: usize, seed: u64) -> Self {
        let mut order: Vec<usize> = (0..space).collect();
        Pcg32::new(seed, 11).shuffle(&mut order);
        RandomSearch { order, next: 0 }
    }
}

impl SearchAlgo for RandomSearch {
    fn name(&self) -> &'static str {
        "random"
    }

    fn propose(&mut self, _history: &[Trial]) -> Option<usize> {
        let i = self.next;
        self.next += 1;
        self.order.get(i).copied()
    }
}

// ---------------------------------------------------------------------------
// Grid search
// ---------------------------------------------------------------------------

/// Deterministic enumeration of the grid in axis-major order, starting
/// from a seed-dependent offset (the paper samples grid points; a fixed
/// origin would make the comparison depend on an arbitrary enumeration
/// choice).
pub struct GridSearch {
    space: usize,
    offset: usize,
    next: usize,
}

impl GridSearch {
    pub fn new(space: usize, seed: u64) -> Self {
        let offset = Pcg32::new(seed, 13).below(space.max(1));
        GridSearch { space, offset, next: 0 }
    }
}

impl SearchAlgo for GridSearch {
    fn name(&self) -> &'static str {
        "grid"
    }

    fn propose(&mut self, _history: &[Trial]) -> Option<usize> {
        if self.next >= self.space {
            return None;
        }
        let i = (self.offset + self.next) % self.space;
        self.next += 1;
        Some(i)
    }
}

// ---------------------------------------------------------------------------
// Genetic algorithm
// ---------------------------------------------------------------------------

/// Binary-encoded GA over a [`crate::quant::ConfigSpace`] genome (7 bits
/// for the general QuantConfig space), mirroring the R `GA` package
/// defaults the paper used: fitness = Top-1 accuracy, tournament-of-2
/// selection, single-point crossover (p=0.8), bit-flip mutation (p=0.1),
/// elitism of 1.
pub struct GeneticSearch {
    rng: Pcg32,
    space: SpaceRef,
    bits: usize,
    population: Vec<Vec<bool>>,
    pending: Vec<usize>, // population members not yet proposed this gen
    pop_size: usize,
}

impl GeneticSearch {
    pub fn new(space: SpaceRef, seed: u64) -> Self {
        let mut rng = Pcg32::new(seed, 17);
        let pop_size = 8;
        let bits = space.genome_bits().max(1);
        let population: Vec<Vec<bool>> = (0..pop_size)
            .map(|_| (0..bits).map(|_| rng.chance(0.5)).collect())
            .collect();
        GeneticSearch {
            rng,
            space,
            bits,
            population,
            pending: (0..pop_size).rev().collect(),
            pop_size,
        }
    }

    fn fitness_of(space: &dyn ConfigSpace, genome: &[bool], history: &[Trial]) -> f64 {
        let idx = space.decode(genome);
        history
            .iter()
            .rev()
            .find(|t| t.config == idx)
            .map(|t| t.accuracy)
            .unwrap_or(0.0)
    }

    fn evolve(&mut self, history: &[Trial]) {
        let fit: Vec<f64> = self
            .population
            .iter()
            .map(|g| Self::fitness_of(self.space.as_ref(), g, history))
            .collect();
        // elitism: keep the best genome
        let best = (0..self.pop_size)
            .max_by(|&a, &b| fit[a].partial_cmp(&fit[b]).unwrap())
            .unwrap();
        let mut next = vec![self.population[best].clone()];
        while next.len() < self.pop_size {
            let pa = self.tournament(&fit);
            let pb = self.tournament(&fit);
            let (mut ca, mut cb) =
                (self.population[pa].clone(), self.population[pb].clone());
            if self.bits > 1 && self.rng.chance(0.8) {
                let cut = 1 + self.rng.below(self.bits - 1);
                for i in cut..self.bits {
                    std::mem::swap(&mut ca[i], &mut cb[i]);
                }
            }
            for g in [&mut ca, &mut cb] {
                for bit in g.iter_mut() {
                    if self.rng.chance(0.1) {
                        *bit = !*bit;
                    }
                }
            }
            next.push(ca);
            if next.len() < self.pop_size {
                next.push(cb);
            }
        }
        self.population = next;
        self.pending = (0..self.pop_size).rev().collect();
    }

    fn tournament(&mut self, fit: &[f64]) -> usize {
        let a = self.rng.below(fit.len());
        let b = self.rng.below(fit.len());
        if fit[a] >= fit[b] {
            a
        } else {
            b
        }
    }
}

impl SearchAlgo for GeneticSearch {
    fn name(&self) -> &'static str {
        "genetic"
    }

    fn propose(&mut self, history: &[Trial]) -> Option<usize> {
        if self.pending.is_empty() {
            self.evolve(history);
        }
        let member = self.pending.pop()?;
        Some(self.space.decode(&self.population[member]))
    }
}

// ---------------------------------------------------------------------------
// XGBoost search (Algorithm 1) + transfer learning
// ---------------------------------------------------------------------------

/// A historical record for transfer learning: features + accuracy from
/// another model's tuning run (the database D of §5.2).
#[derive(Clone, Debug)]
pub struct TransferRecord {
    pub features: Vec<f32>,
    pub accuracy: f32,
}

/// Cost-model search: refit XGBoost on everything measured so far (plus
/// transfer records), then propose the unexplored config with the highest
/// predicted accuracy (§5.2.3: "enumerate the entire space of S_e and
/// pick the top candidate ... not explored in the previous step").
pub struct XgbSearch {
    /// features of every config in the space (arch features ++ one-hot)
    space_features: Vec<Vec<f32>>,
    transfer: Vec<TransferRecord>,
    /// cost-model hyper-parameters (public for the ablation bench)
    pub params: XgbParams,
    rng: Pcg32,
    name: &'static str,
}

impl XgbSearch {
    /// Individual learning (cold start).
    pub fn new(space_features: Vec<Vec<f32>>, seed: u64) -> Self {
        XgbSearch {
            space_features,
            transfer: Vec::new(),
            params: XgbParams::default(),
            rng: Pcg32::new(seed, 23),
            name: "xgb",
        }
    }

    /// Transfer learning: warm-start from other models' databases.
    pub fn with_transfer(
        space_features: Vec<Vec<f32>>,
        transfer: Vec<TransferRecord>,
        seed: u64,
    ) -> Self {
        XgbSearch {
            space_features,
            transfer,
            params: XgbParams::default(),
            rng: Pcg32::new(seed, 23),
            name: "xgb_t",
        }
    }

    /// The fitted cost model for the current history (also used by the
    /// Fig 3 feature-importance bench).
    pub fn fit_cost_model(&self, history: &[Trial]) -> Option<XgbModel> {
        let mut xs: Vec<Vec<f32>> = Vec::new();
        let mut ys: Vec<f32> = Vec::new();
        for r in &self.transfer {
            xs.push(r.features.clone());
            ys.push(r.accuracy);
        }
        for t in history {
            xs.push(self.space_features[t.config].clone());
            ys.push(t.accuracy as f32);
        }
        if xs.is_empty() {
            return None;
        }
        // scale model capacity with the sample count: deep ensembles on a
        // handful of rows memorize them and generalize arbitrarily to the
        // unexplored region, which stalls the search
        let mut params = self.params;
        params.max_depth = params.max_depth.min(1 + xs.len() / 6).max(1);
        params.n_trees = params.n_trees.min(10 + 3 * xs.len());
        XgbModel::fit(&xs, &ys, params).ok()
    }
}

impl SearchAlgo for XgbSearch {
    fn name(&self) -> &'static str {
        self.name
    }

    fn propose(&mut self, history: &[Trial]) -> Option<usize> {
        let explored: std::collections::HashSet<usize> =
            history.iter().map(|t| t.config).collect();
        let unexplored: Vec<usize> = (0..self.space_features.len())
            .filter(|i| !explored.contains(i))
            .collect();
        if unexplored.is_empty() {
            return None;
        }
        match self.fit_cost_model(history) {
            None => {
                // cold start with no data at all: random first probe
                Some(unexplored[self.rng.below(unexplored.len())])
            }
            Some(model) => {
                // "pick the top candidate ... considering diversity"
                // (§5.2.3): break prediction ties uniformly at random
                // instead of by index, so plateaus of the young cost
                // model spread probes across the space
                let preds: Vec<f32> = unexplored
                    .iter()
                    .map(|&i| model.predict(&self.space_features[i]))
                    .collect();
                let best = preds.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let ties: Vec<usize> = unexplored
                    .iter()
                    .copied()
                    .zip(&preds)
                    .filter(|(_, &p)| p >= best - 1e-6)
                    .map(|(i, _)| i)
                    .collect();
                Some(ties[self.rng.below(ties.len())])
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Search driver
// ---------------------------------------------------------------------------

/// Full trace of one search run.
#[derive(Clone, Debug)]
pub struct SearchTrace {
    pub algo: String,
    pub trials: Vec<Trial>,
    pub best_accuracy: f64,
    pub best_config: usize,
}

impl SearchTrace {
    /// First trial index (1-based) whose accuracy is within `eps` of
    /// `target`. `None` if never reached.
    pub fn trials_to_reach(&self, target: f64, eps: f64) -> Option<usize> {
        self.trials
            .iter()
            .position(|t| t.accuracy >= target - eps)
            .map(|i| i + 1)
    }

    /// Best accuracy after the first `n` trials.
    pub fn best_after(&self, n: usize) -> f64 {
        self.trials
            .iter()
            .take(n)
            .map(|t| t.accuracy)
            .fold(f64::NEG_INFINITY, f64::max)
    }
}

/// Run a search algorithm for `budget` proposals, measuring via
/// `measure` (which may serve cached values -- duplicate proposals from
/// the GA still count as trials, as they would on real hardware).
///
/// Errors when no trial ran at all (a zero budget, or an algorithm that
/// declines its very first proposal) -- there is no best config to
/// report in that case.
pub fn run_search(
    algo: &mut dyn SearchAlgo,
    budget: usize,
    mut measure: impl FnMut(usize) -> anyhow::Result<f64>,
) -> anyhow::Result<SearchTrace> {
    let mut trials = Vec::with_capacity(budget);
    for _ in 0..budget {
        let Some(config) = algo.propose(&trials) else { break };
        let accuracy = measure(config)?;
        trials.push(Trial { config, accuracy });
    }
    let Some(best) = trials
        .iter()
        .copied()
        .max_by(|a, b| a.accuracy.partial_cmp(&b.accuracy).unwrap())
    else {
        anyhow::bail!(
            "search {:?} ran no trials (budget {budget}); raise the budget or check \
             why the algorithm declined to propose",
            algo.name()
        );
    };
    Ok(SearchTrace {
        algo: algo.name().to_string(),
        trials,
        best_accuracy: best.accuracy,
        best_config: best.config,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{general_space, vta_space, QuantConfig};

    /// Synthetic oracle with one sharp optimum.
    fn oracle(i: usize) -> f64 {
        let peak = 61;
        1.0 - ((i as f64 - peak as f64).abs() / 96.0)
    }

    fn features(space: usize) -> Vec<Vec<f32>> {
        (0..space).map(|i| QuantConfig::from_index(i).unwrap().one_hot()).collect()
    }

    #[test]
    fn random_covers_space_without_repeats() {
        let mut s = RandomSearch::new(96, 1);
        let mut seen = std::collections::HashSet::new();
        let mut hist = Vec::new();
        for _ in 0..96 {
            let i = s.propose(&hist).unwrap();
            assert!(seen.insert(i), "repeat {i}");
            hist.push(Trial { config: i, accuracy: 0.0 });
        }
        assert_eq!(seen.len(), 96);
        assert!(s.propose(&hist).is_none());
    }

    #[test]
    fn grid_enumerates_all() {
        let mut s = GridSearch::new(12, 5);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..12 {
            seen.insert(s.propose(&[]).unwrap());
        }
        assert_eq!(seen.len(), 12);
    }

    #[test]
    fn genetic_improves_over_generations() {
        let mut s = GeneticSearch::new(general_space(), 3);
        let trace = run_search(&mut s, 96, |i| Ok(oracle(i))).unwrap();
        // after 12 generations the GA should be near the optimum
        assert!(
            trace.best_accuracy > 0.9,
            "GA best {} too far from optimum",
            trace.best_accuracy
        );
    }

    #[test]
    fn genetic_stays_in_range_on_small_spaces() {
        // the 4-bit VTA genome wraps its calib field; every proposal must
        // still land inside the 12-element space
        let space = vta_space();
        let mut s = GeneticSearch::new(space.clone(), 5);
        let trace = run_search(&mut s, 40, |i| {
            assert!(i < space.size(), "GA proposed {i} outside the VTA space");
            Ok(oracle(i))
        })
        .unwrap();
        assert_eq!(trace.trials.len(), 40);
    }

    #[test]
    fn zero_budget_is_an_error_not_a_panic() {
        let mut s = RandomSearch::new(96, 1);
        let err = run_search(&mut s, 0, |_| Ok(0.5)).unwrap_err();
        assert!(err.to_string().contains("no trials"), "{err}");
    }

    #[test]
    fn declining_first_proposal_is_an_error_not_a_panic() {
        // an exhausted random search proposes None immediately
        struct Never;
        impl SearchAlgo for Never {
            fn name(&self) -> &'static str {
                "never"
            }
            fn propose(&mut self, _history: &[Trial]) -> Option<usize> {
                None
            }
        }
        let err = run_search(&mut Never, 10, |_| Ok(0.5)).unwrap_err();
        assert!(err.to_string().contains("never"), "{err}");
    }

    #[test]
    fn xgb_converges_faster_than_random_on_structured_oracle() {
        // structured oracle: accuracy depends additively on config axes,
        // which is the structure the cost model exploits
        let structured = |i: usize| {
            let c = QuantConfig::from_index(i).unwrap();
            let mut a = 0.5;
            if c.scheme == crate::quant::Scheme::Asymmetric {
                a += 0.2;
            }
            if c.clip == crate::quant::Clipping::Kl {
                a += 0.15;
            }
            if c.calib == crate::quant::CalibCount::C512 {
                a += 0.1;
            }
            a
        };
        let target = 0.95;
        let n_seeds = 20;
        let mut best_xgb = Vec::new();
        let mut best_rnd = Vec::new();
        for seed in 0..n_seeds {
            let mut x = XgbSearch::new(features(96), seed);
            let tx = run_search(&mut x, 96, |i| Ok(structured(i))).unwrap();
            best_xgb.push(tx.trials_to_reach(target, 1e-9).unwrap() as f64);
            let mut r = RandomSearch::new(96, seed);
            let tr = run_search(&mut r, 96, |i| Ok(structured(i))).unwrap();
            best_rnd.push(tr.trials_to_reach(target, 1e-9).unwrap() as f64);
        }
        let mx: f64 = best_xgb.iter().sum::<f64>() / n_seeds as f64;
        let mr: f64 = best_rnd.iter().sum::<f64>() / n_seeds as f64;
        assert!(mx < mr, "xgb mean {mx} should beat random mean {mr}");
    }

    #[test]
    fn transfer_warm_start_proposes_good_first_config() {
        // transfer database from a "different model" with the same
        // structure: xgb_t's FIRST proposal should already be good
        let structured = |i: usize| {
            let c = QuantConfig::from_index(i).unwrap();
            if c.clip == crate::quant::Clipping::Kl {
                0.9
            } else {
                0.5
            }
        };
        let feats = features(96);
        let transfer: Vec<TransferRecord> = (0..96)
            .map(|i| TransferRecord {
                features: feats[i].clone(),
                accuracy: structured(i) as f32,
            })
            .collect();
        let mut s = XgbSearch::with_transfer(feats.clone(), transfer, 1);
        let first = s.propose(&[]).unwrap();
        assert_eq!(
            QuantConfig::from_index(first).unwrap().clip,
            crate::quant::Clipping::Kl
        );
    }

    #[test]
    fn trace_metrics() {
        let trace = SearchTrace {
            algo: "x".into(),
            trials: vec![
                Trial { config: 0, accuracy: 0.2 },
                Trial { config: 1, accuracy: 0.8 },
                Trial { config: 2, accuracy: 0.5 },
            ],
            best_accuracy: 0.8,
            best_config: 1,
        };
        assert_eq!(trace.trials_to_reach(0.8, 0.0), Some(2));
        assert_eq!(trace.trials_to_reach(0.9, 0.0), None);
        assert_eq!(trace.best_after(1), 0.2);
        assert_eq!(trace.best_after(3), 0.8);
    }
}
