//! Configuration search algorithms (paper §5-6.2, Fig 5/6).
//!
//! Six algorithms share one driver interface: given the history of
//! (config index, measured score) pairs, propose the next config to
//! measure. `random`, `grid`, and `genetic` are the paper's baselines;
//! `xgb` is the cost-model search (Algorithm 1), `xgb_t` adds transfer
//! learning from other models' trial databases, and `nsga2`
//! ([`ParetoSearch`], module [`pareto`]) searches for the whole
//! accuracy/latency/size Pareto frontier instead of a scalar optimum.
//! rust/SEARCH.md is the user-facing guide to all six.
//!
//! The score every algorithm maximizes is whatever the measure closure
//! returns: plain Top-1 accuracy for the paper's experiments, or a
//! scalarized multi-objective value (accuracy / predicted latency /
//! model bytes, see `coordinator::objective`) -- the algorithms are
//! objective-agnostic. A [`Measured`] result optionally carries the
//! per-component breakdown, which [`SearchTrace`] preserves per trial.
//!
//! Ranking is NaN-safe throughout: a NaN score (e.g. a database hole
//! propagated through an oracle table) degrades to "worst" instead of
//! panicking in a comparator (see [`crate::util::nan_min_cmp`]).
//!
//! Module [`racing`] layers multi-fidelity successive halving
//! ([`SuccessiveHalving`], `--algo sh`) over any of the scalar
//! algorithms: generations are ranked on a small evaluation-set slice
//! and only promoted survivors pay full fidelity, with every trial
//! recording the [`Fidelity`] it was scored at and the evaluation cost
//! it charged.

#![deny(clippy::unwrap_used)]

pub mod ip_alloc;
pub mod pareto;
pub mod racing;

pub use ip_alloc::{allocate, allocate_for_space, AllocOption, Allocation};
pub use pareto::{
    crowding_distance, dominates, non_dominated_sort, ParetoSearch, ParetoTrace,
};
pub use racing::{
    promotion_count, rung_fractions, run_racing, Fidelity, RacingOptions,
    SuccessiveHalving,
};

use crate::quant::{ConfigSpace, SpaceRef};
use crate::util::{nan_min_cmp, Pcg32};
use crate::xgb::{XgbModel, XgbParams};

/// Per-component breakdown of one measurement (the three objective axes
/// of the deployment story: Top-1 accuracy, predicted per-image latency
/// on the deploy target, and serialized quantized model bytes).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Components {
    /// Measured Top-1 accuracy.
    pub accuracy: f64,
    /// Modeled per-image latency on the deploy target (milliseconds).
    pub latency_ms: f64,
    /// Serialized quantized model bytes.
    pub size_bytes: f64,
}

/// What a measure closure hands back to [`run_search`]: the scalar the
/// algorithms maximize, plus (for multi-objective runs) the component
/// breakdown behind it. A bare `f64` converts to an accuracy-only
/// measurement, so existing accuracy-tuning closures work unchanged.
#[derive(Clone, Copy, Debug)]
pub struct Measured {
    /// The scalar the search maximizes.
    pub score: f64,
    /// Per-axis breakdown for multi-objective measurements.
    pub components: Option<Components>,
}

impl From<f64> for Measured {
    fn from(score: f64) -> Measured {
        Measured { score, components: None }
    }
}

impl From<(f64, Components)> for Measured {
    fn from((score, components): (f64, Components)) -> Measured {
        Measured { score, components: Some(components) }
    }
}

/// One measured trial.
#[derive(Clone, Copy, Debug)]
pub struct Trial {
    /// Config index within the space being searched.
    pub config: usize,
    /// The scalar objective value being maximized (Top-1 accuracy when
    /// tuning accuracy alone).
    pub score: f64,
    /// Component breakdown when the measurement was multi-objective.
    pub components: Option<Components>,
    /// Fraction of the evaluation set this trial was scored on (1.0 for
    /// every non-racing trial; see [`racing::Fidelity`]).
    pub fidelity: f64,
    /// Evaluation cost charged, in full-fidelity-evaluation
    /// equivalents: the fidelity fraction for measured trials, 0.0 for
    /// budget-rejected (`-inf` sentinel) trials that never ran.
    pub cost: f64,
}

impl Trial {
    /// Accuracy-only trial (score IS the Top-1 accuracy), measured at
    /// full fidelity.
    pub fn of(config: usize, score: f64) -> Trial {
        Trial { config, score, components: None, fidelity: 1.0, cost: 1.0 }
    }

    /// Full-fidelity trial with a component breakdown.
    pub fn scored(config: usize, score: f64, components: Components) -> Trial {
        Trial { config, score, components: Some(components), fidelity: 1.0, cost: 1.0 }
    }

    /// The measured Top-1 accuracy: the component breakdown's when one
    /// was recorded, the scalar score otherwise.
    pub fn accuracy(&self) -> f64 {
        self.components.map_or(self.score, |c| c.accuracy)
    }
}

/// A search algorithm proposing config indices in `0..space`.
pub trait SearchAlgo {
    /// CLI name of the algorithm ("random", "xgb", ...).
    fn name(&self) -> &'static str;
    /// Propose the next config to measure. `history` holds every prior
    /// trial in order. Returning `None` ends the search early.
    fn propose(&mut self, history: &[Trial]) -> Option<usize>;
}

// ---------------------------------------------------------------------------
// Random search
// ---------------------------------------------------------------------------

/// Uniform random draw without replacement.
pub struct RandomSearch {
    order: Vec<usize>,
    next: usize,
}

impl RandomSearch {
    /// Random search over a space of `space` configs.
    pub fn new(space: usize, seed: u64) -> Self {
        let mut order: Vec<usize> = (0..space).collect();
        Pcg32::new(seed, 11).shuffle(&mut order);
        RandomSearch { order, next: 0 }
    }
}

impl SearchAlgo for RandomSearch {
    fn name(&self) -> &'static str {
        "random"
    }

    fn propose(&mut self, _history: &[Trial]) -> Option<usize> {
        let i = self.next;
        self.next += 1;
        self.order.get(i).copied()
    }
}

// ---------------------------------------------------------------------------
// Grid search
// ---------------------------------------------------------------------------

/// Deterministic enumeration of the grid in axis-major order, starting
/// from a seed-dependent offset (the paper samples grid points; a fixed
/// origin would make the comparison depend on an arbitrary enumeration
/// choice).
pub struct GridSearch {
    space: usize,
    offset: usize,
    next: usize,
}

impl GridSearch {
    /// Grid enumeration over a space of `space` configs.
    pub fn new(space: usize, seed: u64) -> Self {
        let offset = Pcg32::new(seed, 13).below(space.max(1));
        GridSearch { space, offset, next: 0 }
    }
}

impl SearchAlgo for GridSearch {
    fn name(&self) -> &'static str {
        "grid"
    }

    fn propose(&mut self, _history: &[Trial]) -> Option<usize> {
        if self.next >= self.space {
            return None;
        }
        let i = (self.offset + self.next) % self.space;
        self.next += 1;
        Some(i)
    }
}

// ---------------------------------------------------------------------------
// Genetic algorithm
// ---------------------------------------------------------------------------

/// Uniform random population of `pop_size` genomes of `bits` bits (the
/// shared initializer of [`GeneticSearch`] and [`ParetoSearch`]).
fn random_population(rng: &mut Pcg32, pop_size: usize, bits: usize) -> Vec<Vec<bool>> {
    (0..pop_size)
        .map(|_| (0..bits).map(|_| rng.chance(0.5)).collect())
        .collect()
}

/// Breed `count` children from `parents` with the shared variation
/// operators of [`GeneticSearch`] and [`ParetoSearch`]: two `select`
/// draws per pair, single-point crossover (p=0.8), per-bit flip
/// mutation (p=0.1), children pushed in pairs (the odd trailing child
/// is mutated before being dropped, so the RNG stream does not depend
/// on `count`'s parity).
fn breed(
    rng: &mut Pcg32,
    parents: &[Vec<bool>],
    bits: usize,
    count: usize,
    mut select: impl FnMut(&mut Pcg32) -> usize,
) -> Vec<Vec<bool>> {
    let mut next: Vec<Vec<bool>> = Vec::with_capacity(count);
    while next.len() < count {
        let pa = select(rng);
        let pb = select(rng);
        let (mut ca, mut cb) = (parents[pa].clone(), parents[pb].clone());
        if bits > 1 && rng.chance(0.8) {
            let cut = 1 + rng.below(bits - 1);
            for i in cut..bits {
                std::mem::swap(&mut ca[i], &mut cb[i]);
            }
        }
        for g in [&mut ca, &mut cb] {
            for bit in g.iter_mut() {
                if rng.chance(0.1) {
                    *bit = !*bit;
                }
            }
        }
        next.push(ca);
        if next.len() < count {
            next.push(cb);
        }
    }
    next
}

/// Binary-encoded GA over a [`crate::quant::ConfigSpace`] genome (9 bits
/// for the general QuantConfig space), mirroring the R `GA` package
/// defaults the paper used: fitness = the measured score, tournament-of-2
/// selection, single-point crossover (p=0.8), bit-flip mutation (p=0.1),
/// elitism of 1. A NaN score counts as the worst possible fitness, so a
/// poisoned trial can never be selected as the elite.
pub struct GeneticSearch {
    rng: Pcg32,
    space: SpaceRef,
    bits: usize,
    population: Vec<Vec<bool>>,
    pending: Vec<usize>, // population members not yet proposed this gen
    pop_size: usize,
}

impl GeneticSearch {
    /// GA over `space`'s genome (binary bits or wrapped mixed-radix
    /// digit fields -- the layer-wise radix space encodes each width
    /// digit in `ceil(log2 R)` bits, and out-of-range fields wrap).
    pub fn new(space: SpaceRef, seed: u64) -> Self {
        let mut rng = Pcg32::new(seed, 17);
        let pop_size = 8;
        let bits = space.genome_bits().max(1);
        let population = random_population(&mut rng, pop_size, bits);
        GeneticSearch {
            rng,
            space,
            bits,
            population,
            pending: (0..pop_size).rev().collect(),
            pop_size,
        }
    }

    /// GA whose initial population is warm-started from `seeds` (config
    /// indices, best first -- e.g. the trial store's best-known configs
    /// for this model x space). Up to a full population of seeds is
    /// encoded as genomes (proposed first, in order); the remainder of
    /// the population stays random. The RNG is constructed exactly as in
    /// [`GeneticSearch::new`], so an empty `seeds` slice reproduces the
    /// unseeded search bit-for-bit. Errors if a seed index is outside
    /// the space.
    pub fn with_seeds(space: SpaceRef, seed: u64, seeds: &[usize]) -> anyhow::Result<Self> {
        let mut rng = Pcg32::new(seed, 17);
        let pop_size = 8;
        let bits = space.genome_bits().max(1);
        let mut population: Vec<Vec<bool>> = Vec::with_capacity(pop_size);
        for &cfg in seeds.iter().take(pop_size) {
            let mut genome = space.encode(cfg)?;
            genome.resize(bits, false);
            population.push(genome);
        }
        let fill = pop_size - population.len();
        population.extend(random_population(&mut rng, fill, bits));
        Ok(GeneticSearch {
            rng,
            space,
            bits,
            population,
            pending: (0..pop_size).rev().collect(),
            pop_size,
        })
    }

    fn fitness_of(space: &dyn ConfigSpace, genome: &[bool], history: &[Trial]) -> f64 {
        let idx = space.decode(genome);
        history
            .iter()
            .rev()
            .find(|t| t.config == idx)
            // a NaN measurement degrades to the worst fitness instead of
            // poisoning the elitism/tournament comparisons below; an
            // unmeasured genome ranks the same -- a 0.0 default would
            // OUTRANK measured genomes under objectives whose scores go
            // negative (latency/size penalties), inverting selection
            .map(|t| if t.score.is_nan() { f64::NEG_INFINITY } else { t.score })
            .unwrap_or(f64::NEG_INFINITY)
    }

    fn evolve(&mut self, history: &[Trial]) {
        let fit: Vec<f64> = self
            .population
            .iter()
            .map(|g| Self::fitness_of(self.space.as_ref(), g, history))
            .collect();
        // elitism: keep the best genome (population is never empty)
        let best = (0..self.pop_size)
            .max_by(|&a, &b| nan_min_cmp(&fit[a], &fit[b]))
            .expect("non-empty GA population");
        let mut next = vec![self.population[best].clone()];
        // tournament-of-2 parent selection on the scalar fitness
        next.extend(breed(
            &mut self.rng,
            &self.population,
            self.bits,
            self.pop_size - 1,
            |rng| {
                let a = rng.below(fit.len());
                let b = rng.below(fit.len());
                if fit[a] >= fit[b] {
                    a
                } else {
                    b
                }
            },
        ));
        self.population = next;
        self.pending = (0..self.pop_size).rev().collect();
    }
}

impl SearchAlgo for GeneticSearch {
    fn name(&self) -> &'static str {
        "genetic"
    }

    fn propose(&mut self, history: &[Trial]) -> Option<usize> {
        if self.pending.is_empty() {
            self.evolve(history);
        }
        let member = self.pending.pop()?;
        Some(self.space.decode(&self.population[member]))
    }
}

// ---------------------------------------------------------------------------
// XGBoost search (Algorithm 1) + transfer learning
// ---------------------------------------------------------------------------

/// A historical record for transfer learning: features + accuracy from
/// another model's tuning run (the database D of §5.2).
#[derive(Clone, Debug)]
pub struct TransferRecord {
    /// Arch features ++ config features of the historical trial.
    pub features: Vec<f32>,
    /// Its measured accuracy.
    pub accuracy: f32,
    /// Fraction of the evaluation set the accuracy was measured on
    /// (1.0 for legacy / non-racing records). Fed to the surrogate as
    /// an extra feature column so low-fidelity racing estimates still
    /// train it without being mistaken for full measurements.
    pub fidelity: f32,
}

impl TransferRecord {
    /// A full-fidelity transfer record (the common, non-racing case).
    pub fn full(features: Vec<f32>, accuracy: f32) -> TransferRecord {
        TransferRecord { features, accuracy, fidelity: 1.0 }
    }
}

/// `features` with the fidelity column appended -- the row layout the
/// XGB surrogate trains on and predicts with (predictions always ask
/// at full fidelity).
fn with_fidelity(features: &[f32], fidelity: f32) -> Vec<f32> {
    let mut row = Vec::with_capacity(features.len() + 1);
    row.extend_from_slice(features);
    row.push(fidelity);
    row
}

/// Cost-model search: refit XGBoost on everything measured so far (plus
/// transfer records), then propose the unexplored config with the highest
/// predicted accuracy (§5.2.3: "enumerate the entire space of S_e and
/// pick the top candidate ... not explored in the previous step").
pub struct XgbSearch {
    /// features of every config in the space (arch features ++ one-hot)
    space_features: Vec<Vec<f32>>,
    transfer: Vec<TransferRecord>,
    /// cost-model hyper-parameters (public for the ablation bench)
    pub params: XgbParams,
    rng: Pcg32,
    name: &'static str,
    // incremental training cache: the finite rows already drained from
    // `transfer` and from the trial history, so each generation's refit
    // appends only the rows past the two watermarks below instead of
    // re-extracting the full table (the search-side consumer of the
    // store's `records_since` watermark design)
    xs: Vec<Vec<f32>>,
    ys: Vec<f32>,
    transfer_seen: usize,
    history_seen: usize,
}

impl XgbSearch {
    /// Individual learning (cold start).
    pub fn new(space_features: Vec<Vec<f32>>, seed: u64) -> Self {
        XgbSearch {
            space_features,
            transfer: Vec::new(),
            params: XgbParams::default(),
            rng: Pcg32::new(seed, 23),
            name: "xgb",
            xs: Vec::new(),
            ys: Vec::new(),
            transfer_seen: 0,
            history_seen: 0,
        }
    }

    /// Transfer learning: warm-start from other models' databases.
    pub fn with_transfer(
        space_features: Vec<Vec<f32>>,
        transfer: Vec<TransferRecord>,
        seed: u64,
    ) -> Self {
        XgbSearch {
            space_features,
            transfer,
            params: XgbParams::default(),
            rng: Pcg32::new(seed, 23),
            name: "xgb_t",
            xs: Vec::new(),
            ys: Vec::new(),
            transfer_seen: 0,
            history_seen: 0,
        }
    }

    /// Append freshly harvested transfer records (e.g. a watermark
    /// refresh of the trial store via `coordinator::TransferCursor`);
    /// the next refit absorbs exactly the new rows. Rows enter the
    /// training set in arrival order, so records added mid-run land
    /// after already-cached history rows -- the row *set* stays
    /// identical to a full re-extraction, only the order differs.
    pub fn extend_transfer(&mut self, records: impl IntoIterator<Item = TransferRecord>) {
        self.transfer.extend(records);
    }

    /// Drain rows the training cache has not absorbed yet: transfer
    /// records first, then history trials past the watermark. Non-finite
    /// rows are skipped exactly as in [`XgbSearch::fit_cost_model`] but
    /// still advance the watermarks (they teach nothing and are never
    /// revisited). Called by every [`XgbSearch::propose`]; public so the
    /// watermark-equivalence tests can sync without proposing.
    pub fn sync_rows(&mut self, history: &[Trial]) {
        for r in &self.transfer[self.transfer_seen..] {
            if r.accuracy.is_finite() {
                self.xs.push(with_fidelity(&r.features, r.fidelity));
                self.ys.push(r.accuracy);
            }
        }
        self.transfer_seen = self.transfer.len();
        for t in &history[self.history_seen.min(history.len())..] {
            if t.score.is_finite() {
                self.xs
                    .push(with_fidelity(&self.space_features[t.config], t.fidelity as f32));
                self.ys.push(t.score as f32);
            }
        }
        self.history_seen = self.history_seen.max(history.len());
    }

    /// The cached finite training rows `(features, labels)` the next
    /// refit will use. Equals what [`XgbSearch::fit_cost_model`] would
    /// extract from scratch whenever the transfer set was fixed at
    /// construction (the watermark-equivalence tests assert this).
    pub fn training_rows(&self) -> (&[Vec<f32>], &[f32]) {
        (&self.xs, &self.ys)
    }

    /// Fit from the incremental cache, with the same capacity scaling
    /// as [`XgbSearch::fit_cost_model`].
    fn fit_cached(&self) -> Option<XgbModel> {
        if self.xs.is_empty() {
            return None;
        }
        let mut params = self.params;
        params.max_depth = params.max_depth.min(1 + self.xs.len() / 6).max(1);
        params.n_trees = params.n_trees.min(10 + 3 * self.xs.len());
        XgbModel::fit(&self.xs, &self.ys, params).ok()
    }

    /// The fitted cost model for the current history, extracted from
    /// scratch (also used by the Fig 3 feature-importance bench; the
    /// search loop itself refits incrementally via the row cache).
    pub fn fit_cost_model(&self, history: &[Trial]) -> Option<XgbModel> {
        let mut xs: Vec<Vec<f32>> = Vec::new();
        let mut ys: Vec<f32> = Vec::new();
        // non-finite rows would poison every gradient of the fit -- NaN
        // from a poisoned measurement, -inf from a budget-rejected
        // config (see coordinator::Budget): skip them (the trial still
        // counts against the budget, it just teaches nothing)
        for r in &self.transfer {
            if !r.accuracy.is_finite() {
                continue;
            }
            xs.push(with_fidelity(&r.features, r.fidelity));
            ys.push(r.accuracy);
        }
        for t in history {
            if !t.score.is_finite() {
                continue;
            }
            xs.push(with_fidelity(&self.space_features[t.config], t.fidelity as f32));
            ys.push(t.score as f32);
        }
        if xs.is_empty() {
            return None;
        }
        // scale model capacity with the sample count: deep ensembles on a
        // handful of rows memorize them and generalize arbitrarily to the
        // unexplored region, which stalls the search
        let mut params = self.params;
        params.max_depth = params.max_depth.min(1 + xs.len() / 6).max(1);
        params.n_trees = params.n_trees.min(10 + 3 * xs.len());
        XgbModel::fit(&xs, &ys, params).ok()
    }
}

impl SearchAlgo for XgbSearch {
    fn name(&self) -> &'static str {
        self.name
    }

    fn propose(&mut self, history: &[Trial]) -> Option<usize> {
        let explored: std::collections::HashSet<usize> =
            history.iter().map(|t| t.config).collect();
        let unexplored: Vec<usize> = (0..self.space_features.len())
            .filter(|i| !explored.contains(i))
            .collect();
        if unexplored.is_empty() {
            return None;
        }
        self.sync_rows(history);
        match self.fit_cached() {
            None => {
                // cold start with no data at all: random first probe
                Some(unexplored[self.rng.below(unexplored.len())])
            }
            Some(model) => {
                // "pick the top candidate ... considering diversity"
                // (§5.2.3): break prediction ties uniformly at random
                // instead of by index, so plateaus of the young cost
                // model spread probes across the space
                // candidates are predicted AT full fidelity: the
                // surrogate learned from (features, fidelity) rows, and
                // the question asked of it is always "how good would
                // this config be on the whole evaluation set"
                let preds: Vec<f32> = unexplored
                    .iter()
                    .map(|&i| model.predict(&with_fidelity(&self.space_features[i], 1.0)))
                    .collect();
                let best = preds.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let ties: Vec<usize> = unexplored
                    .iter()
                    .copied()
                    .zip(&preds)
                    .filter(|(_, &p)| p >= best - 1e-6)
                    .map(|(i, _)| i)
                    .collect();
                Some(ties[self.rng.below(ties.len())])
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Search driver
// ---------------------------------------------------------------------------

/// Full trace of one search run. `best_score` is the maximum measured
/// scalar (Top-1 accuracy for accuracy-only runs); `best_components` is
/// its breakdown when the run was multi-objective.
#[derive(Clone, Debug)]
pub struct SearchTrace {
    /// Name of the algorithm that ran.
    pub algo: String,
    /// Every trial, in measurement order.
    pub trials: Vec<Trial>,
    /// Maximum measured scalar score.
    pub best_score: f64,
    /// Config index achieving [`SearchTrace::best_score`].
    pub best_config: usize,
    /// Component breakdown of the best trial (multi-objective runs).
    pub best_components: Option<Components>,
}

impl SearchTrace {
    /// First trial index (1-based) whose score is within `eps` of
    /// `target`. `None` if never reached.
    ///
    /// NaN contract: a NaN `target` is explicitly unreachable (`None`) --
    /// there is no score "within eps of NaN" -- and NaN trial *scores*
    /// never satisfy the threshold (every comparison against NaN is
    /// false), so poisoned trials are skipped rather than matched.
    pub fn trials_to_reach(&self, target: f64, eps: f64) -> Option<usize> {
        if target.is_nan() {
            return None;
        }
        self.trials
            .iter()
            .position(|t| t.score >= target - eps)
            .map(|i| i + 1)
    }

    /// Best score after the first `n` trials.
    ///
    /// NaN contract: NaN scores are ignored ([`f64::max`] keeps the
    /// other operand), so the result is the best *real* score in the
    /// prefix -- and `-inf` when the prefix is empty or all-NaN.
    pub fn best_after(&self, n: usize) -> f64 {
        self.trials
            .iter()
            .take(n)
            .map(|t| t.score)
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// Total evaluation cost of the run in full-fidelity-evaluation
    /// equivalents: the sum of every trial's [`Trial::cost`]. For a
    /// plain (non-racing) search this equals the number of measured
    /// trials (budget-rejected `-inf` trials charge nothing); racing
    /// traces come in well below their trial count.
    pub fn total_cost(&self) -> f64 {
        self.trials.iter().map(|t| t.cost).sum()
    }

    /// Evaluation cost spent up to and including the first
    /// *full-fidelity* trial whose score is within `eps` of `target`
    /// (the cost-weighted twin of [`SearchTrace::trials_to_reach`]).
    /// Earlier trials' cost accrues whatever their fidelity, but a
    /// partial-fidelity score is only an estimate and cannot satisfy
    /// the threshold. `None` if never reached; a NaN `target` is
    /// unreachable and NaN scores never match, exactly as in
    /// [`SearchTrace::trials_to_reach`].
    pub fn cost_to_reach(&self, target: f64, eps: f64) -> Option<f64> {
        if target.is_nan() {
            return None;
        }
        let mut spent = 0.0;
        for t in &self.trials {
            spent += t.cost;
            if t.fidelity >= 1.0 && t.score >= target - eps {
                return Some(spent);
            }
        }
        None
    }
}

/// Run a search algorithm for `budget` proposals, measuring via
/// `measure` (which may serve cached values -- duplicate proposals from
/// the GA still count as trials, as they would on real hardware). The
/// closure may return a bare `f64` (accuracy-only tuning) or a
/// `(score, Components)` pair / [`Measured`] for multi-objective runs.
///
/// Errors when no trial ran at all (a zero budget, or an algorithm that
/// declines its very first proposal) -- there is no best config to
/// report in that case. A NaN score is kept in the trace but ranks
/// below every real measurement, so it can never become the best.
pub fn run_search<M: Into<Measured>>(
    algo: &mut dyn SearchAlgo,
    budget: usize,
    mut measure: impl FnMut(usize) -> anyhow::Result<M>,
) -> anyhow::Result<SearchTrace> {
    let mut trials = Vec::with_capacity(budget);
    for _ in 0..budget {
        let Some(config) = algo.propose(&trials) else { break };
        let m: Measured = measure(config)?.into();
        // full fidelity; a budget-rejected config (-inf sentinel, see
        // coordinator::Budget) was never actually measured, so it
        // charges no evaluation cost
        let cost = if m.score == f64::NEG_INFINITY { 0.0 } else { 1.0 };
        trials.push(Trial {
            config,
            score: m.score,
            components: m.components,
            fidelity: 1.0,
            cost,
        });
    }
    let Some(best) = trials
        .iter()
        .copied()
        .max_by(|a, b| nan_min_cmp(&a.score, &b.score))
    else {
        anyhow::bail!(
            "search {:?} ran no trials (budget {budget}); raise the budget or check \
             why the algorithm declined to propose",
            algo.name()
        );
    };
    Ok(SearchTrace {
        algo: algo.name().to_string(),
        trials,
        best_score: best.score,
        best_config: best.config,
        best_components: best.components,
    })
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::quant::{general_space, vta_space, QuantConfig};

    /// Synthetic oracle with one sharp optimum.
    fn oracle(i: usize) -> f64 {
        let peak = 61;
        1.0 - ((i as f64 - peak as f64).abs() / 96.0)
    }

    fn features(space: usize) -> Vec<Vec<f32>> {
        (0..space).map(|i| QuantConfig::from_index(i).unwrap().one_hot()).collect()
    }

    #[test]
    fn random_covers_space_without_repeats() {
        let mut s = RandomSearch::new(96, 1);
        let mut seen = std::collections::HashSet::new();
        let mut hist = Vec::new();
        for _ in 0..96 {
            let i = s.propose(&hist).unwrap();
            assert!(seen.insert(i), "repeat {i}");
            hist.push(Trial::of(i, 0.0));
        }
        assert_eq!(seen.len(), 96);
        assert!(s.propose(&hist).is_none());
    }

    #[test]
    fn grid_enumerates_all() {
        let mut s = GridSearch::new(12, 5);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..12 {
            seen.insert(s.propose(&[]).unwrap());
        }
        assert_eq!(seen.len(), 12);
    }

    #[test]
    fn genetic_improves_over_generations() {
        let mut s = GeneticSearch::new(general_space(), 3);
        let trace = run_search(&mut s, 96, |i| Ok(oracle(i))).unwrap();
        // after 12 generations the GA should be near the optimum
        assert!(
            trace.best_score > 0.9,
            "GA best {} too far from optimum",
            trace.best_score
        );
    }

    #[test]
    fn genetic_stays_in_range_on_small_spaces() {
        // the 4-bit VTA genome wraps its calib field; every proposal must
        // still land inside the 12-element space
        let space = vta_space();
        let mut s = GeneticSearch::new(space.clone(), 5);
        let trace = run_search(&mut s, 40, |i| {
            assert!(i < space.size(), "GA proposed {i} outside the VTA space");
            Ok(oracle(i))
        })
        .unwrap();
        assert_eq!(trace.trials.len(), 40);
    }

    #[test]
    fn zero_budget_is_an_error_not_a_panic() {
        let mut s = RandomSearch::new(96, 1);
        let err = run_search(&mut s, 0, |_| Ok(0.5)).unwrap_err();
        assert!(err.to_string().contains("no trials"), "{err}");
    }

    #[test]
    fn declining_first_proposal_is_an_error_not_a_panic() {
        // an exhausted random search proposes None immediately
        struct Never;
        impl SearchAlgo for Never {
            fn name(&self) -> &'static str {
                "never"
            }
            fn propose(&mut self, _history: &[Trial]) -> Option<usize> {
                None
            }
        }
        let err = run_search(&mut Never, 10, |_| Ok(0.5)).unwrap_err();
        assert!(err.to_string().contains("never"), "{err}");
    }

    #[test]
    fn nan_measurements_never_win_the_trace() {
        // every odd config measures NaN: the search must not panic, and
        // the best must come from the real measurements only
        let mut s = GridSearch::new(12, 0);
        let trace = run_search(&mut s, 12, |i| {
            Ok(if i % 2 == 1 { f64::NAN } else { oracle(i) })
        })
        .unwrap();
        assert_eq!(trace.trials.len(), 12);
        assert!(!trace.best_score.is_nan());
        assert_eq!(trace.best_config % 2, 0);
    }

    #[test]
    fn genetic_survives_nan_fitness() {
        // a NaN score in the history flows through elitism + tournament
        // selection on every generation; 40 trials = 5 generations
        let space = vta_space();
        let mut s = GeneticSearch::new(space, 9);
        let trace = run_search(&mut s, 40, |i| {
            Ok(if i % 3 == 0 { f64::NAN } else { oracle(i) })
        })
        .unwrap();
        assert_eq!(trace.trials.len(), 40);
        // the elite genome is never a NaN-scored one (NEG_INFINITY fitness)
        assert!(trace.best_config % 3 != 0, "NaN config won: {}", trace.best_config);
    }

    #[test]
    fn all_nan_degrades_to_a_nan_best_without_panicking() {
        let mut s = GridSearch::new(4, 0);
        let trace = run_search(&mut s, 4, |_| Ok(f64::NAN)).unwrap();
        assert!(trace.best_score.is_nan());
    }

    #[test]
    fn measured_components_flow_into_the_trace() {
        let comp = |i: usize| Components {
            accuracy: oracle(i),
            latency_ms: 2.0 + i as f64,
            size_bytes: 1000.0 - i as f64,
        };
        let mut s = GridSearch::new(8, 0);
        let trace =
            run_search(&mut s, 8, |i| Ok((oracle(i) - 0.01 * i as f64, comp(i)))).unwrap();
        for t in &trace.trials {
            let c = t.components.expect("multi-objective trial keeps components");
            assert_eq!(c.accuracy, oracle(t.config));
            assert_eq!(t.accuracy(), oracle(t.config));
        }
        let best = trace.best_components.unwrap();
        assert_eq!(best.accuracy, oracle(trace.best_config));
        // accuracy-only closures leave components empty
        let mut s2 = GridSearch::new(4, 0);
        let t2 = run_search(&mut s2, 4, |i| Ok(oracle(i))).unwrap();
        assert!(t2.trials.iter().all(|t| t.components.is_none()));
        assert!(t2.best_components.is_none());
    }

    #[test]
    fn xgb_converges_faster_than_random_on_structured_oracle() {
        // structured oracle: accuracy depends additively on config axes,
        // which is the structure the cost model exploits
        let structured = |i: usize| {
            let c = QuantConfig::from_index(i).unwrap();
            let mut a = 0.5;
            if c.scheme == crate::quant::Scheme::Asymmetric {
                a += 0.2;
            }
            if c.clip == crate::quant::Clipping::Kl {
                a += 0.15;
            }
            if c.calib == crate::quant::CalibCount::C512 {
                a += 0.1;
            }
            a
        };
        let target = 0.95;
        let n_seeds = 20;
        let mut best_xgb = Vec::new();
        let mut best_rnd = Vec::new();
        for seed in 0..n_seeds {
            let mut x = XgbSearch::new(features(96), seed);
            let tx = run_search(&mut x, 96, |i| Ok(structured(i))).unwrap();
            best_xgb.push(tx.trials_to_reach(target, 1e-9).unwrap() as f64);
            let mut r = RandomSearch::new(96, seed);
            let tr = run_search(&mut r, 96, |i| Ok(structured(i))).unwrap();
            best_rnd.push(tr.trials_to_reach(target, 1e-9).unwrap() as f64);
        }
        let mx: f64 = best_xgb.iter().sum::<f64>() / n_seeds as f64;
        let mr: f64 = best_rnd.iter().sum::<f64>() / n_seeds as f64;
        assert!(mx < mr, "xgb mean {mx} should beat random mean {mr}");
    }

    #[test]
    fn xgb_survives_neg_infinity_scores() {
        // budget-rejected trials score -inf; an unfiltered -inf label
        // would drive the fit's base score to -inf, every prediction to
        // NaN, and the tie-break set empty (a below(0) panic)
        let mut s = XgbSearch::new(features(96), 3);
        let trace = run_search(&mut s, 40, |i| {
            Ok(if i % 2 == 0 { f64::NEG_INFINITY } else { oracle(i) })
        })
        .unwrap();
        assert_eq!(trace.trials.len(), 40);
        assert!(trace.best_score.is_finite());
        assert_eq!(trace.best_config % 2, 1, "-inf config won: {}", trace.best_config);
    }

    #[test]
    fn transfer_warm_start_proposes_good_first_config() {
        // transfer database from a "different model" with the same
        // structure: xgb_t's FIRST proposal should already be good
        let structured = |i: usize| {
            let c = QuantConfig::from_index(i).unwrap();
            if c.clip == crate::quant::Clipping::Kl {
                0.9
            } else {
                0.5
            }
        };
        let feats = features(96);
        let transfer: Vec<TransferRecord> = (0..96)
            .map(|i| TransferRecord::full(feats[i].clone(), structured(i) as f32))
            .collect();
        let mut s = XgbSearch::with_transfer(feats.clone(), transfer, 1);
        let first = s.propose(&[]).unwrap();
        assert_eq!(
            QuantConfig::from_index(first).unwrap().clip,
            crate::quant::Clipping::Kl
        );
    }

    #[test]
    fn trace_metrics() {
        let trace = SearchTrace {
            algo: "x".into(),
            trials: vec![
                Trial::of(0, 0.2),
                Trial::of(1, 0.8),
                Trial::of(2, 0.5),
            ],
            best_score: 0.8,
            best_config: 1,
            best_components: None,
        };
        assert_eq!(trace.trials_to_reach(0.8, 0.0), Some(2));
        assert_eq!(trace.trials_to_reach(0.9, 0.0), None);
        assert_eq!(trace.best_after(1), 0.2);
        assert_eq!(trace.best_after(3), 0.8);
    }

    #[test]
    fn trace_cost_accounting() {
        // per-trial cost: full-fidelity trials charge 1.0, partial
        // trials their fraction, budget-rejected (-inf) trials nothing
        let partial = |config, score, fidelity| Trial {
            config,
            score,
            components: None,
            fidelity,
            cost: fidelity,
        };
        let mut rejected = Trial::of(9, f64::NEG_INFINITY);
        rejected.cost = 0.0;
        let trace = SearchTrace {
            algo: "sh(x)".into(),
            trials: vec![
                partial(0, 0.9, 0.25), // low-fidelity estimate of 0.9
                partial(1, 0.3, 0.25),
                rejected,
                partial(0, 0.85, 1.0),
            ],
            best_score: 0.85,
            best_config: 0,
            best_components: None,
        };
        assert_eq!(trace.total_cost(), 1.5);
        // trials_to_reach counts trials (the estimate matches first);
        // cost_to_reach weighs by cost AND requires full fidelity
        assert_eq!(trace.trials_to_reach(0.9, 0.0), Some(1));
        assert_eq!(trace.cost_to_reach(0.9, 0.0), None);
        assert_eq!(trace.cost_to_reach(0.85, 0.0), Some(1.5));
        assert_eq!(trace.cost_to_reach(f64::NAN, 0.0), None);
        // a plain run_search trace: cost == measured-trial count, and
        // cost_to_reach degenerates to trials_to_reach
        let mut s = GridSearch::new(8, 0);
        let plain = run_search(&mut s, 8, |i| Ok(oracle(i))).unwrap();
        assert_eq!(plain.total_cost(), 8.0);
        assert!(plain.trials.iter().all(|t| t.fidelity == 1.0 && t.cost == 1.0));
        assert_eq!(
            plain.cost_to_reach(plain.best_score, 1e-9),
            plain.trials_to_reach(plain.best_score, 1e-9).map(|n| n as f64)
        );
        // -inf (budget-rejected) trials charge nothing in run_search too
        let mut s2 = GridSearch::new(8, 0);
        let gated = run_search(&mut s2, 8, |i| {
            Ok(if i % 2 == 0 { f64::NEG_INFINITY } else { oracle(i) })
        })
        .unwrap();
        assert_eq!(gated.total_cost(), 4.0);
    }

    #[test]
    fn xgb_rows_carry_the_fidelity_column() {
        // transfer + history rows end with their fidelity; predictions
        // (exercised via propose) ask at full fidelity
        let feats = features(96);
        let transfer = vec![
            TransferRecord { features: feats[0].clone(), accuracy: 0.5, fidelity: 0.25 },
            TransferRecord::full(feats[1].clone(), 0.7),
        ];
        let mut s = XgbSearch::with_transfer(feats.clone(), transfer, 1);
        let mut low = Trial::of(2, 0.62);
        low.fidelity = 0.0625;
        low.cost = 0.0625;
        s.sync_rows(&[low, Trial::of(3, 0.8)]);
        let (xs, ys) = s.training_rows();
        assert_eq!(xs.len(), 4);
        let fid_col: Vec<f32> = xs.iter().map(|r| *r.last().unwrap()).collect();
        assert_eq!(fid_col, vec![0.25, 1.0, 0.0625, 1.0]);
        assert_eq!(ys, &[0.5, 0.7, 0.62, 0.8]);
        for (row, want) in xs.iter().zip([&feats[0], &feats[1], &feats[2], &feats[3]]) {
            assert_eq!(&row[..row.len() - 1], want.as_slice());
        }
    }

    #[test]
    fn trace_metrics_nan_contract() {
        let trace = SearchTrace {
            algo: "x".into(),
            trials: vec![
                Trial::of(0, f64::NAN),
                Trial::of(1, 0.6),
                Trial::of(2, f64::NAN),
            ],
            best_score: 0.6,
            best_config: 1,
            best_components: None,
        };
        // a NaN target is unreachable by contract, even with a huge eps
        assert_eq!(trace.trials_to_reach(f64::NAN, 0.0), None);
        assert_eq!(trace.trials_to_reach(f64::NAN, f64::INFINITY), None);
        // NaN scores never satisfy a real threshold; trial 2 (1-based)
        // is the first real score that does
        assert_eq!(trace.trials_to_reach(0.5, 0.0), Some(2));
        // best_after skips NaN scores instead of propagating them
        assert_eq!(trace.best_after(1), f64::NEG_INFINITY);
        assert_eq!(trace.best_after(2), 0.6);
        assert_eq!(trace.best_after(3), 0.6);
    }
}
