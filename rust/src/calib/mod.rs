//! Calibration phase (paper §3, dashed path of Fig 1).
//!
//! Runs the instrumented model over the selected calibration images and
//! accumulates a histogram per quantization point. The instrumented
//! execution is either the `{model}_acts.hlo.txt` PJRT executable (the
//! production path: Glow's "instrumented code") or the rust interpreter
//! (bit-equivalent fallback used in tests / when artifacts are absent).

use std::path::Path;

use anyhow::Result;

use crate::data::{select_calibration_images, Dataset};
use crate::quant::{CalibCount, Histogram};
use crate::runtime::Runtime;
use crate::zoo::ZooModel;

/// The calibration cache of one (model, image-count) pair: one histogram
/// per quantization point, in `graph.quant_points()` order.
#[derive(Clone)]
pub struct CalibrationCache {
    /// Model the cache was collected for.
    pub model: String,
    /// Calibration image count the cache was built from.
    pub count: CalibCount,
    /// One activation histogram per quantization point.
    pub hists: Vec<Histogram>,
    /// wall-clock seconds spent building the cache (Table 2 bookkeeping)
    pub build_secs: f64,
}

/// Which engine runs the instrumented forward.
pub enum CalibBackend<'a> {
    /// PJRT executable from the artifacts directory.
    Hlo {
        /// PJRT runtime handle.
        runtime: &'a Runtime,
        /// Directory holding the `{model}_acts.hlo.txt` artifact.
        artifacts: &'a Path,
    },
    /// Pure-rust interpreter.
    Interp,
}

/// Build the calibration cache for `count` images drawn from `pool`.
///
/// The image selector (paper Fig 1) draws a deterministic random subset;
/// `seed` controls the draw so the three caches are reproducible.
pub fn calibrate(
    model: &ZooModel,
    pool: &Dataset,
    count: CalibCount,
    backend: &CalibBackend,
    seed: u64,
) -> Result<CalibrationCache> {
    let timer = crate::util::Timer::start();
    let idx = select_calibration_images(pool.n, count.images(), seed);
    let qpoints = model.graph.quant_points();
    let mut hists = vec![Histogram::new(); qpoints.len()];

    match backend {
        CalibBackend::Interp => {
            let interp = crate::interp::Interpreter::new(&model.graph, model.weights_map());
            // interpreter batches of 32 keep memory modest; the forwards
            // fan out across the worker pool while histogram updates stay
            // in chunk order, so the cache is bit-identical to a serial
            // run. Fan out one window at a time: only ~2 chunks per
            // worker of captured activations are ever resident at once.
            let workers = crate::util::pool::Pool::auto();
            let chunks: Vec<&[usize]> = idx.chunks(32).collect();
            for window in chunks.chunks(workers.threads().saturating_mul(2).max(1)) {
                let acts_per = workers.map(
                    window,
                    |chunk| -> Result<Vec<crate::ir::Tensor>> {
                        let x = pool.batch(chunk);
                        let (_, acts) = interp.forward_acts(&x)?;
                        Ok(acts)
                    },
                )?;
                for acts in acts_per {
                    for (h, t) in hists.iter_mut().zip(&acts?) {
                        h.update(&t.data);
                    }
                }
            }
        }
        CalibBackend::Hlo { runtime, artifacts } => {
            let exe =
                runtime.load(&artifacts.join(format!("{}_acts.hlo.txt", model.name)))?;
            let flat = model.weights.flat();
            for chunk in idx.chunks(model.batch) {
                let (x, valid) = pool.batch_padded(chunk, model.batch);
                let mut inputs: Vec<&crate::ir::Tensor> = vec![&x];
                inputs.extend(flat.iter().copied());
                let acts = exe.run_f32(&inputs)?;
                anyhow::ensure!(
                    acts.len() == qpoints.len(),
                    "acts artifact returned {} tensors, graph has {} quant points",
                    acts.len(),
                    qpoints.len()
                );
                for (h, t) in hists.iter_mut().zip(&acts) {
                    // batch-padded rows repeat the last image; histogram
                    // only the first `valid` images' activations
                    let per_image = t.data.len() / model.batch;
                    h.update(&t.data[..valid * per_image]);
                }
            }
        }
    }

    Ok(CalibrationCache {
        model: model.name.clone(),
        count,
        hists,
        build_secs: timer.secs(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::CalibCount;

    #[test]
    fn selector_subset_sizes() {
        // the three paper cache sizes at our scale
        for (c, n) in [(CalibCount::C1, 1), (CalibCount::C64, 64), (CalibCount::C512, 512)]
        {
            assert_eq!(c.images(), n);
            let idx = select_calibration_images(512, c.images(), 1);
            assert_eq!(idx.len(), n);
        }
    }
}
