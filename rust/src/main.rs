//! quantune CLI: the user-facing driver.
//!
//! ```text
//! quantune info      [--artifacts DIR]
//! quantune sweep     [--models mn,..] [--backend hlo|interp] [--force]
//!                    [--space general|vta|layerwise] [--layers K] [--bits 4,8,16]
//! quantune search    [--models mn,..] [--algo xgb_t] [--seed N] [--budget N]
//!                    [--space general|vta|layerwise] [--layers K] [--bits 4,8,16]
//!                    [--objective acc|lat|size|balanced] [--device a53|i7|2080ti]
//!                    [--budget-lat-ms X] [--budget-bytes X]
//!                    [--fidelity-min X] [--eta N]      # multi-fidelity racing
//! quantune quantize  [--models mn,..] [--config IDX]   # deploy report
//!                    [--clip max|kl|aciq] [--bias-correct]
//! quantune vta       [--models mn,..]                  # integer-only path
//! quantune latency   [--models mn,..] [--reps N]
//! quantune db status|table|export|migrate [--space TAG] [--format csv|json] [--out F]
//! ```
//!
//! `--space` selects the quantization search space: the 288-element
//! general space (Eq. 1 extended with the analytical-PTQ axes), the
//! 12-element VTA integer-only space (Eq. 23), or a per-model layer-wise
//! mixed-precision space built from a calibration-driven fragility
//! ranking of the top `--layers K` weighted layers on top of the model's
//! best known base config.
//!
//! `--clip max|kl|aciq` and `--bias-correct` override the corresponding
//! axes of the resolved config: the clipping policy (absolute-max, KL
//! entropy minimization, or the analytical ACIQ threshold) and whether
//! the per-channel quantization-error mean is folded into the layer
//! biases. `quantize` applies them to its deploy config; `sweep` /
//! `search --space layerwise` apply them to the base config the
//! layer-wise space is built on.
//!
//! `--bits` sets the per-layer width menu of the layer-wise space: a CSV
//! of integer weight widths (`4`, `8`, `16`), each free layer choosing
//! one of them or the fp32 bypass (always included). The default `8`
//! reproduces the binary {int8, fp32} mask; `--bits 4,8,16` searches the
//! full mixed-radix genome. Wider menus consume more genome bits, so the
//! `--layers` cap shrinks (12 free layers for the binary menu, 6 for the
//! 4-way radix).
//!
//! `--objective` selects what the search maximizes: plain Top-1
//! accuracy (`acc`, the paper's objective) or a weighted scalarization
//! that also prices modeled deployment latency (`lat`), serialized
//! model bytes (`size`), or both (`balanced`). Latency comes from the
//! `--device` profile for the general/layer-wise spaces and from VTA
//! cycle totals for the VTA space. Without an artifacts directory,
//! `search` falls back to the self-contained synthetic model, so the
//! multi-objective path runs from a clean checkout.
//!
//! `db` inspects and manages the persistent trial store (the paper's
//! database D, §5.2): `status` prints backend / record / segment /
//! space / model / device counts, `table` prints the best-known
//! accuracy table for each model in a space, `export` dumps every
//! record as CSV or JSON, and `migrate` converts a legacy
//! `database.json` into the crash-safe segmented trial log (round-trip
//! verified before anything is replaced). `--seed-from-db` warm-starts
//! the GA / NSGA-II initial populations from the store's best-known
//! configs for the target space. See rust/BENCHMARKS.md for the log
//! format and index semantics.
//!
//! `--algo nsga2` searches for the whole Pareto *frontier* over
//! (accuracy, latency, bytes) instead of one scalarized optimum, and
//! prints the recovered front. `--budget-lat-ms` / `--budget-bytes` add
//! hard deployment budgets (epsilon-constraint) to any algorithm:
//! configs whose static cost model exceeds a budget are rejected before
//! their accuracy is ever measured. See rust/SEARCH.md for the
//! algorithm-by-algorithm guide.
//!
//! `--fidelity-min X` / `--eta N` turn any scalar search into a
//! multi-fidelity *race* (successive halving): whole generations are
//! ranked on a cheap stratified fraction of the eval set and only the
//! top `1/eta` survive to the next, `eta`-times-larger fraction, so
//! most configs are rejected at a fraction of the full measurement
//! cost. `--algo sh` is the plain scheduler over random proposals;
//! combined with `--algo xgb`/`xgb_t`, the cost model learns from
//! fidelity-tagged rows. nsga2 does not race (its Pareto ranking needs
//! full component vectors). See the racing section of rust/SEARCH.md.
//!
//! Everything the CLI does is also exposed as library API; the benches in
//! rust/benches regenerate the paper's tables and figures.

use anyhow::{Context, Result};

use quantune::calib::{calibrate, CalibBackend};
use quantune::config::Cli;
use quantune::coordinator::{
    records_equal, write_atomic, Budget, DeviceProfile, Evaluator, HloEvaluator,
    InterpEvaluator, ObjectiveWeights, OracleEvaluator, Quantune, Record, Store, ALGORITHMS,
    DEVICES, GENERAL_SPACE_TAG,
};
use quantune::quant::{
    general_space, max_layers_for, model_size_bytes, model_size_fp32,
    parse_bits_spec, vta_space, Clipping, ConfigSpace, Granularity, QuantConfig,
    SpaceRef, VtaConfig, MAX_LAYERWISE_BITS,
};
use quantune::runtime::Runtime;
use quantune::search::RacingOptions;
use quantune::util::{fmt_duration, Json, Pool, Timer};
use quantune::vta::VtaModel;
use quantune::zoo;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        print_help();
        std::process::exit(2);
    }
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    eprintln!(
        "quantune -- post-training quantization auto-tuner (paper reproduction)\n\
         commands: info | sweep | search | quantize | vta | latency | db\n\
         common options: --artifacts DIR --models mn,shn,... --seed N\n\
         space options:  --space general|vta|layerwise --layers K (layerwise cap)\n\
                         --bits 4,8,16 (layer-wise width menu; default 8 = {{int8,fp32}})\n\
         config axes:    --clip max|kl|aciq --bias-correct (override the resolved config)\n\
         objectives:     --objective acc|lat|size|balanced --device a53|i7|2080ti\n\
         constraints:    --budget-lat-ms X --budget-bytes X (reject before measuring)\n\
         frontier:       --algo nsga2 (Pareto-front search; see rust/SEARCH.md)\n\
         racing:         --fidelity-min X --eta N (successive halving; --algo sh)\n\
         warm start:     --seed-from-db (GA/NSGA-II populations from the trial store)\n\
         trial store:    db status|table|export|migrate [--format csv|json] [--out F]\n\
         env: QUANTUNE_THREADS=N sizes the worker pool (default: all cores)\n\
         see README.md and rust/BENCHMARKS.md for details"
    );
}

/// Apply the `--clip` / `--bias-correct` axis overrides to a resolved
/// config. Absent options leave the config untouched, so the overrides
/// compose with whatever source picked it (the database's best, the
/// TensorRT-like baseline, or an explicit `--config IDX`).
fn apply_config_overrides(cli: &Cli, mut cfg: QuantConfig) -> Result<QuantConfig> {
    if let Some(name) = cli.opt("clip") {
        cfg.clip = Clipping::parse(name)
            .ok_or_else(|| anyhow::anyhow!("unknown --clip {name:?} (try max|kl|aciq)"))?;
    }
    if cli.flag("bias-correct") {
        cfg.bias_correct = true;
    }
    Ok(cfg)
}

/// Resolve `--space` for one model. The layer-wise space builds on the
/// model's best known general config (falling back to the TensorRT-like
/// baseline when no sweep/search ran yet), freeing the `--layers K`
/// most fragile layers to choose among the `--bits` width menu.
fn resolve_space(cli: &Cli, q: &Quantune, model: &zoo::ZooModel) -> Result<SpaceRef> {
    match cli.opt_or("space", "general").as_str() {
        "general" => Ok(general_space()),
        "vta" => Ok(vta_space()),
        "layerwise" => {
            let base = match q.db.best_general(&model.name) {
                Some((cfg, _)) => cfg,
                None => {
                    eprintln!(
                        "[{}] no general-space trials in the database; building the \
                         layer-wise space on the TensorRT-like baseline",
                        model.name
                    );
                    Quantune::tensorrt_like_baseline()
                }
            };
            let base = apply_config_overrides(cli, base)?;
            let widths = parse_bits_spec(&cli.opt_or("bits", "8"))?;
            let max_k = max_layers_for(&widths);
            let k = cli.opt_usize("layers", 4.min(max_k))?;
            anyhow::ensure!(
                (1..=max_k).contains(&k),
                "--layers {k} is out of range for this --bits menu: the layer-wise \
                 genome is capped at {MAX_LAYERWISE_BITS} bits, so K must be in \
                 1..={max_k}"
            );
            q.layerwise_space(model, base, k, &widths)
        }
        other => anyhow::bail!("unknown space {other:?} (try general|vta|layerwise)"),
    }
}

fn run(args: &[String]) -> Result<()> {
    let cli = Cli::parse(args)?;
    if cli.command != "db" {
        if let Some(action) = &cli.action {
            anyhow::bail!(
                "unexpected positional argument {action:?} (only `db` takes an action)"
            );
        }
    }
    match cli.command.as_str() {
        "info" => cmd_info(&cli),
        "sweep" => cmd_sweep(&cli),
        "search" => cmd_search(&cli),
        "quantize" => cmd_quantize(&cli),
        "vta" => cmd_vta(&cli),
        "latency" => cmd_latency(&cli),
        "db" => cmd_db(&cli),
        "help" | "--help" => {
            print_help();
            Ok(())
        }
        other => anyhow::bail!("unknown command {other:?}"),
    }
}

fn cmd_info(cli: &Cli) -> Result<()> {
    let q = Quantune::open(cli.artifacts())?;
    println!("artifacts: {}", q.artifacts.display());
    println!("eval images: {}, calib pool: {}", q.eval.n, q.calib_pool.n);
    println!("database records: {} ({} backend)", q.db.len(), q.db.backend());
    println!("search space: {} configs (Eq. 1)", QuantConfig::SPACE_SIZE);
    for name in cli.models() {
        match q.load_model(&name) {
            Ok(m) => println!(
                "  {:4} {:18} {:>8} params {:>11} MACs fp32 top1 {:.2}% [{} quant points]",
                m.name,
                zoo::full_name(&m.name),
                m.graph.num_params(),
                m.graph.macs()?,
                m.fp32_top1 * 100.0,
                m.graph.quant_points().len(),
            ),
            Err(e) => println!("  {name:4} unavailable: {e}"),
        }
    }
    Ok(())
}

fn cmd_sweep(cli: &Cli) -> Result<()> {
    let mut q = Quantune::open(cli.artifacts())?;
    q.device = parse_device(cli)?; // prices the per-record latency column
    let backend = cli.opt_or("backend", "hlo");
    let runtime = if backend == "hlo" { Some(Runtime::cpu()?) } else { None };
    for name in cli.models() {
        let model = q.load_model(&name)?;
        let space = resolve_space(cli, &q, &model)?;
        let size = space.size();
        let timer = Timer::start();
        let artifacts = q.artifacts.clone();
        let (calib_pool, eval) = (q.calib_pool.clone(), q.eval.clone());
        let table = match &runtime {
            Some(rt) => {
                let mut evaluator =
                    HloEvaluator::new(&model, rt, artifacts, &calib_pool, &eval, q.seed)
                        .with_space(space.clone());
                q.sweep(&model, space.as_ref(), &mut evaluator, cli.flag("force"), |i, acc| {
                    if i % 16 == 15 {
                        println!(
                            "  [{name}] {}/{size} latest top1 {:.2}%",
                            i + 1,
                            acc * 100.0
                        );
                    }
                })?
            }
            None => {
                // interp backend: the configs fan out across the pool
                let evaluator = InterpEvaluator::new(&model, &calib_pool, &eval, q.seed)
                    .with_space(space.clone());
                q.sweep_parallel(
                    &model,
                    space.as_ref(),
                    &evaluator,
                    cli.flag("force"),
                    &Pool::auto(),
                    |done, acc| {
                        if done % 16 == 0 {
                            println!(
                                "  [{name}] {done}/{size} latest top1 {:.2}%",
                                acc * 100.0
                            );
                        }
                    },
                )?
            }
        };
        let best = table
            .iter()
            .enumerate()
            .max_by(|a, b| quantune::util::nan_min_cmp(a.1, b.1))
            .context("empty sweep table")?;
        println!(
            "{name}: best {} top1 {:.2}% (fp32 {:.2}%) in {}",
            space.describe(best.0)?,
            best.1 * 100.0,
            model.fp32_top1 * 100.0,
            fmt_duration(timer.secs()),
        );
    }
    Ok(())
}

/// Parse `--device` (deploy target of the latency objective).
fn parse_device(cli: &Cli) -> Result<DeviceProfile> {
    match cli.opt("device") {
        None => Ok(DEVICES[1]), // i7-8700
        Some(key) => DeviceProfile::by_key(key).copied().ok_or_else(|| {
            anyhow::anyhow!(
                "unknown device {key:?} (try one of {:?})",
                DeviceProfile::KEYS
            )
        }),
    }
}

/// Racing knobs: `--algo sh` or an explicit `--eta` / `--fidelity-min`
/// turns the scalar search into a successive-halving race; `None` means
/// the plain flat trial loop.
fn parse_racing(cli: &Cli, algo: &str) -> Result<Option<RacingOptions>> {
    let on =
        algo == "sh" || cli.opt("eta").is_some() || cli.opt("fidelity-min").is_some();
    if !on {
        return Ok(None);
    }
    let defaults = RacingOptions::default();
    let opts = RacingOptions {
        eta: cli.opt_usize("eta", defaults.eta)?,
        fidelity_min: cli
            .opt_budget_f64("fidelity-min")?
            .unwrap_or(defaults.fidelity_min),
    };
    opts.validate()?;
    Ok(Some(opts))
}

fn cmd_search(cli: &Cli) -> Result<()> {
    let algo = cli.opt_or("algo", "xgb_t");
    anyhow::ensure!(
        ALGORITHMS.contains(&algo.as_str()),
        "--algo must be one of {ALGORITHMS:?}"
    );
    let racing = parse_racing(cli, &algo)?;
    anyhow::ensure!(
        racing.is_none() || algo != "nsga2",
        "nsga2 does not race (Pareto ranking needs full component vectors); \
         drop --fidelity-min / --eta"
    );
    let weights = ObjectiveWeights::parse(&cli.opt_or("objective", "acc"))?;
    let limits = Budget {
        max_latency_ms: cli.opt_budget_f64("budget-lat-ms")?,
        max_size_bytes: cli.opt_budget_f64("budget-bytes")?,
    };
    let device = parse_device(cli)?;
    let seed = cli.opt_u64("seed", 7)?;
    // the synthetic fallback covers exactly the clean-checkout case: the
    // DEFAULT artifacts directory is absent. An explicit --artifacts
    // path (typo) or a present-but-broken directory (corrupt database)
    // must stay a hard error, not a silent switch to a different model.
    let artifacts = cli.artifacts();
    let synthetic = cli.opt("artifacts").is_none() && !artifacts.exists();
    let (mut q, models) = if synthetic {
        if cli.opt("models").is_some() {
            eprintln!("[search] no artifacts; ignoring --models");
        }
        eprintln!(
            "[search] no artifacts at {}; tuning the self-contained synthetic model",
            artifacts.display()
        );
        (Quantune::synthetic(), vec![Quantune::synthetic_model()?])
    } else {
        let q = Quantune::open(artifacts)?;
        let models = cli
            .models()
            .iter()
            .map(|n| q.load_model(n))
            .collect::<Result<Vec<_>>>()?;
        (q, models)
    };
    q.device = device;
    q.seed_from_db = cli.flag("seed-from-db");
    for model in &models {
        let name = &model.name;
        let space = resolve_space(cli, &q, model)?;
        let budget = cli.opt_usize("budget", space.size())?;
        // search against the sweep oracle when this space's ground truth
        // is in the database (fast, identical ground truth); fall back to
        // live interpreter measurement otherwise
        let table = q.db.accuracy_table(name, &space.tag(), space.size());
        let have_oracle = table.iter().any(|a| !a.is_nan());
        // real models measure the general space through the sweep oracle
        // only (a live full-space HLO pass belongs to `sweep`); the
        // synthetic fallback measures any space through the interpreter
        anyhow::ensure!(
            have_oracle || synthetic || space.tag() != GENERAL_SPACE_TAG,
            "{name}: no sweep in database -- run `quantune sweep` first"
        );
        let (calib_pool, eval) = (q.calib_pool.clone(), q.eval.clone());
        let mut oracle;
        let mut interp;
        let evaluator: &mut dyn Evaluator = if have_oracle {
            oracle = OracleEvaluator::new(table);
            &mut oracle
        } else {
            interp = InterpEvaluator::new(model, &calib_pool, &eval, q.seed)
                .with_space(space.clone());
            &mut interp
        };
        // xgb_t with nothing to transfer from is an error in the library
        // (the experiment drivers must not silently change algorithm);
        // the CLI degrades to cold-start xgb with a notice instead
        let algo = if algo == "xgb_t"
            && !q.db.has_transfer_records(name, &space.tag())
        {
            eprintln!(
                "[{name}] no other-model trials in the {:?} space; \
                 falling back to cold-start xgb",
                space.tag()
            );
            "xgb"
        } else {
            algo.as_str()
        };
        let trace = if algo == "nsga2" {
            // Pareto-front search: always objective-aware (the frontier
            // is over the three components), budget-constrained when set
            let (trace, pareto) =
                q.search_pareto(model, &space, evaluator, budget, seed, weights, limits)?;
            println!(
                "{name}: nsga2 frontier -- {} point(s) from {} unique evaluations \
                 (budget {budget} proposals, space {}, constraint {})",
                pareto.front.len(),
                pareto.evaluations,
                space.tag(),
                limits.slug(),
            );
            for t in &pareto.front {
                let c = t.components.expect("pareto front trials carry components");
                println!(
                    "  {:>32} top1 {:>6.2}% | {:>8.3} ms | {:>8.1} KiB",
                    space.describe(t.config)?,
                    c.accuracy * 100.0,
                    c.latency_ms,
                    c.size_bytes / 1024.0,
                );
            }
            trace
        } else if let Some(opts) = racing {
            // successive-halving race over the same proposer; the
            // objective/constraint split mirrors the flat path below
            if weights.is_accuracy_only() && !limits.is_limited() {
                q.search_racing(model, &space, algo, evaluator, budget, seed, opts)?
            } else {
                q.search_racing_objective(
                    model, &space, algo, evaluator, budget, seed, weights, limits, opts,
                )?
            }
        } else if weights.is_accuracy_only() && !limits.is_limited() {
            q.search(model, &space, algo, evaluator, budget, seed)?
        } else {
            // scalarized search; a set budget rides along as the
            // epsilon-constraint even for the accuracy-only objective
            q.search_objective(
                model, &space, algo, evaluator, budget, seed, weights, limits,
            )?
        };
        match trace.best_components {
            None => println!(
                "{name}: {algo} best {} top1 {:.2}% after {} trials (budget {budget}, \
                 space {})",
                space.describe(trace.best_config)?,
                trace.best_score * 100.0,
                trace
                    .trials_to_reach(trace.best_score, 1e-9)
                    .unwrap_or(trace.trials.len()),
                space.tag(),
            ),
            Some(c) => println!(
                "{name}: {algo} best {} score {:.4} [{}] after {} trials \
                 (top1 {:.2}% | latency {:.3} ms | {:.1} KiB; budget {budget}, \
                 space {})",
                space.describe(trace.best_config)?,
                trace.best_score,
                weights.slug(),
                trace
                    .trials_to_reach(trace.best_score, 1e-9)
                    .unwrap_or(trace.trials.len()),
                c.accuracy * 100.0,
                c.latency_ms,
                c.size_bytes / 1024.0,
                space.tag(),
            ),
        }
        if let Some(opts) = racing {
            println!(
                "  racing (eta {}, fidelity-min {}): {} trial(s) across the rungs \
                 cost {:.2} full evaluations",
                opts.eta,
                opts.fidelity_min,
                trace.trials.len(),
                trace.total_cost(),
            );
        }
    }
    Ok(())
}

fn cmd_quantize(cli: &Cli) -> Result<()> {
    let q = Quantune::open(cli.artifacts())?;
    for name in cli.models() {
        let model = q.load_model(&name)?;
        let cfg = apply_config_overrides(
            cli,
            match cli.opt("config") {
                Some(idx) => QuantConfig::from_index(idx.parse()?)?,
                None => {
                    q.db.best_general(&name)
                        .map(|(c, _)| c)
                        .context("no sweep/search results; pass --config IDX")?
                }
            },
        )?;
        let weight_dims = |layer: &str| {
            let w = model.weights.get(&format!("{layer}_w")).unwrap();
            let b = model.weights.get(&format!("{layer}_b")).unwrap();
            (w.len(), b.len())
        };
        let sizes =
            |gran, mixed| model_size_bytes(&model.graph, &weight_dims, gran, mixed);
        let orig = model_size_fp32(&model.graph, &weight_dims);
        println!(
            "{name}: config {cfg} | size {:.2} KiB -> {:.2} KiB ({:.1}x smaller)",
            orig as f64 / 1024.0,
            sizes(cfg.gran, cfg.mixed) as f64 / 1024.0,
            orig as f64 / sizes(cfg.gran, cfg.mixed) as f64,
        );
        println!(
            "       size grid: tensor {:.2} KiB | channel {:.2} KiB | \
             tensor+mixed {:.2} KiB | channel+mixed {:.2} KiB",
            sizes(Granularity::Tensor, false) as f64 / 1024.0,
            sizes(Granularity::Channel, false) as f64 / 1024.0,
            sizes(Granularity::Tensor, true) as f64 / 1024.0,
            sizes(Granularity::Channel, true) as f64 / 1024.0,
        );
    }
    Ok(())
}

fn cmd_vta(cli: &Cli) -> Result<()> {
    let q = Quantune::open(cli.artifacts())?;
    for name in cli.models() {
        let model = q.load_model(&name)?;
        println!("{name}: VTA integer-only deployment (12-config space, Eq. 23)");
        let mut best: Option<(VtaConfig, f64)> = None;
        for cfg in VtaConfig::space() {
            let cache = calibrate(
                &model,
                &q.calib_pool,
                cfg.calib,
                &CalibBackend::Interp,
                q.seed,
            )?;
            let vm =
                VtaModel::build(&model.graph, model.weights_map(), &cache.hists, &cfg)?;
            let mut hits = 0;
            let mut cycles = 0u64;
            let idx: Vec<usize> = (0..q.eval.n).collect();
            for chunk in idx.chunks(64) {
                let x = q.eval.batch(chunk);
                let (_, preds, cyc) = vm.forward(&x)?;
                let labels = q.eval.labels_for(chunk);
                hits += preds
                    .iter()
                    .zip(&labels)
                    .filter(|(&p, &l)| p == l as usize)
                    .count();
                cycles += cyc.total();
            }
            let acc = hits as f64 / q.eval.n as f64;
            println!(
                "  {:28} top1 {:5.2}%  {:>12} cycles",
                cfg.slug(),
                acc * 100.0,
                cycles
            );
            if best.map_or(true, |(_, a)| acc > a) {
                best = Some((cfg, acc));
            }
        }
        let (cfg, acc) = best.unwrap();
        println!(
            "  => best {} top1 {:.2}% (fp32 {:.2}%)",
            cfg.slug(),
            acc * 100.0,
            model.fp32_top1 * 100.0
        );
    }
    Ok(())
}

/// `quantune db <action>`: inspect / export / migrate the trial store.
fn cmd_db(cli: &Cli) -> Result<()> {
    match cli.action.as_deref().unwrap_or("status") {
        "status" => cmd_db_status(cli),
        "table" => cmd_db_table(cli),
        "export" => cmd_db_export(cli),
        "migrate" => cmd_db_migrate(cli),
        other => {
            anyhow::bail!("unknown db action {other:?} (try status|table|export|migrate)")
        }
    }
}

fn cmd_db_status(cli: &Cli) -> Result<()> {
    let db = Store::open(&cli.artifacts())?;
    println!("backend: {}", db.backend());
    match db.location() {
        Some(p) => println!("location: {}", p.display()),
        None => println!("location: (in memory)"),
    }
    println!("records: {}", db.len());
    if db.backend() == "log" {
        println!("segments: {}", db.segments());
    }
    let idx = db.index();
    let spaces = idx.space_counts();
    if !spaces.is_empty() {
        println!("spaces:");
        for (space, n) in spaces {
            println!("  {space:12} {n} record(s)");
        }
    }
    let models = idx.model_counts();
    if !models.is_empty() {
        println!("models:");
        for (model, n) in models {
            println!("  {model:12} {n} record(s)");
        }
    }
    if !idx.device_counts().is_empty() {
        println!("devices:");
        for (dev, n) in idx.device_counts() {
            println!("  {dev:12} {n} record(s)");
        }
    }
    Ok(())
}

fn cmd_db_table(cli: &Cli) -> Result<()> {
    let db = Store::open(&cli.artifacts())?;
    let space = cli.opt_or("space", GENERAL_SPACE_TAG);
    for name in cli.models() {
        let positions = db.index().positions(&space, &name);
        if positions.is_empty() {
            println!("{name} x {space}: no records");
            continue;
        }
        // size the table from the data itself: the CLI has no space
        // object here (layer-wise spaces need a loaded model)
        let size = positions
            .iter()
            .map(|&p| db.records()[p].config + 1)
            .max()
            .unwrap_or(0);
        let table = db.accuracy_table(&name, &space, size);
        let known = table.iter().filter(|a| !a.is_nan()).count();
        println!("{name} x {space}: {known} config(s) known (max index {})", size - 1);
        for (cfg, acc) in table.iter().enumerate() {
            if !acc.is_nan() {
                println!("  config {cfg:4} top1 {:6.2}%", acc * 100.0);
            }
        }
        if let Some((cfg, acc)) = db.best_for(&name, &space) {
            println!("  => best config {cfg} top1 {:.2}%", acc * 100.0);
        }
    }
    Ok(())
}

/// One CSV row per record; empty cells for NaN / absent optionals. The
/// `clip` / `bias_correct` axis columns are decoded from the config
/// index for general-space rows (legacy indices < 96 decode too -- the
/// 288-config space keeps their order) and left empty for rows whose
/// space the index cannot be decoded against (vta, layer-wise).
fn csv_row(seq: usize, r: &Record) -> String {
    let num = |x: f64| if x.is_finite() { format!("{x}") } else { String::new() };
    let opt = |x: Option<f64>| x.map(num).unwrap_or_default();
    let (clip, bias_correct) = if r.space == GENERAL_SPACE_TAG {
        match QuantConfig::from_index(r.config) {
            Ok(c) => (c.clip.name().to_string(), c.bias_correct.to_string()),
            Err(_) => (String::new(), String::new()),
        }
    } else {
        (String::new(), String::new())
    };
    format!(
        "{seq},{},{},{},{clip},{bias_correct},{},{},{},{},{},{}\n",
        r.model,
        r.space,
        r.config,
        num(r.accuracy),
        num(r.measure_secs),
        opt(r.latency_ms),
        opt(r.size_bytes),
        r.device.as_deref().unwrap_or_default(),
        opt(r.fidelity),
    )
}

fn cmd_db_export(cli: &Cli) -> Result<()> {
    let db = Store::open(&cli.artifacts())?;
    let format = cli.opt_or("format", "csv");
    let out = match format.as_str() {
        "csv" => {
            let mut s = String::from(
                "seq,model,space,config,clip,bias_correct,accuracy,measure_secs,\
                 latency_ms,size_bytes,device,fidelity\n",
            );
            for (seq, r) in db.records().iter().enumerate() {
                s.push_str(&csv_row(seq, r));
            }
            s
        }
        "json" => {
            let doc = Json::Arr(db.records().iter().map(Record::to_json).collect());
            let mut s = doc.pretty();
            s.push('\n');
            s
        }
        other => anyhow::bail!("unknown export format {other:?} (try csv|json)"),
    };
    match cli.opt("out") {
        Some(path) => {
            // same crash-safety contract as the store itself: a died
            // export can never leave a half-written file behind
            write_atomic(std::path::Path::new(path), out.as_bytes())?;
            eprintln!("exported {} record(s) to {path} ({format})", db.len());
        }
        None => print!("{out}"),
    }
    Ok(())
}

fn cmd_db_migrate(cli: &Cli) -> Result<()> {
    let artifacts = cli.artifacts();
    let legacy_path = artifacts.join("database.json");
    let log_dir = artifacts.join("trials");
    anyhow::ensure!(
        legacy_path.exists(),
        "no legacy database at {} (nothing to migrate)",
        legacy_path.display()
    );
    anyhow::ensure!(
        !log_dir.exists(),
        "{} already exists; refusing to overwrite an existing trial log",
        log_dir.display()
    );
    let legacy = Store::open_json(&legacy_path)?;
    // replay into a scratch directory; the real `trials/` only appears
    // via the final rename, after the round-trip verification passed
    let tmp_dir = artifacts.join("trials.migrate-tmp");
    if tmp_dir.exists() {
        std::fs::remove_dir_all(&tmp_dir)?;
    }
    let mut log = Store::open_log(&tmp_dir)?;
    for r in legacy.records() {
        log.add(r.clone())?;
    }
    log.save()?;
    drop(log);
    let reread = Store::open_log(&tmp_dir)?;
    anyhow::ensure!(
        reread.len() == legacy.len(),
        "migration round-trip lost records: {} in, {} back",
        legacy.len(),
        reread.len()
    );
    for (seq, (a, b)) in legacy.records().iter().zip(reread.records()).enumerate() {
        anyhow::ensure!(
            records_equal(a, b),
            "migration round-trip corrupted record {seq} ({} {} config {})",
            a.model,
            a.space,
            a.config
        );
    }
    std::fs::rename(&tmp_dir, &log_dir)?;
    let retired = artifacts.join("database.json.migrated");
    std::fs::rename(&legacy_path, &retired)?;
    println!(
        "migrated {} record(s) losslessly into {}",
        legacy.len(),
        log_dir.display()
    );
    println!("legacy file retired to {}", retired.display());
    Ok(())
}

fn cmd_latency(cli: &Cli) -> Result<()> {
    let q = Quantune::open(cli.artifacts())?;
    let runtime = Runtime::cpu()?;
    let reps = cli.opt_usize("reps", 30)?;
    println!("single-image latency on PJRT-CPU ({reps} reps, warm)");
    for name in cli.models() {
        let model = q.load_model(&name)?;
        let report = quantune::latency::fp32_vs_fq_b1(&q, &model, &runtime, reps)?;
        let speedup = report
            .speedup()
            .map_or_else(|| "n/a (degenerate timing)".into(), |s| format!("{s:.2}x"));
        println!(
            "  {name}: fp32 {:.2} ms | int8(fq) {:.2} ms | speedup {speedup}",
            report.fp32_ms, report.fq_ms
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn db_export_decodes_new_axes_and_blanks_undecodable_rows() {
        // a legacy-index general row decodes its clip / bias_correct
        // cells (the 288-config space keeps the old 96's order)
        let r = Record::new("mn".into(), GENERAL_SPACE_TAG.into(), 0, 0.5, 0.1);
        assert!(csv_row(0, &r).contains(",max,false,"), "{}", csv_row(0, &r));
        // an extension-block row decodes the new axes
        let idx = (QuantConfig::LEGACY_SPACE_SIZE..QuantConfig::SPACE_SIZE)
            .find(|&i| {
                let c = QuantConfig::from_index(i).unwrap();
                c.clip == Clipping::Aciq && c.bias_correct
            })
            .unwrap();
        let r = Record::new("mn".into(), GENERAL_SPACE_TAG.into(), idx, 0.5, 0.1);
        assert!(csv_row(1, &r).contains(",aciq,true,"), "{}", csv_row(1, &r));
        // a row against a space the index cannot be decoded for keeps
        // the axis cells empty instead of guessing
        let r = Record::new("mn".into(), "layerwise:mn:v1".into(), 3, 0.5, 0.1);
        assert!(csv_row(2, &r).contains(",3,,,0.5,"), "{}", csv_row(2, &r));
    }
}
