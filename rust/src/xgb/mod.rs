//! Gradient tree boosting (XGBoost) from scratch — the paper's cost model
//! (§5.2, Eq. 15-21).
//!
//! Implements the second-order additive method of Chen & Guestrin 2016
//! with squared-error loss: per round, gradients g_i = ŷ_i − y_i and
//! hessians h_i = 1 feed an exact greedy split search whose gain is the
//! Eq. 21 objective reduction
//!
//!   gain = ½ [ G_L²/(H_L+λ) + G_R²/(H_R+λ) − G²/(H+λ) ] − γ
//!
//! with leaf weight −G/(H+λ), shrunk by η. γ (min split gain) and λ (leaf
//! L2) are the regularizers of Eq. 17. Feature importance is total split
//! gain (what the paper's Fig 3 ranks).
//!
//! Our datasets are ≤ a few hundred rows of one-hot + block features, so
//! the exact greedy algorithm (not the histogram approximation) is the
//! right tool.
//!
//! The split-candidate sort is NaN-safe (`total_cmp`): a NaN feature
//! value sorts deterministically instead of panicking the comparator.

#![deny(clippy::unwrap_used)]

use anyhow::{ensure, Result};

/// Training hyper-parameters (paper §5.2.2 tunes eta and gamma).
#[derive(Clone, Copy, Debug)]
pub struct XgbParams {
    /// Boosting rounds (trees in the ensemble).
    pub n_trees: usize,
    /// Maximum tree depth.
    pub max_depth: usize,
    /// Learning rate (shrinkage) η.
    pub eta: f32,
    /// Leaf L2 regularizer λ (Eq. 17).
    pub lambda: f32,
    /// Minimum split gain γ (Eq. 17).
    pub gamma: f32,
    /// Minimum hessian sum per child.
    pub min_child_weight: f32,
}

impl Default for XgbParams {
    fn default() -> Self {
        XgbParams {
            n_trees: 60,
            max_depth: 4,
            eta: 0.3,
            lambda: 1.0,
            gamma: 0.0,
            min_child_weight: 1.0,
        }
    }
}

#[derive(Clone, Debug)]
enum TreeNode {
    Leaf { weight: f32 },
    Split { feature: usize, threshold: f32, left: usize, right: usize },
}

/// One regression tree of the ensemble (an f_k of Eq. 15).
#[derive(Clone, Debug)]
pub struct Tree {
    nodes: Vec<TreeNode>,
}

impl Tree {
    /// The tree's output for one feature row.
    pub fn predict(&self, row: &[f32]) -> f32 {
        let mut i = 0;
        loop {
            match &self.nodes[i] {
                TreeNode::Leaf { weight } => return *weight,
                TreeNode::Split { feature, threshold, left, right } => {
                    i = if row[*feature] < *threshold { *left } else { *right };
                }
            }
        }
    }

    /// Number of leaf nodes.
    pub fn num_leaves(&self) -> usize {
        self.nodes.iter().filter(|n| matches!(n, TreeNode::Leaf { .. })).count()
    }
}

/// A fitted gradient-boosted ensemble: ŷ = base + Σ_k f_k(x) (Eq. 15).
#[derive(Clone, Debug)]
pub struct XgbModel {
    /// The fitted trees, in boosting order.
    pub trees: Vec<Tree>,
    /// The constant base prediction (label mean).
    pub base_score: f32,
    /// Feature-vector width the model was fitted on.
    pub n_features: usize,
    /// total split gain per feature (Fig 3's importance metric)
    pub feature_gain: Vec<f64>,
    /// Hyper-parameters the model was fitted with.
    pub params: XgbParams,
}

impl XgbModel {
    /// Fit on rows `x` (all the same width) with targets `y`.
    pub fn fit(x: &[Vec<f32>], y: &[f32], params: XgbParams) -> Result<XgbModel> {
        ensure!(!x.is_empty(), "empty training set");
        ensure!(x.len() == y.len(), "x/y length mismatch");
        let n_features = x[0].len();
        ensure!(x.iter().all(|r| r.len() == n_features), "ragged rows");

        let base_score = y.iter().sum::<f32>() / y.len() as f32;
        let mut preds = vec![base_score; y.len()];
        let mut trees = Vec::with_capacity(params.n_trees);
        let mut feature_gain = vec![0f64; n_features];

        for _ in 0..params.n_trees {
            // squared loss: g = pred - y, h = 1
            let grads: Vec<f32> = preds.iter().zip(y).map(|(p, t)| p - t).collect();
            let hess: Vec<f32> = vec![1.0; y.len()];
            let mut builder = TreeBuilder {
                x,
                grads: &grads,
                hess: &hess,
                params: &params,
                nodes: Vec::new(),
                feature_gain: &mut feature_gain,
            };
            let idx: Vec<usize> = (0..y.len()).collect();
            builder.build(&idx, 0);
            let tree = Tree { nodes: builder.nodes };
            for (p, row) in preds.iter_mut().zip(x) {
                *p += params.eta * tree.predict(row);
            }
            trees.push(tree);
        }
        Ok(XgbModel { trees, base_score, n_features, feature_gain, params })
    }

    /// Ensemble prediction for one feature row.
    pub fn predict(&self, row: &[f32]) -> f32 {
        let mut p = self.base_score;
        for t in &self.trees {
            p += self.params.eta * t.predict(row);
        }
        p
    }

    /// Feature importance as normalized total gain (sums to 1 unless the
    /// model never split).
    pub fn feature_importance(&self) -> Vec<f64> {
        let total: f64 = self.feature_gain.iter().sum();
        if total <= 0.0 {
            return vec![0.0; self.n_features];
        }
        self.feature_gain.iter().map(|g| g / total).collect()
    }
}

struct TreeBuilder<'a> {
    x: &'a [Vec<f32>],
    grads: &'a [f32],
    hess: &'a [f32],
    params: &'a XgbParams,
    nodes: Vec<TreeNode>,
    feature_gain: &'a mut Vec<f64>,
}

impl TreeBuilder<'_> {
    /// Build the subtree over `idx`; returns the node index.
    fn build(&mut self, idx: &[usize], depth: usize) -> usize {
        let g: f32 = idx.iter().map(|&i| self.grads[i]).sum();
        let h: f32 = idx.iter().map(|&i| self.hess[i]).sum();
        let leaf_weight = -g / (h + self.params.lambda);

        if depth >= self.params.max_depth || idx.len() < 2 {
            return self.push(TreeNode::Leaf { weight: leaf_weight });
        }

        match self.best_split(idx, g, h) {
            None => self.push(TreeNode::Leaf { weight: leaf_weight }),
            Some((feature, threshold, gain)) => {
                self.feature_gain[feature] += gain as f64;
                let (li, ri): (Vec<usize>, Vec<usize>) =
                    idx.iter().partition(|&&i| self.x[i][feature] < threshold);
                let me = self.push(TreeNode::Split {
                    feature,
                    threshold,
                    left: usize::MAX,
                    right: usize::MAX,
                });
                let l = self.build(&li, depth + 1);
                let r = self.build(&ri, depth + 1);
                if let TreeNode::Split { left, right, .. } = &mut self.nodes[me] {
                    *left = l;
                    *right = r;
                }
                me
            }
        }
    }

    fn push(&mut self, n: TreeNode) -> usize {
        self.nodes.push(n);
        self.nodes.len() - 1
    }

    /// Exact greedy split search (Algorithm 1 of the XGBoost paper).
    fn best_split(&self, idx: &[usize], g: f32, h: f32) -> Option<(usize, f32, f32)> {
        let lam = self.params.lambda;
        let parent = g * g / (h + lam);
        let mut best: Option<(usize, f32, f32)> = None;

        for f in 0..self.x[0].len() {
            let mut order: Vec<usize> = idx.to_vec();
            order.sort_by(|&a, &b| self.x[a][f].total_cmp(&self.x[b][f]));
            let mut gl = 0f32;
            let mut hl = 0f32;
            for w in order.windows(2) {
                gl += self.grads[w[0]];
                hl += self.hess[w[0]];
                let (va, vb) = (self.x[w[0]][f], self.x[w[1]][f]);
                if va == vb {
                    continue; // not a valid threshold between equal values
                }
                let gr = g - gl;
                let hr = h - hl;
                if hl < self.params.min_child_weight || hr < self.params.min_child_weight
                {
                    continue;
                }
                let gain =
                    0.5 * (gl * gl / (hl + lam) + gr * gr / (hr + lam) - parent)
                        - self.params.gamma;
                if gain > 0.0 && best.map_or(true, |(_, _, bg)| gain > bg) {
                    best = Some((f, 0.5 * (va + vb), gain));
                }
            }
        }
        best
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn fit_eval(
        x: &[Vec<f32>],
        y: &[f32],
        params: XgbParams,
    ) -> (XgbModel, f32) {
        let m = XgbModel::fit(x, y, params).unwrap();
        let mse = x
            .iter()
            .zip(y)
            .map(|(r, &t)| (m.predict(r) - t).powi(2))
            .sum::<f32>()
            / y.len() as f32;
        (m, mse)
    }

    #[test]
    fn fits_constant() {
        let x = vec![vec![0.0f32], vec![1.0], vec![2.0]];
        let y = vec![5.0f32; 3];
        let (m, mse) = fit_eval(&x, &y, XgbParams::default());
        assert!(mse < 1e-6);
        assert_eq!(m.predict(&[9.0]), 5.0);
    }

    #[test]
    fn fits_step_function() {
        let mut rng = Pcg32::seeded(1);
        let x: Vec<Vec<f32>> = (0..200).map(|_| vec![rng.f32() * 10.0]).collect();
        let y: Vec<f32> = x.iter().map(|r| if r[0] < 5.0 { 1.0 } else { 3.0 }).collect();
        let (_, mse) = fit_eval(&x, &y, XgbParams::default());
        assert!(mse < 1e-3, "mse {mse}");
    }

    #[test]
    fn fits_and_interaction() {
        // y = x0 AND x1 needs depth 2 to capture the interaction.
        // (Pure symmetric XOR has zero first-split gain for any greedy
        // tree learner -- including the real XGBoost -- so AND is the
        // right minimal interaction test.)
        let x = vec![
            vec![0.0, 0.0],
            vec![0.0, 1.0],
            vec![1.0, 0.0],
            vec![1.0, 1.0],
        ];
        let y = vec![0.0, 0.0, 0.0, 1.0];
        let (_, mse) = fit_eval(
            &x,
            &y,
            XgbParams { n_trees: 50, max_depth: 2, ..Default::default() },
        );
        assert!(mse < 1e-2, "mse {mse}");
    }

    #[test]
    fn importance_identifies_signal_feature() {
        let mut rng = Pcg32::seeded(2);
        let x: Vec<Vec<f32>> = (0..300)
            .map(|_| vec![rng.f32(), rng.f32(), rng.f32()])
            .collect();
        let y: Vec<f32> = x.iter().map(|r| (r[1] * 4.0).floor()).collect();
        let m = XgbModel::fit(&x, &y, XgbParams::default()).unwrap();
        let imp = m.feature_importance();
        assert!(imp[1] > 0.8, "importance {imp:?}");
        assert!((imp.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn gamma_prunes_splits() {
        let mut rng = Pcg32::seeded(3);
        let x: Vec<Vec<f32>> = (0..100).map(|_| vec![rng.f32()]).collect();
        let y: Vec<f32> = x.iter().map(|_| rng.f32() * 0.01).collect(); // noise
        let loose = XgbModel::fit(&x, &y, XgbParams::default()).unwrap();
        let tight = XgbModel::fit(
            &x,
            &y,
            XgbParams { gamma: 10.0, ..Default::default() },
        )
        .unwrap();
        let leaves = |m: &XgbModel| m.trees.iter().map(Tree::num_leaves).sum::<usize>();
        assert!(leaves(&tight) < leaves(&loose));
        assert_eq!(leaves(&tight), tight.trees.len()); // all stumps
    }

    #[test]
    fn generalizes_monotone() {
        let mut rng = Pcg32::seeded(4);
        let x: Vec<Vec<f32>> = (0..200).map(|_| vec![rng.f32() * 6.0]).collect();
        let y: Vec<f32> = x.iter().map(|r| r[0] * 2.0 + 1.0).collect();
        let m = XgbModel::fit(&x, &y, XgbParams::default()).unwrap();
        // held-out points: prediction should be near the line
        for t in [0.5f32, 2.0, 4.5] {
            let p = m.predict(&[t]);
            assert!((p - (2.0 * t + 1.0)).abs() < 0.5, "t={t} p={p}");
        }
    }

    #[test]
    fn rejects_bad_input() {
        assert!(XgbModel::fit(&[], &[], XgbParams::default()).is_err());
        assert!(XgbModel::fit(
            &[vec![1.0], vec![1.0, 2.0]],
            &[0.0, 1.0],
            XgbParams::default()
        )
        .is_err());
    }
}

