//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! python/compile/aot.py, compiles them once on the CPU PJRT client, and
//! executes them from the coordinator's hot path.
//!
//! Interchange is HLO *text* (not serialized HloModuleProto): jax >= 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the
//! text parser reassigns ids (see /opt/xla-example/README.md). All
//! lowered functions return a tuple (return_tuple=True), so results are
//! decomposed with `to_tuple`.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, Context, Result};

use crate::ir::Tensor;

/// A compiled model executable.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// The HLO artifact this executable was compiled from.
    pub path: PathBuf,
}

impl Executable {
    /// Execute with f32 tensor inputs; returns the output tuple as tensors.
    pub fn run_f32(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| tensor_to_literal(t)).collect::<Result<_>>()?;
        let refs: Vec<&xla::Literal> = literals.iter().collect();
        self.run_literals(&refs)
    }

    /// Execute with pre-built literals (mixed dtypes allowed). Taking
    /// borrows lets callers keep constant operands (weights) alive across
    /// batches without re-uploading.
    pub fn run_literals(&self, literals: &[&xla::Literal]) -> Result<Vec<Tensor>> {
        let result = self
            .exe
            .execute::<&xla::Literal>(literals)
            .map_err(|e| anyhow!("pjrt execute: {e}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("pjrt fetch: {e}"))?;
        let parts = out.to_tuple().map_err(|e| anyhow!("untuple: {e}"))?;
        parts.iter().map(literal_to_tensor).collect()
    }

    /// Execute and return i32 outputs (used by the int8 GEMM kernel
    /// artifact whose ABI is i32).
    pub fn run_literals_i32(&self, literals: &[&xla::Literal]) -> Result<Vec<Vec<i32>>> {
        let result = self
            .exe
            .execute::<&xla::Literal>(literals)
            .map_err(|e| anyhow!("pjrt execute: {e}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("pjrt fetch: {e}"))?;
        let parts = out.to_tuple().map_err(|e| anyhow!("untuple: {e}"))?;
        parts
            .iter()
            .map(|l| l.to_vec::<i32>().map_err(|e| anyhow!("literal to i32: {e}")))
            .collect()
    }
}

/// Convert an f32 tensor to a device literal.
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(&t.data)
        .reshape(&dims)
        .map_err(|e| anyhow!("literal reshape {:?}: {e}", t.shape))
}

/// Convert an i32 slice to a literal of the given shape.
pub fn i32_to_literal(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow!("literal reshape {shape:?}: {e}"))
}

fn literal_to_tensor(l: &xla::Literal) -> Result<Tensor> {
    let shape = l.array_shape().map_err(|e| anyhow!("literal shape: {e}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    // some outputs (logits) are f32; convert anything else
    let data = match l.ty().map_err(|e| anyhow!("{e}"))? {
        xla::ElementType::F32 => l.to_vec::<f32>().map_err(|e| anyhow!("{e}"))?,
        _ => {
            let conv = l
                .convert(xla::PrimitiveType::F32)
                .map_err(|e| anyhow!("convert: {e}"))?;
            conv.to_vec::<f32>().map_err(|e| anyhow!("{e}"))?
        }
    };
    Tensor::from_vec(&dims, data)
}

/// PJRT client + executable cache. Compiling an HLO module takes hundreds
/// of ms; the cache makes the general-space sweep compile each artifact
/// once.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: RefCell<HashMap<PathBuf, Rc<Executable>>>,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e}"))?;
        Ok(Runtime { client, cache: RefCell::new(HashMap::new()) })
    }

    /// Platform name reported by the PJRT client.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text artifact (cached by path).
    pub fn load(&self, path: &Path) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(path) {
            return Ok(e.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e}", path.display()))
            .with_context(|| "is the artifact stale? re-run `make artifacts`")?;
        let exe = Rc::new(Executable { exe, path: path.to_path_buf() });
        self.cache.borrow_mut().insert(path.to_path_buf(), exe.clone());
        Ok(exe)
    }

    /// Number of compiled executables held in the cache.
    pub fn cached(&self) -> usize {
        self.cache.borrow().len()
    }
}
