//! Dense f32 / i8 / i32 tensors (NHWC layout for images).

use anyhow::{bail, Result};

/// Dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    /// Dimensions, outermost first (NHWC for images).
    pub shape: Vec<usize>,
    /// Row-major elements (`shape.iter().product()` of them).
    pub data: Vec<f32>,
}

impl Tensor {
    /// All-zero tensor of `shape`.
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    /// Tensor from raw elements; errors on a shape/length mismatch.
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elems, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Is the tensor empty?
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Number of dimensions.
    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Min and max of the data (0.0,0.0 for empty).
    pub fn range(&self) -> (f32, f32) {
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &x in &self.data {
            lo = lo.min(x);
            hi = hi.max(x);
        }
        if lo > hi {
            (0.0, 0.0)
        } else {
            (lo, hi)
        }
    }

    /// Reshape in place (element count must match).
    pub fn reshape(mut self, shape: &[usize]) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!("reshape {:?} -> {:?}: element count mismatch", self.shape, shape);
        }
        self.shape = shape.to_vec();
        Ok(self)
    }
}

/// Quantized int8 tensor with its affine grid parameters.
///
/// `scales`/`zero_points` have one entry for per-tensor granularity or
/// `out_channels` entries for per-channel (weights only).
#[derive(Clone, Debug)]
pub struct QTensor {
    /// Dimensions, outermost first.
    pub shape: Vec<usize>,
    /// int8 grid values.
    pub data: Vec<i8>,
    /// One scale per group (tensor or output channel).
    pub scales: Vec<f32>,
    /// One zero point per group, aligned with `scales`.
    pub zero_points: Vec<i32>,
}

impl QTensor {
    /// Number of elements.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Is the tensor empty?
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Dequantize to f32 (per-tensor params only).
    pub fn dequantize(&self) -> Tensor {
        assert_eq!(self.scales.len(), 1, "per-tensor dequantize only");
        let s = self.scales[0];
        let zp = self.zero_points[0];
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&q| (q as i32 - zp) as f32 * s).collect(),
        }
    }
}

/// Int32 accumulator tensor (VTA simulator).
#[derive(Clone, Debug)]
pub struct I32Tensor {
    /// Dimensions, outermost first.
    pub shape: Vec<usize>,
    /// Accumulator values.
    pub data: Vec<i32>,
}

impl I32Tensor {
    /// All-zero tensor of `shape`.
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        I32Tensor { shape: shape.to_vec(), data: vec![0; n] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_vec_checks_len() {
        assert!(Tensor::from_vec(&[2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::from_vec(&[2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn range() {
        let t = Tensor::from_vec(&[4], vec![-1.0, 5.0, 0.0, 2.0]).unwrap();
        assert_eq!(t.range(), (-1.0, 5.0));
    }

    #[test]
    fn dequantize_roundtrip() {
        let q = QTensor {
            shape: vec![3],
            data: vec![-10, 0, 50],
            scales: vec![0.5],
            zero_points: vec![10],
        };
        let t = q.dequantize();
        assert_eq!(t.data, vec![-10.0, -5.0, 20.0]);
    }
}
