//! Graph IR: tensors, operators, model graphs, shape inference.
//!
//! This is the rust half of the shared architecture spec -- see
//! python/compile/specs.py for the single source of truth and
//! `Graph::from_meta` for the loader.

pub mod graph;
pub mod tensor;

pub use graph::{window_out_dim, Act, Graph, Node, Op, PoolKind};
pub use tensor::{I32Tensor, QTensor, Tensor};
