//! Graph IR for the mini CNN models.
//!
//! Mirrors python/compile/specs.py exactly: graphs arrive as the `nodes`
//! array of `artifacts/{model}_meta.json` and evaluate in list order
//! (specs.py emits a valid topological order; `Graph::validate` checks it).

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::util::Json;

/// Activation fused into a producing node.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Act {
    /// Identity (no activation).
    None,
    /// `max(x, 0)`.
    Relu,
    /// `clamp(x, 0, 6)`.
    Relu6,
}

impl Act {
    /// Parse a spec string (`none` / `relu` / `relu6`).
    pub fn parse(s: &str) -> Result<Act> {
        Ok(match s {
            "none" => Act::None,
            "relu" => Act::Relu,
            "relu6" => Act::Relu6,
            other => bail!("unknown activation {other:?}"),
        })
    }

    /// Apply the activation to one value.
    pub fn apply(self, x: f32) -> f32 {
        match self {
            Act::None => x,
            Act::Relu => x.max(0.0),
            Act::Relu6 => x.clamp(0.0, 6.0),
        }
    }
}

/// Pooling flavor of an [`Op::Pool`] node.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PoolKind {
    /// Window maximum.
    Max,
    /// Window average.
    Avg,
}

/// Graph operator. Channel counts are stored explicitly (as in the spec)
/// so validation can cross-check shape inference.
#[derive(Clone, Debug)]
pub enum Op {
    /// 2D convolution (HWIO weights) with a fused activation.
    Conv {
        /// Square kernel size.
        k: usize,
        /// Stride in both spatial dims.
        stride: usize,
        /// Zero padding in both spatial dims.
        pad: usize,
        /// Input channels.
        in_ch: usize,
        /// Output channels.
        out_ch: usize,
        /// Channel groups (`in_ch` for depthwise).
        groups: usize,
        /// Fused activation.
        act: Act,
    },
    /// Spatial pooling window.
    Pool {
        /// Max or average.
        kind: PoolKind,
        /// Square window size.
        k: usize,
        /// Stride in both spatial dims.
        stride: usize,
        /// Zero padding in both spatial dims.
        pad: usize,
    },
    /// Global average pool: [N,H,W,C] -> [N,C]
    Gap,
    /// Elementwise residual add with a fused activation.
    Add {
        /// Fused activation.
        act: Act,
    },
    /// Channel concatenation of all inputs.
    Concat,
    /// ShuffleNet channel shuffle.
    Shuffle {
        /// Shuffle group count (must divide the channels).
        groups: usize,
    },
    /// Fully connected layer ([in, out] weights).
    Dense {
        /// Input features.
        in_dim: usize,
        /// Output features.
        out_dim: usize,
    },
}

/// One node of a model graph.
#[derive(Clone, Debug)]
pub struct Node {
    /// Unique node name (also names its output tensor).
    pub name: String,
    /// The operator.
    pub op: Op,
    /// Names of the input tensors (`"input"` is the network input).
    pub inputs: Vec<String>,
}

impl Node {
    /// Does this node's output carry its own quantization profile?
    /// (Mirrors specs.QUANT_OPS; see the rationale there.)
    pub fn is_quant_point(&self) -> bool {
        matches!(
            self.op,
            Op::Conv { .. } | Op::Dense { .. } | Op::Add { .. } | Op::Concat | Op::Gap
        )
    }

    /// Does this node own weights (conv / dense)?
    pub fn has_weights(&self) -> bool {
        matches!(self.op, Op::Conv { .. } | Op::Dense { .. })
    }
}

/// Output extent of a square sliding window over one spatial dim:
/// `(extent + 2*pad - k) / stride + 1`, checked.
///
/// The naive unsigned expression underflows whenever the window exceeds
/// the padded input (a panic in debug builds, a garbage shape in
/// release), so every shape-inference and interpreter site routes
/// through here and reports a descriptive, node-named error instead.
/// `what` names the offending node in the error.
pub fn window_out_dim(
    what: &str,
    extent: usize,
    k: usize,
    stride: usize,
    pad: usize,
) -> Result<usize> {
    if k == 0 {
        bail!("{what}: zero window size");
    }
    if stride == 0 {
        bail!("{what}: zero stride");
    }
    let padded = extent + 2 * pad;
    if k > padded {
        bail!(
            "{what}: window {k} exceeds padded input extent {padded} \
             ({extent} + 2*{pad} pad)"
        );
    }
    Ok((padded - k) / stride + 1)
}

/// A CNN model graph plus its ABI metadata.
#[derive(Clone, Debug)]
pub struct Graph {
    /// Model name.
    pub name: String,
    /// Nodes in evaluation (topological) order.
    pub nodes: Vec<Node>,
    /// Network input shape as [H, W, C].
    pub input_shape: [usize; 3],
    /// Classifier output dimension.
    pub num_classes: usize,
}

impl Graph {
    /// Parse the `nodes` array of a meta JSON.
    pub fn from_meta(meta: &Json) -> Result<Graph> {
        let name = meta.get("name")?.as_str()?.to_string();
        let ishape = meta.get("input_shape")?.as_arr()?;
        let input_shape = [
            ishape[0].as_usize()?,
            ishape[1].as_usize()?,
            ishape[2].as_usize()?,
        ];
        let num_classes = meta.get("num_classes")?.as_usize()?;
        let mut nodes = Vec::new();
        for n in meta.get("nodes")?.as_arr()? {
            nodes.push(parse_node(n).with_context(|| format!("node {n:?}"))?);
        }
        let g = Graph { name, nodes, input_shape, num_classes };
        g.validate()?;
        Ok(g)
    }

    /// Evaluation-order sanity: every input is defined before use and all
    /// channel arithmetic is consistent with shape inference.
    pub fn validate(&self) -> Result<()> {
        let mut seen: HashMap<&str, ()> = HashMap::new();
        seen.insert("input", ());
        for n in &self.nodes {
            for i in &n.inputs {
                if !seen.contains_key(i.as_str()) {
                    bail!("node {} uses undefined input {i}", n.name);
                }
            }
            if seen.insert(&n.name, ()).is_some() {
                bail!("duplicate node name {}", n.name);
            }
            match &n.op {
                Op::Conv { in_ch, out_ch, groups, k, stride, .. } => {
                    if *groups == 0 {
                        bail!("conv {}: zero groups", n.name);
                    }
                    if in_ch % groups != 0 || out_ch % groups != 0 {
                        bail!("conv {}: groups {groups} does not divide {in_ch}/{out_ch}",
                              n.name);
                    }
                    if *k == 0 {
                        bail!("conv {}: zero kernel", n.name);
                    }
                    if *stride == 0 {
                        bail!("conv {}: zero stride", n.name);
                    }
                    if n.inputs.len() != 1 {
                        bail!("conv {} wants 1 input", n.name);
                    }
                }
                Op::Pool { k, stride, pad, .. } => {
                    if *k == 0 {
                        bail!("pool {}: zero window", n.name);
                    }
                    if *stride == 0 {
                        bail!("pool {}: zero stride", n.name);
                    }
                    // pad >= k would let border windows see padding only;
                    // under valid-count averaging that is a 0/0 (the
                    // reference reduce_window produces NaN there), so the
                    // geometry is rejected outright
                    if *pad >= *k {
                        bail!(
                            "pool {}: pad {pad} >= window {k} leaves \
                             all-padding border windows",
                            n.name
                        );
                    }
                    if n.inputs.len() != 1 {
                        bail!("pool {} wants 1 input", n.name);
                    }
                }
                Op::Shuffle { groups } => {
                    if *groups == 0 {
                        bail!("shuffle {}: zero groups", n.name);
                    }
                }
                Op::Add { .. } => {
                    if n.inputs.len() != 2 {
                        bail!("add {} wants 2 inputs", n.name);
                    }
                }
                Op::Concat => {
                    if n.inputs.len() < 2 {
                        bail!("concat {} wants >=2 inputs", n.name);
                    }
                }
                _ => {}
            }
        }
        // full shape inference as the final consistency check
        self.infer_shapes()?;
        Ok(())
    }

    /// Infer the [H, W, C] (or [C] after gap/dense) shape of every tensor
    /// for batch-size-agnostic evaluation. Returns name -> shape.
    pub fn infer_shapes(&self) -> Result<HashMap<String, Vec<usize>>> {
        let mut shapes: HashMap<String, Vec<usize>> = HashMap::new();
        shapes.insert("input".into(), self.input_shape.to_vec());
        for n in &self.nodes {
            let get = |i: usize| -> Result<&Vec<usize>> {
                shapes
                    .get(&n.inputs[i])
                    .ok_or_else(|| anyhow::anyhow!("missing shape for {}", n.inputs[i]))
            };
            let shape = match &n.op {
                Op::Conv { k, stride, pad, in_ch, out_ch, .. } => {
                    let s = get(0)?;
                    if s.len() != 3 || s[2] != *in_ch {
                        bail!("conv {}: input shape {:?} != in_ch {}", n.name, s, in_ch);
                    }
                    vec![
                        window_out_dim(&n.name, s[0], *k, *stride, *pad)?,
                        window_out_dim(&n.name, s[1], *k, *stride, *pad)?,
                        *out_ch,
                    ]
                }
                Op::Pool { k, stride, pad, .. } => {
                    let s = get(0)?;
                    vec![
                        window_out_dim(&n.name, s[0], *k, *stride, *pad)?,
                        window_out_dim(&n.name, s[1], *k, *stride, *pad)?,
                        s[2],
                    ]
                }
                Op::Gap => {
                    let s = get(0)?;
                    vec![s[2]]
                }
                Op::Add { .. } => {
                    let (a, b) = (get(0)?.clone(), get(1)?.clone());
                    if a != b {
                        bail!("add {}: shape mismatch {:?} vs {:?}", n.name, a, b);
                    }
                    a
                }
                Op::Concat => {
                    let first = get(0)?.clone();
                    let mut c = 0;
                    for i in 0..n.inputs.len() {
                        let s = get(i)?;
                        if s[..2] != first[..2] {
                            bail!("concat {}: spatial mismatch", n.name);
                        }
                        c += s[2];
                    }
                    vec![first[0], first[1], c]
                }
                Op::Shuffle { groups } => {
                    let s = get(0)?.clone();
                    if s[2] % groups != 0 {
                        bail!("shuffle {}: {} % {} != 0", n.name, s[2], groups);
                    }
                    s
                }
                Op::Dense { in_dim, out_dim } => {
                    let s = get(0)?;
                    if s.len() != 1 || s[0] != *in_dim {
                        bail!("dense {}: input {:?} != in_dim {}", n.name, s, in_dim);
                    }
                    vec![*out_dim]
                }
            };
            shapes.insert(n.name.clone(), shape);
        }
        Ok(shapes)
    }

    /// Quantization-point tensor names: "input" + quant-op outputs,
    /// in evaluation order (matches specs.quant_points / act_params rows).
    pub fn quant_points(&self) -> Vec<String> {
        let mut out = vec!["input".to_string()];
        out.extend(
            self.nodes.iter().filter(|n| n.is_quant_point()).map(|n| n.name.clone()),
        );
        out
    }

    /// Weight tensor names in the flat ABI order (w then b per layer).
    pub fn weight_names(&self) -> Vec<String> {
        let mut out = Vec::new();
        for n in &self.nodes {
            if n.has_weights() {
                out.push(format!("{}_w", n.name));
                out.push(format!("{}_b", n.name));
            }
        }
        out
    }

    /// Weighted layers in graph order (mixed precision keeps first+last fp32).
    pub fn layers(&self) -> Vec<String> {
        self.nodes.iter().filter(|n| n.has_weights()).map(|n| n.name.clone()).collect()
    }

    /// Name of the output (last) node.
    pub fn output(&self) -> &str {
        &self.nodes.last().expect("empty graph").name
    }

    /// Node by name.
    pub fn node(&self, name: &str) -> Option<&Node> {
        self.nodes.iter().find(|n| n.name == name)
    }

    /// Multiply-accumulate count for one input image.
    pub fn macs(&self) -> Result<u64> {
        let shapes = self.infer_shapes()?;
        let mut total: u64 = 0;
        for n in &self.nodes {
            match &n.op {
                Op::Conv { k, in_ch, out_ch, groups, .. } => {
                    let s = &shapes[&n.name];
                    let per_out = (k * k * in_ch / groups) as u64;
                    total += per_out * (s[0] * s[1] * out_ch) as u64;
                }
                Op::Dense { in_dim, out_dim } => {
                    total += (*in_dim * *out_dim) as u64;
                }
                _ => {}
            }
        }
        Ok(total)
    }

    /// Multiply-accumulate count per weighted layer, in [`Graph::layers`]
    /// order (the per-layer resolution the mixed-precision latency model
    /// needs: each layer runs in fp32 or int8 independently). Sums to
    /// [`Graph::macs`] -- only conv/dense nodes do MACs.
    pub fn layer_macs(&self) -> Result<Vec<u64>> {
        let shapes = self.infer_shapes()?;
        let mut out = Vec::new();
        for n in &self.nodes {
            match &n.op {
                Op::Conv { k, in_ch, out_ch, groups, .. } => {
                    let s = &shapes[&n.name];
                    let per_out = (k * k * in_ch / groups) as u64;
                    out.push(per_out * (s[0] * s[1] * out_ch) as u64);
                }
                Op::Dense { in_dim, out_dim } => {
                    out.push((*in_dim * *out_dim) as u64);
                }
                _ => {}
            }
        }
        Ok(out)
    }

    /// Total parameter element count.
    pub fn num_params(&self) -> u64 {
        let mut total = 0u64;
        for n in &self.nodes {
            match &n.op {
                Op::Conv { k, in_ch, out_ch, groups, .. } => {
                    total += (k * k * (in_ch / groups) * out_ch + out_ch) as u64;
                }
                Op::Dense { in_dim, out_dim } => {
                    total += (in_dim * out_dim + out_dim) as u64;
                }
                _ => {}
            }
        }
        total
    }
}

fn parse_node(n: &Json) -> Result<Node> {
    let name = n.get("name")?.as_str()?.to_string();
    let op_s = n.get("op")?.as_str()?;
    let inputs: Vec<String> = n
        .get("inputs")?
        .as_arr()?
        .iter()
        .map(|i| Ok(i.as_str()?.to_string()))
        .collect::<Result<_>>()?;
    let op = match op_s {
        "conv" => Op::Conv {
            k: n.get("k")?.as_usize()?,
            stride: n.get("stride")?.as_usize()?,
            pad: n.get("pad")?.as_usize()?,
            in_ch: n.get("in_ch")?.as_usize()?,
            out_ch: n.get("out_ch")?.as_usize()?,
            groups: n.get("groups")?.as_usize()?,
            act: Act::parse(n.get("act")?.as_str()?)?,
        },
        "pool" => Op::Pool {
            kind: match n.get("kind")?.as_str()? {
                "max" => PoolKind::Max,
                "avg" => PoolKind::Avg,
                other => bail!("unknown pool kind {other:?}"),
            },
            k: n.get("k")?.as_usize()?,
            stride: n.get("stride")?.as_usize()?,
            pad: n.get("pad")?.as_usize()?,
        },
        "gap" => Op::Gap,
        "add" => Op::Add {
            act: Act::parse(n.get_or("act", &Json::Str("none".into())).as_str()?)?,
        },
        "concat" => Op::Concat,
        "shuffle" => Op::Shuffle { groups: n.get("groups")?.as_usize()? },
        "dense" => Op::Dense {
            in_dim: n.get("in_dim")?.as_usize()?,
            out_dim: n.get("out_dim")?.as_usize()?,
        },
        other => bail!("unknown op {other:?}"),
    };
    Ok(Node { name, op, inputs })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_graph() -> Graph {
        let meta = Json::parse(
            r#"{
            "name": "tiny", "input_shape": [8, 8, 3], "num_classes": 4,
            "nodes": [
              {"name": "c1", "op": "conv", "inputs": ["input"],
               "k": 3, "stride": 1, "pad": 1, "in_ch": 3, "out_ch": 8,
               "groups": 1, "act": "relu"},
              {"name": "p1", "op": "pool", "inputs": ["c1"],
               "kind": "max", "k": 2, "stride": 2, "pad": 0},
              {"name": "g1", "op": "gap", "inputs": ["p1"]},
              {"name": "d1", "op": "dense", "inputs": ["g1"],
               "in_dim": 8, "out_dim": 4}
            ]}"#,
        )
        .unwrap();
        Graph::from_meta(&meta).unwrap()
    }

    #[test]
    fn parses_and_validates() {
        let g = tiny_graph();
        assert_eq!(g.nodes.len(), 4);
        assert_eq!(g.output(), "d1");
    }

    #[test]
    fn shape_inference() {
        let g = tiny_graph();
        let shapes = g.infer_shapes().unwrap();
        assert_eq!(shapes["c1"], vec![8, 8, 8]);
        assert_eq!(shapes["p1"], vec![4, 4, 8]);
        assert_eq!(shapes["g1"], vec![8]);
        assert_eq!(shapes["d1"], vec![4]);
    }

    #[test]
    fn quant_points_and_weights() {
        let g = tiny_graph();
        assert_eq!(g.quant_points(), vec!["input", "c1", "g1", "d1"]);
        assert_eq!(g.weight_names(), vec!["c1_w", "c1_b", "d1_w", "d1_b"]);
        assert_eq!(g.layers(), vec!["c1", "d1"]);
    }

    #[test]
    fn macs_and_params() {
        let g = tiny_graph();
        // conv: 3*3*3*8 per pixel * 64 px = 13824; dense: 8*4 = 32
        assert_eq!(g.macs().unwrap(), 13824 + 32);
        // conv: 216 w + 8 b; dense: 32 w + 4 b
        assert_eq!(g.num_params(), 216 + 8 + 32 + 4);
    }

    #[test]
    fn layer_macs_align_with_layers_and_sum_to_macs() {
        let g = tiny_graph();
        let per_layer = g.layer_macs().unwrap();
        assert_eq!(per_layer.len(), g.layers().len());
        assert_eq!(per_layer, vec![13824, 32]);
        assert_eq!(per_layer.iter().sum::<u64>(), g.macs().unwrap());
    }

    #[test]
    fn rejects_undefined_input() {
        let meta = Json::parse(
            r#"{"name": "bad", "input_shape": [4,4,3], "num_classes": 2,
            "nodes": [{"name": "g", "op": "gap", "inputs": ["nope"]}]}"#,
        )
        .unwrap();
        assert!(Graph::from_meta(&meta).is_err());
    }

    #[test]
    fn rejects_oversized_window() {
        // 4x4 input, no pad, k=7: the unsigned out-dim formula would
        // underflow; the checked path must name the node instead
        let meta = Json::parse(
            r#"{"name": "bad", "input_shape": [4,4,3], "num_classes": 2,
            "nodes": [{"name": "cbig", "op": "conv", "inputs": ["input"],
              "k": 7, "stride": 1, "pad": 0, "in_ch": 3, "out_ch": 8,
              "groups": 1, "act": "relu"}]}"#,
        )
        .unwrap();
        let err = Graph::from_meta(&meta).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("cbig") && msg.contains("window"), "got: {msg}");
    }

    #[test]
    fn rejects_all_padding_pool_windows() {
        let meta = Json::parse(
            r#"{"name": "bad", "input_shape": [4,4,3], "num_classes": 2,
            "nodes": [{"name": "pbad", "op": "pool", "inputs": ["input"],
              "kind": "avg", "k": 2, "stride": 1, "pad": 2}]}"#,
        )
        .unwrap();
        let err = Graph::from_meta(&meta).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("pbad") && msg.contains("pad"), "got: {msg}");
    }

    #[test]
    fn rejects_zero_stride() {
        let meta = Json::parse(
            r#"{"name": "bad", "input_shape": [4,4,3], "num_classes": 2,
            "nodes": [{"name": "p0", "op": "pool", "inputs": ["input"],
              "kind": "max", "k": 2, "stride": 0, "pad": 0}]}"#,
        )
        .unwrap();
        assert!(Graph::from_meta(&meta).is_err());
    }

    #[test]
    fn window_out_dim_formula_and_errors() {
        assert_eq!(window_out_dim("t", 8, 3, 1, 1).unwrap(), 8);
        assert_eq!(window_out_dim("t", 8, 2, 2, 0).unwrap(), 4);
        assert_eq!(window_out_dim("t", 2, 2, 1, 1).unwrap(), 3);
        assert!(window_out_dim("t", 4, 7, 1, 0).is_err());
        assert!(window_out_dim("t", 4, 2, 0, 0).is_err());
        assert!(window_out_dim("t", 4, 0, 1, 0).is_err());
    }

    #[test]
    fn rejects_bad_channel_math() {
        let meta = Json::parse(
            r#"{"name": "bad", "input_shape": [4,4,3], "num_classes": 2,
            "nodes": [{"name": "c", "op": "conv", "inputs": ["input"],
              "k": 3, "stride": 1, "pad": 1, "in_ch": 5, "out_ch": 8,
              "groups": 1, "act": "relu"}]}"#,
        )
        .unwrap();
        assert!(Graph::from_meta(&meta).is_err());
    }
}
