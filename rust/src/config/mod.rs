//! CLI / run configuration (no clap in the offline vendor set; this is a
//! small explicit parser with `--key value` / `--flag` syntax).

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{bail, Result};

/// Parsed command line: a subcommand, an optional action, and options.
#[derive(Debug, Default)]
pub struct Cli {
    /// The subcommand (first positional argument).
    pub command: String,
    /// Optional sub-action (second positional argument, e.g. the
    /// `status` of `quantune db status`). Commands that take no action
    /// reject a present one at dispatch time.
    pub action: Option<String>,
    opts: HashMap<String, String>,
    flags: Vec<String>,
}

impl Cli {
    /// Parse `args` (without argv[0]). Grammar:
    /// `<command> [action] [--key value | --flag]...`
    pub fn parse(args: &[String]) -> Result<Cli> {
        let mut cli = Cli::default();
        let mut it = args.iter().peekable();
        match it.next() {
            Some(cmd) if !cmd.starts_with("--") => cli.command = cmd.clone(),
            Some(cmd) => bail!("expected a subcommand before {cmd:?}"),
            None => bail!("missing subcommand"),
        }
        if let Some(a) = it.peek() {
            if !a.starts_with("--") {
                cli.action = it.next().cloned();
            }
        }
        while let Some(a) = it.next() {
            let Some(key) = a.strip_prefix("--") else {
                bail!("unexpected positional argument {a:?}");
            };
            match it.peek() {
                Some(v) if !v.starts_with("--") => {
                    cli.opts.insert(key.to_string(), it.next().unwrap().clone());
                }
                _ => cli.flags.push(key.to_string()),
            }
        }
        Ok(cli)
    }

    /// Value of `--key value`, if present.
    pub fn opt(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(String::as_str)
    }

    /// Value of `--key value`, or `default` when absent.
    pub fn opt_or(&self, key: &str, default: &str) -> String {
        self.opt(key).unwrap_or(default).to_string()
    }

    /// `--key N` parsed as usize, or `default` when absent.
    pub fn opt_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    /// `--key N` parsed as u64, or `default` when absent.
    pub fn opt_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    /// `--key X` parsed as a finite positive f64, or `None` when absent
    /// (budget caps: zero, negative, NaN, or inf caps are user errors,
    /// and so is the bare flag -- silently dropping a mistyped
    /// constraint would run the search unconstrained).
    pub fn opt_budget_f64(&self, key: &str) -> Result<Option<f64>> {
        match self.opt(key) {
            None => {
                anyhow::ensure!(
                    !self.flag(key),
                    "--{key} needs a value (e.g. --{key} 2.5)"
                );
                Ok(None)
            }
            Some(v) => {
                let x: f64 = v
                    .parse()
                    .map_err(|e| anyhow::anyhow!("--{key} {v:?}: {e}"))?;
                anyhow::ensure!(
                    x.is_finite() && x > 0.0,
                    "--{key} must be a finite positive number, got {v:?}"
                );
                Ok(Some(x))
            }
        }
    }

    /// Was the bare `--key` flag passed?
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// Artifacts directory: --artifacts, $QUANTUNE_ARTIFACTS, ./artifacts.
    pub fn artifacts(&self) -> PathBuf {
        self.opt("artifacts")
            .map(PathBuf::from)
            .unwrap_or_else(crate::zoo::artifacts_dir)
    }

    /// Comma-separated model list (default: all six).
    pub fn models(&self) -> Vec<String> {
        self.opt_or("models", &crate::zoo::MODELS.join(","))
            .split(',')
            .map(str::to_string)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<Cli> {
        Cli::parse(&s.split_whitespace().map(str::to_string).collect::<Vec<_>>())
    }

    #[test]
    fn parses_command_opts_flags() {
        let c = parse("sweep --models mn,shn --budget 42 --force").unwrap();
        assert_eq!(c.command, "sweep");
        assert_eq!(c.models(), vec!["mn", "shn"]);
        assert_eq!(c.opt_usize("budget", 0).unwrap(), 42);
        assert!(c.flag("force"));
        assert!(!c.flag("other"));
    }

    #[test]
    fn second_positional_is_the_action() {
        let c = parse("db status --artifacts x").unwrap();
        assert_eq!(c.command, "db");
        assert_eq!(c.action.as_deref(), Some("status"));
        assert_eq!(c.opt("artifacts"), Some("x"));
        // a lone command leaves the action empty
        assert!(parse("sweep --force").unwrap().action.is_none());
    }

    #[test]
    fn rejects_positional_garbage() {
        // a third positional is garbage; the second parses as the action
        // (commands that take none reject it at dispatch time)
        assert!(parse("db status junk").is_err());
        assert!(parse("").is_err());
        assert!(parse("--flag").is_err());
    }

    #[test]
    fn defaults() {
        let c = parse("eval").unwrap();
        assert_eq!(c.models().len(), 6);
        assert_eq!(c.opt_or("algo", "xgb_t"), "xgb_t");
    }

    #[test]
    fn budget_caps_parse_and_validate() {
        let c = parse("search --budget-lat-ms 2.5").unwrap();
        assert_eq!(c.opt_budget_f64("budget-lat-ms").unwrap(), Some(2.5));
        assert_eq!(c.opt_budget_f64("budget-bytes").unwrap(), None);
        for bad in ["0", "-3", "NaN", "inf", "twelve"] {
            let c = parse(&format!("search --budget-bytes {bad}")).unwrap();
            assert!(
                c.opt_budget_f64("budget-bytes").is_err(),
                "{bad:?} must be rejected"
            );
        }
        // a bare flag (value swallowed by the next --flag, or missing
        // entirely) must be an error, not a silently dropped constraint
        for cmd in ["search --budget-lat-ms", "search --budget-lat-ms --budget-bytes 5"]
        {
            let c = parse(cmd).unwrap();
            let err = c.opt_budget_f64("budget-lat-ms").unwrap_err().to_string();
            assert!(err.contains("needs a value"), "{cmd}: {err}");
        }
    }
}
