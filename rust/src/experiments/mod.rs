//! Experiment drivers that regenerate every table and figure of the
//! paper (the index lives in DESIGN.md §5), plus the multi-objective
//! Pareto experiments the deployment story adds on top. The bench
//! binaries (rust/benches/*) are thin CLIs over this module; results
//! are printed and also written as CSV under `results/`.
//!
//! Ranking in this module is NaN-safe (`accuracy_table` holes are NaN).

#![deny(clippy::unwrap_used)]

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::calib::{calibrate, CalibBackend};
use crate::coordinator::{
    CostModel, Evaluator, HloEvaluator, InterpEvaluator, ObjectiveWeights,
    OracleEvaluator, Quantune, SharedEvaluator, DEVICES, GENERAL_SPACE_TAG,
};
use crate::data::{synthetic_dataset, Dataset};
use crate::interp::{argmax_batch, Interpreter};
use crate::metrics::{BestConfigRow, DiversityAnalysis};
use crate::quant::{
    general_space, model_size_bytes, model_size_bytes_at, model_size_fp32,
    vta_space, weight_mse, BitWidth, CalibCount, Clipping, ConfigSpace,
    Granularity, LayerwiseSpace, QuantConfig, Scheme, SpaceRef, VtaConfig,
    ALL_CLIP, ALL_SCHEMES, BINARY_WIDTHS,
};
use crate::runtime::Runtime;
use crate::search::{
    allocate_for_space, run_racing, run_search, Fidelity, GridSearch,
    RacingOptions, SearchTrace, XgbSearch,
};
use crate::util::pool::Pool;
use crate::util::{nan_min_cmp, stats::mean, Csv, Pcg32, Timer};
use crate::vta::VtaModel;
use crate::zoo::{self, synthetic_model, ZooModel};

/// Models that actually have artifacts, in paper order.
pub fn available_models(q: &Quantune) -> Vec<String> {
    zoo::MODELS
        .iter()
        .filter(|m| q.artifacts.join(format!("{m}_meta.json")).exists())
        .map(|s| s.to_string())
        .collect()
}

/// Output directory for CSVs and reports (`$QUANTUNE_RESULTS`, default
/// `results/`).
pub fn results_dir() -> PathBuf {
    std::env::var("QUANTUNE_RESULTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("results"))
}

/// Ensure the database holds a full general-space sweep for `model`,
/// measuring through the HLO backend when missing. Returns the
/// `QuantConfig::SPACE_SIZE`-entry accuracy table.
pub fn ensure_sweep(
    q: &mut Quantune,
    runtime: &Runtime,
    model: &ZooModel,
) -> Result<Vec<f64>> {
    if q.db.has_full_sweep(&model.name, GENERAL_SPACE_TAG, QuantConfig::SPACE_SIZE) {
        return Ok(q.db.accuracy_table(
            &model.name,
            GENERAL_SPACE_TAG,
            QuantConfig::SPACE_SIZE,
        ));
    }
    eprintln!(
        "[sweep] measuring {} ({} configs)...",
        model.name,
        QuantConfig::SPACE_SIZE
    );
    let artifacts = q.artifacts.clone();
    let (calib_pool, eval) = (q.calib_pool.clone(), q.eval.clone());
    let mut evaluator =
        HloEvaluator::new(model, runtime, artifacts, &calib_pool, &eval, q.seed);
    let space = general_space();
    q.sweep(model, space.as_ref(), &mut evaluator, false, |_, _| {})
}

// ---------------------------------------------------------------------------
// Table 1: best configuration per model
// ---------------------------------------------------------------------------

/// Table 1: the best configuration per model, from the full sweep.
pub fn table1(q: &mut Quantune, runtime: &Runtime) -> Result<Vec<BestConfigRow>> {
    let mut rows = Vec::new();
    for name in available_models(q) {
        let model = q.load_model(&name)?;
        let table = ensure_sweep(q, runtime, &model)?;
        let (best_i, best_acc) = table
            .iter()
            .enumerate()
            .max_by(|a, b| nan_min_cmp(a.1, b.1))
            .ok_or_else(|| anyhow::anyhow!("empty sweep table for {name}"))?;
        anyhow::ensure!(
            !best_acc.is_nan(),
            "{name}: sweep table is all NaN -- no measured config to rank"
        );
        rows.push(BestConfigRow {
            model: name,
            fp32_top1: model.fp32_top1,
            best: QuantConfig::from_index(best_i)?,
            best_top1: *best_acc,
        });
    }
    let mut csv = Csv::new(&[
        "model", "precision", "calib_images", "granularity", "clipping", "scheme",
        "top1", "error_vs_fp32",
    ]);
    for r in &rows {
        csv.row(&[
            r.model.clone(),
            if r.best.mixed { "int8+fp32".into() } else { "int8".into() },
            r.best.calib.paper_images().to_string(),
            format!("{:?}", r.best.gran),
            format!("{:?}", r.best.clip),
            r.best.scheme.name().into(),
            format!("{:.4}", r.best_top1),
            format!("{:.4}", r.error_vs_fp32()),
        ]);
    }
    csv.write_file(&results_dir().join("table1_best_configs.csv"))?;
    Ok(rows)
}

// ---------------------------------------------------------------------------
// Table 2: accuracy-measurement cost per device
// ---------------------------------------------------------------------------

/// One row of Table 2 (accuracy-measurement cost per device).
pub struct Table2Row {
    /// Model name.
    pub model: String,
    /// Wall-clock seconds of one HLO measurement on this host.
    pub measured_host_secs: f64,
    /// modeled hours on (a53, i7-8700, 2080ti) for a paper-scale
    /// (50 000 image) validation pass
    pub modeled_hours: [f64; 3],
}

/// Table 2: accuracy-measurement cost, measured on this host and
/// modeled for the paper's three devices.
pub fn table2(q: &mut Quantune, runtime: &Runtime) -> Result<Vec<Table2Row>> {
    let mut rows = Vec::new();
    for name in available_models(q) {
        let model = q.load_model(&name)?;
        // measured: one non-memoized measurement through the HLO backend
        let artifacts = q.artifacts.clone();
        let (calib_pool, eval) = (q.calib_pool.clone(), q.eval.clone());
        let mut ev =
            HloEvaluator::new(&model, runtime, artifacts, &calib_pool, &eval, q.seed);
        let t = Timer::start();
        ev.measure(Quantune::tensorrt_like_baseline().index())?;
        let measured = t.secs();
        let macs = model.graph.macs()?;
        let layers = model.graph.layers().len();
        let modeled = [
            DEVICES[0].accuracy_measurement_hours(macs, layers, 50_000),
            DEVICES[1].accuracy_measurement_hours(macs, layers, 50_000),
            DEVICES[2].accuracy_measurement_hours(macs, layers, 50_000),
        ];
        rows.push(Table2Row { model: name, measured_host_secs: measured, modeled_hours: modeled });
    }
    let mut csv = Csv::new(&["model", "host_secs", "a53_hours", "i7_hours", "gpu_hours"]);
    for r in &rows {
        csv.row(&[
            r.model.clone(),
            format!("{:.2}", r.measured_host_secs),
            format!("{:.4}", r.modeled_hours[0]),
            format!("{:.4}", r.modeled_hours[1]),
            format!("{:.4}", r.modeled_hours[2]),
        ]);
    }
    csv.write_file(&results_dir().join("table2_measurement_cost.csv"))?;
    Ok(rows)
}

// ---------------------------------------------------------------------------
// Table 3: scheme comparison (computed, not just asserted)
// ---------------------------------------------------------------------------

/// One row of Table 3 (scheme comparison).
pub struct Table3Row {
    /// The scheme under comparison.
    pub scheme: Scheme,
    /// fake-quant MSE on a symmetric gaussian tensor (fine-grained mapping)
    pub mse_gaussian: f64,
    /// fake-quant MSE on a skewed (shifted) tensor (robustness to skew)
    pub mse_skewed: f64,
    /// arithmetic ops per requantized value (low computation)
    pub ops_per_value: u32,
    /// Can an integer-only accelerator execute it?
    pub integer_only: bool,
}

/// Table 3: quantitative scheme comparison on synthetic tensors (runs
/// without artifacts).
pub fn table3() -> Result<Vec<Table3Row>> {
    let mut rng = Pcg32::seeded(42);
    let gaussian = crate::ir::Tensor {
        shape: vec![4096],
        data: (0..4096).map(|_| rng.normal()).collect(),
    };
    let skewed = crate::ir::Tensor {
        shape: vec![4096],
        data: (0..4096).map(|_| rng.normal() * 0.5 + 3.0).collect(),
    };
    let mut rows = Vec::new();
    for scheme in ALL_SCHEMES {
        rows.push(Table3Row {
            scheme,
            mse_gaussian: weight_mse(&gaussian, scheme, Granularity::Tensor),
            mse_skewed: weight_mse(&skewed, scheme, Granularity::Tensor),
            // mul + add(zp) + round + clamp vs shift-only pipelines
            ops_per_value: match scheme {
                Scheme::Asymmetric => 4,
                Scheme::Symmetric => 3,
                Scheme::SymmetricUint8 => 3,
                Scheme::Pow2 => 2,
            },
            integer_only: scheme.integer_only(),
        });
    }
    let mut csv = Csv::new(&[
        "scheme", "mse_gaussian", "mse_skewed", "ops_per_value", "integer_only",
    ]);
    for r in &rows {
        csv.row(&[
            r.scheme.name().into(),
            format!("{:.3e}", r.mse_gaussian),
            format!("{:.3e}", r.mse_skewed),
            r.ops_per_value.to_string(),
            r.integer_only.to_string(),
        ]);
    }
    csv.write_file(&results_dir().join("table3_schemes.csv"))?;
    Ok(rows)
}

// ---------------------------------------------------------------------------
// Table 4: diversity (entropy) analysis
// ---------------------------------------------------------------------------

/// Table 4: Shannon-entropy diversity of near-fp32 configurations.
pub fn table4(q: &mut Quantune, runtime: &Runtime, threshold: f64) -> Result<DiversityAnalysis> {
    let mut tables = Vec::new();
    for name in available_models(q) {
        let model = q.load_model(&name)?;
        let table = ensure_sweep(q, runtime, &model)?;
        tables.push((model.fp32_top1, table));
    }
    let d = DiversityAnalysis::compute(&tables, threshold);
    let mut csv = Csv::new(&[
        "precision", "calibration", "granularity", "clipping", "scheme", "n_samples",
    ]);
    csv.row(&[
        format!("{:.2}", d.precision),
        format!("{:.2}", d.calibration),
        format!("{:.2}", d.granularity),
        format!("{:.2}", d.clipping),
        format!("{:.2}", d.scheme),
        d.num_samples.to_string(),
    ]);
    csv.write_file(&results_dir().join("table4_diversity.csv"))?;
    Ok(d)
}

// ---------------------------------------------------------------------------
// Table 5: model sizes
// ---------------------------------------------------------------------------

/// One row of Table 5 (serialized model bytes per configuration).
pub struct Table5Row {
    /// Model name.
    pub model: String,
    /// fp32 bytes.
    pub original: u64,
    /// int8 per-tensor bytes.
    pub tensor: u64,
    /// int8 per-channel bytes.
    pub channel: u64,
    /// Per-tensor with first/last layers fp32.
    pub tensor_mixed: u64,
    /// Per-channel with first/last layers fp32.
    pub channel_mixed: u64,
}

/// Table 5: serialized model sizes per granularity/mixed setting.
pub fn table5(q: &Quantune) -> Result<Vec<Table5Row>> {
    let mut rows = Vec::new();
    for name in available_models(q) {
        let model = q.load_model(&name)?;
        let dims = |layer: &str| {
            let w = model.weights.get(&format!("{layer}_w")).expect("layer weight");
            let b = model.weights.get(&format!("{layer}_b")).expect("layer bias");
            (w.len(), b.len())
        };
        let sz = |g, m| model_size_bytes(&model.graph, &dims, g, m);
        rows.push(Table5Row {
            model: name,
            original: model_size_fp32(&model.graph, &dims),
            tensor: sz(Granularity::Tensor, false),
            channel: sz(Granularity::Channel, false),
            tensor_mixed: sz(Granularity::Tensor, true),
            channel_mixed: sz(Granularity::Channel, true),
        });
    }
    let mut csv = Csv::new(&[
        "model", "original", "tensor", "channel", "tensor_mixed", "channel_mixed",
    ]);
    for r in &rows {
        csv.row(&[
            r.model.clone(),
            r.original.to_string(),
            r.tensor.to_string(),
            r.channel.to_string(),
            r.tensor_mixed.to_string(),
            r.channel_mixed.to_string(),
        ]);
    }
    csv.write_file(&results_dir().join("table5_model_size.csv"))?;
    Ok(rows)
}

// ---------------------------------------------------------------------------
// Fig 2: accuracy across every general-space config
// ---------------------------------------------------------------------------

/// Fig 2: Top-1 across all [`QuantConfig::SPACE_SIZE`] general-space
/// configs, per model.
pub fn fig2(q: &mut Quantune, runtime: &Runtime) -> Result<HashMap<String, Vec<f64>>> {
    let mut out = HashMap::new();
    let mut csv = Csv::new(&["model", "config", "slug", "top1", "fp32_top1"]);
    for name in available_models(q) {
        let model = q.load_model(&name)?;
        let table = ensure_sweep(q, runtime, &model)?;
        for (i, &acc) in table.iter().enumerate() {
            csv.row(&[
                name.clone(),
                i.to_string(),
                QuantConfig::from_index(i)?.slug(),
                format!("{acc:.4}"),
                format!("{:.4}", model.fp32_top1),
            ]);
        }
        out.insert(name, table);
    }
    csv.write_file(&results_dir().join("fig2_sweep.csv"))?;
    Ok(out)
}

// ---------------------------------------------------------------------------
// Fig 3: XGBoost feature importance
// ---------------------------------------------------------------------------

/// Fig 3: XGBoost feature importance (gain), fitted on every sweep.
pub fn fig3(q: &mut Quantune, runtime: &Runtime) -> Result<Vec<(String, f64)>> {
    // fit the cost model on every model's sweep (arch + config features)
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for name in available_models(q) {
        let model = q.load_model(&name)?;
        let table = ensure_sweep(q, runtime, &model)?;
        let arch = model.arch_features();
        for (i, &acc) in table.iter().enumerate() {
            let mut f = arch.clone();
            f.extend(QuantConfig::from_index(i)?.one_hot());
            xs.push(f);
            ys.push(acc as f32);
        }
    }
    let m = crate::xgb::XgbModel::fit(&xs, &ys, crate::xgb::XgbParams::default())?;
    let imp = m.feature_importance();
    let names: Vec<String> = zoo::ARCH_FEATURE_NAMES
        .iter()
        .map(|s| s.to_string())
        .chain(QuantConfig::FEATURE_NAMES.iter().map(|s| s.to_string()))
        .collect();
    let mut ranked: Vec<(String, f64)> =
        names.into_iter().zip(imp.iter().copied()).collect();
    ranked.sort_by(|a, b| nan_min_cmp(&b.1, &a.1));
    let mut csv = Csv::new(&["feature", "gain_importance"]);
    for (n, g) in &ranked {
        csv.row(&[n.clone(), format!("{g:.4}")]);
    }
    csv.write_file(&results_dir().join("fig3_feature_importance.csv"))?;
    Ok(ranked)
}

// ---------------------------------------------------------------------------
// Fig 5/6: search-algorithm convergence
// ---------------------------------------------------------------------------

/// Seed-averaged convergence of one (model, algorithm) pair (Fig 5/6).
pub struct ConvergenceResult {
    /// Model name.
    pub model: String,
    /// Search algorithm name.
    pub algo: String,
    /// mean trials to reach within eps of the sweep best (seed-averaged)
    pub trials_to_best: f64,
    /// one representative trace (first seed)
    pub trace: SearchTrace,
}

/// Fig 5: convergence of the search algorithms (the paper's five plus
/// nsga2's scalar trace) against the sweep oracle, seed-averaged.
pub fn fig5(
    q: &mut Quantune,
    runtime: &Runtime,
    seeds: &[u64],
    eps: f64,
) -> Result<Vec<ConvergenceResult>> {
    let mut results = Vec::new();
    let mut curve_csv = Csv::new(&["model", "algo", "seed", "trial", "best_so_far"]);
    let workers = Pool::auto();
    for name in available_models(q) {
        let model = q.load_model(&name)?;
        let table = ensure_sweep(q, runtime, &model)?;
        let best = table.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut algos: Vec<&'static str> = Vec::new();
        for algo in crate::coordinator::PROPOSERS {
            if algo == "xgb_t"
                && q.transfer_for(&model, general_space().as_ref())?.is_empty()
            {
                continue;
            }
            algos.push(algo);
        }
        // the (algorithm x seed) runs are independent given the sweep
        // table: fan them out, then reduce in the original loop order so
        // the CSVs and seed-averages match a serial run exactly
        let jobs: Vec<(&str, u64)> = algos
            .iter()
            .flat_map(|&a| seeds.iter().map(move |&s| (a, s)))
            .collect();
        let q_ref: &Quantune = q;
        let model_ref = &model;
        let table_ref = &table;
        let space = general_space();
        let space_ref = &space;
        let traces = workers.map(&jobs, |&(algo, seed)| {
            let mut oracle = OracleEvaluator::new(table_ref.clone());
            q_ref.search(
                model_ref,
                space_ref,
                algo,
                &mut oracle,
                QuantConfig::SPACE_SIZE,
                seed,
            )
        })?;
        let mut trace_it = traces.into_iter();
        for algo in algos {
            let mut per_seed = Vec::new();
            let mut first_trace = None;
            for &seed in seeds {
                let trace = trace_it.next().expect("one trace per job")?;
                per_seed
                    .push(trace.trials_to_reach(best, eps).unwrap_or(QuantConfig::SPACE_SIZE)
                        as f64);
                let mut running = f64::NEG_INFINITY;
                for (t, trial) in trace.trials.iter().enumerate() {
                    running = running.max(trial.score);
                    curve_csv.row(&[
                        name.clone(),
                        algo.to_string(),
                        seed.to_string(),
                        (t + 1).to_string(),
                        format!("{running:.4}"),
                    ]);
                }
                if first_trace.is_none() {
                    first_trace = Some(trace);
                }
            }
            results.push(ConvergenceResult {
                model: name.clone(),
                algo: algo.to_string(),
                trials_to_best: mean(&per_seed),
                trace: first_trace.expect("seeds is non-empty"),
            });
        }
    }
    curve_csv.write_file(&results_dir().join("fig5_convergence_curves.csv"))?;

    let mut csv = Csv::new(&["model", "algo", "mean_trials_to_best"]);
    for r in &results {
        csv.row(&[r.model.clone(), r.algo.clone(), format!("{:.2}", r.trials_to_best)]);
    }
    csv.write_file(&results_dir().join("fig5_trials_to_best.csv"))?;
    Ok(results)
}

/// Fig 6: speedup of each algorithm's convergence over random.
pub fn fig6(results: &[ConvergenceResult]) -> Result<Vec<(String, String, f64)>> {
    let mut out = Vec::new();
    let mut csv = Csv::new(&["model", "algo", "speedup_vs_random"]);
    let models: Vec<String> = {
        let mut m: Vec<String> = results.iter().map(|r| r.model.clone()).collect();
        m.dedup();
        m
    };
    for model in models {
        let base = results
            .iter()
            .find(|r| r.model == model && r.algo == "random")
            .map(|r| r.trials_to_best)
            .context("random baseline missing")?;
        for r in results.iter().filter(|r| r.model == model) {
            let speedup = base / r.trials_to_best.max(1.0);
            csv.row(&[model.clone(), r.algo.clone(), format!("{speedup:.2}")]);
            out.push((model.clone(), r.algo.clone(), speedup));
        }
    }
    csv.write_file(&results_dir().join("fig6_speedups.csv"))?;
    Ok(out)
}

// ---------------------------------------------------------------------------
// Fig 7: Quantune vs fixed vendor-default baseline ("TensorRT")
// ---------------------------------------------------------------------------

/// One bar group of Fig 7 (Quantune vs the vendor-default baseline).
pub struct Fig7Row {
    /// Model name.
    pub model: String,
    /// fp32 reference Top-1.
    pub fp32: f64,
    /// Top-1 of the fixed TensorRT-like config.
    pub baseline: f64,
    /// Top-1 of the sweep's best config.
    pub quantune: f64,
}

/// Fig 7: Quantune's sweep-best vs the fixed vendor-default baseline.
pub fn fig7(q: &mut Quantune, runtime: &Runtime) -> Result<Vec<Fig7Row>> {
    let baseline_cfg = Quantune::tensorrt_like_baseline();
    let mut rows = Vec::new();
    for name in available_models(q) {
        let model = q.load_model(&name)?;
        let table = ensure_sweep(q, runtime, &model)?;
        let best = table.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        rows.push(Fig7Row {
            model: name,
            fp32: model.fp32_top1,
            baseline: table[baseline_cfg.index()],
            quantune: best,
        });
    }
    let mut csv = Csv::new(&["model", "fp32", "trt_like_baseline", "quantune"]);
    for r in &rows {
        csv.row(&[
            r.model.clone(),
            format!("{:.4}", r.fp32),
            format!("{:.4}", r.baseline),
            format!("{:.4}", r.quantune),
        ]);
    }
    csv.write_file(&results_dir().join("fig7_vs_tensorrt.csv"))?;
    Ok(rows)
}

// ---------------------------------------------------------------------------
// Fig 8: integer-only accelerator (VTA)
// ---------------------------------------------------------------------------

/// One row of Fig 8 (integer-only VTA deployment).
pub struct Fig8Row {
    /// Model name.
    pub model: String,
    /// fp32 reference Top-1.
    pub fp32: f64,
    /// Top-1 of the single-global-scale TVM-style baseline.
    pub tvm_global: f64,
    /// Top-1 of the best of the 12 integer-only configs.
    pub quantune_best: f64,
    /// The winning VTA config.
    pub best_cfg: VtaConfig,
    /// Simulated accelerator cycles per image of the winner.
    pub cycles_per_image: u64,
}

/// Fig 8: integer-only VTA deployment, per-layer scales vs a single
/// global scale, over at most `eval_n` eval images.
pub fn fig8(q: &Quantune, eval_n: usize) -> Result<Vec<Fig8Row>> {
    let mut rows = Vec::new();
    for name in available_models(q) {
        let model = q.load_model(&name)?;
        let eval_n = eval_n.min(q.eval.n);
        let idx: Vec<usize> = (0..eval_n).collect();
        let measure = |vm: &VtaModel| -> Result<(f64, u64)> {
            let mut hits = 0;
            let mut cycles = 0u64;
            for chunk in idx.chunks(64) {
                let x = q.eval.batch(chunk);
                let (_, preds, cyc) = vm.forward(&x)?;
                hits += preds
                    .iter()
                    .zip(&q.eval.labels_for(chunk))
                    .filter(|(&p, &l)| p == l as usize)
                    .count();
                cycles += cyc.total();
            }
            Ok((hits as f64 / eval_n as f64, cycles / eval_n as u64))
        };

        let base_cache = calibrate(
            &model,
            &q.calib_pool,
            CalibCount::C512,
            &CalibBackend::Interp,
            q.seed,
        )?;
        let global = VtaModel::build_global_scale(
            &model.graph,
            model.weights_map(),
            &base_cache.hists,
            true,
        )?;
        let (gacc, _) = measure(&global)?;

        // the 12 integer-only configs are independent (calibrate + build
        // + measure); fan out, then pick the best in config order so
        // tie-breaking matches the serial loop
        let cfgs = VtaConfig::space();
        let measured = Pool::auto().map(&cfgs, |cfg| -> Result<(f64, u64)> {
            let cache = calibrate(
                &model,
                &q.calib_pool,
                cfg.calib,
                &CalibBackend::Interp,
                q.seed,
            )?;
            let vm =
                VtaModel::build(&model.graph, model.weights_map(), &cache.hists, cfg)?;
            measure(&vm)
        })?;
        let mut best: Option<(VtaConfig, f64, u64)> = None;
        for (cfg, r) in cfgs.iter().zip(measured) {
            let (acc, cyc) = r?;
            if best.map_or(true, |(_, a, c)| acc > a || (acc == a && cyc < c)) {
                best = Some((*cfg, acc, cyc));
            }
        }
        let (cfg, acc, cyc) =
            best.ok_or_else(|| anyhow::anyhow!("empty VTA config space"))?;
        rows.push(Fig8Row {
            model: name,
            fp32: model.fp32_top1,
            tvm_global: gacc,
            quantune_best: acc,
            best_cfg: cfg,
            cycles_per_image: cyc,
        });
    }
    let mut csv = Csv::new(&[
        "model", "fp32", "tvm_global_scale", "quantune", "best_cfg", "cycles_per_image",
    ]);
    for r in &rows {
        csv.row(&[
            r.model.clone(),
            format!("{:.4}", r.fp32),
            format!("{:.4}", r.tvm_global),
            format!("{:.4}", r.quantune_best),
            r.best_cfg.slug(),
            r.cycles_per_image.to_string(),
        ]);
    }
    csv.write_file(&results_dir().join("fig8_vta.csv"))?;
    Ok(rows)
}

// ---------------------------------------------------------------------------
// Fig 9: fp32 vs quantized latency
// ---------------------------------------------------------------------------

/// One row of Fig 9 (fp32 vs quantized latency).
pub struct Fig9Row {
    /// Model name.
    pub model: String,
    /// Measured fp32 batch-1 latency (milliseconds).
    pub fp32_ms: f64,
    /// Measured fake-quant batch-1 latency (milliseconds).
    pub fq_ms: f64,
    /// `None` when a timing was degenerate (zero / non-finite)
    pub speedup: Option<f64>,
    /// modeled relative speedups on (a53, i7, 2080ti)
    pub modeled_speedups: [f64; 3],
}

/// Fig 9: measured fp32 vs fake-quant latency plus modeled per-device
/// speedups.
pub fn fig9(q: &Quantune, runtime: &Runtime, reps: usize) -> Result<Vec<Fig9Row>> {
    let mut rows = Vec::new();
    for name in available_models(q) {
        let model = q.load_model(&name)?;
        let rep = crate::latency::fp32_vs_fq_b1(q, &model, runtime, reps)?;
        let macs = model.graph.macs()?;
        let layers = model.graph.layers().len();
        let modeled = [
            DEVICES[0].fp32_latency_s(macs, layers) / DEVICES[0].int8_latency_s(macs, layers),
            DEVICES[1].fp32_latency_s(macs, layers) / DEVICES[1].int8_latency_s(macs, layers),
            DEVICES[2].fp32_latency_s(macs, layers) / DEVICES[2].int8_latency_s(macs, layers),
        ];
        rows.push(Fig9Row {
            model: name,
            fp32_ms: rep.fp32_ms,
            fq_ms: rep.fq_ms,
            speedup: rep.speedup(),
            modeled_speedups: modeled,
        });
    }
    let mut csv = Csv::new(&[
        "model", "fp32_ms", "fq_ms", "measured_speedup", "a53_speedup", "i7_speedup",
        "gpu_speedup",
    ]);
    for r in &rows {
        csv.row(&[
            r.model.clone(),
            format!("{:.3}", r.fp32_ms),
            format!("{:.3}", r.fq_ms),
            r.speedup.map_or_else(|| "n/a".to_string(), |s| format!("{s:.3}")),
            format!("{:.3}", r.modeled_speedups[0]),
            format!("{:.3}", r.modeled_speedups[1]),
            format!("{:.3}", r.modeled_speedups[2]),
        ]);
    }
    csv.write_file(&results_dir().join("fig9_latency.csv"))?;
    Ok(rows)
}

// ---------------------------------------------------------------------------
// Layer-wise mixed-precision Pareto experiment (accuracy vs quantized
// weight bytes; the §4.5 scenario generalized to arbitrary layer masks)
// ---------------------------------------------------------------------------

/// One measured point of a layer-wise space: a layer mask, its accuracy,
/// and the serialized weight bytes it costs.
pub struct LayerwiseParetoRow {
    /// Config index within the layer-wise space.
    pub config: usize,
    /// Human-readable width assignment.
    pub label: String,
    /// Weighted layers kept fp32.
    pub fp32_layers: usize,
    /// Total weighted layers in the model.
    pub total_layers: usize,
    /// Measured Top-1.
    pub accuracy: f64,
    /// Serialized bytes under the per-width Table-5 accounting.
    pub quant_bytes: u64,
    /// true when no other point has both higher-or-equal accuracy and
    /// lower-or-equal bytes (with at least one strict)
    pub on_frontier: bool,
}

/// 2D dominance flags over (maximize accuracy, minimize bytes) points:
/// `true` where no other point is at least as good on both axes and
/// strictly better on one.
fn frontier2(points: &[(f64, u64)]) -> Vec<bool> {
    points
        .iter()
        .enumerate()
        .map(|(i, &(acc, bytes))| {
            !points.iter().enumerate().any(|(j, &(a, b))| {
                j != i && a >= acc && b <= bytes && (a > acc || b < bytes)
            })
        })
        .collect()
}

fn mark_frontier(rows: &mut [LayerwiseParetoRow]) {
    let points: Vec<(f64, u64)> = rows.iter().map(|r| (r.accuracy, r.quant_bytes)).collect();
    for (r, f) in rows.iter_mut().zip(frontier2(&points)) {
        r.on_frontier = f;
    }
}

/// Enumerate a layer-wise space exhaustively (R^K configs fan out across
/// the worker pool), measuring Top-1 through the interpreter and model
/// size through the per-width Table-5 accounting. `widths` is the
/// per-layer menu (pass [`BINARY_WIDTHS`] for the classic {int8, fp32}
/// masks). `csv_name` lands under `results/`.
#[allow(clippy::too_many_arguments)]
pub fn pareto_layerwise(
    model: &ZooModel,
    calib: &Dataset,
    eval: &Dataset,
    base: QuantConfig,
    k: usize,
    widths: &[BitWidth],
    seed: u64,
    csv_name: &str,
) -> Result<Vec<LayerwiseParetoRow>> {
    let cache =
        std::sync::Arc::new(calibrate(model, calib, base.calib, &CalibBackend::Interp, seed)?);
    let space = std::sync::Arc::new(LayerwiseSpace::rank(
        &model.name,
        &model.graph,
        model.weights_map(),
        &cache.hists,
        base,
        k,
        widths,
    )?);
    let space_ref: SpaceRef = space.clone();
    // the sensitivity calibration is reused by the evaluator instead of
    // recalibrating on the first measurement
    let ev = InterpEvaluator::new(model, calib, eval, seed)
        .with_space(space_ref)
        .with_calibration(base.calib, cache);
    let configs: Vec<usize> = (0..space.size()).collect();
    let accs = Pool::auto().map(&configs, |&i| ev.measure_shared(i))?;

    let dims = |layer: &str| {
        let w = model.weights.get(&format!("{layer}_w")).expect("layer weight");
        let b = model.weights.get(&format!("{layer}_b")).expect("layer bias");
        (w.len(), b.len())
    };
    let total_layers = model.graph.layers().len();
    let mut rows = Vec::with_capacity(space.size());
    for (i, acc) in configs.iter().zip(accs) {
        let lw = space.widths_of(*i);
        rows.push(LayerwiseParetoRow {
            config: *i,
            label: space.describe(*i)?,
            fp32_layers: lw.iter().filter(|w| w.is_float()).count(),
            total_layers,
            accuracy: acc?,
            quant_bytes: model_size_bytes_at(&model.graph, &dims, base.gran, &lw),
            on_frontier: false,
        });
    }
    mark_frontier(&mut rows);

    let mut csv = Csv::new(&[
        "config", "label", "fp32_layers", "total_layers", "top1", "quant_bytes",
        "on_frontier",
    ]);
    for r in &rows {
        csv.row(&[
            r.config.to_string(),
            r.label.clone(),
            r.fp32_layers.to_string(),
            r.total_layers.to_string(),
            format!("{:.4}", r.accuracy),
            r.quant_bytes.to_string(),
            r.on_frontier.to_string(),
        ]);
    }
    csv.write_file(&results_dir().join(csv_name))?;
    Ok(rows)
}

/// The base config the synthetic Pareto experiment stresses: per-tensor
/// symmetric int8, which a channel-spread layer handles badly.
pub fn pareto_synthetic_base() -> QuantConfig {
    QuantConfig {
        calib: CalibCount::C64,
        scheme: Scheme::Symmetric,
        clip: Clipping::Max,
        gran: Granularity::Tensor,
        mixed: false,
        bias_correct: false,
    }
}

/// The fragile synthetic setup shared by the Pareto experiments: a
/// synthetic model whose middle conv gets a planted per-channel weight
/// spread (the paper's "fragile depthwise layer" failure mode), a
/// calibration pool, and an eval split labeled with the fp32 model's
/// own predictions so accuracy measures quantization fidelity
/// (1.0 = lossless).
pub fn fragile_synthetic_setup() -> Result<(ZooModel, Dataset, Dataset)> {
    let mut model = synthetic_model(10, 4, 8, 9)?;
    model.name = "syn_fragile".to_string();
    // Function-preserving channel rescaling (the fragile-layer pathology
    // of the paper's depthwise models, distilled): divide c2's output
    // channel j (weights + bias) by s_j and multiply the dense row that
    // consumes it by s_j. ReLU and global-average-pool are positively
    // homogeneous, so the fp32 function -- and therefore the self-labels
    // below -- is unchanged; but per-tensor int8 quantization of c2 now
    // faces a 32x per-channel scale spread and crushes the small
    // channels, which the layer-wise search can repair by keeping c2
    // fp32 while everything else stays int8.
    {
        let spread = |j: usize| (2.0f32).powf(5.0 * j as f32 / 7.0); // 1..32
        let w = model.weights.tensors.get_mut("c2_w").expect("c2_w exists");
        let c = *w.shape.last().expect("c2_w has a channel axis");
        for (i, x) in w.data.iter_mut().enumerate() {
            *x /= spread(i % c);
        }
        let b = model.weights.tensors.get_mut("c2_b").expect("c2_b exists");
        for (j, x) in b.data.iter_mut().enumerate() {
            *x /= spread(j);
        }
        let d = model.weights.tensors.get_mut("d_w").expect("d_w exists");
        let out = d.shape[1];
        for (i, x) in d.data.iter_mut().enumerate() {
            *x *= spread(i / out);
        }
    }
    let calib = synthetic_dataset(128, 10, 10, 4, 8, 33);
    let mut eval = synthetic_dataset(384, 10, 10, 4, 8, 34);
    // label the eval split with the fp32 model's own argmax: accuracy
    // then reads as agreement with fp32 (1.0 = lossless quantization)
    let interp = Interpreter::new(&model.graph, model.weights_map());
    let idx: Vec<usize> = (0..eval.n).collect();
    let mut labels = Vec::with_capacity(eval.n);
    for chunk in idx.chunks(64) {
        let logits = interp.forward(&eval.batch(chunk))?;
        labels.extend(argmax_batch(&logits).into_iter().map(|p| p as u8));
    }
    eval.labels = labels;
    Ok((model, calib, eval))
}

/// Self-contained layer-wise Pareto experiment (no artifacts needed):
/// the [`fragile_synthetic_setup`] model over the full 2^K mask space,
/// measured through the interpreter. The expected shape: un-quantizing
/// the fragile layer recovers most of the accuracy for a fraction of
/// the fp32 bytes.
pub fn pareto_layerwise_synthetic() -> Result<Vec<LayerwiseParetoRow>> {
    let (model, calib, eval) = fragile_synthetic_setup()?;
    pareto_layerwise(
        &model,
        &calib,
        &eval,
        pareto_synthetic_base(),
        3,
        &BINARY_WIDTHS,
        41,
        "pareto_layerwise_synthetic.csv",
    )
}

// ---------------------------------------------------------------------------
// Radix Pareto experiment: does the {int4, int8, int16, fp32} genome
// dominate the binary {int8, fp32} masks on (size, accuracy)?
// ---------------------------------------------------------------------------

/// One measured point of the radix-vs-binary comparison.
pub struct RadixParetoRow {
    /// Which space the point comes from: `"binary"` ({int8, fp32}) or
    /// `"radix"` ({int4, int8, int16, fp32}).
    pub space: &'static str,
    /// Config index within its space.
    pub config: usize,
    /// Human-readable width assignment ([`ConfigSpace::describe`]).
    pub label: String,
    /// Candidate layers assigned the int4 width.
    pub int4_layers: usize,
    /// Weighted layers kept fp32.
    pub fp32_layers: usize,
    /// Top-1 agreement with the fp32 model (1.0 = lossless).
    pub accuracy: f64,
    /// Serialized bytes under the per-width Table-5 accounting.
    pub quant_bytes: u64,
    /// On the joint (accuracy up, bytes down) frontier over BOTH spaces.
    pub on_frontier: bool,
    /// Radix rows only: dominates the best binary config -- the
    /// highest-accuracy binary mask that quantizes at least one layer,
    /// ties broken by fewer bytes -- i.e. accuracy at least as high AND
    /// bytes at most as large, one strict.
    pub dominates_best_binary: bool,
    /// Radix rows only: this config is the IP width allocator's pick
    /// ([`crate::search::ip_alloc`]) at a byte budget equal to the best
    /// binary config's size -- the non-search analytical baseline.
    pub ip_baseline: bool,
    /// Radix rows only: this config is the XGB tuner's best at the same
    /// byte budget (over-budget configs score worst). The CI gate
    /// asserts its accuracy is no worse than the IP baseline's.
    pub xgb_best: bool,
}

/// Per-sample (top-1 margin, argmax) of a logits batch.
fn margins_of(logits: &crate::ir::Tensor) -> Vec<(f64, u8)> {
    let classes = *logits.shape.last().expect("logits have a class axis");
    let rows = logits.data.len() / classes.max(1);
    (0..rows)
        .map(|r| {
            let row = &logits.data[r * classes..(r + 1) * classes];
            let (mut top1, mut top2, mut arg) =
                (f32::NEG_INFINITY, f32::NEG_INFINITY, 0usize);
            for (c, &v) in row.iter().enumerate() {
                if v > top1 {
                    top2 = top1;
                    top1 = v;
                    arg = c;
                } else if v > top2 {
                    top2 = v;
                }
            }
            ((top1 - top2) as f64, arg as u8)
        })
        .collect()
}

/// The fragile synthetic setup plus one int4-friendly layer and a
/// margin-filtered eval split:
///
/// - `c1`'s weights are snapped to the ternary grid {-absmax, 0,
///   +absmax}, which is exactly representable on the symmetric int4,
///   int8, AND int16 grids -- so `c1`'s fake-quant weights are
///   *identical* (to float rounding) at every integer width, and
///   dropping it to int4 saves bytes at zero accuracy cost. This is the
///   distilled form of Banner et al.'s observation that some layers
///   tolerate 4-bit weights with no loss while others need more bits.
/// - the eval split keeps only the quarter of samples with the largest
///   decision margin under BOTH the fp32 network and the reference
///   quantized deployment (c2 repaired to fp32, everything else int8),
///   and only where the two agree -- so the agreement metric responds
///   to the planted c2 pathology rather than to knife-edge argmax flips
///   from benign rounding noise.
///
/// Also returns the [`pareto_synthetic_base`]-count calibration cache
/// the filter was built with, so callers measure without recalibrating.
pub fn radix_synthetic_setup() -> Result<(
    ZooModel,
    Dataset,
    Dataset,
    std::sync::Arc<crate::calib::CalibrationCache>,
)> {
    let (mut model, calib, eval_full) = fragile_synthetic_setup()?;
    model.name = "syn_radix".to_string();
    {
        let w = model.weights.tensors.get_mut("c1_w").expect("c1_w exists");
        let absmax =
            w.data.iter().fold(0f32, |m, &x| m.max(x.abs())).max(1e-12);
        for x in w.data.iter_mut() {
            // nearest of {-absmax, 0, +absmax}
            *x = if x.abs() > absmax / 2.0 { absmax * x.signum() } else { 0.0 };
        }
    }
    // the reference quantized deployment: the fragile c2 repaired to
    // fp32, c1 and d on the int8 grid of the experiment's base config
    let base = pareto_synthetic_base();
    let cache = std::sync::Arc::new(calibrate(
        &model,
        &calib,
        base.calib,
        &CalibBackend::Interp,
        41,
    )?);
    let plan = crate::quant::QuantPlan {
        base,
        layer_widths: Some(vec![BitWidth::Int8, BitWidth::Fp32, BitWidth::Int8]),
    };
    let setup = crate::coordinator::prepare(&model, &cache, &plan)?;
    let qweights: HashMap<String, std::sync::Arc<crate::ir::Tensor>> = model
        .weights
        .order
        .iter()
        .cloned()
        .zip(setup.weights.iter().cloned())
        .collect();
    let fp32_net = Interpreter::new(&model.graph, model.weights_map());
    let quant_net = Interpreter::new(&model.graph, &qweights);

    // rank samples by the WORSE of the two margins, keep the agreeing
    // top quarter, and label with the fp32 argmax
    let idx: Vec<usize> = (0..eval_full.n).collect();
    let mut ranked: Vec<(f64, usize, u8)> = Vec::with_capacity(eval_full.n);
    for chunk in idx.chunks(64) {
        let x = eval_full.batch(chunk);
        let fm = margins_of(&fp32_net.forward(&x)?);
        let qm = margins_of(&quant_net.forward_fq(&x, &setup.aq)?);
        for ((&i, f), q) in chunk.iter().zip(fm).zip(qm) {
            if f.1 == q.1 {
                ranked.push((f.0.min(q.0), i, f.1));
            }
        }
    }
    ranked.sort_by(|a, b| {
        nan_min_cmp(&b.0, &a.0).then(a.1.cmp(&b.1)) // widest margin first
    });
    ranked.truncate((eval_full.n / 4).max(1));
    ranked.sort_by_key(|r| r.1); // back to stable dataset order
    let il = eval_full.h * eval_full.w * eval_full.c;
    let mut images = Vec::with_capacity(ranked.len() * il);
    let mut labels = Vec::with_capacity(ranked.len());
    for &(_, i, label) in &ranked {
        images.extend_from_slice(&eval_full.images[i * il..(i + 1) * il]);
        labels.push(label);
    }
    let eval = Dataset {
        images,
        labels,
        n: ranked.len(),
        h: eval_full.h,
        w: eval_full.w,
        c: eval_full.c,
    };
    Ok((model, calib, eval, cache))
}

/// Self-contained radix-vs-binary Pareto experiment (no artifacts): the
/// [`radix_synthetic_setup`] model's layer-wise space enumerated twice
/// over the same top-3 fragile candidates -- once with the binary
/// {int8, fp32} menu (8 configs), once with the full {int4, int8,
/// int16, fp32} radix (64 configs) -- measured through the interpreter
/// and priced with the per-width byte accounting. The joint (accuracy,
/// bytes) frontier is marked across both spaces, and each radix row
/// records whether it dominates the best binary config; the int4-exact
/// `c1` layer guarantees at least one does. Emits
/// `results/pareto_radix_synthetic.csv`.
pub fn pareto_radix_synthetic() -> Result<Vec<RadixParetoRow>> {
    let (model, calib, eval, cache) = radix_synthetic_setup()?;
    let base = pareto_synthetic_base();
    let seed = 41;
    let dims = |layer: &str| {
        let w = model.weights.get(&format!("{layer}_w")).expect("layer weight");
        let b = model.weights.get(&format!("{layer}_b")).expect("layer bias");
        (w.len(), b.len())
    };
    let radix_menu =
        [BitWidth::Int4, BitWidth::Int8, BitWidth::Int16, BitWidth::Fp32];
    let mut rows: Vec<RadixParetoRow> = Vec::new();
    let mut radix_space: Option<std::sync::Arc<LayerwiseSpace>> = None;
    for (space_name, menu) in
        [("binary", &BINARY_WIDTHS[..]), ("radix", &radix_menu[..])]
    {
        let space = std::sync::Arc::new(LayerwiseSpace::rank(
            &model.name,
            &model.graph,
            model.weights_map(),
            &cache.hists,
            base,
            3,
            menu,
        )?);
        if space_name == "radix" {
            radix_space = Some(space.clone());
        }
        let space_ref: SpaceRef = space.clone();
        let ev = InterpEvaluator::new(&model, &calib, &eval, seed)
            .with_space(space_ref)
            .with_calibration(base.calib, cache.clone());
        let configs: Vec<usize> = (0..space.size()).collect();
        let accs = Pool::auto().map(&configs, |&i| ev.measure_shared(i))?;
        for (i, acc) in configs.iter().zip(accs) {
            let lw = space.widths_of(*i);
            rows.push(RadixParetoRow {
                space: space_name,
                config: *i,
                label: space.describe(*i)?,
                int4_layers: space.layers_at(*i, BitWidth::Int4),
                fp32_layers: lw.iter().filter(|w| w.is_float()).count(),
                accuracy: acc?,
                quant_bytes: model_size_bytes_at(&model.graph, &dims, base.gran, &lw),
                on_frontier: false,
                dominates_best_binary: false,
                ip_baseline: false,
                xgb_best: false,
            });
        }
    }

    // joint 2D frontier over both spaces (maximize accuracy, minimize
    // bytes) -- the acceptance question is whether int4-capable points
    // push it past anything the binary masks can reach
    let pts: Vec<(f64, u64)> =
        rows.iter().map(|r| (r.accuracy, r.quant_bytes)).collect();
    for (r, f) in rows.iter_mut().zip(frontier2(&pts)) {
        r.on_frontier = f;
    }
    // best binary point: highest accuracy among configs that quantize
    // at least one layer (the all-fp32 mask is the unquantized
    // reference, not a deployment), ties broken by fewer bytes
    let n_layers = model.graph.layers().len();
    let best_binary = rows
        .iter()
        .filter(|r| r.space == "binary" && r.fp32_layers < n_layers)
        .map(|r| (r.accuracy, r.quant_bytes))
        .max_by(|a, b| nan_min_cmp(&a.0, &b.0).then(b.1.cmp(&a.1)))
        .ok_or_else(|| anyhow::anyhow!("binary space produced no rows"))?;
    for r in rows.iter_mut().filter(|r| r.space == "radix") {
        r.dominates_best_binary = r.accuracy >= best_binary.0
            && r.quant_bytes <= best_binary.1
            && (r.accuracy > best_binary.0 || r.quant_bytes < best_binary.1);
    }

    // the analytical-vs-learned comparison at a shared budget (the best
    // binary config's bytes): the IP allocator picks its provably
    // MSE-optimal radix config without any measurement, the XGB tuner
    // searches the measured table with over-budget configs scored worst.
    // Budget = the whole space, and XgbSearch never re-proposes an
    // explored config, so the tuner's best is the true feasible argmax
    // and must be no worse than the MSE proxy's pick.
    let budget = best_binary.1;
    let rspace = radix_space
        .ok_or_else(|| anyhow::anyhow!("radix space was not built"))?;
    let (ip_idx, _alloc) = allocate_for_space(
        &rspace,
        &model.graph,
        model.weights_map(),
        &dims,
        Some(budget),
    )?;
    let radix_table: HashMap<usize, (f64, u64)> = rows
        .iter()
        .filter(|r| r.space == "radix")
        .map(|r| (r.config, (r.accuracy, r.quant_bytes)))
        .collect();
    let feats: Vec<Vec<f32>> =
        (0..rspace.size()).map(|i| rspace.features(i)).collect::<Result<_>>()?;
    let mut xgb = XgbSearch::new(feats, seed);
    let xgb_trace = run_search(&mut xgb, rspace.size(), |i| {
        let &(acc, bytes) = radix_table
            .get(&i)
            .ok_or_else(|| anyhow::anyhow!("unmeasured radix config {i}"))?;
        Ok(if bytes <= budget { acc } else { -1.0 })
    })?;
    let xgb_idx = xgb_trace.best_config;
    for r in rows.iter_mut().filter(|r| r.space == "radix") {
        r.ip_baseline = r.config == ip_idx;
        r.xgb_best = r.config == xgb_idx;
    }

    let mut csv = Csv::new(&[
        "space", "config", "label", "int4_layers", "fp32_layers", "top1",
        "quant_bytes", "on_frontier", "dominates_best_binary", "ip_baseline",
        "xgb_best",
    ]);
    for r in &rows {
        csv.row(&[
            r.space.to_string(),
            r.config.to_string(),
            r.label.clone(),
            r.int4_layers.to_string(),
            r.fp32_layers.to_string(),
            format!("{:.4}", r.accuracy),
            r.quant_bytes.to_string(),
            r.on_frontier.to_string(),
            r.dominates_best_binary.to_string(),
            r.ip_baseline.to_string(),
            r.xgb_best.to_string(),
        ]);
    }
    csv.write_file(&results_dir().join("pareto_radix_synthetic.csv"))?;
    Ok(rows)
}

// ---------------------------------------------------------------------------
// ACIQ clipping experiment: analytical clipping vs max/kl on the
// heavy-tailed synthetic model, with and without bias correction
// ---------------------------------------------------------------------------

/// One measured point of the clipping-policy comparison.
pub struct AciqRow {
    /// Range clipping policy of this row.
    pub clip: Clipping,
    /// Whether per-channel bias correction was folded in.
    pub bias_correct: bool,
    /// Config slug of the measured point.
    pub label: String,
    /// Top-1 agreement with the fp32 model (1.0 = lossless).
    pub top1: f64,
}

/// Self-contained ACIQ experiment (no artifacts): the
/// [`fragile_synthetic_setup`] model measured under every clipping
/// policy x bias-correct combination. Weights use per-channel scales so
/// the planted 32x channel spread lands entirely on the *activation*
/// histograms (activations are always per-tensor): their scale-mixture
/// distribution is heavy-tailed, `Max` clipping surrenders its whole
/// int8 grid to the largest channel, and ACIQ's analytical threshold
/// recovers resolution for the small channels the dense layer
/// re-amplifies. The CI gate (`tools/check_ptq_toolbox.py`) asserts the
/// ACIQ row strictly beats the Max row. Emits
/// `results/aciq_synthetic.csv`.
pub fn aciq_synthetic() -> Result<Vec<AciqRow>> {
    let (model, calib, eval) = fragile_synthetic_setup()?;
    let base =
        QuantConfig { gran: Granularity::Channel, ..pareto_synthetic_base() };
    let cache = std::sync::Arc::new(calibrate(
        &model,
        &calib,
        base.calib,
        &CalibBackend::Interp,
        41,
    )?);
    let ev = InterpEvaluator::new(&model, &calib, &eval, 41)
        .with_space(general_space())
        .with_calibration(base.calib, cache);
    let mut rows = Vec::new();
    for clip in ALL_CLIP {
        for bias_correct in [false, true] {
            let cfg = QuantConfig { clip, bias_correct, ..base };
            let top1 = ev.measure_shared(cfg.index())?;
            rows.push(AciqRow { clip, bias_correct, label: cfg.slug(), top1 });
        }
    }
    let mut csv = Csv::new(&["clip", "bias_correct", "label", "top1"]);
    for r in &rows {
        csv.row(&[
            r.clip.name().to_string(),
            r.bias_correct.to_string(),
            r.label.clone(),
            format!("{:.4}", r.top1),
        ]);
    }
    csv.write_file(&results_dir().join("aciq_synthetic.csv"))?;
    Ok(rows)
}

// ---------------------------------------------------------------------------
// Multi-objective Pareto experiment: accuracy vs latency vs bytes over a
// grid of objective weights (the deployment trade-off the tuner now
// searches directly)
// ---------------------------------------------------------------------------

/// One measured point of a space under the three deployment objectives.
pub struct ObjectiveParetoRow {
    /// Config index within the space.
    pub config: usize,
    /// Human-readable config slug.
    pub label: String,
    /// Measured Top-1.
    pub accuracy: f64,
    /// Modeled per-image latency (milliseconds).
    pub latency_ms: f64,
    /// Serialized quantized model bytes.
    pub size_bytes: f64,
    /// true when no other point is at least as good on all of
    /// (accuracy, latency, bytes) and strictly better on one
    pub on_frontier: bool,
    /// weight settings (by slug) whose scalarized argmax is this config
    pub picked_by: Vec<String>,
}

/// 3D dominance marking: maximize accuracy, minimize latency and bytes.
fn mark_frontier3(rows: &mut [ObjectiveParetoRow]) {
    let pts: Vec<(f64, f64, f64)> =
        rows.iter().map(|r| (r.accuracy, r.latency_ms, r.size_bytes)).collect();
    for (i, r) in rows.iter_mut().enumerate() {
        r.on_frontier = !pts.iter().enumerate().any(|(j, &(a, l, b))| {
            j != i
                && a >= r.accuracy
                && l <= r.latency_ms
                && b <= r.size_bytes
                && (a > r.accuracy || l < r.latency_ms || b < r.size_bytes)
        });
    }
}

/// The weight grid the Pareto experiment scans: the four CLI presets
/// plus strictly-positive mixtures (whose argmax provably lies on the
/// frontier -- a dominated point can never maximize a positive-weight
/// scalarization).
pub fn objective_weight_grid() -> Vec<ObjectiveWeights> {
    let mut grid: Vec<ObjectiveWeights> = crate::coordinator::OBJECTIVES
        .iter()
        .filter_map(|name| ObjectiveWeights::parse(name).ok())
        .collect();
    grid.push(ObjectiveWeights { accuracy: 0.5, latency: 0.4, size: 0.1 });
    grid.push(ObjectiveWeights { accuracy: 0.5, latency: 0.1, size: 0.4 });
    grid.push(ObjectiveWeights { accuracy: 0.34, latency: 0.33, size: 0.33 });
    grid
}

/// Enumerate `space` exhaustively, measure Top-1 through the interpreter
/// (configs fan out across the worker pool), price every config with the
/// static [`CostModel`], mark the 3D Pareto frontier, and record which
/// weight settings of `weight_grid` pick which config. `csv_name` lands
/// under `results/`.
#[allow(clippy::too_many_arguments)]
pub fn pareto_objectives(
    model: &ZooModel,
    calib: &Dataset,
    eval: &Dataset,
    space: SpaceRef,
    device: &crate::coordinator::DeviceProfile,
    weight_grid: &[ObjectiveWeights],
    seed: u64,
    calibration: Option<(CalibCount, std::sync::Arc<crate::calib::CalibrationCache>)>,
    csv_name: &str,
) -> Result<Vec<ObjectiveParetoRow>> {
    let mut ev = InterpEvaluator::new(model, calib, eval, seed).with_space(space.clone());
    // callers that already calibrated (e.g. to rank a layer-wise space)
    // hand their cache over instead of recalibrating on first measure
    if let Some((count, cache)) = calibration {
        ev = ev.with_calibration(count, cache);
    }
    let cost =
        CostModel::build(model, space.as_ref(), device, crate::vta::PYNQ_CLOCK_MHZ)?;
    let configs: Vec<usize> = (0..space.size()).collect();
    let accs = Pool::auto().map(&configs, |&i| ev.measure_shared(i))?;

    let mut rows = Vec::with_capacity(space.size());
    for (&i, acc) in configs.iter().zip(accs) {
        let c = cost.cost(i)?;
        rows.push(ObjectiveParetoRow {
            config: i,
            label: space.describe(i)?,
            accuracy: acc?,
            latency_ms: c.latency_ms,
            size_bytes: c.size_bytes,
            on_frontier: false,
            picked_by: Vec::new(),
        });
    }
    mark_frontier3(&mut rows);
    let row_score = |w: &ObjectiveWeights, r: &ObjectiveParetoRow| {
        let c = crate::coordinator::ConfigCost {
            latency_ms: r.latency_ms,
            size_bytes: r.size_bytes,
        };
        w.score(r.accuracy, c, &cost.refs)
    };
    for w in weight_grid {
        let winner = rows
            .iter()
            .enumerate()
            .map(|(j, r)| (j, row_score(w, r)))
            .max_by(|a, b| nan_min_cmp(&a.1, &b.1))
            .map(|(j, _)| j);
        if let Some(j) = winner {
            rows[j].picked_by.push(w.slug());
        }
    }

    let mut csv = Csv::new(&[
        "config", "label", "top1", "latency_ms", "size_bytes", "on_frontier",
        "picked_by",
    ]);
    for r in &rows {
        csv.row(&[
            r.config.to_string(),
            r.label.clone(),
            format!("{:.4}", r.accuracy),
            format!("{:.4}", r.latency_ms),
            format!("{:.0}", r.size_bytes),
            r.on_frontier.to_string(),
            r.picked_by.join("+"),
        ]);
    }
    csv.write_file(&results_dir().join(csv_name))?;
    Ok(rows)
}

/// Self-contained multi-objective Pareto experiment (no artifacts): the
/// [`fragile_synthetic_setup`] model's layer-wise space, priced on the
/// i7 device profile, scanned over [`objective_weight_grid`]. Emits
/// `results/pareto_objectives_synthetic.csv`.
pub fn pareto_objectives_synthetic() -> Result<Vec<ObjectiveParetoRow>> {
    let (model, calib, eval) = fragile_synthetic_setup()?;
    let base = pareto_synthetic_base();
    let cache = std::sync::Arc::new(calibrate(
        &model,
        &calib,
        base.calib,
        &CalibBackend::Interp,
        41,
    )?);
    let space: SpaceRef = std::sync::Arc::new(LayerwiseSpace::rank(
        &model.name,
        &model.graph,
        model.weights_map(),
        &cache.hists,
        base,
        3,
        &BINARY_WIDTHS,
    )?);
    pareto_objectives(
        &model,
        &calib,
        &eval,
        space,
        &DEVICES[1],
        &objective_weight_grid(),
        41,
        Some((base.calib, cache)),
        "pareto_objectives_synthetic.csv",
    )
}

// ---------------------------------------------------------------------------
// Pareto-front *search*: does NSGA-II recover the exhaustive frontier at
// a fraction of its evaluation cost?
// ---------------------------------------------------------------------------

/// One point of the NSGA-II-vs-exhaustive frontier comparison.
pub struct ParetoSearchRow {
    /// Config index within the radix layer-wise space.
    pub config: usize,
    /// Human-readable width assignment.
    pub label: String,
    /// Measured Top-1 (agreement with fp32 on the synthetic setup).
    pub accuracy: f64,
    /// Modeled per-image latency (milliseconds).
    pub latency_ms: f64,
    /// Serialized quantized model bytes.
    pub size_bytes: f64,
    /// On the exhaustive (true) 3D frontier.
    pub on_true_front: bool,
    /// Measured by the NSGA-II run.
    pub evaluated_by_nsga2: bool,
    /// On the front NSGA-II recovered.
    pub on_nsga2_front: bool,
}

/// Summary of the frontier-recovery comparison
/// ([`pareto_search_synthetic`]).
pub struct ParetoSearchSummary {
    /// Every config of the space, with true-front / searched-front flags.
    pub rows: Vec<ParetoSearchRow>,
    /// Exhaustive evaluation count (= space size).
    pub exhaustive_evals: usize,
    /// Unique configs the NSGA-II run measured.
    pub nsga2_evals: usize,
    /// Hypervolume of the exhaustive frontier.
    pub hv_true: f64,
    /// Hypervolume of the NSGA-II frontier (same reference point).
    pub hv_nsga2: f64,
    /// `hv_nsga2 / hv_true` -- the frontier-recovery metric.
    pub hv_ratio: f64,
    /// Fraction of true-front configs the NSGA-II front contains.
    pub true_front_fraction: f64,
}

/// Self-contained Pareto-front *search* experiment (no artifacts): the
/// [`radix_synthetic_setup`] model's {int4, int8, int16, fp32}^3
/// layer-wise space (64 configs) is enumerated exhaustively -- the same
/// ground truth [`pareto_objectives`] marks -- and NSGA-II
/// (`Quantune::search_pareto`) gets a 16-proposal budget: 25% of the
/// exhaustive evaluation cost. Recovery is scored two ways:
///
/// - **hypervolume ratio** `hv(searched front) / hv(true front)` w.r.t.
///   a common reference point (zero accuracy, worst latency/bytes of
///   the space) -- the standard frontier-quality metric, and the one
///   the acceptance test thresholds at >= 0.8;
/// - **fraction of the true front** -- how many of the exhaustively
///   non-dominated configs the search actually measured and kept.
///
/// Emits `results/pareto_search_synthetic.csv`; asserted in
/// `rust/tests/objective.rs`.
pub fn pareto_search_synthetic() -> Result<ParetoSearchSummary> {
    let (model, calib, eval, cache) = radix_synthetic_setup()?;
    let base = pareto_synthetic_base();
    let seed = 41;
    let menu = [BitWidth::Int4, BitWidth::Int8, BitWidth::Int16, BitWidth::Fp32];
    let space: SpaceRef = std::sync::Arc::new(LayerwiseSpace::rank(
        &model.name,
        &model.graph,
        model.weights_map(),
        &cache.hists,
        base,
        3,
        &menu,
    )?);

    // exhaustive ground truth: every config measured once, 3D frontier
    // marked (this is the pareto_objectives machinery over the same
    // space, kept as its own CSV)
    let exhaustive = pareto_objectives(
        &model,
        &calib,
        &eval,
        space.clone(),
        &DEVICES[1],
        &objective_weight_grid(),
        seed,
        Some((base.calib, cache.clone())),
        "pareto_search_exhaustive.csv",
    )?;

    // NSGA-II under 25% of the exhaustive budget: 16 proposals over the
    // 64-config space; unique evaluations can only be fewer (repeat
    // proposals hit the evaluator memo)
    let q = Quantune {
        artifacts: PathBuf::from("."),
        calib_pool: calib.clone(),
        eval: eval.clone(),
        db: crate::coordinator::Store::in_memory(),
        seed,
        device: DEVICES[1],
        seed_from_db: false,
    };
    let nsga_budget = space.size() / 4;
    let mut ev = InterpEvaluator::new(&model, &calib, &eval, seed)
        .with_space(space.clone())
        .with_calibration(base.calib, cache);
    let (trace, pareto) = q.search_pareto(
        &model,
        &space,
        &mut ev,
        nsga_budget,
        seed,
        ObjectiveWeights::parse("balanced")?,
        crate::coordinator::Budget::unlimited(),
    )?;
    let evaluated: std::collections::HashSet<usize> =
        trace.trials.iter().map(|t| t.config).collect();
    let nsga_front: std::collections::HashSet<usize> =
        pareto.front_configs().into_iter().collect();

    // common frontier representation: (accuracy, latency, bytes) of the
    // exhaustive table, so both hypervolumes price identical points
    let comp = |r: &ObjectiveParetoRow| crate::search::Components {
        accuracy: r.accuracy,
        latency_ms: r.latency_ms,
        size_bytes: r.size_bytes,
    };
    let reference = crate::search::Components {
        accuracy: 0.0,
        latency_ms: exhaustive
            .iter()
            .map(|r| r.latency_ms)
            .fold(f64::NEG_INFINITY, f64::max)
            * 1.01,
        size_bytes: exhaustive
            .iter()
            .map(|r| r.size_bytes)
            .fold(f64::NEG_INFINITY, f64::max)
            * 1.01,
    };
    let all_trials: Vec<crate::search::Trial> = exhaustive
        .iter()
        .map(|r| crate::search::Trial::scored(r.config, r.accuracy, comp(r)))
        .collect();
    let true_trace = crate::search::ParetoTrace::from_trials("exhaustive", &all_trials);
    let hv_true = true_trace.hypervolume(reference);
    let hv_nsga2 = pareto.hypervolume(reference);

    // the true front reuses the flags pareto_objectives already marked
    // (one dominance source of truth across both CSVs); the trace above
    // is only needed for the hypervolume
    let true_front: std::collections::HashSet<usize> = exhaustive
        .iter()
        .filter(|r| r.on_frontier)
        .map(|r| r.config)
        .collect();
    let overlap = true_front.intersection(&nsga_front).count();

    let rows: Vec<ParetoSearchRow> = exhaustive
        .iter()
        .map(|r| ParetoSearchRow {
            config: r.config,
            label: r.label.clone(),
            accuracy: r.accuracy,
            latency_ms: r.latency_ms,
            size_bytes: r.size_bytes,
            on_true_front: true_front.contains(&r.config),
            evaluated_by_nsga2: evaluated.contains(&r.config),
            on_nsga2_front: nsga_front.contains(&r.config),
        })
        .collect();

    let summary = ParetoSearchSummary {
        exhaustive_evals: space.size(),
        nsga2_evals: pareto.evaluations,
        hv_true,
        hv_nsga2,
        hv_ratio: if hv_true > 0.0 { hv_nsga2 / hv_true } else { f64::NAN },
        true_front_fraction: if true_front.is_empty() {
            f64::NAN
        } else {
            overlap as f64 / true_front.len() as f64
        },
        rows,
    };

    let mut csv = Csv::new(&[
        "config", "label", "top1", "latency_ms", "size_bytes", "on_true_front",
        "evaluated_by_nsga2", "on_nsga2_front",
    ]);
    for r in &summary.rows {
        csv.row(&[
            r.config.to_string(),
            r.label.clone(),
            format!("{:.4}", r.accuracy),
            format!("{:.4}", r.latency_ms),
            format!("{:.0}", r.size_bytes),
            r.on_true_front.to_string(),
            r.evaluated_by_nsga2.to_string(),
            r.on_nsga2_front.to_string(),
        ]);
    }
    csv.write_file(&results_dir().join("pareto_search_synthetic.csv"))?;
    Ok(summary)
}

// ---------------------------------------------------------------------------
// Multi-fidelity racing experiment: equal-best recovery at a fraction
// of the exhaustive evaluation cost
// ---------------------------------------------------------------------------

/// One stage of the racing-vs-exhaustive comparison ([`racing_synthetic`]).
pub struct RacingRow {
    /// `"surface"` (analytic 96-config oracle whose low-fidelity ranking
    /// provably matches the full ranking) or `"interp"` (live
    /// interpreter measurement over the 12-config VTA space).
    pub stage: &'static str,
    /// Racing trace tag, e.g. `"sh(grid)"`.
    pub algo: String,
    /// Exhaustive winner (ground truth) and its score.
    pub exhaustive_best: usize,
    /// Score of the exhaustive winner.
    pub exhaustive_score: f64,
    /// Racing winner (always a full-fidelity measurement) and score.
    pub racing_best: usize,
    /// Full-fidelity score of the racing winner.
    pub racing_score: f64,
    /// Equal-best recovery: the racing winner's full-fidelity score
    /// equals the exhaustive best score (the winning *index* may differ
    /// when several configs tie at the top).
    pub recovered: bool,
    /// Exhaustive cost in full-evaluation units (= space size).
    pub exhaustive_cost: f64,
    /// Racing cost in the same units: actual measured work (for the
    /// interp stage, images evaluated / eval-set size, which charges
    /// the batch-ceiling the nominal [`SearchTrace::total_cost`]
    /// rounds away).
    pub racing_cost: f64,
    /// `racing_cost / exhaustive_cost`.
    pub cost_fraction: f64,
    /// Trials across all rungs.
    pub trials: usize,
    /// Of them, full-fidelity measurements.
    pub full_trials: usize,
}

/// Self-contained multi-fidelity racing experiment (no artifacts): two
/// stages race a grid proposer (each config proposed exactly once, so
/// "exhaustive best was proposed" holds by construction) through
/// [`run_racing`] with the default ladder (eta 4, 1/16 .. 1) and
/// compare against exhaustively measuring every config at full
/// fidelity.
///
/// - **surface**: an analytic 96-config oracle with a unique optimum
///   whose low-fidelity score is the full score minus a
///   rung-constant offset -- per-rung ranking therefore equals the
///   full-fidelity ranking, so successive halving *provably* promotes
///   the optimum through every rung. Racing must recover the exact
///   best at 3/16 of the exhaustive cost (6 generations x 3
///   full-evaluation-equivalents vs 96).
/// - **interp**: the [`fragile_synthetic_setup`] model over the VTA
///   space, measured live through [`InterpEvaluator`] -- the
///   exhaustive sweep on one evaluator, the race on a *fresh* one (no
///   shared memo), with racing cost charged by images actually
///   interpreted. Low-fidelity ranking is not guaranteed here (that is
///   the point of reporting it): `recovered` says whether the cheap
///   prefixes were faithful for this model, and the cost fraction
///   stays below 1 by rung arithmetic.
///
/// Emits `results/racing_synthetic.csv`; asserted in
/// `rust/tests/racing.rs` and gated in CI by `tools/check_racing.py`.
pub fn racing_synthetic() -> Result<Vec<RacingRow>> {
    let opts = RacingOptions { eta: 4, fidelity_min: 1.0 / 16.0 };
    let mut rows = Vec::with_capacity(2);

    // ---- stage 1: analytic surface, recovery provable -------------------
    {
        let size = 96usize;
        // unique optimum at 42; everything else lands in [0.55, 0.91]
        let base =
            |j: usize| if j == 42 { 1.0 } else { 0.55 + ((j * 31) % 89) as f64 * 0.004 };
        let (exhaustive_best, exhaustive_score) = (0..size)
            .map(|j| (j, base(j)))
            .max_by(|a, b| nan_min_cmp(&a.1, &b.1))
            .context("empty surface")?;
        let mut algo = GridSearch::new(size, 17);
        let trace = run_racing(&mut algo, size, opts, |cfg, fid| {
            // a rung-constant pessimism: low fidelity underestimates
            // every config equally, so ranking is fidelity-invariant
            Ok(base(cfg) - 0.01 * (1.0 - fid.value()))
        })?;
        let racing_cost = trace.total_cost();
        rows.push(RacingRow {
            stage: "surface",
            algo: trace.algo.clone(),
            exhaustive_best,
            exhaustive_score,
            racing_best: trace.best_config,
            racing_score: trace.best_score,
            recovered: trace.best_score == exhaustive_score,
            exhaustive_cost: size as f64,
            racing_cost,
            cost_fraction: racing_cost / size as f64,
            trials: trace.trials.len(),
            full_trials: trace.trials.iter().filter(|t| t.fidelity >= 1.0).count(),
        });
    }

    // ---- stage 2: live interpreter over the VTA space -------------------
    {
        let (model, calib, eval) = fragile_synthetic_setup()?;
        let space: SpaceRef = vta_space();
        let seed = 43;
        let exhaustive_ev = InterpEvaluator::new(&model, &calib, &eval, seed)
            .with_threads(1)
            .with_space(space.clone());
        let table: Vec<f64> = (0..space.size())
            .map(|cfg| exhaustive_ev.measure_shared(cfg))
            .collect::<Result<_>>()?;
        let (exhaustive_best, &exhaustive_score) = table
            .iter()
            .enumerate()
            .max_by(|a, b| nan_min_cmp(a.1, b.1))
            .context("empty sweep table")?;
        // the race measures through a FRESH evaluator (no memo shared
        // with the exhaustive sweep), charged by images interpreted
        let racing_ev = InterpEvaluator::new(&model, &calib, &eval, seed)
            .with_threads(1)
            .with_space(space.clone());
        let batches = eval.stratified_batches(64);
        let images_at = |fid: Fidelity| -> usize {
            batches[..fid.batches_of(batches.len())].iter().map(Vec::len).sum()
        };
        let mut images = 0usize;
        let mut algo = GridSearch::new(space.size(), seed);
        let trace = run_racing(&mut algo, space.size(), opts, |cfg, fid| {
            images += images_at(fid);
            racing_ev.measure_fidelity_shared(cfg, fid)
        })?;
        let exhaustive_cost = space.size() as f64;
        let racing_cost = images as f64 / eval.n.max(1) as f64;
        rows.push(RacingRow {
            stage: "interp",
            algo: trace.algo.clone(),
            exhaustive_best,
            exhaustive_score,
            racing_best: trace.best_config,
            racing_score: trace.best_score,
            recovered: trace.best_score == exhaustive_score,
            exhaustive_cost,
            racing_cost,
            cost_fraction: racing_cost / exhaustive_cost,
            trials: trace.trials.len(),
            full_trials: trace.trials.iter().filter(|t| t.fidelity >= 1.0).count(),
        });
    }

    let mut csv = Csv::new(&[
        "stage", "algo", "exhaustive_best", "exhaustive_score", "racing_best",
        "racing_score", "recovered", "exhaustive_cost", "racing_cost",
        "cost_fraction", "trials", "full_trials",
    ]);
    for r in &rows {
        csv.row(&[
            r.stage.to_string(),
            r.algo.clone(),
            r.exhaustive_best.to_string(),
            format!("{:.6}", r.exhaustive_score),
            r.racing_best.to_string(),
            format!("{:.6}", r.racing_score),
            r.recovered.to_string(),
            format!("{:.4}", r.exhaustive_cost),
            format!("{:.4}", r.racing_cost),
            format!("{:.4}", r.cost_fraction),
            r.trials.to_string(),
            r.full_trials.to_string(),
        ]);
    }
    csv.write_file(&results_dir().join("racing_synthetic.csv"))?;
    Ok(rows)
}

/// Write a text report file alongside the CSVs.
pub fn write_report(name: &str, content: &str) -> Result<()> {
    let dir = results_dir();
    std::fs::create_dir_all(&dir)?;
    std::fs::write(dir.join(name), content)?;
    Ok(())
}

/// Resolve a `Path` under results/ (helper for benches).
pub fn result_path(name: &str) -> PathBuf {
    results_dir().join(name)
}

#[allow(dead_code)]
fn _unused(_: &Path) {}
