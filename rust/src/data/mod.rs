//! Data containers shared with the python build path: the `.qtd` image
//! dataset and `.qtw` weight files (formats defined in
//! python/compile/dataset.py and python/compile/aot.py), plus batching
//! and the calibration image selector.

use std::collections::HashMap;
use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::ir::Tensor;
use crate::util::Pcg32;

/// An image classification dataset: u8 NHWC pixels + labels.
#[derive(Clone)]
pub struct Dataset {
    /// Raw pixels, `n * h * w * c` bytes in NHWC order.
    pub images: Vec<u8>,
    /// One class label per image.
    pub labels: Vec<u8>,
    /// Number of images.
    pub n: usize,
    /// Image height.
    pub h: usize,
    /// Image width.
    pub w: usize,
    /// Channels per pixel.
    pub c: usize,
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

impl Dataset {
    /// Load a `.qtd` dataset file (see python/compile/dataset.py).
    pub fn load(path: &Path) -> Result<Dataset> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != b"QTD1" {
            bail!("{}: bad magic {magic:?}", path.display());
        }
        let n = read_u32(&mut f)? as usize;
        let h = read_u32(&mut f)? as usize;
        let w = read_u32(&mut f)? as usize;
        let c = read_u32(&mut f)? as usize;
        let mut labels = vec![0u8; n];
        f.read_exact(&mut labels)?;
        let mut images = vec![0u8; n * h * w * c];
        f.read_exact(&mut images)?;
        Ok(Dataset { images, labels, n, h, w, c })
    }

    fn image_len(&self) -> usize {
        self.h * self.w * self.c
    }

    /// Normalized f32 batch [len(idx), H, W, C] in [-1, 1]
    /// (identical to python dataset.normalize).
    pub fn batch(&self, idx: &[usize]) -> Tensor {
        let il = self.image_len();
        let mut data = Vec::with_capacity(idx.len() * il);
        for &i in idx {
            assert!(i < self.n, "image index {i} out of range {}", self.n);
            let src = &self.images[i * il..(i + 1) * il];
            data.extend(src.iter().map(|&b| b as f32 / 127.5 - 1.0));
        }
        Tensor { shape: vec![idx.len(), self.h, self.w, self.c], data }
    }

    /// Batch padded to `batch` rows by repeating the last image (PJRT
    /// executables have a fixed batch dimension). Returns (tensor, valid).
    pub fn batch_padded(&self, idx: &[usize], batch: usize) -> (Tensor, usize) {
        assert!(!idx.is_empty() && idx.len() <= batch);
        let mut padded = idx.to_vec();
        while padded.len() < batch {
            padded.push(*idx.last().unwrap());
        }
        (self.batch(&padded), idx.len())
    }

    /// Labels of the images at `idx`, in order.
    pub fn labels_for(&self, idx: &[usize]) -> Vec<u8> {
        idx.iter().map(|&i| self.labels[i]).collect()
    }

    /// The evaluation set cut into `batch`-image chunks of the
    /// deterministic stratified order (see [`stratified_order`]): any
    /// prefix of the returned batches is label-balanced to within one
    /// image per class, and shorter prefixes are strict subsets of
    /// longer ones -- the nesting property multi-fidelity racing needs
    /// so rung k's images are always contained in rung k+1's
    /// ([`crate::search::Fidelity::batches_of`] picks the prefix
    /// length). The final batch may be short.
    pub fn stratified_batches(&self, batch: usize) -> Vec<Vec<usize>> {
        let order = stratified_order(&self.labels);
        order.chunks(batch.max(1)).map(|c| c.to_vec()).collect()
    }
}

/// Deterministic stratified interleave of `0..labels.len()`: group the
/// indices by label (first-appearance order of the labels, original
/// order within a label) and emit them round-robin, one index per label
/// per round. Every prefix of the result is label-balanced to within
/// one image per class, and the function is pure -- no RNG -- so the
/// order is identical across processes, thread counts, and runs.
///
/// A dataset whose labels already cycle `0, 1, .., k-1, 0, 1, ..` (the
/// self-labeled synthetic evaluation sets) is a fixed point: the
/// stratified order is the identity.
pub fn stratified_order(labels: &[u8]) -> Vec<usize> {
    let mut by_label: Vec<(u8, Vec<usize>)> = Vec::new();
    for (i, &l) in labels.iter().enumerate() {
        match by_label.iter_mut().find(|(tag, _)| *tag == l) {
            Some((_, idx)) => idx.push(i),
            None => by_label.push((l, vec![i])),
        }
    }
    let mut out = Vec::with_capacity(labels.len());
    let mut round = 0usize;
    while out.len() < labels.len() {
        for (_, idx) in &by_label {
            if let Some(&i) = idx.get(round) {
                out.push(i);
            }
        }
        round += 1;
    }
    out
}

/// The paper's "Image Selector": draws the calibration subset from the
/// calibration pool. Sample counts mirror the paper's {1, 1000, 10000}
/// at our scale: {1, 64, 512}.
pub fn select_calibration_images(
    pool_size: usize,
    count: usize,
    seed: u64,
) -> Vec<usize> {
    let count = count.min(pool_size);
    let mut rng = Pcg32::new(seed, 7);
    rng.sample_indices(pool_size, count)
}

/// A seeded random dataset (no files needed). Used by the perf bench and
/// the parallel engine's parity/determinism tests; `n == 0` is a valid
/// empty split.
pub fn synthetic_dataset(
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    classes: usize,
    seed: u64,
) -> Dataset {
    let mut rng = Pcg32::new(seed, 31);
    Dataset {
        images: (0..n * h * w * c).map(|_| rng.below(256) as u8).collect(),
        labels: (0..n).map(|_| rng.below(classes.max(1)) as u8).collect(),
        n,
        h,
        w,
        c,
    }
}

/// Named weight tensors loaded from a `.qtw` file.
pub struct Weights {
    /// Tensors by name.
    pub tensors: HashMap<String, Tensor>,
    /// Names in file order (== the flat ABI order of the HLO artifacts).
    pub order: Vec<String>,
}

impl Weights {
    /// Load a `.qtw` weight file (see python/compile/aot.py).
    pub fn load(path: &Path) -> Result<Weights> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != b"QTW1" {
            bail!("{}: bad magic", path.display());
        }
        let count = read_u32(&mut f)? as usize;
        let mut tensors = HashMap::new();
        let mut order = Vec::new();
        for _ in 0..count {
            let mut lb = [0u8; 2];
            f.read_exact(&mut lb)?;
            let name_len = u16::from_le_bytes(lb) as usize;
            let mut name = vec![0u8; name_len];
            f.read_exact(&mut name)?;
            let name = String::from_utf8(name)?;
            let mut hdr = [0u8; 2];
            f.read_exact(&mut hdr)?;
            let (dtype, ndim) = (hdr[0], hdr[1] as usize);
            if dtype != 0 {
                bail!("tensor {name}: unsupported dtype {dtype}");
            }
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(read_u32(&mut f)? as usize);
            }
            let len: usize = shape.iter().product();
            let mut bytes = vec![0u8; len * 4];
            f.read_exact(&mut bytes)?;
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            order.push(name.clone());
            tensors.insert(name, Tensor { shape, data });
        }
        Ok(Weights { tensors, order })
    }

    /// Tensor by name, or a descriptive error.
    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors.get(name).ok_or_else(|| anyhow::anyhow!("missing weight {name}"))
    }

    /// Tensors in the flat ABI order (for feeding HLO executables).
    pub fn flat(&self) -> Vec<&Tensor> {
        self.order.iter().map(|n| &self.tensors[n]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selector_is_deterministic_and_distinct() {
        let a = select_calibration_images(512, 64, 9);
        let b = select_calibration_images(512, 64, 9);
        assert_eq!(a, b);
        let mut s = a.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 64);
    }

    #[test]
    fn selector_caps_at_pool() {
        assert_eq!(select_calibration_images(8, 100, 1).len(), 8);
    }

    #[test]
    fn batch_normalization_range() {
        let ds = Dataset {
            images: vec![0, 255, 127, 128, 0, 255],
            labels: vec![0],
            n: 1,
            h: 1,
            w: 2,
            c: 3,
        };
        let t = ds.batch(&[0]);
        assert_eq!(t.shape, vec![1, 1, 2, 3]);
        assert!((t.data[0] + 1.0).abs() < 1e-6);
        assert!((t.data[1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn stratified_order_interleaves_labels() {
        // grouped labels -> round-robin interleave, stable within a label
        let labels = [0u8, 0, 0, 1, 1, 2];
        assert_eq!(stratified_order(&labels), vec![0, 3, 5, 1, 4, 2]);
        // cycling labels are a fixed point (the identity order)
        let cycling: Vec<u8> = (0..12).map(|i| (i % 4) as u8).collect();
        assert_eq!(stratified_order(&cycling), (0..12).collect::<Vec<_>>());
        // label-balance of every prefix: counts differ by at most one
        // while a class still has images left
        let labels: Vec<u8> = (0..30).map(|i| (i * 7 % 3) as u8).collect();
        let order = stratified_order(&labels);
        for take in 1..=30 {
            let mut counts = [0usize; 3];
            for &i in &order[..take] {
                counts[labels[i] as usize] += 1;
            }
            let (mn, mx) =
                (counts.iter().min().unwrap(), counts.iter().max().unwrap());
            assert!(mx - mn <= 1, "prefix {take}: unbalanced {counts:?}");
        }
        assert!(stratified_order(&[]).is_empty());
    }

    #[test]
    fn stratified_batches_nest() {
        let ds = synthetic_dataset(50, 1, 1, 1, 4, 3);
        let batches = ds.stratified_batches(8);
        assert_eq!(batches.iter().map(Vec::len).sum::<usize>(), 50);
        assert_eq!(batches.last().unwrap().len(), 2, "final batch is short");
        // deterministic + a permutation of the whole set
        assert_eq!(batches, ds.stratified_batches(8));
        let mut all: Vec<usize> = batches.concat();
        all.sort_unstable();
        assert_eq!(all, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn batch_padded_repeats_last() {
        let ds = Dataset {
            images: vec![10, 20],
            labels: vec![1, 2],
            n: 2,
            h: 1,
            w: 1,
            c: 1,
        };
        let (t, valid) = ds.batch_padded(&[0, 1], 4);
        assert_eq!(valid, 2);
        assert_eq!(t.shape[0], 4);
        assert_eq!(t.data[1], t.data[2]);
        assert_eq!(t.data[2], t.data[3]);
    }
}
