//! Result analysis: Top-1 bookkeeping and the Shannon-entropy diversity
//! analysis of Table 4.

use crate::quant::QuantConfig;
use crate::util::stats::shannon_entropy;

/// Per-dimension Shannon entropy of the configs whose accuracy is within
/// `threshold` of the fp32 baseline (the paper uses the MLPerf 1% margin).
#[derive(Clone, Debug)]
pub struct DiversityAnalysis {
    /// Entropy of the mixed-precision bit among qualifying configs.
    pub precision: f64,
    /// Entropy of the calibration image count.
    pub calibration: f64,
    /// Entropy of the weight-scale granularity.
    pub granularity: f64,
    /// Entropy of the clipping policy.
    pub clipping: f64,
    /// Entropy of the quantization scheme.
    pub scheme: f64,
    /// Number of (model, config) pairs that qualified.
    pub num_samples: usize,
}

impl DiversityAnalysis {
    /// `tables`: per model, (fp32 accuracy, per-config accuracies).
    /// Configs within `threshold` (absolute accuracy drop) qualify.
    pub fn compute(tables: &[(f64, Vec<f64>)], threshold: f64) -> DiversityAnalysis {
        let mut calib = Vec::new();
        let mut scheme = Vec::new();
        let mut clip = Vec::new();
        let mut gran = Vec::new();
        let mut mixed = Vec::new();
        for (fp32, accs) in tables {
            for (i, &a) in accs.iter().enumerate() {
                if a.is_nan() || a < fp32 - threshold {
                    continue;
                }
                let c = QuantConfig::from_index(i).expect("index in space");
                calib.push(c.calib.index());
                scheme.push(c.scheme.name());
                clip.push(c.clip == crate::quant::Clipping::Kl);
                gran.push(c.gran == crate::quant::Granularity::Channel);
                mixed.push(c.mixed);
            }
        }
        DiversityAnalysis {
            precision: shannon_entropy(&mixed),
            calibration: shannon_entropy(&calib),
            granularity: shannon_entropy(&gran),
            clipping: shannon_entropy(&clip),
            scheme: shannon_entropy(&scheme),
            num_samples: calib.len(),
        }
    }

    /// All dimensions carry non-zero entropy => no universal config
    /// (the paper's Table 4 takeaway).
    pub fn no_universal_config(&self) -> bool {
        self.precision > 0.0
            && self.calibration > 0.0
            && self.granularity > 0.0
            && self.clipping > 0.0
            && self.scheme > 0.0
    }
}

/// Summary row of one model's sweep (Table 1).
#[derive(Clone, Debug)]
pub struct BestConfigRow {
    /// Model name.
    pub model: String,
    /// fp32 reference Top-1.
    pub fp32_top1: f64,
    /// The sweep's best configuration.
    pub best: QuantConfig,
    /// Top-1 of the best configuration.
    pub best_top1: f64,
}

impl BestConfigRow {
    /// Signed Top-1 delta of the best config against fp32.
    pub fn error_vs_fp32(&self) -> f64 {
        self.best_top1 - self.fp32_top1
    }

    /// Formatted like the paper's Table 1 accuracy column.
    pub fn accuracy_cell(&self) -> String {
        format!(
            "{:.2}({:+.2})%",
            self.best_top1 * 100.0,
            self.error_vs_fp32() * 100.0
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diversity_zero_when_one_config_wins() {
        // only config 0 is good in a single table -> all entropies zero
        let mut accs = vec![0.0; QuantConfig::SPACE_SIZE];
        accs[0] = 0.9;
        let d = DiversityAnalysis::compute(&[(0.9, accs)], 0.01);
        assert_eq!(d.num_samples, 1);
        assert!(!d.no_universal_config());
        assert_eq!(d.scheme, 0.0);
    }

    #[test]
    fn diversity_positive_when_many_configs_qualify() {
        // every config within 1%: entropies equal the marginal entropies
        let accs = vec![0.9; QuantConfig::SPACE_SIZE];
        let d = DiversityAnalysis::compute(&[(0.9, accs)], 0.01);
        assert_eq!(d.num_samples, 96);
        assert!(d.no_universal_config());
        // scheme is uniform over 4 -> ln 4
        assert!((d.scheme - 4f64.ln()).abs() < 1e-9);
        assert!((d.clipping - 2f64.ln()).abs() < 1e-9);
    }

    #[test]
    fn accuracy_cell_format() {
        let row = BestConfigRow {
            model: "mn".into(),
            fp32_top1: 0.7181,
            best: QuantConfig::from_index(0).unwrap(),
            best_top1: 0.7123,
        };
        assert_eq!(row.accuracy_cell(), "71.23(-0.58)%");
    }
}
