//! Result analysis: Top-1 bookkeeping, the Shannon-entropy diversity
//! analysis of Table 4, and interpreter dispatch accounting (what
//! fraction of a sweep's MACs ran on the integer engine).

use std::sync::atomic::{AtomicU64, Ordering};

use crate::quant::QuantConfig;
use crate::util::stats::shannon_entropy;

/// Shared counters recording, per fake-quant conv/dense dispatch,
/// whether the layer ran on the integer engine or fell back to the f32
/// route, plus the MAC volume of each. Relaxed atomics: the counts are
/// monotonic tallies with no ordering dependencies, safe to bump from
/// every worker thread concurrently.
#[derive(Debug, Default)]
pub struct DispatchCounters {
    int_layers: AtomicU64,
    fallback_layers: AtomicU64,
    int_macs: AtomicU64,
    fallback_macs: AtomicU64,
}

impl DispatchCounters {
    /// Fresh zeroed counters.
    pub fn new() -> DispatchCounters {
        DispatchCounters::default()
    }

    /// Record one conv/dense dispatch: `int_path` says which engine ran
    /// it, `macs` its multiply-accumulate volume.
    pub fn record(&self, int_path: bool, macs: u64) {
        if int_path {
            self.int_layers.fetch_add(1, Ordering::Relaxed);
            self.int_macs.fetch_add(macs, Ordering::Relaxed);
        } else {
            self.fallback_layers.fetch_add(1, Ordering::Relaxed);
            self.fallback_macs.fetch_add(macs, Ordering::Relaxed);
        }
    }

    /// Snapshot the tallies (prepack stats are filled in by the caller
    /// that owns the weight cache; they default to zero here).
    pub fn snapshot(&self) -> DispatchStats {
        DispatchStats {
            int_layers: self.int_layers.load(Ordering::Relaxed),
            fallback_layers: self.fallback_layers.load(Ordering::Relaxed),
            int_macs: self.int_macs.load(Ordering::Relaxed),
            fallback_macs: self.fallback_macs.load(Ordering::Relaxed),
            prepack_hits: 0,
            prepack_builds: 0,
        }
    }
}

/// Point-in-time view of [`DispatchCounters`], plus the weight cache's
/// prepack reuse tallies.
#[derive(Clone, Copy, Debug, Default)]
pub struct DispatchStats {
    /// Conv/dense dispatches that ran on the integer engine.
    pub int_layers: u64,
    /// Conv/dense dispatches that fell back to the f32 route.
    pub fallback_layers: u64,
    /// MACs executed on the integer engine.
    pub int_macs: u64,
    /// MACs executed on the f32 fallback.
    pub fallback_macs: u64,
    /// Prepacked-weight cache hits (panel reused across variants).
    pub prepack_hits: u64,
    /// Prepacked-weight cache builds (panel packed from scratch).
    pub prepack_builds: u64,
}

impl DispatchStats {
    /// Fraction of all fake-quant MACs that ran on the integer engine
    /// (0.0 when nothing was dispatched).
    pub fn integer_mac_fraction(&self) -> f64 {
        let total = self.int_macs + self.fallback_macs;
        if total == 0 {
            0.0
        } else {
            self.int_macs as f64 / total as f64
        }
    }
}

/// Per-dimension Shannon entropy of the configs whose accuracy is within
/// `threshold` of the fp32 baseline (the paper uses the MLPerf 1% margin).
#[derive(Clone, Debug)]
pub struct DiversityAnalysis {
    /// Entropy of the mixed-precision bit among qualifying configs.
    pub precision: f64,
    /// Entropy of the calibration image count.
    pub calibration: f64,
    /// Entropy of the weight-scale granularity.
    pub granularity: f64,
    /// Entropy of the clipping policy.
    pub clipping: f64,
    /// Entropy of the quantization scheme.
    pub scheme: f64,
    /// Number of (model, config) pairs that qualified.
    pub num_samples: usize,
}

impl DiversityAnalysis {
    /// `tables`: per model, (fp32 accuracy, per-config accuracies).
    /// Configs within `threshold` (absolute accuracy drop) qualify.
    pub fn compute(tables: &[(f64, Vec<f64>)], threshold: f64) -> DiversityAnalysis {
        let mut calib = Vec::new();
        let mut scheme = Vec::new();
        let mut clip = Vec::new();
        let mut gran = Vec::new();
        let mut mixed = Vec::new();
        for (fp32, accs) in tables {
            for (i, &a) in accs.iter().enumerate() {
                if a.is_nan() || a < fp32 - threshold {
                    continue;
                }
                let c = QuantConfig::from_index(i).expect("index in space");
                calib.push(c.calib.index());
                scheme.push(c.scheme.name());
                clip.push(c.clip == crate::quant::Clipping::Kl);
                gran.push(c.gran == crate::quant::Granularity::Channel);
                mixed.push(c.mixed);
            }
        }
        DiversityAnalysis {
            precision: shannon_entropy(&mixed),
            calibration: shannon_entropy(&calib),
            granularity: shannon_entropy(&gran),
            clipping: shannon_entropy(&clip),
            scheme: shannon_entropy(&scheme),
            num_samples: calib.len(),
        }
    }

    /// All dimensions carry non-zero entropy => no universal config
    /// (the paper's Table 4 takeaway).
    pub fn no_universal_config(&self) -> bool {
        self.precision > 0.0
            && self.calibration > 0.0
            && self.granularity > 0.0
            && self.clipping > 0.0
            && self.scheme > 0.0
    }
}

/// Summary row of one model's sweep (Table 1).
#[derive(Clone, Debug)]
pub struct BestConfigRow {
    /// Model name.
    pub model: String,
    /// fp32 reference Top-1.
    pub fp32_top1: f64,
    /// The sweep's best configuration.
    pub best: QuantConfig,
    /// Top-1 of the best configuration.
    pub best_top1: f64,
}

impl BestConfigRow {
    /// Signed Top-1 delta of the best config against fp32.
    pub fn error_vs_fp32(&self) -> f64 {
        self.best_top1 - self.fp32_top1
    }

    /// Formatted like the paper's Table 1 accuracy column.
    pub fn accuracy_cell(&self) -> String {
        format!(
            "{:.2}({:+.2})%",
            self.best_top1 * 100.0,
            self.error_vs_fp32() * 100.0
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diversity_zero_when_one_config_wins() {
        // only config 0 is good in a single table -> all entropies zero
        let mut accs = vec![0.0; QuantConfig::SPACE_SIZE];
        accs[0] = 0.9;
        let d = DiversityAnalysis::compute(&[(0.9, accs)], 0.01);
        assert_eq!(d.num_samples, 1);
        assert!(!d.no_universal_config());
        assert_eq!(d.scheme, 0.0);
    }

    #[test]
    fn diversity_positive_when_many_configs_qualify() {
        // every config within 1%: entropies equal the marginal entropies
        let accs = vec![0.9; QuantConfig::SPACE_SIZE];
        let d = DiversityAnalysis::compute(&[(0.9, accs)], 0.01);
        assert_eq!(d.num_samples, 96);
        assert!(d.no_universal_config());
        // scheme is uniform over 4 -> ln 4
        assert!((d.scheme - 4f64.ln()).abs() < 1e-9);
        assert!((d.clipping - 2f64.ln()).abs() < 1e-9);
    }

    #[test]
    fn dispatch_counters_tally_and_fraction() {
        let c = DispatchCounters::new();
        c.record(true, 600);
        c.record(true, 200);
        c.record(false, 200);
        let s = c.snapshot();
        assert_eq!(s.int_layers, 2);
        assert_eq!(s.fallback_layers, 1);
        assert_eq!(s.int_macs, 800);
        assert_eq!(s.fallback_macs, 200);
        assert!((s.integer_mac_fraction() - 0.8).abs() < 1e-12);
        assert_eq!(DispatchStats::default().integer_mac_fraction(), 0.0);
    }

    #[test]
    fn accuracy_cell_format() {
        let row = BestConfigRow {
            model: "mn".into(),
            fp32_top1: 0.7181,
            best: QuantConfig::from_index(0).unwrap(),
            best_top1: 0.7123,
        };
        assert_eq!(row.accuracy_cell(), "71.23(-0.58)%");
    }
}
