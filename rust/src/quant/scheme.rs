//! The four uniform quantization schemes (paper §4.2, Eq. 2-13) and the
//! [`BitWidth`] grid they are instantiated on.
//!
//! A scheme maps an observed float range [min, max] to affine grid
//! parameters (scale, zero_point, qmin, qmax). The fake-quant evaluation
//! path and the HLO graphs consume these as plain numbers, so all four
//! schemes share one quantizer kernel. The paper works on the int8 grid;
//! [`Scheme::params_for`] generalizes the same equations to saturating
//! int4 and int16 grids for the per-layer radix search
//! ([`crate::quant::LayerwiseSpace`]).

use std::fmt;

use anyhow::Result;

/// Per-layer numeric precision of a weight tensor: a saturating signed
/// integer grid (int4 / int8 / int16) or the fp32 bypass.
///
/// The radix genome of [`crate::quant::LayerwiseSpace`] chooses one of
/// these per weighted layer. Integer widths fake-quantize the weights
/// onto the `2^bits`-level grid of the base scheme (activations stay on
/// the int8 grid, as in weight-only mixed-precision PTQ); [`Fp32`]
/// bypasses both the weight and the layer's activation quantization.
///
/// [`Fp32`]: BitWidth::Fp32
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum BitWidth {
    /// 4-bit signed grid (16 levels, saturating at ±(2^3 - 1) under
    /// symmetric schemes) -- the aggressive end of Banner et al.'s
    /// post-training 4-bit regime.
    Int4,
    /// 8-bit signed grid (the paper's default precision).
    Int8,
    /// 16-bit signed grid (near-lossless fallback for fragile layers
    /// that is still half the fp32 bytes).
    Int16,
    /// No quantization: the layer's weights and output activations stay
    /// fp32 (the §4.5 mixed-precision bypass).
    Fp32,
}

/// Every width, ascending by bits.
pub const ALL_WIDTHS: [BitWidth; 4] =
    [BitWidth::Int4, BitWidth::Int8, BitWidth::Int16, BitWidth::Fp32];

/// The legacy binary menu of PR 2's layer mask: {int8, fp32}.
pub const BINARY_WIDTHS: [BitWidth; 2] = [BitWidth::Int8, BitWidth::Fp32];

impl BitWidth {
    /// Bits per stored weight element (fp32 counts its full 32).
    pub fn bits(self) -> u32 {
        match self {
            BitWidth::Int4 => 4,
            BitWidth::Int8 => 8,
            BitWidth::Int16 => 16,
            BitWidth::Fp32 => 32,
        }
    }

    /// Is this the fp32 (no-quantization) bypass?
    pub fn is_float(self) -> bool {
        self == BitWidth::Fp32
    }

    /// Largest representable positive grid value (`2^(bits-1) - 1`);
    /// `None` for fp32.
    pub fn qmax(self) -> Option<f32> {
        match self {
            BitWidth::Fp32 => None,
            w => Some(((1u32 << (w.bits() - 1)) - 1) as f32),
        }
    }

    /// Serialized bytes of `elems` weight elements at this width. int4
    /// packs two elements per byte (odd counts round up); int8/int16/
    /// fp32 are 1/2/4 bytes per element.
    pub fn weight_bytes(self, elems: usize) -> u64 {
        match self {
            BitWidth::Int4 => elems.div_ceil(2) as u64,
            BitWidth::Int8 => elems as u64,
            BitWidth::Int16 => 2 * elems as u64,
            BitWidth::Fp32 => 4 * elems as u64,
        }
    }

    /// Canonical name (`int4` / `int8` / `int16` / `fp32`).
    pub fn name(self) -> &'static str {
        match self {
            BitWidth::Int4 => "int4",
            BitWidth::Int8 => "int8",
            BitWidth::Int16 => "int16",
            BitWidth::Fp32 => "fp32",
        }
    }

    /// Parse a width spec: a bare bit count (`4`, `8`, `16`, `32`) or a
    /// canonical name (`int4`, ..., `fp32`).
    pub fn parse(s: &str) -> Option<BitWidth> {
        match s.trim() {
            "4" | "int4" => Some(BitWidth::Int4),
            "8" | "int8" => Some(BitWidth::Int8),
            "16" | "int16" => Some(BitWidth::Int16),
            "32" | "fp32" => Some(BitWidth::Fp32),
            _ => None,
        }
    }
}

impl fmt::Display for BitWidth {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Parse a `--bits` CSV spec (e.g. `"4,8,16"`) into a width list.
/// Duplicates are an error; fp32 may be listed but is implied (the
/// layer-wise space always appends it as the bypass choice).
///
/// # Examples
///
/// ```
/// use quantune::quant::{parse_bits_spec, BitWidth};
///
/// # fn main() -> anyhow::Result<()> {
/// let menu = parse_bits_spec("4,8,16")?;
/// assert_eq!(menu, vec![BitWidth::Int4, BitWidth::Int8, BitWidth::Int16]);
/// assert!(parse_bits_spec("fp32").is_err(), "needs an integer width");
/// # Ok(())
/// # }
/// ```
pub fn parse_bits_spec(spec: &str) -> Result<Vec<BitWidth>> {
    let mut out = Vec::new();
    for part in spec.split(',') {
        let w = BitWidth::parse(part).ok_or_else(|| {
            anyhow::anyhow!(
                "bad bit-width {part:?} in {spec:?} (try a CSV of 4|8|16|fp32)"
            )
        })?;
        anyhow::ensure!(!out.contains(&w), "duplicate bit-width {w} in {spec:?}");
        out.push(w);
    }
    anyhow::ensure!(
        out.iter().any(|w| !w.is_float()),
        "{spec:?} needs at least one integer width (4, 8, or 16)"
    );
    Ok(out)
}

/// Uniform quantization scheme.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Scheme {
    /// Affine: full int8 range, arbitrary zero point (Eq. 2-5).
    Asymmetric,
    /// Zero maps to zero; scale from the absolute maximum (Eq. 6-8).
    Symmetric,
    /// Glow's "symmetric with uint8" (Eq. 9-12): all-positive ranges use
    /// the uint8 grid (zero_point = -128); ranges with negatives fall
    /// back to symmetric.
    SymmetricUint8,
    /// Symmetric with the scale rounded to a power of two (Eq. 13);
    /// requantization becomes a bit-shift -- the only scheme an
    /// integer-only accelerator (VTA) can execute.
    Pow2,
}

/// Every scheme, in index order.
pub const ALL_SCHEMES: [Scheme; 4] =
    [Scheme::Asymmetric, Scheme::Symmetric, Scheme::SymmetricUint8, Scheme::Pow2];

impl Scheme {
    /// Canonical name ("asymmetric", ...).
    pub fn name(self) -> &'static str {
        match self {
            Scheme::Asymmetric => "asymmetric",
            Scheme::Symmetric => "symmetric",
            Scheme::SymmetricUint8 => "symmetric_uint8",
            Scheme::Pow2 => "pow2",
        }
    }

    /// Parse a canonical scheme name.
    pub fn parse(s: &str) -> Option<Scheme> {
        ALL_SCHEMES.iter().copied().find(|x| x.name() == s)
    }

    /// Can the whole inference run with integer multiply/add/shift only?
    pub fn integer_only(self) -> bool {
        matches!(self, Scheme::Pow2)
    }

    /// int8 grid parameters for an observed range (paper Eq. 3/4, 7,
    /// 10/11, 13). Shorthand for [`Scheme::params_for`] at
    /// [`BitWidth::Int8`].
    pub fn params_from_range(self, min: f32, max: f32) -> QParams {
        self.params_for(min, max, BitWidth::Int8)
    }

    /// Grid parameters for an observed range on an arbitrary integer
    /// grid: the paper's int8 equations with 127/128/255 replaced by
    /// the `width` grid's `qmax`/`|qmin|`/level count. Narrow grids
    /// saturate (values round then clamp to [qmin, qmax]), which is
    /// what makes the int4 path well-defined on outlier-heavy tensors.
    ///
    /// [`BitWidth::Fp32`] returns [`QParams::identity`] -- the bypass
    /// row the activation tables use. Callers must branch on
    /// [`BitWidth::is_float`] instead of fake-quantizing through it
    /// (the identity row still rounds; bypass is a flag, not a grid).
    pub fn params_for(self, min: f32, max: f32, width: BitWidth) -> QParams {
        let Some(qmax) = width.qmax() else {
            return QParams::identity();
        };
        let qmin = -(qmax + 1.0);
        let levels = 2.0 * qmax + 1.0; // full signed range, e.g. 255 at int8
        // guard degenerate ranges; include zero like every practical
        // quantizer so that zero is exactly representable
        let min = min.min(0.0);
        let max = max.max(0.0);
        let absmax = min.abs().max(max.abs()).max(1e-12);
        match self {
            Scheme::Asymmetric => {
                let scale = ((max - min) / levels).max(1e-12);
                let zero_point =
                    (-(min / scale)).round_ties_even() as i32 + qmin as i32;
                QParams { scale, zero_point, qmin, qmax }
            }
            Scheme::Symmetric => {
                QParams { scale: absmax / qmax, zero_point: 0, qmin, qmax }
            }
            Scheme::SymmetricUint8 => {
                if min >= 0.0 {
                    // unsigned grid stored in the signed range with a
                    // -2^(bits-1) offset (Glow's uint8 trick, per width)
                    QParams {
                        scale: (max / levels).max(1e-12),
                        zero_point: qmin as i32,
                        qmin,
                        qmax,
                    }
                } else {
                    QParams { scale: absmax / qmax, zero_point: 0, qmin, qmax }
                }
            }
            Scheme::Pow2 => {
                let exp = (absmax / qmax).log2().round().clamp(-31.0, 31.0);
                QParams { scale: exp.exp2(), zero_point: 0, qmin, qmax }
            }
        }
    }
}

impl fmt::Display for Scheme {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Affine int8 grid parameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QParams {
    /// Float value of one grid step.
    pub scale: f32,
    /// Grid value that represents float zero.
    pub zero_point: i32,
    /// Smallest grid value (saturation floor).
    pub qmin: f32,
    /// Largest grid value (saturation ceiling).
    pub qmax: f32,
}

impl QParams {
    /// Identity parameters (used for bypassed fp32 tensors).
    pub fn identity() -> QParams {
        QParams { scale: 1.0, zero_point: 0, qmin: -128.0, qmax: 127.0 }
    }

    /// Quantize one value to the int grid (round-half-to-even, matching
    /// XLA RoundNearestEven and jnp.round).
    pub fn quantize(&self, x: f32) -> i32 {
        let q = (x / self.scale + self.zero_point as f32).round_ties_even();
        q.clamp(self.qmin, self.qmax) as i32
    }

    /// Dequantize an int grid value.
    pub fn dequantize(&self, q: i32) -> f32 {
        (q - self.zero_point) as f32 * self.scale
    }

    /// Quantize-dequantize (the fake-quant the HLO graphs apply).
    pub fn fake_quant(&self, x: f32) -> f32 {
        self.dequantize(self.quantize(x))
    }

    /// Requantize an integer accumulator onto this grid.
    ///
    /// This is the integer kernel path's output hop: a zero-point-
    /// corrected i8/i4 GEMM accumulator `acc` carries an effective float
    /// scale `acc_scale` (the product of its operand scales, e.g.
    /// `s_a * s_w`), so `acc * acc_scale` is the real-valued result and
    /// requantizing is a single [`QParams::quantize`] onto this grid.
    /// Equals `self.quantize(acc as f32 * acc_scale)` by construction,
    /// so integer consumers never materialize an f32 tensor to hop grids.
    pub fn requantize(&self, acc: i32, acc_scale: f32) -> i32 {
        self.quantize(acc as f32 * acc_scale)
    }

    /// Worst-case absolute rounding error inside the clipped range.
    pub fn step(&self) -> f32 {
        self.scale * 0.5
    }

    /// Build the integer-only requantizer for accumulators carrying the
    /// effective float scale `acc_scale` landing on this grid. See
    /// [`FixedRequant`] for the contract.
    pub fn fixed_requant(&self, acc_scale: f32) -> FixedRequant {
        FixedRequant::new(acc_scale, self)
    }

    /// The representable float interval.
    pub fn float_range(&self) -> (f32, f32) {
        (
            self.dequantize(self.qmin as i32),
            self.dequantize(self.qmax as i32),
        )
    }
}

/// Integer-only requantizer: a fixed-point multiplier + rounding shift
/// that maps an i32 GEMM accumulator onto an output grid without any
/// float arithmetic (the gemmlowp / Jacob et al. deployment recipe).
///
/// Construction factors the real ratio `acc_scale / out.scale` as
/// `mult * 2^-shift` with `mult` the exact 53-bit f64 mantissa, so
/// `apply` computes `round_half_even(acc * mult * 2^-shift) + zero_point`
/// clamped to the grid -- bit-identical to rounding the *infinitely
/// precise* product `acc * (acc_scale/out.scale)` whenever that ratio is
/// exactly representable in f64 (always true for [`Scheme::Pow2`], where
/// the shift degenerates to a pure bit-shift).
///
/// This is the deployment-style path an integer-only target (e.g. VTA)
/// would run. The interpreter's oracle-parity hot loop deliberately does
/// *not* use it: bit-exactness against the f32 fake-quant oracle requires
/// replaying the oracle's f32 operation order, which
/// [`QParams::requantize`] does. Tests pin the two against each other on
/// ratios where f32 rounding cannot diverge.
///
/// # Examples
///
/// ```
/// use quantune::quant::Scheme;
///
/// let out = Scheme::Pow2.params_from_range(-2.0, 2.0);
/// let rq = out.fixed_requant(out.scale * 0.5); // dyadic ratio: exact
/// for acc in [-300, -1, 0, 7, 1000] {
///     assert_eq!(rq.apply(acc), out.requantize(acc, out.scale * 0.5));
/// }
/// ```
#[derive(Clone, Copy, Debug)]
pub struct FixedRequant {
    /// Fixed-point multiplier (the 53-bit mantissa of the ratio), or a
    /// sentinel for degenerate ratios (see `new`).
    mult: i64,
    /// Right-shift applied after the multiply; negative means left-shift.
    shift: i32,
    zero_point: i32,
    qmin: i32,
    qmax: i32,
}

impl FixedRequant {
    /// Factor `acc_scale / out.scale` into multiplier + shift for `out`'s
    /// grid. Zero/subnormal ratios collapse to "always returns the zero
    /// point" (the accumulator carries no representable signal).
    pub fn new(acc_scale: f32, out: &QParams) -> FixedRequant {
        let zero_point = out.zero_point;
        let (qmin, qmax) = (out.qmin as i32, out.qmax as i32);
        let ratio = acc_scale as f64 / out.scale as f64;
        if !(ratio.is_finite() && ratio >= f64::MIN_POSITIVE) {
            // zero, subnormal, negative, or non-finite ratio: no signal
            return FixedRequant { mult: 0, shift: 0, zero_point, qmin, qmax };
        }
        // exact binary factoring: ratio = m * 2^exp with m in [1, 2), so
        // mult = m * 2^52 is the integer mantissa and the residual shift
        // is 52 - exp (shift right if positive, left if negative)
        let exp = ((ratio.to_bits() >> 52) & 0x7ff) as i32 - 1023;
        let mult = (ratio / 2f64.powi(exp) * (1u64 << 52) as f64) as i64;
        FixedRequant { mult, shift: 52 - exp, zero_point, qmin, qmax }
    }

    /// Requantize one accumulator value: multiply, round-half-even shift,
    /// add the zero point, clamp to the grid.
    pub fn apply(&self, acc: i32) -> i32 {
        let prod = acc as i128 * self.mult as i128;
        let rounded: i128 = if self.shift <= 0 {
            // huge ratio: the product only grows; i128 holds
            // |acc| * mult * 2^|shift| for any shift >= -43 (i.e. any
            // exp <= 95, far beyond finite grids), so shift safely
            prod << (-self.shift).min(43)
        } else if self.shift >= 127 {
            0 // rounds to zero for any i32 accumulator
        } else {
            let floor = prod >> self.shift;
            let rem = prod - (floor << self.shift);
            let half = 1i128 << (self.shift - 1);
            // round half to even, matching f32/f64 round_ties_even
            if rem > half || (rem == half && floor & 1 == 1) {
                floor + 1
            } else {
                floor
            }
        };
        let q = rounded.clamp(i32::MIN as i128, i32::MAX as i128) as i32;
        q.saturating_add(self.zero_point).clamp(self.qmin, self.qmax)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn asymmetric_uses_full_range() {
        let p = Scheme::Asymmetric.params_from_range(-1.0, 3.0);
        let (lo, hi) = p.float_range();
        assert!((lo - -1.0).abs() < p.scale, "lo {lo}");
        assert!((hi - 3.0).abs() < p.scale, "hi {hi}");
        // zero is representable exactly
        assert!(p.fake_quant(0.0).abs() <= p.scale * 0.5 + 1e-9);
    }

    #[test]
    fn symmetric_zero_maps_to_zero() {
        let p = Scheme::Symmetric.params_from_range(-2.0, 1.0);
        assert_eq!(p.zero_point, 0);
        assert_eq!(p.fake_quant(0.0), 0.0);
        assert!((p.scale - 2.0 / 127.0).abs() < 1e-9);
    }

    #[test]
    fn symmetric_uint8_switches_on_sign() {
        let pos = Scheme::SymmetricUint8.params_from_range(0.0, 6.0);
        assert_eq!(pos.zero_point, -128);
        assert!((pos.scale - 6.0 / 255.0).abs() < 1e-9);
        let neg = Scheme::SymmetricUint8.params_from_range(-1.0, 6.0);
        assert_eq!(neg.zero_point, 0);
        assert!((neg.scale - 6.0 / 127.0).abs() < 1e-9);
    }

    #[test]
    fn pow2_scale_is_power_of_two() {
        let p = Scheme::Pow2.params_from_range(-3.0, 3.0);
        let exp = p.scale.log2();
        assert_eq!(exp, exp.round());
        assert_eq!(p.zero_point, 0);
    }

    #[test]
    fn quantize_clamps() {
        let p = Scheme::Symmetric.params_from_range(-1.0, 1.0);
        assert_eq!(p.quantize(100.0), 127);
        assert_eq!(p.quantize(-100.0), -128);
    }

    #[test]
    fn fake_quant_error_bounded() {
        for scheme in ALL_SCHEMES {
            let p = scheme.params_from_range(-4.0, 4.0);
            let (lo, hi) = p.float_range();
            for i in -40..=40 {
                let x = i as f32 / 10.0;
                let err = (p.fake_quant(x) - x).abs();
                // inside the representable interval: rounding error only;
                // at the edges (pow2 rounds the scale down) saturation can
                // add up to one extra step
                let bound = if x >= lo && x <= hi {
                    p.scale * 0.5
                } else {
                    p.scale
                };
                assert!(
                    err <= bound + 1e-6,
                    "{scheme}: x={x} err={err} scale={}",
                    p.scale
                );
            }
        }
    }

    #[test]
    fn degenerate_range_is_safe() {
        for scheme in ALL_SCHEMES {
            let p = scheme.params_from_range(0.0, 0.0);
            assert!(p.scale > 0.0);
            let y = p.fake_quant(0.0);
            assert!(y.is_finite());
        }
    }

    #[test]
    fn width_grid_constants() {
        assert_eq!(BitWidth::Int4.qmax(), Some(7.0));
        assert_eq!(BitWidth::Int8.qmax(), Some(127.0));
        assert_eq!(BitWidth::Int16.qmax(), Some(32767.0));
        assert_eq!(BitWidth::Fp32.qmax(), None);
        // int4 packs two elements per byte, odd counts round up
        assert_eq!(BitWidth::Int4.weight_bytes(9), 5);
        assert_eq!(BitWidth::Int8.weight_bytes(9), 9);
        assert_eq!(BitWidth::Int16.weight_bytes(9), 18);
        assert_eq!(BitWidth::Fp32.weight_bytes(9), 36);
        for w in ALL_WIDTHS {
            assert_eq!(BitWidth::parse(w.name()), Some(w));
            assert_eq!(BitWidth::parse(&w.bits().to_string()), Some(w));
        }
        assert_eq!(BitWidth::parse("int12"), None);
    }

    #[test]
    fn bits_spec_parses_and_rejects() {
        assert_eq!(
            parse_bits_spec("4,8,16").unwrap(),
            vec![BitWidth::Int4, BitWidth::Int8, BitWidth::Int16]
        );
        assert_eq!(
            parse_bits_spec("8,fp32").unwrap(),
            vec![BitWidth::Int8, BitWidth::Fp32]
        );
        assert!(parse_bits_spec("4,4").is_err(), "duplicates rejected");
        assert!(parse_bits_spec("fp32").is_err(), "needs an integer width");
        assert!(parse_bits_spec("4,7").is_err(), "unknown width rejected");
    }

    #[test]
    fn params_for_int8_matches_legacy_grid() {
        for scheme in ALL_SCHEMES {
            for (lo, hi) in [(-1.0f32, 3.0f32), (0.0, 6.0), (-2.5, 0.5)] {
                assert_eq!(
                    scheme.params_for(lo, hi, BitWidth::Int8),
                    scheme.params_from_range(lo, hi),
                    "{scheme} [{lo}, {hi}]"
                );
            }
        }
    }

    #[test]
    fn int4_grid_saturates_and_bounds_error() {
        let p = Scheme::Symmetric.params_for(-1.0, 1.0, BitWidth::Int4);
        assert_eq!((p.qmin, p.qmax), (-8.0, 7.0));
        assert!((p.scale - 1.0 / 7.0).abs() < 1e-7);
        // saturating grid: outliers clamp instead of wrapping
        assert_eq!(p.quantize(100.0), 7);
        assert_eq!(p.quantize(-100.0), -8);
        // inside the representable interval the error is half a step
        for i in -10..=10 {
            let x = i as f32 / 10.0;
            assert!((p.fake_quant(x) - x).abs() <= p.scale * 0.5 + 1e-6, "x={x}");
        }
    }

    #[test]
    fn wider_grids_are_monotonically_finer() {
        // for every scheme, the int16 step is below int8 is below int4
        for scheme in ALL_SCHEMES {
            let s4 = scheme.params_for(-3.0, 2.0, BitWidth::Int4).scale;
            let s8 = scheme.params_for(-3.0, 2.0, BitWidth::Int8).scale;
            let s16 = scheme.params_for(-3.0, 2.0, BitWidth::Int16).scale;
            assert!(s16 < s8 && s8 < s4, "{scheme}: {s16} {s8} {s4}");
        }
        // fp32 maps to the bypass row convention
        let id = Scheme::Symmetric.params_for(-3.0, 2.0, BitWidth::Fp32);
        assert_eq!(id, QParams::identity());
    }

    #[test]
    fn requantize_matches_float_composition() {
        // output grid differs from the accumulator scale: requantize must
        // agree with quantizing the dequantized real value, including
        // saturation and round-half-to-even at the midpoints
        let out = Scheme::Asymmetric.params_from_range(-1.0, 3.0);
        for acc in [-3000i32, -17, -1, 0, 1, 255, 4096, 100_000] {
            for acc_scale in [1e-4f32, 3.7e-3, 0.5] {
                let want = out.quantize(acc as f32 * acc_scale);
                assert_eq!(out.requantize(acc, acc_scale), want, "acc={acc}");
            }
        }
        // identity sanity: scale-1 accumulator onto a scale-1 grid
        let id = QParams::identity();
        assert_eq!(id.requantize(42, 1.0), 42);
        assert_eq!(id.requantize(1000, 1.0), 127, "saturates at qmax");
    }

    #[test]
    fn round_ties_even_convention() {
        // scale 1, zp 0: 0.5 rounds to 0, 1.5 rounds to 2
        let p = QParams { scale: 1.0, zero_point: 0, qmin: -128.0, qmax: 127.0 };
        assert_eq!(p.quantize(0.5), 0);
        assert_eq!(p.quantize(1.5), 2);
        assert_eq!(p.quantize(-0.5), 0);
    }

    #[test]
    fn fixed_requant_exact_on_dyadic_ratios() {
        // power-of-two ratios are exact in both f64 and the fixed-point
        // factoring, so the integer path must match the f64 reference
        // (round-half-even) on every accumulator
        let p = QParams { scale: 1.0, zero_point: 3, qmin: -128.0, qmax: 127.0 };
        for ratio_exp in [-8i32, -3, -1, 0, 1, 4] {
            let ratio = (ratio_exp as f32).exp2();
            let rq = p.fixed_requant(ratio);
            for acc in -1000i32..=1000 {
                let want = ((acc as f64 * ratio as f64).round_ties_even()
                    as i32
                    + p.zero_point)
                    .clamp(p.qmin as i32, p.qmax as i32);
                assert_eq!(rq.apply(acc), want, "ratio=2^{ratio_exp} acc={acc}");
            }
        }
    }

    #[test]
    fn fixed_requant_ties_go_to_even() {
        // ratio 0.5: acc=1 -> 0.5 -> 0 (even), acc=3 -> 1.5 -> 2
        let p = QParams { scale: 1.0, zero_point: 0, qmin: -128.0, qmax: 127.0 };
        let rq = p.fixed_requant(0.5);
        assert_eq!(rq.apply(1), 0);
        assert_eq!(rq.apply(3), 2);
        assert_eq!(rq.apply(-1), 0);
        assert_eq!(rq.apply(-3), -2);
    }

    #[test]
    fn fixed_requant_matches_pow2_requantize() {
        // pow2-scheme scales are powers of two, so with a dyadic
        // acc_scale every f32 step in QParams::requantize is exact and
        // the integer requantizer must agree bit-for-bit (on arbitrary
        // scales the f32 composition double-rounds, which is exactly why
        // FixedRequant exists)
        for range in [(-1.5f32, 2.5f32), (-0.1, 0.1), (-8.0, 64.0)] {
            let out = Scheme::Pow2.params_from_range(range.0, range.1);
            for mul in [0.125f32, 0.25, 1.0, 2.0] {
                let acc_scale = out.scale * mul;
                let rq = out.fixed_requant(acc_scale);
                for acc in [-100_000, -513, -3, -1, 0, 1, 2, 511, 65_535] {
                    assert_eq!(
                        rq.apply(acc),
                        out.requantize(acc, acc_scale),
                        "range={range:?} mul={mul} acc={acc}"
                    );
                }
            }
        }
    }

    #[test]
    fn fixed_requant_is_monotone_and_clamped() {
        let out = Scheme::Asymmetric.params_from_range(-1.0, 3.0);
        let rq = out.fixed_requant(1.7e-3);
        let mut prev = i32::MIN;
        for acc in (-200_000..=200_000).step_by(97) {
            let q = rq.apply(acc);
            assert!(q >= out.qmin as i32 && q <= out.qmax as i32);
            assert!(q >= prev, "monotone at acc={acc}");
            prev = q;
        }
        assert_eq!(rq.apply(i32::MAX), out.qmax as i32);
        assert_eq!(rq.apply(i32::MIN), out.qmin as i32);
    }

    #[test]
    fn fixed_requant_degenerate_ratio_returns_zero_point() {
        let p = QParams { scale: 1.0, zero_point: 5, qmin: -128.0, qmax: 127.0 };
        for bad in [0.0f32, -1.0, f32::NAN, f32::INFINITY] {
            let rq = p.fixed_requant(bad);
            assert_eq!(rq.apply(12345), 5, "acc_scale={bad}");
        }
        // tiny-but-normal ratios round every representable acc to zp too
        let rq = p.fixed_requant(1e-30);
        assert_eq!(rq.apply(i32::MAX), 5);
    }
}
