//! The four uniform int8 quantization schemes (paper §4.2, Eq. 2-13).
//!
//! A scheme maps an observed float range [min, max] to affine grid
//! parameters (scale, zero_point, qmin, qmax). The fake-quant evaluation
//! path and the HLO graphs consume these as plain numbers, so all four
//! schemes share one quantizer kernel.

use std::fmt;

/// Uniform quantization scheme.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Scheme {
    /// Affine: full int8 range, arbitrary zero point (Eq. 2-5).
    Asymmetric,
    /// Zero maps to zero; scale from the absolute maximum (Eq. 6-8).
    Symmetric,
    /// Glow's "symmetric with uint8" (Eq. 9-12): all-positive ranges use
    /// the uint8 grid (zero_point = -128); ranges with negatives fall
    /// back to symmetric.
    SymmetricUint8,
    /// Symmetric with the scale rounded to a power of two (Eq. 13);
    /// requantization becomes a bit-shift -- the only scheme an
    /// integer-only accelerator (VTA) can execute.
    Pow2,
}

pub const ALL_SCHEMES: [Scheme; 4] =
    [Scheme::Asymmetric, Scheme::Symmetric, Scheme::SymmetricUint8, Scheme::Pow2];

impl Scheme {
    pub fn name(self) -> &'static str {
        match self {
            Scheme::Asymmetric => "asymmetric",
            Scheme::Symmetric => "symmetric",
            Scheme::SymmetricUint8 => "symmetric_uint8",
            Scheme::Pow2 => "pow2",
        }
    }

    pub fn parse(s: &str) -> Option<Scheme> {
        ALL_SCHEMES.iter().copied().find(|x| x.name() == s)
    }

    /// Can the whole inference run with integer multiply/add/shift only?
    pub fn integer_only(self) -> bool {
        matches!(self, Scheme::Pow2)
    }

    /// Grid parameters for an observed range (paper Eq. 3/4, 7, 10/11, 13).
    pub fn params_from_range(self, min: f32, max: f32) -> QParams {
        // guard degenerate ranges; include zero like every practical
        // quantizer so that zero is exactly representable
        let min = min.min(0.0);
        let max = max.max(0.0);
        let absmax = min.abs().max(max.abs()).max(1e-12);
        match self {
            Scheme::Asymmetric => {
                let scale = ((max - min) / 255.0).max(1e-12);
                let zero_point = (-(min / scale)).round_ties_even() as i32 - 128;
                QParams { scale, zero_point, qmin: -128.0, qmax: 127.0 }
            }
            Scheme::Symmetric => QParams {
                scale: absmax / 127.0,
                zero_point: 0,
                qmin: -128.0,
                qmax: 127.0,
            },
            Scheme::SymmetricUint8 => {
                if min >= 0.0 {
                    // uint8 grid stored in int8 with offset -128
                    QParams {
                        scale: (max / 255.0).max(1e-12),
                        zero_point: -128,
                        qmin: -128.0,
                        qmax: 127.0,
                    }
                } else {
                    QParams {
                        scale: absmax / 127.0,
                        zero_point: 0,
                        qmin: -128.0,
                        qmax: 127.0,
                    }
                }
            }
            Scheme::Pow2 => {
                let exp = (absmax / 127.0).log2().round().clamp(-31.0, 31.0);
                QParams {
                    scale: exp.exp2(),
                    zero_point: 0,
                    qmin: -128.0,
                    qmax: 127.0,
                }
            }
        }
    }
}

impl fmt::Display for Scheme {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Affine int8 grid parameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QParams {
    pub scale: f32,
    pub zero_point: i32,
    pub qmin: f32,
    pub qmax: f32,
}

impl QParams {
    /// Identity parameters (used for bypassed fp32 tensors).
    pub fn identity() -> QParams {
        QParams { scale: 1.0, zero_point: 0, qmin: -128.0, qmax: 127.0 }
    }

    /// Quantize one value to the int grid (round-half-to-even, matching
    /// XLA RoundNearestEven and jnp.round).
    pub fn quantize(&self, x: f32) -> i32 {
        let q = (x / self.scale + self.zero_point as f32).round_ties_even();
        q.clamp(self.qmin, self.qmax) as i32
    }

    /// Dequantize an int grid value.
    pub fn dequantize(&self, q: i32) -> f32 {
        (q - self.zero_point) as f32 * self.scale
    }

    /// Quantize-dequantize (the fake-quant the HLO graphs apply).
    pub fn fake_quant(&self, x: f32) -> f32 {
        self.dequantize(self.quantize(x))
    }

    /// Worst-case absolute rounding error inside the clipped range.
    pub fn step(&self) -> f32 {
        self.scale * 0.5
    }

    /// The representable float interval.
    pub fn float_range(&self) -> (f32, f32) {
        (
            self.dequantize(self.qmin as i32),
            self.dequantize(self.qmax as i32),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn asymmetric_uses_full_range() {
        let p = Scheme::Asymmetric.params_from_range(-1.0, 3.0);
        let (lo, hi) = p.float_range();
        assert!((lo - -1.0).abs() < p.scale, "lo {lo}");
        assert!((hi - 3.0).abs() < p.scale, "hi {hi}");
        // zero is representable exactly
        assert!(p.fake_quant(0.0).abs() <= p.scale * 0.5 + 1e-9);
    }

    #[test]
    fn symmetric_zero_maps_to_zero() {
        let p = Scheme::Symmetric.params_from_range(-2.0, 1.0);
        assert_eq!(p.zero_point, 0);
        assert_eq!(p.fake_quant(0.0), 0.0);
        assert!((p.scale - 2.0 / 127.0).abs() < 1e-9);
    }

    #[test]
    fn symmetric_uint8_switches_on_sign() {
        let pos = Scheme::SymmetricUint8.params_from_range(0.0, 6.0);
        assert_eq!(pos.zero_point, -128);
        assert!((pos.scale - 6.0 / 255.0).abs() < 1e-9);
        let neg = Scheme::SymmetricUint8.params_from_range(-1.0, 6.0);
        assert_eq!(neg.zero_point, 0);
        assert!((neg.scale - 6.0 / 127.0).abs() < 1e-9);
    }

    #[test]
    fn pow2_scale_is_power_of_two() {
        let p = Scheme::Pow2.params_from_range(-3.0, 3.0);
        let exp = p.scale.log2();
        assert_eq!(exp, exp.round());
        assert_eq!(p.zero_point, 0);
    }

    #[test]
    fn quantize_clamps() {
        let p = Scheme::Symmetric.params_from_range(-1.0, 1.0);
        assert_eq!(p.quantize(100.0), 127);
        assert_eq!(p.quantize(-100.0), -128);
    }

    #[test]
    fn fake_quant_error_bounded() {
        for scheme in ALL_SCHEMES {
            let p = scheme.params_from_range(-4.0, 4.0);
            let (lo, hi) = p.float_range();
            for i in -40..=40 {
                let x = i as f32 / 10.0;
                let err = (p.fake_quant(x) - x).abs();
                // inside the representable interval: rounding error only;
                // at the edges (pow2 rounds the scale down) saturation can
                // add up to one extra step
                let bound = if x >= lo && x <= hi {
                    p.scale * 0.5
                } else {
                    p.scale
                };
                assert!(
                    err <= bound + 1e-6,
                    "{scheme}: x={x} err={err} scale={}",
                    p.scale
                );
            }
        }
    }

    #[test]
    fn degenerate_range_is_safe() {
        for scheme in ALL_SCHEMES {
            let p = scheme.params_from_range(0.0, 0.0);
            assert!(p.scale > 0.0);
            let y = p.fake_quant(0.0);
            assert!(y.is_finite());
        }
    }

    #[test]
    fn round_ties_even_convention() {
        // scale 1, zp 0: 0.5 rounds to 0, 1.5 rounds to 2
        let p = QParams { scale: 1.0, zero_point: 0, qmin: -128.0, qmax: 127.0 };
        assert_eq!(p.quantize(0.5), 0);
        assert_eq!(p.quantize(1.5), 2);
        assert_eq!(p.quantize(-0.5), 0);
    }
}
