//! Quantization configuration spaces (paper Eq. 1 and Eq. 23).
//!
//! `QuantConfig` is one point of the 96-element general-purpose space:
//!
//! ```text
//! SearchSpace(96) = CalibrationCache(3) x Scheme(4) x Clipping(2)
//!                   x Granularity(2) x MixedPrecision(2)
//! ```
//!
//! `VtaConfig` is one point of the 12-element integer-only space (Eq. 23):
//! scheme is pinned to pow2, granularity to tensor, and the free choice
//! becomes conv+ReLU fusion.

use std::fmt;

use anyhow::{bail, Result};

use super::scheme::{Scheme, ALL_SCHEMES};

/// Number of calibration images. Paper: {1, 1000, 10000} of ImageNet
/// train; here {1, 64, 512} of the synthetic calibration pool (DESIGN.md
/// §2 explains the scaling).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CalibCount {
    /// One calibration image (paper: 1).
    C1,
    /// 64 calibration images (paper: 1 000).
    C64,
    /// 512 calibration images (paper: 10 000).
    C512,
}

/// Every calibration count, in index order.
pub const ALL_CALIB: [CalibCount; 3] = [CalibCount::C1, CalibCount::C64, CalibCount::C512];

impl CalibCount {
    /// Number of calibration images at our scale.
    pub fn images(self) -> usize {
        match self {
            CalibCount::C1 => 1,
            CalibCount::C64 => 64,
            CalibCount::C512 => 512,
        }
    }

    /// The count the paper reports for the equivalent cache.
    pub fn paper_images(self) -> usize {
        match self {
            CalibCount::C1 => 1,
            CalibCount::C64 => 1_000,
            CalibCount::C512 => 10_000,
        }
    }

    /// Ordinal position (0..3).
    pub fn index(self) -> usize {
        match self {
            CalibCount::C1 => 0,
            CalibCount::C64 => 1,
            CalibCount::C512 => 2,
        }
    }
}

/// Range clipping policy (paper §4.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Clipping {
    /// Use the raw observed min/max.
    Max,
    /// KL-divergence threshold search (TensorRT/Glow procedure).
    Kl,
}

/// Both clipping policies, in index order.
pub const ALL_CLIP: [Clipping; 2] = [Clipping::Max, Clipping::Kl];

/// Scale sharing granularity for *weights* (paper §4.4; activations are
/// always per-tensor, as in Glow).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Granularity {
    /// One scale per weight tensor.
    Tensor,
    /// One scale per output channel.
    Channel,
}

/// Both granularities, in index order.
pub const ALL_GRAN: [Granularity; 2] = [Granularity::Tensor, Granularity::Channel];

/// One point of the 96-element search space.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct QuantConfig {
    /// Calibration image count.
    pub calib: CalibCount,
    /// Quantization scheme.
    pub scheme: Scheme,
    /// Range clipping policy.
    pub clip: Clipping,
    /// Weight-scale granularity.
    pub gran: Granularity,
    /// keep first and last weighted layers in fp32 (paper §4.5)
    pub mixed: bool,
}

impl QuantConfig {
    /// The full space, in a fixed deterministic order (index 0..96).
    pub fn space() -> Vec<QuantConfig> {
        let mut out = Vec::with_capacity(96);
        for calib in ALL_CALIB {
            for scheme in ALL_SCHEMES {
                for clip in ALL_CLIP {
                    for gran in ALL_GRAN {
                        for mixed in [false, true] {
                            out.push(QuantConfig { calib, scheme, clip, gran, mixed });
                        }
                    }
                }
            }
        }
        out
    }

    /// Number of configurations in the general space.
    pub const SPACE_SIZE: usize = 96;

    /// Position in `space()` order.
    pub fn index(&self) -> usize {
        let s = ALL_SCHEMES.iter().position(|x| x == &self.scheme).unwrap();
        (((self.calib.index() * 4 + s) * 2 + (self.clip == Clipping::Kl) as usize) * 2
            + (self.gran == Granularity::Channel) as usize)
            * 2
            + self.mixed as usize
    }

    /// Config at position `i` of `space()` order.
    pub fn from_index(i: usize) -> Result<QuantConfig> {
        if i >= Self::SPACE_SIZE {
            bail!("config index {i} out of range");
        }
        Ok(Self::space()[i])
    }

    /// Binary-ish genome for the genetic algorithm: 7 bits
    /// (2 calib, 2 scheme, 1 clip, 1 gran, 1 mixed). Calib/scheme use
    /// 2-bit fields where value 3 wraps (the GA package's binary
    /// encoding does the same for non-power-of-two cardinalities).
    pub fn from_genome(bits: &[bool; 7]) -> QuantConfig {
        let calib = ALL_CALIB[((bits[0] as usize) * 2 + bits[1] as usize) % 3];
        let scheme = ALL_SCHEMES[(bits[2] as usize) * 2 + bits[3] as usize];
        QuantConfig {
            calib,
            scheme,
            clip: if bits[4] { Clipping::Kl } else { Clipping::Max },
            gran: if bits[5] { Granularity::Channel } else { Granularity::Tensor },
            mixed: bits[6],
        }
    }

    /// The canonical 7-bit genome of this config (see `from_genome`).
    pub fn to_genome(&self) -> [bool; 7] {
        let c = self.calib.index();
        let s = ALL_SCHEMES.iter().position(|x| x == &self.scheme).unwrap();
        [
            c / 2 == 1,
            c % 2 == 1,
            s / 2 == 1,
            s % 2 == 1,
            self.clip == Clipping::Kl,
            self.gran == Granularity::Channel,
            self.mixed,
        ]
    }

    /// One-hot feature encoding for the XGBoost cost model (13 features:
    /// 3 calib + 4 scheme + 2 clip + 2 gran + 2 mixed). One-hot (not
    /// ordinal) matches the paper's preprocessing choice (§5.2.2).
    pub fn one_hot(&self) -> Vec<f32> {
        let mut v = vec![0.0f32; 13];
        v[self.calib.index()] = 1.0;
        v[3 + ALL_SCHEMES.iter().position(|x| x == &self.scheme).unwrap()] = 1.0;
        v[7 + (self.clip == Clipping::Kl) as usize] = 1.0;
        v[9 + (self.gran == Granularity::Channel) as usize] = 1.0;
        v[11 + self.mixed as usize] = 1.0;
        v
    }

    /// Width of the one-hot feature encoding.
    pub const ONE_HOT_DIM: usize = 13;

    /// Categorical (ordinal) feature encoding: one integer-valued feature
    /// per axis. The paper (§5.2.2) compared this against one-hot and
    /// found one-hot better; `bench_ablation` reproduces that comparison.
    pub fn categorical(&self) -> Vec<f32> {
        vec![
            self.calib.index() as f32,
            ALL_SCHEMES.iter().position(|x| x == &self.scheme).unwrap() as f32,
            (self.clip == Clipping::Kl) as u8 as f32,
            (self.gran == Granularity::Channel) as u8 as f32,
            self.mixed as u8 as f32,
        ]
    }

    /// Width of the categorical feature encoding.
    pub const CATEGORICAL_DIM: usize = 5;
    /// Names of the one-hot feature dimensions, in order.
    pub const FEATURE_NAMES: [&'static str; 13] = [
        "calib_1", "calib_64", "calib_512",
        "scheme_asym", "scheme_sym", "scheme_sym_u8", "scheme_pow2",
        "clip_max", "clip_kl",
        "gran_tensor", "gran_channel",
        "mixed_off", "mixed_on",
    ];

    /// Compact human-readable label ("c512_symmetric_kl_channel_int8").
    pub fn slug(&self) -> String {
        format!(
            "c{}_{}_{}_{}_{}",
            self.calib.images(),
            self.scheme.name(),
            match self.clip {
                Clipping::Max => "max",
                Clipping::Kl => "kl",
            },
            match self.gran {
                Granularity::Tensor => "tensor",
                Granularity::Channel => "channel",
            },
            if self.mixed { "mixed" } else { "int8" },
        )
    }
}

impl fmt::Display for QuantConfig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.slug())
    }
}

/// One point of the VTA integer-only space (Eq. 23, |space| = 12).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct VtaConfig {
    /// Calibration image count.
    pub calib: CalibCount,
    /// Range clipping policy.
    pub clip: Clipping,
    /// execute conv+ReLU as one fused accelerator op
    pub fusion: bool,
}

impl VtaConfig {
    /// The full space, in a fixed deterministic order (index 0..12).
    pub fn space() -> Vec<VtaConfig> {
        let mut out = Vec::with_capacity(12);
        for calib in ALL_CALIB {
            for clip in ALL_CLIP {
                for fusion in [false, true] {
                    out.push(VtaConfig { calib, clip, fusion });
                }
            }
        }
        out
    }

    /// Number of configurations in the VTA space.
    pub const SPACE_SIZE: usize = 12;

    /// Position in `space()` order.
    pub fn index(&self) -> usize {
        (self.calib.index() * 2 + (self.clip == Clipping::Kl) as usize) * 2
            + self.fusion as usize
    }

    /// Config at position `i` of `space()` order.
    pub fn from_index(i: usize) -> Result<VtaConfig> {
        if i >= Self::SPACE_SIZE {
            bail!("vta config index {i} out of range");
        }
        Ok(Self::space()[i])
    }

    /// The equivalent general config (pow2 / tensor / no mixed).
    pub fn as_quant_config(&self) -> QuantConfig {
        QuantConfig {
            calib: self.calib,
            scheme: Scheme::Pow2,
            clip: self.clip,
            gran: Granularity::Tensor,
            mixed: false,
        }
    }

    /// Compact human-readable label ("vta_c512_kl_fused").
    pub fn slug(&self) -> String {
        format!(
            "vta_c{}_{}_{}",
            self.calib.images(),
            match self.clip {
                Clipping::Max => "max",
                Clipping::Kl => "kl",
            },
            if self.fusion { "fused" } else { "unfused" },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn space_is_96_distinct() {
        let space = QuantConfig::space();
        assert_eq!(space.len(), 96);
        let set: std::collections::HashSet<_> = space.iter().collect();
        assert_eq!(set.len(), 96);
    }

    #[test]
    fn index_roundtrip() {
        for (i, cfg) in QuantConfig::space().iter().enumerate() {
            assert_eq!(cfg.index(), i);
            assert_eq!(&QuantConfig::from_index(i).unwrap(), cfg);
        }
    }

    #[test]
    fn genome_roundtrip() {
        for cfg in QuantConfig::space() {
            let g = cfg.to_genome();
            assert_eq!(QuantConfig::from_genome(&g), cfg);
        }
    }

    #[test]
    fn one_hot_shape() {
        for cfg in QuantConfig::space() {
            let v = cfg.one_hot();
            assert_eq!(v.len(), QuantConfig::ONE_HOT_DIM);
            assert_eq!(v.iter().filter(|&&x| x == 1.0).count(), 5);
        }
    }

    #[test]
    fn vta_space_is_12() {
        let space = VtaConfig::space();
        assert_eq!(space.len(), 12);
        for (i, cfg) in space.iter().enumerate() {
            assert_eq!(cfg.index(), i);
            assert!(cfg.as_quant_config().scheme.integer_only());
        }
    }
}
