//! Quantization configuration spaces (paper Eq. 1 and Eq. 23).
//!
//! `QuantConfig` is one point of the 288-element general-purpose space:
//!
//! ```text
//! SearchSpace(288) = CalibrationCache(3) x Scheme(4) x Clipping(3)
//!                    x Granularity(2) x MixedPrecision(2) x BiasCorrect(2)
//! ```
//!
//! The space grew from the paper's 96 configs (clipping was {max, kl},
//! no bias correction) when the analytical PTQ toolbox landed: ACIQ
//! clipping ([`Clipping::Aciq`]) and per-channel bias correction
//! ([`QuantConfig::bias_correct`], Banner et al., arXiv:1810.05723) are
//! extra axes the tuner searches alongside the original four. Index
//! order is backward compatible: indices `0..96` decode to exactly the
//! configs they always did (the legacy {max, kl} x no-bias-correct
//! block, in the legacy nested order), so persisted trial records keep
//! their meaning; the new (clipping, bias-correct) combinations occupy
//! indices `96..288` in four blocks of 48.
//!
//! `VtaConfig` is one point of the 12-element integer-only space (Eq. 23):
//! scheme is pinned to pow2, granularity to tensor, and the free choice
//! becomes conv+ReLU fusion. The VTA space predates the toolbox axes and
//! stays at 12 configs ({max, kl} only -- the accelerator path has no
//! bias-correct or ACIQ wiring).

use std::fmt;

use anyhow::{bail, Result};

use super::scheme::{Scheme, ALL_SCHEMES};

/// Number of calibration images. Paper: {1, 1000, 10000} of ImageNet
/// train; here {1, 64, 512} of the synthetic calibration pool (DESIGN.md
/// §2 explains the scaling).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CalibCount {
    /// One calibration image (paper: 1).
    C1,
    /// 64 calibration images (paper: 1 000).
    C64,
    /// 512 calibration images (paper: 10 000).
    C512,
}

/// Every calibration count, in index order.
pub const ALL_CALIB: [CalibCount; 3] = [CalibCount::C1, CalibCount::C64, CalibCount::C512];

impl CalibCount {
    /// Number of calibration images at our scale.
    pub fn images(self) -> usize {
        match self {
            CalibCount::C1 => 1,
            CalibCount::C64 => 64,
            CalibCount::C512 => 512,
        }
    }

    /// The count the paper reports for the equivalent cache.
    pub fn paper_images(self) -> usize {
        match self {
            CalibCount::C1 => 1,
            CalibCount::C64 => 1_000,
            CalibCount::C512 => 10_000,
        }
    }

    /// Ordinal position (0..3).
    pub fn index(self) -> usize {
        match self {
            CalibCount::C1 => 0,
            CalibCount::C64 => 1,
            CalibCount::C512 => 2,
        }
    }
}

/// Range clipping policy (paper §4.3; ACIQ from Banner et al.).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Clipping {
    /// Use the raw observed min/max.
    Max,
    /// KL-divergence threshold search (TensorRT/Glow procedure).
    Kl,
    /// ACIQ analytical clipping: the closed-form threshold minimizing
    /// expected clipping + rounding MSE under a Laplace/Gaussian fit of
    /// the calibration histogram's moments -- no threshold sweep (see
    /// [`crate::quant::Histogram::aciq_threshold`]).
    Aciq,
}

/// Every clipping policy, in index order.
pub const ALL_CLIP: [Clipping; 3] = [Clipping::Max, Clipping::Kl, Clipping::Aciq];

/// The legacy clipping pair of the paper's original 96-config space
/// (and of the VTA space, which never grew the ACIQ arm).
pub const LEGACY_CLIP: [Clipping; 2] = [Clipping::Max, Clipping::Kl];

impl Clipping {
    /// Canonical name (`max` / `kl` / `aciq`).
    pub fn name(self) -> &'static str {
        match self {
            Clipping::Max => "max",
            Clipping::Kl => "kl",
            Clipping::Aciq => "aciq",
        }
    }

    /// Parse a canonical clipping name.
    pub fn parse(s: &str) -> Option<Clipping> {
        ALL_CLIP.iter().copied().find(|c| c.name() == s)
    }

    /// Ordinal position (0..3).
    pub fn index(self) -> usize {
        match self {
            Clipping::Max => 0,
            Clipping::Kl => 1,
            Clipping::Aciq => 2,
        }
    }
}

impl fmt::Display for Clipping {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Scale sharing granularity for *weights* (paper §4.4; activations are
/// always per-tensor, as in Glow).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Granularity {
    /// One scale per weight tensor.
    Tensor,
    /// One scale per output channel.
    Channel,
}

/// Both granularities, in index order.
pub const ALL_GRAN: [Granularity; 2] = [Granularity::Tensor, Granularity::Channel];

/// One point of the 288-element search space.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct QuantConfig {
    /// Calibration image count.
    pub calib: CalibCount,
    /// Quantization scheme.
    pub scheme: Scheme,
    /// Range clipping policy.
    pub clip: Clipping,
    /// Weight-scale granularity.
    pub gran: Granularity,
    /// keep first and last weighted layers in fp32 (paper §4.5)
    pub mixed: bool,
    /// fold the per-output-channel weight quantization-error mean into
    /// the layer bias at prepare time (Banner et al.'s bias correction)
    pub bias_correct: bool,
}

/// The extension blocks above the legacy prefix, in index order: each is
/// a (clipping, bias_correct) pair the legacy 96 never covered, worth 48
/// configs (calib x scheme x gran x mixed).
const EXT_BLOCKS: [(Clipping, bool); 4] = [
    (Clipping::Aciq, false),
    (Clipping::Max, true),
    (Clipping::Kl, true),
    (Clipping::Aciq, true),
];

impl QuantConfig {
    /// The full space, in a fixed deterministic order (index 0..288):
    /// the legacy 96-config block first (identical to the pre-toolbox
    /// ordering), then the four extension blocks of [`EXT_BLOCKS`].
    pub fn space() -> Vec<QuantConfig> {
        let mut out = Vec::with_capacity(Self::SPACE_SIZE);
        for calib in ALL_CALIB {
            for scheme in ALL_SCHEMES {
                for clip in LEGACY_CLIP {
                    for gran in ALL_GRAN {
                        for mixed in [false, true] {
                            out.push(QuantConfig {
                                calib,
                                scheme,
                                clip,
                                gran,
                                mixed,
                                bias_correct: false,
                            });
                        }
                    }
                }
            }
        }
        for (clip, bias_correct) in EXT_BLOCKS {
            for calib in ALL_CALIB {
                for scheme in ALL_SCHEMES {
                    for gran in ALL_GRAN {
                        for mixed in [false, true] {
                            out.push(QuantConfig {
                                calib,
                                scheme,
                                clip,
                                gran,
                                mixed,
                                bias_correct,
                            });
                        }
                    }
                }
            }
        }
        out
    }

    /// Number of configurations in the general space.
    pub const SPACE_SIZE: usize = 288;

    /// Size of the legacy prefix: indices below this decode to exactly
    /// the configs of the paper's original 96-element space.
    pub const LEGACY_SPACE_SIZE: usize = 96;

    /// Position in `space()` order.
    pub fn index(&self) -> usize {
        let s = ALL_SCHEMES.iter().position(|x| x == &self.scheme).unwrap();
        let gran = (self.gran == Granularity::Channel) as usize;
        if !self.bias_correct && self.clip != Clipping::Aciq {
            // legacy prefix: the pre-toolbox nested order, untouched
            let kl = (self.clip == Clipping::Kl) as usize;
            return (((self.calib.index() * 4 + s) * 2 + kl) * 2 + gran) * 2
                + self.mixed as usize;
        }
        let block = EXT_BLOCKS
            .iter()
            .position(|&(c, b)| c == self.clip && b == self.bias_correct)
            .unwrap();
        Self::LEGACY_SPACE_SIZE
            + block * 48
            + (((self.calib.index() * 4 + s) * 2 + gran) * 2 + self.mixed as usize)
    }

    /// Config at position `i` of `space()` order.
    pub fn from_index(i: usize) -> Result<QuantConfig> {
        if i >= Self::SPACE_SIZE {
            bail!("config index {i} out of range");
        }
        Ok(Self::space()[i])
    }

    /// Binary-ish genome for the genetic algorithm: 9 bits
    /// (2 calib, 2 scheme, 2 clip, 1 gran, 1 mixed, 1 bias_correct).
    /// Calib/scheme/clip use 2-bit fields where out-of-range values wrap
    /// (the GA package's binary encoding does the same for
    /// non-power-of-two cardinalities).
    pub fn from_genome(bits: &[bool; 9]) -> QuantConfig {
        let calib = ALL_CALIB[((bits[0] as usize) * 2 + bits[1] as usize) % 3];
        let scheme = ALL_SCHEMES[(bits[2] as usize) * 2 + bits[3] as usize];
        let clip = ALL_CLIP[((bits[4] as usize) * 2 + bits[5] as usize) % 3];
        QuantConfig {
            calib,
            scheme,
            clip,
            gran: if bits[6] { Granularity::Channel } else { Granularity::Tensor },
            mixed: bits[7],
            bias_correct: bits[8],
        }
    }

    /// The canonical 9-bit genome of this config (see `from_genome`).
    pub fn to_genome(&self) -> [bool; 9] {
        let c = self.calib.index();
        let s = ALL_SCHEMES.iter().position(|x| x == &self.scheme).unwrap();
        let k = self.clip.index();
        [
            c / 2 == 1,
            c % 2 == 1,
            s / 2 == 1,
            s % 2 == 1,
            k / 2 == 1,
            k % 2 == 1,
            self.gran == Granularity::Channel,
            self.mixed,
            self.bias_correct,
        ]
    }

    /// One-hot feature encoding for the XGBoost cost model (16 features:
    /// 3 calib + 4 scheme + 3 clip + 2 gran + 2 mixed + 2 bias_correct).
    /// One-hot (not ordinal) matches the paper's preprocessing choice
    /// (§5.2.2).
    pub fn one_hot(&self) -> Vec<f32> {
        let mut v = vec![0.0f32; Self::ONE_HOT_DIM];
        v[self.calib.index()] = 1.0;
        v[3 + ALL_SCHEMES.iter().position(|x| x == &self.scheme).unwrap()] = 1.0;
        v[7 + self.clip.index()] = 1.0;
        v[10 + (self.gran == Granularity::Channel) as usize] = 1.0;
        v[12 + self.mixed as usize] = 1.0;
        v[14 + self.bias_correct as usize] = 1.0;
        v
    }

    /// Width of the one-hot feature encoding.
    pub const ONE_HOT_DIM: usize = 16;

    /// Categorical (ordinal) feature encoding: one integer-valued feature
    /// per axis. The paper (§5.2.2) compared this against one-hot and
    /// found one-hot better; `bench_ablation` reproduces that comparison.
    pub fn categorical(&self) -> Vec<f32> {
        vec![
            self.calib.index() as f32,
            ALL_SCHEMES.iter().position(|x| x == &self.scheme).unwrap() as f32,
            self.clip.index() as f32,
            (self.gran == Granularity::Channel) as u8 as f32,
            self.mixed as u8 as f32,
            self.bias_correct as u8 as f32,
        ]
    }

    /// Width of the categorical feature encoding.
    pub const CATEGORICAL_DIM: usize = 6;
    /// Names of the one-hot feature dimensions, in order.
    pub const FEATURE_NAMES: [&'static str; 16] = [
        "calib_1", "calib_64", "calib_512",
        "scheme_asym", "scheme_sym", "scheme_sym_u8", "scheme_pow2",
        "clip_max", "clip_kl", "clip_aciq",
        "gran_tensor", "gran_channel",
        "mixed_off", "mixed_on",
        "bias_corr_off", "bias_corr_on",
    ];

    /// Compact human-readable label ("c512_symmetric_kl_channel_int8";
    /// bias-corrected configs append "_bc", so legacy slugs are
    /// unchanged).
    pub fn slug(&self) -> String {
        format!(
            "c{}_{}_{}_{}_{}{}",
            self.calib.images(),
            self.scheme.name(),
            self.clip.name(),
            match self.gran {
                Granularity::Tensor => "tensor",
                Granularity::Channel => "channel",
            },
            if self.mixed { "mixed" } else { "int8" },
            if self.bias_correct { "_bc" } else { "" },
        )
    }
}

impl fmt::Display for QuantConfig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.slug())
    }
}

/// One point of the VTA integer-only space (Eq. 23, |space| = 12).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct VtaConfig {
    /// Calibration image count.
    pub calib: CalibCount,
    /// Range clipping policy (the enumerated space uses {max, kl} only).
    pub clip: Clipping,
    /// execute conv+ReLU as one fused accelerator op
    pub fusion: bool,
}

impl VtaConfig {
    /// The full space, in a fixed deterministic order (index 0..12).
    pub fn space() -> Vec<VtaConfig> {
        let mut out = Vec::with_capacity(12);
        for calib in ALL_CALIB {
            for clip in LEGACY_CLIP {
                for fusion in [false, true] {
                    out.push(VtaConfig { calib, clip, fusion });
                }
            }
        }
        out
    }

    /// Number of configurations in the VTA space.
    pub const SPACE_SIZE: usize = 12;

    /// Position in `space()` order.
    pub fn index(&self) -> usize {
        (self.calib.index() * 2 + (self.clip == Clipping::Kl) as usize) * 2
            + self.fusion as usize
    }

    /// Config at position `i` of `space()` order.
    pub fn from_index(i: usize) -> Result<VtaConfig> {
        if i >= Self::SPACE_SIZE {
            bail!("vta config index {i} out of range");
        }
        Ok(Self::space()[i])
    }

    /// The equivalent general config (pow2 / tensor / no mixed).
    pub fn as_quant_config(&self) -> QuantConfig {
        QuantConfig {
            calib: self.calib,
            scheme: Scheme::Pow2,
            clip: self.clip,
            gran: Granularity::Tensor,
            mixed: false,
            bias_correct: false,
        }
    }

    /// Compact human-readable label ("vta_c512_kl_fused").
    pub fn slug(&self) -> String {
        format!(
            "vta_c{}_{}_{}",
            self.calib.images(),
            self.clip.name(),
            if self.fusion { "fused" } else { "unfused" },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn space_is_288_distinct() {
        let space = QuantConfig::space();
        assert_eq!(space.len(), QuantConfig::SPACE_SIZE);
        let set: std::collections::HashSet<_> = space.iter().collect();
        assert_eq!(set.len(), QuantConfig::SPACE_SIZE);
    }

    #[test]
    fn index_roundtrip() {
        for (i, cfg) in QuantConfig::space().iter().enumerate() {
            assert_eq!(cfg.index(), i);
            assert_eq!(&QuantConfig::from_index(i).unwrap(), cfg);
        }
    }

    #[test]
    fn legacy_prefix_order_is_preserved() {
        // the pre-toolbox space enumerated calib -> scheme -> {max, kl}
        // -> gran -> mixed with no bias correction; persisted trial
        // records index into exactly that order, so the first 96 entries
        // may never change
        let mut legacy = Vec::with_capacity(QuantConfig::LEGACY_SPACE_SIZE);
        for calib in ALL_CALIB {
            for scheme in ALL_SCHEMES {
                for clip in [Clipping::Max, Clipping::Kl] {
                    for gran in ALL_GRAN {
                        for mixed in [false, true] {
                            legacy.push(QuantConfig {
                                calib,
                                scheme,
                                clip,
                                gran,
                                mixed,
                                bias_correct: false,
                            });
                        }
                    }
                }
            }
        }
        let space = QuantConfig::space();
        assert_eq!(&space[..QuantConfig::LEGACY_SPACE_SIZE], &legacy[..]);
        // and every new-axis config lives strictly above the prefix
        for cfg in &space[QuantConfig::LEGACY_SPACE_SIZE..] {
            assert!(cfg.bias_correct || cfg.clip == Clipping::Aciq);
        }
    }

    #[test]
    fn genome_roundtrip() {
        for cfg in QuantConfig::space() {
            let g = cfg.to_genome();
            assert_eq!(QuantConfig::from_genome(&g), cfg);
        }
    }

    #[test]
    fn one_hot_shape() {
        for cfg in QuantConfig::space() {
            let v = cfg.one_hot();
            assert_eq!(v.len(), QuantConfig::ONE_HOT_DIM);
            assert_eq!(v.iter().filter(|&&x| x == 1.0).count(), 6);
        }
    }

    #[test]
    fn categorical_shape() {
        for cfg in QuantConfig::space() {
            assert_eq!(cfg.categorical().len(), QuantConfig::CATEGORICAL_DIM);
        }
    }

    #[test]
    fn slug_distinguishes_new_axes() {
        let base = QuantConfig::from_index(0).unwrap();
        assert!(!base.slug().ends_with("_bc"));
        let bc = QuantConfig { bias_correct: true, ..base };
        assert!(bc.slug().ends_with("_bc"));
        let aciq = QuantConfig { clip: Clipping::Aciq, ..base };
        assert!(aciq.slug().contains("_aciq_"));
        // slugs stay unique over the whole space
        let slugs: std::collections::HashSet<String> =
            QuantConfig::space().iter().map(|c| c.slug()).collect();
        assert_eq!(slugs.len(), QuantConfig::SPACE_SIZE);
    }

    #[test]
    fn clipping_names_roundtrip() {
        for clip in ALL_CLIP {
            assert_eq!(Clipping::parse(clip.name()), Some(clip));
        }
        assert_eq!(Clipping::parse("minmax"), None);
    }

    #[test]
    fn vta_space_is_12() {
        let space = VtaConfig::space();
        assert_eq!(space.len(), 12);
        for (i, cfg) in space.iter().enumerate() {
            assert_eq!(cfg.index(), i);
            assert!(cfg.as_quant_config().scheme.integer_only());
            assert!(!cfg.as_quant_config().bias_correct);
        }
    }
}
