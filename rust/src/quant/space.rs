//! Search-space abstraction: the `ConfigSpace` trait and its three
//! implementations.
//!
//! The paper's claim is that the XGB cost model accelerates search over
//! *whatever* quantization space the compiler exposes (Eq. 1 is just one
//! instance). This module makes that literal: a space is anything that
//! can enumerate its points, decode a point into a concrete
//! [`QuantPlan`] for the evaluators, featurize points for the cost
//! model, and encode/decode a binary genome for the GA.
//!
//! - [`GeneralSpace`]: the 288-element space of Eq. 1 extended with the
//!   analytical-PTQ axes ([`QuantConfig`]);
//! - [`VtaSpace`]: the 12-element integer-only space of Eq. 23
//!   ([`VtaConfig`]);
//! - [`LayerwiseSpace`]: per-layer mixed precision (paper §4.5,
//!   generalized): starting from a fixed base config, each of the top-K
//!   most quantization-fragile weighted layers independently chooses a
//!   [`BitWidth`] from a configurable menu (int4 / int8 / int16 / fp32),
//!   making the genome a mixed-radix number rather than a bitmask. K is
//!   capped so the R^K space stays enumerable, and the fragility ranking
//!   is calibration-driven (weight fake-quant MSE plus activation
//!   quantization noise from the calibration histograms).

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::ir::{Graph, Op, Tensor};

use super::config::{QuantConfig, VtaConfig, ALL_CALIB};
use super::histogram::Histogram;
use super::scheme::BitWidth;
use super::weights::weight_mse;
use super::Clipping;

/// Everything an evaluator needs to realize one configuration: the base
/// axes (calibration count, scheme, clipping, granularity) plus the
/// per-layer weight bit-widths.
#[derive(Clone, Debug)]
pub struct QuantPlan {
    /// The base configuration (calibration count, scheme, clipping,
    /// granularity, and the legacy `mixed` bit).
    pub base: QuantConfig,
    /// Explicit per-layer bit-widths over `graph.layers()` order.
    /// `None` derives the widths from `base.mixed` (int8 everywhere,
    /// fp32 first+last when mixed -- paper §4.5).
    pub layer_widths: Option<Vec<BitWidth>>,
}

impl QuantPlan {
    /// Plan with no per-layer overrides (widths derive from the config).
    pub fn from_config(base: QuantConfig) -> QuantPlan {
        QuantPlan { base, layer_widths: None }
    }

    /// Resolve the per-layer bit-widths for a model with `n_layers`
    /// weighted layers.
    pub fn resolve_widths(&self, n_layers: usize) -> Result<Vec<BitWidth>> {
        if let Some(w) = &self.layer_widths {
            anyhow::ensure!(
                w.len() == n_layers,
                "width vector covers {} layers but the model has {n_layers}",
                w.len()
            );
            return Ok(w.clone());
        }
        let mut widths = vec![BitWidth::Int8; n_layers];
        if self.base.mixed && n_layers > 0 {
            widths[0] = BitWidth::Fp32;
            widths[n_layers - 1] = BitWidth::Fp32;
        }
        Ok(widths)
    }

    /// Resolve the fp32-layer mask (`width == fp32` per layer) for a
    /// model with `n_layers` weighted layers. This is the projection the
    /// activation bypass rows and the legacy size accounting consume.
    pub fn resolve_mask(&self, n_layers: usize) -> Result<Vec<bool>> {
        Ok(self
            .resolve_widths(n_layers)?
            .into_iter()
            .map(BitWidth::is_float)
            .collect())
    }
}

impl From<QuantConfig> for QuantPlan {
    fn from(base: QuantConfig) -> QuantPlan {
        QuantPlan::from_config(base)
    }
}

/// A quantization search space: an indexed, featurized, genome-encoded
/// set of configurations the generic search/sweep/database plumbing
/// operates on.
pub trait ConfigSpace: Send + Sync {
    /// Stable identifier stored with database records so transfer
    /// learning never mixes feature vectors from incompatible spaces.
    fn tag(&self) -> String;

    /// Number of configurations (indices are `0..size()`).
    fn size(&self) -> usize;

    /// Decode an index into the concrete evaluation plan.
    fn plan(&self, i: usize) -> Result<QuantPlan>;

    /// Human-readable slug for an index.
    fn describe(&self, i: usize) -> Result<String>;

    /// Config-feature vector for the XGBoost cost model (the `s` half of
    /// the paper's §5.1 features; the model's arch features `e` are
    /// prepended by the coordinator).
    fn features(&self, i: usize) -> Result<Vec<f32>>;

    /// Names of the `features()` dimensions, for importance reports.
    fn feature_names(&self) -> Vec<String>;

    /// Genome length for the binary GA.
    fn genome_bits(&self) -> usize;

    /// Encode an index as a genome of `genome_bits()` bits.
    fn encode(&self, i: usize) -> Result<Vec<bool>>;

    /// Decode a genome to a valid index. Missing trailing bits read as
    /// 0 and out-of-range field values wrap (the GA package's binary
    /// encoding does the same for non-power-of-two cardinalities), so
    /// every genome decodes to some point of the space.
    ///
    /// # Examples
    ///
    /// Every space round-trips `encode`/`decode`; the layer-wise space
    /// does it over mixed-radix width digits:
    ///
    /// ```
    /// use quantune::coordinator::Quantune;
    /// use quantune::quant::{general_space, BitWidth, ConfigSpace};
    ///
    /// # fn main() -> anyhow::Result<()> {
    /// let g = general_space();
    /// assert_eq!(g.decode(&g.encode(42)?), 42);
    ///
    /// // a radix genome over zoo::synthetic_model: each of the 2 freed
    /// // layers picks one of {int4, int8, int16, fp32}
    /// let q = Quantune::synthetic();
    /// let model = Quantune::synthetic_model()?;
    /// let base = Quantune::tensorrt_like_baseline();
    /// let menu = [BitWidth::Int4, BitWidth::Int8, BitWidth::Int16];
    /// let lw = q.layerwise_space(&model, base, 2, &menu)?;
    /// assert_eq!(lw.size(), 16); // 4 widths ^ 2 layers
    /// for i in 0..lw.size() {
    ///     assert_eq!(lw.decode(&lw.encode(i)?), i);
    /// }
    /// # Ok(())
    /// # }
    /// ```
    fn decode(&self, bits: &[bool]) -> usize;
}

/// Shared handle to a space (search algorithms and evaluators hold one).
pub type SpaceRef = Arc<dyn ConfigSpace>;

fn bit(bits: &[bool], j: usize) -> bool {
    bits.get(j).copied().unwrap_or(false)
}

// ---------------------------------------------------------------------------
// General space (Eq. 1 grown by the PTQ toolbox axes, |S| = 288)
// ---------------------------------------------------------------------------

/// The 288-element general-purpose space of [`QuantConfig`].
#[derive(Clone, Copy, Debug, Default)]
pub struct GeneralSpace;

/// Shared handle to the general space.
pub fn general_space() -> SpaceRef {
    Arc::new(GeneralSpace)
}

impl ConfigSpace for GeneralSpace {
    fn tag(&self) -> String {
        "general".to_string()
    }

    fn size(&self) -> usize {
        QuantConfig::SPACE_SIZE
    }

    fn plan(&self, i: usize) -> Result<QuantPlan> {
        Ok(QuantPlan::from_config(QuantConfig::from_index(i)?))
    }

    fn describe(&self, i: usize) -> Result<String> {
        Ok(QuantConfig::from_index(i)?.slug())
    }

    fn features(&self, i: usize) -> Result<Vec<f32>> {
        Ok(QuantConfig::from_index(i)?.one_hot())
    }

    fn feature_names(&self) -> Vec<String> {
        QuantConfig::FEATURE_NAMES.iter().map(|s| s.to_string()).collect()
    }

    fn genome_bits(&self) -> usize {
        9
    }

    fn encode(&self, i: usize) -> Result<Vec<bool>> {
        Ok(QuantConfig::from_index(i)?.to_genome().to_vec())
    }

    fn decode(&self, bits: &[bool]) -> usize {
        let mut g = [false; 9];
        for (j, b) in g.iter_mut().enumerate() {
            *b = bit(bits, j);
        }
        QuantConfig::from_genome(&g).index()
    }
}

// ---------------------------------------------------------------------------
// VTA integer-only space (Eq. 23, |S| = 12)
// ---------------------------------------------------------------------------

/// The 12-element integer-only space of [`VtaConfig`].
#[derive(Clone, Copy, Debug, Default)]
pub struct VtaSpace;

/// Shared handle to the VTA space.
pub fn vta_space() -> SpaceRef {
    Arc::new(VtaSpace)
}

impl VtaSpace {
    /// Feature dimension names: 3 calib + 2 clip + 2 fusion (one-hot).
    pub const FEATURE_NAMES: [&'static str; 7] = [
        "calib_1", "calib_64", "calib_512", "clip_max", "clip_kl", "fusion_off",
        "fusion_on",
    ];
}

impl ConfigSpace for VtaSpace {
    fn tag(&self) -> String {
        "vta".to_string()
    }

    fn size(&self) -> usize {
        VtaConfig::SPACE_SIZE
    }

    fn plan(&self, i: usize) -> Result<QuantPlan> {
        Ok(QuantPlan::from_config(VtaConfig::from_index(i)?.as_quant_config()))
    }

    fn describe(&self, i: usize) -> Result<String> {
        Ok(VtaConfig::from_index(i)?.slug())
    }

    fn features(&self, i: usize) -> Result<Vec<f32>> {
        let c = VtaConfig::from_index(i)?;
        let mut v = vec![0.0f32; 7];
        v[c.calib.index()] = 1.0;
        v[3 + (c.clip == Clipping::Kl) as usize] = 1.0;
        v[5 + c.fusion as usize] = 1.0;
        Ok(v)
    }

    fn feature_names(&self) -> Vec<String> {
        Self::FEATURE_NAMES.iter().map(|s| s.to_string()).collect()
    }

    fn genome_bits(&self) -> usize {
        4
    }

    fn encode(&self, i: usize) -> Result<Vec<bool>> {
        let c = VtaConfig::from_index(i)?;
        let ci = c.calib.index();
        Ok(vec![ci / 2 == 1, ci % 2 == 1, c.clip == Clipping::Kl, c.fusion])
    }

    fn decode(&self, bits: &[bool]) -> usize {
        let calib = ALL_CALIB[((bit(bits, 0) as usize) * 2 + bit(bits, 1) as usize) % 3];
        let cfg = VtaConfig {
            calib,
            clip: if bit(bits, 2) { Clipping::Kl } else { Clipping::Max },
            fusion: bit(bits, 3),
        };
        cfg.index()
    }
}

// ---------------------------------------------------------------------------
// Layer-wise mixed-precision space
// ---------------------------------------------------------------------------

/// Cap on the genome length in bits: at most 2^12 = 4096 configs, which
/// keeps an exhaustive interpreter sweep tractable. Each free layer
/// consumes `ceil(log2(R))` genome bits for a menu of R widths, so the
/// cap bounds K at 12 free layers for the binary {int8, fp32} menu and
/// 6 for the full {int4, int8, int16, fp32} radix.
pub const MAX_LAYERWISE_BITS: usize = 12;

/// Genome bits one digit of an R-way width menu consumes
/// (`ceil(log2(R))`; R is at least 2 after normalization).
fn digit_bits(radix: usize) -> usize {
    usize::BITS as usize - (radix - 1).leading_zeros() as usize
}

/// Largest `--layers K` a width menu admits under
/// [`MAX_LAYERWISE_BITS`] (the genome budget divided by the bits one
/// mixed-radix digit consumes).
pub fn max_layers_for(widths: &[BitWidth]) -> usize {
    (MAX_LAYERWISE_BITS / digit_bits(normalize_menu(widths).len())).max(1)
}

/// Normalize a width menu to the canonical digit order: int8 first when
/// present (so digit 0 keeps the base config and index 0 stays the
/// all-base point), the remaining integer widths ascending, and fp32
/// (always included -- it is the bypass escape hatch) last.
fn normalize_menu(widths: &[BitWidth]) -> Vec<BitWidth> {
    let mut ints: Vec<BitWidth> = Vec::new();
    for &w in widths {
        if !w.is_float() && !ints.contains(&w) {
            ints.push(w);
        }
    }
    ints.sort_by_key(|w| w.bits());
    if ints.is_empty() {
        // a menu of only fp32 has nothing to search: fall back to the
        // binary {int8, fp32} space instead of a degenerate radix of 1
        ints.push(BitWidth::Int8);
    }
    let mut menu = Vec::with_capacity(ints.len() + 1);
    if ints.contains(&BitWidth::Int8) {
        menu.push(BitWidth::Int8);
    }
    menu.extend(ints.iter().copied().filter(|&w| w != BitWidth::Int8));
    menu.push(BitWidth::Fp32);
    menu
}

/// One candidate layer of a [`LayerwiseSpace`], with the per-layer
/// features the XGB cost model consumes and the sensitivity score that
/// selected it.
#[derive(Clone, Debug)]
pub struct LayerCandidate {
    /// Index into `graph.layers()`.
    pub layer_index: usize,
    /// The layer's node name.
    pub name: String,
    /// Position in the weighted-layer sequence, scaled to [0, 1].
    pub depth_frac: f32,
    /// ln(weight element count).
    pub log_params: f32,
    /// Layer kind: 0 = dense conv, 1 = depthwise/grouped conv, 2 = dense.
    pub kind: f32,
    /// Calibration-driven fragility score (higher = more fragile).
    pub sensitivity: f32,
}

/// Per-layer [`BitWidth`] choice over the top-K most fragile weighted
/// layers, on top of a fixed base [`QuantConfig`].
///
/// An index is a K-digit mixed-radix number over the width menu: digit
/// `j` (base R = menu length) selects candidate `j`'s width. Digit 0 is
/// the menu's base entry (int8 when present), so index 0 is always the
/// all-base configuration. With the legacy binary menu {int8, fp32}
/// this degenerates to exactly PR 2's bitmask space.
pub struct LayerwiseSpace {
    base: QuantConfig,
    model: String,
    n_layers: usize,
    /// Canonical per-layer width menu (the radix; see `normalize_menu`).
    widths: Vec<BitWidth>,
    /// Top-K fragile layers, ascending by `layer_index` (stable digit
    /// order).
    candidates: Vec<LayerCandidate>,
}

impl LayerwiseSpace {
    /// Build the space from calibration statistics: rank every weighted
    /// layer by fragility under `base`, keep the `k` most fragile, and
    /// let each choose among `widths` (normalized: int8-first order,
    /// fp32 always appended; see [`max_layers_for`] for the K cap the
    /// menu implies).
    ///
    /// The fragility score has two calibration-driven parts:
    /// - relative weight fake-quant MSE under the base scheme and
    ///   granularity (fine-grained channel spread shows up here);
    /// - relative activation quantization noise: `scale^2 / 12` of the
    ///   layer output's int8 grid (from its calibration histogram and
    ///   the base clipping policy) over the histogram's mean square.
    ///
    /// `weights` maps `{layer}_w` names to tensors; `hists` is one
    /// histogram per `graph.quant_points()` entry. `base.mixed` is
    /// ignored (the explicit widths supersede it).
    pub fn rank(
        model: &str,
        graph: &Graph,
        weights: &HashMap<String, Tensor>,
        hists: &[Histogram],
        base: QuantConfig,
        k: usize,
        widths: &[BitWidth],
    ) -> Result<LayerwiseSpace> {
        let menu = normalize_menu(widths);
        let qpoints = graph.quant_points();
        anyhow::ensure!(
            hists.len() == qpoints.len(),
            "{} histograms for {} quant points",
            hists.len(),
            qpoints.len()
        );
        let layers = graph.layers();
        if layers.is_empty() {
            bail!("{model}: no weighted layers to choose precision for");
        }
        let base = QuantConfig { mixed: false, ..base };
        let k = k
            .clamp(1, layers.len())
            .min((MAX_LAYERWISE_BITS / digit_bits(menu.len())).max(1));

        let mut scored: Vec<LayerCandidate> = Vec::with_capacity(layers.len());
        for (li, name) in layers.iter().enumerate() {
            let w = weights
                .get(&format!("{name}_w"))
                .ok_or_else(|| anyhow::anyhow!("{model}: missing weight {name}_w"))?;
            let mean_sq_w = w.data.iter().map(|&x| (x as f64).powi(2)).sum::<f64>()
                / w.data.len().max(1) as f64;
            let wq_rel =
                weight_mse(w, base.scheme, base.gran) / (mean_sq_w + 1e-12);

            let qi = qpoints
                .iter()
                .position(|q| q == name)
                .ok_or_else(|| anyhow::anyhow!("{name} is not a quant point"))?;
            let h = &hists[qi];
            let (lo, hi) = match base.clip {
                Clipping::Max => h.range(),
                Clipping::Kl => h.kl_clipped_range(),
                Clipping::Aciq => h.aciq_clipped_range(8),
            };
            let scale = base.scheme.params_from_range(lo, hi).scale as f64;
            let act_rel = (scale * scale / 12.0) / (h.mean_sq() + 1e-12);

            let kind = match graph.node(name).map(|n| &n.op) {
                Some(Op::Conv { groups, .. }) => {
                    if *groups > 1 {
                        1.0
                    } else {
                        0.0
                    }
                }
                Some(Op::Dense { .. }) => 2.0,
                _ => 0.0,
            };
            scored.push(LayerCandidate {
                layer_index: li,
                name: name.clone(),
                depth_frac: li as f32 / (layers.len() - 1).max(1) as f32,
                log_params: (w.data.len().max(1) as f32).ln(),
                kind,
                sensitivity: (wq_rel + act_rel) as f32,
            });
        }
        // most fragile first; ties break by depth so the order is total
        scored.sort_by(|a, b| {
            b.sensitivity
                .partial_cmp(&a.sensitivity)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.layer_index.cmp(&b.layer_index))
        });
        scored.truncate(k);
        // stable digit order: ascending layer position
        scored.sort_by_key(|c| c.layer_index);
        Ok(LayerwiseSpace {
            base,
            model: model.to_string(),
            n_layers: layers.len(),
            widths: menu,
            candidates: scored,
        })
    }

    /// The fixed base configuration the per-layer widths override.
    pub fn base(&self) -> QuantConfig {
        self.base
    }

    /// The top-K fragile layers, ascending by layer position.
    pub fn candidates(&self) -> &[LayerCandidate] {
        &self.candidates
    }

    /// Number of weighted layers in the model.
    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    /// The canonical per-layer width menu (the radix of the genome).
    pub fn width_menu(&self) -> &[BitWidth] {
        &self.widths
    }

    /// Mixed-radix digits of index `i`, one per candidate
    /// (little-endian: digit `j` selects candidate `j`'s width).
    fn digits_of(&self, i: usize) -> Vec<usize> {
        let r = self.widths.len();
        let mut rest = i;
        (0..self.candidates.len())
            .map(|_| {
                let d = rest % r;
                rest /= r;
                d
            })
            .collect()
    }

    /// Per-layer bit-widths over all weighted layers for index `i`
    /// (non-candidate layers stay at the int8 base).
    pub fn widths_of(&self, i: usize) -> Vec<BitWidth> {
        let mut out = vec![BitWidth::Int8; self.n_layers];
        for (c, d) in self.candidates.iter().zip(self.digits_of(i)) {
            out[c.layer_index] = self.widths[d];
        }
        out
    }

    /// fp32 mask over all weighted layers for index `i`.
    pub fn mask_of(&self, i: usize) -> Vec<bool> {
        self.widths_of(i).into_iter().map(BitWidth::is_float).collect()
    }

    /// Names of the layers index `i` keeps fp32.
    pub fn fp32_layer_names(&self, i: usize) -> Vec<String> {
        self.candidates
            .iter()
            .zip(self.digits_of(i))
            .filter(|(_, d)| self.widths[*d].is_float())
            .map(|(c, _)| c.name.clone())
            .collect()
    }

    /// Number of layers index `i` quantizes (any integer width).
    pub fn quantized_layers(&self, i: usize) -> usize {
        self.n_layers - self.mask_of(i).iter().filter(|&&b| b).count()
    }

    /// Number of candidate layers index `i` puts at `width`.
    pub fn layers_at(&self, i: usize, width: BitWidth) -> usize {
        self.digits_of(i).into_iter().filter(|&d| self.widths[d] == width).count()
    }

    /// Inverse of the mixed-radix digit expansion: the config index whose
    /// per-candidate width choices are `digits` (digit `j` picks candidate
    /// `j`'s menu entry). The IP width allocator composes its per-layer
    /// picks back into a space index through this.
    pub fn index_of_digits(&self, digits: &[usize]) -> Result<usize> {
        let r = self.widths.len();
        anyhow::ensure!(
            digits.len() == self.candidates.len(),
            "{} digits for {} candidates",
            digits.len(),
            self.candidates.len()
        );
        let mut i = 0usize;
        let mut place = 1usize;
        for &d in digits {
            anyhow::ensure!(d < r, "digit {d} out of radix {r}");
            i += d * place;
            place *= r;
        }
        Ok(i)
    }
}

impl ConfigSpace for LayerwiseSpace {
    fn tag(&self) -> String {
        let cands: Vec<String> =
            self.candidates.iter().map(|c| c.layer_index.to_string()).collect();
        let menu: Vec<&str> = self.widths.iter().map(|w| w.name()).collect();
        format!(
            "layerwise/{}/b{}/{}/{}",
            self.model,
            self.base.index(),
            menu.join("."),
            cands.join(".")
        )
    }

    fn size(&self) -> usize {
        self.widths.len().pow(self.candidates.len() as u32)
    }

    fn plan(&self, i: usize) -> Result<QuantPlan> {
        if i >= self.size() {
            bail!("layerwise config index {i} out of range {}", self.size());
        }
        Ok(QuantPlan { base: self.base, layer_widths: Some(self.widths_of(i)) })
    }

    fn describe(&self, i: usize) -> Result<String> {
        if i >= self.size() {
            bail!("layerwise config index {i} out of range {}", self.size());
        }
        let overrides: Vec<String> = self
            .candidates
            .iter()
            .zip(self.digits_of(i))
            .filter(|(_, d)| *d != 0)
            .map(|(c, d)| format!("{}:{}", c.name, self.widths[d]))
            .collect();
        Ok(if overrides.is_empty() {
            format!("lw_all_{}", self.widths[0])
        } else {
            format!("lw_{}", overrides.join("+"))
        })
    }

    /// Per-candidate blocks of R + 3: a one-hot over the width menu,
    /// then the layer's depth fraction, log param count, and kind gated
    /// by "deviates from the int8 base" -- so the cost model sees *which
    /// kind of layer* changed precision and to what, not just how many.
    fn features(&self, i: usize) -> Result<Vec<f32>> {
        if i >= self.size() {
            bail!("layerwise config index {i} out of range {}", self.size());
        }
        let r = self.widths.len();
        let mut v = Vec::with_capacity((r + 3) * self.candidates.len());
        for (c, d) in self.candidates.iter().zip(self.digits_of(i)) {
            for slot in 0..r {
                v.push((slot == d) as u8 as f32);
            }
            let dev = (self.widths[d] != BitWidth::Int8) as u8 as f32;
            v.extend([c.depth_frac * dev, c.log_params * dev, c.kind * dev]);
        }
        Ok(v)
    }

    fn feature_names(&self) -> Vec<String> {
        self.candidates
            .iter()
            .flat_map(|c| {
                self.widths
                    .iter()
                    .map(|w| format!("{}_{}", w, c.name))
                    .chain([
                        format!("dev_depth_{}", c.name),
                        format!("dev_logp_{}", c.name),
                        format!("dev_kind_{}", c.name),
                    ])
                    .collect::<Vec<_>>()
            })
            .collect()
    }

    fn genome_bits(&self) -> usize {
        self.candidates.len() * digit_bits(self.widths.len())
    }

    /// Mixed-radix encoding: each digit takes `ceil(log2(R))` bits,
    /// little-endian within the digit.
    fn encode(&self, i: usize) -> Result<Vec<bool>> {
        if i >= self.size() {
            bail!("layerwise config index {i} out of range {}", self.size());
        }
        let db = digit_bits(self.widths.len());
        let mut out = Vec::with_capacity(self.genome_bits());
        for d in self.digits_of(i) {
            for b in 0..db {
                out.push((d >> b) & 1 == 1);
            }
        }
        Ok(out)
    }

    /// Digits read back from their bit fields; a field value at or above
    /// the radix wraps (mod R), so every genome decodes to a valid index
    /// -- the same convention the general space's calibration field uses.
    fn decode(&self, bits: &[bool]) -> usize {
        let r = self.widths.len();
        let db = digit_bits(r);
        let mut i = 0usize;
        let mut place = 1usize;
        for j in 0..self.candidates.len() {
            let mut d = 0usize;
            for b in 0..db {
                if bit(bits, j * db + b) {
                    d |= 1 << b;
                }
            }
            i += (d % r) * place;
            place *= r;
        }
        i
    }
}

#[cfg(test)]
mod tests {
    use super::super::config::{CalibCount, Granularity};
    use super::super::scheme::{Scheme, BINARY_WIDTHS};
    use super::*;
    use crate::util::Json;

    const RADIX_WIDTHS: [BitWidth; 4] =
        [BitWidth::Int4, BitWidth::Int8, BitWidth::Int16, BitWidth::Fp32];

    fn space_roundtrips(space: &dyn ConfigSpace) {
        let dim = space.features(0).unwrap().len();
        assert_eq!(space.feature_names().len(), dim, "{}", space.tag());
        for i in 0..space.size() {
            let g = space.encode(i).unwrap();
            assert_eq!(g.len(), space.genome_bits(), "{} index {i}", space.tag());
            assert_eq!(space.decode(&g), i, "{} genome roundtrip {i}", space.tag());
            assert_eq!(space.features(i).unwrap().len(), dim);
            assert!(!space.describe(i).unwrap().is_empty());
            let plan = space.plan(i).unwrap();
            assert!(plan.base.index() < QuantConfig::SPACE_SIZE);
        }
        assert!(space.plan(space.size()).is_err());
        assert!(space.describe(space.size()).is_err());
    }

    #[test]
    fn general_space_roundtrips() {
        let s = GeneralSpace;
        assert_eq!(s.size(), QuantConfig::SPACE_SIZE);
        space_roundtrips(&s);
        // decode matches QuantConfig's own genome decode for every point
        for i in 0..s.size() {
            let cfg = QuantConfig::from_index(i).unwrap();
            assert_eq!(s.decode(&cfg.to_genome()), i);
        }
    }

    #[test]
    fn vta_space_roundtrips() {
        let s = VtaSpace;
        assert_eq!(s.size(), 12);
        space_roundtrips(&s);
        // every plan is integer-only (pow2/tensor, no mixed)
        for i in 0..s.size() {
            let p = s.plan(i).unwrap();
            assert_eq!(p.base.scheme, Scheme::Pow2);
            assert_eq!(p.base.gran, Granularity::Tensor);
            assert!(!p.base.mixed);
        }
        // genome wrap: an out-of-range 2-bit calib field still decodes
        let wrapped = s.decode(&[true, true, false, false]);
        assert!(wrapped < s.size());
    }

    fn tiny_graph() -> Graph {
        Graph::from_meta(
            &Json::parse(
                r#"{"name": "t", "input_shape": [8, 8, 2], "num_classes": 3,
            "nodes": [
              {"name": "c1", "op": "conv", "inputs": ["input"], "k": 3,
               "stride": 1, "pad": 1, "in_ch": 2, "out_ch": 4, "groups": 1,
               "act": "relu"},
              {"name": "c2", "op": "conv", "inputs": ["c1"], "k": 3,
               "stride": 1, "pad": 1, "in_ch": 4, "out_ch": 4, "groups": 1,
               "act": "relu"},
              {"name": "g", "op": "gap", "inputs": ["c2"]},
              {"name": "d", "op": "dense", "inputs": ["g"], "in_dim": 4,
               "out_dim": 3}]}"#,
            )
            .unwrap(),
        )
        .unwrap()
    }

    fn tiny_weights(graph: &Graph, fragile: &str) -> HashMap<String, Tensor> {
        let mut rng = crate::util::Pcg32::seeded(5);
        let mut out = HashMap::new();
        for n in &graph.nodes {
            let (w_shape, b_len): (Vec<usize>, usize) = match &n.op {
                Op::Conv { k, in_ch, out_ch, groups, .. } => {
                    (vec![*k, *k, in_ch / groups, *out_ch], *out_ch)
                }
                Op::Dense { in_dim, out_dim } => (vec![*in_dim, *out_dim], *out_dim),
                _ => continue,
            };
            let wn: usize = w_shape.iter().product();
            let c = *w_shape.last().unwrap();
            let spread = n.name == fragile;
            let data: Vec<f32> = (0..wn)
                .map(|i| {
                    let x = rng.normal() * 0.1;
                    // the fragile layer gets a huge per-channel spread,
                    // which per-tensor int8 quantization handles badly
                    if spread && i % c == 0 {
                        x * 100.0
                    } else {
                        x
                    }
                })
                .collect();
            out.insert(format!("{}_w", n.name), Tensor { shape: w_shape, data });
            out.insert(
                format!("{}_b", n.name),
                Tensor { shape: vec![b_len], data: vec![0.0; b_len] },
            );
        }
        out
    }

    fn tiny_hists(graph: &Graph) -> Vec<Histogram> {
        let mut rng = crate::util::Pcg32::seeded(6);
        graph
            .quant_points()
            .iter()
            .map(|_| {
                let mut h = Histogram::new();
                let xs: Vec<f32> = (0..512).map(|_| rng.normal()).collect();
                h.update(&xs);
                h
            })
            .collect()
    }

    fn base() -> QuantConfig {
        QuantConfig {
            calib: CalibCount::C64,
            scheme: Scheme::Symmetric,
            clip: Clipping::Max,
            gran: Granularity::Tensor,
            mixed: false,
            bias_correct: false,
        }
    }

    #[test]
    fn layerwise_space_roundtrips_and_masks() {
        let g = tiny_graph();
        let w = tiny_weights(&g, "c2");
        let h = tiny_hists(&g);
        let s = LayerwiseSpace::rank("t", &g, &w, &h, base(), 3, &BINARY_WIDTHS)
            .unwrap();
        assert_eq!(s.size(), 8);
        assert_eq!(s.n_layers(), 3);
        assert_eq!(s.width_menu(), &BINARY_WIDTHS);
        space_roundtrips(&s);
        // index 0 is the all-int8 base plan
        let p0 = s.plan(0).unwrap();
        assert_eq!(p0.resolve_mask(3).unwrap(), vec![false; 3]);
        assert_eq!(p0.resolve_widths(3).unwrap(), vec![BitWidth::Int8; 3]);
        assert_eq!(s.quantized_layers(0), 3);
        // the full mask keeps every candidate fp32
        let full = s.size() - 1;
        assert_eq!(s.quantized_layers(full), 0);
        assert_eq!(s.fp32_layer_names(full).len(), 3);
    }

    #[test]
    fn layerwise_radix_space_roundtrips() {
        let g = tiny_graph();
        let w = tiny_weights(&g, "c2");
        let h = tiny_hists(&g);
        let s = LayerwiseSpace::rank("t", &g, &w, &h, base(), 3, &RADIX_WIDTHS)
            .unwrap();
        // 4 widths over 3 candidates: 64 configs, 2 genome bits per digit
        assert_eq!(s.size(), 64);
        assert_eq!(s.genome_bits(), 6);
        assert_eq!(
            s.width_menu(),
            &[BitWidth::Int8, BitWidth::Int4, BitWidth::Int16, BitWidth::Fp32],
            "canonical order: int8 first, ints ascending, fp32 last"
        );
        space_roundtrips(&s);
        // index 0 is the all-int8 base; the menu's digit arithmetic holds
        assert_eq!(s.widths_of(0), vec![BitWidth::Int8; 3]);
        assert_eq!(s.describe(0).unwrap(), "lw_all_int8");
        // digit 1 on candidate 0 alone = index 1 -> int4 on that layer
        let w1 = s.widths_of(1);
        assert_eq!(w1.iter().filter(|&&x| x == BitWidth::Int4).count(), 1);
        assert_eq!(s.layers_at(1, BitWidth::Int4), 1);
        assert!(s.describe(1).unwrap().contains(":int4"));
        // the all-fp32 point is the last index (digit R-1 everywhere)
        let full = s.size() - 1;
        assert_eq!(s.widths_of(full), vec![BitWidth::Fp32; 3]);
        assert_eq!(s.quantized_layers(full), 0);
        // plans carry the width vector through to the evaluators
        let p = s.plan(1).unwrap();
        assert_eq!(p.resolve_widths(3).unwrap(), w1);
    }

    #[test]
    fn index_of_digits_inverts_digit_expansion() {
        let g = tiny_graph();
        let w = tiny_weights(&g, "c2");
        let h = tiny_hists(&g);
        let s = LayerwiseSpace::rank("t", &g, &w, &h, base(), 3, &RADIX_WIDTHS)
            .unwrap();
        for i in 0..s.size() {
            assert_eq!(s.index_of_digits(&s.digits_of(i)).unwrap(), i);
        }
        assert!(s.index_of_digits(&[0, 0]).is_err()); // wrong arity
        assert!(s.index_of_digits(&[4, 0, 0]).is_err()); // digit >= radix
    }

    #[test]
    fn layerwise_radix_genome_wraps_to_valid_indices() {
        let g = tiny_graph();
        let w = tiny_weights(&g, "c2");
        let h = tiny_hists(&g);
        // a 3-way menu ({int4, int8} + fp32) uses 2-bit digit fields
        // whose value 3 must wrap instead of escaping the space
        let menu = [BitWidth::Int4, BitWidth::Int8];
        let s = LayerwiseSpace::rank("t", &g, &w, &h, base(), 2, &menu).unwrap();
        assert_eq!(s.size(), 9);
        assert_eq!(s.genome_bits(), 4);
        let wrapped = s.decode(&[true, true, true, true]); // digits (3, 3)
        assert!(wrapped < s.size());
        for i in 0..s.size() {
            assert_eq!(s.decode(&s.encode(i).unwrap()), i);
        }
    }

    #[test]
    fn layerwise_ranking_finds_the_fragile_layer() {
        let g = tiny_graph();
        let w = tiny_weights(&g, "c2");
        let h = tiny_hists(&g);
        // K = 1: only the most fragile layer is free, and the channel
        // spread planted in c2 must dominate the ranking
        let s = LayerwiseSpace::rank("t", &g, &w, &h, base(), 1, &BINARY_WIDTHS)
            .unwrap();
        assert_eq!(s.size(), 2);
        assert_eq!(s.candidates()[0].name, "c2");
        assert_eq!(s.fp32_layer_names(1), vec!["c2".to_string()]);
    }

    #[test]
    fn layerwise_k_is_capped() {
        let g = tiny_graph();
        let w = tiny_weights(&g, "c2");
        let h = tiny_hists(&g);
        let s = LayerwiseSpace::rank("t", &g, &w, &h, base(), 99, &BINARY_WIDTHS)
            .unwrap();
        assert_eq!(s.genome_bits(), 3); // only 3 weighted layers exist
        // the 4-way radix menu halves the genome budget per layer:
        // max_layers_for reports the cap rank enforces
        assert_eq!(max_layers_for(&BINARY_WIDTHS), 12);
        assert_eq!(max_layers_for(&RADIX_WIDTHS), 6);
        // base.mixed is always neutralized by the explicit widths
        let mixed = QuantConfig { mixed: true, ..base() };
        let s = LayerwiseSpace::rank("t", &g, &w, &h, mixed, 2, &BINARY_WIDTHS)
            .unwrap();
        assert!(!s.base().mixed);
        let p = s.plan(0).unwrap();
        assert_eq!(p.resolve_mask(3).unwrap(), vec![false; 3]);
    }

    #[test]
    fn plan_mask_resolution() {
        let p = QuantPlan::from_config(QuantConfig { mixed: true, ..base() });
        assert_eq!(p.resolve_mask(4).unwrap(), vec![true, false, false, true]);
        let p = QuantPlan::from_config(base());
        assert_eq!(p.resolve_mask(2).unwrap(), vec![false, false]);
        let p = QuantPlan {
            base: base(),
            layer_widths: Some(vec![BitWidth::Fp32, BitWidth::Int8]),
        };
        assert_eq!(p.resolve_mask(2).unwrap(), vec![true, false]);
        assert!(p.resolve_mask(3).is_err());
        // width vectors flow through untouched, and int4/int16 are not
        // part of the fp32 mask projection
        let p = QuantPlan {
            base: base(),
            layer_widths: Some(vec![
                BitWidth::Int4,
                BitWidth::Int16,
                BitWidth::Fp32,
            ]),
        };
        assert_eq!(
            p.resolve_widths(3).unwrap(),
            vec![BitWidth::Int4, BitWidth::Int16, BitWidth::Fp32]
        );
        assert_eq!(p.resolve_mask(3).unwrap(), vec![false, false, true]);
    }
}
