//! Search-space abstraction: the `ConfigSpace` trait and its three
//! implementations.
//!
//! The paper's claim is that the XGB cost model accelerates search over
//! *whatever* quantization space the compiler exposes (Eq. 1 is just one
//! instance). This module makes that literal: a space is anything that
//! can enumerate its points, decode a point into a concrete
//! [`QuantPlan`] for the evaluators, featurize points for the cost
//! model, and encode/decode a binary genome for the GA.
//!
//! - [`GeneralSpace`]: the 96-element space of Eq. 1 ([`QuantConfig`]);
//! - [`VtaSpace`]: the 12-element integer-only space of Eq. 23
//!   ([`VtaConfig`]);
//! - [`LayerwiseSpace`]: per-layer mixed precision (paper §4.5,
//!   generalized): starting from a fixed base config, each of the top-K
//!   most quantization-fragile weighted layers independently chooses
//!   {int8, fp32}. K is capped so the 2^K space stays enumerable, and
//!   the fragility ranking is calibration-driven (weight fake-quant MSE
//!   plus activation quantization noise from the calibration
//!   histograms).

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::ir::{Graph, Op, Tensor};

use super::config::{QuantConfig, VtaConfig, ALL_CALIB};
use super::histogram::Histogram;
use super::weights::weight_mse;
use super::Clipping;

/// Everything an evaluator needs to realize one configuration: the base
/// axes (calibration count, scheme, clipping, granularity) plus which
/// weighted layers stay fp32.
#[derive(Clone, Debug)]
pub struct QuantPlan {
    pub base: QuantConfig,
    /// Explicit fp32 mask over `graph.layers()` order. `None` derives
    /// the mask from `base.mixed` (first+last, paper §4.5).
    pub fp32_mask: Option<Vec<bool>>,
}

impl QuantPlan {
    pub fn from_config(base: QuantConfig) -> QuantPlan {
        QuantPlan { base, fp32_mask: None }
    }

    /// Resolve the fp32-layer mask for a model with `n_layers` weighted
    /// layers.
    pub fn resolve_mask(&self, n_layers: usize) -> Result<Vec<bool>> {
        if let Some(m) = &self.fp32_mask {
            anyhow::ensure!(
                m.len() == n_layers,
                "fp32 mask covers {} layers but the model has {n_layers}",
                m.len()
            );
            return Ok(m.clone());
        }
        let mut mask = vec![false; n_layers];
        if self.base.mixed && n_layers > 0 {
            mask[0] = true;
            mask[n_layers - 1] = true;
        }
        Ok(mask)
    }
}

impl From<QuantConfig> for QuantPlan {
    fn from(base: QuantConfig) -> QuantPlan {
        QuantPlan::from_config(base)
    }
}

/// A quantization search space: an indexed, featurized, genome-encoded
/// set of configurations the generic search/sweep/database plumbing
/// operates on.
pub trait ConfigSpace: Send + Sync {
    /// Stable identifier stored with database records so transfer
    /// learning never mixes feature vectors from incompatible spaces.
    fn tag(&self) -> String;

    /// Number of configurations (indices are `0..size()`).
    fn size(&self) -> usize;

    /// Decode an index into the concrete evaluation plan.
    fn plan(&self, i: usize) -> Result<QuantPlan>;

    /// Human-readable slug for an index.
    fn describe(&self, i: usize) -> Result<String>;

    /// Config-feature vector for the XGBoost cost model (the `s` half of
    /// the paper's §5.1 features; the model's arch features `e` are
    /// prepended by the coordinator).
    fn features(&self, i: usize) -> Result<Vec<f32>>;

    /// Names of the `features()` dimensions, for importance reports.
    fn feature_names(&self) -> Vec<String>;

    /// Genome length for the binary GA.
    fn genome_bits(&self) -> usize;

    /// Encode an index as a genome of `genome_bits()` bits.
    fn encode(&self, i: usize) -> Result<Vec<bool>>;

    /// Decode a genome to a valid index. Missing trailing bits read as
    /// 0 and out-of-range field values wrap (the GA package's binary
    /// encoding does the same for non-power-of-two cardinalities), so
    /// every genome decodes to some point of the space.
    fn decode(&self, bits: &[bool]) -> usize;
}

/// Shared handle to a space (search algorithms and evaluators hold one).
pub type SpaceRef = Arc<dyn ConfigSpace>;

fn bit(bits: &[bool], j: usize) -> bool {
    bits.get(j).copied().unwrap_or(false)
}

// ---------------------------------------------------------------------------
// General space (Eq. 1, |S| = 96)
// ---------------------------------------------------------------------------

/// The 96-element general-purpose space of [`QuantConfig`].
#[derive(Clone, Copy, Debug, Default)]
pub struct GeneralSpace;

/// Shared handle to the general space.
pub fn general_space() -> SpaceRef {
    Arc::new(GeneralSpace)
}

impl ConfigSpace for GeneralSpace {
    fn tag(&self) -> String {
        "general".to_string()
    }

    fn size(&self) -> usize {
        QuantConfig::SPACE_SIZE
    }

    fn plan(&self, i: usize) -> Result<QuantPlan> {
        Ok(QuantPlan::from_config(QuantConfig::from_index(i)?))
    }

    fn describe(&self, i: usize) -> Result<String> {
        Ok(QuantConfig::from_index(i)?.slug())
    }

    fn features(&self, i: usize) -> Result<Vec<f32>> {
        Ok(QuantConfig::from_index(i)?.one_hot())
    }

    fn feature_names(&self) -> Vec<String> {
        QuantConfig::FEATURE_NAMES.iter().map(|s| s.to_string()).collect()
    }

    fn genome_bits(&self) -> usize {
        7
    }

    fn encode(&self, i: usize) -> Result<Vec<bool>> {
        Ok(QuantConfig::from_index(i)?.to_genome().to_vec())
    }

    fn decode(&self, bits: &[bool]) -> usize {
        let mut g = [false; 7];
        for (j, b) in g.iter_mut().enumerate() {
            *b = bit(bits, j);
        }
        QuantConfig::from_genome(&g).index()
    }
}

// ---------------------------------------------------------------------------
// VTA integer-only space (Eq. 23, |S| = 12)
// ---------------------------------------------------------------------------

/// The 12-element integer-only space of [`VtaConfig`].
#[derive(Clone, Copy, Debug, Default)]
pub struct VtaSpace;

/// Shared handle to the VTA space.
pub fn vta_space() -> SpaceRef {
    Arc::new(VtaSpace)
}

impl VtaSpace {
    /// Feature dimension names: 3 calib + 2 clip + 2 fusion (one-hot).
    pub const FEATURE_NAMES: [&'static str; 7] = [
        "calib_1", "calib_64", "calib_512", "clip_max", "clip_kl", "fusion_off",
        "fusion_on",
    ];
}

impl ConfigSpace for VtaSpace {
    fn tag(&self) -> String {
        "vta".to_string()
    }

    fn size(&self) -> usize {
        VtaConfig::SPACE_SIZE
    }

    fn plan(&self, i: usize) -> Result<QuantPlan> {
        Ok(QuantPlan::from_config(VtaConfig::from_index(i)?.as_quant_config()))
    }

    fn describe(&self, i: usize) -> Result<String> {
        Ok(VtaConfig::from_index(i)?.slug())
    }

    fn features(&self, i: usize) -> Result<Vec<f32>> {
        let c = VtaConfig::from_index(i)?;
        let mut v = vec![0.0f32; 7];
        v[c.calib.index()] = 1.0;
        v[3 + (c.clip == Clipping::Kl) as usize] = 1.0;
        v[5 + c.fusion as usize] = 1.0;
        Ok(v)
    }

    fn feature_names(&self) -> Vec<String> {
        Self::FEATURE_NAMES.iter().map(|s| s.to_string()).collect()
    }

    fn genome_bits(&self) -> usize {
        4
    }

    fn encode(&self, i: usize) -> Result<Vec<bool>> {
        let c = VtaConfig::from_index(i)?;
        let ci = c.calib.index();
        Ok(vec![ci / 2 == 1, ci % 2 == 1, c.clip == Clipping::Kl, c.fusion])
    }

    fn decode(&self, bits: &[bool]) -> usize {
        let calib = ALL_CALIB[((bit(bits, 0) as usize) * 2 + bit(bits, 1) as usize) % 3];
        let cfg = VtaConfig {
            calib,
            clip: if bit(bits, 2) { Clipping::Kl } else { Clipping::Max },
            fusion: bit(bits, 3),
        };
        cfg.index()
    }
}

// ---------------------------------------------------------------------------
// Layer-wise mixed-precision space
// ---------------------------------------------------------------------------

/// Cap on the number of free layers: 2^12 = 4096 configs keeps an
/// exhaustive interpreter sweep tractable.
pub const MAX_LAYERWISE_BITS: usize = 12;

/// One candidate layer of a [`LayerwiseSpace`], with the per-layer
/// features the XGB cost model consumes and the sensitivity score that
/// selected it.
#[derive(Clone, Debug)]
pub struct LayerCandidate {
    /// Index into `graph.layers()`.
    pub layer_index: usize,
    pub name: String,
    /// Position in the weighted-layer sequence, scaled to [0, 1].
    pub depth_frac: f32,
    /// ln(weight element count).
    pub log_params: f32,
    /// Layer kind: 0 = dense conv, 1 = depthwise/grouped conv, 2 = dense.
    pub kind: f32,
    /// Calibration-driven fragility score (higher = more fragile).
    pub sensitivity: f32,
}

/// Per-layer {int8, fp32} choice over the top-K most fragile weighted
/// layers, on top of a fixed base [`QuantConfig`]. Index 0 is the
/// all-int8 base config; bit `j` of an index keeps candidate `j` fp32.
pub struct LayerwiseSpace {
    base: QuantConfig,
    model: String,
    n_layers: usize,
    /// Top-K fragile layers, ascending by `layer_index` (stable bit order).
    candidates: Vec<LayerCandidate>,
}

impl LayerwiseSpace {
    /// Build the space from calibration statistics: rank every weighted
    /// layer by fragility under `base`, keep the `k` most fragile.
    ///
    /// The fragility score has two calibration-driven parts:
    /// - relative weight fake-quant MSE under the base scheme and
    ///   granularity (fine-grained channel spread shows up here);
    /// - relative activation quantization noise: `scale^2 / 12` of the
    ///   layer output's int8 grid (from its calibration histogram and
    ///   the base clipping policy) over the histogram's mean square.
    ///
    /// `weights` maps `{layer}_w` names to tensors; `hists` is one
    /// histogram per `graph.quant_points()` entry. `base.mixed` is
    /// ignored (the explicit mask supersedes it).
    pub fn rank(
        model: &str,
        graph: &Graph,
        weights: &HashMap<String, Tensor>,
        hists: &[Histogram],
        base: QuantConfig,
        k: usize,
    ) -> Result<LayerwiseSpace> {
        let qpoints = graph.quant_points();
        anyhow::ensure!(
            hists.len() == qpoints.len(),
            "{} histograms for {} quant points",
            hists.len(),
            qpoints.len()
        );
        let layers = graph.layers();
        if layers.is_empty() {
            bail!("{model}: no weighted layers to choose precision for");
        }
        let base = QuantConfig { mixed: false, ..base };
        let k = k.clamp(1, layers.len()).min(MAX_LAYERWISE_BITS);

        let mut scored: Vec<LayerCandidate> = Vec::with_capacity(layers.len());
        for (li, name) in layers.iter().enumerate() {
            let w = weights
                .get(&format!("{name}_w"))
                .ok_or_else(|| anyhow::anyhow!("{model}: missing weight {name}_w"))?;
            let mean_sq_w = w.data.iter().map(|&x| (x as f64).powi(2)).sum::<f64>()
                / w.data.len().max(1) as f64;
            let wq_rel =
                weight_mse(w, base.scheme, base.gran) / (mean_sq_w + 1e-12);

            let qi = qpoints
                .iter()
                .position(|q| q == name)
                .ok_or_else(|| anyhow::anyhow!("{name} is not a quant point"))?;
            let h = &hists[qi];
            let (lo, hi) = match base.clip {
                Clipping::Max => h.range(),
                Clipping::Kl => h.kl_clipped_range(),
            };
            let scale = base.scheme.params_from_range(lo, hi).scale as f64;
            let act_rel = (scale * scale / 12.0) / (h.mean_sq() + 1e-12);

            let kind = match graph.node(name).map(|n| &n.op) {
                Some(Op::Conv { groups, .. }) => {
                    if *groups > 1 {
                        1.0
                    } else {
                        0.0
                    }
                }
                Some(Op::Dense { .. }) => 2.0,
                _ => 0.0,
            };
            scored.push(LayerCandidate {
                layer_index: li,
                name: name.clone(),
                depth_frac: li as f32 / (layers.len() - 1).max(1) as f32,
                log_params: (w.data.len().max(1) as f32).ln(),
                kind,
                sensitivity: (wq_rel + act_rel) as f32,
            });
        }
        // most fragile first; ties break by depth so the order is total
        scored.sort_by(|a, b| {
            b.sensitivity
                .partial_cmp(&a.sensitivity)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.layer_index.cmp(&b.layer_index))
        });
        scored.truncate(k);
        // stable bit order: ascending layer position
        scored.sort_by_key(|c| c.layer_index);
        Ok(LayerwiseSpace {
            base,
            model: model.to_string(),
            n_layers: layers.len(),
            candidates: scored,
        })
    }

    pub fn base(&self) -> QuantConfig {
        self.base
    }

    pub fn candidates(&self) -> &[LayerCandidate] {
        &self.candidates
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    /// fp32 mask over all weighted layers for index `i`.
    pub fn mask_of(&self, i: usize) -> Vec<bool> {
        let mut mask = vec![false; self.n_layers];
        for (j, c) in self.candidates.iter().enumerate() {
            if (i >> j) & 1 == 1 {
                mask[c.layer_index] = true;
            }
        }
        mask
    }

    /// Names of the layers index `i` keeps fp32.
    pub fn fp32_layer_names(&self, i: usize) -> Vec<String> {
        self.candidates
            .iter()
            .enumerate()
            .filter(|(j, _)| (i >> j) & 1 == 1)
            .map(|(_, c)| c.name.clone())
            .collect()
    }

    /// Number of layers index `i` quantizes (the complement of the mask).
    pub fn quantized_layers(&self, i: usize) -> usize {
        self.n_layers - self.mask_of(i).iter().filter(|&&b| b).count()
    }
}

impl ConfigSpace for LayerwiseSpace {
    fn tag(&self) -> String {
        let cands: Vec<String> =
            self.candidates.iter().map(|c| c.layer_index.to_string()).collect();
        format!("layerwise/{}/b{}/{}", self.model, self.base.index(), cands.join("."))
    }

    fn size(&self) -> usize {
        1usize << self.candidates.len()
    }

    fn plan(&self, i: usize) -> Result<QuantPlan> {
        if i >= self.size() {
            bail!("layerwise config index {i} out of range {}", self.size());
        }
        Ok(QuantPlan { base: self.base, fp32_mask: Some(self.mask_of(i)) })
    }

    fn describe(&self, i: usize) -> Result<String> {
        if i >= self.size() {
            bail!("layerwise config index {i} out of range {}", self.size());
        }
        let names = self.fp32_layer_names(i);
        Ok(if names.is_empty() {
            "lw_all_int8".to_string()
        } else {
            format!("lw_fp32_{}", names.join("+"))
        })
    }

    /// Per-candidate blocks of 4: the fp32 bit gated with the layer's
    /// depth fraction, log param count, and kind -- so the cost model
    /// sees *which kind of layer* was bypassed, not just how many.
    fn features(&self, i: usize) -> Result<Vec<f32>> {
        if i >= self.size() {
            bail!("layerwise config index {i} out of range {}", self.size());
        }
        let mut v = Vec::with_capacity(4 * self.candidates.len());
        for (j, c) in self.candidates.iter().enumerate() {
            if (i >> j) & 1 == 1 {
                v.extend([1.0, c.depth_frac, c.log_params, c.kind]);
            } else {
                v.extend([0.0, 0.0, 0.0, 0.0]);
            }
        }
        Ok(v)
    }

    fn feature_names(&self) -> Vec<String> {
        self.candidates
            .iter()
            .flat_map(|c| {
                [
                    format!("fp32_{}", c.name),
                    format!("fp32_depth_{}", c.name),
                    format!("fp32_logp_{}", c.name),
                    format!("fp32_kind_{}", c.name),
                ]
            })
            .collect()
    }

    fn genome_bits(&self) -> usize {
        self.candidates.len()
    }

    fn encode(&self, i: usize) -> Result<Vec<bool>> {
        if i >= self.size() {
            bail!("layerwise config index {i} out of range {}", self.size());
        }
        Ok((0..self.candidates.len()).map(|j| (i >> j) & 1 == 1).collect())
    }

    fn decode(&self, bits: &[bool]) -> usize {
        let mut i = 0usize;
        for j in 0..self.candidates.len() {
            if bit(bits, j) {
                i |= 1 << j;
            }
        }
        i
    }
}

#[cfg(test)]
mod tests {
    use super::super::config::{CalibCount, Granularity};
    use super::super::scheme::Scheme;
    use super::*;
    use crate::util::Json;

    fn space_roundtrips(space: &dyn ConfigSpace) {
        let dim = space.features(0).unwrap().len();
        assert_eq!(space.feature_names().len(), dim, "{}", space.tag());
        for i in 0..space.size() {
            let g = space.encode(i).unwrap();
            assert_eq!(g.len(), space.genome_bits(), "{} index {i}", space.tag());
            assert_eq!(space.decode(&g), i, "{} genome roundtrip {i}", space.tag());
            assert_eq!(space.features(i).unwrap().len(), dim);
            assert!(!space.describe(i).unwrap().is_empty());
            let plan = space.plan(i).unwrap();
            assert!(plan.base.index() < QuantConfig::SPACE_SIZE);
        }
        assert!(space.plan(space.size()).is_err());
        assert!(space.describe(space.size()).is_err());
    }

    #[test]
    fn general_space_roundtrips() {
        let s = GeneralSpace;
        assert_eq!(s.size(), 96);
        space_roundtrips(&s);
        // decode matches QuantConfig's own genome decode for every point
        for i in 0..s.size() {
            let cfg = QuantConfig::from_index(i).unwrap();
            assert_eq!(s.decode(&cfg.to_genome()), i);
        }
    }

    #[test]
    fn vta_space_roundtrips() {
        let s = VtaSpace;
        assert_eq!(s.size(), 12);
        space_roundtrips(&s);
        // every plan is integer-only (pow2/tensor, no mixed)
        for i in 0..s.size() {
            let p = s.plan(i).unwrap();
            assert_eq!(p.base.scheme, Scheme::Pow2);
            assert_eq!(p.base.gran, Granularity::Tensor);
            assert!(!p.base.mixed);
        }
        // genome wrap: an out-of-range 2-bit calib field still decodes
        let wrapped = s.decode(&[true, true, false, false]);
        assert!(wrapped < s.size());
    }

    fn tiny_graph() -> Graph {
        Graph::from_meta(
            &Json::parse(
                r#"{"name": "t", "input_shape": [8, 8, 2], "num_classes": 3,
            "nodes": [
              {"name": "c1", "op": "conv", "inputs": ["input"], "k": 3,
               "stride": 1, "pad": 1, "in_ch": 2, "out_ch": 4, "groups": 1,
               "act": "relu"},
              {"name": "c2", "op": "conv", "inputs": ["c1"], "k": 3,
               "stride": 1, "pad": 1, "in_ch": 4, "out_ch": 4, "groups": 1,
               "act": "relu"},
              {"name": "g", "op": "gap", "inputs": ["c2"]},
              {"name": "d", "op": "dense", "inputs": ["g"], "in_dim": 4,
               "out_dim": 3}]}"#,
            )
            .unwrap(),
        )
        .unwrap()
    }

    fn tiny_weights(graph: &Graph, fragile: &str) -> HashMap<String, Tensor> {
        let mut rng = crate::util::Pcg32::seeded(5);
        let mut out = HashMap::new();
        for n in &graph.nodes {
            let (w_shape, b_len): (Vec<usize>, usize) = match &n.op {
                Op::Conv { k, in_ch, out_ch, groups, .. } => {
                    (vec![*k, *k, in_ch / groups, *out_ch], *out_ch)
                }
                Op::Dense { in_dim, out_dim } => (vec![*in_dim, *out_dim], *out_dim),
                _ => continue,
            };
            let wn: usize = w_shape.iter().product();
            let c = *w_shape.last().unwrap();
            let spread = n.name == fragile;
            let data: Vec<f32> = (0..wn)
                .map(|i| {
                    let x = rng.normal() * 0.1;
                    // the fragile layer gets a huge per-channel spread,
                    // which per-tensor int8 quantization handles badly
                    if spread && i % c == 0 {
                        x * 100.0
                    } else {
                        x
                    }
                })
                .collect();
            out.insert(format!("{}_w", n.name), Tensor { shape: w_shape, data });
            out.insert(
                format!("{}_b", n.name),
                Tensor { shape: vec![b_len], data: vec![0.0; b_len] },
            );
        }
        out
    }

    fn tiny_hists(graph: &Graph) -> Vec<Histogram> {
        let mut rng = crate::util::Pcg32::seeded(6);
        graph
            .quant_points()
            .iter()
            .map(|_| {
                let mut h = Histogram::new();
                let xs: Vec<f32> = (0..512).map(|_| rng.normal()).collect();
                h.update(&xs);
                h
            })
            .collect()
    }

    fn base() -> QuantConfig {
        QuantConfig {
            calib: CalibCount::C64,
            scheme: Scheme::Symmetric,
            clip: Clipping::Max,
            gran: Granularity::Tensor,
            mixed: false,
        }
    }

    #[test]
    fn layerwise_space_roundtrips_and_masks() {
        let g = tiny_graph();
        let w = tiny_weights(&g, "c2");
        let h = tiny_hists(&g);
        let s = LayerwiseSpace::rank("t", &g, &w, &h, base(), 3).unwrap();
        assert_eq!(s.size(), 8);
        assert_eq!(s.n_layers(), 3);
        space_roundtrips(&s);
        // index 0 is the all-int8 base plan
        let p0 = s.plan(0).unwrap();
        assert_eq!(p0.resolve_mask(3).unwrap(), vec![false; 3]);
        assert_eq!(s.quantized_layers(0), 3);
        // the full mask keeps every candidate fp32
        let full = s.size() - 1;
        assert_eq!(s.quantized_layers(full), 0);
        assert_eq!(s.fp32_layer_names(full).len(), 3);
    }

    #[test]
    fn layerwise_ranking_finds_the_fragile_layer() {
        let g = tiny_graph();
        let w = tiny_weights(&g, "c2");
        let h = tiny_hists(&g);
        // K = 1: only the most fragile layer is free, and the channel
        // spread planted in c2 must dominate the ranking
        let s = LayerwiseSpace::rank("t", &g, &w, &h, base(), 1).unwrap();
        assert_eq!(s.size(), 2);
        assert_eq!(s.candidates()[0].name, "c2");
        assert_eq!(s.fp32_layer_names(1), vec!["c2".to_string()]);
    }

    #[test]
    fn layerwise_k_is_capped() {
        let g = tiny_graph();
        let w = tiny_weights(&g, "c2");
        let h = tiny_hists(&g);
        let s = LayerwiseSpace::rank("t", &g, &w, &h, base(), 99).unwrap();
        assert_eq!(s.genome_bits(), 3); // only 3 weighted layers exist
        // base.mixed is always neutralized by the explicit mask
        let mixed = QuantConfig { mixed: true, ..base() };
        let s = LayerwiseSpace::rank("t", &g, &w, &h, mixed, 2).unwrap();
        assert!(!s.base().mixed);
        let p = s.plan(0).unwrap();
        assert_eq!(p.resolve_mask(3).unwrap(), vec![false; 3]);
    }

    #[test]
    fn plan_mask_resolution() {
        let p = QuantPlan::from_config(QuantConfig { mixed: true, ..base() });
        assert_eq!(p.resolve_mask(4).unwrap(), vec![true, false, false, true]);
        let p = QuantPlan::from_config(base());
        assert_eq!(p.resolve_mask(2).unwrap(), vec![false, false]);
        let p = QuantPlan { base: base(), fp32_mask: Some(vec![true, false]) };
        assert_eq!(p.resolve_mask(2).unwrap(), vec![true, false]);
        assert!(p.resolve_mask(3).is_err());
    }
}
