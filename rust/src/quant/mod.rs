//! Quantization substrate: schemes, histograms + KL clipping,
//! configuration spaces, and weight quantization.
//!
//! This module is the "Glow extension" half of the paper (§4): everything
//! needed to turn calibration statistics + a `QuantConfig` into concrete
//! quantization parameters for every tensor of a model.

pub mod config;
pub mod histogram;
pub mod scheme;
pub mod space;
pub mod weights;

pub use config::{
    CalibCount, Clipping, Granularity, QuantConfig, VtaConfig, ALL_CALIB, ALL_CLIP,
    ALL_GRAN, LEGACY_CLIP,
};
pub use histogram::Histogram;
pub use scheme::{
    parse_bits_spec, BitWidth, FixedRequant, QParams, Scheme, ALL_SCHEMES,
    ALL_WIDTHS, BINARY_WIDTHS,
};
pub use space::{
    general_space, max_layers_for, vta_space, ConfigSpace, GeneralSpace,
    LayerCandidate, LayerwiseSpace, QuantPlan, SpaceRef, VtaSpace,
    MAX_LAYERWISE_BITS,
};
pub use weights::{
    bias_correction_sums, channel_params, channel_params_at, correct_bias,
    fake_quant_weights, fake_quant_weights_at, layer_size_bytes_at, model_size_bytes,
    model_size_bytes_at, model_size_bytes_masked, model_size_fp32, quantize_weights_int,
    quantize_weights_int8, tensor_params, tensor_params_at, weight_mse, weight_mse_at,
    IntRepr, PackedI4, QuantWeight,
};

use anyhow::Result;

/// Activation quantization parameters for every quantization point of a
/// model, derived from calibration histograms + a config. This is the
/// [L, 5] `act_params` array the fq HLO executables take (rows:
/// scale, zero_point, qmin, qmax, bypass).
#[derive(Clone, Debug)]
pub struct ActQuantization {
    /// One [scale, zero_point, qmin, qmax, bypass] row per quant point.
    pub rows: Vec<[f32; 5]>,
}

impl ActQuantization {
    /// Build from per-quant-point histograms (same order as
    /// `Graph::quant_points`).
    ///
    /// `bypass` marks rows that stay fp32: for mixed precision the caller
    /// passes the set of quant points adjacent to the first/last layers.
    pub fn from_histograms(
        hists: &[Histogram],
        scheme: Scheme,
        clip: Clipping,
        bypass: &[bool],
    ) -> Result<ActQuantization> {
        anyhow::ensure!(hists.len() == bypass.len(), "bypass arity mismatch");
        let mut rows = Vec::with_capacity(hists.len());
        for (h, &by) in hists.iter().zip(bypass) {
            if by {
                rows.push([1.0, 0.0, -128.0, 127.0, 1.0]);
                continue;
            }
            let (lo, hi) = match clip {
                Clipping::Max => h.range(),
                Clipping::Kl => h.kl_clipped_range(),
                // activations quantize onto the int8 grid; degenerate
                // histograms fall back to the raw range (Max behavior)
                Clipping::Aciq => h.aciq_clipped_range(8),
            };
            let p = scheme.params_from_range(lo, hi);
            rows.push([p.scale, p.zero_point as f32, p.qmin, p.qmax, 0.0]);
        }
        Ok(ActQuantization { rows })
    }

    /// Flatten to the [L*5] f32 buffer the runtime feeds to PJRT.
    pub fn flat(&self) -> Vec<f32> {
        self.rows.iter().flatten().copied().collect()
    }

    /// QParams view of row `i` (bypassed rows return identity).
    pub fn params(&self, i: usize) -> QParams {
        let r = &self.rows[i];
        QParams { scale: r[0], zero_point: r[1] as i32, qmin: r[2], qmax: r[3] }
    }

    /// Does row `i` stay fp32 (the mixed-precision bypass)?
    pub fn is_bypassed(&self, i: usize) -> bool {
        self.rows[i][4] > 0.5
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn act_quantization_rows() {
        let mut h = Histogram::new();
        h.update(&[-1.0, 0.5, 2.0]);
        let hists = vec![h.clone(), h];
        let aq = ActQuantization::from_histograms(
            &hists,
            Scheme::Asymmetric,
            Clipping::Max,
            &[false, true],
        )
        .unwrap();
        assert_eq!(aq.rows.len(), 2);
        assert!(!aq.is_bypassed(0));
        assert!(aq.is_bypassed(1));
        assert_eq!(aq.flat().len(), 10);
        let p = aq.params(0);
        assert!((p.scale - 3.0 / 255.0).abs() < 1e-6);
    }

    #[test]
    fn arity_checked() {
        let hists = vec![Histogram::new()];
        assert!(ActQuantization::from_histograms(
            &hists,
            Scheme::Symmetric,
            Clipping::Max,
            &[false, false]
        )
        .is_err());
    }
}
