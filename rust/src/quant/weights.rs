//! Weight quantization: fake-quant for the HLO evaluation path, true int8
//! for the VTA path, and model-size accounting (paper Table 5).
//!
//! Weights are quantized from their raw min/max (clipping applies to
//! activations, whose statistics come from calibration; Glow does the
//! same). Granularity selects between one scale per tensor and one scale
//! per output channel -- the output channel is the last axis for both
//! conv HWIO and dense [in, out] tensors. Every entry point comes in an
//! int8 form (the paper's grid) and a `_at` form generalized over a
//! [`BitWidth`] for the per-layer radix search.

use crate::ir::{Graph, QTensor, Tensor};

use super::config::Granularity;
use super::scheme::{BitWidth, QParams, Scheme};

/// Per-channel slices: yields (channel, stride view) over the last axis.
fn channel_dim(shape: &[usize]) -> usize {
    *shape.last().expect("scalar weight")
}

/// Compute quantization params per channel (last axis) of a weight
/// tensor, on the `width` grid.
pub fn channel_params_at(w: &Tensor, scheme: Scheme, width: BitWidth) -> Vec<QParams> {
    let c = channel_dim(&w.shape);
    let mut mins = vec![f32::INFINITY; c];
    let mut maxs = vec![f32::NEG_INFINITY; c];
    for (i, &x) in w.data.iter().enumerate() {
        let ch = i % c;
        mins[ch] = mins[ch].min(x);
        maxs[ch] = maxs[ch].max(x);
    }
    (0..c).map(|ch| scheme.params_for(mins[ch], maxs[ch], width)).collect()
}

/// Compute quantization params per channel (last axis) of a weight
/// tensor, on the int8 grid.
pub fn channel_params(w: &Tensor, scheme: Scheme) -> Vec<QParams> {
    channel_params_at(w, scheme, BitWidth::Int8)
}

/// Compute a single per-tensor param set on the `width` grid.
pub fn tensor_params_at(w: &Tensor, scheme: Scheme, width: BitWidth) -> QParams {
    let (lo, hi) = w.range();
    scheme.params_for(lo, hi, width)
}

/// Compute a single per-tensor param set on the int8 grid.
pub fn tensor_params(w: &Tensor, scheme: Scheme) -> QParams {
    tensor_params_at(w, scheme, BitWidth::Int8)
}

/// Fake-quantize a weight tensor onto the `width` grid.
/// [`BitWidth::Fp32`] is the identity (an untouched copy), so a
/// per-layer width vector can drive one uniform preparation loop.
pub fn fake_quant_weights_at(
    w: &Tensor,
    scheme: Scheme,
    gran: Granularity,
    width: BitWidth,
) -> Tensor {
    if width.is_float() {
        return w.clone();
    }
    match gran {
        Granularity::Tensor => {
            let p = tensor_params_at(w, scheme, width);
            Tensor {
                shape: w.shape.clone(),
                data: w.data.iter().map(|&x| p.fake_quant(x)).collect(),
            }
        }
        Granularity::Channel => {
            let params = channel_params_at(w, scheme, width);
            let c = params.len();
            Tensor {
                shape: w.shape.clone(),
                data: w
                    .data
                    .iter()
                    .enumerate()
                    .map(|(i, &x)| params[i % c].fake_quant(x))
                    .collect(),
            }
        }
    }
}

/// Fake-quantize a weight tensor onto the int8 grid (what the rust
/// coordinator feeds to the `{model}_fq.hlo.txt` executables).
pub fn fake_quant_weights(w: &Tensor, scheme: Scheme, gran: Granularity) -> Tensor {
    fake_quant_weights_at(w, scheme, gran, BitWidth::Int8)
}

/// True int8 quantization (VTA path; per-tensor only -- the accelerator
/// has a single shift register per GEMM).
pub fn quantize_weights_int8(w: &Tensor, scheme: Scheme) -> QTensor {
    let p = tensor_params(w, scheme);
    QTensor {
        shape: w.shape.clone(),
        data: w.data.iter().map(|&x| p.quantize(x) as i8).collect(),
        scales: vec![p.scale],
        zero_points: vec![p.zero_point],
    }
}

/// Mean squared fake-quant error of a weight tensor on the `width` grid
/// (zero for [`BitWidth::Fp32`]).
pub fn weight_mse_at(
    w: &Tensor,
    scheme: Scheme,
    gran: Granularity,
    width: BitWidth,
) -> f64 {
    let fq = fake_quant_weights_at(w, scheme, gran, width);
    let n = w.data.len().max(1);
    w.data
        .iter()
        .zip(&fq.data)
        .map(|(&a, &b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        / n as f64
}

/// Mean squared int8 fake-quant error of a weight tensor (used by Table
/// 3's "fine-grained mapping" metric and by tests).
pub fn weight_mse(w: &Tensor, scheme: Scheme, gran: Granularity) -> f64 {
    weight_mse_at(w, scheme, gran, BitWidth::Int8)
}

/// Serialized size in bytes of a quantized model (paper Table 5).
///
/// Accounting (matches Glow's serialized format in spirit):
/// - int8 layer: 1 byte per weight element, biases as int32 (4B/elem),
///   plus (scale f32 + zero_point i32) = 8B per scale group
///   (1 group per tensor, or out_channels groups per channel).
/// - fp32 layer (mixed precision first/last): 4 bytes per element.
pub fn model_size_bytes(
    graph: &Graph,
    weights: &dyn Fn(&str) -> (usize, usize), // name -> (w elems, channels)
    gran: Granularity,
    mixed: bool,
) -> u64 {
    let n = graph.layers().len();
    let mask: Vec<bool> =
        (0..n).map(|i| mixed && (i == 0 || i == n.saturating_sub(1))).collect();
    model_size_bytes_masked(graph, weights, gran, &mask)
}

/// Serialized size under an arbitrary fp32-layer mask (layer-wise mixed
/// precision; `mask` follows `graph.layers()` order, same accounting as
/// [`model_size_bytes`]). Masked layers are fp32, the rest int8.
pub fn model_size_bytes_masked(
    graph: &Graph,
    weights: &dyn Fn(&str) -> (usize, usize), // name -> (w elems, channels)
    gran: Granularity,
    mask: &[bool],
) -> u64 {
    let widths: Vec<BitWidth> = (0..graph.layers().len())
        .map(|i| {
            if mask.get(i).copied().unwrap_or(false) {
                BitWidth::Fp32
            } else {
                BitWidth::Int8
            }
        })
        .collect();
    model_size_bytes_at(graph, weights, gran, &widths)
}

/// Serialized size under a per-layer bit-width vector (`widths` follows
/// `graph.layers()` order; missing trailing entries read as int8).
///
/// Accounting per layer at width `w`:
/// - fp32: 4 bytes per weight and bias element, no scale overhead;
/// - integer: [`BitWidth::weight_bytes`] for the weights (int4 packs two
///   per byte), biases as int32 (4B/elem), plus (scale f32 + zero_point
///   i32) = 8B per scale group (1 per tensor, or `channels` per layer at
///   channel granularity).
pub fn model_size_bytes_at(
    graph: &Graph,
    weights: &dyn Fn(&str) -> (usize, usize), // name -> (w elems, channels)
    gran: Granularity,
    widths: &[BitWidth],
) -> u64 {
    let layers = graph.layers();
    let mut total = 0u64;
    for (i, layer) in layers.iter().enumerate() {
        let (w_elems, channels) = weights(layer);
        let bias_elems = channels;
        let width = widths.get(i).copied().unwrap_or(BitWidth::Int8);
        if width.is_float() {
            total += 4 * (w_elems + bias_elems) as u64;
        } else {
            let groups = match gran {
                Granularity::Tensor => 1,
                Granularity::Channel => channels,
            };
            total += width.weight_bytes(w_elems); // packed integer weights
            total += 4 * bias_elems as u64; // int32 biases
            total += 8 * groups as u64; // scale + zero point
        }
    }
    total
}

/// fp32 (original) model size in bytes.
pub fn model_size_fp32(graph: &Graph, weights: &dyn Fn(&str) -> (usize, usize)) -> u64 {
    graph
        .layers()
        .iter()
        .map(|l| {
            let (w, c) = weights(l);
            4 * (w + c) as u64
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn rand_weight(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Pcg32::seeded(seed);
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: (0..n).map(|_| rng.normal() * 0.1).collect(),
        }
    }

    #[test]
    fn channel_beats_tensor_on_spread_channels() {
        // channel 0 tiny values, channel 1 large: per-channel scales must
        // quantize the tiny channel far better (this is exactly why
        // depthwise-conv models are "fragile" under tensor granularity)
        let mut w = rand_weight(&[3, 3, 4, 2], 1);
        for (i, x) in w.data.iter_mut().enumerate() {
            if i % 2 == 0 {
                *x *= 0.01;
            } else {
                *x *= 10.0;
            }
        }
        let fq_t = fake_quant_weights(&w, Scheme::Symmetric, Granularity::Tensor);
        let fq_c = fake_quant_weights(&w, Scheme::Symmetric, Granularity::Channel);
        // measure the error on the tiny channel only (channel 0)
        let ch0_mse = |fq: &Tensor| -> f64 {
            w.data
                .iter()
                .zip(&fq.data)
                .enumerate()
                .filter(|(i, _)| i % 2 == 0)
                .map(|(_, (&a, &b))| ((a - b) as f64).powi(2))
                .sum::<f64>()
        };
        let (t0, c0) = (ch0_mse(&fq_t), ch0_mse(&fq_c));
        assert!(
            c0 < t0 / 100.0,
            "channel-gran ch0 err {c0} should be orders below tensor-gran {t0}"
        );
    }

    #[test]
    fn int8_quantization_roundtrip_error() {
        let w = rand_weight(&[3, 3, 8, 16], 2);
        let q = quantize_weights_int8(&w, Scheme::Symmetric);
        let dq = q.dequantize();
        let max_err = w
            .data
            .iter()
            .zip(&dq.data)
            .map(|(&a, &b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(max_err <= q.scales[0] * 0.5 + 1e-6);
    }

    #[test]
    fn fake_quant_matches_true_quant() {
        let w = rand_weight(&[4, 4], 3);
        let fq = fake_quant_weights(&w, Scheme::Symmetric, Granularity::Tensor);
        let q = quantize_weights_int8(&w, Scheme::Symmetric);
        let dq = q.dequantize();
        for (a, b) in fq.data.iter().zip(&dq.data) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn channel_param_count() {
        let w = rand_weight(&[3, 3, 4, 7], 4);
        assert_eq!(channel_params(&w, Scheme::Asymmetric).len(), 7);
    }

    #[test]
    fn width_roundtrip_error_bounds() {
        // quantize -> dequantize error is bounded by half the grid step
        // at every width, and the bound shrinks monotonically with bits
        let w = rand_weight(&[3, 3, 8, 16], 11);
        let mut last_max_err = f64::INFINITY;
        for width in [BitWidth::Int4, BitWidth::Int8, BitWidth::Int16] {
            let p = tensor_params_at(&w, Scheme::Symmetric, width);
            let fq = fake_quant_weights_at(
                &w,
                Scheme::Symmetric,
                Granularity::Tensor,
                width,
            );
            let max_err = w
                .data
                .iter()
                .zip(&fq.data)
                .map(|(&a, &b)| (a - b).abs() as f64)
                .fold(0f64, f64::max);
            assert!(
                max_err <= p.scale as f64 * 0.5 + 1e-9,
                "{width}: err {max_err} vs step {}",
                p.scale
            );
            assert!(max_err < last_max_err, "{width} must refine the grid");
            last_max_err = max_err;
        }
        // fp32 is exactly the identity
        let fq = fake_quant_weights_at(
            &w,
            Scheme::Symmetric,
            Granularity::Tensor,
            BitWidth::Fp32,
        );
        assert_eq!(fq.data, w.data);
        assert_eq!(weight_mse_at(&w, Scheme::Symmetric, Granularity::Tensor, BitWidth::Fp32), 0.0);
    }

    #[test]
    fn int4_mse_orders_below_int16() {
        let w = rand_weight(&[128], 12);
        let m4 = weight_mse_at(&w, Scheme::Symmetric, Granularity::Tensor, BitWidth::Int4);
        let m8 = weight_mse(&w, Scheme::Symmetric, Granularity::Tensor);
        let m16 =
            weight_mse_at(&w, Scheme::Symmetric, Granularity::Tensor, BitWidth::Int16);
        assert!(m16 < m8 && m8 < m4, "{m16} {m8} {m4}");
    }
}
