//! Weight quantization: fake-quant for the HLO evaluation path, true int8
//! for the VTA path, and model-size accounting (paper Table 5).
//!
//! Weights are quantized from their raw min/max (clipping applies to
//! activations, whose statistics come from calibration; Glow does the
//! same). Granularity selects between one scale per tensor and one scale
//! per output channel -- the output channel is the last axis for both
//! conv HWIO and dense [in, out] tensors. Every entry point comes in an
//! int8 form (the paper's grid) and a `_at` form generalized over a
//! [`BitWidth`] for the per-layer radix search.

use crate::ir::{Graph, QTensor, Tensor};

use super::config::Granularity;
use super::scheme::{BitWidth, QParams, Scheme};

/// Per-channel slices: yields (channel, stride view) over the last axis.
fn channel_dim(shape: &[usize]) -> usize {
    *shape.last().expect("scalar weight")
}

/// Compute quantization params per channel (last axis) of a weight
/// tensor, on the `width` grid.
pub fn channel_params_at(w: &Tensor, scheme: Scheme, width: BitWidth) -> Vec<QParams> {
    let c = channel_dim(&w.shape);
    let mut mins = vec![f32::INFINITY; c];
    let mut maxs = vec![f32::NEG_INFINITY; c];
    for (i, &x) in w.data.iter().enumerate() {
        let ch = i % c;
        mins[ch] = mins[ch].min(x);
        maxs[ch] = maxs[ch].max(x);
    }
    (0..c).map(|ch| scheme.params_for(mins[ch], maxs[ch], width)).collect()
}

/// Compute quantization params per channel (last axis) of a weight
/// tensor, on the int8 grid.
pub fn channel_params(w: &Tensor, scheme: Scheme) -> Vec<QParams> {
    channel_params_at(w, scheme, BitWidth::Int8)
}

/// Compute a single per-tensor param set on the `width` grid.
pub fn tensor_params_at(w: &Tensor, scheme: Scheme, width: BitWidth) -> QParams {
    let (lo, hi) = w.range();
    scheme.params_for(lo, hi, width)
}

/// Compute a single per-tensor param set on the int8 grid.
pub fn tensor_params(w: &Tensor, scheme: Scheme) -> QParams {
    tensor_params_at(w, scheme, BitWidth::Int8)
}

/// Fake-quantize a weight tensor onto the `width` grid.
/// [`BitWidth::Fp32`] is the identity (an untouched copy), so a
/// per-layer width vector can drive one uniform preparation loop.
pub fn fake_quant_weights_at(
    w: &Tensor,
    scheme: Scheme,
    gran: Granularity,
    width: BitWidth,
) -> Tensor {
    if width.is_float() {
        return w.clone();
    }
    match gran {
        Granularity::Tensor => {
            let p = tensor_params_at(w, scheme, width);
            Tensor {
                shape: w.shape.clone(),
                data: w.data.iter().map(|&x| p.fake_quant(x)).collect(),
            }
        }
        Granularity::Channel => {
            let params = channel_params_at(w, scheme, width);
            let c = params.len();
            Tensor {
                shape: w.shape.clone(),
                data: w
                    .data
                    .iter()
                    .enumerate()
                    .map(|(i, &x)| params[i % c].fake_quant(x))
                    .collect(),
            }
        }
    }
}

/// Fake-quantize a weight tensor onto the int8 grid (what the rust
/// coordinator feeds to the `{model}_fq.hlo.txt` executables).
pub fn fake_quant_weights(w: &Tensor, scheme: Scheme, gran: Granularity) -> Tensor {
    fake_quant_weights_at(w, scheme, gran, BitWidth::Int8)
}

/// True int8 quantization (VTA path; per-tensor only -- the accelerator
/// has a single shift register per GEMM).
pub fn quantize_weights_int8(w: &Tensor, scheme: Scheme) -> QTensor {
    let p = tensor_params(w, scheme);
    QTensor {
        shape: w.shape.clone(),
        data: w.data.iter().map(|&x| p.quantize(x) as i8).collect(),
        scales: vec![p.scale],
        zero_points: vec![p.zero_point],
    }
}

/// Two int4 values per byte, flat element order: element `2i` lives in
/// the low nibble of byte `i`, element `2i+1` in the high nibble (odd
/// lengths leave the last high nibble zero).
///
/// This is the serialized layout [`BitWidth::weight_bytes`] accounts
/// for, and the storage the packed-int4 GEMM kernel consumes directly
/// (unpack-in-register; the f32 weights are never materialized).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PackedI4 {
    bytes: Vec<u8>,
    len: usize,
}

impl PackedI4 {
    /// Pack a slice of int4 values (each must lie in [-8, 7]).
    pub fn pack(vals: &[i8]) -> PackedI4 {
        let mut bytes = vec![0u8; vals.len().div_ceil(2)];
        for (i, &v) in vals.iter().enumerate() {
            debug_assert!((-8..=7).contains(&v), "int4 value {v} out of range");
            let nib = (v as u8) & 0x0f;
            if i % 2 == 0 {
                bytes[i / 2] |= nib;
            } else {
                bytes[i / 2] |= nib << 4;
            }
        }
        PackedI4 { bytes, len: vals.len() }
    }

    /// Element `i`, sign-extended from its nibble.
    pub fn get(&self, i: usize) -> i8 {
        debug_assert!(i < self.len);
        let byte = self.bytes[i / 2];
        if i % 2 == 0 {
            ((byte << 4) as i8) >> 4
        } else {
            (byte as i8) >> 4
        }
    }

    /// Number of packed elements.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Is the buffer empty?
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The raw nibble-pair bytes.
    pub fn bytes(&self) -> &[u8] {
        &self.bytes
    }
}

/// Integer storage of a quantized weight tensor.
#[derive(Clone, Debug)]
pub enum IntRepr {
    /// One i8 per element (the int8 grid).
    I8(Vec<i8>),
    /// Two int4 elements per byte (see [`PackedI4`]).
    I4(PackedI4),
}

/// A weight tensor held as true integers plus its grid parameters --
/// the operand the integer GEMM kernels compute on.
///
/// `scales` / `zero_points` have one entry per scale group: a single
/// entry at [`Granularity::Tensor`], one per output channel (the last
/// axis, same `i % c` indexing as [`channel_params_at`]) at
/// [`Granularity::Channel`]. [`QuantWeight::dequantize`] reproduces
/// [`fake_quant_weights_at`] bit-for-bit, which is what lets the integer
/// interpreter path stand in for the f32 fake-quant route.
#[derive(Clone, Debug)]
pub struct QuantWeight {
    /// Tensor shape (HWIO for conv, [in, out] for dense).
    pub shape: Vec<usize>,
    /// The integer elements.
    pub repr: IntRepr,
    /// One scale per group (len 1 or `channels`).
    pub scales: Vec<f32>,
    /// One zero point per group (aligned with `scales`).
    pub zero_points: Vec<i32>,
    /// The grid the elements live on ([`BitWidth::Int4`] or
    /// [`BitWidth::Int8`]).
    pub width: BitWidth,
}

impl QuantWeight {
    /// Flat element `i` as an i32 grid value.
    pub fn get(&self, i: usize) -> i32 {
        match &self.repr {
            IntRepr::I8(d) => d[i] as i32,
            IntRepr::I4(p) => p.get(i) as i32,
        }
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        match &self.repr {
            IntRepr::I8(d) => d.len(),
            IntRepr::I4(p) => p.len(),
        }
    }

    /// Is the tensor empty?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Scale-group index of flat element `i` (0 at tensor granularity,
    /// the output channel -- last axis -- at channel granularity).
    pub fn group(&self, i: usize) -> usize {
        i % self.scales.len()
    }

    /// Dequantize to f32; bit-identical to [`fake_quant_weights_at`] at
    /// the same (scheme, granularity, width).
    pub fn dequantize(&self) -> Tensor {
        let data = (0..self.len())
            .map(|i| {
                let g = self.group(i);
                (self.get(i) - self.zero_points[g]) as f32 * self.scales[g]
            })
            .collect();
        Tensor { shape: self.shape.clone(), data }
    }
}

/// Quantize a weight tensor to true integers on the `width` grid.
///
/// Returns `None` for [`BitWidth::Int16`] and [`BitWidth::Fp32`]: those
/// widths have no integer kernel (int16 products overflow the i8 GEMM's
/// operand contract; fp32 is the bypass), so their layers stay on the
/// f32 fake-quant route. Uses the same per-tensor / per-channel
/// parameters as [`fake_quant_weights_at`], so dequantizing the result
/// reproduces the fake-quant tensor exactly.
pub fn quantize_weights_int(
    w: &Tensor,
    scheme: Scheme,
    gran: Granularity,
    width: BitWidth,
) -> Option<QuantWeight> {
    if !matches!(width, BitWidth::Int4 | BitWidth::Int8) {
        return None;
    }
    let params: Vec<QParams> = match gran {
        Granularity::Tensor => vec![tensor_params_at(w, scheme, width)],
        Granularity::Channel => channel_params_at(w, scheme, width),
    };
    let c = params.len();
    let q: Vec<i8> = w
        .data
        .iter()
        .enumerate()
        .map(|(i, &x)| params[i % c].quantize(x) as i8)
        .collect();
    let repr = match width {
        BitWidth::Int4 => IntRepr::I4(PackedI4::pack(&q)),
        _ => IntRepr::I8(q),
    };
    Some(QuantWeight {
        shape: w.shape.clone(),
        repr,
        scales: params.iter().map(|p| p.scale).collect(),
        zero_points: params.iter().map(|p| p.zero_point).collect(),
        width,
    })
}

/// Mean squared fake-quant error of a weight tensor on the `width` grid
/// (zero for [`BitWidth::Fp32`]).
pub fn weight_mse_at(
    w: &Tensor,
    scheme: Scheme,
    gran: Granularity,
    width: BitWidth,
) -> f64 {
    let fq = fake_quant_weights_at(w, scheme, gran, width);
    let n = w.data.len().max(1);
    w.data
        .iter()
        .zip(&fq.data)
        .map(|(&a, &b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        / n as f64
}

/// Mean squared int8 fake-quant error of a weight tensor (used by Table
/// 3's "fine-grained mapping" metric and by tests).
pub fn weight_mse(w: &Tensor, scheme: Scheme, gran: Granularity) -> f64 {
    weight_mse_at(w, scheme, gran, BitWidth::Int8)
}

/// Serialized size in bytes of a quantized model (paper Table 5).
///
/// Accounting (matches Glow's serialized format in spirit):
/// - int8 layer: 1 byte per weight element, biases as int32 (4B/elem),
///   plus (scale f32 + zero_point i32) = 8B per scale group
///   (1 group per tensor, or out_channels groups per channel).
/// - fp32 layer (mixed precision first/last): 4 bytes per element.
pub fn model_size_bytes(
    graph: &Graph,
    weights: &dyn Fn(&str) -> (usize, usize), // name -> (w elems, channels)
    gran: Granularity,
    mixed: bool,
) -> u64 {
    let n = graph.layers().len();
    let mask: Vec<bool> =
        (0..n).map(|i| mixed && (i == 0 || i == n.saturating_sub(1))).collect();
    model_size_bytes_masked(graph, weights, gran, &mask)
}

/// Serialized size under an arbitrary fp32-layer mask (layer-wise mixed
/// precision; `mask` follows `graph.layers()` order, same accounting as
/// [`model_size_bytes`]). Masked layers are fp32, the rest int8.
pub fn model_size_bytes_masked(
    graph: &Graph,
    weights: &dyn Fn(&str) -> (usize, usize), // name -> (w elems, channels)
    gran: Granularity,
    mask: &[bool],
) -> u64 {
    let widths: Vec<BitWidth> = (0..graph.layers().len())
        .map(|i| {
            if mask.get(i).copied().unwrap_or(false) {
                BitWidth::Fp32
            } else {
                BitWidth::Int8
            }
        })
        .collect();
    model_size_bytes_at(graph, weights, gran, &widths)
}

/// Serialized size under a per-layer bit-width vector (`widths` follows
/// `graph.layers()` order; missing trailing entries read as int8).
///
/// Accounting per layer at width `w`:
/// - fp32: 4 bytes per weight and bias element, no scale overhead;
/// - integer: [`BitWidth::weight_bytes`] for the weights (int4 packs two
///   per byte), biases as int32 (4B/elem), plus (scale f32 + zero_point
///   i32) = 8B per scale group (1 per tensor, or `channels` per layer at
///   channel granularity).
pub fn model_size_bytes_at(
    graph: &Graph,
    weights: &dyn Fn(&str) -> (usize, usize), // name -> (w elems, channels)
    gran: Granularity,
    widths: &[BitWidth],
) -> u64 {
    let layers = graph.layers();
    let mut total = 0u64;
    for (i, layer) in layers.iter().enumerate() {
        let (w_elems, channels) = weights(layer);
        let width = widths.get(i).copied().unwrap_or(BitWidth::Int8);
        total += layer_size_bytes_at(w_elems, channels, gran, width);
    }
    total
}

/// Serialized size in bytes of one layer at width `width` -- the
/// single source of truth for the per-layer accounting shared by
/// [`model_size_bytes_at`] and the IP width allocator
/// ([`crate::search::ip_alloc`]): the allocator's byte costs and the
/// experiment CSVs must agree, or the budget-feasibility oracle test
/// would compare apples to oranges.
pub fn layer_size_bytes_at(
    w_elems: usize,
    channels: usize,
    gran: Granularity,
    width: BitWidth,
) -> u64 {
    let bias_elems = channels;
    if width.is_float() {
        return 4 * (w_elems + bias_elems) as u64;
    }
    let groups = match gran {
        Granularity::Tensor => 1,
        Granularity::Channel => channels,
    };
    width.weight_bytes(w_elems) // packed integer weights
        + 4 * bias_elems as u64 // int32 biases
        + 8 * groups as u64 // scale + zero point
}

/// Per-output-channel sums of the fake-quant weight error on the given
/// grid: entry `c` is `sum_{i = c mod channels} (w_i - fq(w_i))`, i.e.
/// the exact bias shift that zeroes channel `c`'s mean output error for
/// a unit-mean input (Banner et al.'s bias correction, computed exactly
/// from the weights -- fan_in * (E[W] - E[Wq]) per channel, no
/// activation statistics involved). Accumulated in f64 so the
/// cancellation-heavy sum stays exact.
pub fn bias_correction_sums(
    w: &Tensor,
    scheme: Scheme,
    gran: Granularity,
    width: BitWidth,
) -> Vec<f64> {
    let c = channel_dim(&w.shape);
    let fq = fake_quant_weights_at(w, scheme, gran, width);
    let mut sums = vec![0.0f64; c];
    for (i, (&a, &b)) in w.data.iter().zip(&fq.data).enumerate() {
        sums[i % c] += (a - b) as f64;
    }
    sums
}

/// Fold the per-channel weight quantization error into a bias vector:
/// `b'[c] = b[c] + sum_c(W - Wq)` (see [`bias_correction_sums`]). The
/// corrected bias compensates the DC component of the weight rounding
/// error at the layer output. Returns `b` untouched when its length
/// does not match the weight's channel count (defensive; the model
/// loaders always pair them).
pub fn correct_bias(
    b: &Tensor,
    w: &Tensor,
    scheme: Scheme,
    gran: Granularity,
    width: BitWidth,
) -> Tensor {
    let sums = bias_correction_sums(w, scheme, gran, width);
    if b.data.len() != sums.len() {
        return b.clone();
    }
    Tensor {
        shape: b.shape.clone(),
        data: b
            .data
            .iter()
            .zip(&sums)
            .map(|(&bc, &s)| (f64::from(bc) + s) as f32)
            .collect(),
    }
}

/// fp32 (original) model size in bytes.
pub fn model_size_fp32(graph: &Graph, weights: &dyn Fn(&str) -> (usize, usize)) -> u64 {
    graph
        .layers()
        .iter()
        .map(|l| {
            let (w, c) = weights(l);
            4 * (w + c) as u64
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn rand_weight(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Pcg32::seeded(seed);
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: (0..n).map(|_| rng.normal() * 0.1).collect(),
        }
    }

    #[test]
    fn channel_beats_tensor_on_spread_channels() {
        // channel 0 tiny values, channel 1 large: per-channel scales must
        // quantize the tiny channel far better (this is exactly why
        // depthwise-conv models are "fragile" under tensor granularity)
        let mut w = rand_weight(&[3, 3, 4, 2], 1);
        for (i, x) in w.data.iter_mut().enumerate() {
            if i % 2 == 0 {
                *x *= 0.01;
            } else {
                *x *= 10.0;
            }
        }
        let fq_t = fake_quant_weights(&w, Scheme::Symmetric, Granularity::Tensor);
        let fq_c = fake_quant_weights(&w, Scheme::Symmetric, Granularity::Channel);
        // measure the error on the tiny channel only (channel 0)
        let ch0_mse = |fq: &Tensor| -> f64 {
            w.data
                .iter()
                .zip(&fq.data)
                .enumerate()
                .filter(|(i, _)| i % 2 == 0)
                .map(|(_, (&a, &b))| ((a - b) as f64).powi(2))
                .sum::<f64>()
        };
        let (t0, c0) = (ch0_mse(&fq_t), ch0_mse(&fq_c));
        assert!(
            c0 < t0 / 100.0,
            "channel-gran ch0 err {c0} should be orders below tensor-gran {t0}"
        );
    }

    #[test]
    fn int8_quantization_roundtrip_error() {
        let w = rand_weight(&[3, 3, 8, 16], 2);
        let q = quantize_weights_int8(&w, Scheme::Symmetric);
        let dq = q.dequantize();
        let max_err = w
            .data
            .iter()
            .zip(&dq.data)
            .map(|(&a, &b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(max_err <= q.scales[0] * 0.5 + 1e-6);
    }

    #[test]
    fn fake_quant_matches_true_quant() {
        let w = rand_weight(&[4, 4], 3);
        let fq = fake_quant_weights(&w, Scheme::Symmetric, Granularity::Tensor);
        let q = quantize_weights_int8(&w, Scheme::Symmetric);
        let dq = q.dequantize();
        for (a, b) in fq.data.iter().zip(&dq.data) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn channel_param_count() {
        let w = rand_weight(&[3, 3, 4, 7], 4);
        assert_eq!(channel_params(&w, Scheme::Asymmetric).len(), 7);
    }

    #[test]
    fn width_roundtrip_error_bounds() {
        // quantize -> dequantize error is bounded by half the grid step
        // at every width, and the bound shrinks monotonically with bits
        let w = rand_weight(&[3, 3, 8, 16], 11);
        let mut last_max_err = f64::INFINITY;
        for width in [BitWidth::Int4, BitWidth::Int8, BitWidth::Int16] {
            let p = tensor_params_at(&w, Scheme::Symmetric, width);
            let fq = fake_quant_weights_at(
                &w,
                Scheme::Symmetric,
                Granularity::Tensor,
                width,
            );
            let max_err = w
                .data
                .iter()
                .zip(&fq.data)
                .map(|(&a, &b)| (a - b).abs() as f64)
                .fold(0f64, f64::max);
            assert!(
                max_err <= p.scale as f64 * 0.5 + 1e-9,
                "{width}: err {max_err} vs step {}",
                p.scale
            );
            assert!(max_err < last_max_err, "{width} must refine the grid");
            last_max_err = max_err;
        }
        // fp32 is exactly the identity
        let fq = fake_quant_weights_at(
            &w,
            Scheme::Symmetric,
            Granularity::Tensor,
            BitWidth::Fp32,
        );
        assert_eq!(fq.data, w.data);
        assert_eq!(weight_mse_at(&w, Scheme::Symmetric, Granularity::Tensor, BitWidth::Fp32), 0.0);
    }

    #[test]
    fn packed_i4_roundtrips_all_values() {
        // every int4 value at both nibble positions, odd length included
        let vals: Vec<i8> = (-8..=7).chain(-8..=6).collect();
        let p = PackedI4::pack(&vals);
        assert_eq!(p.len(), vals.len());
        assert_eq!(p.bytes().len(), vals.len().div_ceil(2));
        for (i, &v) in vals.iter().enumerate() {
            assert_eq!(p.get(i), v, "element {i}");
        }
        assert!(PackedI4::pack(&[]).is_empty());
    }

    #[test]
    fn quant_weight_dequantizes_to_fake_quant_bitwise() {
        // the integer path's correctness hinges on this: true-integer
        // storage + dequantize must be the f32 fake-quant tensor exactly
        let w = rand_weight(&[3, 3, 4, 6], 7);
        for width in [BitWidth::Int4, BitWidth::Int8] {
            for gran in [Granularity::Tensor, Granularity::Channel] {
                for scheme in [Scheme::Asymmetric, Scheme::Symmetric, Scheme::Pow2] {
                    let q = quantize_weights_int(&w, scheme, gran, width).unwrap();
                    assert_eq!(q.width, width);
                    assert_eq!(q.len(), w.data.len());
                    let fq = fake_quant_weights_at(&w, scheme, gran, width);
                    for (i, (a, b)) in q.dequantize().data.iter().zip(&fq.data).enumerate()
                    {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "{scheme}/{gran:?}/{width} elem {i}: {a} vs {b}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn quantize_weights_int_rejects_kernel_less_widths() {
        let w = rand_weight(&[4, 4], 8);
        for width in [BitWidth::Int16, BitWidth::Fp32] {
            assert!(quantize_weights_int(
                &w,
                Scheme::Symmetric,
                Granularity::Tensor,
                width
            )
            .is_none());
        }
    }

    #[test]
    fn int4_mse_orders_below_int16() {
        let w = rand_weight(&[128], 12);
        let m4 = weight_mse_at(&w, Scheme::Symmetric, Granularity::Tensor, BitWidth::Int4);
        let m8 = weight_mse(&w, Scheme::Symmetric, Granularity::Tensor);
        let m16 =
            weight_mse_at(&w, Scheme::Symmetric, Granularity::Tensor, BitWidth::Int16);
        assert!(m16 < m8 && m8 < m4, "{m16} {m8} {m4}");
    }

    #[test]
    fn layer_size_matches_model_accounting() {
        // layer_size_bytes_at is the factored-out per-layer term of
        // model_size_bytes_at; spot-check the arithmetic directly
        for gran in [Granularity::Tensor, Granularity::Channel] {
            assert_eq!(layer_size_bytes_at(100, 10, gran, BitWidth::Fp32), 4 * 110);
        }
        // int8, per-tensor: 100 weight bytes + 40 bias + 8 scale
        assert_eq!(layer_size_bytes_at(100, 10, Granularity::Tensor, BitWidth::Int8), 148);
        // int8, per-channel: 10 scale groups
        assert_eq!(layer_size_bytes_at(100, 10, Granularity::Channel, BitWidth::Int8), 220);
        // int4 packs two per byte
        assert_eq!(layer_size_bytes_at(100, 10, Granularity::Tensor, BitWidth::Int4), 98);
    }

    #[test]
    fn bias_correction_zeroes_channel_mean_error() {
        // oracle: after folding the per-channel error sum into the bias,
        // the channel-mean residual of (W - Wq) + (b' - b) is exactly 0
        // up to f32 rounding of the final addition
        let w = rand_weight(&[16, 8], 21);
        let b = Tensor {
            shape: vec![8],
            data: (0..8).map(|i| i as f32 * 0.1 - 0.3).collect(),
        };
        for gran in [Granularity::Tensor, Granularity::Channel] {
            let sums = bias_correction_sums(&w, Scheme::Symmetric, gran, BitWidth::Int4);
            let fq = fake_quant_weights_at(&w, Scheme::Symmetric, gran, BitWidth::Int4);
            // some channels must actually carry rounding error at int4
            assert!(sums.iter().any(|s| s.abs() > 1e-6));
            let bc = correct_bias(&b, &w, Scheme::Symmetric, gran, BitWidth::Int4);
            for c in 0..8 {
                let werr: f64 = w
                    .data
                    .iter()
                    .zip(&fq.data)
                    .enumerate()
                    .filter(|(i, _)| i % 8 == c)
                    .map(|(_, (&a, &q))| (a - q) as f64)
                    .sum();
                assert!((sums[c] - werr).abs() < 1e-9);
                let shift = f64::from(bc.data[c]) - f64::from(b.data[c]);
                assert!(
                    (shift - werr).abs() < 1e-6,
                    "channel {c}: bias shift {shift} vs weight error {werr}"
                );
            }
        }
        // fp32 width: no rounding error, correction is a no-op
        let noop = correct_bias(&b, &w, Scheme::Symmetric, Granularity::Tensor, BitWidth::Fp32);
        assert_eq!(noop.data, b.data);
    }

    #[test]
    fn bias_correction_rejects_mismatched_shapes() {
        let w = rand_weight(&[4, 4], 33);
        let b = Tensor { shape: vec![3], data: vec![0.1, 0.2, 0.3] };
        let out = correct_bias(&b, &w, Scheme::Symmetric, Granularity::Tensor, BitWidth::Int8);
        assert_eq!(out.data, b.data);
    }
}
