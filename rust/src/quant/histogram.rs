//! Activation histograms and KL-divergence clipping (paper §4.3).
//!
//! During calibration every quantization-point tensor accumulates a
//! 2048-bin histogram (Glow-style expanding range: when a new batch
//! exceeds the current range the histogram is rebinned into a doubled
//! range, so one pass suffices). Clipping then either uses the raw
//! min/max ("max"), searches a threshold minimizing the KL divergence
//! between the clipped distribution and its 128-level quantized
//! approximation (the TensorRT/Glow procedure the paper builds on), or
//! computes the ACIQ analytical threshold from the histogram's moments
//! with no sweep at all ([`Histogram::aciq_threshold`]; Banner et al.,
//! arXiv:1810.05723).

/// Histogram resolution (Glow's default bin count).
pub const NUM_BINS: usize = 2048;
const QUANT_LEVELS: usize = 128;

/// Distribution-fit decision boundary for ACIQ: the kurtosis proxy
/// rho = E[x^2] / E[|x|]^2 is exactly 2 for a Laplace distribution and
/// pi/2 for a zero-mean Gaussian; tensors split at the midpoint.
const ACIQ_LAPLACE_SPLIT: f64 = (2.0 + std::f64::consts::FRAC_PI_2) / 2.0;

/// Abramowitz & Stegun 7.1.26 erf approximation (|error| <= 1.5e-7),
/// good far beyond the tolerance of the ACIQ stationarity solve.
fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let poly = t
        * (0.254829592
            + t * (-0.284496736 + t * (1.421413741 + t * (-1.453152027 + t * 1.061405429))));
    sign * (1.0 - poly * (-x * x).exp())
}

/// Standard normal density phi(x).
fn normal_pdf(x: f64) -> f64 {
    (-0.5 * x * x).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Standard normal upper-tail mass Q(x) = P[X > x].
fn normal_tail(x: f64) -> f64 {
    0.5 * (1.0 - erf(x / std::f64::consts::SQRT_2))
}

/// ACIQ clip ratio alpha*/b for a Laplace(0, b) tensor quantized to a
/// symmetric `bits`-wide grid: the unique root of r * e^r = 3 * 4^bits
/// (stationarity of clip noise 2 b^2 e^-r plus rounding noise
/// alpha^2 / (3 * 4^bits)). Solved by Newton on f(r) = r + ln r - ln C,
/// which is concave with f(ln C) > 0, so the iteration converges
/// monotonically from r0 = ln C.
fn aciq_laplace_ratio(bits: u32) -> f64 {
    let ln_c = (3.0f64).ln() + 2.0 * f64::from(bits) * (2.0f64).ln();
    let mut r = ln_c.max(1e-3);
    for _ in 0..64 {
        let f = r + r.ln() - ln_c;
        let step = f / (1.0 + 1.0 / r);
        r -= step;
        if step.abs() < 1e-13 * r.max(1.0) {
            break;
        }
    }
    r
}

/// ACIQ clip ratio alpha*/sigma for a zero-mean Gaussian tensor: the
/// unique root of 2 * (phi(r) - r * Q(r)) = r / (3 * 4^bits). The left
/// side minus the right is strictly decreasing (d/dr [phi - r Q] = -Q),
/// so plain bisection finds it.
fn aciq_gauss_ratio(bits: u32) -> f64 {
    let inv_c = 1.0 / (3.0 * 4.0f64.powi(bits as i32));
    let g = |r: f64| 2.0 * (normal_pdf(r) - r * normal_tail(r)) - r * inv_c;
    let (mut lo, mut hi) = (1e-6f64, 40.0f64);
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if g(mid) > 0.0 {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

/// Reusable buffers for the KL threshold scan.
struct KlScratch {
    p: Vec<f64>,
    raw: Vec<f64>,
    q: Vec<f64>,
}

impl KlScratch {
    fn new() -> Self {
        KlScratch {
            p: Vec::with_capacity(NUM_BINS),
            raw: Vec::with_capacity(NUM_BINS),
            q: Vec::with_capacity(NUM_BINS),
        }
    }
}

/// Expanding-range histogram over the absolute values of a tensor stream,
/// plus exact running min/max of the raw values.
#[derive(Clone, Debug)]
pub struct Histogram {
    /// bin i counts |x| in [i*width, (i+1)*width)
    pub bins: Vec<u64>,
    /// current |x| range covered: [0, limit)
    pub limit: f32,
    /// Smallest raw value observed.
    pub min: f32,
    /// Largest raw value observed.
    pub max: f32,
    /// Total values accumulated.
    pub count: u64,
    /// memoized KL threshold (§Perf: the general-space sweep asks for the
    /// same histogram's threshold once per KL config; the search is
    /// ~5 ms/tensor, so recomputing dominated `prepare`). `OnceLock`
    /// rather than `Cell` so calibration caches are `Sync` and shareable
    /// across the worker pool; racing fills compute the same value.
    kl_cache: std::sync::OnceLock<f32>,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// An empty histogram.
    pub fn new() -> Histogram {
        Histogram {
            bins: vec![0; NUM_BINS],
            limit: 0.0,
            min: f32::INFINITY,
            max: f32::NEG_INFINITY,
            count: 0,
            kl_cache: std::sync::OnceLock::new(),
        }
    }

    /// Accumulate a batch of values.
    pub fn update(&mut self, xs: &[f32]) {
        if xs.is_empty() {
            return;
        }
        self.kl_cache.take();
        let mut absmax = 0f32;
        for &x in xs {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
            absmax = absmax.max(x.abs());
        }
        if absmax > self.limit {
            self.grow_to(absmax);
        }
        let inv_width = NUM_BINS as f32 / self.limit;
        for &x in xs {
            let b = ((x.abs() * inv_width) as usize).min(NUM_BINS - 1);
            self.bins[b] += 1;
        }
        self.count += xs.len() as u64;
    }

    /// Double the covered range until `absmax` fits, merging bin pairs.
    fn grow_to(&mut self, absmax: f32) {
        if self.limit == 0.0 {
            // first batch: set the range directly (slightly padded)
            self.limit = absmax * 1.0001;
            return;
        }
        while self.limit < absmax {
            for i in 0..NUM_BINS / 2 {
                self.bins[i] = self.bins[2 * i] + self.bins[2 * i + 1];
            }
            for b in self.bins[NUM_BINS / 2..].iter_mut() {
                *b = 0;
            }
            self.limit *= 2.0;
        }
    }

    /// Mean of |x|^2 over everything accumulated, estimated from the
    /// bins (bin centers weight the counts). Used by the layer-wise
    /// sensitivity ranking to normalize activation quantization noise.
    pub fn mean_sq(&self) -> f64 {
        if self.count == 0 || self.limit <= 0.0 {
            return 0.0;
        }
        let width = self.limit as f64 / NUM_BINS as f64;
        let mut acc = 0.0f64;
        for (i, &c) in self.bins.iter().enumerate() {
            if c > 0 {
                let center = (i as f64 + 0.5) * width;
                acc += c as f64 * center * center;
            }
        }
        acc / self.count as f64
    }

    /// Mean of |x| over everything accumulated, estimated from the bins
    /// (bin centers weight the counts). Together with [`mean_sq`] this
    /// is all ACIQ needs: b = E[|x|] for a Laplace fit, sigma^2 = E[x^2]
    /// for a zero-mean Gaussian fit.
    ///
    /// [`mean_sq`]: Histogram::mean_sq
    pub fn mean_abs(&self) -> f64 {
        if self.count == 0 || self.limit <= 0.0 {
            return 0.0;
        }
        let width = self.limit as f64 / NUM_BINS as f64;
        let mut acc = 0.0f64;
        for (i, &c) in self.bins.iter().enumerate() {
            if c > 0 {
                acc += c as f64 * (i as f64 + 0.5) * width;
            }
        }
        acc / self.count as f64
    }

    /// ACIQ analytical clip threshold alpha* for a symmetric `bits`-wide
    /// grid: fit a Laplace or Gaussian to the histogram's moments
    /// (whichever the kurtosis proxy E[x^2]/E[|x|]^2 says is closer),
    /// then apply the closed-form ratio minimizing expected clipping +
    /// rounding MSE -- no threshold sweep. The result is clamped to the
    /// observed |x| limit (clipping beyond the data is a no-op, and the
    /// clamp keeps fitted tails from inflating wide-width thresholds).
    ///
    /// Returns `None` for degenerate histograms (empty, all-zero, or
    /// non-finite moments); callers fall back to `Max` clipping. This is
    /// the guard that keeps a 0/0 scale out of the quantizer -- same
    /// discipline as `nan_min_cmp` in the ranking paths.
    pub fn aciq_threshold(&self, bits: u32) -> Option<f32> {
        if self.count == 0 || self.limit <= 0.0 {
            return None;
        }
        let mean_abs = self.mean_abs();
        let mean_sq = self.mean_sq();
        if !(mean_abs > 1e-12) || !mean_abs.is_finite() || !mean_sq.is_finite() {
            return None;
        }
        let rho = mean_sq / (mean_abs * mean_abs);
        let t = if rho >= ACIQ_LAPLACE_SPLIT {
            mean_abs * aciq_laplace_ratio(bits)
        } else {
            mean_sq.sqrt() * aciq_gauss_ratio(bits)
        };
        let t = (t as f32).min(self.limit);
        (t.is_finite() && t > 0.0).then_some(t)
    }

    /// Clipped range after ACIQ threshold selection: the observed range
    /// intersected with [-alpha*, alpha*]. Degenerate histograms fall
    /// back to the raw [`range`] (i.e. `Max` clipping).
    ///
    /// [`range`]: Histogram::range
    pub fn aciq_clipped_range(&self, bits: u32) -> (f32, f32) {
        match self.aciq_threshold(bits) {
            Some(t) => (self.min.max(-t), self.max.min(t)),
            None => self.range(),
        }
    }

    /// Raw observed range.
    pub fn range(&self) -> (f32, f32) {
        if self.count == 0 {
            (0.0, 0.0)
        } else {
            (self.min, self.max)
        }
    }

    /// Clipped range after KL-threshold selection: the observed range
    /// intersected with [-T, T] where T minimizes the KL divergence.
    pub fn kl_clipped_range(&self) -> (f32, f32) {
        if self.count == 0 {
            return (0.0, 0.0);
        }
        let t = self.kl_threshold();
        (self.min.max(-t), self.max.min(t))
    }

    /// TensorRT-style KL threshold search over the |x| histogram
    /// (memoized; see §Perf in EXPERIMENTS.md).
    pub fn kl_threshold(&self) -> f32 {
        if let Some(&t) = self.kl_cache.get() {
            return t;
        }
        let width = self.limit / NUM_BINS as f32;
        let total: u64 = self.bins.iter().sum();
        if total == 0 {
            return self.limit.max(1e-12);
        }
        let mut best_i = NUM_BINS;
        let mut best_kl = f64::INFINITY;
        // candidate thresholds: clip after bin i (i quantization source
        // bins); allocations are hoisted out of the scan
        let mut scratch = KlScratch::new();
        let mut i = QUANT_LEVELS;
        while i <= NUM_BINS {
            let kl = self.kl_for_clip(i, &mut scratch);
            if kl < best_kl {
                best_kl = kl;
                best_i = i;
            }
            i += 8; // stride-8 scan: 240 candidates (see DESIGN.md §9)
        }
        let t = (best_i as f32 + 0.5) * width;
        // a racing worker may have filled it with the same value; ignore
        let _ = self.kl_cache.set(t);
        t
    }

    /// KL(P || Q) when clipping the histogram to its first `m` bins.
    ///
    /// Bin 0 is excluded from both distributions: post-ReLU activations
    /// are zero-inflated and the huge zero bin would otherwise dominate
    /// the divergence and drive the threshold toward pathological
    /// over-clipping (the MXNet/TensorRT implementations do the same).
    fn kl_for_clip(&self, m: usize, scratch: &mut KlScratch) -> f64 {
        // P: first m bins, outliers added to the last bin.
        let outliers: u64 = self.bins[m..].iter().sum();
        let p = &mut scratch.p;
        p.clear();
        p.extend(self.bins[..m].iter().map(|&c| c as f64));
        p[0] = 0.0;
        *p.last_mut().unwrap() += outliers as f64;

        // Q: the *raw* first m bins (without the outlier mass -- this is
        // what an int8 grid over the clipped range actually represents)
        // re-binned to QUANT_LEVELS levels then expanded back, preserving
        // which source bins were empty. raw == p except the last bin.
        let raw = &mut scratch.raw;
        raw.clear();
        raw.extend_from_slice(p);
        *raw.last_mut().unwrap() -= outliers as f64;
        let group = m as f64 / QUANT_LEVELS as f64;
        let q = &mut scratch.q;
        q.clear();
        q.resize(m, 0f64);
        for level in 0..QUANT_LEVELS {
            let start = (level as f64 * group).floor() as usize;
            let end = (((level + 1) as f64 * group).floor() as usize).min(m).max(start + 1);
            let slice = &raw[start..end];
            let sum: f64 = slice.iter().sum();
            let nonzero = slice.iter().filter(|&&x| x > 0.0).count();
            if nonzero > 0 {
                let avg = sum / nonzero as f64;
                for (j, &val) in slice.iter().enumerate() {
                    if val > 0.0 {
                        q[start + j] = avg;
                    }
                }
            }
        }

        let (p, q) = (&scratch.p, &scratch.q);
        let psum: f64 = p.iter().sum();
        let qsum: f64 = q.iter().sum();
        if psum == 0.0 || qsum == 0.0 {
            return f64::INFINITY;
        }
        let mut kl = 0.0;
        for (pi, qi) in p.iter().zip(q.iter()) {
            if *pi > 0.0 {
                let pp = pi / psum;
                let qq = (qi / qsum).max(1e-12);
                kl += pp * (pp / qq).ln();
            }
        }
        kl
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn tracks_min_max() {
        let mut h = Histogram::new();
        h.update(&[-1.0, 2.0, 0.5]);
        assert_eq!(h.range(), (-1.0, 2.0));
        assert_eq!(h.count, 3);
    }

    #[test]
    fn grows_range_preserving_counts() {
        let mut h = Histogram::new();
        h.update(&[1.0; 100]);
        let before: u64 = h.bins.iter().sum();
        h.update(&[8.0; 10]); // forces multiple doublings
        let after: u64 = h.bins.iter().sum();
        assert_eq!(before + 10, after);
        assert!(h.limit >= 8.0);
    }

    #[test]
    fn kl_threshold_clips_outliers() {
        // gaussian bulk + a few extreme outliers: threshold should land
        // well below the outliers
        let mut rng = Pcg32::seeded(1);
        let mut xs: Vec<f32> = (0..100_000).map(|_| rng.normal()).collect();
        xs.extend([50.0; 5]);
        let mut h = Histogram::new();
        h.update(&xs);
        let t = h.kl_threshold();
        assert!(t < 25.0, "threshold {t} did not clip outliers");
        assert!(t > 1.0, "threshold {t} clipped the bulk");
        let (lo, hi) = h.kl_clipped_range();
        assert!(lo >= -25.0 && hi <= 25.0);
    }

    #[test]
    fn kl_keeps_clean_range() {
        // no outliers: threshold stays near the true max
        let mut rng = Pcg32::seeded(2);
        let xs: Vec<f32> = (0..50_000).map(|_| rng.range_f32(-3.0, 3.0)).collect();
        let mut h = Histogram::new();
        h.update(&xs);
        let t = h.kl_threshold();
        assert!(t > 2.0, "threshold {t} over-clipped a uniform distribution");
    }

    #[test]
    fn empty_histogram_is_safe() {
        let h = Histogram::new();
        assert_eq!(h.range(), (0.0, 0.0));
        assert_eq!(h.kl_clipped_range(), (0.0, 0.0));
        assert_eq!(h.aciq_threshold(8), None);
        assert_eq!(h.aciq_clipped_range(8), (0.0, 0.0));
    }

    #[test]
    fn aciq_laplace_ratio_solves_stationarity() {
        // the Laplace alpha*/b ratio must satisfy r * e^r = 3 * 4^bits
        for bits in [2u32, 3, 4, 8, 16] {
            let r = aciq_laplace_ratio(bits);
            let c = 3.0 * 4.0f64.powi(bits as i32);
            let residual = (r * r.exp() - c).abs() / c;
            assert!(residual < 1e-9, "bits={bits}: residual {residual}");
        }
        // Banner et al. report alpha* = 2.83b / 3.89b / 5.03b for 2/3/4
        // bits (table in arXiv:1810.05723 §3)
        assert!((aciq_laplace_ratio(2) - 2.83).abs() < 0.05);
        assert!((aciq_laplace_ratio(3) - 3.89).abs() < 0.05);
        assert!((aciq_laplace_ratio(4) - 5.03).abs() < 0.05);
    }

    #[test]
    fn aciq_gauss_ratio_solves_stationarity() {
        // the Gaussian alpha*/sigma ratio must satisfy
        // 2 * (phi(r) - r * Q(r)) = r / (3 * 4^bits)
        for bits in [2u32, 3, 4, 8] {
            let r = aciq_gauss_ratio(bits);
            let lhs = 2.0 * (normal_pdf(r) - r * normal_tail(r));
            let rhs = r / (3.0 * 4.0f64.powi(bits as i32));
            assert!(
                (lhs - rhs).abs() < 1e-8,
                "bits={bits}: lhs {lhs} vs rhs {rhs}"
            );
        }
        // Banner et al. report alpha* ~= 2.55 sigma at 4 bits
        assert!((aciq_gauss_ratio(4) - 2.55).abs() < 0.15);
        // ratios must grow with bit width (finer grids tolerate wider
        // ranges)
        assert!(aciq_gauss_ratio(8) > aciq_gauss_ratio(4));
        assert!(aciq_laplace_ratio(8) > aciq_laplace_ratio(4));
    }

    #[test]
    fn aciq_clips_heavy_tails() {
        // Laplace(0, 1) samples: rho = E[x^2]/E|x|^2 = 2, so the fit
        // picks Laplace and the 4-bit threshold lands near 5.03 * b,
        // well inside the ~11 b observed extreme
        let mut rng = Pcg32::seeded(7);
        let xs: Vec<f32> = (0..100_000)
            .map(|_| {
                let u = rng.range_f32(-0.4999, 0.4999);
                -u.signum() * (1.0 - 2.0 * u.abs()).ln()
            })
            .collect();
        let mut h = Histogram::new();
        h.update(&xs);
        let absmax = xs.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        let t = h.aciq_threshold(4).expect("non-degenerate");
        assert!(t > 3.0 && t < 7.0, "4-bit Laplace threshold {t}");
        assert!(t < absmax, "threshold {t} did not clip the {absmax} tail");
        let (lo, hi) = h.aciq_clipped_range(4);
        assert!(lo >= -t && hi <= t);
    }

    #[test]
    fn aciq_gaussian_threshold_tracks_sigma() {
        // N(0, 1) samples: rho ~= pi/2, the fit picks Gaussian and the
        // 4-bit threshold lands near 2.55 sigma
        let mut rng = Pcg32::seeded(9);
        let xs: Vec<f32> = (0..100_000).map(|_| rng.normal()).collect();
        let mut h = Histogram::new();
        h.update(&xs);
        let t = h.aciq_threshold(4).expect("non-degenerate");
        assert!(t > 2.1 && t < 3.1, "4-bit Gaussian threshold {t}");
    }

    #[test]
    fn aciq_degenerate_falls_back_to_max() {
        // all-zero tensor: limit stays 0, threshold must refuse rather
        // than produce a 0/0 scale, and the clipped range equals the raw
        // range (Max behavior)
        let mut h = Histogram::new();
        h.update(&[0.0; 256]);
        assert_eq!(h.aciq_threshold(8), None);
        assert_eq!(h.aciq_clipped_range(8), h.range());

        // single repeated value: every fit overshoots the data, the
        // clamp pulls alpha* back to the observed limit, and clipping
        // becomes a no-op -- identical to Max
        let mut h = Histogram::new();
        h.update(&[3.0; 100]);
        let t = h.aciq_threshold(8).expect("non-degenerate");
        assert!(t >= 3.0, "threshold {t} clipped a constant tensor");
        assert_eq!(h.aciq_clipped_range(8), h.range());
    }

    #[test]
    fn mean_abs_matches_bins() {
        let mut h = Histogram::new();
        let xs: Vec<f32> = (0..10_000).map(|i| (i % 100) as f32 / 50.0 - 1.0).collect();
        h.update(&xs);
        let exact: f64 = xs.iter().map(|x| f64::from(x.abs())).sum::<f64>() / xs.len() as f64;
        let est = h.mean_abs();
        assert!(
            (est - exact).abs() < 0.01,
            "mean_abs {est} vs exact {exact}"
        );
        assert!(h.mean_abs() > 0.0 && h.mean_sq() > 0.0);
    }
}
