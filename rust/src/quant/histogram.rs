//! Activation histograms and KL-divergence clipping (paper §4.3).
//!
//! During calibration every quantization-point tensor accumulates a
//! 2048-bin histogram (Glow-style expanding range: when a new batch
//! exceeds the current range the histogram is rebinned into a doubled
//! range, so one pass suffices). Clipping then either uses the raw
//! min/max ("max") or searches a threshold minimizing the KL divergence
//! between the clipped distribution and its 128-level quantized
//! approximation (the TensorRT/Glow procedure the paper builds on).

/// Histogram resolution (Glow's default bin count).
pub const NUM_BINS: usize = 2048;
const QUANT_LEVELS: usize = 128;

/// Reusable buffers for the KL threshold scan.
struct KlScratch {
    p: Vec<f64>,
    raw: Vec<f64>,
    q: Vec<f64>,
}

impl KlScratch {
    fn new() -> Self {
        KlScratch {
            p: Vec::with_capacity(NUM_BINS),
            raw: Vec::with_capacity(NUM_BINS),
            q: Vec::with_capacity(NUM_BINS),
        }
    }
}

/// Expanding-range histogram over the absolute values of a tensor stream,
/// plus exact running min/max of the raw values.
#[derive(Clone, Debug)]
pub struct Histogram {
    /// bin i counts |x| in [i*width, (i+1)*width)
    pub bins: Vec<u64>,
    /// current |x| range covered: [0, limit)
    pub limit: f32,
    /// Smallest raw value observed.
    pub min: f32,
    /// Largest raw value observed.
    pub max: f32,
    /// Total values accumulated.
    pub count: u64,
    /// memoized KL threshold (§Perf: the 96-config sweep asks for the
    /// same histogram's threshold once per KL config; the search is
    /// ~5 ms/tensor, so recomputing dominated `prepare`). `OnceLock`
    /// rather than `Cell` so calibration caches are `Sync` and shareable
    /// across the worker pool; racing fills compute the same value.
    kl_cache: std::sync::OnceLock<f32>,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// An empty histogram.
    pub fn new() -> Histogram {
        Histogram {
            bins: vec![0; NUM_BINS],
            limit: 0.0,
            min: f32::INFINITY,
            max: f32::NEG_INFINITY,
            count: 0,
            kl_cache: std::sync::OnceLock::new(),
        }
    }

    /// Accumulate a batch of values.
    pub fn update(&mut self, xs: &[f32]) {
        if xs.is_empty() {
            return;
        }
        self.kl_cache.take();
        let mut absmax = 0f32;
        for &x in xs {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
            absmax = absmax.max(x.abs());
        }
        if absmax > self.limit {
            self.grow_to(absmax);
        }
        let inv_width = NUM_BINS as f32 / self.limit;
        for &x in xs {
            let b = ((x.abs() * inv_width) as usize).min(NUM_BINS - 1);
            self.bins[b] += 1;
        }
        self.count += xs.len() as u64;
    }

    /// Double the covered range until `absmax` fits, merging bin pairs.
    fn grow_to(&mut self, absmax: f32) {
        if self.limit == 0.0 {
            // first batch: set the range directly (slightly padded)
            self.limit = absmax * 1.0001;
            return;
        }
        while self.limit < absmax {
            for i in 0..NUM_BINS / 2 {
                self.bins[i] = self.bins[2 * i] + self.bins[2 * i + 1];
            }
            for b in self.bins[NUM_BINS / 2..].iter_mut() {
                *b = 0;
            }
            self.limit *= 2.0;
        }
    }

    /// Mean of |x|^2 over everything accumulated, estimated from the
    /// bins (bin centers weight the counts). Used by the layer-wise
    /// sensitivity ranking to normalize activation quantization noise.
    pub fn mean_sq(&self) -> f64 {
        if self.count == 0 || self.limit <= 0.0 {
            return 0.0;
        }
        let width = self.limit as f64 / NUM_BINS as f64;
        let mut acc = 0.0f64;
        for (i, &c) in self.bins.iter().enumerate() {
            if c > 0 {
                let center = (i as f64 + 0.5) * width;
                acc += c as f64 * center * center;
            }
        }
        acc / self.count as f64
    }

    /// Raw observed range.
    pub fn range(&self) -> (f32, f32) {
        if self.count == 0 {
            (0.0, 0.0)
        } else {
            (self.min, self.max)
        }
    }

    /// Clipped range after KL-threshold selection: the observed range
    /// intersected with [-T, T] where T minimizes the KL divergence.
    pub fn kl_clipped_range(&self) -> (f32, f32) {
        if self.count == 0 {
            return (0.0, 0.0);
        }
        let t = self.kl_threshold();
        (self.min.max(-t), self.max.min(t))
    }

    /// TensorRT-style KL threshold search over the |x| histogram
    /// (memoized; see §Perf in EXPERIMENTS.md).
    pub fn kl_threshold(&self) -> f32 {
        if let Some(&t) = self.kl_cache.get() {
            return t;
        }
        let width = self.limit / NUM_BINS as f32;
        let total: u64 = self.bins.iter().sum();
        if total == 0 {
            return self.limit.max(1e-12);
        }
        let mut best_i = NUM_BINS;
        let mut best_kl = f64::INFINITY;
        // candidate thresholds: clip after bin i (i quantization source
        // bins); allocations are hoisted out of the scan
        let mut scratch = KlScratch::new();
        let mut i = QUANT_LEVELS;
        while i <= NUM_BINS {
            let kl = self.kl_for_clip(i, &mut scratch);
            if kl < best_kl {
                best_kl = kl;
                best_i = i;
            }
            i += 8; // stride-8 scan: 240 candidates (see DESIGN.md §9)
        }
        let t = (best_i as f32 + 0.5) * width;
        // a racing worker may have filled it with the same value; ignore
        let _ = self.kl_cache.set(t);
        t
    }

    /// KL(P || Q) when clipping the histogram to its first `m` bins.
    ///
    /// Bin 0 is excluded from both distributions: post-ReLU activations
    /// are zero-inflated and the huge zero bin would otherwise dominate
    /// the divergence and drive the threshold toward pathological
    /// over-clipping (the MXNet/TensorRT implementations do the same).
    fn kl_for_clip(&self, m: usize, scratch: &mut KlScratch) -> f64 {
        // P: first m bins, outliers added to the last bin.
        let outliers: u64 = self.bins[m..].iter().sum();
        let p = &mut scratch.p;
        p.clear();
        p.extend(self.bins[..m].iter().map(|&c| c as f64));
        p[0] = 0.0;
        *p.last_mut().unwrap() += outliers as f64;

        // Q: the *raw* first m bins (without the outlier mass -- this is
        // what an int8 grid over the clipped range actually represents)
        // re-binned to QUANT_LEVELS levels then expanded back, preserving
        // which source bins were empty. raw == p except the last bin.
        let raw = &mut scratch.raw;
        raw.clear();
        raw.extend_from_slice(p);
        *raw.last_mut().unwrap() -= outliers as f64;
        let group = m as f64 / QUANT_LEVELS as f64;
        let q = &mut scratch.q;
        q.clear();
        q.resize(m, 0f64);
        for level in 0..QUANT_LEVELS {
            let start = (level as f64 * group).floor() as usize;
            let end = (((level + 1) as f64 * group).floor() as usize).min(m).max(start + 1);
            let slice = &raw[start..end];
            let sum: f64 = slice.iter().sum();
            let nonzero = slice.iter().filter(|&&x| x > 0.0).count();
            if nonzero > 0 {
                let avg = sum / nonzero as f64;
                for (j, &val) in slice.iter().enumerate() {
                    if val > 0.0 {
                        q[start + j] = avg;
                    }
                }
            }
        }

        let (p, q) = (&scratch.p, &scratch.q);
        let psum: f64 = p.iter().sum();
        let qsum: f64 = q.iter().sum();
        if psum == 0.0 || qsum == 0.0 {
            return f64::INFINITY;
        }
        let mut kl = 0.0;
        for (pi, qi) in p.iter().zip(q.iter()) {
            if *pi > 0.0 {
                let pp = pi / psum;
                let qq = (qi / qsum).max(1e-12);
                kl += pp * (pp / qq).ln();
            }
        }
        kl
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn tracks_min_max() {
        let mut h = Histogram::new();
        h.update(&[-1.0, 2.0, 0.5]);
        assert_eq!(h.range(), (-1.0, 2.0));
        assert_eq!(h.count, 3);
    }

    #[test]
    fn grows_range_preserving_counts() {
        let mut h = Histogram::new();
        h.update(&[1.0; 100]);
        let before: u64 = h.bins.iter().sum();
        h.update(&[8.0; 10]); // forces multiple doublings
        let after: u64 = h.bins.iter().sum();
        assert_eq!(before + 10, after);
        assert!(h.limit >= 8.0);
    }

    #[test]
    fn kl_threshold_clips_outliers() {
        // gaussian bulk + a few extreme outliers: threshold should land
        // well below the outliers
        let mut rng = Pcg32::seeded(1);
        let mut xs: Vec<f32> = (0..100_000).map(|_| rng.normal()).collect();
        xs.extend([50.0; 5]);
        let mut h = Histogram::new();
        h.update(&xs);
        let t = h.kl_threshold();
        assert!(t < 25.0, "threshold {t} did not clip outliers");
        assert!(t > 1.0, "threshold {t} clipped the bulk");
        let (lo, hi) = h.kl_clipped_range();
        assert!(lo >= -25.0 && hi <= 25.0);
    }

    #[test]
    fn kl_keeps_clean_range() {
        // no outliers: threshold stays near the true max
        let mut rng = Pcg32::seeded(2);
        let xs: Vec<f32> = (0..50_000).map(|_| rng.range_f32(-3.0, 3.0)).collect();
        let mut h = Histogram::new();
        h.update(&xs);
        let t = h.kl_threshold();
        assert!(t > 2.0, "threshold {t} over-clipped a uniform distribution");
    }

    #[test]
    fn empty_histogram_is_safe() {
        let h = Histogram::new();
        assert_eq!(h.range(), (0.0, 0.0));
        assert_eq!(h.kl_clipped_range(), (0.0, 0.0));
    }
}
