//! VTA cycle cost model.
//!
//! Models the published VTA micro-architecture (Moreau et al., IEEE Micro
//! 2019) closely enough for the paper's latency-shape claims: a 1x16x16
//! int8 GEMM core (one input vector times a 16x16 weight tile per cycle),
//! a 16-lane vector ALU, and DMA load/store at 16 bytes/cycle. Fusion of
//! conv+ReLU removes the intermediate store + load + separate ALU pass
//! (the paper: "executed in consecutive cycles without extra off-chip
//! memory access").

/// Cycle counters per functional unit.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Cycles {
    /// GEMM-core cycles.
    pub gemm: u64,
    /// Vector-ALU cycles.
    pub alu: u64,
    /// DMA load cycles.
    pub load: u64,
    /// DMA store cycles.
    pub store: u64,
}

/// GEMM-core batch dimension (input vectors per cycle).
pub const GEMM_BATCH: u64 = 1;
/// GEMM-core input-channel tile width.
pub const GEMM_IN: u64 = 16;
/// GEMM-core output-channel tile width.
pub const GEMM_OUT: u64 = 16;
/// Vector-ALU lane count.
pub const ALU_LANES: u64 = 16;
/// DMA throughput (bytes per cycle).
pub const DMA_BYTES_PER_CYCLE: u64 = 16;

impl Cycles {
    /// Sum over all functional units.
    pub fn total(&self) -> u64 {
        self.gemm + self.alu + self.load + self.store
    }

    /// GEMM of [m, k] x [k, n] int8 operands.
    pub fn add_gemm(&mut self, m: u64, k: u64, n: u64) {
        self.gemm += m.div_ceil(GEMM_BATCH)
            * k.div_ceil(GEMM_IN)
            * n.div_ceil(GEMM_OUT);
    }

    /// Elementwise ALU pass over `elems` values (shift/add/min/max).
    pub fn add_alu(&mut self, elems: u64) {
        self.alu += elems.div_ceil(ALU_LANES);
    }

    /// DMA load of `bytes`.
    pub fn add_load(&mut self, bytes: u64) {
        self.load += bytes.div_ceil(DMA_BYTES_PER_CYCLE);
    }

    /// DMA store of `bytes`.
    pub fn add_store(&mut self, bytes: u64) {
        self.store += bytes.div_ceil(DMA_BYTES_PER_CYCLE);
    }

    /// Accumulate another counter set.
    pub fn add(&mut self, other: Cycles) {
        self.gemm += other.gemm;
        self.alu += other.alu;
        self.load += other.load;
        self.store += other.store;
    }

    /// Wall-clock estimate (milliseconds) at a fabric clock in MHz.
    /// Device profiles with different clocks (the PYNQ's canonical
    /// [`PYNQ_CLOCK_MHZ`], an Ultra96 at 333 MHz, ...) all reuse this
    /// instead of hard-coding 100 MHz. Panics on a non-positive or
    /// non-finite clock -- those are configuration bugs, not data.
    pub fn ms_at(&self, clock_mhz: f64) -> f64 {
        assert!(
            clock_mhz.is_finite() && clock_mhz > 0.0,
            "clock must be a positive frequency in MHz, got {clock_mhz}"
        );
        self.total() as f64 / (clock_mhz * 1e6) * 1e3
    }
}

/// The canonical VTA PYNQ fabric clock (MHz).
pub const PYNQ_CLOCK_MHZ: f64 = 100.0;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_tiles_round_up() {
        let mut c = Cycles::default();
        c.add_gemm(1, 17, 16); // k=17 -> 2 tiles
        assert_eq!(c.gemm, 2);
    }

    #[test]
    fn alu_lanes_round_up() {
        let mut c = Cycles::default();
        c.add_alu(17);
        assert_eq!(c.alu, 2);
    }

    #[test]
    fn totals_accumulate() {
        let mut c = Cycles::default();
        c.add_gemm(16, 16, 16);
        c.add_load(32);
        c.add_store(15);
        assert_eq!(c.total(), 16 + 2 + 1);
    }

    #[test]
    fn wallclock_scales_with_the_clock() {
        let mut c = Cycles::default();
        c.add_load(16 * 100_000); // 100k cycles
        assert!((c.ms_at(PYNQ_CLOCK_MHZ) - 1.0).abs() < 1e-12);
        assert!((c.ms_at(200.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "positive frequency")]
    fn zero_clock_is_a_configuration_bug() {
        let _ = Cycles::default().ms_at(0.0);
    }
}
