//! VTA cycle cost model.
//!
//! Models the published VTA micro-architecture (Moreau et al., IEEE Micro
//! 2019) closely enough for the paper's latency-shape claims: a 1x16x16
//! int8 GEMM core (one input vector times a 16x16 weight tile per cycle),
//! a 16-lane vector ALU, and DMA load/store at 16 bytes/cycle. Fusion of
//! conv+ReLU removes the intermediate store + load + separate ALU pass
//! (the paper: "executed in consecutive cycles without extra off-chip
//! memory access").

/// Cycle counters per functional unit.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Cycles {
    pub gemm: u64,
    pub alu: u64,
    pub load: u64,
    pub store: u64,
}

pub const GEMM_BATCH: u64 = 1;
pub const GEMM_IN: u64 = 16;
pub const GEMM_OUT: u64 = 16;
pub const ALU_LANES: u64 = 16;
pub const DMA_BYTES_PER_CYCLE: u64 = 16;

impl Cycles {
    pub fn total(&self) -> u64 {
        self.gemm + self.alu + self.load + self.store
    }

    /// GEMM of [m, k] x [k, n] int8 operands.
    pub fn add_gemm(&mut self, m: u64, k: u64, n: u64) {
        self.gemm += m.div_ceil(GEMM_BATCH)
            * k.div_ceil(GEMM_IN)
            * n.div_ceil(GEMM_OUT);
    }

    /// Elementwise ALU pass over `elems` values (shift/add/min/max).
    pub fn add_alu(&mut self, elems: u64) {
        self.alu += elems.div_ceil(ALU_LANES);
    }

    pub fn add_load(&mut self, bytes: u64) {
        self.load += bytes.div_ceil(DMA_BYTES_PER_CYCLE);
    }

    pub fn add_store(&mut self, bytes: u64) {
        self.store += bytes.div_ceil(DMA_BYTES_PER_CYCLE);
    }

    pub fn add(&mut self, other: Cycles) {
        self.gemm += other.gemm;
        self.alu += other.alu;
        self.load += other.load;
        self.store += other.store;
    }

    /// Wall-clock estimate at the canonical 100 MHz VTA PYNQ clock.
    pub fn ms_at_100mhz(&self) -> f64 {
        self.total() as f64 / 100e6 * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_tiles_round_up() {
        let mut c = Cycles::default();
        c.add_gemm(1, 17, 16); // k=17 -> 2 tiles
        assert_eq!(c.gemm, 2);
    }

    #[test]
    fn alu_lanes_round_up() {
        let mut c = Cycles::default();
        c.add_alu(17);
        assert_eq!(c.alu, 2);
    }

    #[test]
    fn totals_accumulate() {
        let mut c = Cycles::default();
        c.add_gemm(16, 16, 16);
        c.add_load(32);
        c.add_store(15);
        assert_eq!(c.total(), 16 + 2 + 1);
    }
}
