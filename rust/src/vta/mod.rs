//! VTA integer-only execution (paper §6.3, Fig 8).
//!
//! Simulates deploying a quantized model on the Versatile Tensor
//! Accelerator: every tensor is int8 with a power-of-two scale (stored as
//! the exponent e, scale = 2^e), accumulators are int32, and all
//! requantization is multiply-free (rounding arithmetic shifts). The
//! cycle model lives in [`cycles`].
//!
//! Two quantizers are provided:
//! - [`VtaModel::build`]: per-layer exponents from calibration
//!   histograms (Quantune's approach);
//! - [`VtaModel::build_global_scale`]: a single activation exponent for
//!   the whole network (the TVM-VTA baseline the paper reports a ~33%
//!   accuracy drop for).
//!
//! Fusion (the 12-config space's last axis) executes conv+ReLU in
//! consecutive GEMM/ALU cycles without the intermediate store+load; it
//! changes cycle counts, not numerics (with zero-point-0 pow2 grids,
//! relu(requant(x)) == requant(relu(x)) exactly -- DESIGN.md §5 Fig 8).

pub mod cycles;

use std::collections::HashMap;

use anyhow::{anyhow, ensure, Result};

use crate::interp::gemm::gemm_i32;
use crate::ir::{Act, Graph, Op, PoolKind, Tensor};
use crate::quant::{Clipping, Histogram, Scheme, VtaConfig};

pub use cycles::{Cycles, PYNQ_CLOCK_MHZ};

/// int8 tensor + its power-of-two exponent (scale = 2^exp).
#[derive(Clone, Debug)]
pub struct VTensor {
    /// Dimensions, outermost first.
    pub shape: Vec<usize>,
    /// int8 grid values.
    pub data: Vec<i8>,
    /// Power-of-two scale exponent (scale = 2^exp).
    pub exp: i32,
}

/// Rounding arithmetic right shift (negative = left shift). This is the
/// only requantization primitive the simulated hardware has.
#[inline]
pub fn rshift_round(acc: i64, shift: i32) -> i64 {
    if shift > 0 {
        (acc + (1i64 << (shift - 1))) >> shift
    } else {
        acc << (-shift)
    }
}

#[inline]
fn sat_i8(v: i64) -> i8 {
    v.clamp(-128, 127) as i8
}

/// Exponent of a pow2 scheme scale for a range.
fn exp_for_range(lo: f32, hi: f32) -> i32 {
    let p = Scheme::Pow2.params_from_range(lo, hi);
    p.scale.log2().round() as i32
}

/// A VTA-deployable integer-only model.
pub struct VtaModel {
    /// The model graph being simulated.
    pub graph: Graph,
    /// per weighted layer: int8 weights (HWIO / [in,out]) + exponent
    qweights: HashMap<String, (Vec<i8>, Vec<usize>, i32)>,
    /// per weighted layer: int32 bias at scale 2^(e_in + e_w)
    qbiases: HashMap<String, Vec<i32>>,
    /// exponent of every tensor in the graph (quant points calibrated,
    /// pass-through ops inherit their input's)
    exps: HashMap<String, i32>,
    /// Execute conv+ReLU as one fused accelerator op.
    pub fusion: bool,
}

impl VtaModel {
    /// Per-layer exponents from calibration histograms (Quantune).
    /// `hists` rows follow `graph.quant_points()` order.
    pub fn build(
        graph: &Graph,
        weights: &HashMap<String, Tensor>,
        hists: &[Histogram],
        cfg: &VtaConfig,
    ) -> Result<VtaModel> {
        let qpoints = graph.quant_points();
        ensure!(hists.len() == qpoints.len(), "histogram arity mismatch");
        let mut point_exp = HashMap::new();
        for (name, h) in qpoints.iter().zip(hists) {
            let (lo, hi) = match cfg.clip {
                Clipping::Max => h.range(),
                Clipping::Kl => h.kl_clipped_range(),
                // the enumerated VTA space never emits Aciq, but the
                // type admits it: use the analytical int8 threshold
                Clipping::Aciq => h.aciq_clipped_range(8),
            };
            point_exp.insert(name.clone(), exp_for_range(lo, hi));
        }
        Self::build_with_exponents(graph, weights, point_exp, cfg.fusion)
    }

    /// Single global scale for the whole network -- the TVM-VTA baseline
    /// of Fig 8 ("the choice of a quantization scale for the whole
    /// network ... can be imprecise for small values and truncate large
    /// values"). One fixed-point format serves every tensor INCLUDING
    /// the weights, so small weight values collapse to a handful of
    /// quantization levels while wide activations saturate.
    pub fn build_global_scale(
        graph: &Graph,
        weights: &HashMap<String, Tensor>,
        hists: &[Histogram],
        fusion: bool,
    ) -> Result<VtaModel> {
        let qpoints = graph.quant_points();
        ensure!(hists.len() == qpoints.len(), "histogram arity mismatch");
        let mut lo = 0f32;
        let mut hi = 0f32;
        for h in hists {
            let (l, m) = h.range();
            lo = lo.min(l);
            hi = hi.max(m);
        }
        for name in graph.weight_names() {
            if name.ends_with("_w") {
                if let Some(w) = weights.get(&name) {
                    let (l, m) = w.range();
                    lo = lo.min(l);
                    hi = hi.max(m);
                }
            }
        }
        let e = exp_for_range(lo, hi);
        let point_exp = qpoints.iter().map(|n| (n.clone(), e)).collect();
        Self::build_with_exponents_impl(graph, weights, point_exp, fusion, Some(e))
    }

    fn build_with_exponents(
        graph: &Graph,
        weights: &HashMap<String, Tensor>,
        point_exp: HashMap<String, i32>,
        fusion: bool,
    ) -> Result<VtaModel> {
        Self::build_with_exponents_impl(graph, weights, point_exp, fusion, None)
    }

    fn build_with_exponents_impl(
        graph: &Graph,
        weights: &HashMap<String, Tensor>,
        point_exp: HashMap<String, i32>,
        fusion: bool,
        weight_exp_override: Option<i32>,
    ) -> Result<VtaModel> {
        // propagate exponents to non-quant-point tensors
        let mut exps: HashMap<String, i32> = HashMap::new();
        exps.insert(
            "input".into(),
            *point_exp.get("input").ok_or_else(|| anyhow!("missing input exp"))?,
        );
        for n in &graph.nodes {
            let e = if let Some(&e) = point_exp.get(&n.name) {
                e
            } else {
                // pass-through ops (pool, shuffle) inherit input exponent
                exps[&n.inputs[0]]
            };
            exps.insert(n.name.clone(), e);
        }

        // quantize weights + biases
        let mut qweights = HashMap::new();
        let mut qbiases = HashMap::new();
        for n in &graph.nodes {
            if !n.has_weights() {
                continue;
            }
            let w = weights
                .get(&format!("{}_w", n.name))
                .ok_or_else(|| anyhow!("missing weight {}_w", n.name))?;
            let b = weights
                .get(&format!("{}_b", n.name))
                .ok_or_else(|| anyhow!("missing weight {}_b", n.name))?;
            let (lo, hi) = w.range();
            let ew = weight_exp_override.unwrap_or_else(|| exp_for_range(lo, hi));
            let sw = (ew as f32).exp2();
            let qw: Vec<i8> = w
                .data
                .iter()
                .map(|&x| sat_i8((x / sw).round_ties_even() as i64))
                .collect();
            let e_in = exps[&n.inputs[0]];
            // bias lives at the accumulator scale 2^(e_in + e_w)
            let sb = ((e_in + ew) as f32).exp2();
            let qb: Vec<i32> = b
                .data
                .iter()
                .map(|&x| (x / sb).round_ties_even() as i32)
                .collect();
            qweights.insert(n.name.clone(), (qw, w.shape.clone(), ew));
            qbiases.insert(n.name.clone(), qb);
        }

        Ok(VtaModel { graph: graph.clone(), qweights, qbiases, exps, fusion })
    }

    /// Quantize a normalized f32 input batch to the input grid.
    pub fn quantize_input(&self, x: &Tensor) -> VTensor {
        let e = self.exps["input"];
        let s = (e as f32).exp2();
        VTensor {
            shape: x.shape.clone(),
            data: x
                .data
                .iter()
                .map(|&v| sat_i8((v / s).round_ties_even() as i64))
                .collect(),
            exp: e,
        }
    }

    /// Integer-only forward. Returns int32 logits [N, classes] and the
    /// cycle count for one batch.
    pub fn forward(&self, x: &Tensor) -> Result<(Vec<i32>, Vec<usize>, Cycles)> {
        let mut cyc = Cycles::default();
        let qx = self.quantize_input(x);
        cyc.add_load(qx.data.len() as u64);

        let mut env: HashMap<&str, VTensor> = HashMap::new();
        let mut logits: Option<(Vec<i32>, Vec<usize>)> = None;
        env.insert("input", qx);

        for node in &self.graph.nodes {
            let ins: Vec<&VTensor> = node
                .inputs
                .iter()
                .map(|i| env.get(i.as_str()).ok_or_else(|| anyhow!("missing {i}")))
                .collect::<Result<_>>()?;
            let e_out = self.exps[&node.name];
            let t = match &node.op {
                Op::Conv { k, stride, pad, in_ch, out_ch, groups, act } => self
                    .conv_int(
                        ins[0], node, *k, *stride, *pad, *in_ch, *out_ch, *groups,
                        *act, e_out, &mut cyc,
                    )?,
                Op::Pool { kind, k, stride, pad } => {
                    pool_int(ins[0], *kind, *k, *stride, *pad, &mut cyc)
                }
                Op::Gap => gap_int(ins[0], e_out, &mut cyc),
                Op::Add { act } => add_int(ins[0], ins[1], *act, e_out, &mut cyc),
                Op::Concat => concat_int(&ins, e_out, &mut cyc),
                Op::Shuffle { groups } => shuffle_int(ins[0], *groups, &mut cyc),
                Op::Dense { in_dim, out_dim } => {
                    let (acc, n) = self.dense_int(ins[0], node, *in_dim, *out_dim, &mut cyc)?;
                    // final layer: argmax over the int32 accumulator
                    let preds = acc
                        .chunks_exact(*out_dim)
                        .map(|row| {
                            row.iter().enumerate().max_by_key(|(_, &v)| v).unwrap().0
                        })
                        .collect();
                    logits = Some((acc, preds));
                    let _ = n;
                    // dense is the last node in all our graphs
                    VTensor { shape: vec![0], data: vec![], exp: e_out }
                }
            };
            env.insert(node.name.as_str(), t);
        }

        let (acc, preds) =
            logits.ok_or_else(|| anyhow!("graph has no dense output layer"))?;
        Ok((acc, preds, cyc))
    }

    #[allow(clippy::too_many_arguments)]
    fn conv_int(
        &self,
        x: &VTensor,
        node: &crate::ir::Node,
        k: usize,
        stride: usize,
        pad: usize,
        in_ch: usize,
        out_ch: usize,
        groups: usize,
        act: Act,
        e_out: i32,
        cyc: &mut Cycles,
    ) -> Result<VTensor> {
        let (n, h, w) = (x.shape[0], x.shape[1], x.shape[2]);
        ensure!(x.shape[3] == in_ch, "conv {}: channel mismatch", node.name);
        let (qw, wshape, ew) = &self.qweights[&node.name];
        let bias = &self.qbiases[&node.name];
        let cg = in_ch / groups;
        let outg = out_ch / groups;
        let oh = (h + 2 * pad - k) / stride + 1;
        let ow = (w + 2 * pad - k) / stride + 1;
        let m = n * oh * ow;
        let cols = k * k * cg;

        cyc.add_load(qw.len() as u64 + 4 * bias.len() as u64);
        cyc.add_load(x.data.len() as u64);

        // shift from accumulator scale 2^(e_x + e_w) to output 2^(e_out)
        let shift = e_out - x.exp - ew;
        let relu6_cap = (6.0 / (e_out as f32).exp2()).round_ties_even() as i64;

        let mut out = vec![0i8; m * out_ch];
        let mut patches = vec![0i32; m * cols];
        let mut wm = vec![0i32; cols * outg];
        let mut acc = vec![0i32; m * outg];
        for g in 0..groups {
            // im2col into i32 operands
            patches.iter_mut().for_each(|v| *v = 0);
            for ni in 0..n {
                for oy in 0..oh {
                    for ox in 0..ow {
                        let row = ((ni * oh + oy) * ow + ox) * cols;
                        for ky in 0..k {
                            let iy = (oy * stride + ky) as isize - pad as isize;
                            if iy < 0 || iy >= h as isize {
                                continue;
                            }
                            for kx in 0..k {
                                let ix = (ox * stride + kx) as isize - pad as isize;
                                if ix < 0 || ix >= w as isize {
                                    continue;
                                }
                                let src = ((ni * h + iy as usize) * w + ix as usize)
                                    * in_ch
                                    + g * cg;
                                let dst = row + (ky * k + kx) * cg;
                                for i in 0..cg {
                                    patches[dst + i] = x.data[src + i] as i32;
                                }
                            }
                        }
                    }
                }
            }
            // weight matrix [cols, outg] for this group
            let (_k1, _k2, _cg, oc) = (wshape[0], wshape[1], wshape[2], wshape[3]);
            for r in 0..cols {
                for j in 0..outg {
                    wm[r * outg + j] = qw[r * oc + g * outg + j] as i32;
                }
            }
            acc.iter_mut().for_each(|v| *v = 0);
            gemm_i32(m, cols, outg, &patches, &wm, &mut acc);
            cyc.add_gemm(m as u64, cols as u64, outg as u64);

            // epilogue: bias, activation (fused or separate), requantize
            for r in 0..m {
                for j in 0..outg {
                    let mut a = acc[r * outg + j] as i64 + bias[g * outg + j] as i64;
                    if self.fusion {
                        // activation on the int32 accumulator, then requant
                        a = match act {
                            Act::None => a,
                            Act::Relu => a.max(0),
                            Act::Relu6 => a, // capped after requant below
                        };
                    }
                    let mut q = rshift_round(a, shift);
                    match act {
                        Act::None => {}
                        Act::Relu => q = q.max(0),
                        Act::Relu6 => q = q.clamp(0, relu6_cap),
                    }
                    out[r * out_ch + g * outg + j] = sat_i8(q);
                }
            }
        }
        // epilogue cycle cost: fused = one ALU pass; unfused = store the
        // int32 accumulator, reload, separate ALU pass, store again
        let elems = (m * out_ch) as u64;
        cyc.add_alu(elems); // requant shift pass
        if act != Act::None {
            if self.fusion {
                cyc.add_alu(elems); // relu in consecutive cycles, no DMA
            } else {
                cyc.add_store(4 * elems);
                cyc.add_load(4 * elems);
                cyc.add_alu(elems);
            }
        }
        cyc.add_store(elems);
        Ok(VTensor { shape: vec![n, oh, ow, out_ch], data: out, exp: e_out })
    }

    fn dense_int(
        &self,
        x: &VTensor,
        node: &crate::ir::Node,
        in_dim: usize,
        out_dim: usize,
        cyc: &mut Cycles,
    ) -> Result<(Vec<i32>, usize)> {
        let n = x.shape[0];
        ensure!(x.data.len() == n * in_dim, "dense input shape");
        let (qw, _shape, _ew) = &self.qweights[&node.name];
        let bias = &self.qbiases[&node.name];
        cyc.add_load(qw.len() as u64 + 4 * bias.len() as u64 + x.data.len() as u64);
        let a: Vec<i32> = x.data.iter().map(|&v| v as i32).collect();
        let b: Vec<i32> = qw.iter().map(|&v| v as i32).collect();
        let mut acc = vec![0i32; n * out_dim];
        for row in acc.chunks_exact_mut(out_dim) {
            row.copy_from_slice(bias);
        }
        gemm_i32(n, in_dim, out_dim, &a, &b, &mut acc);
        cyc.add_gemm(n as u64, in_dim as u64, out_dim as u64);
        cyc.add_store(4 * acc.len() as u64);
        Ok((acc, n))
    }
}

/// Static cycle estimate of one integer-only forward pass: replays the
/// exact cycle accounting of [`VtaModel::forward`] from inferred shapes
/// alone, without weights, calibration, or input data. The estimate is
/// *equal* to the counters a real `forward` of a `batch`-image input
/// reports (the accounting depends only on shapes), which makes it the
/// VTA latency model for the multi-objective search: configs only differ
/// in cycles through `fusion`.
pub fn estimate_cycles(graph: &Graph, fusion: bool, batch: usize) -> Result<Cycles> {
    let shapes = graph.infer_shapes()?;
    let elems = |name: &str| -> u64 {
        (batch * shapes[name].iter().product::<usize>()) as u64
    };
    let mut cyc = Cycles::default();
    cyc.add_load(elems("input"));
    for node in &graph.nodes {
        let x = node.inputs[0].as_str();
        match &node.op {
            Op::Conv { k, in_ch, out_ch, groups, act, .. } => {
                let out = &shapes[&node.name];
                let (oh, ow) = (out[0], out[1]);
                let cg = in_ch / groups;
                let outg = out_ch / groups;
                let m = (batch * oh * ow) as u64;
                let cols = (k * k * cg) as u64;
                let qw = (k * k * cg * out_ch) as u64;
                cyc.add_load(qw + 4 * *out_ch as u64);
                cyc.add_load(elems(x));
                for _ in 0..*groups {
                    cyc.add_gemm(m, cols, outg as u64);
                }
                let n_out = m * *out_ch as u64;
                cyc.add_alu(n_out); // requant shift pass
                if *act != Act::None {
                    if fusion {
                        cyc.add_alu(n_out);
                    } else {
                        cyc.add_store(4 * n_out);
                        cyc.add_load(4 * n_out);
                        cyc.add_alu(n_out);
                    }
                }
                cyc.add_store(n_out);
            }
            Op::Pool { k, .. } => cyc.add_alu(elems(&node.name) * (k * k) as u64),
            Op::Gap => cyc.add_alu(elems(x)),
            Op::Add { .. } => cyc.add_alu(3 * elems(&node.name)),
            Op::Concat => cyc.add_alu(elems(&node.name)),
            Op::Shuffle { .. } => {
                cyc.add_load(elems(&node.name));
                cyc.add_store(elems(&node.name));
            }
            Op::Dense { in_dim, out_dim } => {
                let qw = (in_dim * out_dim) as u64;
                cyc.add_load(qw + 4 * *out_dim as u64 + (batch * in_dim) as u64);
                cyc.add_gemm(batch as u64, *in_dim as u64, *out_dim as u64);
                cyc.add_store(4 * (batch * out_dim) as u64);
            }
        }
    }
    Ok(cyc)
}

fn pool_int(
    x: &VTensor,
    kind: PoolKind,
    k: usize,
    stride: usize,
    pad: usize,
    cyc: &mut Cycles,
) -> VTensor {
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let oh = (h + 2 * pad - k) / stride + 1;
    let ow = (w + 2 * pad - k) / stride + 1;
    let mut data = vec![0i8; n * oh * ow * c];
    for ni in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                for ci in 0..c {
                    let mut mx = i32::MIN;
                    let mut sum = 0i64;
                    let mut cnt = 0i64;
                    for ky in 0..k {
                        let iy = (oy * stride + ky) as isize - pad as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kx in 0..k {
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            let v = x.data
                                [((ni * h + iy as usize) * w + ix as usize) * c + ci]
                                as i32;
                            mx = mx.max(v);
                            sum += v as i64;
                            cnt += 1;
                        }
                    }
                    let out = match kind {
                        PoolKind::Max => mx as i64,
                        PoolKind::Avg => {
                            // integer reciprocal multiply: round(2^16/cnt)
                            let recip = ((1i64 << 16) + cnt / 2) / cnt;
                            rshift_round(sum * recip, 16)
                        }
                    };
                    data[((ni * oh + oy) * ow + ox) * c + ci] = sat_i8(out);
                }
            }
        }
    }
    cyc.add_alu((n * oh * ow * c * k * k) as u64);
    VTensor { shape: vec![n, oh, ow, c], data, exp: x.exp }
}

fn gap_int(x: &VTensor, e_out: i32, cyc: &mut Cycles) -> VTensor {
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let hw = (h * w) as i64;
    let mut data = vec![0i8; n * c];
    // out = sum * 2^(e_in - e_out) / hw, as fixed-point multiply-shift
    let mult = (((x.exp - e_out) as f64).exp2() / hw as f64 * (1i64 << 20) as f64)
        .round() as i64;
    for ni in 0..n {
        for ci in 0..c {
            let mut sum = 0i64;
            for p in 0..h * w {
                sum += x.data[(ni * h * w + p) * c + ci] as i64;
            }
            data[ni * c + ci] = sat_i8(rshift_round(sum * mult, 20));
        }
    }
    cyc.add_alu((n * h * w * c) as u64);
    VTensor { shape: vec![n, c], data, exp: e_out }
}

/// Rescale an int8 value between pow2 grids with a rounding shift.
#[inline]
fn rescale(q: i8, e_from: i32, e_to: i32) -> i64 {
    rshift_round(q as i64, e_to - e_from)
}

fn add_int(a: &VTensor, b: &VTensor, act: Act, e_out: i32, cyc: &mut Cycles) -> VTensor {
    assert_eq!(a.shape, b.shape, "add shape mismatch");
    let relu6_cap = (6.0 / (e_out as f32).exp2()).round_ties_even() as i64;
    let data = a
        .data
        .iter()
        .zip(&b.data)
        .map(|(&x, &y)| {
            let mut v = rescale(x, a.exp, e_out) + rescale(y, b.exp, e_out);
            match act {
                Act::None => {}
                Act::Relu => v = v.max(0),
                Act::Relu6 => v = v.clamp(0, relu6_cap),
            }
            sat_i8(v)
        })
        .collect();
    cyc.add_alu(3 * a.data.len() as u64);
    VTensor { shape: a.shape.clone(), data, exp: e_out }
}

fn concat_int(ins: &[&VTensor], e_out: i32, cyc: &mut Cycles) -> VTensor {
    let (n, h, w) = (ins[0].shape[0], ins[0].shape[1], ins[0].shape[2]);
    let cs: Vec<usize> = ins.iter().map(|t| t.shape[3]).collect();
    let c_total: usize = cs.iter().sum();
    let mut data = vec![0i8; n * h * w * c_total];
    let rows = n * h * w;
    for r in 0..rows {
        let mut off = 0;
        for (t, &ct) in ins.iter().zip(&cs) {
            for i in 0..ct {
                data[r * c_total + off + i] = sat_i8(rescale(t.data[r * ct + i], t.exp, e_out));
            }
            off += ct;
        }
    }
    cyc.add_alu((rows * c_total) as u64);
    VTensor { shape: vec![n, h, w, c_total], data, exp: e_out }
}

fn shuffle_int(x: &VTensor, groups: usize, cyc: &mut Cycles) -> VTensor {
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let per = c / groups;
    let mut data = vec![0i8; x.data.len()];
    let rows = n * h * w;
    for r in 0..rows {
        for g in 0..groups {
            for p in 0..per {
                data[r * c + p * groups + g] = x.data[r * c + g * per + p];
            }
        }
    }
    cyc.add_load(x.data.len() as u64);
    cyc.add_store(x.data.len() as u64);
    VTensor { shape: x.shape.clone(), data, exp: x.exp }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::CalibCount;
    use crate::util::{Json, Pcg32};

    fn tiny_graph() -> Graph {
        Graph::from_meta(
            &Json::parse(
                r#"{"name": "t", "input_shape": [8, 8, 3], "num_classes": 4,
            "nodes": [
              {"name": "c1", "op": "conv", "inputs": ["input"], "k": 3,
               "stride": 1, "pad": 1, "in_ch": 3, "out_ch": 8, "groups": 1,
               "act": "relu"},
              {"name": "p1", "op": "pool", "inputs": ["c1"], "kind": "max",
               "k": 2, "stride": 2, "pad": 0},
              {"name": "g1", "op": "gap", "inputs": ["p1"]},
              {"name": "d1", "op": "dense", "inputs": ["g1"], "in_dim": 8,
               "out_dim": 4}]}"#,
            )
            .unwrap(),
        )
        .unwrap()
    }

    fn rand_setup() -> (Graph, HashMap<String, Tensor>, Vec<Histogram>, Tensor) {
        let g = tiny_graph();
        let mut rng = Pcg32::seeded(3);
        let mut weights = HashMap::new();
        for name in g.weight_names() {
            let shape = match name.as_str() {
                "c1_w" => vec![3, 3, 3, 8],
                "c1_b" => vec![8],
                "d1_w" => vec![8, 4],
                "d1_b" => vec![4],
                _ => unreachable!(),
            };
            let n: usize = shape.iter().product();
            weights.insert(
                name,
                Tensor {
                    shape,
                    data: (0..n).map(|_| rng.normal() * 0.3).collect(),
                },
            );
        }
        let x = Tensor {
            shape: vec![2, 8, 8, 3],
            data: (0..2 * 8 * 8 * 3).map(|_| rng.range_f32(-1.0, 1.0)).collect(),
        };
        // calibrate from a real fp32 pass
        let interp = crate::interp::Interpreter::new(&g, &weights);
        let (_, acts) = interp.forward_acts(&x).unwrap();
        let hists = acts
            .iter()
            .map(|t| {
                let mut h = Histogram::new();
                h.update(&t.data);
                h
            })
            .collect();
        (g, weights, hists, x)
    }

    fn cfg() -> VtaConfig {
        VtaConfig { calib: CalibCount::C64, clip: Clipping::Max, fusion: true }
    }

    #[test]
    fn integer_forward_tracks_fp32() {
        let (g, weights, hists, x) = rand_setup();
        let m = VtaModel::build(&g, &weights, &hists, &cfg()).unwrap();
        let (_, preds, cyc) = m.forward(&x).unwrap();
        assert_eq!(preds.len(), 2);
        assert!(cyc.total() > 0);

        // int8 logits should usually agree with fp32 argmax on this easy case
        let interp = crate::interp::Interpreter::new(&g, &weights);
        let fp = interp.forward(&x).unwrap();
        let fp_preds = crate::interp::argmax_batch(&fp);
        let agree = preds.iter().zip(&fp_preds).filter(|(a, b)| a == b).count();
        assert!(agree >= 1, "int-only predictions diverged entirely");
    }

    #[test]
    fn fusion_changes_cycles_not_numerics() {
        let (g, weights, hists, x) = rand_setup();
        let fused = VtaModel::build(&g, &weights, &hists, &cfg()).unwrap();
        let unfused = VtaModel::build(
            &g,
            &weights,
            &hists,
            &VtaConfig { fusion: false, ..cfg() },
        )
        .unwrap();
        let (la, pa, ca) = fused.forward(&x).unwrap();
        let (lb, pb, cb) = unfused.forward(&x).unwrap();
        assert_eq!(la, lb);
        assert_eq!(pa, pb);
        assert!(cb.total() > ca.total(), "unfused must cost extra cycles");
    }

    #[test]
    fn global_scale_is_coarser() {
        let (g, weights, hists, _) = rand_setup();
        let tuned = VtaModel::build(&g, &weights, &hists, &cfg()).unwrap();
        let global = VtaModel::build_global_scale(&g, &weights, &hists, true).unwrap();
        // global exponent must be >= every tuned exponent (coarser grids)
        for (k, &e) in &tuned.exps {
            assert!(global.exps[k] >= e, "{k}: global {} < tuned {e}", global.exps[k]);
        }
    }

    #[test]
    fn static_cycle_estimate_matches_the_real_forward() {
        // the estimator must replay forward()'s accounting exactly --
        // it is the VTA latency model of the multi-objective search
        let (g, weights, hists, x) = rand_setup();
        for fusion in [true, false] {
            let m = VtaModel::build(
                &g,
                &weights,
                &hists,
                &VtaConfig { fusion, ..cfg() },
            )
            .unwrap();
            let (_, _, measured) = m.forward(&x).unwrap();
            let estimated = estimate_cycles(&g, fusion, x.shape[0]).unwrap();
            assert_eq!(estimated, measured, "fusion={fusion}");
        }
        // fused estimates are strictly cheaper, as in Fig 8
        let fused = estimate_cycles(&g, true, 1).unwrap();
        let unfused = estimate_cycles(&g, false, 1).unwrap();
        assert!(fused.total() < unfused.total());
    }

    #[test]
    fn rshift_round_rounds_half_up() {
        assert_eq!(rshift_round(5, 1), 3); // 2.5 -> 3
        assert_eq!(rshift_round(-5, 1), -2); // -2.5 -> -2 (adds +half)
        assert_eq!(rshift_round(4, 2), 1);
        assert_eq!(rshift_round(3, 0), 3);
        assert_eq!(rshift_round(3, -2), 12);
    }

    #[test]
    fn quantize_input_saturates() {
        let (g, weights, hists, _) = rand_setup();
        let m = VtaModel::build(&g, &weights, &hists, &cfg()).unwrap();
        let big = Tensor { shape: vec![1, 1, 1, 3], data: vec![1e9, -1e9, 0.0] };
        let q = m.quantize_input(&big);
        assert_eq!(q.data[0], 127);
        assert_eq!(q.data[1], -128);
        assert_eq!(q.data[2], 0);
    }
}
