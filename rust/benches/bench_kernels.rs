//! Quantized kernel engine benchmark (BENCHMARKS.md §Kernel engine).
//!
//! A/Bs the four GEMM routes of the interpreter on conv-shaped operands
//! and persists the numbers to `BENCH_kernels.json`:
//! - `f32_scalar`   -- the legacy fake-quant route: f32 GEMM over
//!   dequantized grid values ([`gemm_f32_tiled`], 1 thread);
//! - `f32_blocked`  -- the packed, register-tiled f32 kernel;
//! - `i8`           -- the integer path end to end: quantize the
//!   activation to i8, zero-point-corrected i8 GEMM, dequantize the i32
//!   accumulator (B packed once per shape, as the interpreter packs once
//!   per layer call);
//! - `i4_packed`    -- same with nibble-packed int4 weights consumed
//!   two-per-byte.
//!
//! Every integer kernel is cross-checked against a naive centered
//! reference on a slice of the operands before any timing, so a wrong
//! kernel fails the bench instead of reporting a fast lie.
//!
//! ```bash
//! cargo bench --offline --bench bench_kernels            # full shapes
//! cargo bench --offline --bench bench_kernels -- --smoke # CI smoke
//! cargo bench --offline --bench bench_kernels -- --out path.json
//! ```

use anyhow::Result;

use quantune::interp::gemm::gemm_f32_tiled;
use quantune::interp::kernels::{
    gemm_f32_blocked_tiled, pack_b_f32, pack_b_i4, pack_b_i8, qgemm_i4_tiled,
    qgemm_i8_tiled,
};
use quantune::quant::QParams;
use quantune::util::stats::percentile;
use quantune::util::{Json, Pcg32, Timer};

fn bench<F: FnMut() -> Result<()>>(name: &str, reps: usize, mut f: F) -> Result<(f64, f64)> {
    for _ in 0..2.max(reps / 10) {
        f()?;
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Timer::start();
        f()?;
        samples.push(t.ms());
    }
    let p50 = percentile(&samples, 50.0);
    let mean: f64 = samples.iter().sum::<f64>() / samples.len() as f64;
    println!("{name:<44} p50 {p50:>9.3} ms   mean {mean:>9.3} ms   ({reps} reps)");
    Ok((p50, mean))
}

/// One shape's operand set: a quantized activation (raw i8 + its exact
/// dequantized f32 view) and a weight on the int8 and int4 grids (raw +
/// dequantized f32 views), mirroring what the two interpreter routes see.
struct Operands {
    m: usize,
    k: usize,
    n: usize,
    pa: QParams,
    qa: Vec<i8>,
    a_f32: Vec<f32>,
    zw8: i32,
    sw8: f32,
    qb8: Vec<i8>,
    b8_f32: Vec<f32>,
    zw4: i32,
    sw4: f32,
    qb4: Vec<i8>,
}

fn operands(m: usize, k: usize, n: usize, seed: u64) -> Operands {
    let mut rng = Pcg32::seeded(seed);
    // asymmetric activation grid with ~50% of values at the zero point,
    // the post-ReLU sparsity the zero-skip path is keyed to
    let pa = QParams { scale: 0.02, zero_point: -20, qmin: -128.0, qmax: 127.0 };
    let qa: Vec<i8> = (0..m * k)
        .map(|_| {
            if rng.chance(0.5) {
                pa.zero_point as i8
            } else {
                (rng.below(256) as i32 - 128) as i8
            }
        })
        .collect();
    let a_f32: Vec<f32> =
        qa.iter().map(|&q| (q as i32 - pa.zero_point) as f32 * pa.scale).collect();
    let (zw8, sw8) = (3i32, 0.01f32);
    let qb8: Vec<i8> = (0..k * n).map(|_| (rng.below(256) as i32 - 128) as i8).collect();
    let b8_f32: Vec<f32> = qb8.iter().map(|&q| (q as i32 - zw8) as f32 * sw8).collect();
    let (zw4, sw4) = (-1i32, 0.1f32);
    let qb4: Vec<i8> = (0..k * n).map(|_| (rng.below(16) as i32 - 8) as i8).collect();
    Operands { m, k, n, pa, qa, a_f32, zw8, sw8, qb8, b8_f32, zw4, sw4, qb4 }
}

/// Naive centered integer reference over the first `rows` rows.
fn naive_centered(o: &Operands, rows: usize, qb: &[i8], zw: i32) -> Vec<i32> {
    let (k, n) = (o.k, o.n);
    let za = o.pa.zero_point;
    let mut c = vec![0i32; rows * n];
    for i in 0..rows {
        for j in 0..n {
            for p in 0..k {
                c[i * n + j] +=
                    (o.qa[i * k + p] as i32 - za) * (qb[p * n + j] as i32 - zw);
            }
        }
    }
    c
}

/// Correctness gate: both integer kernels must reproduce the naive
/// centered product exactly on a slice of the real bench operands.
fn verify(o: &Operands) -> Result<()> {
    let rows = o.m.min(32);
    let a = &o.qa[..rows * o.k];
    let p8 = pack_b_i8(o.k, o.n, |p, j| o.qb8[p * o.n + j]);
    let mut c = vec![0i32; rows * o.n];
    qgemm_i8_tiled(rows, a, o.pa.zero_point, &p8, &[o.zw8], &mut c, 1);
    anyhow::ensure!(
        c == naive_centered(o, rows, &o.qb8, o.zw8),
        "i8 kernel mismatch at {}x{}x{}",
        o.m,
        o.k,
        o.n
    );
    let p4 = pack_b_i4(o.k, o.n, |p, j| o.qb4[p * o.n + j]);
    let mut c = vec![0i32; rows * o.n];
    qgemm_i4_tiled(rows, a, o.pa.zero_point, &p4, &[o.zw4], &mut c, 1);
    anyhow::ensure!(
        c == naive_centered(o, rows, &o.qb4, o.zw4),
        "i4 kernel mismatch at {}x{}x{}",
        o.m,
        o.k,
        o.n
    );
    Ok(())
}

fn kernel_row(p50: f64, mean: f64, macs: usize) -> Json {
    Json::obj(vec![
        ("p50_ms", Json::num(p50)),
        ("mean_ms", Json::num(mean)),
        ("gmacs_per_s", Json::num(macs as f64 / (p50 * 1e6))),
    ])
}

fn bench_shape(m: usize, k: usize, n: usize, reps: usize, seed: u64) -> Result<Json> {
    println!("\n-- shape {m}x{k}x{n} --");
    let o = operands(m, k, n, seed);
    verify(&o)?;
    let macs = m * k * n;
    let mut kernels = Vec::new();

    // legacy route: f32 GEMM over the dequantized (fake-quant) operands
    let mut c32 = vec![0.0f32; m * n];
    let (p50_scalar, mean) = bench(&format!("f32_scalar ({m}x{k}x{n})"), reps, || {
        c32.iter_mut().for_each(|v| *v = 0.0);
        gemm_f32_tiled(m, k, n, &o.a_f32, &o.b8_f32, &mut c32, 1);
        std::hint::black_box(&c32);
        Ok(())
    })?;
    kernels.push(("f32_scalar", kernel_row(p50_scalar, mean, macs)));

    let pf = pack_b_f32(k, n, &o.b8_f32);
    let (p50, mean) = bench(&format!("f32_blocked ({m}x{k}x{n})"), reps, || {
        c32.iter_mut().for_each(|v| *v = 0.0);
        gemm_f32_blocked_tiled(m, &o.a_f32, &pf, &mut c32, 1);
        std::hint::black_box(&c32);
        Ok(())
    })?;
    kernels.push(("f32_blocked", kernel_row(p50, mean, macs)));

    // integer route end to end, as conv_int runs it: quantize the f32
    // activation to i8, corrected integer GEMM, dequantize the i32
    // accumulator (B packed once per shape = once per layer call)
    let p8 = pack_b_i8(k, n, |p, j| o.qb8[p * o.n + j]);
    let mut acc = vec![0i32; m * n];
    let mut out = vec![0.0f32; m * n];
    let acc_scale8 = o.pa.scale * o.sw8;
    let (p50_i8, mean) = bench("i8 (quant+qgemm+dequant)", reps, || {
        let xq: Vec<i8> = o.a_f32.iter().map(|&v| o.pa.quantize(v) as i8).collect();
        qgemm_i8_tiled(m, &xq, o.pa.zero_point, &p8, &[o.zw8], &mut acc, 1);
        for (ov, &av) in out.iter_mut().zip(&acc) {
            *ov = av as f32 * acc_scale8;
        }
        std::hint::black_box(&out);
        Ok(())
    })?;
    kernels.push(("i8", kernel_row(p50_i8, mean, macs)));

    let p4 = pack_b_i4(k, n, |p, j| o.qb4[p * o.n + j]);
    let acc_scale4 = o.pa.scale * o.sw4;
    let (p50, mean) = bench("i4_packed (quant+qgemm+dequant)", reps, || {
        let xq: Vec<i8> = o.a_f32.iter().map(|&v| o.pa.quantize(v) as i8).collect();
        qgemm_i4_tiled(m, &xq, o.pa.zero_point, &p4, &[o.zw4], &mut acc, 1);
        for (ov, &av) in out.iter_mut().zip(&acc) {
            *ov = av as f32 * acc_scale4;
        }
        std::hint::black_box(&out);
        Ok(())
    })?;
    kernels.push(("i4_packed", kernel_row(p50, mean, macs)));

    let speedup = p50_scalar / p50_i8;
    println!("   i8 speedup vs f32_scalar: {speedup:.2}x");
    Ok(Json::obj(vec![
        ("m", Json::num(m as f64)),
        ("k", Json::num(k as f64)),
        ("n", Json::num(n as f64)),
        ("kernels", Json::obj(kernels)),
        ("speedup_i8_vs_f32", Json::num(speedup)),
    ]))
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let out_path = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| "BENCH_kernels.json".to_string());

    // conv-shaped GEMMs: m = imgs * out pixels, k = kh*kw*cin, n = cout
    let shapes: &[(usize, usize, usize)] = if smoke {
        &[(512, 144, 32), (256, 288, 64)]
    } else {
        &[(8192, 144, 32), (2048, 288, 64), (1024, 576, 128), (64, 256, 16)]
    };
    let reps = if smoke { 3 } else { 20 };
    println!(
        "kernel engine A/B: {} shape(s), {} reps, single-thread (see \
         BENCHMARKS.md \u{00a7}Kernel engine)",
        shapes.len(),
        reps
    );

    let mut rows = Vec::new();
    for (i, &(m, k, n)) in shapes.iter().enumerate() {
        rows.push(bench_shape(m, k, n, reps, 40 + i as u64)?);
    }
    let report = Json::obj(vec![
        ("threads", Json::num(1.0)),
        ("smoke", Json::Bool(smoke)),
        (
            "variants",
            Json::Arr(
                ["f32_scalar", "f32_blocked", "i8", "i4_packed"]
                    .iter()
                    .map(|v| Json::str(*v))
                    .collect(),
            ),
        ),
        ("shapes", Json::Arr(rows)),
    ]);
    report.write_file(std::path::Path::new(&out_path))?;
    println!("\nwrote {out_path}");
    Ok(())
}
